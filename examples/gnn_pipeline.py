"""BARQ as the GNN data pipeline: fanout neighbor sampling expressed as
merge-join scans over the sorted quad store, feeding GraphSAGE minibatch
training (DESIGN.md §3 — the paper's engine as a first-class framework
feature).

    PYTHONPATH=src python examples/gnn_pipeline.py --steps 30
"""

import argparse
import time

import jax
import numpy as np

from repro.core.storage import QuadStore
from repro.models.gnn.models import GNNConfig, GraphShape, init, loss as gnn_loss
from repro.models.gnn.sampler import BARQSampler, CSRSampler
from repro.pipeline.data import GraphPipeline, block_to_model_inputs
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--n-nodes", type=int, default=2000)
    ap.add_argument("--sampler", choices=("barq", "csr"), default="barq")
    args = ap.parse_args()

    # synthetic power-law graph
    rng = np.random.RandomState(0)
    n = args.n_nodes
    src = rng.randint(0, n, n * 8).astype(np.int32)
    dst = (rng.pareto(1.5, n * 8) * n / 10).astype(np.int64) % n
    keep = src != dst
    edge_index = np.unique(np.stack([src[keep], dst[keep].astype(np.int32)]), axis=1)
    # labels recoverable from the id-keyed synthetic features (learnable task)
    labels = ((np.arange(n) % 977) * 5 // 977).astype(np.int32)
    print(f"graph: {n} nodes, {edge_index.shape[1]} edges")

    if args.sampler == "barq":
        store = QuadStore()
        for i in range(n):
            store.dict.encode(i)  # node ids encode as themselves
        pred = store.dict.encode(":edge")
        g = store.dict.encode(":default")
        quads = np.stack(
            [edge_index[0], np.full(edge_index.shape[1], pred, np.int32),
             edge_index[1], np.full(edge_index.shape[1], g, np.int32)], axis=1)
        store.add_encoded(quads)
        store.build()
        sampler = BARQSampler(store, ":edge", seed=0)
        print("sampler: BARQ merge-join scans over the quad store")
    else:
        sampler = CSRSampler(edge_index, n, seed=0)
        print("sampler: CSR")

    fanouts = [5, 3]
    batch_nodes = 64
    pipe = GraphPipeline(sampler, labels, n, batch_nodes, fanouts, seed=1)

    d_feat = 32
    n_total = batch_nodes * (1 + fanouts[0] + fanouts[0] * fanouts[1])
    shape = GraphShape(n_total, batch_nodes * fanouts[0] * (1 + fanouts[1]),
                       d_feat, 5)
    cfg = GNNConfig("sage", "graphsage", 2, 64)
    params = init(jax.random.PRNGKey(0), cfg, shape)
    opt = init_opt_state(params)
    opt_cfg = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=args.steps)

    @jax.jit
    def train_step(params, opt, graph):
        l, grads = jax.value_and_grad(gnn_loss)(params, cfg, graph)
        params, opt, m = adamw_update(opt_cfg, params, grads, opt)
        return params, opt, l

    t0 = time.perf_counter()
    losses = []
    for step in range(args.steps):
        block = pipe.batch(step)
        graph = {k: jax.numpy.asarray(v) for k, v in
                 block_to_model_inputs(block, d_feat).items()}
        params, opt, l = train_step(params, opt, graph)
        losses.append(float(l))
        if step % 10 == 0:
            print(f"step {step}: loss {float(l):.4f}")
    k = max(min(10, len(losses) // 3), 1)
    first, last = np.mean(losses[:k]), np.mean(losses[-k:])
    print(f"\n{args.steps} steps in {time.perf_counter() - t0:.1f}s; "
          f"loss {first:.4f} -> {last:.4f}")
    assert last < first, "loss should decrease"
    print("training with the BARQ-backed pipeline works ✓")


if __name__ == "__main__":
    main()
