"""Train a reduced LM config end-to-end with the production substrate
(jitted train step, AdamW, async checkpointing, watchdog), including a
mid-run restart to demonstrate checkpoint/resume fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import logging
import shutil
import tempfile

from repro.launch.train import run


def main():
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro_lm_")
    override = {"global_batch": 8, "seq_len": 128}
    try:
        # phase 1: train the first half, then 'lose the job'
        half = args.steps // 2
        result1, t1 = run(args.arch, "train_4k", half, ckpt_dir,
                          override_shape=override)
        print(f"\nphase 1 done at step {result1['step']} "
              f"(loss {result1['loss']:.4f}); simulating preemption...\n")

        # phase 2: a fresh trainer resumes from the checkpoint
        result2, t2 = run(args.arch, "train_4k", args.steps, ckpt_dir,
                          override_shape=override)
        assert t2.metrics_history[0]["step"] == half + 1, "did not resume!"
        losses = [m["loss"] for m in t1.metrics_history + t2.metrics_history]
        print(f"\nresumed at step {half + 1} ✓")
        print(f"loss: start={losses[0]:.4f} mid={losses[half - 1]:.4f} "
              f"final={losses[-1]:.4f}")
        assert losses[-1] < losses[0], "loss did not improve"
        print("loss improved over training ✓")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
