"""Distributed BARQ: hash-partitioned join + GROUP BY across 8 (placeholder)
devices via shard_map — the multi-pod execution path of DESIGN.md §2.1.

    PYTHONPATH=src python examples/distributed_join.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import collections  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.core import distributed as D  # noqa: E402
from repro.data import generate_social_graph  # noqa: E402


def main():
    print(f"devices: {len(jax.devices())}")
    store, meta = generate_social_graph(scale=0.3)
    print(f"social graph: {meta}")

    # relation 1: (?p1 :knows ?p2) ; relation 2: (?p2 :hasInterest ?tag)
    d = store.dict
    spoc = store.index_array("spoc")
    knows = spoc[spoc[:, 1] == d.lookup(":knows")]
    interest = spoc[spoc[:, 1] == d.lookup(":hasInterest")]
    # join on ?p2: left keyed by object (p2), right keyed by subject
    left = np.stack([knows[:, 2], knows[:, 0]]).astype(np.int32)
    right = np.stack([interest[:, 0], interest[:, 2]]).astype(np.int32)
    print(f"|knows|={left.shape[1]} |interest|={right.shape[1]}")

    mesh = D.engine_mesh()
    join_count = D.make_join_count(mesh, cap_factor=4.0)
    l_sh = D.shard_relation(mesh, left)
    r_sh = D.shard_relation(mesh, right)

    t0 = time.perf_counter()
    count, overflow = join_count(l_sh, r_sh)
    jax.block_until_ready(count)
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    count, overflow = join_count(l_sh, r_sh)
    jax.block_until_ready(count)
    t_steady = time.perf_counter() - t0

    lc = collections.Counter(left[0].tolist())
    rc = collections.Counter(right[0].tolist())
    oracle = sum(lc[k] * rc[k] for k in lc if k in rc)
    print(f"distributed join count = {int(count)} (oracle {oracle}) "
          f"overflow={int(overflow)}")
    assert int(count) == oracle and int(overflow) == 0
    print(f"compile+run: {t_first:.3f}s, steady-state: {t_steady * 1e3:.1f}ms")

    # distributed GROUP BY ?p2 COUNT(*) over the knows relation
    group = D.make_group_count(mesh, cap_factor=4.0, max_groups_per_dev=4096)
    gkeys, gcounts, of = group(l_sh)
    gk, gc = np.asarray(gkeys).ravel(), np.asarray(gcounts).ravel()
    valid = gk != np.iinfo(np.int32).max
    got = {int(k): int(c) for k, c in zip(gk[valid], gc[valid]) if c > 0}
    assert got == dict(lc)
    print(f"distributed group-count over {len(got)} groups matches oracle ✓")


if __name__ == "__main__":
    main()
