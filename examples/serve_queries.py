"""End-to-end driver: serve a batched SPARQL workload (the paper's kind of
system serves queries, not tokens).

Generates LSQB-like + BSBM-like stores, builds a mixed OLTP/analytical
request stream, and serves it through the BARQ engine with plan caching,
reporting throughput and latency percentiles for BARQ vs the legacy
executor (paper §5's comparison, as a serving loop).

    PYTHONPATH=src python examples/serve_queries.py [--requests 200]
"""

import argparse

import numpy as np

from repro.core import EngineConfig
from repro.data import (
    BSBM_EXPLORE_TEMPLATES,
    LSQB_QUERIES,
    generate_ecommerce_graph,
    generate_social_graph,
    instantiate_explore,
)
from repro.serve.query_server import QueryServer


def build_workload(meta, n_requests: int, seed: int = 0):
    """80% OLTP point lookups + 20% analytical (a realistic mix)."""
    rng = np.random.RandomState(seed)
    reqs = []
    explore = list(BSBM_EXPLORE_TEMPLATES.items())
    for i in range(n_requests):
        if rng.rand() < 0.8:
            key, tpl = explore[rng.randint(len(explore))]
            reqs.append((f"explore_{key}", instantiate_explore(tpl, meta, rng)))
        else:
            key = rng.choice(["q1", "q2", "q5"])
            reqs.append((f"lsqb_{key}", None))  # filled below
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.15)
    args = ap.parse_args()

    print("generating stores...")
    social, smeta = generate_social_graph(scale=args.scale)
    shop, emeta = generate_ecommerce_graph(scale=args.scale)

    workload = build_workload(emeta, args.requests)

    for engine in ("barq", "legacy"):
        shop_server = QueryServer(shop, EngineConfig(engine=engine))
        social_server = QueryServer(social, EngineConfig(engine=engine))
        import time

        lats = []
        rows = 0
        t0 = time.perf_counter()
        for key, text in workload:
            if text is None:
                q = LSQB_QUERIES[key.split("_", 1)[1]]
                r = social_server.execute(key, q)
            else:
                r = shop_server.execute(key, text)
            lats.append(r.latency_s)
            rows += r.n_rows
        wall = time.perf_counter() - t0
        lats_ms = np.asarray(lats) * 1e3
        print(
            f"[{engine:6s}] {len(workload)} requests in {wall:.2f}s "
            f"({len(workload) / wall:.1f} qps) | rows={rows} | "
            f"p50={np.percentile(lats_ms, 50):.2f}ms "
            f"p95={np.percentile(lats_ms, 95):.2f}ms "
            f"p99={np.percentile(lats_ms, 99):.2f}ms"
        )


if __name__ == "__main__":
    main()
