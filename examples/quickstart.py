"""Quickstart: load a graph, run SPARQL through BARQ, inspect the profile.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import Engine, EngineConfig, QuadStore

# 1. build a store (insertion API; bulk loading uses add_encoded)
store = QuadStore()
store.add(":Alice", ":knows", ":Bob")
store.add(":Alice", ":knows", ":Carol")
store.add(":Bob", ":knows", ":Carol")
store.add(":Carol", ":knows", ":Dave")
store.add(":Bob", ":worksAt", ":ACME")
store.add(":Carol", ":worksAt", ":ACME")
store.add(":Dave", ":worksAt", ":Initech")
store.add(":Alice", ":age", 31)
store.add(":Bob", ":age", 42)
store.add(":Alice", ":name", '"Alice Liddell"')
store.add(":Bob", ":name", '"Bob Cratchit"')
store.add(":Carol", ":name", '"Carol Danvers"')
store.add(":Dave", ":name", '"Dave Bowman"')
store.build()

# 2. the motivating-example query shape (Figure 1 of the paper)
QUERY = """
SELECT ?a ?c ?company {
  ?a :knows ?b .
  ?b :knows ?c .
  ?c :worksAt ?company .
  FILTER (?a != ?c)
}
"""

engine = Engine(store, EngineConfig(engine="barq"))
result = engine.execute(QUERY)
print("rows:")
for row in result.decoded(store.dict):
    print("  ", row)

# 3. operator-tree profile (paper Listing 1 style)
print("\nprofile:")
print(result.profile())

# 4. same query on the legacy row-based engine — identical answers
legacy = Engine(store, EngineConfig(engine="legacy")).execute(QUERY)
assert sorted(map(str, legacy.decoded(store.dict))) == sorted(
    map(str, result.decoded(store.dict))
)
print("\nlegacy engine agrees ✓")

# 5. aggregation + numeric filter
AGG = """
SELECT ?p (COUNT(DISTINCT ?q) AS ?n) {
  ?p :knows ?q .
} GROUP BY ?p
"""
print("\nfriend counts:", Engine(store).execute(AGG).decoded(store.dict))

# 5b. the vectorized grouping engine (DESIGN.md §10): multi-key GROUP BY
# runs through packed composite keys + segmented-reduction kernels, and
# HAVING filters the aggregate output through the expression VM. Aggregate
# calls are legal inside HAVING — COUNT(?p) here desugars to a hidden
# aggregate the projection strips.
HAVING_Q = """
SELECT ?company (AVG(?age) AS ?avgage) {
  ?p :worksAt ?company .
  OPTIONAL { ?p :age ?age }
} GROUP BY ?company HAVING (COUNT(?p) >= 2)
"""
having_result = Engine(store).execute(HAVING_Q)
print("\ncompanies with >= 2 people (avg age; unbound if none known):")
for row in having_result.decoded(store.dict):
    print("  ", row)
# the profile shows the Group operator's kernel counters
# (group_runs / segment_reduce / segment_reduce_ms) and the Having stage
print("\ngrouping profile:")
print(having_result.profile())

# 6. property paths: the vectorized frontier engine (DESIGN.md §8).
# `:knows+` is the transitive closure; `/` sequences into :worksAt.
PATH = """
SELECT ?reach ?company {
  :Alice :knows+/:worksAt? ?reach .
  ?reach :worksAt ?company
}
"""
path_result = engine.execute(PATH)
print("\nAlice's transitive network (with employers):")
for row in path_result.decoded(store.dict):
    print("  ", row)
# the profile shows the PathExpand operator with its frontier metrics
# (rounds, peak frontier, dedup ratio) and the seed-side choice
print("\npath profile:")
print(path_result.profile())

# 6b. join strategies (DESIGN.md §11): EXPLAIN-style plan output. The
# UNION's output arrives unsorted on ?b, so sorting both inputs for a
# merge join would cost two O(n log n) pipeline breakers — the cost model
# picks the radix-partitioned HashJoin instead (probe side streams
# unsorted; the build side is partitioned once). Forcing join_strategy
# shows the alternative plan; FILTER NOT EXISTS plans onto the same
# machinery as an anti hash/merge join.
from repro.core.planner import explain

STRAT = """
SELECT ?a ?b ?company {
  { ?a :knows ?b } UNION { ?b :knows ?a }
  OPTIONAL { ?b :worksAt ?company }
  FILTER NOT EXISTS { ?b :worksAt :Initech }
}
"""
node, vt = engine.parse(STRAT)
print("\nchosen plan (cost-based — note HashJoin, no Sort below it):")
print(explain(engine.plan(node), vt))
forced = Engine(store, EngineConfig(join_strategy="merge"))
print("\nforced join_strategy='merge' (the pre-§11 double-Sort shape):")
print(explain(forced.plan(forced.parse(STRAT)[0]), vt))
strat_rows = engine.execute(STRAT).decoded(store.dict)
assert sorted(map(str, forced.execute(STRAT).decoded(store.dict))) == sorted(
    map(str, strat_rows)
)
print("\nboth strategies agree ✓:", strat_rows)

# 7. the expression VM (DESIGN.md §9): FILTER/BIND compile to bytecode
# programs at plan time — string predicates evaluate once per distinct
# dictionary term, three-valued logic is exact (COALESCE recovers the
# rows where ?age is unbound instead of erroring them away).
EXPR = """
SELECT ?p ?name ?grp {
  ?p :name ?name .
  OPTIONAL { ?p :age ?age }
  FILTER(REGEX(?name, "^[A-C]") && !CONTAINS(?name, "z"))
  BIND(IF(COALESCE(?age, 0) >= 40, 1, 0) AS ?grp)
}
"""
expr_result = engine.execute(EXPR)
print("\nexpression VM (FILTER(REGEX) + BIND(IF/COALESCE)):")
for row in expr_result.decoded(store.dict):
    print("  ", row)
# the profile's Filter[vm] line carries the program size and fused
# dispatch count/time: expr_ops / expr_dispatches / expr_eval_ms
print("\nexpression profile:")
print(expr_result.profile())

# 8. sideways information passing (DESIGN.md §12): when a join's build
# side is much smaller than its probe side, the planner annotates
# probe-side scans with SipFilter prefilters — the build phase exports a
# bloom filter + key code range, and the scans seek past rows that
# cannot survive the join before the join ever sees them. explain()
# shows the pushed filters (sip=[...] on scans) and their exporters
# (sip-export=[...] on joins); sip="off" disables the rewrite.
SIP_Q = """
SELECT ?p ?q ?company {
  ?p :knows ?q .
  ?p :worksAt ?company .
  ?p :age ?age .
}
"""
sip_engine = Engine(store, EngineConfig(sip="on"))
node, vt = sip_engine.parse(SIP_Q)
print("\nplan with sideways information passing (note sip=/sip-export=):")
print(explain(sip_engine.plan(node), vt))
sip_rows = sip_engine.execute(SIP_Q).decoded(store.dict)
off_rows = Engine(store, EngineConfig(sip="off")).execute(SIP_Q).decoded(store.dict)
assert sorted(map(str, sip_rows)) == sorted(map(str, off_rows))
# the profile surfaces what SIP did: sip_range_seeks / sip_pruned_rows
# on scans, sip_exports on the joins that produced the filters
print("\nSIP on/off agree ✓:", sip_rows)

# 9. query telemetry (DESIGN.md §13): every execute records a QueryTrace —
# lifecycle spans, a per-query kernel ledger (dispatch counts + wall time
# by kernel and backend, exact even when a server interleaves queries),
# and EXPLAIN ANALYZE: the planner's cardinality estimates printed next
# to actual rows, with MISEST(q=...) flags at q-error >= 4.
result2 = engine.execute(QUERY)
print("\nEXPLAIN ANALYZE (est vs actual, misestimates flagged):")
print(result2.explain_analyze())
trace = result2.trace
print("\nlifecycle spans (ms):",
      {name: round(dur * 1e3, 2) for name, _c, _t, dur, _a in trace.spans})
print("kernel ledger:", dict(trace.ledger.counts))
print("pool delta (this query only):", result2.pool_delta())
# the trace exports Chrome-trace JSON — open in ui.perfetto.dev
trace.save_chrome_trace("/tmp/quickstart.trace.json")
print("wrote /tmp/quickstart.trace.json (Perfetto-loadable)")

# 9b. serving metrics: QueryServer aggregates per-request telemetry into
# a registry with sliding-window p50/p99/QPS, plan-cache hit rates, and
# kernel/pool attribution — exported as JSON for dashboards.
from repro.serve.query_server import QueryServer

server = QueryServer(store, EngineConfig(engine="barq"))
workload = [("fig1", QUERY), ("agg", AGG)] * 3
print("\nserved workload:", server.run_workload(workload, warmup=2))
print("metrics snapshot:")
print(server.metrics_json())

# 10. workload history + cardinality feedback (DESIGN.md §14): queries
# group under a canonical template fingerprint (literals, whitespace and
# variable names normalized away), and the engine records each plan
# node's *actual* cardinality into a feedback store keyed by stable node
# fingerprints. Under cardinality_feedback="apply" the planner reads
# those observations back: a query that misestimates on its first run
# (MISEST flags in EXPLAIN ANALYZE) re-plans from observed cardinalities
# on its second — estimates print as est=...(source=feedback) and the
# MISEST flags disappear.
FEEDBACK_Q = """
SELECT ?a ?c {
  ?a :knows ?b . ?b :knows ?c . ?c :age ?x .
  FILTER(?x > 25)
}
"""
# a store big enough that misestimates are real correlation effects, not
# tiny-count noise: a cyclic :knows graph defeats the independence
# assumption on the two-hop join
fb_store = QuadStore()
for i in range(120):
    fb_store.add(f":p{i}", ":knows", f":p{(i * 7 + 1) % 120}")
    fb_store.add(f":p{i}", ":age", 20 + i % 30)
fb_store = fb_store.build()
fb_engine = Engine(fb_store, EngineConfig(engine="barq",
                                          cardinality_feedback="apply"))
run1 = fb_engine.execute(FEEDBACK_Q)
print("\nrun 1 (cold estimates — note any MISEST flags):")
print(run1.explain_analyze())
run2 = fb_engine.execute(FEEDBACK_Q)
print("\nrun 2 (re-planned from observed cardinalities):")
print(run2.explain_analyze())
assert "MISEST" not in run2.explain_analyze()
assert run1.n_rows == run2.n_rows  # feedback changes plans, not answers

# the serving layer accumulates the same history per fingerprint: top
# templates by wall time, q-error leaderboard, latency regressions, and
# an OpenMetrics exposition for scrape-based monitoring
from repro.serve.metrics import validate_openmetrics

fb_server = QueryServer(fb_store, EngineConfig(
    engine="barq", cardinality_feedback="apply"))
fb_server.execute("fq", FEEDBACK_Q)
fb_server.execute("fq", FEEDBACK_Q)
top = fb_server.workload.top_by_wall(3)
print("\nworkload history (top templates):",
      [(t["fingerprint"][:8], t["n"], t["max_q_error"]) for t in top])
exposition = fb_server.openmetrics()
validate_openmetrics(exposition)
print("OpenMetrics exposition validates ✓ "
      f"({exposition.count(chr(10))} lines)")

# 11. out-of-core execution (DESIGN.md §15): EngineConfig.memory_budget
# caps the bytes a pipeline breaker may keep resident. A hash join whose
# build side exceeds it becomes a *grace* hash join — both inputs are
# radix-partitioned once (same key, same partition), non-resident
# partitions spill to spill_dir, and the join is built one partition at
# a time; skewed partitions re-partition recursively with a different
# hash. EXPLAIN shows the chosen fan-out and expected spill up front,
# and the spill counters land in EXPLAIN ANALYZE and the serving
# metrics. With memory_budget=None (the default) plans are untouched.
import tempfile

import numpy as np

rng = np.random.RandomState(11)
big = QuadStore()
for i in range(30_000):
    big.add(f":u{i:06d}", ":follows", f":u{rng.randint(0, 30_000):06d}")
    big.add(f":u{i:06d}", ":city", f":c{rng.randint(0, 200):03d}")
big = big.build()
GRACE_Q = "SELECT ?a ?b ?c { ?a :follows ?b . ?a :city ?c }"

spill_dir = tempfile.mkdtemp(prefix="barq-spill-")
tiny_budget = 64 * 1024  # far below the ~240KB build side
grace_engine = Engine(big, EngineConfig(
    engine="barq", join_strategy="hash",
    memory_budget=tiny_budget, spill_dir=spill_dir,
))
grace_res = grace_engine.execute(GRACE_Q)
print("\ngrace plan under a 64KB memory budget:")
print(grace_engine.explain(GRACE_Q))
print(grace_res.explain_analyze())

resident = Engine(big, EngineConfig(engine="barq", join_strategy="hash"))
assert grace_res.n_rows == resident.execute(GRACE_Q).n_rows
assert "grace" in grace_engine.explain(GRACE_Q)
print(f"same {grace_res.n_rows} rows as the resident build, "
      f"spill dir empty again: {not __import__('glob').glob(spill_dir + '/*.npy')}")

# 12. correctness tooling (DESIGN.md §16): three machine-checked layers.
# barqlint statically checks pool/kernel/stats/dtype discipline over the
# tree (`make lint`); EngineConfig.verify_plans re-derives the planner's
# structural invariants on every plan (sortedness under merge joins,
# SIP soundness, grace/adaptive gating) and raises naming the node;
# EngineConfig.sanitize swaps the arena for a SanitizingBatchPool that
# poisons released buffers and turns ownership-protocol violations into
# immediate SanitizeErrors attributed to the allocating operator. CI
# runs the whole suite with both knobs on (BARQ_SANITIZE=1
# BARQ_VERIFY_PLANS=1) — here we just show the pieces working.
from repro.analysis.lint import RULES, lint_paths
from repro.analysis.sanitize import SanitizeError

hardened = Engine(store, EngineConfig(
    engine="barq", sanitize=True, verify_plans=True))
hr = hardened.execute(QUERY)
c = hardened.pool.counters()
assert c["live"] == 0 and c["allocs"] == c["releases"] + c["pooled"]
assert hardened.pool.leaks() == []
print(f"\nhardened run: {hr.n_rows} rows, pool conservation {c}")

from repro.core.batch import ColumnBatch

victim = ColumnBatch.from_columns((0,), [np.arange(4, dtype=np.int32)],
                                  pool=hardened.pool)
victim.release()
try:
    victim.column(0)
except SanitizeError as e:
    print(f"use-after-release caught: {str(e)[:72]}...")

print(f"barqlint: {len(RULES)} rules, "
      f"{len(lint_paths([__import__('pathlib').Path('src')]))} findings on src/")
