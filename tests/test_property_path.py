"""Property paths (?x :p+ ?y): vectorized frontier engine under barq/mixed
(DESIGN.md §8), row/set evaluation under the legacy engine."""

import numpy as np
import pytest

from repro.core import Engine, EngineConfig, QuadStore


@pytest.fixture()
def chain_store():
    s = QuadStore()
    # a -> b -> c -> d, plus e -> c, and a disjoint cycle f <-> g
    for x, y in [("a", "b"), ("b", "c"), ("c", "d"), ("e", "c"),
                 ("f", "g"), ("g", "f")]:
        s.add(f":{x}", ":next", f":{y}")
    for x in "abcdefg":
        s.add(f":{x}", "rdf:type", ":Node")
    return s.build()


def _closure_oracle(edges):
    adj = {}
    for a, b in edges:
        adj.setdefault(a, set()).add(b)
    out = set()
    for src in adj:
        seen, stack = set(), [src]
        while stack:
            u = stack.pop()
            for v in adj.get(u, ()):
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        out |= {(src, t) for t in seen}
    return out


EDGES = [("a", "b"), ("b", "c"), ("c", "d"), ("e", "c"), ("f", "g"), ("g", "f")]


@pytest.mark.parametrize("engine", ["barq", "legacy", "mixed"])
def test_transitive_closure(chain_store, engine):
    e = Engine(chain_store, EngineConfig(engine=engine))
    r = e.execute("SELECT ?x ?y { ?x :next+ ?y }")
    got = {
        (chain_store.dict.decode(int(a))[1:], chain_store.dict.decode(int(b))[1:])
        for a, b in r.rows.tolist()
    }
    assert got == _closure_oracle(EDGES), engine


@pytest.mark.parametrize("engine", ["barq", "legacy"])
def test_path_joins_with_triple_pattern(chain_store, engine):
    """Path output merge-joins against ordinary scans (adapter in between)."""
    e = Engine(chain_store, EngineConfig(engine=engine))
    r = e.execute(
        "SELECT ?x ?y { ?x :next+ ?y . ?x rdf:type :Node }"
    )
    got = {
        (chain_store.dict.decode(int(a))[1:], chain_store.dict.decode(int(b))[1:])
        for a, b in r.rows.tolist()
    }
    assert got == _closure_oracle(EDGES), engine


def test_path_vectorized_in_barq_profile(chain_store):
    e = Engine(chain_store, EngineConfig(engine="barq"))
    r = e.execute("SELECT ?x ?y { ?x :next+ ?y }")
    prof = r.profile()
    assert "PathExpand" in prof  # vectorized subsystem, no row bridge
    assert "RowToBatch" not in prof
    assert "frontier_rounds" in prof and "dedup_ratio" in prof


def test_path_rowbased_in_legacy_profile(chain_store):
    e = Engine(chain_store, EngineConfig(engine="legacy"))
    r = e.execute("SELECT ?x ?y { ?x :next+ ?y }")
    assert "PathScan" in r.profile()


def test_cycle_terminates(chain_store):
    e = Engine(chain_store, EngineConfig(engine="barq"))
    r = e.execute("SELECT ?x ?y { ?x :next+ ?y }")
    # f+ reaches {g, f}; g+ reaches {f, g}
    names = {
        (chain_store.dict.decode(int(a)), chain_store.dict.decode(int(b)))
        for a, b in r.rows.tolist()
    }
    assert (":f", ":f") in names and (":f", ":g") in names
