"""Vectorized property-path subsystem (DESIGN.md §8): parser grammar,
planner costing, frontier-engine parity against the set-based oracle
(including cycles, self-loops, empty frontiers) across all kernel
backends, and the pooling/profiling contract."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Engine, EngineConfig, QuadStore
from repro.core import algebra as A
from repro.core.legacy.property_path import RowTransitivePath, eval_path_pairs
from repro.core.batch import BatchPool
from repro.core.operators.path import PathExpand
from repro.core.parser import parse_query
from repro.core.paths import PathEngine
from repro.core.paths.expr import (
    PAlt,
    PClosure,
    PInv,
    PLink,
    PSeq,
    matches_zero_length,
    path_repr,
)
from repro.core.planner import PPathExpand, Planner, explain
from repro.core.stats import CLOSURE_DEPTH_CAP, GraphStats

BACKENDS = ("numpy", "jax", "pallas")


# ---------------------------------------------------------------------------
# parser grammar
# ---------------------------------------------------------------------------


def _only_path(query: str):
    node, _ = parse_query(query)
    while not isinstance(node, A.BGP):
        node = node.child
    (pat,) = node.patterns
    assert isinstance(pat, A.PathPattern)
    return pat.expr


@pytest.mark.parametrize("src,expect", [
    ("?x :p+ ?y", PClosure(PLink(":p"), 1)),
    ("?x :p* ?y", PClosure(PLink(":p"), 0)),
    ("?x :p? ?y", PClosure(PLink(":p"), 0, 1)),
    ("?x ^:p ?y", PInv(PLink(":p"))),
    ("?x :p/:q ?y", PSeq((PLink(":p"), PLink(":q")))),
    ("?x :p|:q ?y", PAlt((PLink(":p"), PLink(":q")))),
    ("?x (:p/:q)+ ?y", PClosure(PSeq((PLink(":p"), PLink(":q"))), 1)),
    ("?x :p/:q|:r ?y", PAlt((PSeq((PLink(":p"), PLink(":q"))), PLink(":r")))),
    ("?x ^:p+ ?y", PInv(PClosure(PLink(":p"), 1))),
    ("?x :p/^:q ?y", PSeq((PLink(":p"), PInv(PLink(":q"))))),
    ("?x (a|:p)* ?y", PClosure(PAlt((PLink("rdf:type"), PLink(":p"))), 0)),
])
def test_parse_path_grammar(src, expect):
    assert _only_path("SELECT ?x ?y { " + src + " }") == expect


def test_parse_plain_predicate_stays_triple():
    node, _ = parse_query("SELECT ?x ?y { ?x :p ?y }")
    while not isinstance(node, A.BGP):
        node = node.child
    (pat,) = node.patterns
    assert isinstance(pat, A.TriplePattern)


def test_parse_variable_predicate_path_rejected():
    with pytest.raises(SyntaxError, match="constant predicate"):
        parse_query("SELECT ?x ?y { ?x ?p+ ?y }")
    with pytest.raises(SyntaxError, match="constant predicate"):
        parse_query("SELECT ?x ?y { ?x (:p/?q) ?y }")


def test_path_repr_round_trip():
    e = _only_path("SELECT ?x ?y { ?x (^:p/:q)|:r+ ?y }")
    assert path_repr(e) == "(^:p/:q)|:r+"
    assert matches_zero_length(_only_path("SELECT ?x ?y { ?x :p* ?y }"))
    assert not matches_zero_length(e)


# ---------------------------------------------------------------------------
# graphs + oracle helpers
# ---------------------------------------------------------------------------


def _store_from_edges(edges, extra_preds=()):
    s = QuadStore()
    for p, a, b in edges:
        s.add(f":n{a}", f":{p}", f":n{b}")
    for p, a, b in extra_preds:
        s.add(f":n{a}", f":{p}", f":n{b}")
    return s.build()


def _pairs_from_result(res):
    return set(zip(res.src.tolist(), res.dst.tolist()))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name,edges", [
    ("chain", [("p", i, i + 1) for i in range(12)]),
    ("cycle", [("p", i, (i + 1) % 6) for i in range(6)]),
    ("self_loops", [("p", 0, 0), ("p", 0, 1), ("p", 1, 1)]),
    ("diamond", [("p", 0, 1), ("p", 0, 2), ("p", 1, 3), ("p", 2, 3), ("p", 3, 4)]),
    ("empty_frontier", [("q", 0, 1)]),  # predicate :p has no edges at all
])
def test_closure_matches_oracle(backend, name, edges):
    store = _store_from_edges(edges)
    eng = PathEngine(store, BatchPool(), backend=backend)
    expr = PClosure(PLink(":p"), 1)
    got = _pairs_from_result(eng.evaluate(expr))
    assert got == eval_path_pairs(store, expr), name


@pytest.mark.parametrize("expr", [
    PClosure(PLink(":p"), 0),
    PClosure(PLink(":p"), 0, 1),
    PInv(PClosure(PLink(":p"), 1)),
    PSeq((PLink(":p"), PLink(":q"))),
    PAlt((PLink(":p"), PInv(PLink(":q")))),
    PClosure(PSeq((PLink(":p"), PLink(":q"))), 1),
    PClosure(PAlt((PLink(":p"), PLink(":q"))), 1),
])
def test_operators_match_oracle(expr):
    edges = [("p", 0, 1), ("p", 1, 2), ("p", 2, 0), ("q", 2, 3), ("q", 3, 3)]
    store = _store_from_edges(edges)
    eng = PathEngine(store, BatchPool())
    assert _pairs_from_result(eng.evaluate(expr)) == eval_path_pairs(store, expr)


def _rand_edges(rng, n_nodes, n_edges, preds=("p",)):
    return [
        (preds[int(rng.randint(len(preds)))],
         int(rng.randint(n_nodes)), int(rng.randint(n_nodes)))
        for _ in range(n_edges)
    ]


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_random_graph_parity_all_backends(data):
    """Property parity: random graphs (cycles/self-loops/dead ends) through
    the vectorized engine equal the set-based oracle on every backend."""
    rng = np.random.RandomState(data.draw(st.integers(0, 10**6)))
    n_nodes = data.draw(st.integers(1, 24))
    n_edges = data.draw(st.integers(0, 60))
    store = _store_from_edges(_rand_edges(rng, n_nodes, n_edges, ("p", "q")))
    expr = data.draw(st.sampled_from([
        PClosure(PLink(":p"), 1),
        PClosure(PLink(":p"), 0),
        PClosure(PAlt((PLink(":p"), PLink(":q"))), 1),
        PSeq((PClosure(PLink(":p"), 1), PLink(":q"))),
        PInv(PClosure(PLink(":p"), 1)),
    ]))
    want = eval_path_pairs(store, expr)
    for backend in BACKENDS:
        eng = PathEngine(store, BatchPool(), backend=backend)
        assert _pairs_from_result(eng.evaluate(expr)) == want, backend


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_transitive_parity_vs_row_engine(data):
    """The vectorized `+` operator against RowTransitivePath (the §5 row
    baseline) on random graphs, via the full operator protocol."""
    rng = np.random.RandomState(data.draw(st.integers(0, 10**6)))
    n_nodes = data.draw(st.integers(1, 20))
    n_edges = data.draw(st.integers(0, 50))
    store = _store_from_edges(_rand_edges(rng, n_nodes, n_edges))
    row = RowTransitivePath(store, ":p", 0, 1)
    want = set()
    while True:
        r = row.next_row()
        if r is None:
            break
        want.add((r[0], r[1]))
    op = PathExpand(
        store, PClosure(PLink(":p"), 1), A.V(0), A.V(1),
        batch_size=64, pool=BatchPool(),
    )
    got = set()
    prev = None
    while True:
        b = op.next_batch()
        if b is None:
            break
        for row_vals in b.to_rows_array():
            s, o = int(row_vals[0]), int(row_vals[1])
            got.add((s, o))
            assert prev is None or s >= prev  # subject-sorted emission
            prev = s
        b.release()
    assert got == want


# ---------------------------------------------------------------------------
# seed sides / bound endpoints
# ---------------------------------------------------------------------------


@pytest.fixture()
def chain_store():
    return _store_from_edges([("p", i, i + 1) for i in range(8)])


def test_bound_subject_seeds_forward(chain_store):
    e = Engine(chain_store, EngineConfig(engine="barq"))
    r = e.execute("SELECT ?y { :n0 :p+ ?y }")
    assert r.n_rows == 8
    assert "seed=subject" in r.profile()


def test_bound_object_seeds_reverse(chain_store):
    e = Engine(chain_store, EngineConfig(engine="barq"))
    r = e.execute("SELECT ?x { ?x :p+ :n8 }")
    assert r.n_rows == 8
    assert "seed=object" in r.profile()


def test_both_bound_existence(chain_store):
    e = Engine(chain_store, EngineConfig(engine="barq"))
    assert e.execute("SELECT ?z { :n0 :p+ :n5 . :n5 :p ?z }").n_rows == 1
    assert e.execute("SELECT ?z { :n5 :p+ :n0 . :n5 :p ?z }").n_rows == 0


def test_same_var_both_ends_cycles_only():
    store = _store_from_edges([("p", 0, 1), ("p", 1, 0), ("p", 2, 3)])
    e = Engine(store, EngineConfig(engine="barq"))
    r = e.execute("SELECT ?x { ?x :p+ ?x }")
    got = {v[0] for v in r.rows.tolist()}
    assert got == {store.dict.lookup(":n0"), store.dict.lookup(":n1")}


@pytest.mark.parametrize("engine", ["barq", "legacy", "mixed"])
@pytest.mark.parametrize("q", [
    "SELECT ?x ?y { ?x :p* ?y }",
    "SELECT ?x ?y { ?x :p? ?y }",
    "SELECT ?x ?y { ?x ^:p+ ?y }",
    "SELECT ?x ?y { ?x (:p/:p)+ ?y }",
    "SELECT ?x ?y { ?x (:p|^:p)+ ?y }",
    "SELECT ?y { :n2 :p* ?y }",
])
def test_engine_equivalence_on_paths(engine, q, chain_store):
    want = Engine(chain_store, EngineConfig(engine="legacy")).execute(q)
    got = Engine(chain_store, EngineConfig(engine=engine)).execute(q)
    as_set = lambda r: {tuple(row) for row in r.rows.tolist()}
    assert as_set(got) == as_set(want), (engine, q)


def test_10k_edge_tree_end_to_end():
    """Acceptance: an LSQB/BSBM-style transitive query over a >=10k-edge
    tree runs through the vectorized subsystem end-to-end; the result size
    equals the closed-form ancestor count (sum of node depths)."""
    n_edges, branch = 10_000, 2
    store = QuadStore()
    quads = np.zeros((n_edges, 4), dtype=np.int32)
    pid = store.dict.encode(":child")
    gid = store.dict.encode(":default")
    for i in range(n_edges):
        quads[i] = (
            store.dict.encode(f":n{i + 1}"), pid,
            store.dict.encode(f":n{i // branch}"), gid,
        )
    store.add_encoded(quads)
    store.build()
    depth = [0] * (n_edges + 1)
    for j in range(1, n_edges + 1):
        depth[j] = depth[(j - 1) // branch] + 1
    want = sum(depth)
    e = Engine(store, EngineConfig(engine="barq"))
    r = e.execute("SELECT ?s ?o { ?s :child+ ?o }")
    assert r.n_rows == want
    prof = r.profile()
    assert "PathExpand" in prof and "frontier_rounds" in prof
    # spot-check: the deepest node reaches exactly its ancestor chain
    r2 = e.execute(f"SELECT ?o {{ :n{n_edges} :child+ ?o }}")
    assert r2.n_rows == depth[n_edges]


# ---------------------------------------------------------------------------
# planner costing
# ---------------------------------------------------------------------------


def test_closure_multiplier_pinned():
    # chain: 99 edges over 99 subjects (k=1) -> capped average depth
    assert GraphStats.closure_multiplier(99, 99, 99) == float(
        min(99, CLOSURE_DEPTH_CAP)
    )
    # fan-out k=4 over few objects: reach caps at d_obj, multiplier d_obj/k
    assert GraphStats.closure_multiplier(400, 100, 8) == pytest.approx(8 / 4.0)
    # empty relation
    assert GraphStats.closure_multiplier(0, 1, 1) == 1.0
    # multiplier never drops below 1 (closure contains the relation)
    assert GraphStats.closure_multiplier(10, 1, 1) == 1.0


def test_planner_uses_stats_closure_estimate():
    store = _store_from_edges([("p", i, i + 1) for i in range(99)])
    stats = GraphStats(store)
    planner = Planner(stats)
    node, vt = parse_query("SELECT ?x ?y { ?x :p+ ?y }")
    phys = planner.plan(node)
    leaf = phys
    while not isinstance(leaf, PPathExpand):
        leaf = leaf.child
    # 99 edges * capped depth 16 — not the old hard-coded 3x
    assert leaf.est_rows == pytest.approx(99 * CLOSURE_DEPTH_CAP)
    assert "PathExpand" in explain(phys, vt)


def test_legacy_plus_triple_pattern_still_plans():
    """Programmatic plans using TriplePattern(path='+') normalize to the
    vectorized node."""
    store = _store_from_edges([("p", 0, 1), ("p", 1, 2)])
    planner = Planner(GraphStats(store))
    pat = A.TriplePattern(A.V(0), A.K(":p"), A.V(1), path="+")
    phys = planner.plan(A.BGP([pat]))
    assert isinstance(phys, PPathExpand)


# ---------------------------------------------------------------------------
# pooling + profiler counters
# ---------------------------------------------------------------------------


def test_steady_state_rounds_reuse_pool_buffers():
    """Per-round working sets come from the arena: far fewer fresh
    allocations than rounds, and the counters expose the frontier walk."""
    store = _store_from_edges([("p", i, i + 1) for i in range(300)])
    pool = BatchPool()
    eng = PathEngine(store, pool)
    eng.evaluate(PClosure(PLink(":p"), 1))
    assert eng.counters.rounds == 301  # 300 discovery rounds + final empty round
    s = pool.stats()
    assert s["reuses"] > 10 * s["allocations"]
    assert s["allocations"] <= 12  # O(1) distinct buffer shapes, not O(rounds)


def test_profiler_surfaces_frontier_metrics(chain_store):
    e = Engine(chain_store, EngineConfig(engine="barq"))
    r = e.execute("SELECT ?x ?y { ?x :p+ ?y }")
    from repro.core.profiler import collect_stats

    agg = collect_stats(r.root, pool=r.pool)
    assert agg["frontier_rounds"] == 9  # 8 discovery rounds + final empty round
    assert agg["dedup_in"] >= agg["dedup_out"] > 0
    assert 0 < agg["dedup_ratio"] <= 1.0
