"""Property-based equivalence: BARQ == legacy == mixed == brute-force
oracle, over random graphs and the full operator repertoire (the paper's
correctness bar for gradual migration, §4)."""

import collections

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import Engine, EngineConfig, QuadStore

ENGINES = ("barq", "legacy", "mixed")


def _build_store(knows, interests, ages):
    store = QuadStore()
    for s, o in knows:
        store.add(f":p{s}", ":knows", f":p{o}")
    for s, t in interests:
        store.add(f":p{s}", ":interest", f":tag{t}")
    for s, a in ages.items():
        store.add(f":p{s}", ":age", int(a))
    return store.build()


def _run(store, query, engine, batch=64):
    e = Engine(store, EngineConfig(engine=engine, initial_batch=32, max_batch=batch))
    r = e.execute(query)
    rows = []
    for row in r.rows:
        rows.append(
            tuple(None if c == -1 else store.dict.decode(int(c)) for c in row)
        )
    return sorted(rows, key=str)


graphs = st.builds(
    lambda e1, e2, ages: (
        sorted(set(e1)), sorted(set(e2)), {i: a for i, a in enumerate(ages)}
    ),
    st.lists(st.tuples(st.integers(0, 7), st.integers(0, 7)), max_size=60),
    st.lists(st.tuples(st.integers(0, 7), st.integers(0, 3)), max_size=25),
    st.lists(st.integers(10, 70), min_size=8, max_size=8),
)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(graphs)
def test_two_hop_filter(g):
    knows, interests, ages = g
    store = _build_store(knows, interests, ages)
    q = "SELECT ?a ?b ?c { ?a :knows ?b . ?b :knows ?c . FILTER(?a != ?c) }"
    ks = set(knows)
    oracle = sorted(
        (
            (f":p{a}", f":p{b}", f":p{c}")
            for a, b in ks
            for b2, c in ks
            if b2 == b and a != c
        ),
        key=str,
    )
    results = {eng: _run(store, q, eng) for eng in ENGINES}
    for eng in ENGINES:
        assert results[eng] == oracle, eng


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(graphs)
def test_optional_and_minus(g):
    knows, interests, ages = g
    store = _build_store(knows, interests, ages)
    it = collections.defaultdict(list)
    for s, t in interests:
        it[s].append(t)
    q_opt = "SELECT ?a ?b ?t { ?a :knows ?b . OPTIONAL { ?b :interest ?t } }"
    oracle = []
    for a, b in set(knows):
        if it[b]:
            oracle.extend((f":p{a}", f":p{b}", f":tag{t}") for t in it[b])
        else:
            oracle.append((f":p{a}", f":p{b}", None))
    oracle = sorted(oracle, key=str)
    for eng in ENGINES:
        assert _run(store, q_opt, eng) == oracle, eng

    q_minus = "SELECT ?a ?b { ?a :knows ?b . MINUS { ?b :knows ?a } }"
    ks = set(knows)
    oracle2 = sorted(
        ((f":p{a}", f":p{b}") for a, b in ks if (b, a) not in ks), key=str
    )
    for eng in ENGINES:
        assert _run(store, q_minus, eng) == oracle2, eng


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(graphs, st.integers(20, 60))
def test_optional_with_join_condition(g, cutoff):
    """SPARQL LeftJoin semantics: a FILTER inside OPTIONAL referencing
    left-side vars is the join *condition* — a left row whose matches all
    fail it still appears, NULL-extended."""
    knows, interests, ages = g
    store = _build_store(knows, interests, ages)
    q = (f"SELECT ?p ?a ?b {{ ?p :age ?a . "
         f"OPTIONAL {{ ?p :knows ?b . FILTER(?a >= {cutoff}) }} }}")
    ks = set(knows)
    oracle = []
    for s, a in ages.items():
        matches = [b for s2, b in ks if s2 == s and a >= cutoff]
        if matches:
            oracle.extend((f":p{s}", a, f":p{b}") for b in matches)
        else:
            oracle.append((f":p{s}", a, None))
    oracle = sorted(oracle, key=str)
    for eng in ENGINES:
        assert _run(store, q, eng) == oracle, eng


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(graphs)
def test_group_aggregates(g):
    knows, interests, ages = g
    store = _build_store(knows, interests, ages)
    q = ("SELECT ?a (COUNT(DISTINCT ?b) AS ?n) { ?a :knows ?b } GROUP BY ?a")
    grp = collections.defaultdict(set)
    for a, b in set(knows):
        grp[a].add(b)
    oracle = sorted(((f":p{a}", len(v)) for a, v in grp.items()), key=str)
    for eng in ENGINES:
        assert _run(store, q, eng) == oracle, eng


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(graphs, st.integers(20, 60))
def test_numeric_filter_and_bind(g, cutoff):
    knows, interests, ages = g
    store = _build_store(knows, interests, ages)
    q = f"SELECT ?p ?a {{ ?p :age ?a . FILTER(?a >= {cutoff}) }}"
    oracle = sorted(
        ((f":p{s}", a) for s, a in ages.items() if a >= cutoff), key=str
    )
    for eng in ENGINES:
        assert _run(store, q, eng) == oracle, eng
    # BIND arithmetic
    qb = "SELECT ?p ?b { ?p :age ?a . BIND((?a * 2) AS ?b) }"
    oracleb = sorted(((f":p{s}", a * 2) for s, a in ages.items()), key=str)
    for eng in ENGINES:
        assert _run(store, qb, eng) == oracleb, eng


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(graphs)
def test_union_distinct(g):
    knows, interests, ages = g
    store = _build_store(knows, interests, ages)
    q = "SELECT DISTINCT ?x { { ?x :knows ?y } UNION { ?x :interest ?t } }"
    oracle = sorted(
        {(f":p{a}",) for a, _ in set(knows)} | {(f":p{s}",) for s, _ in set(interests)},
        key=str,
    )
    for eng in ENGINES:
        assert _run(store, q, eng) == oracle, eng


def test_triangle_multikey(tiny_store):
    store = tiny_store
    q = "SELECT ?a ?b ?c { ?a :knows ?b . ?b :knows ?c . ?c :knows ?a }"
    base = _run(store, q, "barq")
    for eng in ("legacy", "mixed"):
        assert _run(store, q, eng) == base


@pytest.mark.parametrize("max_batch", [32, 256, 4096])
def test_batch_size_invariance(tiny_store, max_batch):
    """Results must not depend on the compiled batch capacity."""
    q = "SELECT ?a ?b ?t { ?a :knows ?b . ?b :interest ?t }"
    ref = _run(tiny_store, q, "barq", batch=4096)
    assert _run(tiny_store, q, "barq", batch=max_batch) == ref
