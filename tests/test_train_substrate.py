"""Fault-tolerance substrate: checkpoint roundtrip/GC, trainer resume,
crash-retry, watchdog, gradient compression numerics."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.compression import (
    compress_tree,
    decompress_tree,
    init_residuals,
)
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_at,
)
from repro.train.trainer import Trainer, TrainerConfig


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jax.random.normal(k, (4,)), "step": jnp.int32(3)},
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        t = _tree()
        mgr.save(10, t)
        mgr.wait()
        restored, manifest = mgr.restore(None, jax.tree.map(np.asarray, t))
        assert manifest["step"] == 10
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), b), t, restored
        )

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree())
            mgr.wait()
        assert mgr.all_steps() == [3, 4]

    def test_crash_leaves_no_partial(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
        mgr.save(5, _tree())
        # simulate a crash mid-write of a later step: orphan tmp dir
        os.makedirs(tmp_path / "step_000000009.tmp")
        assert mgr.latest_step() == 5  # tmp ignored
        mgr.save(7, _tree())  # gc removes the orphan
        assert not (tmp_path / "step_000000009.tmp").exists()

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, _tree())
        bad = {"a": np.zeros((2, 2)), "nested": {"b": np.zeros(4), "step": np.int32(0)}}
        with pytest.raises(ValueError):
            mgr.restore(1, bad)


class TestOptimizer:
    def test_lr_schedule(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                              min_lr_ratio=0.1)
        assert float(lr_at(cfg, 0)) == 0.0
        assert float(lr_at(cfg, 10)) == pytest.approx(1.0, rel=1e-3)
        assert float(lr_at(cfg, 110)) == pytest.approx(0.1, rel=1e-2)

    def test_adamw_descends_quadratic(self):
        cfg = OptimizerConfig(lr=0.1, warmup_steps=1, total_steps=200,
                              weight_decay=0.0)
        params = {"w": jnp.asarray([3.0, -2.0])}
        opt = init_opt_state(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(cfg, params, grads, opt)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_clipping(self):
        cfg = OptimizerConfig(clip_norm=1.0, warmup_steps=0)
        params = {"w": jnp.ones((4,))}
        opt = init_opt_state(params)
        grads = {"w": jnp.full((4,), 1e6)}
        _, _, metrics = adamw_update(cfg, params, grads, opt)
        assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


class TestTrainer:
    def _mk(self, tmp_path, total=20, fault_hook=None, ckpt_every=5):
        cfg = OptimizerConfig(lr=0.05, warmup_steps=1, total_steps=total)

        def init_state():
            p = {"w": jnp.asarray([4.0])}
            return (p, init_opt_state(p))

        @jax.jit
        def step_impl(params, opt, x):
            loss, g = jax.value_and_grad(
                lambda p: jnp.sum((p["w"] - 1.0) ** 2) + 0.0 * x
            )(params)
            params, opt, m = adamw_update(cfg, params, g, opt)
            return params, opt, {"loss": loss, **m}

        def train_step(state, batch):
            p, o = state
            p, o, m = step_impl(p, o, batch)
            return (p, o), m

        return Trainer(
            TrainerConfig(total_steps=total, ckpt_every=ckpt_every,
                          ckpt_dir=str(tmp_path), log_every=100),
            train_step,
            init_state,
            lambda step: jnp.float32(step),
            fault_hook=fault_hook,
        )

    def test_runs_and_checkpoints(self, tmp_path):
        t = self._mk(tmp_path)
        out = t.run()
        assert out["step"] == 20 and not out["preempted"]
        assert t.ckpt.latest_step() == 20

    def test_resume_from_checkpoint(self, tmp_path):
        t1 = self._mk(tmp_path, total=10)
        t1.run()
        # new trainer continues to 20 from step 10 without redoing work
        t2 = self._mk(tmp_path, total=20)
        out = t2.run()
        assert out["step"] == 20
        assert len(t2.metrics_history) == 10  # only steps 10..20

    def test_crash_retry_restores(self, tmp_path):
        crashes = {"n": 0}

        def fault(step):
            if step == 7 and crashes["n"] == 0:
                crashes["n"] += 1
                raise RuntimeError("injected node failure")

        t = self._mk(tmp_path, total=12, fault_hook=fault)
        out = t.run()
        assert out["step"] == 12
        assert crashes["n"] == 1  # crashed once, resumed from step-5 ckpt

    def test_crash_budget_exhausted(self, tmp_path):
        def fault(step):
            raise RuntimeError("permanent failure")

        t = self._mk(tmp_path, total=5, fault_hook=fault)
        with pytest.raises(RuntimeError):
            t.run()


class TestCompression:
    def test_error_feedback_reduces_bias(self):
        rng = np.random.RandomState(0)
        g_true = {"w": jnp.asarray(rng.randn(1000).astype(np.float32))}
        res = init_residuals(g_true)
        acc = jnp.zeros(1000)
        acc_ref = jnp.zeros(1000)
        for _ in range(50):
            qs, ss, res = compress_tree(g_true, res)
            deq = decompress_tree(qs, ss, g_true)
            acc = acc + deq["w"]
            acc_ref = acc_ref + g_true["w"]
        # accumulated compressed gradients converge to the true sum
        rel = float(jnp.linalg.norm(acc - acc_ref) / jnp.linalg.norm(acc_ref))
        assert rel < 0.01

    def test_single_shot_quantization_error_bounded(self):
        x = jnp.linspace(-3, 3, 512)
        qs, ss, _ = compress_tree({"w": x}, init_residuals({"w": x}))
        deq = decompress_tree(qs, ss, {"w": x})
        assert float(jnp.max(jnp.abs(deq["w"] - x))) <= float(ss["w"]) * 0.51


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
