"""Expression VM (DESIGN.md §9): grammar, compiler, three-valued
semantics, backend parity, and end-to-end engine wiring.

The numpy executor of core/exprs is the oracle; the legacy interpreted
tree walk (core/expressions.py) must match it exactly (it shares the
per-term semantics through core/exprs/terms), and the jnp / Pallas
backends must match over float32-exact inputs."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Engine, EngineConfig, QuadStore
from repro.core import algebra as A
from repro.core.batch import NULL_ID, ColumnBatch
from repro.core.dictionary import Dictionary
from repro.core.expressions import eval_expr_mask, eval_expr_values
from repro.core.exprs import (
    compile_expr,
    disassemble,
    eval_program_mask,
    eval_program_values,
)
from repro.core.exprs import bytecode as B
from repro.core.parser import parse_query

BACKENDS = ("numpy", "jax", "pallas")

# variable layout used by the unit/property tests:
#   ?v0 ?v1  numeric columns (codes == int values)
#   ?v2      divisor column (0 rows produce division errors)
#   ?v3      term column (strings / IRIs / numbers, NULLs)
NUM_RANGE = 21


def _dict():
    d = Dictionary()
    for v in range(NUM_RANGE):  # code i <-> term int(i)
        d.encode(int(v))
    terms = ['"apple"', '"applesauce"', '"banana"', '""', ":iri1", ":iri2", 2.5]
    codes = [d.encode(t) for t in terms]
    return d, codes


def _batch(rng, n, term_codes, null_frac=0.15):
    a = rng.randint(0, NUM_RANGE, n).astype(np.int32)
    b = rng.randint(0, NUM_RANGE, n).astype(np.int32)
    div = rng.choice([0, 1, 2, 4], n).astype(np.int32)  # f32-exact quotients
    t = rng.choice(term_codes + [int(NULL_ID)], n).astype(np.int32)
    for col in (a, b):
        col[rng.rand(n) < null_frac] = NULL_ID
    return ColumnBatch.from_columns((0, 1, 2, 3), [a, b, div, t],
                                    capacity=max(n, 1))


# ---------------------------------------------------------------------------
# parser: function grammar
# ---------------------------------------------------------------------------


def test_parse_builtin_functions():
    node, vt = parse_query(
        'SELECT ?x { ?x :p ?y . FILTER(IF(BOUND(?y), ?y > 2, COALESCE(?x, 1)))'
        ' FILTER(REGEX(?x, "^a", "i") || STRSTARTS(?x, "a") ||'
        ' STRENDS(?x, "z") || CONTAINS(?x, "b"))'
        ' FILTER(ISNUMERIC(?y) && SAMETERM(?x, ?y) && ?y IN (1, 2, 3)) }'
    )
    found = set()

    def walk(e):
        if isinstance(e, A.Func):
            found.add(e.name)
            for x in e.args:
                walk(x)
        elif isinstance(e, (A.And, A.Or)):
            for t in e.terms:
                walk(t)
        elif isinstance(e, A.Not):
            walk(e.term)
        elif isinstance(e, (A.Cmp, A.Arith)):
            walk(e.lhs)
            walk(e.rhs)

    n = node
    while hasattr(n, "child"):
        if isinstance(n, A.Filter):
            walk(n.expr)
        n = n.child
    assert {"if", "coalesce", "regex", "strstarts", "strends", "contains",
            "isnumeric", "sameterm", "in"} <= found


def test_parse_not_in_and_arity_errors():
    node, _ = parse_query("SELECT ?x { ?x :p ?y . FILTER(?y NOT IN (1, 2)) }")
    with pytest.raises(SyntaxError):
        parse_query("SELECT ?x { ?x :p ?y . FILTER(SAMETERM(?x)) }")
    with pytest.raises(SyntaxError):
        parse_query("SELECT ?x { ?x :p ?y . FILTER(IF(?x, ?y)) }")


def test_parse_order_by_expression_desugars():
    node, vt = parse_query(
        "SELECT ?x ?y { ?x :p ?y } ORDER BY DESC(?y * 2 + 1) ?x"
    )
    assert isinstance(node, A.Project)  # re-projection strips the sort var
    assert node.vars == [vt.var("x"), vt.var("y")]
    ob = node.child
    assert isinstance(ob, A.OrderBy)
    assert [k.ascending for k in ob.keys] == [False, True]
    carry = ob.child  # projection carrying the computed key column
    assert isinstance(carry, A.Project) and ob.keys[0].var in carry.vars
    ext = carry.child  # the BIND sits below the projection (hidden vars ok)
    assert isinstance(ext, A.Extend) and ob.keys[0].var == ext.var


def test_parse_group_by_expression_desugars():
    node, vt = parse_query(
        "SELECT ?k (COUNT(*) AS ?n) { ?x :p ?y } GROUP BY (?y / 2 AS ?k)"
    )
    n = node
    while not isinstance(n, A.GroupAgg):
        n = n.child
    assert n.group_vars == [vt.var("k")]
    assert isinstance(n.child, A.Extend) and n.child.var == vt.var("k")


# ---------------------------------------------------------------------------
# compiler: folding / CSE / DCE / register allocation / domain split
# ---------------------------------------------------------------------------


def test_constant_folding_and_dce():
    d, _ = _dict()
    e = A.Cmp(">", A.VarRef(0), A.Arith("*", A.Lit(2), A.Arith("+", A.Lit(1), A.Lit(2))))
    prog = compile_expr(e, d, "mask")
    # 2 * (1 + 2) folds to one constant load; dead LOAD_CONSTs are swept
    assert sum(1 for i in prog.instrs if i[0] == B.LOAD_CONST) == 1
    assert prog.consts.count(6.0) == 1
    assert len(prog.instrs) == 3  # load_num, load_const, gt


def test_cse_dedups_repeated_subtrees():
    d, _ = _dict()
    s = A.Arith("+", A.VarRef(0), A.VarRef(1))
    e = A.And((A.Cmp(">", s, A.Lit(3)), A.Cmp("<", s, A.Lit(9))))
    prog = compile_expr(e, d, "mask")
    assert sum(1 for i in prog.instrs if i[0] == B.ADD) == 1
    # var-vs-var equality is canonicalized, so both orders CSE together
    e2 = A.And((A.Cmp("=", A.VarRef(0), A.VarRef(1)),
                A.Cmp("=", A.VarRef(1), A.VarRef(0))))
    p2 = compile_expr(e2, d, "mask")
    assert sum(1 for i in p2.instrs if i[0] == B.EQ_CODE) == 1


def test_register_allocation_reuses_registers():
    d, _ = _dict()
    # a deep left-leaning sum: SSA would need O(n) registers, linear scan O(1)
    e = A.VarRef(0)
    for _ in range(12):
        e = A.Arith("+", e, A.VarRef(1))
    prog = compile_expr(A.Cmp(">", e, A.Lit(3)), d, "mask")
    assert prog.n_regs <= 4
    assert "ret" in disassemble(prog)


def test_code_value_domain_split():
    d, _ = _dict()
    # pure code-domain expression: no numeric columns are planned at all
    e = A.And((A.Cmp("=", A.VarRef(0), A.VarRef(1)),
               A.Not(A.Cmp("!=", A.VarRef(0), A.Lit(3))), A.Bound(1)))
    prog = compile_expr(e, d, "mask")
    assert prog.num_vars == ()
    assert set(prog.code_vars) == {0, 1}
    # ordered comparison forces the value domain for its operands only
    e2 = A.And((A.Cmp("<", A.VarRef(0), A.Lit(3)), A.Cmp("=", A.VarRef(1), A.Lit(2))))
    p2 = compile_expr(e2, d, "mask")
    assert p2.num_vars == (0,)


def test_string_predicates_are_dictionary_domain():
    d, codes = _dict()
    e = A.Func("regex", (A.VarRef(3), A.Lit('"^app"')))
    prog = compile_expr(e, d, "mask")
    assert prog.num_vars == ()  # never decodes numerics
    assert len(prog.tables) == 1 and prog.tables[0].func == "regex"
    rng = np.random.RandomState(1)
    b = _batch(rng, 64, codes)
    mask = eval_program_mask(prog, b, d)
    want = eval_expr_mask(e, b, d)
    np.testing.assert_array_equal(mask, want)


# ---------------------------------------------------------------------------
# three-valued logic: the legacy-oracle regression pins (ISSUE satellites)
# ---------------------------------------------------------------------------


def _one_row(d, a_code, b_code):
    return ColumnBatch.from_columns(
        (0, 1), [np.array([a_code], np.int32), np.array([b_code], np.int32)]
    )


def test_not_of_error_stays_error():
    """NOT(error) must stay error: a row where ?a is unbound satisfies
    neither FILTER(?a = ?b) nor FILTER(!(?a = ?b))."""
    d, _ = _dict()
    b = _one_row(d, int(NULL_ID), 3)
    inner = A.Cmp("=", A.VarRef(0), A.VarRef(1))
    assert not eval_expr_mask(inner, b, d)[0]
    assert not eval_expr_mask(A.Not(inner), b, d)[0]  # was True pre-fix
    # and the VM agrees
    assert not eval_program_mask(compile_expr(A.Not(inner), d, "mask"), b, d)[0]


def test_true_or_error_is_true():
    """true || error == true: an error on one disjunct must not discard a
    row another disjunct accepts."""
    d, _ = _dict()
    b = _one_row(d, 3, int(NULL_ID))  # ?a = 3 bound, ?b unbound
    e = A.Or((A.Cmp("=", A.VarRef(0), A.Lit(3)),   # true
              A.Cmp("=", A.VarRef(1), A.Lit(5))))  # error (unbound)
    assert eval_expr_mask(e, b, d)[0]  # was False pre-fix
    assert eval_program_mask(compile_expr(e, d, "mask"), b, d)[0]
    # false || error stays error (excluded)
    e2 = A.Or((A.Cmp("=", A.VarRef(0), A.Lit(4)),
               A.Cmp("=", A.VarRef(1), A.Lit(5))))
    assert not eval_expr_mask(e2, b, d)[0]
    assert not eval_program_mask(compile_expr(e2, d, "mask"), b, d)[0]


def test_false_and_error_is_false_under_not():
    """Kleene AND: false && error == false, so !(false && error) == true."""
    d, _ = _dict()
    b = _one_row(d, 3, int(NULL_ID))
    e = A.Not(A.And((A.Cmp("=", A.VarRef(0), A.Lit(4)),
                     A.Cmp("=", A.VarRef(1), A.Lit(5)))))
    assert eval_expr_mask(e, b, d)[0]
    assert eval_program_mask(compile_expr(e, d, "mask"), b, d)[0]


def test_boolean_context_if_coalesce_apply_ebv_to_terms():
    """IF/COALESCE branches in a FILTER follow boolean context: a string
    variable gets its EBV (nonempty -> true), not a numeric decode (which
    would be NaN -> error). VM must match the tree walk."""
    d, codes = _dict()
    s = d.lookup('"apple"')
    b = ColumnBatch.from_columns(
        (0, 1), [np.array([s, int(NULL_ID)], np.int32), np.array([5, 5], np.int32)]
    )
    for e in (
        A.Func("coalesce", (A.VarRef(0), A.Lit(0))),
        A.Func("if", (A.Bound(0), A.VarRef(0), A.Lit(0))),
    ):
        want = eval_expr_mask(e, b, d)
        got = eval_program_mask(compile_expr(e, d, "mask"), b, d)
        np.testing.assert_array_equal(got, want)
        assert want[0] and not want[1]  # "apple" -> true; unbound -> falls through to 0


def test_in_mixes_term_and_computed_items():
    """IN classifies per item: a term constant in the list keeps term
    identity (string matches stay true) even when another item forces a
    value-domain comparison."""
    d, codes = _dict()
    s = d.lookup('"apple"')
    b = ColumnBatch.from_columns(
        (0, 1), [np.array([s, 5], np.int32), np.array([0, 5], np.int32)]
    )
    e = A.Func("in", (A.VarRef(0), A.Lit('"apple"'),
                      A.Arith("+", A.VarRef(1), A.Lit(0))))
    want = eval_expr_mask(e, b, d)
    got = eval_program_mask(compile_expr(e, d, "mask"), b, d)
    np.testing.assert_array_equal(got, want)
    assert want[0] and want[1]  # row0: term match; row1: 5 == 5+0
    # var-vs-var item over string terms is term identity, both regimes
    e2 = A.Func("in", (A.VarRef(0), A.VarRef(0)))
    assert eval_expr_mask(e2, b, d)[0]
    assert eval_program_mask(compile_expr(e2, d, "mask"), b, d)[0]


def test_constant_vs_constant_absent_terms_not_equal():
    """Two distinct constants absent from the dictionary must compare
    unequal (they are real, different terms) in BOTH regimes."""
    d = Dictionary()
    d.encode(int(1))
    b = _one_row(d, 0, 0)
    e = A.Cmp("=", A.Lit('"nope"'), A.Lit('"also-nope"'))
    assert not eval_expr_mask(e, b, d)[0]
    assert not eval_program_mask(compile_expr(e, d, "mask"), b, d)[0]
    e2 = A.Func("sameterm", (A.Lit('"nope"'), A.Lit('"nope"')))
    assert eval_expr_mask(e2, b, d)[0]
    assert eval_program_mask(compile_expr(e2, d, "mask"), b, d)[0]


def test_division_by_zero_is_error_not_false():
    d, _ = _dict()
    b = _one_row(d, 3, 0)
    e = A.Cmp(">=", A.Arith("/", A.VarRef(0), A.VarRef(1)), A.Lit(0))
    assert not eval_expr_mask(e, b, d)[0]
    assert not eval_expr_mask(A.Not(e), b, d)[0]  # error survives the NOT
    prog = compile_expr(A.Not(e), d, "mask")
    assert not eval_program_mask(prog, b, d)[0]
    # ... but COALESCE recovers from it
    e2 = A.Cmp(
        ">=", A.Func("coalesce", (A.Arith("/", A.VarRef(0), A.VarRef(1)), A.Lit(7))),
        A.Lit(7),
    )
    assert eval_expr_mask(e2, b, d)[0]
    assert eval_program_mask(compile_expr(e2, d, "mask"), b, d)[0]


# ---------------------------------------------------------------------------
# hypothesis parity sweeps: VM (all backends) vs the interpreted oracle
# ---------------------------------------------------------------------------


def _gen_num(draw, depth):
    kind = draw(st.integers(0, 5 if depth > 0 else 1))
    if kind == 0:
        return A.VarRef(draw(st.integers(0, 1)))
    if kind == 1:
        return A.Lit(int(draw(st.integers(0, NUM_RANGE - 1))))
    if kind == 2:
        return A.Arith(draw(st.sampled_from(["+", "-", "*"])),
                       _gen_num(draw, depth - 1), _gen_num(draw, depth - 1))
    if kind == 3:  # division errors: divisor column has zero rows
        return A.Arith("/", _gen_num(draw, depth - 1), A.VarRef(2))
    if kind == 4:
        return A.Func("if", (_gen_bool(draw, depth - 1),
                             _gen_num(draw, depth - 1), _gen_num(draw, depth - 1)))
    return A.Func("coalesce", (_gen_num(draw, depth - 1), _gen_num(draw, depth - 1)))


_STR_FUNCS = ("strstarts", "strends", "contains", "regex")
_STR_ARGS = ('"ap"', '"a"', '"e"', '"an"', '"^a.p"', '""')


def _gen_bool(draw, depth):
    kind = draw(st.integers(0, 8 if depth > 0 else 4))
    if kind == 0:
        return A.Cmp(draw(st.sampled_from(["<", "<=", ">", ">="])),
                     _gen_num(draw, depth - 1), _gen_num(draw, depth - 1))
    if kind == 1:  # code-domain equality (vars / constants / the term col)
        lhs = A.VarRef(draw(st.integers(0, 3)))
        rhs = draw(st.sampled_from(
            [A.VarRef(0), A.VarRef(3), A.Lit(3), A.Lit('"apple"'), A.Lit(":iri1")]
        ))
        return A.Cmp(draw(st.sampled_from(["=", "!="])), lhs, rhs)
    if kind == 2:
        return A.Bound(draw(st.integers(0, 3)))
    if kind == 3:
        f = draw(st.sampled_from(_STR_FUNCS))
        return A.Func(f, (A.VarRef(3), A.Lit(draw(st.sampled_from(_STR_ARGS)))))
    if kind == 4:
        return A.Func(
            draw(st.sampled_from(["isnumeric", "isiri", "isliteral"])),
            (A.VarRef(3),),
        )
    if kind == 5:
        return A.Not(_gen_bool(draw, depth - 1))
    if kind == 6:
        terms = tuple(_gen_bool(draw, depth - 1) for _ in range(draw(st.integers(2, 3))))
        return (A.And if draw(st.integers(0, 1)) else A.Or)(terms)
    if kind == 7:
        return A.Func("in", (A.VarRef(draw(st.integers(0, 1))),
                             A.Lit(1), A.Lit(5), A.Lit(9)))
    # IF/COALESCE with raw term branches: EBV must apply per branch
    if draw(st.integers(0, 1)):
        return A.Func("coalesce", (A.VarRef(draw(st.integers(0, 3))),
                                   _gen_bool(draw, depth - 1)))
    return A.Func("if", (_gen_bool(draw, depth - 1),
                         _gen_bool(draw, depth - 1), _gen_bool(draw, depth - 1)))


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_mask_parity_vm_vs_oracle_all_backends(data):
    d, codes = _dict()
    expr = _gen_bool(data.draw, depth=3)
    n = data.draw(st.integers(0, 200))  # 0 == empty batch
    rng = np.random.RandomState(data.draw(st.integers(0, 2**31 - 1)))
    batch = _batch(rng, n, codes)
    want = eval_expr_mask(expr, batch, d)  # interpreted tree walk
    prog = compile_expr(expr, d, "mask")
    for backend in BACKENDS:
        got = eval_program_mask(prog, batch, d, backend=backend)
        np.testing.assert_array_equal(
            got, want, err_msg=f"{backend}\n{disassemble(prog)}"
        )


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_value_parity_vm_vs_oracle(data):
    d, codes = _dict()
    expr = _gen_num(data.draw, depth=3)
    n = data.draw(st.integers(0, 150))
    rng = np.random.RandomState(data.draw(st.integers(0, 2**31 - 1)))
    batch = _batch(rng, n, codes)
    want_v, want_ok = eval_expr_values(expr, batch, d)
    prog = compile_expr(expr, d, "value")
    for backend in BACKENDS:
        got_v, got_ok = eval_program_values(prog, batch, d, backend=backend)
        np.testing.assert_array_equal(got_ok, want_ok, err_msg=backend)
        np.testing.assert_allclose(
            got_v[want_ok], want_v[want_ok], rtol=1e-6, err_msg=backend
        )


def test_predicate_table_cache_extends_with_dictionary():
    from repro.core.exprs.vm import predicate_table

    d, codes = _dict()
    spec = B.TableSpec("strstarts", ('"app"',), 3)
    t1 = predicate_table(d, spec)
    n1 = len(t1)
    extra = d.encode('"approval"')
    t2 = predicate_table(d, spec)
    assert len(t2) == len(d) and t2[extra] == 1
    np.testing.assert_array_equal(t2[:n1], t1)


# ---------------------------------------------------------------------------
# end-to-end engine wiring
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def people_store():
    store = QuadStore()
    names = ["alice", "albert", "bob", "carol", "dave", "eve", "mallory"]
    for i, nm in enumerate(names):
        store.add(f":p{i}", ":name", f'"{nm}"')
        store.add(f":p{i}", ":age", 20 + 5 * i)
        store.add(f":p{i}", ":knows", f":p{(i + 1) % len(names)}")
        if i % 2 == 0:
            store.add(f":p{i}", ":city", ":springfield")
    store.build()
    return store


def _rows(res, store):
    return sorted(map(str, res.decoded(store.dict)))


def _both_engines(store, q):
    barq = Engine(store, EngineConfig(engine="barq")).execute(q)
    legacy = Engine(store, EngineConfig(engine="legacy")).execute(q)
    assert _rows(barq, store) == _rows(legacy, store)
    return barq


def test_engine_filter_regex_and_bind_if(people_store):
    q = """
    SELECT ?p ?cat {
      ?p :name ?n . ?p :age ?a .
      FILTER(REGEX(?n, "^a") || CONTAINS(?n, "or"))
      BIND(IF(?a >= 30, 1, 0) AS ?cat)
    }
    """
    res = _both_engines(people_store, q)
    assert res.n_rows == 3  # alice albert mallory
    prof = res.profile()
    assert "expr_ops" in prof and "expr_dispatches" in prof


def test_engine_in_and_sameterm(people_store):
    q = 'SELECT ?p { ?p :age ?a . FILTER(?a IN (20, 30, 45)) }'
    assert _both_engines(people_store, q).n_rows == 3
    q2 = 'SELECT ?p { ?p :knows ?q . FILTER(!SAMETERM(?p, ?q)) }'
    assert _both_engines(people_store, q2).n_rows == 7


def test_engine_optional_condition_via_vm(people_store):
    # left-join condition references both sides: compiled to a VM program
    # on the PMergeJoin node (post_program)
    q = """
    SELECT ?p ?c {
      ?p :age ?a .
      OPTIONAL { ?p :city ?c . FILTER(?a / 2 >= 15) }
    }
    """
    res = _both_engines(people_store, q)
    assert res.n_rows == 7
    decoded = res.decoded(people_store.dict)
    assert sum(1 for r in decoded if r["c"] is not None) == 3  # p2 p4 p6


def test_engine_order_by_and_group_by_expressions(people_store):
    q = "SELECT ?p ?a { ?p :age ?a } ORDER BY DESC(?a * 2)"
    res = _both_engines(people_store, q)
    ages = [r["a"] for r in res.decoded(people_store.dict)]
    assert ages == sorted(ages, reverse=True)
    # the key may reference a NON-projected variable: ?a is bound below
    # the projection, so the desugared BIND must sit below it too
    q_hidden = "SELECT ?p { ?p :age ?a } ORDER BY DESC(?a * 2)"
    res_h = _both_engines(people_store, q_hidden)
    ps = [r["p"] for r in res_h.decoded(people_store.dict)]
    assert ps[0] == ":p6" and ps[-1] == ":p0"  # oldest first
    # ... but under DISTINCT that is a (clear) syntax error per SPARQL
    with pytest.raises(SyntaxError):
        parse_query("SELECT DISTINCT ?p { ?p :age ?a } ORDER BY DESC(?a * 2)")
    q2 = """
    SELECT ?k (COUNT(*) AS ?n) { ?p :age ?a } GROUP BY (?a / 10 AS ?k)
    """
    res2 = _both_engines(people_store, q2)
    got = {r["k"]: r["n"] for r in res2.decoded(people_store.dict)}
    assert sum(got.values()) == 7


def test_engine_coalesce_unbound_recovery(people_store):
    q = """
    SELECT ?p ?v {
      ?p :age ?a .
      OPTIONAL { ?p :city ?c }
      BIND(COALESCE(?c, ?a) AS ?v)
    }
    """
    res = _both_engines(people_store, q)
    assert all(r["v"] is not None for r in res.decoded(people_store.dict))


def test_plan_caches_programs_on_nodes(people_store):
    from repro.core import planner as PL

    eng = Engine(people_store)
    node, vt = eng.parse(
        'SELECT ?p { ?p :name ?n . FILTER(STRSTARTS(?n, "a") && ?p != :p0) }'
    )
    phys = eng.plan(node)

    progs = []

    def walk(n):
        if isinstance(n, PL.PFilter) and n.program is not None:
            progs.append(n.program)
        for f in ("child", "left", "right", "probe", "build"):
            if hasattr(n, f):
                walk(getattr(n, f))

    walk(phys)
    assert progs, "planner should attach compiled programs to PFilter"
    # planning the same query again reuses the cached program object
    phys2 = eng.plan(eng.parse(
        'SELECT ?p { ?p :name ?n . FILTER(STRSTARTS(?n, "a") && ?p != :p0) }'
    )[0])
    progs2 = []

    def walk2(n):
        if isinstance(n, PL.PFilter) and n.program is not None:
            progs2.append(n.program)
        for f in ("child", "left", "right", "probe", "build"):
            if hasattr(n, f):
                walk2(getattr(n, f))

    walk2(phys2)
    assert any(p1 is p2 for p1 in progs for p2 in progs2)


def test_query_server_key_collisions_are_safe(people_store):
    """Two different queries submitted under the SAME caller key must not
    share a cached plan (the key is now derived from the query text)."""
    from repro.serve.query_server import QueryServer

    srv = QueryServer(people_store)
    q1 = "SELECT ?p { ?p :age ?a . FILTER(?a >= 40) }"
    q2 = "SELECT ?p { ?p :age ?a . FILTER(?a < 40) }"
    r1 = srv.execute("shared-key", q1)
    r2 = srv.execute("shared-key", q2)
    assert r1.n_rows == 3 and r2.n_rows == 4
    # and repeated submission hits the cache (one entry per distinct text)
    srv.execute("other-key", q1)
    assert len(srv._plan_cache) == 2
