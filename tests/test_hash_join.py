"""Radix-partitioned hash join (DESIGN.md §11): kernel-level backend
parity, operator parity against merge join / the legacy row engine /
brute force across all four modes, the NOT-EXISTS and disjoint-OPTIONAL
semantics regressions (both engines), strategy-choice and semi/anti
costing pins, and the dispatch-ledger assertion that the Pallas path
actually fires."""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import Engine, EngineConfig, QuadStore, vecops
from repro.core.batch import BatchPool
from repro.core.legacy.operators import RowHashJoin
from repro.core.operators.adapters import BatchToRow
from repro.core.operators.hash_join import HashJoin
from repro.core.operators.merge_join import MergeJoin
from repro.core.operators.sort import MaterializedSource
from repro.kernels import ops as KOPS

BACKENDS = ("numpy", "jax", "pallas")
MODES = ("inner", "left_outer", "semi", "anti")


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _src(var_ids, cols, sorted_var=None, batch=8, pool=None):
    return MaterializedSource(
        var_ids, np.asarray(cols, np.int32), sorted_var, batch_size=batch,
        pool=pool,
    )


def _drain_rows(op):
    rows = []
    for b in op.drain():
        c = b.compact()
        rows.extend(tuple(r) for r in c.to_rows_array().tolist())
        c.release()
    return sorted(rows)


def _drain_row_op(op, vars_):
    out = []
    while True:
        r = op.next_row()
        if r is None:
            break
        out.append(tuple(r.get(v, -1) for v in vars_))
    return sorted(out)


def _brute_join(l, r, lv, rv, mode):
    shared = [v for v in lv if v in rv]
    out = []
    for lrow in zip(*l):
        matches = [
            rrow for rrow in zip(*r)
            if all(lrow[lv.index(s)] == rrow[rv.index(s)] for s in shared)
        ]
        if mode == "inner":
            for rrow in matches:
                out.append(tuple(lrow) + tuple(
                    rrow[rv.index(v)] for v in rv if v not in lv))
        elif mode == "left_outer":
            if matches:
                for rrow in matches:
                    out.append(tuple(lrow) + tuple(
                        rrow[rv.index(v)] for v in rv if v not in lv))
            else:
                out.append(tuple(lrow) + tuple(
                    -1 for v in rv if v not in lv))
        elif mode == "semi" and matches:
            out.append(tuple(lrow))
        elif mode == "anti" and not matches:
            out.append(tuple(lrow))
    return sorted(out)


# ---------------------------------------------------------------------------
# kernel parity: hash_build / hash_probe across backends
# ---------------------------------------------------------------------------

kernel_cases = st.tuples(
    st.integers(0, 200),  # n_build
    st.integers(0, 150),  # n_probe
    st.sampled_from([2, 5, 40, 5000]),  # key range (2 = heavy skew)
    st.sampled_from([1, 4, 16]),  # n_parts
)


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(kernel_cases, st.integers(0, 10_000))
def test_hash_kernels_backend_parity_single_key(case, seed):
    n_b, n_q, key_range, n_parts = case
    rng = np.random.RandomState(seed)
    bk = rng.randint(-1, key_range, n_b).astype(np.int32)  # -1 == NULL key
    qk = rng.randint(-1, key_range + 3, n_q).astype(np.int32)
    results = {}
    for be in BACKENDS:
        order, starts = KOPS.hash_build(None, bk, n_parts, backend=be)
        sk = bk[order]
        spid = np.repeat(np.arange(n_parts, dtype=np.int32), np.diff(starts))
        lo, hi = KOPS.hash_probe(
            spid, None, sk, None, qk, starts, n_parts, backend=be)
        # semantic: [lo, hi) is exactly the probe key's match run
        for i in range(n_q):
            assert (sk[lo[i]:hi[i]] == qk[i]).all(), (be, i)
            assert hi[i] - lo[i] == int((bk == qk[i]).sum()), (be, i)
        results[be] = (starts, lo, hi)
    for be in BACKENDS[1:]:
        for got, want in zip(results[be], results["numpy"]):
            np.testing.assert_array_equal(got, want, err_msg=be)


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.integers(0, 10_000))
def test_hash_kernels_backend_parity_pair_key(seed):
    rng = np.random.RandomState(seed)
    n_b, n_q, n_parts = 150, 120, 8
    cols_b = np.stack([rng.randint(-1, 9, n_b),
                       rng.randint(-1, 6, n_b)]).astype(np.int32)
    cols_q = np.stack([rng.randint(-1, 12, n_q),
                       rng.randint(-1, 8, n_q)]).astype(np.int32)
    spans = [int(c.max(initial=-1)) + 3 for c in cols_b]
    pb = vecops.pack_group_keys(cols_b, spans=spans)
    pq = vecops.pack_group_keys(cols_q, spans=spans)
    bh, bl = (pb >> 31).astype(np.int32), (pb & 0x7FFFFFFF).astype(np.int32)
    qh, ql = (pq >> 31).astype(np.int32), (pq & 0x7FFFFFFF).astype(np.int32)
    results = {}
    for be in BACKENDS:
        order, starts = KOPS.hash_build(bh, bl, n_parts, backend=be)
        spid = np.repeat(np.arange(n_parts, dtype=np.int32), np.diff(starts))
        lo, hi = KOPS.hash_probe(
            spid, bh[order], bl[order], qh, ql, starts, n_parts, backend=be)
        want = np.asarray([
            int(((cols_b[0] == cols_q[0][i]) & (cols_b[1] == cols_q[1][i])).sum())
            for i in range(n_q)
        ])
        np.testing.assert_array_equal(hi - lo, want, err_msg=be)
        results[be] = (lo, hi)
    for be in BACKENDS[1:]:
        np.testing.assert_array_equal(results[be][0], results["numpy"][0], be)
        np.testing.assert_array_equal(results[be][1], results["numpy"][1], be)


def test_pack_group_keys_fixed_spans_sentinel():
    """Out-of-range probe values clamp onto the reserved sentinel slot and
    can never collide with a real build key."""
    build = np.asarray([[0, 7], [3, 3]], np.int32)  # two cols, max 7 / 3
    spans = [int(c.max()) + 3 for c in build]
    pb = vecops.pack_group_keys(build, spans=spans)
    probe = np.asarray([[7, 99], [3, 3]], np.int32)  # 99 out of range
    pq = vecops.pack_group_keys(probe, spans=spans)
    assert pq[0] == pb[1]  # exact match preserved
    assert pq[1] not in set(pb.tolist())  # clamped, no false match
    # overflow -> None (operator falls back to primary-key + pairs)
    assert vecops.pack_group_keys(build, spans=[1 << 40, 1 << 40]) is None


# ---------------------------------------------------------------------------
# operator parity: HashJoin vs MergeJoin vs RowHashJoin vs brute force
# ---------------------------------------------------------------------------

join_cases = st.tuples(
    st.integers(0, 45),  # n_left
    st.integers(0, 45),  # n_right (0 == empty build side)
    st.sampled_from([2, 3, 12]),  # key range: 2/3 == heavy skew
    st.sampled_from(MODES),
)


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(join_cases, st.integers(0, 10_000))
def test_hash_join_modes_vs_bruteforce_and_merge(case, seed):
    nl, nr, key_range, mode = case
    rng = np.random.RandomState(seed)
    lk = rng.randint(-1, key_range, nl).astype(np.int32)  # NULL keys included
    rk = rng.randint(-1, key_range, nr).astype(np.int32)
    l = [lk, rng.randint(0, 5, nl)]  # vars (0, 1)
    r = [rk, rng.randint(0, 5, nr)]  # vars (0, 2)
    want = _brute_join(l, r, (0, 1), (0, 2), mode)

    for be in BACKENDS:
        pool = BatchPool()
        j = HashJoin(
            _src((0, 1), l, pool=pool), _src((0, 2), r, pool=pool), (0,),
            mode, pool=pool, backend=be,
        )
        assert _drain_rows(j) == want, (mode, be)

    ls = np.argsort(lk, kind="stable")
    rs = np.argsort(rk, kind="stable")
    mj = MergeJoin(
        _src((0, 1), [c[ls] for c in l], 0), _src((0, 2), [c[rs] for c in r], 0),
        0, mode=mode,
    )
    assert _drain_rows(mj) == want, mode

    rj = RowHashJoin(
        BatchToRow(_src((0, 1), l)), BatchToRow(_src((0, 2), r)), (0,), mode)
    vars_ = (0, 1) if mode in ("semi", "anti") else (0, 1, 2)
    assert _drain_row_op(rj, vars_) == want, mode


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.sampled_from(MODES), st.integers(0, 10_000))
def test_hash_join_multi_key_parity(mode, seed):
    """Two shared variables: the packed-composite hash-key path."""
    rng = np.random.RandomState(seed)
    nl, nr = rng.randint(1, 35), rng.randint(1, 35)
    l = [rng.randint(-1, 5, nl), rng.randint(0, 3, nl)]  # vars (0, 1)
    r = [rng.randint(-1, 5, nr), rng.randint(0, 3, nr),
         rng.randint(10, 13, nr)]  # vars (0, 1, 2)
    want = _brute_join(l, r, (0, 1), (0, 1, 2), mode)
    for be in BACKENDS:
        j = HashJoin(_src((0, 1), l), _src((0, 1, 2), r), (0, 1), mode,
                     backend=be)
        assert _drain_rows(j) == want, (mode, be)
    rj = RowHashJoin(BatchToRow(_src((0, 1), l)),
                     BatchToRow(_src((0, 1, 2), r)), (0, 1), mode)
    vars_ = (0, 1) if mode in ("semi", "anti") else (0, 1, 2)
    assert _drain_row_op(rj, vars_) == want, mode


@pytest.mark.parametrize("mode", MODES)
def test_hash_join_span_overflow_fallback(mode):
    """Key values near 2^30 across three columns overflow the 62-bit pack;
    the operator must fall back to primary-key hashing + pair verification
    and still be exact."""
    rng = np.random.RandomState(3)
    base = (1 << 31) - 4  # spans > 2^31 each: two columns overflow 62 bits
    nl = nr = 25
    lk = rng.randint(0, 4, nl).astype(np.int64) + base
    rk = rng.randint(0, 4, nr).astype(np.int64) + base
    l = [lk, lk - rng.randint(0, 2, nl), rng.randint(0, 3, nl)]
    r = [rk, rk - rng.randint(0, 2, nr), rng.randint(0, 3, nr)]
    l = [np.asarray(c, np.int32) for c in l]
    r = [np.asarray(c, np.int32) for c in r]
    # vars (0,1,2) join (0,1,3): keys (0,1) both huge-valued
    want = _brute_join(l, r, (0, 1, 2), (0, 1, 3), mode)
    j = HashJoin(_src((0, 1, 2), l), _src((0, 1, 3), r), (0, 1), mode)
    assert _drain_rows(j) == want, mode
    assert j._spans is None  # the fallback actually engaged
    assert j._pair_vars, "pair verification should carry the overflow keys"


def test_hash_join_empty_key_degenerate_cross():
    """keys=(): inner == cross product, left_outer == NULL-extending cross,
    anti == drop-all-iff-build-nonempty (the NOT EXISTS shape)."""
    l = [np.arange(3), np.arange(3) + 10]
    for mode in MODES:
        for nr in (0, 4):
            r = [np.arange(nr) + 100]
            want = _brute_join(l, r, (0, 1), (2,), mode)
            j = HashJoin(_src((0, 1), l), _src((2,), r), (), mode)
            assert _drain_rows(j) == want, (mode, nr)


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.integers(0, 10_000))
def test_hash_join_left_outer_condition(seed):
    """The SPARQL LeftJoin condition: a probe row whose matches all fail
    the expression still emits NULL-extended (parity vs RowHashJoin with
    the same post_filter)."""
    from repro.core.algebra import Cmp, Lit, VarRef
    from repro.core.dictionary import Dictionary

    rng = np.random.RandomState(seed)
    d = Dictionary()
    for v in range(20):
        d.encode(v)
    nl, nr = rng.randint(1, 25), rng.randint(0, 25)
    l = [rng.randint(0, 6, nl), rng.randint(0, 20, nl)]
    r = [rng.randint(0, 6, nr), rng.randint(0, 20, nr)]
    cond = Cmp(">", VarRef(2), Lit(9))  # right payload > 9
    j = HashJoin(_src((0, 1), l), _src((0, 2), r), (0,), "left_outer",
                 post_filter=cond, dictionary=d)
    got = _drain_rows(j)
    rj = RowHashJoin(BatchToRow(_src((0, 1), l)), BatchToRow(_src((0, 2), r)),
                     (0,), "left_outer", post_filter=cond, dictionary=d)
    assert got == _drain_row_op(rj, (0, 1, 2))


def test_hash_join_skip_floor_keeps_pending_rows():
    """A parent gallop (skip) must not drop already-expanded rows at or
    above the target — the regression behind the q3 triangle undercount."""
    n = 50
    lk = np.arange(n, dtype=np.int32)
    l = [lk, lk + 100]
    r = [np.repeat(lk, 2), np.repeat(lk, 2) + 200]
    j = HashJoin(_src((0, 1), l, sorted_var=0, batch=64),
                 _src((0, 2), r, batch=64), (0,))
    b = j.next_batch()  # prime: expansion enters pending state
    got = {tuple(row) for row in b.compact().to_rows_array().tolist()}
    j.skip(0, 10)  # gallop: rows with ?v0 >= 10 must survive
    while True:
        b = j.next_batch()
        if b is None:
            break
        got |= {tuple(row) for row in b.compact().to_rows_array().tolist()}
    want = {(k, k + 100, k + 200) for k in range(n) if k >= 10}
    missing = want - got
    assert not missing, sorted(missing)[:5]
    assert all(row[0] >= 10 or row in got for row in want)


# ---------------------------------------------------------------------------
# dispatch ledger: the Pallas path actually fires
# ---------------------------------------------------------------------------


def test_dispatch_ledger_pallas_hash_path_fires():
    rng = np.random.RandomState(0)
    l = [rng.randint(0, 50, 300), rng.randint(0, 5, 300)]
    r = [rng.randint(0, 50, 200), rng.randint(0, 5, 200)]
    KOPS.reset_dispatch_counts()
    j = HashJoin(_src((0, 1), l), _src((0, 2), r), (0,), backend="pallas")
    n_out = sum(b.n_active for b in j.drain())
    assert n_out > 0
    assert KOPS.dispatch_count("hash_build") == 1
    assert KOPS.dispatch_count("hash_probe") >= 1
    # the build's bucketing rides the radix_partition Pallas kernel
    assert KOPS.dispatch_count("radix_partition") == 1
    KOPS.reset_dispatch_counts()


# ---------------------------------------------------------------------------
# engine-level regressions: NOT EXISTS vs MINUS, disjoint OPTIONAL
# ---------------------------------------------------------------------------

ENGINES = ("barq", "legacy", "mixed")


def _exec(store, query, engine, strategy=None):
    e = Engine(store, EngineConfig(engine=engine, join_strategy=strategy))
    r = e.execute(query)
    return sorted(
        tuple(None if c == -1 else store.dict.decode(int(c)) for c in row)
        for row in r.rows
    )


@pytest.fixture()
def small_store():
    store = QuadStore()
    store.add(":a", ":knows", ":b")
    store.add(":b", ":knows", ":c")
    store.add(":x", ":flag", ":on")
    return store.build()


@pytest.mark.parametrize("engine", ENGINES)
def test_not_exists_disjoint_removes_all(small_store, engine):
    """SPARQL §8.3.3 divergence: the inner pattern shares no variables and
    HAS a solution -> NOT EXISTS removes every row, MINUS keeps every row.
    The old desugaring to MINUS answered both queries identically."""
    q_ne = "SELECT ?a ?b { ?a :knows ?b . FILTER NOT EXISTS { ?x :flag :on } }"
    q_mi = "SELECT ?a ?b { ?a :knows ?b . MINUS { ?x :flag :on } }"
    assert _exec(small_store, q_ne, engine) == []
    assert _exec(small_store, q_mi, engine) == [(":a", ":b"), (":b", ":c")]


@pytest.mark.parametrize("engine", ENGINES)
def test_not_exists_disjoint_empty_inner_keeps_all(small_store, engine):
    q = "SELECT ?a ?b { ?a :knows ?b . FILTER NOT EXISTS { ?x :flag :off } }"
    assert _exec(small_store, q, engine) == [(":a", ":b"), (":b", ":c")]


@pytest.mark.parametrize("engine", ENGINES)
def test_not_exists_shared_vars_still_anti_join(small_store, engine):
    q = "SELECT ?a ?b { ?a :knows ?b . FILTER NOT EXISTS { ?b :knows ?c } }"
    assert _exec(small_store, q, engine) == [(":b", ":c")]


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("strategy", [None, "hash", "merge"])
def test_optional_disjoint_keeps_left_rows(small_store, engine, strategy):
    """Left join with no shared variables and an EMPTY optional side must
    keep every left row with the optional variable unbound (the PCross
    plan returned zero rows)."""
    q = "SELECT ?a ?b ?x { ?a :knows ?b . OPTIONAL { ?x :flag :off } }"
    want = [(":a", ":b", None), (":b", ":c", None)]
    assert _exec(small_store, q, engine, strategy) == want


@pytest.mark.parametrize("engine", ENGINES)
def test_optional_disjoint_nonempty_is_cross(small_store, engine):
    q = "SELECT ?a ?b ?x { ?a :knows ?b . OPTIONAL { ?x :flag :on } }"
    want = [(":a", ":b", ":x"), (":b", ":c", ":x")]
    assert _exec(small_store, q, engine) == want


# ---------------------------------------------------------------------------
# engine-level hypothesis parity: forced-hash == forced-merge == legacy row
# ---------------------------------------------------------------------------

graphs = st.builds(
    lambda e1, e2: (sorted(set(e1)), sorted(set(e2))),
    st.lists(st.tuples(st.integers(0, 7), st.integers(0, 7)), max_size=50),
    st.lists(st.tuples(st.integers(0, 7), st.integers(0, 3)), max_size=25),
)


def _graph_store(knows, interests):
    store = QuadStore()
    for s, o in knows:
        store.add(f":p{s}", ":knows", f":p{o}")
    for s, t in interests:
        store.add(f":p{s}", ":interest", f":tag{t}")
    return store.build()


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(graphs)
def test_strategies_agree_on_optional_minus_not_exists(g):
    knows, interests = g
    store = _graph_store(knows, interests)
    queries = [
        "SELECT ?a ?b ?t { ?a :knows ?b . OPTIONAL { ?b :interest ?t } }",
        "SELECT ?a ?b { ?a :knows ?b . MINUS { ?b :knows ?a } }",
        "SELECT ?a ?b { ?a :knows ?b . FILTER NOT EXISTS { ?b :interest ?t } }",
        "SELECT ?a ?b ?c { ?a :knows ?b . ?b :knows ?c . ?c :knows ?a }",
    ]
    for q in queries:
        ref = _exec(store, q, "legacy", "merge")
        for engine in ENGINES:
            for strategy in (None, "hash", "merge"):
                assert _exec(store, q, engine, strategy) == ref, (q, engine, strategy)


# ---------------------------------------------------------------------------
# costing pins: strategy choice + semi/anti estimates through stats
# ---------------------------------------------------------------------------


def _plan_for(store, query, strategy=None):
    e = Engine(store, EngineConfig(join_strategy=strategy))
    node, vt = e.parse(query)
    return e.plan(node), vt


def test_planner_anti_estimate_flows_through_stats(small_store):
    """The anti estimate must reflect the right side (containment-based
    semi-join selectivity), not the old flat left * 0.5 — and it must be
    set before the hash-vs-merge choice prices output cost."""
    from repro.core.planner import PHashJoin, PMergeJoin
    from repro.core.stats import GraphStats

    stats = GraphStats(small_store)
    # pin the stats method itself: d_b >= d_a -> every left key can match
    assert stats.semi_join_cardinality(100, 10, 10, anti=True) == 0.0
    assert stats.semi_join_cardinality(100, 10, 10, anti=False) == 100.0
    # half the left key domain is covered by the right side
    assert stats.semi_join_cardinality(100, 10, 5, anti=True) == 50.0

    q = "SELECT ?a ?b { ?a :knows ?b . MINUS { ?b :knows ?c } }"
    plan, _ = _plan_for(small_store, q)

    def find_join(n):
        if isinstance(n, (PHashJoin, PMergeJoin)):
            return n
        for f in ("child", "probe", "build", "left", "right"):
            if hasattr(n, f):
                j = find_join(getattr(n, f))
                if j is not None:
                    return j
        return None

    j = find_join(plan)
    assert j is not None and j.mode == "anti"
    # :knows has 2 edges with every subject also an object's domain; the
    # containment estimate gives 0 surviving rows — the flat rule said 1.0
    assert j.est_rows != pytest.approx(2 * 0.5), j.est_rows


def test_planner_strategy_choice_and_force(small_store):
    from repro.core.planner import PHashJoin, PMergeJoin, PSort, explain

    # UNION output is unsorted on the join var -> cost picks hash, no PSort
    q = ("SELECT ?a ?b ?t { { ?a :knows ?b } UNION { ?b :knows ?a } "
         "OPTIONAL { ?b :interest ?t } }")
    plan, vt = _plan_for(small_store, q)

    def collect(n, cls, acc):
        if isinstance(n, cls):
            acc.append(n)
        for f in ("child", "probe", "build", "left", "right"):
            if hasattr(n, f):
                collect(getattr(n, f), cls, acc)
        return acc

    hash_joins = collect(plan, PHashJoin, [])
    assert hash_joins and hash_joins[0].mode == "left_outer"
    assert not collect(plan, PSort, []), "hash strategy must not re-sort"
    assert "HashJoin" in explain(plan, vt)

    # forcing merge restores the double-PSort shape
    plan_m, _ = _plan_for(small_store, q, strategy="merge")
    assert collect(plan_m, PMergeJoin, [])
    assert not collect(plan_m, PHashJoin, [])
    assert len(collect(plan_m, PSort, [])) >= 1

    # forcing hash converts even sorted-input binary joins
    q2 = "SELECT ?a ?b ?t { ?a :knows ?b . OPTIONAL { ?a :interest ?t } }"
    plan_h, _ = _plan_for(small_store, q2, strategy="hash")
    assert collect(plan_h, PHashJoin, [])


def test_planner_sorted_inputs_still_merge(small_store):
    """Cost-model pin: with both inputs already sorted on the join var the
    merge join is nearly free and must win; two large unsorted inputs must
    flip to hash (that is the whole point of §11)."""
    from repro.core import algebra as A
    from repro.core.planner import Planner, PScan
    from repro.core.stats import GraphStats

    pl = Planner(GraphStats(small_store), dictionary=small_store.dict)
    pat = A.TriplePattern(A.V(0), A.K(":knows"), A.V(1))

    def leaf(est, sort_var):
        n = PScan(pat, sort_var)
        n.est_rows = est
        return n

    sorted_l, sorted_r = leaf(100_000, 0), leaf(100_000, 0)
    assert pl._choose_join_strategy(sorted_l, sorted_r, 0, 100.0) == "merge"
    unsorted_l, unsorted_r = leaf(100_000, None), leaf(100_000, None)
    assert pl._choose_join_strategy(unsorted_l, unsorted_r, 0, 100.0) == "hash"
    # one sorted side + a tiny other side: re-sorting the tiny side is
    # cheaper than building a hash table over the big sorted one
    tiny = leaf(100, None)
    assert pl._choose_join_strategy(tiny, sorted_r, 0, 100.0) == "merge"


def test_hash_join_profile_surfaces_counters(small_store):
    e = Engine(small_store, EngineConfig(join_strategy="hash"))
    q = "SELECT ?a ?b ?t { ?a :knows ?b . OPTIONAL { ?b :interest ?t } }"
    r = e.execute(q)
    prof = r.profile()
    assert "HashJoin" in prof and "hash_build_rows" in prof
