"""Workload-history observability (DESIGN.md §14): canonical query
fingerprinting, plan-node fingerprints and the cardinality feedback
store, the end-to-end feedback loop (repeated query loses its MISEST
flags under ``cardinality_feedback="apply"``), the workload repository's
histograms/persistence/regression detection, the flight recorder's
triggers, the OpenMetrics exposition + validator, and the sliding-window
edge cases the exporter depends on."""

import json
import os
import threading
import time

import pytest

from repro.core import Engine, EngineConfig, QuadStore, telemetry
from repro.core.profiler import collect_stats
from repro.core.telemetry import CardinalityFeedback, query_fingerprint
from repro.serve.flight_recorder import FlightRecorder
from repro.serve.metrics import (
    LatencyHistogram,
    MetricsRegistry,
    SlidingWindow,
    validate_openmetrics,
)
from repro.serve.workload_repo import WorkloadRepository


def _chain_store(n=120):
    store = QuadStore()
    for i in range(n):
        store.add(f":p{i}", ":knows", f":p{(i * 7 + 1) % n}")
        store.add(f":p{i}", ":age", 20 + i % 30)
        store.add(f":p{i}", ":interest", f":tag{i % 5}")
    return store.build()


def _parse(text):
    store = _chain_store(12)
    return Engine(store).parse(text)[0]


# ---------------------------------------------------------------------------
# template fingerprinting
# ---------------------------------------------------------------------------


def test_query_fingerprint_canonicalizes_vars_and_literals():
    base = query_fingerprint(
        _parse("SELECT ?a { ?a :age ?x . FILTER(?x > 25) }"))
    # different variable names, whitespace, and literal values: same shape
    assert base == query_fingerprint(
        _parse("SELECT  ?person  { ?person :age ?n .  FILTER( ?n > 42 ) }"))
    # different predicate: different shape
    assert base != query_fingerprint(
        _parse("SELECT ?a { ?a :knows ?x . FILTER(?x > 25) }"))
    # different structure (no filter): different shape
    assert base != query_fingerprint(_parse("SELECT ?a { ?a :age ?x }"))


def test_query_fingerprint_distinguishes_join_shapes():
    one_hop = query_fingerprint(_parse("SELECT ?a ?b { ?a :knows ?b }"))
    two_hop = query_fingerprint(
        _parse("SELECT ?a ?c { ?a :knows ?b . ?b :knows ?c }"))
    assert one_hop != two_hop


# ---------------------------------------------------------------------------
# plan-node fingerprints + cardinality feedback store
# ---------------------------------------------------------------------------


def test_node_fingerprints_annotated_and_stable():
    store = _chain_store(30)
    eng = Engine(store)
    node, _vt = eng.parse("SELECT ?a ?b { ?a :knows ?b . ?b :age ?x }")
    p1 = eng.plan(node)
    node2, _ = eng.parse("SELECT ?a ?b { ?a :knows ?b . ?b :age ?x }")
    p2 = eng.plan(node2)

    def fps(n, acc):
        acc.add(n.fp)
        for fld in ("child", "left", "right", "probe", "build"):
            c = getattr(n, fld, None)
            if hasattr(c, "fp"):
                fps(c, acc)
        return acc

    s1, s2 = fps(p1, set()), fps(p2, set())
    assert s1 == s2 and all(s1)  # same query -> same node fingerprints


def test_cardinality_feedback_ewma_merge_eviction():
    fb = CardinalityFeedback(alpha=0.5, max_entries=3)
    fb.record("a", 100.0)
    assert fb.lookup("a") == 100.0
    fb.record("a", 200.0)  # EWMA: 0.5*200 + 0.5*100
    assert fb.lookup("a") == pytest.approx(150.0)
    assert fb.observations("a") == 2
    assert fb.lookup("missing") is None

    v0 = fb.version
    fb.record("b", 10.0)
    fb.record("c", 20.0)
    fb.record("d", 30.0)  # over capacity: least-observed entry evicted
    assert fb.version > v0
    assert len(fb) == 3
    assert fb.lookup("a") is not None  # most-observed survives

    other = CardinalityFeedback()
    other.merge(fb.snapshot())
    assert other.lookup("a") == fb.lookup("a")
    # count-weighted merge: 2 obs at 150 + 1 obs at 300 -> 200
    third = CardinalityFeedback()
    third.record("a", 300.0)
    third.merge({"a": [150.0, 2]})
    assert third.lookup("a") == pytest.approx(200.0)
    assert third.observations("a") == 3


# ---------------------------------------------------------------------------
# end-to-end feedback loop
# ---------------------------------------------------------------------------


def _misest_query():
    # chain join + filter: enough structure for the independence
    # assumption to misestimate on the cyclic chain store
    return ("SELECT ?a ?c { ?a :knows ?b . ?b :knows ?c . ?c :age ?x . "
            "FILTER(?x > 25) }")


def test_feedback_apply_overrides_estimates_and_shows_source():
    store = _chain_store()
    eng = Engine(store, EngineConfig(engine="barq",
                                     cardinality_feedback="apply"))
    q = _misest_query()
    r1 = eng.execute(q)
    q1 = collect_stats(r1.root).get("max_q_error", 1.0)
    # second run re-plans with observed per-node cardinalities
    r2 = eng.execute(q)
    q2 = collect_stats(r2.root).get("max_q_error", 1.0)
    assert r2.n_rows == r1.n_rows
    assert q2 <= max(2.0, q1)  # never worse, and converged
    assert q2 <= 2.0
    assert "MISEST" not in r2.explain_analyze()
    assert "(source=feedback)" in eng.explain(q)
    assert "(source=feedback)" in r2.explain_analyze()


def test_feedback_off_is_byte_identical_and_observe_changes_nothing():
    store = _chain_store()
    q = _misest_query()
    default = Engine(store, EngineConfig(engine="barq"))
    off = Engine(store, EngineConfig(engine="barq",
                                     cardinality_feedback="off"))
    obs = Engine(store, EngineConfig(engine="barq",
                                     cardinality_feedback="observe"))
    assert off.explain(q) == default.explain(q)
    obs.execute(q)
    # observe records but never reads: plans stay identical after runs
    assert obs.explain(q) == default.explain(q)
    assert len(obs.feedback) > 0  # ...but the store did fill
    assert off.feedback is None


def test_feedback_version_advances_plan_fingerprint_only_in_apply():
    store = _chain_store()
    q = _misest_query()
    ap = Engine(store, EngineConfig(engine="barq",
                                    cardinality_feedback="apply"))
    fp0 = ap.plan_fingerprint()
    ap.execute(q)
    assert ap.plan_fingerprint() != fp0  # new observations -> new plans

    obs = Engine(store, EngineConfig(engine="barq",
                                     cardinality_feedback="observe"))
    fp0 = obs.plan_fingerprint()
    obs.execute(q)
    assert obs.plan_fingerprint() == fp0  # observe never re-plans


# ---------------------------------------------------------------------------
# workload repository
# ---------------------------------------------------------------------------


def test_repository_accumulates_and_persists(tmp_path):
    repo = WorkloadRepository()
    led = telemetry.KernelLedger()
    led.record("join_expand", "numpy", 0.002)
    for i in range(5):
        repo.observe("fp1", 0.010 + i * 1e-4, rows=100, ledger=led,
                     max_q_error=3.0, query_text="SELECT ...")
    repo.observe("fp2", 0.5, rows=1, max_q_error=40.0)
    st = repo.get("fp1")
    assert st.n == 5 and st.rows == 500
    assert st.kernel_counts["join_expand"] == 5
    assert st.max_q_error == 3.0
    assert repo.qerror_leaderboard(5)[0]["fingerprint"] == "fp2"
    assert repo.top_by_wall(1)[0]["fingerprint"] == "fp2"  # 0.5s dominates

    path = str(tmp_path / "wl.jsonl")
    repo.feedback.record("node-a", 123.0)
    assert repo.save(path) == 2
    fresh = WorkloadRepository()
    assert fresh.load(path) == 2
    assert fresh.get("fp1").n == 5
    assert fresh.get("fp1").latency_hist == repo.get("fp1").latency_hist
    assert fresh.feedback.lookup("node-a") == 123.0
    # loading twice merges additively
    fresh.load(path)
    assert fresh.get("fp1").n == 10


def test_repository_eviction_and_bound():
    repo = WorkloadRepository(max_fingerprints=4)
    for i in range(10):
        repo.observe(f"fp{i}", 0.001, ts=float(i))
    assert len(repo) == 4
    assert repo.n_evicted == 6
    assert repo.get("fp9") is not None  # most recent survives
    assert repo.get("fp0") is None


def test_repository_regression_detection():
    repo = WorkloadRepository(regression_factor=2.0)
    for i in range(20):
        out = repo.observe("fp", 0.010, ts=float(i))
        assert out["regression"] is None  # steady state: no alarms
    out = repo.observe("fp", 0.100, ts=30.0)  # 10x the established p99
    assert out["regression"] is not None
    assert out["regression"]["factor"] >= 2.0
    assert repo.regressions[-1]["fingerprint"] == "fp"
    # a cold fingerprint can't regress: no baseline yet
    out = repo.observe("cold-fp", 9.9)
    assert out["regression"] is None


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------


def test_flight_recorder_q_error_trigger(tmp_path):
    fr = FlightRecorder(out_dir=str(tmp_path / "flight"),
                        q_error_threshold=16.0)
    tr = telemetry.QueryTrace("t")
    bundle = fr.observe("fp", 0.01, max_q_error=100.0, trace=tr,
                        explain_fn=lambda: "EXPLAIN TEXT",
                        query_text="SELECT ...")
    assert bundle is not None
    assert sorted(os.listdir(bundle)) == ["explain.txt", "meta.json",
                                          "trace.json"]
    with open(os.path.join(bundle, "meta.json")) as f:
        meta = json.load(f)
    assert meta["reasons"] == ["q_error"]
    assert meta["query"] == "SELECT ..."
    with open(os.path.join(bundle, "explain.txt")) as f:
        assert "EXPLAIN TEXT" in f.read()
    # under threshold: ring only, no bundle
    assert fr.observe("fp", 0.01, max_q_error=2.0) is None
    assert fr.n_captures == 1


def test_flight_recorder_latency_trigger_and_bounds(tmp_path):
    fr = FlightRecorder(out_dir=str(tmp_path / "flight"),
                        latency_factor=3.0, ring_size=4, max_captures=2)
    # no baseline -> no latency trigger however slow
    assert fr.observe("fp", 10.0, baseline_p99_s=0.0) is None
    assert fr.observe("fp", 0.5, baseline_p99_s=0.01) is not None
    assert fr.observe("fp", 0.5, baseline_p99_s=0.01) is not None
    # capture budget exhausted: still ringing, no more disk
    assert fr.observe("fp", 0.5, baseline_p99_s=0.01) is None
    assert fr.n_captures == 2
    for _ in range(10):
        fr.observe("fp", 0.001)
    assert len(fr.ring) == 4  # bounded ring
    assert fr.snapshot()["observed"] == 14
    assert all("trace" not in e for e in fr.snapshot()["ring"])


# ---------------------------------------------------------------------------
# server end-to-end
# ---------------------------------------------------------------------------


def test_server_feedback_loop_and_workload_surface(tmp_path):
    from repro.serve.query_server import QueryServer

    store = _chain_store()
    fr = FlightRecorder(out_dir=str(tmp_path / "flight"),
                        q_error_threshold=4.0)
    srv = QueryServer(
        store,
        EngineConfig(engine="barq", cardinality_feedback="apply"),
        flight=fr,
    )
    q = _misest_query()
    r1 = srv.execute("q", q)
    r2 = srv.execute("q", q)
    assert r1.fingerprint == r2.fingerprint != ""
    assert r2.n_rows == r1.n_rows
    assert r2.max_q_error <= 2.0  # repeat re-planned from feedback
    if r1.max_q_error >= 4.0:
        assert r1.flight_bundle is not None  # cold misestimate captured

    snap = srv.metrics_snapshot()
    assert snap["workload"]["fingerprints"] == 1
    assert snap["workload"]["top_by_wall"][0]["n"] == 2
    assert snap["workload"]["feedback_entries"] > 0
    assert "regressions" in snap
    assert snap["flight"]["observed"] == 2

    exposition = srv.openmetrics()
    fams = validate_openmetrics(exposition)
    assert "barq_fingerprint_requests" in fams
    assert f'fingerprint="{r1.fingerprint}"' in exposition


def test_server_observe_mode_keeps_plan_cache_hot():
    from repro.serve.query_server import QueryServer

    store = _chain_store()
    srv = QueryServer(store, EngineConfig(
        engine="barq", cardinality_feedback="observe"))
    q = _misest_query()
    srv.execute("q", q)
    r2 = srv.execute("q", q)
    assert r2.plan_cache_hit  # observe never invalidates cached plans


# ---------------------------------------------------------------------------
# metrics edge cases + exposition validation
# ---------------------------------------------------------------------------


def test_sliding_window_empty_and_single_sample():
    w = SlidingWindow()
    assert w.percentile(50) == 0.0
    assert w.mean() == 0.0
    assert w.rate() == 0.0
    w.add(0.01, ts=100.0)
    assert w.percentile(99) == 0.01
    assert w.rate(window_s=60, now=100.0) == 0.0  # one sample: no rate
    assert w.percentile(-5) == w.percentile(200) == 0.01  # clamped


def test_metrics_registry_empty_snapshot_schema():
    snap = MetricsRegistry().snapshot()
    # pinned key schema: exporters and the report tool key into these
    assert set(snap) == {"uptime_s", "requests", "plan_cache", "kernels",
                         "pool", "latency_hist", "execution"}
    assert set(snap["requests"]) == {"count", "rows", "errors", "qps",
                                     "mean_ms", "p50_ms", "p99_ms"}
    assert set(snap["plan_cache"]) == {"hits", "misses", "hit_rate"}
    assert set(snap["latency_hist"]) == {"buckets", "sum", "count"}
    assert set(snap["execution"]) == {"spill_bytes", "spill_files",
                                      "adaptive_switches"}
    # zero-traffic server: all-zero, never NaN/ZeroDivisionError
    assert snap["requests"]["qps"] == 0.0
    assert snap["requests"]["p99_ms"] == 0.0
    assert snap["plan_cache"]["hit_rate"] == 0.0
    assert snap["execution"]["spill_bytes"] == 0
    json.dumps(snap)


def test_latency_histogram_buckets_and_merge():
    h = LatencyHistogram()
    h.observe(0.0004)
    h.observe(0.003)
    h.observe(99.0)  # beyond last bound -> +Inf bucket
    cum = dict(h.cumulative())
    assert cum["0.0005"] == 1 and cum["0.005"] == 2 and cum["+Inf"] == 3
    other = LatencyHistogram()
    other.merge_snapshot(h.snapshot())
    other.merge_snapshot(h.snapshot())
    assert other.count == 6
    assert dict(other.cumulative())["+Inf"] == 6
    assert other.sum == pytest.approx(2 * h.sum)


def test_validate_openmetrics_catches_tampering():
    reg = MetricsRegistry()
    reg.observe_request(0.01, n_rows=3)
    text = reg.to_openmetrics()
    assert "barq_requests" in validate_openmetrics(text)
    for tamper, msg in [
        (text.replace("# EOF\n", ""), "EOF"),
        (text.replace("barq_requests_total", "barq_requests"), "_total"),
        ("barq_orphan 1\n# EOF\n", "TYPE"),
        (text + "# EOF\n", "exactly once"),
        (text.replace("\nbarq_qps ", "\nbarq_qps_total "), "suffixed"),
    ]:
        with pytest.raises(ValueError, match=msg):
            validate_openmetrics(tamper)
    # histogram cumulativity: shrink a later bucket below an earlier one
    broken = text.replace('le="+Inf"} ', 'le="+Inf"} -')
    with pytest.raises(ValueError):
        validate_openmetrics(broken)


# ---------------------------------------------------------------------------
# threaded trace isolation (contextvar scoping)
# ---------------------------------------------------------------------------


def test_trace_query_threads_do_not_leak_dispatches():
    """Two threads tracing concurrently must each see only their own
    kernel dispatches — the active trace is a contextvar, not a global."""
    results = {}
    barrier = threading.Barrier(2)

    def worker(name, n_dispatches):
        tr = telemetry.QueryTrace(name)
        barrier.wait()
        with telemetry.trace_query(trace=tr):
            for _ in range(n_dispatches):
                telemetry.record_dispatch(f"k_{name}", "numpy",
                                          time.perf_counter(), 1e-6)
                time.sleep(0.001)
        results[name] = tr.ledger

    t1 = threading.Thread(target=worker, args=("alpha", 7))
    t2 = threading.Thread(target=worker, args=("beta", 11))
    t1.start(); t2.start(); t1.join(); t2.join()

    assert dict(results["alpha"].counts) == {"k_alpha": 7}
    assert dict(results["beta"].counts) == {"k_beta": 11}


# ---------------------------------------------------------------------------
# report tooling
# ---------------------------------------------------------------------------


def test_report_metrics_and_workload_tables(tmp_path):
    from repro.launch.report import metrics_report, workload_report

    reg = MetricsRegistry()
    led = telemetry.KernelLedger()
    led.record("gather_emit", "numpy", 0.001)
    reg.observe_request(0.01, n_rows=5, ledger=led,
                        pool_delta={"allocations": 2})
    reg.observe_plan_cache(True)
    mpath = str(tmp_path / "metrics.json")
    reg.save(mpath)
    out = metrics_report(mpath)
    assert "requests: 1" in out and "gather_emit/numpy" in out

    repo = WorkloadRepository()
    for i in range(20):
        repo.observe("fp-slow", 0.02, rows=10, max_q_error=8.0,
                     query_text="SELECT ?a { ?a :p ?b }", ts=float(i))
    repo.observe("fp-slow", 0.2, ts=30.0)  # triggers a regression
    wpath = str(tmp_path / "wl.jsonl")
    repo.save(wpath)
    out = workload_report(wpath)
    assert "fp-slow" in out
    assert "q-error leaderboard" in out
    assert "latency regressions" in out
