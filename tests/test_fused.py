"""Fused whole-BGP counting vs the operator engine (beyond-paper path)."""

import numpy as np
import pytest

from repro.core import Engine, EngineConfig
from repro.core.fused import fused_chain_count, fused_q6_count
from repro.data import generate_social_graph


@pytest.fixture(scope="module")
def store():
    s, _ = generate_social_graph(scale=0.05, seed=9)
    return s


def _engine_count(store, q):
    r = Engine(store, EngineConfig(engine="barq")).execute(q)
    return int(store.dict.decode(int(r.rows[0, 0])))


def test_chain2_matches_engine(store):
    want = _engine_count(
        store, "SELECT (COUNT(*) AS ?c) { ?a :knows ?b . ?b :hasInterest ?t }"
    )
    got = fused_chain_count(store, [":knows", ":hasInterest"])
    assert got == want


def test_chain3_matches_engine(store):
    want = _engine_count(
        store,
        "SELECT (COUNT(*) AS ?c) { ?a :knows ?b . ?b :knows ?c . ?c :hasInterest ?t }",
    )
    got = fused_chain_count(store, [":knows", ":knows", ":hasInterest"])
    assert got == want


def test_q6_matches_engine(store):
    want = _engine_count(
        store,
        """SELECT (COUNT(*) AS ?c) {
             ?p1 :knows ?p2 . ?p2 :knows ?p3 . ?p3 :hasInterest ?t .
             FILTER (?p1 != ?p3)
           }""",
    )
    got = fused_q6_count(store)
    assert got == want


def test_empty_predicate():
    from repro.core import QuadStore

    s = QuadStore()
    s.add(":a", ":knows", ":b")
    s.build()
    assert fused_chain_count(s, [":knows", ":nope"]) == 0
    assert fused_q6_count(s) == 0
