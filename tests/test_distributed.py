"""Distributed engine tests — run in a subprocess with 8 placeholder
devices so the main pytest process keeps its single real CPU device."""

import json
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import collections, json
    import numpy as np
    import jax
    from repro.core import distributed as D

    mesh = D.engine_mesh()
    rng = np.random.RandomState(1)
    NL, NR = 4096, 2048
    lkeys = rng.randint(0, 300, NL).astype(np.int32)
    rkeys = rng.randint(0, 300, NR).astype(np.int32)
    lrows = np.stack([lkeys, rng.randint(0, 99, NL).astype(np.int32)])
    rrows = np.stack([rkeys, rng.randint(0, 99, NR).astype(np.int32)])
    lc = collections.Counter(lkeys.tolist()); rc = collections.Counter(rkeys.tolist())
    oracle = sum(lc[k] * rc[k] for k in lc if k in rc)

    f = D.make_join_count(mesh, cap_factor=4.0)
    cnt, of = f(D.shard_relation(mesh, lrows), D.shard_relation(mesh, rrows))

    g = D.make_group_count(mesh, cap_factor=4.0, max_groups_per_dev=512)
    gkeys, gcounts, _ = g(D.shard_relation(mesh, lrows))
    got = {int(k): int(c) for k, c in zip(np.asarray(gkeys).ravel(),
                                           np.asarray(gcounts).ravel())
           if k != np.iinfo(np.int32).max and c > 0}

    m = D.make_join_materialize(mesh, out_cap_per_device=16384, cap_factor=4.0)
    out_keys, li, ri, n, of3 = m(D.shard_relation(mesh, lrows),
                                 D.shard_relation(mesh, rrows))
    ks = np.asarray(out_keys); ks = ks[ks != np.iinfo(np.int32).max]
    mat_ok = (collections.Counter(ks.tolist())
              == {k: lc[k] * rc[k] for k in lc if k in rc})

    print(json.dumps({
        "count": int(cnt), "oracle": oracle, "overflow": int(of),
        "group_ok": got == dict(lc), "mat_ok": bool(mat_ok),
        "mat_n": int(n), "mat_of": int(of3),
        "n_devices": len(jax.devices()),
    }))
    """
)


@pytest.fixture(scope="module")
def dist_result():
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_runs_on_8_devices(dist_result):
    assert dist_result["n_devices"] == 8


def test_join_count_exact(dist_result):
    assert dist_result["count"] == dist_result["oracle"]
    assert dist_result["overflow"] == 0


def test_group_count_exact(dist_result):
    assert dist_result["group_ok"]


def test_join_materialize_exact(dist_result):
    assert dist_result["mat_ok"]
    assert dist_result["mat_n"] == dist_result["oracle"]
    assert dist_result["mat_of"] == 0
