"""Vectorized grouping engine (DESIGN.md §10): DISTINCT-aggregate
semantics regressions, empty-group unbound outputs, HAVING end-to-end
(parser → planner → executor), the segment_reduce kernel-dispatch claim,
and hypothesis parity sweeps — batch engine vs a Python-dict oracle vs the
legacy row engine, across the numpy/jax/pallas kernel backends."""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import Engine, EngineConfig, QuadStore
from repro.core import algebra as A
from repro.core import vecops
from repro.core.algebra import AggSpec
from repro.core.batch import BatchPool
from repro.core.dictionary import Dictionary
from repro.core.operators.aggregate import SortGroupBy, StreamingGroupBy
from repro.core.operators.sort import MaterializedSource
from repro.core.parser import parse_query
from repro.core.planner import PGroup, PHaving, Planner, explain
from repro.core.stats import GraphStats
from repro.kernels import ops

BACKENDS = ("numpy", "jax", "pallas")
ENGINES = ("barq", "legacy", "mixed")
FUNCS = ("count", "sum", "min", "max", "avg")


# ---------------------------------------------------------------------------
# oracle (shared single source of truth for the aggregate semantics)
# ---------------------------------------------------------------------------


def oracle_group(rows, n_keys, aggs, numeric_of):
    """Python-dict grouping oracle over code tuples (None == unbound).

    Semantics pinned here and implemented by BOTH engines: COUNT counts
    bound terms; SUM/MIN/MAX/AVG restrict to numeric terms; DISTINCT dedups
    bound codes before the function applies; MIN/MAX/AVG of an empty
    numeric set are unbound (None); SUM of an empty set is 0.
    """
    groups = {}
    for r in rows:
        groups.setdefault(tuple(r[:n_keys]), []).append(r[n_keys:])
    out = []
    for key, rs in sorted(groups.items(), key=str):
        vals = []
        for ai, a in enumerate(aggs):
            if a.var is None:
                vals.append(float(len(rs)))
                continue
            codes = [r[ai] for r in rs if r[ai] is not None]
            if a.distinct:
                codes = sorted(set(codes))
            nums = [numeric_of(c) for c in codes]
            nums = [v for v in nums if v is not None]
            if a.func == "count":
                vals.append(float(len(codes)))
            elif a.func == "sum":
                vals.append(float(sum(nums)))
            elif a.func == "min":
                vals.append(min(nums) if nums else None)
            elif a.func == "max":
                vals.append(max(nums) if nums else None)
            elif a.func == "avg":
                vals.append(sum(nums) / len(nums) if nums else None)
        out.append(key + tuple(vals))
    return out


def _drain_rows(op):
    rows = []
    while True:
        b = op.next_batch()
        if b is None:
            return rows
        rows.extend(tuple(r) for r in b.to_rows_array())
        b.release()


def _decode_agg(d, code):
    return None if code == -1 else float(d.decode(int(code)))


# ---------------------------------------------------------------------------
# DISTINCT-aggregate regressions (the SUM(DISTINCT) == COUNT(DISTINCT) bug)
# ---------------------------------------------------------------------------


def _store_with_vals():
    store = QuadStore()
    # :p0 has values {1, 2, 3} with 2 duplicated; :p1 only {5}
    for v in (1, 2, 2, 3):
        store.add(":p0", ":val", int(v))
    store.add(":p1", ":val", 5)
    return store.build()


def _run_rows(store, q, engine):
    e = Engine(store, EngineConfig(engine=engine, initial_batch=32, max_batch=64))
    r = e.execute(q)
    return sorted(
        tuple(None if c == -1 else store.dict.decode(int(c)) for c in row)
        for row in r.rows
    )


@pytest.mark.parametrize("func,p0,p1", [
    ("sum", 6, 5),       # 1+2+3, not the distinct COUNT 3
    ("min", 1, 5),
    ("max", 3, 5),
    ("avg", 2, 5),       # (1+2+3)/3
    ("count", 3, 1),
])
def test_distinct_aggregate_applies_function(func, p0, p1):
    store = _store_with_vals()
    q = (f"SELECT ?p ({func.upper()}(DISTINCT ?v) AS ?o) "
         "{ ?p :val ?v } GROUP BY ?p")
    for eng in ENGINES:
        assert _run_rows(store, q, eng) == [(":p0", p0), (":p1", p1)], eng


def test_count_distinct_ignores_unbound_and_counts_iris():
    store = QuadStore()
    store.add(":a", ":knows", ":x")
    store.add(":a", ":knows", ":y")
    store.add(":b", ":knows", ":x")
    store.add(":a", ":age", 3)
    store.add(":b", ":age", 4)
    store.add(":c", ":age", 5)  # :c has no :knows — OPTIONAL leaves ?q unbound
    store.build()
    q = ("SELECT ?p (COUNT(DISTINCT ?q) AS ?n) "
         "{ ?p :age ?a OPTIONAL { ?p :knows ?q } } GROUP BY ?p")
    for eng in ENGINES:
        # IRIs are bound non-numeric terms: COUNT must include them,
        # unbound rows must not contribute (SPARQL 1.1 §18.5)
        assert _run_rows(store, q, eng) == [(":a", 2), (":b", 1), (":c", 0)], eng


def test_empty_group_min_max_avg_unbound():
    store = _store_with_vals()
    # no :nope triples: the global aggregate still yields ONE row, with
    # COUNT/SUM zero and MIN/MAX/AVG *unbound* — never an encoded NaN term
    q = ("SELECT (COUNT(?v) AS ?c) (SUM(?v) AS ?s) (MIN(?v) AS ?mn) "
         "(MAX(?v) AS ?mx) (AVG(?v) AS ?a) { ?p :nope ?v }")
    for eng in ENGINES:
        assert _run_rows(store, q, eng) == [(0, 0, None, None, None)], eng
    # an all-non-numeric group follows the same unbound rule for the
    # numeric aggregates, while COUNT still counts the bound terms
    store2 = QuadStore()
    store2.add(":a", ":tag", ":t1")
    store2.add(":b", ":tag", ":t2")
    store2.build()
    qs = ("SELECT (MIN(?t) AS ?mn) (AVG(?t) AS ?a) (COUNT(?t) AS ?c) "
          "{ ?p :tag ?t }")
    for eng in ENGINES:
        rows = _run_rows(store2, qs, eng)
        assert rows == [(None, None, 2)], (eng, rows)


def test_no_nan_term_encoded():
    store = _store_with_vals()
    before = len(store.dict)
    _run_rows(store, "SELECT (MIN(?v) AS ?m) { ?p :nope ?v }", "barq")
    added = [store.dict.decode(i) for i in range(before, len(store.dict))]
    assert not any(isinstance(t, float) and np.isnan(t) for t in added), added


# ---------------------------------------------------------------------------
# the docstring claim: segment_reduce kernels actually power the hot path
# ---------------------------------------------------------------------------


def test_grouped_query_dispatches_segment_reduce_kernel():
    store = _store_with_vals()
    e = Engine(store, EngineConfig(engine="barq"))
    before = ops.dispatch_count("segment_reduce")
    r = e.execute(
        "SELECT ?p (SUM(?v) AS ?s) (COUNT(DISTINCT ?v) AS ?n) "
        "{ ?p :val ?v } GROUP BY ?p"
    )
    assert r.n_rows == 2
    # the kernel dispatch layer saw the segmented reductions...
    assert ops.dispatch_count("segment_reduce") > before
    # ...and the operator accounts for them in its profiler stats
    found = {}

    def walk(op):
        found.update({
            k: v for k, v in op.stats.extra.items() if k.startswith(("group", "segment"))
        })
        for c in op.children():
            walk(c)

    walk(r.root)
    assert found.get("segment_reduce", 0) > 0
    assert found.get("group_runs", 0) >= 2
    assert "segment_reduce_ms" in found
    assert "segment_reduce" in r.profile()


# ---------------------------------------------------------------------------
# HAVING: parser → planner → executor
# ---------------------------------------------------------------------------


def test_parse_having_alias_and_hidden_aggregate():
    node, vt = parse_query(
        "SELECT ?g (SUM(?v) AS ?s) { ?g :p ?v } "
        "GROUP BY ?g HAVING (?s > 5) (COUNT(?v) > 1)"
    )
    proj = node
    assert isinstance(proj, A.Project)
    g = proj.child
    assert isinstance(g, A.GroupAgg)
    assert isinstance(g.having, A.And) and len(g.having.terms) == 2
    # COUNT(?v) desugared to a hidden spec, stripped by the projection
    assert len(g.aggs) == 2
    hidden = g.aggs[1]
    assert (hidden.func, hidden.var, hidden.distinct) == ("count", vt.var("v"), False)
    assert hidden.out not in proj.vars
    # the SUM alias is shared, not duplicated
    assert g.having.terms[0] == A.Cmp(">", A.VarRef(g.aggs[0].out), A.Lit(5))


def test_parse_having_reuses_matching_select_aggregate():
    node, _ = parse_query(
        "SELECT ?g (SUM(?v) AS ?s) { ?g :p ?v } GROUP BY ?g HAVING (SUM(?v) > 5)"
    )
    g = node.child
    assert isinstance(g, A.GroupAgg)
    assert len(g.aggs) == 1  # SUM(?v) in HAVING resolved to the ?s spec
    assert g.having == A.Cmp(">", A.VarRef(g.aggs[0].out), A.Lit(5))


def test_parse_having_requires_parenthesized_constraint():
    with pytest.raises(SyntaxError):
        parse_query("SELECT ?g { ?g :p ?v } GROUP BY ?g HAVING ?v > 5")


def test_select_star_does_not_leak_hidden_having_aggregate():
    store = _store_with_vals()
    node, vt = parse_query(
        "SELECT * { ?p :val ?v } GROUP BY ?p HAVING (SUM(?v) > 5)"
    )
    assert isinstance(node, A.Project)
    assert node.vars == [vt.var("p")]  # the hidden SUM column is stripped
    e = Engine(store, EngineConfig(engine="barq"))
    r = e.execute("SELECT * { ?p :val ?v } GROUP BY ?p HAVING (SUM(?v) > 5)")
    assert r.rows.shape == (1, 1)  # one surviving group, ?p only
    assert store.dict.decode(int(r.rows[0, 0])) == ":p0"


def test_having_rejects_non_group_non_aggregate_vars():
    with pytest.raises(SyntaxError, match="group variables or aggregates"):
        parse_query("SELECT ?s { ?s :p ?v } HAVING (?s > 0)")
    with pytest.raises(SyntaxError, match="group variables or aggregates"):
        parse_query("SELECT ?g (SUM(?v) AS ?s) { ?g :p ?v } "
                    "GROUP BY ?g HAVING (?v > 0)")
    # projecting an ungrouped var is a parse error too, not an internal
    # ValueError downstream (HAVING alone introduces the grouping here)
    with pytest.raises(SyntaxError, match="GROUP BY key or an aggregate"):
        parse_query("SELECT ?x { ?x :p ?y } HAVING (COUNT(?y) > 1)")
    with pytest.raises(SyntaxError, match="GROUP BY key or an aggregate"):
        parse_query("SELECT ?x (SUM(?y) AS ?s) { ?x :p ?y } GROUP BY ?g")


def test_count_distinct_star_rejected():
    # whole-solution dedup is unimplemented: refusing beats a silently
    # wrong plain row count
    with pytest.raises(SyntaxError, match="DISTINCT"):
        parse_query("SELECT (COUNT(DISTINCT *) AS ?n) { ?s :p ?o }")


def test_distinct_dedup_timed_separately_from_segment_reduce():
    d = _dict_with_terms()
    keys = np.sort(np.arange(64, dtype=np.int32) % 8)
    vals = (np.arange(64) % 5).astype(np.int32)
    src = MaterializedSource((0, 1), np.stack([keys, vals]), 0, 32)
    op = StreamingGroupBy(
        src, 0, [AggSpec("sum", 1, True, 5), AggSpec("sum", 1, False, 6)], d,
        batch_size=32,
    )
    _drain_rows(op)
    ex = op.stats.extra
    assert ex["segment_reduce"] > 0 and ex["distinct_dedup"] > 0
    assert "distinct_dedup_ms" in ex and "segment_reduce_ms" in ex


def test_having_plans_to_phaving_filter_stage():
    store = _store_with_vals()
    node, vt = parse_query(
        "SELECT ?p (SUM(?v) AS ?s) { ?p :val ?v } GROUP BY ?p HAVING (?s > 5)"
    )
    planner = Planner(GraphStats(store), dictionary=store.dict)
    phys = planner.plan(node)
    n = phys
    while not isinstance(n, PHaving):
        n = n.child
    assert isinstance(n.child, PGroup)
    assert n.program is not None  # compiled to an expression-VM program
    assert "Having" in explain(phys, vt)


def test_having_end_to_end_all_engines():
    store = _store_with_vals()
    q = ("SELECT ?p (SUM(DISTINCT ?v) AS ?s) { ?p :val ?v } "
         "GROUP BY ?p HAVING (?s > 5)")
    for eng in ENGINES:
        assert _run_rows(store, q, eng) == [(":p0", 6)], eng
    # hidden-aggregate constraint + global aggregate
    q2 = "SELECT (SUM(?v) AS ?s) { ?p :val ?v } HAVING (COUNT(?v) > 10)"
    for eng in ENGINES:
        assert _run_rows(store, q2, eng) == [], eng


# ---------------------------------------------------------------------------
# packed composite keys
# ---------------------------------------------------------------------------


def test_pack_group_keys_matches_lexsort():
    rng = np.random.RandomState(7)
    cols = np.stack([
        rng.randint(-1, 5, 200).astype(np.int32),
        rng.randint(-1, 3, 200).astype(np.int32),
        rng.randint(-1, 7, 200).astype(np.int32),
    ])
    packed = vecops.pack_group_keys(cols)
    want = np.lexsort(tuple(cols[::-1]))
    got = np.argsort(packed, kind="stable")
    assert np.array_equal(cols[:, got], cols[:, want])


def test_pack_group_keys_overflow_fallback():
    rng = np.random.RandomState(8)
    big = np.iinfo(np.int32).max - 1
    cols = np.stack([
        rng.choice([0, big], 64).astype(np.int32),
        rng.choice([1, big - 1], 64).astype(np.int32),
        rng.choice([2, big - 2], 64).astype(np.int32),
    ])
    packed = vecops.pack_group_keys(cols)  # ranges overflow 63 bits
    order = np.argsort(packed, kind="stable")
    srt = cols[:, order]
    # grouping equivalence: equal packed key <-> equal column tuple
    for j in range(1, srt.shape[1]):
        same_packed = packed[order][j] == packed[order][j - 1]
        same_cols = bool((srt[:, j] == srt[:, j - 1]).all())
        assert same_packed == same_cols
    assert np.array_equal(
        srt, cols[:, np.lexsort(tuple(cols[::-1]))]
    )


# ---------------------------------------------------------------------------
# hypothesis parity sweeps (operator level, all kernel backends)
# ---------------------------------------------------------------------------

_ALL_AGGS = tuple(
    AggSpec(f, 2, dist, 10 + i)
    for i, (f, dist) in enumerate(
        [(f, d) for f in FUNCS for d in (False, True)]
    )
) + (AggSpec("count", None, False, 30),)


def _dict_with_terms():
    d = Dictionary()
    for v in range(10):
        d.encode(int(v))          # codes 0..9: numeric
    for s in ("a", "b", "c"):
        d.encode(f":{s}")         # codes 10..12: non-numeric IRIs
    return d


def _numeric_of(d):
    def f(code):
        v = d.numeric_of(np.asarray([code]))[0]
        return None if np.isnan(v) else float(v)
    return f


codes_col = st.lists(
    st.one_of(st.integers(0, 12), st.none()), min_size=0, max_size=120
)


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(codes_col, st.integers(1, 5), st.integers(0, 2))
def test_sort_group_by_matches_oracle(vals, n_g1, n_g2):
    """Multi-key sort-based grouping == Python-dict oracle, with mixed
    NULLs/duplicates/non-numeric codes (numpy backend)."""
    rng = np.random.RandomState(len(vals) * 31 + n_g1)
    d = _dict_with_terms()
    n = len(vals)
    g1 = rng.randint(0, n_g1, n).astype(np.int32)
    g2 = rng.randint(-1, n_g2 + 1, n).astype(np.int32)  # -1: NULL group key
    v = np.asarray([-1 if c is None else c for c in vals], dtype=np.int32)
    src = MaterializedSource((0, 1, 2), np.stack([g1, g2, v]), None, 32)
    op = SortGroupBy(src, (0, 1), _ALL_AGGS, d, batch_size=32, pool=BatchPool())
    got = sorted(
        (
            (int(r[0]), int(r[1]))
            + tuple(_decode_agg(d, c) for c in r[2:])
            for r in _drain_rows(op)
        ),
        key=str,
    )
    rows = [
        (int(a), int(b)) + tuple(None if x < 0 else int(x) for x in [c] * 10)
        for a, b, c in zip(g1, g2, v)
    ]
    want = sorted(oracle_group(rows, 2, _ALL_AGGS, _numeric_of(d)), key=str)
    assert got == want


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(codes_col, st.integers(1, 6))
def test_streaming_group_by_backends_match_oracle(vals, n_groups):
    """Single sorted group var through every kernel backend (numpy oracle,
    jnp segmented scan, Pallas segmented scan) — including the batch
    boundary carry (batch_size 32 forces spanning runs)."""
    rng = np.random.RandomState(len(vals) * 17 + n_groups)
    d = _dict_with_terms()
    n = len(vals)
    keys = np.sort(rng.randint(0, n_groups, n)).astype(np.int32)
    v = np.asarray([-1 if c is None else c for c in vals], dtype=np.int32)
    rows = [
        (int(k),) + tuple(None if x < 0 else int(x) for x in [c] * 10)
        for k, c in zip(keys, v)
    ]
    want = sorted(oracle_group(rows, 1, _ALL_AGGS, _numeric_of(d)), key=str)
    for be in BACKENDS:
        src = MaterializedSource((0, 2), np.stack([keys, v]), 0, 32)
        op = StreamingGroupBy(src, 0, _ALL_AGGS, d, batch_size=32, backend=be)
        got = sorted(
            (
                (int(r[0]),) + tuple(_decode_agg(d, c) for c in r[1:])
                for r in _drain_rows(op)
            ),
            key=str,
        )
        assert got == want, be


def test_streaming_extremes():
    d = _dict_with_terms()
    aggs = (AggSpec("sum", 1, True, 5), AggSpec("count", 1, True, 6),
            AggSpec("avg", 1, False, 7))
    # single group spanning many batches
    keys = np.zeros(300, dtype=np.int32)
    vals = np.arange(300, dtype=np.int32) % 10
    src = MaterializedSource((0, 1), np.stack([keys, vals]), 0, 32)
    op = StreamingGroupBy(src, 0, aggs, d, batch_size=32)
    [row] = _drain_rows(op)
    assert _decode_agg(d, row[1]) == 45.0  # sum over distinct {0..9}
    assert _decode_agg(d, row[2]) == 10.0
    assert _decode_agg(d, row[3]) == 4.5
    # every row its own group AND every row distinct
    keys = np.arange(64, dtype=np.int32)
    vals = (keys % 10).astype(np.int32)
    src = MaterializedSource((0, 1), np.stack([keys, vals]), 0, 16)
    op = StreamingGroupBy(src, 0, aggs, d, batch_size=16)
    rows = _drain_rows(op)
    assert len(rows) == 64
    assert all(_decode_agg(d, r[2]) == 1.0 for r in rows)
    # empty input: grouped => no rows; global => one row
    src = MaterializedSource((0, 1), np.zeros((2, 0), np.int32), 0, 16)
    assert _drain_rows(StreamingGroupBy(src, 0, aggs, d)) == []
    src = MaterializedSource((0, 1), np.zeros((2, 0), np.int32), 0, 16)
    [row] = _drain_rows(StreamingGroupBy(src, None, aggs, d))
    assert _decode_agg(d, row[0]) == 0.0       # SUM(DISTINCT) of nothing
    assert _decode_agg(d, row[1]) == 0.0       # COUNT(DISTINCT) of nothing
    assert row[2] == -1                        # AVG of nothing: unbound


# ---------------------------------------------------------------------------
# hypothesis parity sweep (engine level: barq == legacy == mixed == oracle)
# ---------------------------------------------------------------------------

entities = st.lists(
    st.tuples(
        st.integers(0, 2),                        # ?a group key
        st.integers(0, 1),                        # ?b group key
        st.lists(st.integers(0, 5), max_size=4),  # values (may be empty)
    ),
    min_size=0, max_size=10,
)


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(entities, st.integers(0, 8))
def test_multikey_having_engine_parity(ents, cutoff):
    """Random multi-key GROUP BY + HAVING queries: every engine returns the
    Python oracle's answer (acceptance query shape of ISSUE 4)."""
    store = QuadStore()
    for i, (a, b, vals) in enumerate(ents):
        store.add(f":e{i}", ":ka", int(a))
        store.add(f":e{i}", ":kb", int(b))
        for v in set(vals):
            store.add(f":e{i}", ":val", int(v))
    store.build()
    q = ("SELECT ?a ?b (SUM(DISTINCT ?v) AS ?s) (COUNT(?v) AS ?c) "
         "{ ?e :ka ?a . ?e :kb ?b OPTIONAL { ?e :val ?v } } "
         f"GROUP BY ?a ?b HAVING (?s >= {cutoff})")
    groups = {}
    for i, (a, b, vals) in enumerate(ents):
        rows = sorted(set(vals)) or [None]
        groups.setdefault((a, b), []).extend(rows)
    oracle = []
    for (a, b), vs in groups.items():
        bound = [v for v in vs if v is not None]
        s = sum(set(bound))
        if s >= cutoff:
            oracle.append((a, b, s, len(bound)))
    oracle = sorted(oracle, key=str)
    for eng in ENGINES:
        assert _run_rows(store, q, eng) == oracle, eng
