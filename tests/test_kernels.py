"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) and jnp-ref
backends against the numpy oracle (repro.core.vecops)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import vecops
from repro.kernels import ops

BACKENDS = ("jax", "pallas")


def _groups(rng, g, max_l, max_r):
    llens = rng.randint(1, max_l + 1, g).astype(np.int32)
    rlens = rng.randint(1, max_r + 1, g).astype(np.int32)
    lstarts = np.cumsum(np.concatenate([[0], llens[:-1]])).astype(np.int32)
    rstarts = np.cumsum(np.concatenate([[0], rlens[:-1]])).astype(np.int32)
    cum = vecops.group_output_offsets(llens, rlens)
    return lstarts, llens, rstarts, rlens, cum


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("g,max_l,max_r,base", [
    (1, 1, 1, 0),
    (7, 3, 5, 2),
    (64, 8, 8, 11),
    (513, 4, 2, 0),      # > one grid block of groups
    (37, 40, 1, 5),      # long left runs
    (37, 1, 40, 5),      # long right runs
])
def test_join_expand_sweep(backend, g, max_l, max_r, base):
    rng = np.random.RandomState(g * 7 + max_l)
    ls, ll, rs, rl, cum = _groups(rng, g, max_l, max_r)
    total = int(cum[-1])
    count = total - base
    want = vecops.expand_cross(ls, ll, rs, rl, cum, base, count)
    got = ops.join_expand(ls, ll, rs, rl, cum.astype(np.int32), base, count,
                          backend=backend)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


def test_join_expand_group_chunking():
    """Pallas wrapper must split probes beyond G_MAX groups."""
    from repro.kernels.join_expand import G_MAX

    rng = np.random.RandomState(0)
    g = G_MAX + 77
    ls, ll, rs, rl, cum = _groups(rng, g, 2, 2)
    total = int(cum[-1])
    want = vecops.expand_cross(ls, ll, rs, rl, cum, 3, total - 3)
    got = ops.join_expand(ls, ll, rs, rl, cum.astype(np.int64), 3, total - 3,
                          backend="pallas")
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


def _ge_case(rng, kl, kr, nl, nr, c, virtual_frac):
    lcols = rng.randint(0, 40, (kl, nl)).astype(np.int32)
    rcols = rng.randint(0, 40, (kr, max(nr, 1))).astype(np.int32)
    li = rng.randint(0, nl, c).astype(np.int32)
    if nr == 0:
        ri = np.full(c, -1, np.int32)
    else:
        ri = rng.randint(0, nr, c).astype(np.int32)
        ri[rng.rand(c) < virtual_frac] = -1
    return lcols, rcols[:, :nr] if nr else rcols[:, :0], li, ri


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kl,kr,nl,nr,c,vf", [
    (1, 1, 1, 1, 1, 0.0),
    (2, 2, 50, 30, 100, 0.0),
    (3, 4, 700, 300, 1000, 0.25),     # > one output block + virtual rows
    (2, 2, 1500, 2000, 600, 0.1),     # > one source chunk (N_TILE=512)
    (4, 1, 64, 64, 5000, 0.0),        # long output
])
def test_gather_emit_sweep(backend, kl, kr, nl, nr, c, vf):
    rng = np.random.RandomState(kl * 31 + nl + c)
    lcols, rcols, li, ri = _ge_case(rng, kl, kr, nl, nr, c, vf)
    lsel = tuple(range(kl))
    rsel = tuple(range(kr))[:1]
    pairs = ((kl - 1, kr - 1),)
    want = vecops.gather_emit(lcols, rcols, li, ri, lsel, rsel, pairs)
    got = ops.gather_emit(lcols, rcols, li, ri, lsel, rsel, pairs, backend=backend)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


@pytest.mark.parametrize("backend", BACKENDS)
def test_gather_emit_mask_only_and_null_rows(backend):
    """semi/anti use the primitive mask-only (no emitted columns); concat
    uses -1 lsel rows for NULL schema alignment."""
    rng = np.random.RandomState(7)
    lcols, rcols, li, ri = _ge_case(rng, 3, 3, 80, 60, 200, 0.2)
    pairs = ((0, 0), (2, 1))
    want = vecops.gather_emit(lcols, rcols, li, ri, (), (), pairs)
    got = ops.gather_emit(lcols, rcols, li, ri, (), (), pairs, backend=backend)
    assert got[0].shape == (0, 200)
    np.testing.assert_array_equal(got[1], want[1])

    wb, _ = vecops.gather_emit(lcols, None, li, None, (0, -1, 2), (), ())
    gb, _ = ops.gather_emit(lcols, None, li, None, (0, -1, 2), (), (),
                            backend=backend)
    assert (wb[1] == -1).all()
    np.testing.assert_array_equal(gb, wb)


def test_gather_emit_out_offset():
    """The pooled fast path writes into the destination at an offset."""
    rng = np.random.RandomState(3)
    lcols, rcols, li, ri = _ge_case(rng, 2, 2, 50, 50, 64, 0.0)
    want, _ = vecops.gather_emit(lcols, rcols, li, ri, (0, 1), (0,), ())
    out = np.full((3, 300), 99, np.int32)
    vecops.gather_emit(lcols, rcols, li, ri, (0, 1), (0,), (),
                       out=out, out_offset=100)
    np.testing.assert_array_equal(out[:, 100:164], want)
    assert (out[:, :100] == 99).all() and (out[:, 164:] == 99).all()


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_gather_emit_property(data):
    """Random shapes/selections: every backend matches the numpy oracle."""
    rng = np.random.RandomState(data.draw(st.integers(0, 10**6)))
    kl = data.draw(st.integers(1, 4))
    kr = data.draw(st.integers(1, 4))
    nl = data.draw(st.integers(1, 600))
    nr = data.draw(st.integers(0, 600))
    c = data.draw(st.integers(1, 700))
    lcols, rcols, li, ri = _ge_case(rng, kl, kr, nl, nr, c, 0.15)
    lsel = tuple(
        data.draw(st.integers(-1, kl - 1)) for _ in range(data.draw(st.integers(0, kl)))
    )
    rsel = tuple(range(data.draw(st.integers(0, kr))))
    pairs = tuple(
        (data.draw(st.integers(0, kl - 1)), data.draw(st.integers(0, kr - 1)))
        for _ in range(data.draw(st.integers(0, 2)))
    )
    want = vecops.gather_emit(lcols, rcols, li, ri, lsel, rsel, pairs)
    for backend in BACKENDS:
        got = ops.gather_emit(lcols, rcols, li, ri, lsel, rsel, pairs,
                              backend=backend)
        np.testing.assert_array_equal(got[0], want[0], err_msg=backend)
        np.testing.assert_array_equal(got[1], want[1], err_msg=backend)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n,m", [(0, 5), (1, 1), (100, 37), (5000, 700)])
@pytest.mark.parametrize("side", ["left", "right"])
def test_sorted_search_sweep(backend, n, m, side):
    rng = np.random.RandomState(n + m)
    keys = np.sort(rng.randint(-50, 50, n)).astype(np.int32)
    qs = rng.randint(-60, 60, m).astype(np.int32)
    want = vecops.sorted_search(keys, qs, side)
    got = ops.sorted_search(keys, qs, side, backend=backend)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("func", ["count", "sum", "min", "max"])
@pytest.mark.parametrize("n,k", [(1, 1), (100, 5), (3000, 40), (2048, 1)])
def test_segment_reduce_sweep(backend, func, n, k):
    rng = np.random.RandomState(n * 3 + k)
    keys = np.sort(rng.randint(0, k, n)).astype(np.int32)
    vals = rng.randn(n)
    want_k, want_v = vecops.segment_reduce(keys, vals, func)
    got_k, got_v = ops.segment_reduce(keys, vals, func, backend=backend)
    np.testing.assert_array_equal(got_k, want_k)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_filter_conjunction_compiles_to_vm(backend):
    """The old conjunction-kernel spec format — (col, op, rhs_col|-1,
    const) conjunctions over int columns — is now a *compile target* of
    the expression VM: the equivalent And-of-Cmp tree must produce the
    plain numpy conjunction mask through every backend (the fused
    expr_eval kernel replaced kernels/filter_eval.py)."""
    from repro.core import algebra as A
    from repro.core.batch import ColumnBatch
    from repro.core.dictionary import Dictionary
    from repro.core.exprs import compile_expr, eval_program_mask

    ops_names = ("=", "!=", "<", "<=", ">", ">=")
    rng = np.random.RandomState(0)
    d = Dictionary()
    for v in range(20):  # term i == int i -> code i: codes ARE the values
        d.encode(int(v))
    for k, n in [(1, 1), (3, 100), (6, 5000)]:
        cols = rng.randint(0, 20, (k, n)).astype(np.int32)
        spec = tuple(
            (rng.randint(k), rng.randint(6),
             rng.randint(k) if rng.rand() < 0.5 else -1, int(rng.randint(0, 20)))
            for _ in range(min(k, 3))
        )
        want = np.ones(n, dtype=bool)
        terms = []
        for col, op, rhs_col, const in spec:
            a = cols[col]
            b = cols[rhs_col] if rhs_col >= 0 else np.int32(const)
            want &= [a == b, a != b, a < b, a <= b, a > b, a >= b][op]
            rhs = A.VarRef(rhs_col) if rhs_col >= 0 else A.Lit(const)
            terms.append(A.Cmp(ops_names[op], A.VarRef(col), rhs))
        expr = terms[0] if len(terms) == 1 else A.And(tuple(terms))
        batch = ColumnBatch.from_columns(
            tuple(range(k)), list(cols), capacity=max(n, 1)
        )
        prog = compile_expr(expr, d, "mask")
        got = eval_program_mask(prog, batch, d, backend=backend)[:n]
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n_parts", [2, 16, 128])
@pytest.mark.parametrize("n", [1, 500, 6000])
def test_radix_partition_sweep(backend, n_parts, n):
    rng = np.random.RandomState(n + n_parts)
    keys = rng.randint(0, 2**30, n).astype(np.int32)
    want_p, want_h = ops.radix_partition(keys, n_parts, backend="numpy")
    got_p, got_h = ops.radix_partition(keys, n_parts, backend=backend)
    np.testing.assert_array_equal(got_p, want_p)
    np.testing.assert_array_equal(got_h, want_h)


def _sorted_pairs(rng, n, hi_range, lo_range):
    hi = rng.randint(0, hi_range, n).astype(np.int32)
    lo = rng.randint(0, lo_range, n).astype(np.int32)
    order = np.lexsort((lo, hi))
    return hi[order], lo[order]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("c,v", [
    (0, 0),
    (1, 0),
    (1, 1),
    (100, 40),       # heavy duplication + visited overlap
    (700, 2500),     # > one cand block and > one visited tile
    (5000, 0),       # pure sort-unique (relation dedup path)
])
def test_frontier_dedup_sweep(backend, c, v):
    rng = np.random.RandomState(c * 13 + v + 1)
    ch, cl = _sorted_pairs(rng, c, 20, 20)
    vh, vl = _sorted_pairs(rng, v, 20, 20)
    if v:  # visited sets hold unique pairs
        keep = vecops.frontier_dedup(vh, vl, vh[:0], vl[:0])
        vh, vl = vh[keep], vl[keep]
    want = vecops.frontier_dedup(ch, cl, vh, vl)
    got = ops.frontier_dedup(ch, cl, vh, vl, backend=backend)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_frontier_dedup_property(data):
    """Masked candidates == set difference of unique pairs vs visited, on
    every backend."""
    rng = np.random.RandomState(data.draw(st.integers(0, 10**6)))
    c = data.draw(st.integers(0, 300))
    v = data.draw(st.integers(0, 300))
    ch, cl = _sorted_pairs(rng, c, 12, 12)
    vh, vl = _sorted_pairs(rng, v, 12, 12)
    if v:
        keep = vecops.frontier_dedup(vh, vl, vh[:0], vl[:0])
        vh, vl = vh[keep], vl[keep]
    want_set = set(zip(ch.tolist(), cl.tolist())) - set(
        zip(vh.tolist(), vl.tolist())
    )
    for backend in ("numpy",) + BACKENDS:
        mask = ops.frontier_dedup(ch, cl, vh, vl, backend=backend)
        got = set(zip(ch[mask].tolist(), cl[mask].tolist()))
        assert got == want_set, backend
        # first-occurrence semantics: masked rows are unique
        assert len(got) == int(mask.sum()), backend


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=1, max_size=300),
       st.sampled_from(["sum", "min", "max"]))
def test_segment_scan_property(keys, op):
    """Pallas segmented scan == per-run numpy reduce at run ends."""
    keys = np.sort(np.asarray(keys, np.int32))
    vals = np.random.RandomState(1).randn(len(keys))
    got_k, got_v = ops.segment_reduce(keys, vals, op, backend="pallas")
    want_k, want_v = vecops.segment_reduce(keys, vals, op)
    np.testing.assert_array_equal(got_k, want_k)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.integers(-1000, 1000), min_size=1, max_size=400),
    st.lists(st.integers(-1100, 1100), min_size=1, max_size=200),
)
def test_sorted_search_property(keys, queries):
    """Positions returned by every backend partition the key array exactly
    like numpy searchsorted, for arbitrary (incl. negative) key spaces."""
    keys = np.sort(np.asarray(keys, np.int32))
    qs = np.asarray(queries, np.int32)
    for side in ("left", "right"):
        want = np.searchsorted(keys, qs, side=side)
        for backend in BACKENDS:
            got = ops.sorted_search(keys, qs, side, backend=backend)
            np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_join_expand_property(data):
    """Random group structures: all backends emit the exact cross-product
    index sequence for every (base, count) window."""
    g = data.draw(st.integers(1, 50))
    rng = np.random.RandomState(g)
    ls, ll, rs, rl, cum = _groups(rng, g, 6, 6)
    total = int(cum[-1])
    base = data.draw(st.integers(0, max(total - 1, 0)))
    count = data.draw(st.integers(1, total - base))
    want = vecops.expand_cross(ls, ll, rs, rl, cum, base, count)
    for backend in BACKENDS:
        got = ops.join_expand(ls, ll, rs, rl, cum.astype(np.int32), base,
                              count, backend=backend)
        np.testing.assert_array_equal(got[0], want[0])
        np.testing.assert_array_equal(got[1], want[1])
