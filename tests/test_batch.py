import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.batch import (
    BATCH_BUCKETS,
    NULL_ID,
    ColumnBatch,
    bucket_for,
    concat_batches,
)


def test_bucket_for():
    assert bucket_for(1) == BATCH_BUCKETS[0]
    assert bucket_for(BATCH_BUCKETS[0]) == BATCH_BUCKETS[0]
    assert bucket_for(BATCH_BUCKETS[0] + 1) == BATCH_BUCKETS[1]
    assert bucket_for(10**9) == BATCH_BUCKETS[-1]


def test_from_columns_and_masking():
    b = ColumnBatch.from_columns((1, 2), [np.arange(5), np.arange(5) * 10], sorted_by=1)
    assert b.n_rows == 5 and b.n_active == 5
    assert b.capacity >= 5
    mask = np.zeros(b.capacity, dtype=bool)
    mask[[0, 2, 4]] = True
    b2 = b.with_mask(mask)
    assert b2.n_active == 3
    np.testing.assert_array_equal(b2.selection_vector(), [0, 2, 4])
    np.testing.assert_array_equal(b2.active_column(2), [0, 20, 40])
    # original untouched (selection vectors don't copy data, paper §3.1)
    assert b.n_active == 5


def test_compact_and_project():
    b = ColumnBatch.from_columns((7, 8), [np.arange(6), np.arange(6) + 100])
    m = np.zeros(b.capacity, dtype=bool)
    m[[1, 3]] = True
    c = b.with_mask(m).compact()
    assert c.n_rows == c.n_active == 2
    p = c.project((8,))
    assert p.var_ids == (8,)
    np.testing.assert_array_equal(p.active_column(8), [101, 103])


def test_rows_iteration_skips_nulls():
    cols = np.asarray([[1, NULL_ID], [5, 7]], dtype=np.int32)
    b = ColumnBatch((1, 2), cols, np.asarray([True, True]), 2)
    rows = list(b.rows())
    assert rows[0] == {1: 1, 2: 5}
    assert rows[1] == {2: 7}  # NULL var omitted


@given(
    st.lists(st.integers(0, 100), min_size=0, max_size=40),
    st.lists(st.integers(0, 100), min_size=0, max_size=40),
)
def test_concat_batches_property(a, b):
    ba = ColumnBatch.from_columns((0,), [np.asarray(a, np.int32)])
    bb = ColumnBatch.from_columns((0,), [np.asarray(b, np.int32)])
    out = concat_batches([ba, bb])
    got = out.active_column(0).tolist() if (a or b) else []
    assert got == a + b


def test_concat_schema_alignment():
    ba = ColumnBatch.from_columns((0, 1), [np.asarray([1]), np.asarray([2])])
    bb = ColumnBatch.from_columns((1, 2), [np.asarray([3]), np.asarray([4])])
    out = concat_batches([ba, bb])
    assert set(out.var_ids) == {0, 1, 2}
    rows = out.to_rows_array()
    assert rows.shape == (2, 3)
