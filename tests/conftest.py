"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
single CPU device; only the dry-run (and the distributed subprocess tests)
force a placeholder device count, in their own processes."""

import numpy as np
import pytest

from repro.core import QuadStore


@pytest.fixture(scope="session")
def social_store():
    from repro.data import generate_social_graph

    store, meta = generate_social_graph(scale=0.04, seed=3)
    return store, meta


@pytest.fixture()
def tiny_store():
    store = QuadStore()
    rng = np.random.RandomState(0)
    people = [f":p{i}" for i in range(10)]
    for i in range(10):
        for j in rng.choice(10, size=3, replace=False):
            if i != int(j):
                store.add(people[i], ":knows", people[int(j)])
        store.add(people[i], ":age", int(rng.randint(20, 60)))
        for t in rng.choice(4, size=2, replace=False):
            store.add(people[i], ":interest", f":tag{int(t)}")
    return store.build()
