"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
single CPU device; only the dry-run (and the distributed subprocess tests)
force a placeholder device count, in their own processes."""

import sys

import numpy as np
import pytest

# The tier-1 container has no hypothesis and installs are forbidden; fall
# back to the deterministic stub so the property tests still run (instead of
# the whole suite dying at collection). Real hypothesis wins when present.
try:  # pragma: no cover - environment probe
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    import _hypothesis_stub

    _hyp, _st = _hypothesis_stub._as_module()
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

from repro.core import QuadStore


@pytest.fixture(scope="session")
def social_store():
    from repro.data import generate_social_graph

    store, meta = generate_social_graph(scale=0.04, seed=3)
    return store, meta


@pytest.fixture()
def tiny_store():
    store = QuadStore()
    rng = np.random.RandomState(0)
    people = [f":p{i}" for i in range(10)]
    for i in range(10):
        for j in rng.choice(10, size=3, replace=False):
            if i != int(j):
                store.add(people[i], ":knows", people[int(j)])
        store.add(people[i], ":age", int(rng.randint(20, 60)))
        for t in rng.choice(4, size=2, replace=False):
            store.add(people[i], ":interest", f":tag{int(t)}")
    return store.build()
