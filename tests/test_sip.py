"""Sideways information passing (DESIGN.md §12): bloom kernel parity
across backends, SipFilter semantics, planner annotations + bushy
ordering, engine equivalence with SIP on/off, and the serve-layer plan
cache fingerprint."""

import hashlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Engine, EngineConfig, QuadStore
from repro.core import planner as PL
from repro.core import vecops
from repro.core.batch import NULL_ID
from repro.core.operators.scan import IndexScan
from repro.core.algebra import K, TriplePattern, V
from repro.core.sip import SipFilter
from repro.kernels import ops

BACKENDS = ("numpy", "jax", "pallas")


# ---------------------------------------------------------------------------
# bloom kernel: three-backend parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_bloom_empty_build(backend):
    words, lo, hi = ops.bloom_build(np.zeros(0, np.int32), backend=backend)
    assert hi < lo  # provably-empty marker
    q = np.arange(10, dtype=np.int32)
    assert not ops.bloom_probe(words, q, backend=backend).any()


@pytest.mark.parametrize("backend", BACKENDS)
def test_bloom_no_false_negatives_and_hits(backend):
    rng = np.random.RandomState(5)
    keys = rng.randint(0, 1 << 18, size=777).astype(np.int32)
    words, lo, hi = ops.bloom_build(keys, backend=backend)
    assert lo == int(keys.min()) and hi == int(keys.max())
    # every inserted key must probe positive (no false negatives)
    assert ops.bloom_probe(words, keys, backend=backend).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_bloom_all_miss(backend):
    keys = np.arange(100, dtype=np.int32)
    words, _, _ = ops.bloom_build(keys, backend=backend)
    misses = np.arange(1 << 20, (1 << 20) + 500, dtype=np.int32)
    hits = ops.bloom_probe(words, misses, backend=backend)
    # disjoint domain: only bloom false positives may fire, and with
    # ~16 bits/key they must be rare
    assert hits.mean() < 0.05


@pytest.mark.parametrize("backend", BACKENDS)
def test_bloom_null_id_key(backend):
    """NULL_ID (-1) is a legal join key (it equals itself in joins) and
    must round-trip through the uint32 hash on every backend."""
    keys = np.asarray([NULL_ID, 3, 7], dtype=np.int32)
    words, lo, hi = ops.bloom_build(keys, backend=backend)
    assert lo == NULL_ID and hi == 7
    got = ops.bloom_probe(
        words, np.asarray([NULL_ID, 3, 7], dtype=np.int32), backend=backend
    )
    assert got.all()


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_bloom_backend_parity_property(data):
    """Random key/query sets (including >16-bit domains): jax and pallas
    are bit-identical to the numpy oracle."""
    rng = np.random.RandomState(data.draw(st.integers(0, 10**6)))
    nk = data.draw(st.integers(0, 800))
    nq = data.draw(st.integers(0, 900))
    dom = data.draw(st.sampled_from([64, 1 << 10, 1 << 17, 1 << 22]))
    keys = rng.randint(-2, dom, size=nk).astype(np.int32)
    queries = rng.randint(-2, dom, size=nq).astype(np.int32)
    w0, lo0, hi0 = ops.bloom_build(keys, backend="numpy")
    m0 = ops.bloom_probe(w0, queries, backend="numpy")
    for backend in ("jax", "pallas"):
        w, lo, hi = ops.bloom_build(keys, backend=backend)
        np.testing.assert_array_equal(w, w0)
        assert (lo, hi) == (lo0, hi0)
        np.testing.assert_array_equal(
            ops.bloom_probe(w, queries, backend=backend), m0
        )
    if nk:
        members = np.isin(queries, keys)
        assert (m0 | ~members).all()  # no false negatives


def test_bloom_pallas_dispatch_counted():
    before_b = ops.dispatch_count("bloom_build")
    before_p = ops.dispatch_count("bloom_probe")
    keys = np.arange(100, dtype=np.int32)
    words, _, _ = ops.bloom_build(keys, backend="pallas")
    ops.bloom_probe(words, keys, backend="pallas")
    assert ops.dispatch_count("bloom_build") == before_b + 1
    assert ops.dispatch_count("bloom_probe") == before_p + 1


def test_bloom_n_words_sizing():
    assert vecops.bloom_n_words(0) >= 1
    for n in (1, 100, 10_000):
        w = vecops.bloom_n_words(n)
        assert w & (w - 1) == 0  # power of two
    assert vecops.bloom_n_words(10**9) <= 1 << 20  # capped


# ---------------------------------------------------------------------------
# SipFilter runtime semantics
# ---------------------------------------------------------------------------


def test_sip_filter_pass_through_without_provider():
    f = SipFilter(var=0)
    assert f.code_range() is None
    assert f.mask(np.arange(5, dtype=np.int32)) is None


def test_sip_filter_range_and_mask():
    f = SipFilter(var=0)
    f.bind(lambda: ("keys", np.asarray([10, 20, 30], np.int32)))
    assert f.code_range() == (10, 30)
    m = f.mask(np.asarray([5, 10, 20, 25, 30, 99], np.int32))
    assert m[1] and m[2] and m[4]  # members always kept
    assert not m[0] and not m[5]  # outside the range: always pruned
    assert f.rows_pruned >= 2


def test_sip_filter_empty_build_prunes_everything():
    f = SipFilter(var=0)
    f.bind(lambda: ("keys", np.zeros(0, np.int32)))
    lo, hi = f.code_range()
    assert hi < lo
    assert not f.mask(np.arange(100, dtype=np.int32)).any()


def test_sip_filter_range_only_provider():
    f = SipFilter(var=0)
    f.bind(lambda: ("range", 5, 9))
    assert f.code_range() == (5, 9)
    m = f.mask(np.asarray([4, 5, 9, 10], np.int32))
    assert list(m) == [False, True, True, False]


# ---------------------------------------------------------------------------
# scan integration: can_skip + mask-mode fallback
# ---------------------------------------------------------------------------


def _scan_store():
    store = QuadStore()
    for i in range(200):
        store.add(f":s{i:03d}", ":p", f":o{i % 7}")
    return store.build()


def test_scan_skip_on_unsorted_var_still_raises():
    store = _scan_store()
    pat = TriplePattern(V(0), K(":p"), V(1))
    scan = IndexScan(store, pat)
    sv = scan.sorted_by()
    other = [v for v in scan.var_ids() if v != sv][0]
    assert scan.can_skip(sv)
    assert not scan.can_skip(other)
    with pytest.raises(ValueError):
        scan.skip(other, 3)


def test_scan_sip_falls_back_to_mask_on_unsorted_var():
    """A SIP filter on a non-sorted var must not try to seek (would
    raise); it degrades to batch masking via can_skip, no exceptions."""
    store = _scan_store()
    pat = TriplePattern(V(0), K(":p"), V(1))
    probe = IndexScan(store, pat)
    ov = [v for v in probe.var_ids() if v != probe.sorted_by()][0]
    # collect the unsorted var's values without any filter, pick two
    all_vals = []
    while True:
        b = probe.next_batch()
        if b is None:
            break
        all_vals.append(b.columns[b.col_index(ov), : b.n_rows][b.mask[: b.n_rows]])
        b.release()
    all_vals = np.concatenate(all_vals)
    keep = np.unique(all_vals)[:2].astype(np.int32)
    expected = int(np.isin(all_vals, keep).sum())
    assert expected > 0
    f = SipFilter(var=ov)
    f.bind(lambda: ("keys", keep))
    scan = IndexScan(store, pat, sip_filters=[f])
    rows = 0
    while True:
        b = scan.next_batch()
        if b is None:
            break
        vals = b.columns[b.col_index(ov), : b.n_rows][b.mask[: b.n_rows]]
        assert np.isin(vals, keep).all()
        rows += b.n_active
        b.release()
    assert rows == expected
    assert f.rows_pruned > 0


def test_scan_sip_range_narrowing_cuts_reads():
    """On the sorted var the filter seeks: rows_scanned must shrink to
    roughly the build-side range instead of the whole relation."""
    store = _scan_store()
    pat = TriplePattern(V(0), K(":p"), V(1))
    base = IndexScan(store, pat, want_sorted_var=0)
    assert base.sorted_by() == 0  # subject-sorted (SPO-family index)
    lo = store.dict.lookup(":s050")
    hi = store.dict.lookup(":s059")
    f = SipFilter(var=0)
    f.bind(lambda: ("range", min(lo, hi), max(lo, hi)))
    scan = IndexScan(store, pat, want_sorted_var=0, sip_filters=[f])
    n = 0
    while True:
        b = scan.next_batch()
        if b is None:
            break
        n += b.n_active
        b.release()
    assert n <= 10
    assert scan.stats.rows_scanned < 200
    assert scan.stats.extra.get("sip_range_seeks", 0) == 1


# ---------------------------------------------------------------------------
# planner: annotations, knob, bushy ordering
# ---------------------------------------------------------------------------


def _chain_store():
    store = QuadStore()
    for i in range(12):
        store.add(f":a{i}", ":r1", f":b{i}")
    for i in range(3000):
        store.add(f":b{i % 400}", ":r2", f":c{i % 350}")
        store.add(f":c{i % 350}", ":r3", f":d{i % 400}")
    for i in range(12):
        store.add(f":d{i}", ":r4", f":e{i}")
        store.add(f":e{i}", ":r5", f":f{i}")
    return store.build()


CHAIN_Q = (
    "SELECT ?a ?f { ?a :r1 ?b . ?b :r2 ?c . ?c :r3 ?d . "
    "?d :r4 ?e . ?e :r5 ?f }"
)

_JOINS = (PL.PMergeJoin, PL.PHashJoin, PL.PLookupJoin, PL.PCross)


def _join_children(n):
    return [n.left, n.right] if hasattr(n, "left") else [n.probe, n.build]


def _count_joins(n):
    out = 1 if isinstance(n, _JOINS) else 0
    for fld in ("child", "left", "right", "probe", "build"):
        c = getattr(n, fld, None)
        if isinstance(c, PL.PhysNode):
            out += _count_joins(c)
    return out


def _is_bushy(n):
    if isinstance(n, _JOINS):
        kids = _join_children(n)
        if all(_count_joins(k) >= 1 for k in kids):
            return True
    return any(
        _is_bushy(getattr(n, fld))
        for fld in ("child", "left", "right", "probe", "build")
        if isinstance(getattr(n, fld, None), PL.PhysNode)
    )


def test_bushy_planner_picks_nonlinear_shape():
    store = _chain_store()
    eng = Engine(store, EngineConfig())
    node, vt = eng.parse(CHAIN_Q)
    phys = eng.plan(node)
    assert _is_bushy(phys), PL.explain(phys, vt)
    # and the shape is not just decorative: results match legacy exactly
    got = sorted(map(tuple, eng.execute_plan(phys, vt).rows.tolist()))
    leg = Engine(store, EngineConfig(engine="legacy")).execute(CHAIN_Q)
    assert got == sorted(map(tuple, leg.rows.tolist()))


def test_explain_prints_sip_annotations():
    store = _chain_store()
    eng = Engine(store, EngineConfig(sip="on"))
    node, vt = eng.parse(CHAIN_Q)
    text = PL.explain(eng.plan(node), vt)
    assert "SipFilter(" in text
    assert "sip-export=" in text


def test_sip_off_plans_have_no_annotations():
    store = _chain_store()
    eng = Engine(store, EngineConfig(sip="off"))
    node, vt = eng.parse(CHAIN_Q)
    text = PL.explain(eng.plan(node), vt)
    assert "SipFilter(" not in text


def test_sip_never_pushed_into_optional_side():
    """left_outer: the nullable side must keep unmatched rows, so no SIP
    annotation may land in it."""
    store = _chain_store()
    eng = Engine(store, EngineConfig(sip="on"))
    q = (
        "SELECT ?a ?b ?c { ?a :r1 ?b . "
        "OPTIONAL { ?b :r2 ?c . ?c :r3 ?d . ?d :r4 ?e } }"
    )
    node, vt = eng.parse(q)
    phys = eng.plan(node)

    def exports_in(n):
        out = set(a.sid for a in getattr(n, "sip_exports", ()))
        for fld in ("child", "left", "right", "probe", "build"):
            c = getattr(n, fld, None)
            if isinstance(c, PL.PhysNode):
                out |= exports_in(c)
        return out

    def leaf_sids(n):
        out = set(a.sid for a in getattr(n, "sip", ()))
        for fld in ("child", "left", "right", "probe", "build"):
            c = getattr(n, fld, None)
            if isinstance(c, PL.PhysNode):
                out |= leaf_sids(c)
        return out

    def check(n):
        # a nullable/subtrahend side may only consume filters exported by
        # joins inside that same side — never from across the boundary
        if isinstance(n, _JOINS) and getattr(n, "mode", "inner") in (
            "left_outer", "anti",
        ):
            nullable = _join_children(n)[1]
            outside = leaf_sids(nullable) - exports_in(nullable)
            assert not outside, PL.explain(phys, vt)
        for fld in ("child", "left", "right", "probe", "build"):
            c = getattr(n, fld, None)
            if isinstance(c, PL.PhysNode):
                check(c)

    check(phys)
    # sanity: OPTIONAL results agree with legacy under sip=on
    got = sorted(map(tuple, eng.execute_plan(phys, vt).rows.tolist()))
    leg = Engine(store, EngineConfig(engine="legacy")).execute(q)
    assert got == sorted(map(tuple, leg.rows.tolist()))


# ---------------------------------------------------------------------------
# engine equivalence: SIP is a pure prefilter
# ---------------------------------------------------------------------------

PARITY_QUERIES = [
    CHAIN_Q,
    "SELECT ?a ?c { ?a :r1 ?b . ?b :r2 ?c }",
    "SELECT ?b ?d { ?b :r2 ?c . ?c :r3 ?d . ?d :r4 ?e }",
    "SELECT ?a ?b ?c { ?a :r1 ?b . OPTIONAL { ?b :r2 ?c } }",
    "SELECT ?b { ?b :r2 ?c . MINUS { ?b :r2 :c1 } }",
    "SELECT ?b ?c { ?b :r2 ?c . FILTER NOT EXISTS { ?c :r3 :d3 } }",
    "SELECT ?c (COUNT(?b) AS ?n) { ?b :r2 ?c . ?c :r3 ?d } GROUP BY ?c",
]


@pytest.mark.parametrize("qi", range(len(PARITY_QUERIES)))
def test_engine_parity_sip_on_off(qi):
    store = _chain_store()
    q = PARITY_QUERIES[qi]
    want = None
    for cfg in (
        EngineConfig(engine="legacy"),
        EngineConfig(sip="off"),
        EngineConfig(sip="on"),
        EngineConfig(),  # auto gate
        EngineConfig(sip="on", join_strategy="hash"),
        EngineConfig(sip="on", join_strategy="merge"),
    ):
        res = Engine(store, cfg).execute(q)
        got = sorted(map(tuple, res.rows.tolist()))
        if want is None:
            want = got
        else:
            assert got == want, f"{cfg} diverges on {q}"


def test_sip_actually_prunes_probe_rows():
    """SIP must do real work: either bloom masks prune probe rows or
    range seeks cut storage reads (usually both, depending on whether the
    probe scan is sorted by the filtered var)."""
    store = _chain_store()

    def totals(cfg):
        res = Engine(store, cfg).execute(CHAIN_Q)
        agg = {"scanned": 0, "pruned": 0, "seeks": 0}

        def walk(op):
            agg["scanned"] += op.stats.rows_scanned
            agg["pruned"] += op.stats.extra.get("sip_pruned_rows", 0)
            agg["seeks"] += op.stats.extra.get("sip_range_seeks", 0)
            for c in op.children():
                walk(c)

        walk(res.root)
        return agg

    on = totals(EngineConfig(sip="on"))
    off = totals(EngineConfig(sip="off"))
    assert on["seeks"] > 0
    assert on["pruned"] > 0 or on["scanned"] < off["scanned"]
    assert off["pruned"] == 0 and off["seeks"] == 0


# ---------------------------------------------------------------------------
# serve layer: plan-cache key includes the config fingerprint
# ---------------------------------------------------------------------------


def test_plan_cache_key_includes_config_fingerprint():
    from repro.serve.query_server import QueryServer

    store = _chain_store()
    q = "SELECT ?a ?d { ?a :r1 ?b . ?b :r2 ?c . ?c :r3 ?d }"
    server = QueryServer(store, EngineConfig(sip="off"))
    server.execute("q", q)
    assert len(server._plan_cache) == 1
    # same text, same config: cache hit
    server.execute("q", q)
    assert len(server._plan_cache) == 1
    # reconfigured engine (different fingerprint): must replan, not serve
    # the sip=off-shaped plan
    server.engine = Engine(store, EngineConfig(sip="on"))
    server.execute("q", q)
    assert len(server._plan_cache) == 2
    (k1, (p1, _, _)), (k2, (p2, _, _)) = sorted(server._plan_cache.items())
    texts = {PL.explain(p1), PL.explain(p2)}
    assert any("SipFilter(" in t for t in texts)
    assert any("SipFilter(" not in t for t in texts)


def test_engine_plan_fingerprint_covers_knobs():
    store = _scan_store()
    fps = {
        Engine(store, cfg).plan_fingerprint()
        for cfg in (
            EngineConfig(),
            EngineConfig(sip="on"),
            EngineConfig(sip="off"),
            EngineConfig(join_strategy="hash"),
            EngineConfig(engine="legacy"),
        )
    }
    assert len(fps) == 5
