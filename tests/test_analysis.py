"""Correctness tooling (DESIGN.md §16): barqlint rule pinning, PlanVerifier
structural checks, the pool sanitizer's ownership tracking, and the
close_tree aggregation contract.

The lint_bad fixtures each seed exactly one violation; pinning them here is
what keeps every rule honest — a rule that stops firing on its fixture is a
rule that silently stopped protecting the tree."""

import time
from pathlib import Path

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.analysis.lint import (
    DEFAULT_EXCLUDES,
    RULES,
    iter_py_files,
    lint_file,
    lint_paths,
)
from repro.analysis.plan_verify import PlanInvariantError, verify_plan
from repro.analysis.sanitize import (
    POISON,
    PoolSanitizer,
    SanitizeError,
    SanitizingBatchPool,
)
from repro.core import Engine, EngineConfig, QuadStore
from repro.core import planner as PL
from repro.core.batch import BatchPool, ColumnBatch
from repro.core.operators.base import CloseError, OpStats, close_tree

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "lint_bad"

# ---------------------------------------------------------------------------
# barqlint: rule pinning on the seeded-violation corpus
# ---------------------------------------------------------------------------

PINNED = {
    "POOL001": FIXTURES / "pool001.py",
    "POOL002": FIXTURES / "pool002.py",
    "POOL003": FIXTURES / "pool003.py",
    "KERN001": FIXTURES / "kern001" / "kernels" / "ops.py",
    "KERN002": FIXTURES / "kern002" / "kernels" / "ops.py",
    "KERN003": FIXTURES / "kern003" / "kernels" / "orphan.py",
    "STAT001": FIXTURES / "stat001.py",
    "STAT002": FIXTURES / "stat002.py",
    "DTYPE001": FIXTURES / "dtype001" / "vecops.py",
    "DTYPE002": FIXTURES / "dtype002" / "vecops.py",
}


def test_every_rule_has_a_fixture():
    assert set(PINNED) == set(RULES)


@pytest.mark.parametrize("rule_id", sorted(PINNED))
def test_rule_fires_on_exactly_its_fixture(rule_id):
    diags = lint_file(PINNED[rule_id])
    assert diags, f"{rule_id} did not fire on its fixture"
    assert {d.rule for d in diags} == {rule_id}, [d.render() for d in diags]


def test_suppression_comment_silences_finding():
    assert lint_file(FIXTURES / "suppressed.py") == []


def test_diagnostic_render_format():
    d = lint_file(PINNED["POOL001"])[0]
    text = d.render()
    assert text.startswith(d.path)
    assert f":{d.line}: POOL001 " in text


def test_default_walk_excludes_fixture_corpus():
    walked = set(iter_py_files([REPO / "tests"]))
    assert not any("lint_bad" in f.parts for f in walked)
    # but explicit files are always linted, exclusion or not
    assert lint_file(PINNED["POOL001"])


def test_merged_tree_lints_clean_and_fast():
    t0 = time.perf_counter()
    diags = lint_paths([REPO / "src"])
    elapsed = time.perf_counter() - t0
    assert diags == [], [d.render() for d in diags]
    assert elapsed < 10.0, f"lint took {elapsed:.1f}s (budget 10s)"


def test_select_narrows_rules():
    diags = lint_file(PINNED["POOL001"], select=["STAT001"])
    assert diags == []


def test_cli_exit_status(capsys):
    from repro.analysis.lint import main

    assert main([str(REPO / "src")]) == 0
    assert main([str(PINNED["POOL001"])]) == 1
    out = capsys.readouterr().out
    assert "POOL001" in out


def test_default_excludes_constant():
    assert "lint_bad" in DEFAULT_EXCLUDES


# ---------------------------------------------------------------------------
# PlanVerifier
# ---------------------------------------------------------------------------


def _plan(store, query, **cfg):
    e = Engine(store, EngineConfig(engine="barq", **cfg))
    node, _ = e.parse(query)
    return e.planner.plan(node)


def _find(plan, cls):
    out = []

    def walk(n):
        if isinstance(n, cls):
            out.append(n)
        for fld in ("child", "left", "right", "probe", "build"):
            c = getattr(n, fld, None)
            if isinstance(c, PL.PhysNode):
                walk(c)

    walk(plan)
    return out


VERIFY_QUERIES = (
    "SELECT ?a ?b ?c { ?a :knows ?b . ?b :knows ?c . FILTER(?a != ?c) }",
    "SELECT ?a ?b ?t { ?a :knows ?b . OPTIONAL { ?b :interest ?t } }",
    "SELECT ?a (COUNT(?b) AS ?n) { ?a :knows ?b } GROUP BY ?a",
    "SELECT DISTINCT ?x { { ?x :knows ?y } UNION { ?x :interest ?t } }",
    "SELECT ?a ?b { ?a :knows ?b } ORDER BY ?b LIMIT 5",
)


@pytest.mark.parametrize("strategy", [None, "hash", "merge"])
def test_planner_output_verifies_clean(tiny_store, strategy):
    for q in VERIFY_QUERIES:
        plan = _plan(tiny_store, q, join_strategy=strategy)
        assert verify_plan(plan, collect=True) == [], q


def test_verify_flags_missing_fingerprint(tiny_store):
    plan = _plan(tiny_store, "SELECT ?a ?b { ?a :knows ?b }")
    plan.fp = ""
    with pytest.raises(PlanInvariantError, match="V-FP"):
        verify_plan(plan)


def test_verify_flags_bad_estimate(tiny_store):
    plan = _plan(tiny_store, "SELECT ?a ?b { ?a :knows ?b }")
    plan.est_rows = -5.0
    diags = verify_plan(plan, collect=True)
    assert any(d.check == "V-FP" and "est_rows" in d.message for d in diags)


# a chain long enough that the planner reliably picks nested merge joins
# (2-hop chains on tiny stores cost out to lookup joins instead)
MERGE_CHAIN = "SELECT ?a ?d { ?a :knows ?b . ?b :knows ?c . ?c :knows ?d }"


def _merge_plan(store):
    plan = _plan(store, MERGE_CHAIN, join_strategy="merge")
    joins = _find(plan, PL.PMergeJoin)
    assert joins, "planner no longer picks merge joins for the chain query"
    return plan, joins


def test_verify_flags_unbound_join_var(tiny_store):
    plan, joins = _merge_plan(tiny_store)
    joins[0].var = 9999  # not produced by either side
    diags = verify_plan(plan, collect=True)
    assert any(d.check == "V-SCHEMA" for d in diags), diags


def test_verify_flags_unsorted_merge_input(tiny_store):
    plan, joins = _merge_plan(tiny_store)
    # break the sortedness claim on whichever shape the planner chose
    mj = joins[0]
    for side in ("left", "right"):
        sub = getattr(mj, side)
        if isinstance(sub, PL.PSort):
            setattr(mj, side, sub.child)
        elif isinstance(sub, PL.PScan):
            sub.sort_var = None
    diags = verify_plan(plan, collect=True)
    assert any(d.check == "V-SORT" for d in diags), diags


def test_verify_flags_bogus_grace_mark(tiny_store):
    plan = _plan(
        tiny_store,
        "SELECT ?a ?c { ?a :knows ?b . ?b :knows ?c }",
        join_strategy="hash",
    )
    (hj,) = _find(plan, PL.PHashJoin)[:1]
    hj.grace = True
    hj.grace_parts = 1  # grace with a degenerate fan-out
    diags = verify_plan(plan, collect=True)
    assert any(d.check == "V-GRACE" for d in diags)


def test_verify_flags_streaming_distinct_over_unsorted(tiny_store):
    plan = _plan(tiny_store, "SELECT DISTINCT ?a { ?a :knows ?b }")
    dist = _find(plan, PL.PDistinct)
    if not dist:
        pytest.skip("planner produced no PDistinct for this shape")
    d0 = dist[0]
    child_vars = PL.phys_vars(d0.child)
    d0.streaming_var = child_vars[-1]
    if PL.phys_sorted_by(d0.child) == d0.streaming_var:
        d0.child = PL.PSort(child=d0.child, var=child_vars[0])
        d0.child.fp, d0.child.est_rows = "synthetic", 1.0
        d0.streaming_var = child_vars[-1] if child_vars[-1] != child_vars[0] else child_vars[0] + 10**6
    diags = verify_plan(plan, collect=True)
    assert any(d.check in ("V-SORT", "V-SCHEMA") for d in diags), diags


def test_verify_flags_adaptive_under_order_consumer(tiny_store):
    plan, joins = _merge_plan(tiny_store)
    inner = [j for j in joins if j is not joins[0]]
    if not inner:
        pytest.skip("planner did not nest merge joins for this shape")
    # the planner separates nested merge joins with a PSort, which resets
    # the order requirement — strip it so the inner join's output order
    # feeds the outer join directly, then claim re-strategy eligibility
    outer = joins[0]
    for side in ("left", "right"):
        sub = getattr(outer, side)
        if isinstance(sub, PL.PSort) and sub.child is inner[0]:
            setattr(outer, side, inner[0])
    inner[0].adaptive_ok = True
    diags = verify_plan(plan, collect=True)
    assert any(d.check == "V-ADAPTIVE" for d in diags), diags


def test_verify_flags_orphan_sip_consumer(tiny_store):
    plan = _plan(tiny_store, "SELECT ?a ?b { ?a :knows ?b }")
    scans = _find(plan, PL.PScan)
    scans[0].sip = (PL.PSipFilter(var=scans[0].pattern.vars()[0],
                                  sid=999, source="hash_build"),)
    with pytest.raises(PlanInvariantError, match="V-SIP"):
        verify_plan(plan)


def test_verify_error_names_offending_node(tiny_store):
    plan = _plan(tiny_store, "SELECT ?a ?b { ?a :knows ?b }")
    plan.fp = ""
    with pytest.raises(PlanInvariantError) as ei:
        verify_plan(plan)
    assert type(plan).__name__ in str(ei.value)


def test_engine_runs_verifier_when_configured(tiny_store):
    e = Engine(tiny_store, EngineConfig(engine="barq", verify_plans=True))
    for q in VERIFY_QUERIES:
        e.execute(q)  # must not raise


def test_env_var_enables_verifier(monkeypatch):
    monkeypatch.setenv("BARQ_VERIFY_PLANS", "1")
    assert EngineConfig().verify_plans
    monkeypatch.setenv("BARQ_VERIFY_PLANS", "")
    assert not EngineConfig().verify_plans


# ---------------------------------------------------------------------------
# pool sanitizer
# ---------------------------------------------------------------------------


def _san_pool():
    # a private tracker per test: installation is global, so fresh state
    # here keeps tests order-independent
    return SanitizingBatchPool(sanitizer=PoolSanitizer())


def test_sanitizer_poisons_released_region():
    pool = _san_pool()
    b = ColumnBatch.from_columns((0,), [np.arange(8, dtype=np.int32)], pool=pool)
    cols = b.columns
    b.release()
    assert (cols[0, :8] == POISON).all()


def test_sanitizer_use_after_release_names_operator_and_site():
    pool = _san_pool()
    pool.sanitizer.push_op("HashJoinBuild")
    b = ColumnBatch.from_columns((0, 1), [np.arange(4)] * 2, pool=pool)
    pool.sanitizer.pop_op()
    b.release()
    with pytest.raises(SanitizeError) as ei:
        b.column(0)
    msg = str(ei.value)
    assert "use-after-released" in msg
    assert "HashJoinBuild" in msg
    assert "test_analysis.py:" in msg  # creation site
    assert pool.sanitizer.use_after_release_errors == 1


def test_sanitizer_use_after_move():
    pool = _san_pool()
    b = ColumnBatch.from_columns((0,), [np.arange(6, dtype=np.int32)], pool=pool)
    m = np.zeros(b.capacity, dtype=bool)
    m[:3] = True
    b2 = b.with_mask(m)  # MOVE: b2 now owns the buffers
    with pytest.raises(SanitizeError, match="use-after-moved"):
        b.n_active
    assert b2.n_active == 3  # the new owner is untouched
    b2.release()


def test_sanitizer_double_release_at_pool_level():
    pool = _san_pool()
    cols, mask = pool.acquire(2, 32)
    pool.release(cols, mask)
    with pytest.raises(SanitizeError, match="double-release"):
        pool.release(cols, mask)
    assert pool.sanitizer.double_release_errors == 1


def test_batch_release_stays_idempotent_under_sanitizer():
    pool = _san_pool()
    b = ColumnBatch.from_columns((0,), [np.arange(4)], pool=pool)
    b.release()
    b.release()  # batch-level release is contractually idempotent: no-op


def test_sanitizer_reports_leak_at_drain():
    pool = _san_pool()
    b = ColumnBatch.from_columns((0,), [np.arange(4)], pool=pool)
    with pytest.raises(SanitizeError, match="leaked"):
        pool.drain()
    assert len(pool.leaks()) == 1
    b.release()
    assert pool.leaks() == []
    pool.drain()  # clean now


def test_sanitizer_ignores_plain_pool_batches():
    _san_pool()  # installs the global hook
    plain = BatchPool()
    b = ColumnBatch.from_columns((0,), [np.arange(4)], pool=plain)
    b.release()
    b.column(0)  # released, but untracked: plain pools keep seed semantics


def test_counters_conservation_law():
    pool = BatchPool(max_per_bucket=1)
    batches = [ColumnBatch.alloc((0,), 32, pool) for _ in range(3)]
    c = pool.counters()
    assert c["live"] == 3 and c["allocs"] == 3
    for b in batches:
        b.release()
    c = pool.counters()
    # one pooled (bucket cap 1), two retired; nothing live
    assert c["live"] == 0
    assert c["allocs"] == c["releases"] + c["pooled"]
    pool.drain()
    c = pool.counters()
    assert c["pooled"] == 0 and c["allocs"] == c["releases"]


# ---------------------------------------------------------------------------
# close_tree: aggregated teardown errors (the raising-close satellite)
# ---------------------------------------------------------------------------


class _FakeOp:
    def __init__(self, name, children=(), raise_on_close=False):
        self.stats = OpStats(name)
        self._children = list(children)
        self.closed = False
        self._raise = raise_on_close

    def children(self):
        return self._children

    def _close(self):
        self.closed = True
        if self._raise:
            raise RuntimeError(f"boom:{self.stats.name}")


def test_close_tree_survives_raising_close():
    a = _FakeOp("a", raise_on_close=True)
    b = _FakeOp("b")
    c = _FakeOp("c", raise_on_close=True)
    root = _FakeOp("root", children=[a, b, c])
    with pytest.raises(CloseError) as ei:
        close_tree(root)
    # every operator was still closed — no spill leaks behind the error
    assert all(op.closed for op in (root, a, b, c))
    err = ei.value
    assert len(err.errors) == 2
    assert {name for name, _ in err.errors} == {"a", "c"}
    assert "boom:a" in str(err) or "boom:c" in str(err)


def test_close_tree_quiet_on_clean_tree():
    leaf = _FakeOp("leaf")
    root = _FakeOp("root", children=[leaf])
    close_tree(root)
    assert root.closed and leaf.closed


# ---------------------------------------------------------------------------
# engine-level: hardened execution equivalence + overhead budget
# ---------------------------------------------------------------------------


def _rows(store, query, **cfg):
    e = Engine(store, EngineConfig(engine="barq", **cfg))
    r = e.execute(query)
    return e, sorted(tuple(int(c) for c in row) for row in r.rows)


def test_sanitize_off_matches_seed_semantics(tiny_store):
    """sanitize=False must run the plain BatchPool and produce the same
    ids as hardened execution — the no-observable-change contract."""
    for q in VERIFY_QUERIES:
        e_plain, plain = _rows(tiny_store, q, sanitize=False)
        e_hard, hard = _rows(tiny_store, q, sanitize=True, verify_plans=True)
        assert type(e_plain.pool) is BatchPool
        assert type(e_hard.pool) is SanitizingBatchPool
        assert plain == hard, q


def test_hardened_execution_leaves_no_leaks(tiny_store):
    e = Engine(tiny_store, EngineConfig(engine="barq", sanitize=True,
                                        verify_plans=True))
    for q in VERIFY_QUERIES:
        e.execute(q)
    assert e.pool.leaks() == []
    c = e.pool.counters()
    assert c["live"] == 0, c
    assert c["allocs"] == c["releases"] + c["pooled"], c


_graphs = st.builds(
    lambda e1, e2, ages: (
        sorted(set(e1)), sorted(set(e2)), {i: a for i, a in enumerate(ages)}
    ),
    st.lists(st.tuples(st.integers(0, 7), st.integers(0, 7)), max_size=60),
    st.lists(st.tuples(st.integers(0, 7), st.integers(0, 3)), max_size=25),
    st.lists(st.integers(10, 70), min_size=8, max_size=8),
)


def _property_store(g):
    knows, interests, ages = g
    store = QuadStore()
    for s, o in knows:
        store.add(f":p{s}", ":knows", f":p{o}")
    for s, t in interests:
        store.add(f":p{s}", ":interest", f":tag{t}")
    for s, a in ages.items():
        store.add(f":p{s}", ":age", int(a))
    return store.build()


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(_graphs)
def test_pool_balance_property(g):
    """Buffer conservation over random graphs: after any query finishes,
    every fresh allocation is either pooled or retired — nothing live,
    nothing leaked — for every engine, sanitized or not."""
    store = _property_store(g)
    for engine in ("barq", "legacy", "mixed"):
        for sanitize in (False, True):
            e = Engine(store, EngineConfig(engine=engine, initial_batch=32,
                                           max_batch=64, sanitize=sanitize))
            for q in VERIFY_QUERIES:
                e.execute(q)
            if e.pool is None:
                assert engine == "legacy"  # row engine: nothing pooled
                continue
            c = e.pool.counters()
            assert c["live"] == 0, (engine, sanitize, c)
            assert c["allocs"] == c["releases"] + c["pooled"], (engine, sanitize, c)
            if sanitize:
                assert e.pool.leaks() == [], (engine, sanitize)


def _hash_join_store(n=200000):
    rng = np.random.RandomState(7)
    store = QuadStore()
    ppl = [f":p{i}" for i in range(n)]
    dst = rng.randint(n, size=n)
    for i in range(n):
        store.add(ppl[i], ":knows", ppl[int(dst[i])])
    for i in range(0, n, 2):
        store.add(ppl[i], ":age", int(20 + (i % 40)))
    return store.build()


def test_sanitizer_overhead_budget():
    """Acceptance bar: < 15% on a 200k-row hash join. Interleaved min-of-N
    — the only statistic robust to CI scheduler noise."""
    store = _hash_join_store()
    q = "SELECT ?a ?b ?t { ?a :knows ?b . ?b :age ?t }"
    engines = {
        s: Engine(store, EngineConfig(engine="barq", join_strategy="hash",
                                      sanitize=s))
        for s in (False, True)
    }
    rows = {}
    for s, e in engines.items():
        rows[s] = e.execute(q).n_rows
        e.execute(q)  # warm the arena
    assert rows[False] == rows[True] > 0
    best = {False: float("inf"), True: float("inf")}
    for _ in range(7):
        for s, e in engines.items():
            t0 = time.perf_counter()
            e.execute(q)
            best[s] = min(best[s], time.perf_counter() - t0)
    overhead = best[True] / best[False] - 1.0
    assert overhead < 0.15, (
        f"sanitizer overhead {overhead:.1%} (plain {best[False]*1e3:.0f}ms, "
        f"sanitized {best[True]*1e3:.0f}ms)"
    )
