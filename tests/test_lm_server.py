"""Continuous-batching LM serving: outputs must equal offline greedy
decoding regardless of admission order / slot reuse."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as TF
from repro.parallel.sharding import MeshAxes
from repro.serve.lm_server import LMServer, Request


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(get_config("qwen3-8b").reduced_model, remat="none")
    params = TF.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _offline_greedy(cfg, params, prompt, max_new):
    axes = MeshAxes()
    cache = TF.init_cache(cfg, 1, 256)
    toks = list(prompt)
    logits = None
    for t, tok in enumerate(toks):
        logits, cache = TF.decode_step(
            params, cfg, axes, cache,
            jnp.asarray([[tok]], jnp.int32), jnp.asarray([[t]], jnp.int32),
        )
    out = []
    pos = len(toks)
    last = int(jnp.argmax(logits[0, 0]))
    for _ in range(max_new):
        out.append(last)
        logits, cache = TF.decode_step(
            params, cfg, axes, cache,
            jnp.asarray([[last]], jnp.int32), jnp.asarray([[pos]], jnp.int32),
        )
        pos += 1
        last = int(jnp.argmax(logits[0, 0]))
    return out


def test_server_matches_offline_greedy(model):
    cfg, params = model
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, rng.randint(3, 7)).astype(np.int32)
               for _ in range(5)]
    server = LMServer(cfg, params, n_slots=3, cache_len=64)
    for i, p in enumerate(prompts):
        server.submit(Request(rid=i, prompt=p, max_new=4))
    results = server.run_until_drained()
    assert set(results) == set(range(5))
    for i, p in enumerate(prompts):
        want = _offline_greedy(cfg, params, p.tolist(), 4)
        # server generates token t+1 from the last prompt token onward;
        # its first generated token corresponds to offline's first output
        assert results[i] == want, f"request {i}"


def test_slot_reuse_isolated(model):
    """A second tenant of a freed slot must not see stale KV entries."""
    cfg, params = model
    rng = np.random.RandomState(1)
    p1 = rng.randint(0, cfg.vocab, 5).astype(np.int32)
    p2 = rng.randint(0, cfg.vocab, 4).astype(np.int32)
    # one slot only: requests are served strictly sequentially via reuse
    server = LMServer(cfg, params, n_slots=1, cache_len=64)
    server.submit(Request(rid=0, prompt=p1, max_new=3))
    server.submit(Request(rid=1, prompt=p2, max_new=3))
    results = server.run_until_drained()
    assert results[1] == _offline_greedy(cfg, params, p2.tolist(), 3)


def test_adaptive_admission_reacts(model):
    cfg, params = model
    server = LMServer(cfg, params, n_slots=4, cache_len=32)
    rng = np.random.RandomState(2)
    for i in range(6):
        server.submit(Request(rid=i, prompt=rng.randint(0, cfg.vocab, 3).astype(np.int32),
                              max_new=2))
    server.run_until_drained()
    # drained queue triggers on_skip shrinkage at least once
    assert server.sizer.size <= server.n_slots
