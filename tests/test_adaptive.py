"""Adaptive execution (paper §3.4 + DESIGN.md §15): AdaptiveBatchSizer
controller properties, the AdaptiveMergeJoin mid-plan merge->hash
re-strategy (operator- and engine-level, with the switch visible in
EXPLAIN ANALYZE), and the planner's order-safety marking that gates it."""

import dataclasses

import numpy as np
import pytest

from repro.core import Engine, EngineConfig, QuadStore
from repro.core import planner as PL
from repro.core.adaptive import AdaptiveBatchSizer
from repro.core.operators.adaptive_join import AdaptiveMergeJoin
from repro.core.operators.merge_join import MergeJoin
from repro.core.operators.sort import MaterializedSource
from repro.core.profiler import profile_tree

# ---------------------------------------------------------------------------
# AdaptiveBatchSizer controller (satellite: direct coverage)
# ---------------------------------------------------------------------------


def test_sizer_shrinks_on_skip_between_nexts():
    s = AdaptiveBatchSizer(initial=256, min_size=16, max_size=1024)
    assert s.size == 256
    s.on_next()
    s.on_skip()
    assert s.on_next() == 128  # halved: skip() arrived since the last next()
    s.on_skip()
    s.on_skip()  # multiple skips in one gap still halve once
    assert s.on_next() == 64


def test_sizer_shrink_saturates_at_min_size():
    s = AdaptiveBatchSizer(initial=32, min_size=16, max_size=1024)
    for _ in range(10):
        s.on_skip()
        s.on_next()
    assert s.size == 16


def test_sizer_grow_streak_doubles_and_saturates_at_max():
    s = AdaptiveBatchSizer(initial=64, min_size=16, max_size=256, grow_streak=2)
    sizes = [s.on_next() for _ in range(12)]
    # every grow_streak-th clean next() doubles: 64,128,128,256,...
    assert sizes[1] == 128
    assert sizes[3] == 256
    assert all(x == 256 for x in sizes[4:])  # saturated at max_size
    assert s.size == 256


def test_sizer_reset_restores_initial_epoch():
    s = AdaptiveBatchSizer(initial=64, min_size=16, max_size=1024, grow_streak=2)
    s.on_next(), s.on_next(), s.on_next()
    assert s.size > 64
    s.on_skip()
    s.on_reset()
    assert s.size == 64
    # the pre-reset skip must not bleed into the new epoch
    assert s.on_next() == 64
    assert s.on_next() == 128


def test_sizer_disabled_is_inert():
    s = AdaptiveBatchSizer(initial=64, enabled=False)
    s.on_skip()
    assert s.on_next() == 64
    assert s.on_next() == 64


def test_sizer_initial_clamped_into_bounds():
    assert AdaptiveBatchSizer(initial=1, min_size=16).size == 16
    assert AdaptiveBatchSizer(initial=1 << 20, max_size=4096).size == 4096


# ---------------------------------------------------------------------------
# AdaptiveMergeJoin operator
# ---------------------------------------------------------------------------


def _src(var_ids, cols, sorted_var=None, batch=4096):
    return MaterializedSource(
        var_ids, np.asarray(cols, np.int32), sorted_var, batch_size=batch,
    )


def _drain_rows(op):
    rows = []
    for b in op.drain():
        c = b.compact()
        rows.extend(tuple(r) for r in c.to_rows_array().tolist())
        c.release()
    return sorted(rows)


def _mk_inputs(seed=0, n=20_000):
    rng = np.random.RandomState(seed)
    l = np.stack([np.sort(rng.randint(0, 2000, n)),
                  rng.randint(0, 100, n)]).astype(np.int32)
    r = np.stack([rng.randint(0, 2000, n // 2),
                  rng.randint(0, 100, n // 2)]).astype(np.int32)
    return l, r


@pytest.mark.parametrize("mode", ("inner", "left_outer", "semi", "anti"))
def test_adaptive_join_parity_both_branches(mode):
    l, r = _mk_inputs()
    rs = r[:, np.argsort(r[0], kind="stable")]
    base = _drain_rows(
        MergeJoin(_src((0, 1), l, 0), _src((0, 2), rs, 0), 0, mode=mode)
    )
    # accurate estimate -> stays merge
    stay = AdaptiveMergeJoin(
        _src((0, 1), l, 0), _src((0, 2), r), 0, mode=mode,
        est_build=float(r.shape[1]),
    )
    assert _drain_rows(stay) == base
    assert stay.stats.extra["adaptive_switches"] == 0
    assert "-> merge" in stay.stats.detail
    # badly under-estimated build -> switches to hash, same multiset
    switch = AdaptiveMergeJoin(
        _src((0, 1), l, 0), _src((0, 2), r), 0, mode=mode, est_build=10.0,
    )
    assert _drain_rows(switch) == base
    assert switch.stats.extra["adaptive_switches"] == 1
    assert switch.stats.extra["adaptive_qerror"] >= 4.0
    assert "-> hash" in switch.stats.detail


def test_adaptive_join_overestimate_keeps_merge():
    """Over-estimates mean the sort is cheaper than planned — switching
    would only add hash-build cost."""
    l, r = _mk_inputs(seed=1, n=4000)
    j = AdaptiveMergeJoin(
        _src((0, 1), l, 0), _src((0, 2), r), 0, est_build=1e9,
    )
    _drain_rows(j)
    assert j.stats.extra["adaptive_switches"] == 0


def test_adaptive_join_switch_visible_in_profile_tree():
    l, r = _mk_inputs(seed=2, n=8000)
    j = AdaptiveMergeJoin(
        _src((0, 1), l, 0), _src((0, 2), r), 0, est_build=5.0,
    )
    _drain_rows(j)
    rep = profile_tree(j)
    assert "adaptive_switch" in rep
    assert "-> hash" in rep
    assert "HashJoin" in rep  # the chosen inner operator is in the tree


# ---------------------------------------------------------------------------
# planner gating + engine integration
# ---------------------------------------------------------------------------


def _store(n=3000, seed=7):
    rng = np.random.RandomState(seed)
    store = QuadStore()
    for i in range(n):
        store.add(f":s{i:05d}", ":knows", f":o{rng.randint(0, 400):05d}")
    for i in range(n * 2 // 3):
        store.add(f":t{i:05d}", ":likes", f":o{rng.randint(0, 400):05d}")
        store.add(f":t{i:05d}", ":age", int(rng.randint(0, 100)))
    return store.build()


Q3 = "SELECT ?a ?x ?g { ?a :knows ?x . ?b :likes ?x . ?b :age ?g }"


def _find(op, name):
    if op.stats.name == name:
        return op
    for c in op.children():
        found = _find(c, name)
        if found is not None:
            return found
    return None


def _force_misestimate(phys, est=10.0):
    """Shrink the planner's build-side estimates in place — the forced
    MISEST of the §15 acceptance test."""
    if isinstance(phys, PL.PMergeJoin) and isinstance(phys.right, PL.PSort):
        phys.right.est_rows = est
    for f in dataclasses.fields(phys):
        v = getattr(phys, f.name)
        if isinstance(v, PL.Phys):
            _force_misestimate(v, est)


def test_planner_marks_order_free_merge_joins_adaptive():
    store = _store()
    eng = Engine(store, EngineConfig(join_strategy="merge", adaptive_join="on"))
    node, _ = eng.parse(Q3)
    ex = PL.explain(eng.plan(node))
    assert "adaptive" in ex
    # the knob off -> no marks, identical shape otherwise
    eng_off = Engine(store, EngineConfig(join_strategy="merge"))
    ex_off = PL.explain(eng_off.plan(node))
    assert "adaptive" not in ex_off
    assert ex.replace(" adaptive", "") == ex_off


def test_planner_suppresses_adaptive_under_order_consumers():
    """A merge join feeding ORDER BY on its sort var — or a streaming
    group-by — must never re-strategize: order is load-bearing there."""
    store = _store()
    eng = Engine(store, EngineConfig(join_strategy="merge", adaptive_join="on"))
    q = ("SELECT ?x (COUNT(*) AS ?c) { ?a :knows ?x . ?b :likes ?x } "
         "GROUP BY ?x")
    node, _ = eng.parse(q)
    phys = eng.plan(node)
    ex = PL.explain(phys)

    def joins_feeding_streaming_groups_unmarked(n, order_needed):
        if isinstance(n, PL.PMergeJoin) and order_needed:
            assert not n.adaptive_ok, ex
        for f in dataclasses.fields(n):
            v = getattr(n, f.name)
            if isinstance(v, PL.Phys):
                need = order_needed or (
                    isinstance(n, PL.PGroup) and n.streaming
                )
                joins_feeding_streaming_groups_unmarked(v, need)

    joins_feeding_streaming_groups_unmarked(phys, False)


def test_engine_forced_misestimate_switches_and_shows_in_explain_analyze():
    store = _store()
    base_eng = Engine(store, EngineConfig(join_strategy="merge"))
    node, vt = base_eng.parse(Q3)
    base = sorted(map(tuple,
                      base_eng.execute_plan(base_eng.plan(node), vt)
                      .rows.tolist()))

    eng = Engine(store, EngineConfig(join_strategy="merge", adaptive_join="on"))
    # accurate estimates: lowers to AdaptiveJoin, stays merge
    phys = eng.plan(node)
    res = eng.execute_plan(phys, vt)
    assert sorted(map(tuple, res.rows.tolist())) == base
    aj = _find(res.root, "AdaptiveJoin")
    assert aj is not None and aj.stats.extra["adaptive_switches"] == 0

    # forced misestimate: switches mid-plan, parity holds, EXPLAIN ANALYZE
    # carries the evidence (ISSUE-9 acceptance)
    phys2 = eng.plan(node)
    _force_misestimate(phys2)
    res2 = eng.execute_plan(phys2, vt)
    assert sorted(map(tuple, res2.rows.tolist())) == base
    aj2 = _find(res2.root, "AdaptiveJoin")
    assert aj2.stats.extra["adaptive_switches"] == 1
    analyze = res2.explain_analyze()
    assert "adaptive_switch" in analyze
    assert "-> hash" in analyze


def test_adaptive_off_plans_unchanged_and_no_adaptive_ops():
    store = _store()
    eng = Engine(store, EngineConfig(join_strategy="merge"))
    node, vt = eng.parse(Q3)
    res = eng.execute_plan(eng.plan(node), vt)
    assert _find(res.root, "AdaptiveJoin") is None
