"""Query-scoped telemetry (DESIGN.md §13): scoped kernel ledger exactness
under interleaving, EXPLAIN ANALYZE est-vs-actual plumbing across all
three engines, Chrome-trace export structure, collect_stats aggregation
rules, pool-delta attribution on a shared Engine, profiler formatting,
and the serving metrics registry."""

import json

import numpy as np
import pytest

from repro.core import Engine, EngineConfig, QuadStore, telemetry
from repro.core.profiler import (
    _fmt_extra,
    collect_stats,
    profile_tree,
    q_error,
)
from repro.kernels import ops as KOPS


def _chain_store(n=60):
    store = QuadStore()
    for i in range(n):
        store.add(f":p{i}", ":knows", f":p{(i * 7 + 1) % n}")
        store.add(f":p{i}", ":age", 20 + i % 30)
    return store.build()


# ---------------------------------------------------------------------------
# scoped kernel ledger
# ---------------------------------------------------------------------------


def test_global_ledger_compat_semantics():
    """DISPATCH_COUNTS / dispatch_count / reset keep their pre-§13 meaning:
    process-global, reset-able, and the Counter object identity is the
    global ledger's counts."""
    assert KOPS.DISPATCH_COUNTS is telemetry.global_ledger().counts
    KOPS.reset_dispatch_counts()
    assert KOPS.dispatch_count("sorted_search") == 0
    keys = np.arange(100, dtype=np.int64)
    KOPS.sorted_search(keys, np.array([5, 50], dtype=np.int64))
    assert KOPS.dispatch_count("sorted_search") == 1
    assert KOPS.dispatch_count() >= 1
    # wall-time attribution landed too, keyed by kernel and backend
    led = telemetry.global_ledger()
    assert led.wall_s["sorted_search"] > 0
    assert led.backend_counts[("sorted_search", "numpy")] == 1
    KOPS.reset_dispatch_counts()
    assert KOPS.dispatch_count() == 0
    assert not led.wall_s


def test_nested_dispatches_tick_both():
    """hash_build internally dispatches radix_partition: both count (the
    pinned pre-§13 behavior), and build wall-time includes partition's."""
    KOPS.reset_dispatch_counts()
    hi = np.zeros(64, dtype=np.uint64)
    lo = np.arange(64, dtype=np.uint64)
    with telemetry.trace_query("nested") as tr:
        KOPS.hash_build(hi, lo, 4)
    for led in (tr.ledger, telemetry.global_ledger()):
        assert led.counts["hash_build"] == 1
        assert led.counts["radix_partition"] == 1
        assert led.wall_s["hash_build"] >= led.wall_s["radix_partition"]


def test_interleaved_queries_attribute_exactly():
    """The acceptance pin: two queries interleaved batch-by-batch through
    one process attribute every kernel dispatch to the right trace, and
    the global ledger sees the sum."""
    store = _chain_store()
    q = "SELECT ?a ?b { ?a :knows ?b . ?b :age ?x . FILTER(?x > 25) }"
    cfg = EngineConfig(engine="barq", initial_batch=32, max_batch=32,
                       adaptive_batching=False, telemetry=False)

    def build_tree():
        from repro.core.executor import Translator

        eng = Engine(store, cfg)
        node, vt = eng.parse(q)
        return Translator(store, eng.cfg).translate(eng.plan(node))

    # solo run: the expected per-query dispatch profile
    KOPS.reset_dispatch_counts()
    solo = build_tree()
    with telemetry.trace_query("solo") as tr_solo:
        while solo.next_batch() is not None:
            pass
    expected = dict(tr_solo.ledger.counts)
    assert expected, "workload dispatched no kernels"

    # interleaved: alternate next_batch between two trees, each call under
    # its own trace context
    KOPS.reset_dispatch_counts()
    op_a, op_b = build_tree(), build_tree()
    tr_a, tr_b = telemetry.QueryTrace("qa"), telemetry.QueryTrace("qb")
    done_a = done_b = False
    while not (done_a and done_b):
        if not done_a:
            with telemetry.trace_query(trace=tr_a):
                done_a = op_a.next_batch() is None
        if not done_b:
            with telemetry.trace_query(trace=tr_b):
                done_b = op_b.next_batch() is None
    assert dict(tr_a.ledger.counts) == expected
    assert dict(tr_b.ledger.counts) == expected
    # global = exact sum of both queries
    for name, c in expected.items():
        assert KOPS.dispatch_count(name) == 2 * c
    # wall attribution is per-query, not shared
    assert tr_a.ledger.total_wall_s() > 0
    assert tr_b.ledger.total_wall_s() > 0


def test_trace_context_does_not_leak():
    KOPS.reset_dispatch_counts()
    with telemetry.trace_query("scoped") as tr:
        assert telemetry.current_trace() is tr
    assert telemetry.current_trace() is None
    KOPS.sorted_search(np.arange(8, dtype=np.int64),
                       np.array([3], dtype=np.int64))
    assert tr.ledger.counts["sorted_search"] == 0  # outside the scope
    assert KOPS.dispatch_count("sorted_search") == 1


# ---------------------------------------------------------------------------
# EXPLAIN ANALYZE
# ---------------------------------------------------------------------------


def test_q_error():
    assert q_error(10, 10) == 1.0
    assert q_error(100, 10) == 10.0
    assert q_error(10, 100) == 10.0
    assert q_error(0, 0) == 1.0  # clamped, no div-by-zero
    assert q_error(0, 8) == 8.0


@pytest.mark.parametrize("engine", ["barq", "mixed", "legacy"])
def test_explain_analyze_est_vs_actual(engine):
    """est_rows flows planner -> Phys -> OpStats -> report in every
    engine; the COUNT(*) aggregate's 10%-of-child estimate vs its actual
    single output row forces a flagged misestimate."""
    store = _chain_store()
    eng = Engine(store, EngineConfig(engine=engine))
    res = eng.execute("SELECT (COUNT(*) AS ?c) { ?a :knows ?b }")
    assert res.n_rows == 1

    # stats got stamped on the tree
    ests = []

    def walk(op):
        if op.stats.est_rows is not None:
            ests.append(op.stats.est_rows)
        for c in op.children():
            walk(c)

    walk(res.root)
    assert ests, "no operator received an estimate"

    report = res.explain_analyze()
    assert "est:" in report
    assert "MISEST" in report  # est ~6 vs actual 1 -> q >= 4
    # plain profile() hides the analyze columns
    assert "MISEST" not in res.profile()
    # Engine.explain_analyze() is the one-shot text API
    assert "est:" in eng.explain_analyze("SELECT ?a { ?a :age ?x }")


def test_collect_stats_q_error_and_rules():
    """Aggregation rules: *_peak -> max, *_ratio -> recomputed (never
    summed), additive default; max_q_error summarizes est quality."""
    from repro.core.operators.base import BatchOperator

    class Stub(BatchOperator):
        def __init__(self, name, children=(), **extra):
            super().__init__(name)
            self._kids = list(children)
            self.stats.extra.update(extra)

        def children(self):
            return self._kids

    leaf1 = Stub("L1", frontier_peak=10, dedup_in=100, dedup_out=50,
                 dedup_ratio=0.5, rounds=3)
    leaf2 = Stub("L2", frontier_peak=40, dedup_in=100, dedup_out=25,
                 dedup_ratio=0.25, rounds=2)
    root = Stub("R", children=[leaf1, leaf2])
    root.stats.results = 7
    root.stats.est_rows = 70.0  # q = 10

    agg = collect_stats(root)
    assert agg["frontier_peak"] == 40  # max, not 50
    assert agg["rounds"] == 5  # additive
    assert agg["dedup_ratio"] == 0.375  # 75/200 recomputed, not 0.75
    assert agg["max_q_error"] == 10.0
    assert agg["operators"] == 3


def test_collect_stats_pool_base_delta():
    from repro.core.batch import BatchPool
    from repro.core.operators.base import BatchOperator

    class Leaf(BatchOperator):
        def __init__(self):
            super().__init__("Leaf")

    pool = BatchPool()
    pool.acquire(2, 32)
    base = dict(pool.stats())
    pool.acquire(2, 64)
    agg = collect_stats(Leaf(), pool=pool, pool_base=base)
    assert agg["pool_allocations"] == 1  # second acquire only


# ---------------------------------------------------------------------------
# pool attribution on a shared Engine
# ---------------------------------------------------------------------------


def test_shared_engine_pool_delta_per_query():
    """Satellite fix: the second query's report must not include the first
    query's allocations. The Engine-owned pool stays warm, so the repeat
    run allocates nothing fresh and the delta proves it."""
    store = _chain_store()
    eng = Engine(store, EngineConfig(engine="barq"))
    q = "SELECT ?a ?b { ?a :knows ?b . ?b :age ?x . FILTER(?x > 25) }"
    r1 = eng.execute(q)
    r2 = eng.execute(q)
    assert r1.pool is r2.pool  # one warm arena
    d1, d2 = r1.pool_delta(), r2.pool_delta()
    assert d1["allocations"] > 0
    assert d2["allocations"] == 0  # warm pool: all reuse on the repeat
    assert d2["reuses"] > 0
    assert d1["releases"] == d2["releases"]  # same query, same traffic
    # deltas partition the cumulative counters exactly
    cum = r2.pool.stats()
    for k in cum:
        assert d1[k] + d2[k] == cum[k], k
    # and the profile header prints the delta, not the cumulative
    line1 = r1.profile().splitlines()[0]
    line2 = r2.profile().splitlines()[0]
    assert line1.startswith("pool:") and line2.startswith("pool:")
    assert "alloc: 0" in line2


def test_fresh_engine_first_query_delta_is_absolute():
    store = _chain_store()
    r = Engine(store, EngineConfig(engine="barq")).execute(
        "SELECT ?a { ?a :age ?x }")
    assert r.pool_delta() == r.pool.stats()


# ---------------------------------------------------------------------------
# trace spans + Chrome-trace export
# ---------------------------------------------------------------------------


def test_query_trace_spans_and_chrome_export(tmp_path):
    store = _chain_store()
    res = Engine(store, EngineConfig(engine="barq")).execute(
        "SELECT ?a ?b { ?a :knows ?b . ?b :age ?x }")
    tr = res.trace
    assert tr is not None
    assert [s[0] for s in tr.spans] == ["parse", "plan", "translate",
                                        "execute"]
    assert all(s[3] >= 0 for s in tr.spans)
    assert tr.ledger.total() > 0

    path = tmp_path / "trace.json"
    tr.save_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    # Perfetto's Chrome-trace contract: traceEvents with ph/ts/dur/pid/tid
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert {m["args"]["name"] for m in metas} == {"query", "kernels",
                                                 "operators"}
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert xs and all(
        {"name", "ts", "dur", "pid", "tid"} <= set(e) for e in xs)
    assert all(e["dur"] >= 0 for e in xs)
    cats = {e.get("cat") for e in xs}
    assert {"query", "kernel", "operator"} <= cats
    # operator lane durations nest inside the execute span
    exec_span = next(e for e in xs if e["name"] == "execute")
    op_events = [e for e in xs if e.get("cat") == "operator"]
    root_ev = max(op_events, key=lambda e: e["dur"])
    assert root_ev["dur"] <= exec_span["dur"] * 1.5 + 1e3

    summ = tr.summary()
    assert summ["spans_ms"]["execute"] > 0
    assert summ["kernels"]["dispatches"]


def test_telemetry_off_skips_tracing():
    store = _chain_store()
    res = Engine(store, EngineConfig(engine="barq", telemetry=False)).execute(
        "SELECT ?a { ?a :age ?x }")
    assert res.trace is None
    assert res.pool_delta()  # pool attribution still works


# ---------------------------------------------------------------------------
# profiler formatting (satellite fix)
# ---------------------------------------------------------------------------


def test_profiler_float_formatting():
    assert _fmt_extra(3.141592653589793) == "3.14"
    assert _fmt_extra(0.5) == "0.50"
    assert _fmt_extra(123456.0) == "123.5K"  # large float -> _fmt_count
    assert _fmt_extra(42) == "42"
    assert _fmt_extra(2_000_000) == "2.0M"

    from repro.core.operators.base import BatchOperator

    class Leaf(BatchOperator):
        def __init__(self):
            super().__init__("Leaf")
            self.stats.extra["seg_ms"] = 3.141592653589793
            self.stats.extra["big_float"] = 123456.0

    out = profile_tree(Leaf())
    assert "seg_ms: 3.14" in out
    assert "big_float: 123.5K" in out
    assert "3.141592653589793" not in out


# ---------------------------------------------------------------------------
# serving metrics
# ---------------------------------------------------------------------------


def test_sliding_window_percentiles_match_numpy():
    from repro.serve.metrics import SlidingWindow

    rng = np.random.RandomState(7)
    vals = rng.exponential(10.0, 200)
    w = SlidingWindow(maxlen=1024)
    for v in vals:
        w.add(float(v), ts=0.0)
    for p in (0, 25, 50, 90, 99, 100):
        assert w.percentile(p) == pytest.approx(np.percentile(vals, p))
    assert w.mean() == pytest.approx(vals.mean())
    # bounded window keeps only the newest maxlen observations
    w2 = SlidingWindow(maxlen=10)
    for i in range(100):
        w2.add(float(i), ts=float(i))
    assert len(w2) == 10 and min(w2.values()) == 90.0


def test_sliding_window_rate_decays():
    from repro.serve.metrics import SlidingWindow

    w = SlidingWindow()
    for i in range(10):
        w.add(1.0, ts=100.0 + i)
    assert w.rate(window_s=60, now=110.0) == pytest.approx(1.0, rel=0.3)
    assert w.rate(window_s=60, now=1000.0) == 0.0  # idle: decays to zero


def test_metrics_registry_aggregation():
    from repro.serve.metrics import MetricsRegistry

    reg = MetricsRegistry()
    led = telemetry.KernelLedger()
    led.record("join_expand", "numpy", 0.002)
    led.record("gather_emit", "pallas", 0.001)
    reg.observe_request(0.010, n_rows=5, ledger=led,
                        pool_delta={"allocations": 3}, ts=0.0)
    reg.observe_request(0.020, n_rows=2, ledger=led,
                        pool_delta={"allocations": 1}, ts=0.0)
    reg.observe_plan_cache(False)
    reg.observe_plan_cache(True)
    reg.observe_plan_cache(True)

    snap = reg.snapshot()
    assert snap["requests"]["count"] == 2
    assert snap["requests"]["rows"] == 7
    assert snap["requests"]["p99_ms"] >= snap["requests"]["p50_ms"] > 0
    assert snap["plan_cache"] == {"hits": 2, "misses": 1, "hit_rate": 0.6667}
    assert snap["kernels"]["dispatches"] == {"join_expand": 2,
                                             "gather_emit": 2}
    assert snap["kernels"]["by_backend"]["gather_emit/pallas"] == 2
    assert snap["pool"]["allocations"] == 4
    json.loads(reg.to_json())  # JSON-able end to end


def test_query_server_per_request_attribution():
    """Each request's RequestResult carries its own kernel/pool deltas;
    the registry aggregates them exactly."""
    from repro.serve.query_server import QueryServer

    store = _chain_store()
    srv = QueryServer(store, EngineConfig(engine="barq"))
    q1 = "SELECT ?a ?b { ?a :knows ?b . ?b :age ?x . FILTER(?x > 25) }"
    q2 = "SELECT ?a { ?a :age ?x }"

    r1 = srv.execute("q1", q1)
    r2 = srv.execute("q2", q2)
    r3 = srv.execute("q1", q1)

    assert not r1.plan_cache_hit and not r2.plan_cache_hit
    assert r3.plan_cache_hit
    assert r1.kernel_dispatches > 0
    assert r2.kernel_dispatches == 0  # single-scan query: no kernels
    # same plan re-run attributes the same kernel profile
    assert dict(r3.trace.ledger.counts) == dict(r1.trace.ledger.counts)
    assert r3.pool_delta["allocations"] == 0  # warm arena on the repeat

    snap = srv.metrics_snapshot()
    assert snap["requests"]["count"] == 3
    assert snap["plan_cache"]["hits"] == 1
    assert snap["plan_cache"]["misses"] == 2
    total = r1.kernel_dispatches + r2.kernel_dispatches + r3.kernel_dispatches
    assert sum(snap["kernels"]["dispatches"].values()) == total
    json.loads(srv.metrics_json())

    # EXPLAIN ANALYZE through the server reuses the cached plan
    misses = srv.metrics.plan_cache_misses
    report = srv.explain_analyze(q1)
    assert "est:" in report
    assert srv.metrics.plan_cache_misses == misses


def test_run_workload_keeps_pinned_keys_and_adds_attribution(tiny_store):
    from repro.serve.query_server import QueryServer

    srv = QueryServer(tiny_store, EngineConfig(engine="barq"))
    reqs = [("a", "SELECT ?a ?b { ?a :knows ?b }"),
            ("b", "SELECT ?p { ?p :interest :tag0 }")] * 3
    stats = srv.run_workload(reqs, warmup=2)
    for key in ("n_requests", "total_rows", "qps", "mean_ms", "p50_ms",
                "p99_ms"):
        assert key in stats  # pre-§13 consumers keep working
    assert stats["n_requests"] == 4
    assert stats["plan_cache_hit_rate"] == 1.0  # warmed both templates
    assert stats["kernel_dispatches"] >= 0
