import numpy as np
import pytest

from repro.core import QuadStore
from repro.core.storage import INDEX_ORDERS


@pytest.fixture()
def store():
    s = QuadStore()
    s.add(":a", ":p", ":x")
    s.add(":a", ":p", ":y")
    s.add(":b", ":p", ":x")
    s.add(":b", ":q", ":z")
    s.add(":a", ":p", ":x")  # duplicate — must dedupe
    return s.build()


def test_dedupe(store):
    assert store.n_quads == 4


def test_indexes_sorted(store):
    for name in INDEX_ORDERS:
        arr = store.index_array(name)
        key = arr[:, 0] * 10**6 + arr[:, 1] * 10**3 + arr[:, 2]
        assert np.all(np.diff(key.astype(np.int64)) >= 0) or len(arr) < 2


def test_range_for_pattern(store):
    d = store.dict
    p = d.lookup(":p")
    a = d.lookup(":a")
    idx = store.choose_index([a, p, None, None], None)
    rng = store.range_for_pattern(idx, [a, p, None, None])
    rows = store.read(rng, 0, 100)
    assert len(rows) == 2  # (:a :p :x), (:a :p :y)


def test_choose_index_prefers_bound_prefix(store):
    d = store.dict
    p = d.lookup(":p")
    # predicate-bound only: posc or psoc both valid
    idx = store.choose_index([None, p, None, None], None)
    assert idx in ("posc", "psoc")
    # object-bound: ospc
    x = d.lookup(":x")
    assert store.choose_index([None, None, x, None], None) == "ospc"


def test_seek(store):
    d = store.dict
    p = d.lookup(":p")
    idx = store.choose_index([None, p, None, None], 0)  # want subject-sorted
    rng = store.range_for_pattern(idx, [None, p, None, None])
    b = d.lookup(":b")
    col_pos = INDEX_ORDERS[idx].index(0)
    off = store.seek(rng, 0, col_pos, b)
    rows = store.read(rng, off, 10)
    assert all(r[col_pos] >= b for r in rows)


def test_pattern_cardinality(store):
    d = store.dict
    p = d.lookup(":p")
    assert store.pattern_cardinality([None, p, None, None]) == 3
    assert store.pattern_cardinality([None, None, None, None]) == 4
