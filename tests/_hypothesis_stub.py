"""Deterministic fallback for the `hypothesis` API surface these tests use.

The container that runs tier-1 verification does not ship hypothesis and
nothing may be pip-installed there, so ``conftest.py`` registers this module
as ``hypothesis`` when the real library is absent. Instead of skipping the
property tests, it draws a fixed number of pseudo-random examples per test
from a seed derived from the test name — deterministic across runs, so
failures are reproducible. With real hypothesis installed (see
requirements-dev.txt) this module is never imported and the genuine
shrinking/replay machinery is used instead.

Only the strategies the test suite uses are implemented: integers, lists,
tuples, sampled_from, builds, data, none, one_of.
"""

from __future__ import annotations

import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.randint(min_value, max_value + 1)))


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.randint(min_size, max_size + 1))
        return [elements._draw(rng) for _ in range(n)]

    return _Strategy(draw)


def tuples(*elems):
    return _Strategy(lambda rng: tuple(e._draw(rng) for e in elems))


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.randint(len(seq)))])


def none():
    return _Strategy(lambda rng: None)


def one_of(*strategies):
    return _Strategy(
        lambda rng: strategies[int(rng.randint(len(strategies)))]._draw(rng)
    )


def builds(fn, *args):
    return _Strategy(lambda rng: fn(*[a._draw(rng) for a in args]))


class _DataObject:
    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy):
        return strategy._draw(self._rng)


def data():
    return _Strategy(lambda rng: _DataObject(rng))


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None,
             suppress_health_check=(), **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        def wrapper(*fixture_args, **fixture_kwargs):
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__name__}".encode())
            for i in range(n):
                rng = np.random.RandomState((seed + i) % (2**31 - 1))
                drawn = [s._draw(rng) for s in strategies]
                try:
                    fn(*fixture_args, *drawn, **fixture_kwargs)
                except Exception as e:  # surface the failing example
                    raise AssertionError(
                        f"falsifying example #{i} for {fn.__name__}: {drawn!r}"
                    ) from e

        # keep identity for pytest, but do NOT set __wrapped__ — pytest would
        # follow it and try to inject fixtures for the drawn argument names
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def _as_module():
    """Materialize this file as importable `hypothesis` + `hypothesis.strategies`."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.HealthCheck = HealthCheck
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "lists", "tuples", "sampled_from", "builds",
                 "data", "none", "one_of"):
        setattr(st, name, globals()[name])
    hyp.strategies = st
    return hyp, st
