"""End-to-end behaviour tests for the paper's system: full query pipeline
(parse → optimize → translate → execute → decode) on the paper's own
workload shapes, engine co-existence, and the fused beyond-paper path."""

import numpy as np
import pytest

from repro.core import Engine, EngineConfig
from repro.core.fused import fused_q6_count
from repro.core.profiler import collect_stats
from repro.data import (
    BSBM_BI_QUERIES,
    BSBM_EXPLORE_TEMPLATES,
    LSQB_QUERIES,
    generate_ecommerce_graph,
    generate_social_graph,
    instantiate_explore,
)


@pytest.fixture(scope="module")
def social():
    return generate_social_graph(scale=0.04, seed=1)


@pytest.fixture(scope="module")
def shop():
    return generate_ecommerce_graph(scale=0.05, seed=2)


def _count(store, q, engine):
    r = Engine(store, EngineConfig(engine=engine)).execute(q)
    return int(store.dict.decode(int(r.rows[0, 0])))


@pytest.mark.parametrize("qname", sorted(LSQB_QUERIES))
def test_lsqb_queries_all_engines_agree(social, qname):
    store, _ = social
    counts = {e: _count(store, LSQB_QUERIES[qname], e)
              for e in ("barq", "legacy", "mixed")}
    assert len(set(counts.values())) == 1, counts
    # CPU-bound suite should actually produce work
    if qname in ("q1", "q6", "q9"):
        assert counts["barq"] > 0


def test_motivating_example_matches_fused(social):
    store, _ = social
    assert _count(store, LSQB_QUERIES["q6"], "barq") == fused_q6_count(store)


@pytest.mark.parametrize("tname", sorted(BSBM_EXPLORE_TEMPLATES))
def test_bsbm_explore_templates(shop, tname):
    store, meta = shop
    rng = np.random.RandomState(7)
    q = instantiate_explore(BSBM_EXPLORE_TEMPLATES[tname], meta, rng)
    rb = Engine(store, EngineConfig(engine="barq")).execute(q)
    rl = Engine(store, EngineConfig(engine="legacy")).execute(q)
    assert sorted(map(tuple, rb.rows.tolist())) == sorted(
        map(tuple, rl.rows.tolist())
    )


@pytest.mark.parametrize("qname", sorted(BSBM_BI_QUERIES))
def test_bsbm_bi_queries(shop, qname):
    store, _ = shop
    rb = Engine(store, EngineConfig(engine="barq")).execute(BSBM_BI_QUERIES[qname])
    rl = Engine(store, EngineConfig(engine="legacy")).execute(BSBM_BI_QUERIES[qname])
    decode = lambda r: sorted(  # noqa: E731
        tuple(None if c == -1 else store.dict.decode(int(c)) for c in row)
        for row in r.rows.tolist()
    )
    assert decode(rb) == decode(rl)


def test_profiler_reports_tree(social):
    store, _ = social
    r = Engine(store, EngineConfig(engine="barq")).execute(LSQB_QUERIES["q6"])
    prof = r.profile()
    # the cost-based planner may pick either join strategy here (§11)
    assert ("MergeJoin" in prof or "HashJoin" in prof)
    assert "Scan" in prof and "wall" in prof
    stats = collect_stats(r.root)
    assert stats["rows_scanned"] > 0 and stats["operators"] >= 5


def test_adaptive_batching_reduces_overfetch(shop):
    """§3.4: adaptive sizing must not scan more than a large fixed batch."""
    store, meta = shop
    rng = np.random.RandomState(0)
    q = instantiate_explore(BSBM_EXPLORE_TEMPLATES["e2"], meta, rng)

    def scanned(cfg):
        r = Engine(store, cfg).execute(q)
        return collect_stats(r.root)["rows_scanned"]

    adaptive = scanned(EngineConfig(engine="barq", adaptive_batching=True))
    fixed = scanned(
        EngineConfig(engine="barq", adaptive_batching=False,
                     initial_batch=4096, max_batch=4096)
    )
    assert adaptive <= fixed
