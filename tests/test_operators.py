"""Operator-level tests: merge join modes, lookup join, adaptive sizing,
streaming aggregation/distinct, adapters, spill."""

import numpy as np
import pytest

from repro.core import Engine, EngineConfig
from repro.core.adaptive import AdaptiveBatchSizer
from repro.core.batch import ColumnBatch
from repro.core.operators.lookup_join import LookupJoin
from repro.core.operators.merge_join import MergeJoin
from repro.core.operators.sort import MaterializedSource


def _src(var_ids, cols, sorted_var, batch=8):
    return MaterializedSource(
        var_ids, np.asarray(cols, np.int32), sorted_var, batch_size=batch
    )


def _drain_rows(op):
    rows = []
    for b in op.drain():
        rows.extend(tuple(r) for r in b.compact().to_rows_array().tolist())
    return sorted(rows)


def _brute_join(l, r, lv, rv, mode):
    shared = [v for v in lv if v in rv]
    out = []
    for lrow in zip(*l):
        matches = [
            rrow for rrow in zip(*r)
            if all(lrow[lv.index(s)] == rrow[rv.index(s)] for s in shared)
        ]
        if mode == "inner":
            for rrow in matches:
                out.append(
                    tuple(lrow) + tuple(
                        rrow[rv.index(v)] for v in rv if v not in lv
                    )
                )
        elif mode == "left_outer":
            if matches:
                for rrow in matches:
                    out.append(tuple(lrow) + tuple(
                        rrow[rv.index(v)] for v in rv if v not in lv))
            else:
                out.append(tuple(lrow) + tuple(
                    -1 for v in rv if v not in lv))
        elif mode == "semi" and matches:
            out.append(tuple(lrow))
        elif mode == "anti" and not matches:
            out.append(tuple(lrow))
    return sorted(out)


@pytest.mark.parametrize("mode", ["inner", "left_outer", "semi", "anti"])
@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("batch", [4, 64])
def test_merge_join_modes_vs_bruteforce(mode, seed, batch):
    rng = np.random.RandomState(seed)
    nl, nr = rng.randint(0, 40), rng.randint(0, 40)
    lk = np.sort(rng.randint(0, 12, nl))
    rk = np.sort(rng.randint(0, 12, nr))
    l = [lk, rng.randint(0, 5, nl)]  # vars (0, 1)
    r = [rk, rng.randint(0, 5, nr)]  # vars (0, 2)
    join = MergeJoin(_src((0, 1), l, 0, batch), _src((0, 2), r, 0, batch), 0,
                     mode=mode)
    got = _drain_rows(join)
    want = _brute_join(l, r, (0, 1), (0, 2), mode)
    assert got == want, f"{mode} seed={seed}"


@pytest.mark.parametrize("mode", ["inner", "semi", "anti"])
@pytest.mark.parametrize("seed", range(4))
def test_merge_join_multikey(mode, seed):
    """Two shared vars: secondary key checked via the vectorized equality
    pass (paper §3.2 Multiple Join Keys)."""
    rng = np.random.RandomState(seed + 100)
    nl, nr = rng.randint(1, 30), rng.randint(1, 30)
    lk, rk = np.sort(rng.randint(0, 6, nl)), np.sort(rng.randint(0, 6, nr))
    l = [lk, rng.randint(0, 3, nl)]  # vars (0, 1) — var 1 shared too
    r = [rk, rng.randint(0, 3, nr), rng.randint(10, 13, nr)]  # vars (0, 1, 2)
    join = MergeJoin(_src((0, 1), l, 0, 8), _src((0, 1, 2), r, 0, 8), 0, mode=mode)
    got = _drain_rows(join)
    want = _brute_join(l, r, (0, 1), (0, 1, 2), mode)
    assert got == want


@pytest.mark.parametrize("mode", ["inner", "semi", "anti"])
def test_lookup_join_vs_bruteforce(mode):
    rng = np.random.RandomState(7)
    nl, nr = 50, 30
    lk = rng.randint(0, 10, nl)  # probe unsorted
    rk = np.sort(rng.randint(0, 10, nr))
    l = [lk, rng.randint(0, 4, nl)]
    r = [rk, rng.randint(0, 4, nr)]
    join = LookupJoin(_src((0, 1), l, None, 16), _src((0, 2), r, 0, 16), 0, mode)
    got = _drain_rows(join)
    want = _brute_join(l, r, (0, 1), (0, 2), mode)
    assert got == want


def test_merge_join_skip_reduces_scans(social_store):
    """The Skip phase must cut storage reads on selective joins
    (paper §3.4 / Listing 3)."""
    store, meta = social_store
    q = """
    SELECT ?p ?tag {
      ?p :studyAt ?u .
      ?p :hasInterest ?tag .
      FILTER (?u = :univ0)
    }
    """
    res_skip = Engine(store, EngineConfig(engine="barq")).execute(q)
    res_noskip = Engine(
        store, EngineConfig(engine="barq", allow_child_skip=False)
    ).execute(q)
    assert sorted(map(tuple, res_skip.rows.tolist())) == sorted(
        map(tuple, res_noskip.rows.tolist())
    )

    def scanned(root):
        total = 0
        def walk(op):
            nonlocal total
            total += op.stats.rows_scanned
            for c in op.children():
                walk(c)
        walk(root)
        return total

    assert scanned(res_skip.root) <= scanned(res_noskip.root)


def test_adaptive_sizer_grows_and_shrinks():
    s = AdaptiveBatchSizer(initial=64, min_size=32, max_size=1024, grow_streak=2)
    # scan-heavy consumer: doubles to cap
    sizes = [s.on_next() for _ in range(12)]
    assert sizes[-1] == 1024
    # skip-heavy: halves back down
    for _ in range(12):
        s.on_skip()
        s.on_next()
    assert s.size == 32
    s.on_reset()
    assert s.size == 64


def test_spill_window(tmp_path):
    """Right ranges spanning many batches spill to disk and stay correct."""
    import repro.core.operators.merge_join as mj

    old = mj._SPILL_THRESHOLD_ROWS
    mj._SPILL_THRESHOLD_ROWS = 64
    try:
        n = 500  # one giant key run on the right
        l = [np.asarray([5, 5]), np.asarray([1, 2])]
        r = [np.full(n, 5), np.arange(n)]
        join = MergeJoin(
            _src((0, 1), l, 0, 4), _src((0, 2), r, 0, 16), 0,
            spill_dir=str(tmp_path),
        )
        got = _drain_rows(join)
        assert len(got) == 2 * n
    finally:
        mj._SPILL_THRESHOLD_ROWS = old


def test_streaming_distinct_uses_skip():
    keys = np.repeat(np.arange(20), 50)  # many duplicates
    src = _src((0,), [keys], 0, batch=64)
    from repro.core.operators.aggregate import StreamingDistinct

    d = StreamingDistinct(src, 0)
    got = _drain_rows(d)
    assert got == [(i,) for i in range(20)]
    assert src.stats.skip_calls > 0  # DISTINCT-via-skip engaged (paper §3.3)


def test_adapters_roundtrip(tiny_store):
    from repro.core.algebra import K, TriplePattern, V
    from repro.core.operators.adapters import BatchToRow, RowToBatch
    from repro.core.operators.scan import IndexScan

    scan = IndexScan(tiny_store, TriplePattern(V(0), K(":knows"), V(1)))
    rows = list(BatchToRow(scan).drain())
    scan2 = IndexScan(tiny_store, TriplePattern(V(0), K(":knows"), V(1)))
    batches = RowToBatch(BatchToRow(scan2), batch_size=16).drain()
    n = sum(b.n_active for b in batches)
    assert n == len(rows) > 0
