import numpy as np
from hypothesis import given, strategies as st

from repro.core import vecops


@given(st.lists(st.integers(0, 20), min_size=0, max_size=200))
def test_run_boundaries(keys):
    keys = np.sort(np.asarray(keys, np.int32))
    vals, starts, lens = vecops.run_boundaries(keys)
    # reconstruct
    rebuilt = np.concatenate([np.full(l, v) for v, l in zip(vals, lens)]) if len(vals) else np.zeros(0)
    np.testing.assert_array_equal(rebuilt, keys)
    assert np.all(np.diff(vals) > 0) or len(vals) < 2
    np.testing.assert_array_equal(starts, np.concatenate([[0], np.cumsum(lens)[:-1]]) if len(lens) else starts)


@given(
    st.lists(st.integers(0, 15), min_size=0, max_size=60),
    st.lists(st.integers(0, 15), min_size=0, max_size=60),
)
def test_probe_and_expand_match_bruteforce(lkeys, rkeys):
    lkeys = np.sort(np.asarray(lkeys, np.int32))
    rkeys = np.sort(np.asarray(rkeys, np.int32))
    lv, ls, ll = vecops.run_boundaries(lkeys)
    rv, rs, rl = vecops.run_boundaries(rkeys)
    gl, gr = vecops.probe_groups(lv, rv)
    cum = vecops.group_output_offsets(ll[gl], rl[gr])
    total = int(cum[-1])
    # brute-force expected pairs
    expected = [
        (i, j)
        for i in range(len(lkeys))
        for j in range(len(rkeys))
        if lkeys[i] == rkeys[j]
    ]
    assert total == len(expected)
    if total:
        li, ri = vecops.expand_cross(ls[gl], ll[gl], rs[gr], rl[gr], cum, 0, total)
        got = sorted(zip(li.tolist(), ri.tolist()))
        assert got == sorted(expected)
        # chunked emission agrees with one-shot (lazy streaming, §3.2)
        pieces = []
        for base in range(0, total, 7):
            cnt = min(7, total - base)
            a, b = vecops.expand_cross(ls[gl], ll[gl], rs[gr], rl[gr], cum, base, cnt)
            pieces.extend(zip(a.tolist(), b.tolist()))
        assert sorted(pieces) == sorted(expected)


@given(st.lists(st.integers(0, 10), min_size=1, max_size=100))
def test_segment_reduce_sum_count(keys):
    keys = np.sort(np.asarray(keys, np.int32))
    vals = np.random.RandomState(0).randn(len(keys))
    rk, cnt = vecops.segment_reduce(keys, None, "count")
    rk2, sm = vecops.segment_reduce(keys, vals, "sum")
    np.testing.assert_array_equal(rk, rk2)
    assert cnt.sum() == len(keys)
    np.testing.assert_allclose(sm.sum(), vals.sum(), rtol=1e-9)


def test_hash_partition_stable_and_complete():
    keys = np.arange(10000, dtype=np.int32)
    pid = vecops.hash_partition(keys, 16)
    assert pid.min() >= 0 and pid.max() < 16
    hist = vecops.partition_histogram(pid, 16)
    assert hist.sum() == len(keys)
    # roughly uniform (fibonacci hashing on dense ids)
    assert hist.max() < 3 * hist.mean()
