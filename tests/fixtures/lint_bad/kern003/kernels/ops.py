"""Dispatcher stub for the KERN003 fixture: wires in nothing."""

REGISTRY = {}
