"""Seeded KERN003: a *_pallas kernel the sibling ops.py never references."""


def orphan_copy_pallas(x_ref, o_ref):
    o_ref[...] = x_ref[...]
