"""Seeded KERN001: public kernel wrapper without @_ledgered."""


def segment_sum(values, seg_ids, backend="numpy"):
    if backend == "numpy":
        return _np_impl(values, seg_ids)
    if backend == "jax":
        return _jax_impl(values, seg_ids)
    if backend == "pallas":
        return _pallas_impl(values, seg_ids)
    raise ValueError(backend)


def _np_impl(values, seg_ids):
    return values


def _jax_impl(values, seg_ids):
    return values


def _pallas_impl(values, seg_ids):
    return values
