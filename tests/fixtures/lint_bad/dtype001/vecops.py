"""Seeded DTYPE001: un-dtyped numpy constructor on a kernel hot path."""

import numpy as np


def scratch(n):
    return np.zeros(n)  # silently float64
