"""Seeded POOL003: non-idempotent close — unguarded unlink, no clear."""


class SpillingOp:
    def __init__(self, spill_path):
        self.spill = spill_path

    def _close(self):
        self.spill.unlink()  # second close_tree visit raises FileNotFoundError
