"""A genuine POOL001 violation silenced by a suppression comment — the
pinning test asserts barqlint reports nothing here."""


def leaky_but_known(pool, var_ids, cap, ColumnBatch):
    b = ColumnBatch.alloc(var_ids, cap, pool)  # barqlint: disable=POOL001
    return cap
