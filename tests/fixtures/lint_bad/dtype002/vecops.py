"""Seeded DTYPE002: builtin float used as a dtype."""

import numpy as np


def widen(xs):
    return xs.astype(float)
