"""Seeded STAT001: camelCase OpStats extra key."""


class FrontierOp:
    def record(self, rounds):
        self.stats.extra["FrontierRounds"] = rounds
