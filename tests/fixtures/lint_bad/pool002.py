"""Seeded POOL002: operator parks acquired batches on self, no _close."""


class BufferingOp:
    def __init__(self, child):
        self.child = child
        self._stash = None

    def _next(self):
        b = self.child.next_batch()
        self._stash = b  # pooled buffers held across calls
        return None
