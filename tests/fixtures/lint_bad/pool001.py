"""Seeded POOL001: acquired batch bound to a name that is never consumed."""


def leaky(pool, var_ids, cap, ColumnBatch):
    b = ColumnBatch.alloc(var_ids, cap, pool)
    return cap  # 'b' never released / returned / stored -> buffers leak
