"""Seeded KERN002: kernel wrapper that silently drops the pallas backend."""


def _ledgered(fn):
    return fn


@_ledgered
def run_filter(values, backend="numpy"):
    if backend == "numpy":
        return _np_impl(values)
    if backend == "jax":
        return _jax_impl(values)
    raise ValueError(backend)  # the pallas leg of the trio is missing


def _np_impl(values):
    return values


def _jax_impl(values):
    return values
