"""Seeded STAT002: a _ms counter assigned a formatted string."""


class TimedOp:
    def record(self, elapsed):
        self.stats.extra["decode_ms"] = f"{elapsed * 1e3:.1f}"
