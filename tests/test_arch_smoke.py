"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (brief (f)).
The FULL configs are exercised only via the dry-run."""

import dataclasses

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import _gnn_graph_shape, build_step
from repro.models.gnn import models as GNN
from repro.pipeline.data import recsys_batch, token_batch
from repro.train.optimizer import OptimizerConfig, init_opt_state

SMOKE_SHAPES = {
    "lm": {"train_4k": {"global_batch": 4, "seq_len": 64}},
    "gnn": {
        "full_graph_sm": {"n_nodes": 128, "n_edges": 512, "d_feat": 24,
                          "n_classes": 6},
    },
    "recsys": {"train_batch": {"batch": 64}},
}


def _smoke_arch(arch_id):
    arch = get_config(arch_id)
    shape_name, override = next(iter(SMOKE_SHAPES[arch.kind].items()))
    shapes = {shape_name: {**arch.shapes[shape_name], **override}}
    return dataclasses.replace(arch, shapes=shapes), shape_name


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_train_step(arch_id):
    arch, shape_name = _smoke_arch(arch_id)
    mesh = make_smoke_mesh()
    opt_cfg = OptimizerConfig(warmup_steps=2, total_steps=10)
    with compat.set_mesh(mesh):
        bundle = build_step(arch, shape_name, mesh, opt_cfg, use_reduced=True)
        key = jax.random.PRNGKey(0)
        reduced = arch.reduced_model
        if arch.kind == "lm":
            from repro.models.transformer import init_params

            params = init_params(reduced, key)
            d = token_batch(0, 0, 4, 64, reduced.vocab)
            args = (d["tokens"], d["labels"])
        elif arch.kind == "gnn":
            gshape = _gnn_graph_shape(arch, shape_name, reduced)
            params = GNN.init(key, reduced, gshape)
            args = (GNN.make_graph_inputs(gshape),)
        else:
            from repro.models.recsys.dcn import init_params as dcn_init

            params = dcn_init(reduced, key)
            d = recsys_batch(0, 0, 64, reduced.n_dense, reduced.n_sparse,
                             [reduced.table_rows(i) for i in range(reduced.n_sparse)])
            args = (d["dense"], d["sparse"], d["labels"])
        opt = init_opt_state(params)
        step = jax.jit(bundle.fn)
        new_params, new_opt, metrics = step(params, opt, *args)

    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch_id}: non-finite loss"
    assert float(metrics["grad_norm"]) > 0, f"{arch_id}: zero grads"
    assert int(new_opt["step"]) == 1
    # param tree structure and shapes preserved by the update
    jax.tree.map(lambda a, b: (_ for _ in ()).throw(AssertionError())
                 if a.shape != b.shape else None, params, new_params)
    # one leaf actually changed
    changed = jax.tree.reduce(
        lambda acc, pair: acc or bool(jnp.any(pair)),
        jax.tree.map(lambda a, b: jnp.any(a != b), params, new_params),
        False,
    )
    assert changed, f"{arch_id}: no parameter moved"


@pytest.mark.parametrize("arch_id", ["qwen3-8b", "qwen3-moe-30b-a3b"])
def test_reduced_decode_matches_prefill(arch_id):
    """Serving path consistency on reduced configs."""
    from repro.models.transformer import (
        decode_step, init_cache, init_params, prefill,
    )
    from repro.parallel.sharding import MeshAxes

    arch = get_config(arch_id)
    cfg = dataclasses.replace(arch.reduced_model, remat="none")
    if cfg.moe is not None:
        # capacity dropping is batch-size-dependent by design (GShard);
        # disable drops so prefill and decode see identical expert outputs
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    axes = MeshAxes()
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    logits_p, _ = prefill(params, cfg, axes, toks)
    cache = init_cache(cfg, 2, 12)
    for t in range(12):
        logits_d, cache = decode_step(
            params, cfg, axes, cache, toks[:, t : t + 1],
            jnp.full((2, 1), t, jnp.int32),
        )
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32), np.asarray(logits_d, np.float32),
        rtol=1e-3, atol=1e-3,
    )


def test_all_arch_ids_have_full_config_fields():
    for arch_id in ARCH_IDS:
        arch = get_config(arch_id)
        assert arch.shapes, arch_id
        assert arch.reduced_model is not None, arch_id
        if arch.kind == "lm":
            m = arch.model
            assert m.param_count() > 1e9, f"{arch_id} param count suspicious"


def test_assigned_configs_match_brief():
    """The exact published numbers from the assignment block."""
    q = get_config("qwen3-8b").model
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff, q.vocab) == (
        36, 4096, 32, 8, 12288, 151936) and q.qk_norm
    d = get_config("deepseek-7b").model
    assert (d.n_layers, d.d_model, d.n_heads, d.n_kv_heads, d.d_ff, d.vocab) == (
        30, 4096, 32, 32, 11008, 102400)
    c = get_config("command-r-plus-104b").model
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        64, 12288, 96, 8, 33792, 256000)
    qm = get_config("qwen3-moe-30b-a3b").model
    assert (qm.n_layers, qm.d_model, qm.n_heads, qm.n_kv_heads, qm.vocab) == (
        48, 2048, 32, 4, 151936)
    assert (qm.moe.n_experts, qm.moe.top_k, qm.moe.d_expert_ff) == (128, 8, 768)
    mo = get_config("moonshot-v1-16b-a3b").model
    assert (mo.n_layers, mo.d_model, mo.n_heads, mo.n_kv_heads, mo.vocab) == (
        48, 2048, 16, 16, 163840)
    assert (mo.moe.n_experts, mo.moe.top_k, mo.moe.d_expert_ff) == (64, 6, 1408)
    gs = get_config("graphsage-reddit").model
    assert (gs.n_layers, gs.d_hidden, gs.aggregator) == (2, 128, "mean")
    dn = get_config("dimenet").model
    assert (dn.n_layers, dn.d_hidden, dn.n_bilinear, dn.n_spherical, dn.n_radial) == (
        6, 128, 8, 7, 6)
    gi = get_config("gin-tu").model
    assert (gi.n_layers, gi.d_hidden, gi.aggregator) == (5, 64, "sum")
    ga = get_config("gat-cora").model
    assert (ga.n_layers, ga.d_hidden, ga.n_heads) == (2, 8, 8)
    dc = get_config("dcn-v2").model
    assert (dc.n_dense, dc.n_sparse, dc.embed_dim, dc.n_cross_layers) == (13, 26, 16, 3)
    assert dc.mlp_dims == (1024, 1024, 512)
