"""Buffer-pool + ring-window invariants (DESIGN.md §2.3).

The zero-copy pipeline must be *invisible*: pooled execution over recycled
buffers and the amortized join windows must produce bit-identical results
to pool-disabled execution, across engines and batch sizes. And it must
actually pay off: steady-state buffer allocations are O(plan depth), not
O(batches emitted)."""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import Engine, EngineConfig, QuadStore
from repro.core.batch import BatchPool, ColumnBatch, concat_batches


# ---------------------------------------------------------------------------
# BatchPool unit behavior
# ---------------------------------------------------------------------------


def test_pool_acquire_release_recycles():
    pool = BatchPool()
    cols, mask = pool.acquire(3, 64)
    assert cols.shape == (3, 64) and mask.shape == (64,)
    pool.release(cols, mask)
    cols2, _ = pool.acquire(3, 64)
    assert cols2 is cols  # same buffer came back
    assert pool.allocations == 1 and pool.reuses == 1


def test_pool_bucket_isolation_and_drain():
    pool = BatchPool(max_per_bucket=2)
    a = pool.acquire(2, 32)
    pool.release(*a)
    b, _ = pool.acquire(2, 64)  # different bucket: fresh
    assert pool.allocations == 2 and b.shape == (2, 64)
    pool.drain()
    c, _ = pool.acquire(2, 32)  # drained: fresh again
    assert pool.allocations == 3


def test_from_columns_pooled_matches_unpooled():
    pool = BatchPool()
    cols = [np.arange(5, dtype=np.int32), np.arange(5, dtype=np.int32) * 7]
    plain = ColumnBatch.from_columns((1, 2), cols, sorted_by=1)
    # dirty a recycled buffer first so the pooled path must repair padding
    dirty = ColumnBatch.from_columns((1, 2), [np.full(30, 9)] * 2, pool=pool)
    dirty.release()
    pooled = ColumnBatch.from_columns((1, 2), cols, sorted_by=1, pool=pool)
    np.testing.assert_array_equal(pooled.columns, plain.columns)
    np.testing.assert_array_equal(pooled.mask, plain.mask)
    assert pool.reuses == 1


def test_release_is_idempotent_and_ownership_moves():
    pool = BatchPool()
    b = ColumnBatch.from_columns((0,), [np.arange(4)], pool=pool)
    m = np.zeros(b.capacity, dtype=bool)
    m[:2] = True
    b2 = b.with_mask(m)  # ownership moved to b2
    assert b.pool is None and b2.pool is pool
    b.release()  # no-op
    assert pool.releases == 0
    b2.release()
    b2.release()
    assert pool.releases == 1


def test_concat_batches_pooled_matches_seed_semantics():
    pool = BatchPool()
    ba = ColumnBatch.from_columns((0, 1), [np.asarray([1, 2]), np.asarray([5, 6])])
    bb = ColumnBatch.from_columns((1, 2), [np.asarray([3]), np.asarray([4])])
    want = concat_batches([ba, bb])
    got = concat_batches([ba, bb], pool=pool)
    np.testing.assert_array_equal(got.to_rows_array(), want.to_rows_array())
    assert got.var_ids == want.var_ids


# ---------------------------------------------------------------------------
# ring/doubling window
# ---------------------------------------------------------------------------


def test_window_ring_append_trim_gather():
    from repro.core.operators.merge_join import _Window

    w = _Window((0, 1), 0, None)
    rng = np.random.RandomState(0)
    keys = np.sort(rng.randint(0, 100, 500)).astype(np.int32)
    payload = rng.randint(0, 1000, 500).astype(np.int32)
    # append in uneven chunks, interleaved with trims, mirroring against a
    # plain concatenate oracle
    oracle = np.zeros((2, 0), dtype=np.int32)
    pos = 0
    for chunk in (7, 120, 1, 300, 72):
        b = ColumnBatch.from_columns((0, 1), [keys[pos:pos + chunk],
                                              payload[pos:pos + chunk]], 0)
        w.append_batch(b)
        oracle = np.concatenate([oracle, np.stack([keys[pos:pos + chunk],
                                                   payload[pos:pos + chunk]])], axis=1)
        pos += chunk
        cut_key = int(oracle[0, oracle.shape[1] // 3])
        cut = int(np.searchsorted(oracle[0], cut_key, side="left"))
        dropped = w.trim_below(cut_key)
        assert dropped == cut - 0 if pos == chunk else True
        oracle = oracle[:, cut:]
        np.testing.assert_array_equal(w.cols, oracle)
        np.testing.assert_array_equal(w.keys, oracle[0])
        idx = np.arange(0, oracle.shape[1], 3, dtype=np.int32)
        np.testing.assert_array_equal(w.gather(idx), oracle[:, idx])


def test_window_masked_batch_append():
    from repro.core.operators.merge_join import _Window

    w = _Window((0,), 0, None)
    b = ColumnBatch.from_columns((0,), [np.arange(10, dtype=np.int32)], 0)
    m = np.zeros(b.capacity, dtype=bool)
    m[[1, 4, 7]] = True
    assert w.append_batch(b.with_mask(m)) == 3
    np.testing.assert_array_equal(w.keys, [1, 4, 7])


# ---------------------------------------------------------------------------
# engine-level equivalence (pooled / ring-buffer vs pool-disabled)
# ---------------------------------------------------------------------------


def _build_store(knows, interests, ages):
    store = QuadStore()
    for s, o in knows:
        store.add(f":p{s}", ":knows", f":p{o}")
    for s, t in interests:
        store.add(f":p{s}", ":interest", f":tag{t}")
    for s, a in ages.items():
        store.add(f":p{s}", ":age", int(a))
    return store.build()


def _rows(store, query, engine, batch=64, **kw):
    e = Engine(store, EngineConfig(engine=engine, initial_batch=32,
                                   max_batch=batch, **kw))
    r = e.execute(query)
    return sorted(
        tuple(int(c) for c in row) for row in r.rows
    )


QUERIES = (
    "SELECT ?a ?b ?c { ?a :knows ?b . ?b :knows ?c . FILTER(?a != ?c) }",
    "SELECT ?a ?b ?t { ?a :knows ?b . OPTIONAL { ?b :interest ?t } }",
    "SELECT ?a ?b { ?a :knows ?b . MINUS { ?b :knows ?a } }",
    "SELECT ?a (COUNT(?b) AS ?n) { ?a :knows ?b } GROUP BY ?a",
    "SELECT DISTINCT ?x { { ?x :knows ?y } UNION { ?x :interest ?t } }",
)

graphs = st.builds(
    lambda e1, e2, ages: (
        sorted(set(e1)), sorted(set(e2)), {i: a for i, a in enumerate(ages)}
    ),
    st.lists(st.tuples(st.integers(0, 7), st.integers(0, 7)), max_size=60),
    st.lists(st.tuples(st.integers(0, 7), st.integers(0, 3)), max_size=25),
    st.lists(st.integers(10, 70), min_size=8, max_size=8),
)


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(graphs)
def test_pooled_execution_bit_identical(g):
    """Recycled buffers + ring windows must not change a single result id,
    for every engine and batch size."""
    store = _build_store(*g)
    for q in QUERIES:
        for engine in ("barq", "mixed"):
            for batch in (32, 4096):
                pooled = _rows(store, q, engine, batch, pool_buffers=True)
                plain = _rows(store, q, engine, batch, pool_buffers=False)
                assert pooled == plain, (q, engine, batch)


@pytest.mark.parametrize("engine", ["barq", "mixed"])
def test_pooled_matches_legacy(tiny_store, engine):
    q = "SELECT ?a ?b ?t { ?a :knows ?b . ?b :interest ?t }"
    assert _rows(tiny_store, q, engine) == _rows(tiny_store, q, "legacy")


def test_steady_state_allocations_o_plan_depth():
    """The acceptance bar: per-query buffer allocations track plan depth,
    not batches emitted."""
    store = QuadStore()
    rng = np.random.RandomState(0)
    for i in range(500):
        for j in rng.choice(500, size=8, replace=False):
            if i != int(j):
                store.add(f":p{i}", ":knows", f":p{int(j)}")
    store = store.build()
    q = "SELECT ?a ?b ?c { ?a :knows ?b . ?b :knows ?c . FILTER(?a != ?c) }"
    e = Engine(store, EngineConfig(engine="barq", initial_batch=32,
                                   max_batch=64, adaptive_batching=False))
    r = e.execute(q)
    s = r.pool.stats()
    batches = r.root.stats.batches
    assert batches > 100, "query too small to exercise the steady state"
    assert s["allocations"] <= 40, s  # bounded by live batches, not emitted
    assert s["reuses"] > batches, s
    # and the counters survive into the profile report
    assert "pool:" in r.profile().splitlines()[0]
