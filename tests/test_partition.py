"""Partitioned-operator substrate (DESIGN.md §15): PartitionedRelation
lifecycle + spill accounting, grace hash join parity (including the
200k x 200k out-of-core acceptance workload vs the legacy row engine and
recursive re-partitioning under seeded skew), partitioned GROUP BY /
DISTINCT parity, budget-aware planning (grace marks in EXPLAIN, byte-
identical plans with the budget off), the plan-fingerprint knob fold,
and the spill-file leak fix on mid-query error paths."""

import glob
import os

import numpy as np
import pytest

from repro.core import Engine, EngineConfig, QuadStore
from repro.core import planner as PL
from repro.core.batch import BatchPool
from repro.core.legacy.operators import RowHashJoin
from repro.core.operators.adapters import BatchToRow
from repro.core.operators.aggregate import (
    PartitionedDistinct,
    PartitionedGroupBy,
    SortDistinct,
    SortGroupBy,
)
from repro.core.operators.hash_join import HashJoin
from repro.core.operators.sort import MaterializedSource
from repro.core.partition import (
    PartitionedRelation,
    next_pow2,
    partition_ids,
    partition_ids_multi,
    split_block,
)

MODES = ("inner", "left_outer", "semi", "anti")


def _src(var_ids, cols, sorted_var=None, batch=4096, pool=None):
    return MaterializedSource(
        var_ids, np.asarray(cols, np.int32), sorted_var, batch_size=batch,
        pool=pool,
    )


def _drain_rows(op):
    rows = []
    for b in op.drain():
        c = b.compact()
        rows.extend(tuple(r) for r in c.to_rows_array().tolist())
        c.release()
    return sorted(rows)


def _spill_leaks(d):
    return glob.glob(os.path.join(str(d), "*.npy"))


# ---------------------------------------------------------------------------
# partition-id kernels
# ---------------------------------------------------------------------------


def test_next_pow2():
    assert [next_pow2(x) for x in (0, 1, 2, 3, 5, 8, 1000)] == [
        1, 1, 2, 4, 8, 8, 1024,
    ]


def test_partition_ids_range_and_determinism():
    rng = np.random.RandomState(0)
    hi = rng.randint(0, 1 << 20, 5000).astype(np.int32)
    lo = rng.randint(0, 1 << 20, 5000).astype(np.int32)
    for n_parts in (2, 8, 64):
        p = partition_ids(hi, lo, n_parts)
        assert p.dtype == np.int32
        assert p.min() >= 0 and p.max() < n_parts
        assert np.array_equal(p, partition_ids(hi, lo, n_parts))


def test_partition_ids_levels_decorrelated():
    """Recursive re-partitioning only helps if level k+1 splits what level
    k hashed together — same keys, different level, different spread."""
    rng = np.random.RandomState(1)
    hi = rng.randint(0, 1 << 20, 4000).astype(np.int32)
    lo = rng.randint(0, 1 << 20, 4000).astype(np.int32)
    p0 = partition_ids(hi, lo, 16, level=0)
    # take one level-0 bucket and re-split it at level 1
    m = p0 == int(p0[0])
    p1 = partition_ids(hi[m], lo[m], 16, level=1)
    assert len(np.unique(p1)) > 1


def test_partition_ids_multi_equal_tuples_colocate():
    rng = np.random.RandomState(2)
    cols = [rng.randint(0, 50, 3000).astype(np.int32) for _ in range(3)]
    p = partition_ids_multi(cols, 32)
    assert p.min() >= 0 and p.max() < 32
    # identical key tuples must land in the same partition
    keys = np.stack(cols).T
    for pid in np.unique(p[:100]):
        rows = {tuple(r) for r in keys[p == pid].tolist()}
        other = {tuple(r) for r in keys[p != pid].tolist()}
        assert not rows & other


def test_split_block_partition_of_input():
    rng = np.random.RandomState(3)
    cols = rng.randint(0, 100, (3, 2000)).astype(np.int32)
    pids = partition_ids_multi([cols[0]], 8)
    parts = split_block(cols, pids, 8)
    assert sum(b.shape[1] for _, b in parts) == 2000
    rebuilt = sorted(
        tuple(r) for _, b in parts for r in b.T.tolist()
    )
    assert rebuilt == sorted(tuple(r) for r in cols.T.tolist())


# ---------------------------------------------------------------------------
# PartitionedRelation lifecycle
# ---------------------------------------------------------------------------


def test_partitioned_relation_round_trip(tmp_path):
    rng = np.random.RandomState(4)
    rel = PartitionedRelation(2, 8, spill_dir=str(tmp_path))
    expect = []
    for _ in range(5):
        cols = rng.randint(0, 1000, (2, 700)).astype(np.int32)
        pids = partition_ids_multi([cols[0]], 8)
        rel.append(cols, pids)
        expect.extend(tuple(r) for r in cols.T.tolist())
    got = []
    for p in range(8):
        block = rel.load(p)
        assert np.array_equal(
            partition_ids_multi([block[0]], 8),
            np.full(block.shape[1], p, np.int32),
        )
        got.extend(tuple(r) for r in block.T.tolist())
    assert sorted(got) == sorted(expect)
    rel.close()
    assert not _spill_leaks(tmp_path)


def test_partitioned_relation_spills_under_budget(tmp_path):
    rng = np.random.RandomState(5)
    rel = PartitionedRelation(2, 16, spill_dir=str(tmp_path), budget_bytes=8_000)
    for _ in range(10):
        cols = rng.randint(0, 1 << 16, (2, 2000)).astype(np.int32)
        rel.append(cols, partition_ids_multi([cols[0]], 16))
    assert rel.spill_files > 0 and rel.spill_bytes > 0
    assert _spill_leaks(tmp_path)  # files actually on disk
    total = sum(rel.load(p).shape[1] for p in range(16))
    assert total == 20_000
    # take() frees a partition's disk footprint eagerly
    before = len(_spill_leaks(tmp_path))
    spilled = [p for p in range(16) if rel._files[p]]
    rel.take(spilled[0])
    assert len(_spill_leaks(tmp_path)) < before
    rel.close()
    rel.close()  # idempotent
    assert not _spill_leaks(tmp_path)


def test_partitioned_relation_no_budget_stays_resident(tmp_path):
    rel = PartitionedRelation(1, 4, spill_dir=str(tmp_path))
    cols = np.arange(4000, dtype=np.int32).reshape(1, -1)
    rel.append(cols, partition_ids_multi([cols[0]], 4))
    assert rel.spill_files == 0
    assert not _spill_leaks(tmp_path)
    rel.close()


# ---------------------------------------------------------------------------
# grace hash join
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_grace_join_mode_parity(tmp_path, mode):
    rng = np.random.RandomState(6)
    n = 20_000
    l = np.stack([rng.randint(0, 500, n), rng.randint(0, 1000, n)]).astype(np.int32)
    r = np.stack([rng.randint(0, 700, n // 2), rng.randint(0, 1000, n // 2)]).astype(np.int32)
    base = _drain_rows(HashJoin(_src((0, 1), l), _src((0, 2), r), (0,), mode))
    grace = HashJoin(
        _src((0, 1), l), _src((0, 2), r), (0,), mode,
        memory_budget=10_000, spill_dir=str(tmp_path), grace=True,
    )
    assert _drain_rows(grace) == base
    assert grace.stats.extra["spill_files"] > 0
    grace.close()
    assert not _spill_leaks(tmp_path)


def test_grace_join_200k_parity_vs_legacy_row_engine(tmp_path):
    """ISSUE-9 acceptance: 200k x 200k unsorted join, budget < 25% of the
    build side's bytes, exact multiset parity vs the legacy row engine,
    spill counters > 0."""
    rng = np.random.RandomState(7)
    n = 200_000
    l = np.stack([rng.randint(0, n, n), rng.randint(0, 1000, n)]).astype(np.int32)
    r = np.stack([rng.randint(0, n, n), rng.randint(0, 1000, n)]).astype(np.int32)
    build_bytes = r.nbytes  # 200k rows x 2 vars x 4B = 1.6MB
    budget = build_bytes // 5  # < 25% of the build side
    grace = HashJoin(
        _src((0, 1), l), _src((0, 2), r), (0,), "inner",
        memory_budget=budget, spill_dir=str(tmp_path), grace=True,
    )
    got = _drain_rows(grace)
    assert grace.stats.extra["spill_files"] > 0
    assert grace.stats.extra["spill_bytes"] > 0
    oracle = RowHashJoin(
        BatchToRow(_src((0, 1), l)), BatchToRow(_src((0, 2), r)), (0,),
    )
    expect = []
    while True:
        row = oracle.next_row()
        if row is None:
            break
        expect.append((row[0], row[1], row[2]))
    assert got == sorted(expect)
    grace.close()
    assert not _spill_leaks(tmp_path)


def test_grace_join_skew_triggers_recursive_repartition(tmp_path):
    """80% of the build mass on one key: the top-level partition holding it
    blows the budget and must re-partition at level 1."""
    rng = np.random.RandomState(8)
    n = 40_000
    lk = np.where(rng.rand(n) < 0.8, 7, rng.randint(0, 2000, n)).astype(np.int32)
    rk = np.where(rng.rand(n) < 0.8, 7, rng.randint(0, 2000, n)).astype(np.int32)
    l = np.stack([lk, rng.randint(0, 10, n)]).astype(np.int32)
    r = np.stack([rk, rng.randint(0, 10, n)]).astype(np.int32)
    base = _drain_rows(
        HashJoin(_src((0, 1), l), _src((0, 2), r), (0,), "semi")
    )
    grace = HashJoin(
        _src((0, 1), l), _src((0, 2), r), (0,), "semi",
        memory_budget=r.nbytes // 10, spill_dir=str(tmp_path), grace=True,
    )
    assert _drain_rows(grace) == base
    assert grace.stats.extra["repartitions"] > 0
    grace.close()
    assert not _spill_leaks(tmp_path)


def test_runtime_switch_to_grace_on_oversized_build(tmp_path):
    """No planner directive (grace=None) — the operator discovers at build
    time that the materialized block exceeds the budget and re-partitions
    it instead of building resident."""
    rng = np.random.RandomState(9)
    n = 30_000
    l = np.stack([rng.randint(0, n, n), rng.randint(0, 5, n)]).astype(np.int32)
    r = np.stack([rng.randint(0, n, n), rng.randint(0, 5, n)]).astype(np.int32)
    base = _drain_rows(HashJoin(_src((0, 1), l), _src((0, 2), r), (0,)))
    j = HashJoin(
        _src((0, 1), l), _src((0, 2), r), (0,),
        memory_budget=r.nbytes // 4, spill_dir=str(tmp_path),
    )
    assert _drain_rows(j) == base
    assert j.stats.extra["adaptive_switches"] == 1
    j.close()
    assert not _spill_leaks(tmp_path)


def test_grace_join_multi_key_parity(tmp_path):
    rng = np.random.RandomState(10)
    n = 15_000
    l = np.stack([rng.randint(0, 60, n), rng.randint(0, 60, n),
                  rng.randint(0, 100, n)]).astype(np.int32)
    r = np.stack([rng.randint(0, 60, n), rng.randint(0, 60, n),
                  rng.randint(0, 100, n)]).astype(np.int32)
    base = _drain_rows(
        HashJoin(_src((0, 1, 2), l), _src((0, 1, 3), r), (0, 1))
    )
    grace = HashJoin(
        _src((0, 1, 2), l), _src((0, 1, 3), r), (0, 1),
        memory_budget=8_000, spill_dir=str(tmp_path), grace=True,
    )
    assert _drain_rows(grace) == base
    grace.close()
    assert not _spill_leaks(tmp_path)


# ---------------------------------------------------------------------------
# partitioned GROUP BY / DISTINCT
# ---------------------------------------------------------------------------


def _agg_store_cols(rng, n):
    return np.stack([
        rng.randint(0, 40, n), rng.randint(0, 25, n), rng.randint(0, 500, n),
    ]).astype(np.int32)


def test_partitioned_group_by_parity(tmp_path):
    from repro.core.algebra import AggSpec
    from repro.core.dictionary import Dictionary

    rng = np.random.RandomState(11)
    cols = _agg_store_cols(rng, 30_000)
    aggs = (
        AggSpec("count", None, False, 10),
        AggSpec("sum", 2, False, 11),
        AggSpec("sum", 2, True, 12),
        AggSpec("min", 2, False, 13),
    )
    d = Dictionary()
    for v in range(500):
        d.encode(int(v))  # agg-var codes resolve to numerics
    base = _drain_rows(
        SortGroupBy(_src((0, 1, 2), cols), (0, 1), aggs, d)
    )
    part = PartitionedGroupBy(
        _src((0, 1, 2), cols), (0, 1), aggs, d,
        memory_budget=10_000, spill_dir=str(tmp_path), n_parts=8,
    )
    assert _drain_rows(part) == base
    assert part.stats.extra["spill_files"] > 0
    part.close()
    assert not _spill_leaks(tmp_path)


def test_partitioned_distinct_parity(tmp_path):
    rng = np.random.RandomState(12)
    cols = _agg_store_cols(rng, 30_000)[:2]
    base = _drain_rows(SortDistinct(_src((0, 1), cols)))
    part = PartitionedDistinct(
        _src((0, 1), cols),
        memory_budget=8_000, spill_dir=str(tmp_path), n_parts=8,
    )
    assert _drain_rows(part) == base
    assert part.stats.extra["spill_files"] > 0
    part.close()
    assert not _spill_leaks(tmp_path)


# ---------------------------------------------------------------------------
# planner + engine integration
# ---------------------------------------------------------------------------


def _join_store(n=4000, seed=13):
    rng = np.random.RandomState(seed)
    store = QuadStore()
    for i in range(n):
        store.add(f":s{i:05d}", ":knows", f":o{rng.randint(0, 50):05d}")
        store.add(f":s{i:05d}", ":name", f":n{rng.randint(0, 30):05d}")
        store.add(f":t{i:05d}", ":likes", f":o{rng.randint(0, 50):05d}")
        store.add(f":t{i:05d}", ":age", int(rng.randint(0, 90)))
    return store.build()


QUERIES = (
    "SELECT ?s ?o ?n { ?s :knows ?o . ?s :name ?n }",
    "SELECT ?o (COUNT(*) AS ?c) { ?s :knows ?o . ?s :name ?n } GROUP BY ?o",
    "SELECT DISTINCT ?o ?n { ?s :knows ?o . ?s :name ?n }",
    "SELECT ?s ?o { ?s :knows ?o } ORDER BY ?o LIMIT 17",
)


def _run(store, cfg, q):
    eng = Engine(store, cfg)
    node, vt = eng.parse(q)
    phys = eng.plan(node)
    res = eng.execute_plan(phys, vt)
    return phys, sorted(map(tuple, res.rows.tolist()))


def test_memory_budget_none_plans_byte_identical():
    """The whole §15 layer must be invisible until the knob is set."""
    store = _join_store()
    for q in QUERIES:
        eng_off = Engine(store, EngineConfig())
        eng_none = Engine(store, EngineConfig(spill_dir="/tmp", adaptive_join="off"))
        node, _ = eng_off.parse(q)
        assert PL.explain(eng_off.plan(node)) == PL.explain(eng_none.plan(node))


def test_engine_grace_join_explain_and_parity(tmp_path):
    store = _join_store()
    q = QUERIES[0]
    _, base = _run(store, EngineConfig(), q)
    phys, rows = _run(
        store,
        EngineConfig(spill_dir=str(tmp_path), memory_budget=20_000,
                     join_strategy="hash"),
        q,
    )
    ex = PL.explain(phys)
    assert "grace parts=" in ex and "spill≈" in ex
    assert rows == base
    assert not _spill_leaks(tmp_path)


def test_engine_partitioned_group_and_distinct_parity(tmp_path):
    store = _join_store()
    for q, marker in ((QUERIES[1], "Group[partitioned"),
                      (QUERIES[2], "Distinct[partitioned")):
        _, base = _run(store, EngineConfig(), q)
        phys, rows = _run(
            store, EngineConfig(spill_dir=str(tmp_path), memory_budget=20_000), q,
        )
        assert marker in PL.explain(phys)
        assert rows == base
        assert not _spill_leaks(tmp_path)


def test_budget_costing_penalizes_oversized_hash_builds():
    """Cost-based strategy choice must see the spill penalty: with a tiny
    budget the planner still plans, and grace marks land only on joins
    whose build estimate exceeds the budget."""
    store = _join_store()
    eng = Engine(store, EngineConfig(memory_budget=1 << 30))  # huge budget
    node, _ = eng.parse(QUERIES[0])
    assert "grace" not in PL.explain(eng.plan(node))


def test_plan_fingerprint_covers_budget_and_adaptive_knobs():
    """Satellite 2: a plan cache keyed without these knobs would serve a
    resident-shaped plan after the budget changed."""
    store = _join_store(n=50)
    fps = [
        Engine(store, cfg).plan_fingerprint()
        for cfg in (
            EngineConfig(),
            EngineConfig(memory_budget=1_000_000),
            EngineConfig(memory_budget=2_000_000),
            EngineConfig(adaptive_join="on"),
            EngineConfig(memory_budget=1_000_000, adaptive_join="on"),
        )
    ]
    assert len(set(fps)) == len(fps)


def test_query_server_plan_cache_no_collision_across_budget(tmp_path):
    """Same query text, different memory budget -> different cache entries
    (the stale-plan collision the fingerprint fold prevents)."""
    from repro.serve.query_server import QueryServer

    store = _join_store()
    q = QUERIES[0]
    srv1 = QueryServer(store, EngineConfig())
    srv1.execute("q", q)
    srv2 = QueryServer(
        store, EngineConfig(spill_dir=str(tmp_path), memory_budget=20_000,
                            join_strategy="hash"),
    )
    srv2.execute("q", q)
    (p1, _, _), = srv1._plan_cache.values()
    (p2, _, _), = srv2._plan_cache.values()
    assert set(srv1._plan_cache) != set(srv2._plan_cache)
    assert PL.explain(p1) != PL.explain(p2)


def test_serve_metrics_capture_spill_counters(tmp_path):
    from repro.serve.metrics import validate_openmetrics
    from repro.serve.query_server import QueryServer

    store = _join_store()
    srv = QueryServer(
        store, EngineConfig(spill_dir=str(tmp_path), memory_budget=20_000,
                            join_strategy="hash"),
    )
    srv.execute("q", QUERIES[0])
    snap = srv.metrics.snapshot()
    assert snap["execution"]["spill_files"] > 0
    assert snap["execution"]["spill_bytes"] > 0
    om = srv.metrics.to_openmetrics()
    validate_openmetrics(om)
    assert "barq_spill_bytes_total" in om
    assert "barq_adaptive_switches_total" in om
    assert not _spill_leaks(tmp_path)


# ---------------------------------------------------------------------------
# spill-file lifecycle on error paths (satellite 1)
# ---------------------------------------------------------------------------


class _Bomb(RuntimeError):
    pass


def _failing_project(monkeypatch, after_batches):
    """Make ProjectOp blow up after N batches — a downstream consumer dying
    mid-query, while upstream operators have live spill state."""
    from repro.core.operators import simple

    orig = simple.ProjectOp._next
    state = {"n": 0}

    def boom(self):
        if state["n"] >= after_batches:
            raise _Bomb("downstream failure")
        state["n"] += 1
        return orig(self)

    monkeypatch.setattr(simple.ProjectOp, "_next", boom)


def _count_window_spills(monkeypatch):
    from repro.core.operators.merge_join import _Window

    counter = {"n": 0}
    orig = _Window._spill

    def counting(self):
        counter["n"] += 1
        return orig(self)

    monkeypatch.setattr(_Window, "_spill", counting)
    return counter


def _count_rel_spills(monkeypatch):
    counter = {"n": 0}
    orig = PartitionedRelation._spill_partition

    def counting(self, *a, **kw):
        counter["n"] += 1
        return orig(self, *a, **kw)

    monkeypatch.setattr(PartitionedRelation, "_spill_partition", counting)
    return counter


def test_merge_join_spill_not_leaked_on_error(tmp_path, monkeypatch):
    from repro.core.operators import merge_join

    monkeypatch.setattr(merge_join, "_SPILL_THRESHOLD_ROWS", 64)
    spills = _count_window_spills(monkeypatch)
    _failing_project(monkeypatch, 1)
    store = _join_store()
    eng = Engine(
        store,
        EngineConfig(spill_dir=str(tmp_path), join_strategy="merge"),
    )
    q = "SELECT ?a ?x ?g { ?a :knows ?x . ?b :likes ?x . ?b :age ?g }"
    node, vt = eng.parse(q)
    phys = eng.plan(node)
    assert "MergeJoin" in PL.explain(phys)
    with pytest.raises(_Bomb):
        eng.execute_plan(phys, vt)
    assert spills["n"] > 0  # the failure really interrupted spilled state
    assert not _spill_leaks(tmp_path)


def test_grace_join_spill_not_leaked_on_error(tmp_path, monkeypatch):
    spills = _count_rel_spills(monkeypatch)
    _failing_project(monkeypatch, 1)
    store = _join_store()
    eng = Engine(
        store,
        EngineConfig(spill_dir=str(tmp_path), memory_budget=20_000,
                     join_strategy="hash"),
    )
    node, vt = eng.parse(QUERIES[0])
    phys = eng.plan(node)
    assert "grace" in PL.explain(phys)
    with pytest.raises(_Bomb):
        eng.execute_plan(phys, vt)
    assert spills["n"] > 0
    assert not _spill_leaks(tmp_path)


def test_partitioned_group_by_spill_not_leaked_on_error(tmp_path, monkeypatch):
    """Die *inside* the partition-at-a-time aggregation loop: unconsumed
    partitions still hold spill files when the exception unwinds."""
    spills = _count_rel_spills(monkeypatch)
    orig = SortGroupBy._aggregate_block
    calls = {"n": 0}

    def bomb(self, cols, need, avars):
        calls["n"] += 1
        if calls["n"] >= 2:
            raise _Bomb("mid-aggregation failure")
        return orig(self, cols, need, avars)

    monkeypatch.setattr(SortGroupBy, "_aggregate_block", bomb)
    store = _join_store()
    eng = Engine(
        store, EngineConfig(spill_dir=str(tmp_path), memory_budget=8_000),
    )
    node, vt = eng.parse(QUERIES[1])
    phys = eng.plan(node)
    assert "Group[partitioned" in PL.explain(phys)
    with pytest.raises(_Bomb):
        eng.execute_plan(phys, vt)
    assert spills["n"] > 0
    assert not _spill_leaks(tmp_path)
