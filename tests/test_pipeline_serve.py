"""Data pipelines (samplers incl. the BARQ-backed one) + query serving."""

import numpy as np
import pytest

from repro.core import EngineConfig, QuadStore
from repro.models.gnn.sampler import BARQSampler, CSRSampler
from repro.pipeline.data import (
    GraphPipeline,
    block_to_model_inputs,
    recsys_batch,
    token_batch,
)
from repro.serve.query_server import QueryServer


@pytest.fixture()
def small_graph():
    rng = np.random.RandomState(0)
    n = 60
    src = rng.randint(0, n, 400).astype(np.int32)
    dst = rng.randint(0, n, 400).astype(np.int32)
    keep = src != dst
    edge_index = np.unique(np.stack([src[keep], dst[keep]]), axis=1)
    return edge_index, n


def _adj(edge_index):
    adj = {}
    for s, d in edge_index.T:
        adj.setdefault(int(s), set()).add(int(d))
    return adj


def test_csr_sampler_neighbors_valid(small_graph):
    edge_index, n = small_graph
    adj = _adj(edge_index)
    s = CSRSampler(edge_index, n, seed=0)
    seeds = np.arange(n, dtype=np.int32)
    nbrs = s.sample_neighbors(seeds, 5)
    for i in range(n):
        got = {int(x) for x in nbrs[i] if x >= 0}
        assert got <= adj.get(i, set())
        # fanout respected and saturating
        assert len(got) == min(len(adj.get(i, set())), 5) or len(got) <= 5


def test_barq_sampler_matches_adjacency(small_graph):
    """The engine-backed sampler must draw from exactly the same neighbor
    sets as the CSR sampler (BARQ as data pipeline, DESIGN.md §3)."""
    edge_index, n = small_graph
    adj = _adj(edge_index)
    store = QuadStore()
    quads = np.stack(
        [
            edge_index[0],
            np.full(edge_index.shape[1], 0, np.int32),
            edge_index[1],
            np.full(edge_index.shape[1], 1, np.int32),
        ],
        axis=1,
    )
    # encode node ids as themselves: pre-populate dictionary 0..n-1
    for i in range(max(n, 2)):
        store.dict.encode(i)
    pred = store.dict.encode(":edge")
    g = store.dict.encode(":default")
    quads[:, 1] = pred
    quads[:, 3] = g
    store.add_encoded(quads)
    store.build()

    s = BARQSampler(store, ":edge", seed=0)
    seeds = np.arange(n, dtype=np.int32)
    nbrs = s.sample_neighbors(seeds, 4)
    for i in range(n):
        got = {int(x) for x in nbrs[i] if x >= 0}
        assert got <= adj.get(i, set()), f"node {i}"


def test_block_assembly_local_indices(small_graph):
    edge_index, n = small_graph
    s = CSRSampler(edge_index, n, seed=1)
    labels = np.arange(n, dtype=np.int32) % 7
    block = s.sample_block(np.asarray([0, 1, 2, 3], np.int32), [3, 2], labels)
    n_total = len(block.nodes)
    assert block.seed_mask[:4].all()
    ok = block.edge_src >= -1
    assert ok.all()
    for e in (block.edge_src, block.edge_dst):
        assert e.max() < n_total
    # local edges refer to matching global nodes
    inputs = block_to_model_inputs(block, d_feat=8)
    assert inputs["x"].shape == (n_total, 8)
    assert np.isfinite(inputs["x"]).all()


def test_graph_pipeline_deterministic(small_graph):
    edge_index, n = small_graph
    s1 = CSRSampler(edge_index, n, seed=5)
    s2 = CSRSampler(edge_index, n, seed=5)
    labels = np.zeros(n, np.int32)
    p1 = GraphPipeline(s1, labels, n, 8, [3, 2], seed=2)
    p2 = GraphPipeline(s2, labels, n, 8, [3, 2], seed=2)
    b1, b2 = p1.batch(7), p2.batch(7)
    np.testing.assert_array_equal(b1.nodes, b2.nodes)


def test_token_and_recsys_batches_resumable():
    a = token_batch(1, 5, 4, 16, 100)
    b = token_batch(1, 5, 4, 16, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = recsys_batch(1, 5, 8, 4, 3, [10, 10, 10])
    d = recsys_batch(1, 5, 8, 4, 3, [10, 10, 10])
    np.testing.assert_array_equal(c["sparse"], d["sparse"])
    assert c["labels"].shape == (8,)


def test_query_server_workload(social_store):
    store, meta = social_store
    server = QueryServer(store, EngineConfig(engine="barq"))
    reqs = [
        ("q1", "SELECT (COUNT(*) AS ?c) { ?a :knows ?b . ?b :hasInterest ?t }"),
        ("q2", "SELECT ?a { ?a :isLocatedIn :city0 }"),
    ] * 5
    stats = server.run_workload(reqs, warmup=2)
    assert stats["n_requests"] == 8
    assert stats["qps"] > 0 and stats["p99_ms"] >= stats["p50_ms"]
    # plan cache: one plan per template
    assert len(server._plan_cache) == 2
