"""Listing 3 / §5.2 ablation — adaptive vs fixed batch size.

Measures the *overfetching* metric directly: rows read from storage by the
scans under a selective merge-join plan (the paper's §3.4 example query),
with adaptive sizing on vs off. Paper: Explore throughput drops ~33% and
BI ~44% with fixed batches; the scans of Listing 3b read 10x+ more rows
than 3c."""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import Suite, time_query
from repro.data import BSBM_EXPLORE_TEMPLATES, generate_ecommerce_graph, instantiate_explore


def run(scale: float = 0.2, runs: int = 5) -> str:
    store, meta = generate_ecommerce_graph(scale=scale)
    rng = np.random.RandomState(3)
    q = instantiate_explore(BSBM_EXPLORE_TEMPLATES["e2"], meta, rng)
    suite = Suite(f"Adaptive batch sizing (Listing 3) scale={scale}")

    adaptive = time_query(store, q, "barq", runs=runs, adaptive_batching=True)
    for fixed in (64, 512, 4096):
        f = time_query(
            store, q, "barq", runs=runs,
            adaptive_batching=False, initial_batch=fixed, max_batch=fixed,
            join_initial_batch=fixed,
        )
        suite.add(
            f"fixed_{fixed}", f["mean_s"] * 1e6,
            f"rows_scanned={f['rows_scanned']};"
            f"overfetch_vs_adaptive={f['rows_scanned'] / max(adaptive['rows_scanned'], 1):.2f}x",
        )
    suite.add(
        "adaptive", adaptive["mean_s"] * 1e6,
        f"rows_scanned={adaptive['rows_scanned']}",
    )
    legacy = time_query(store, q, "legacy", runs=max(runs // 2, 1))
    suite.add(
        "legacy_rowbased", legacy["mean_s"] * 1e6,
        f"rows_scanned={legacy['rows_scanned']} (row-at-a-time floor)",
    )
    return suite.emit()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--runs", type=int, default=5)
    a = ap.parse_args()
    print(run(a.scale, a.runs))
