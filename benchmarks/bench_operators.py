"""Listing 1/5 profiles — operator microbenchmarks: tuples/second through
the vectorized merge join / filter / streaming aggregation vs their
row-based counterparts, at the batch sizes the adaptive sizer actually
settles on. The paper's Listing 5 headline: the top merge join emits 288M
rows in ~10% of query time; here we measure emission throughput directly."""

from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import Suite
from repro.core.algebra import AggSpec, And, Arith, Cmp, Func, Lit, VarRef
from repro.core.batch import BatchPool
from repro.core.expressions import eval_expr_mask
from repro.core.exprs import compile_expr, eval_program_mask
from repro.core.legacy.operators import RowGroupBy, RowMergeJoin, RowSort
from repro.core.operators.adapters import BatchToRow
from repro.core.operators.aggregate import SortGroupBy, StreamingGroupBy
from repro.core.operators.merge_join import MergeJoin
from repro.core.operators.sort import MaterializedSource
from repro.core.dictionary import Dictionary


def _sorted_rel(rng, n, n_keys, extra_cols=1):
    keys = np.sort(rng.randint(0, n_keys, n)).astype(np.int32)
    cols = [keys] + [rng.randint(0, 1000, n).astype(np.int32) for _ in range(extra_cols)]
    return np.stack(cols)


def _drain_timed(make_join, reps=3):
    """Warmup + best-of-N: rebuild and drain the operator tree per rep,
    timing only the drain (single-shot numbers on a shared box are ~10%
    noisy; the min is the standard microbenchmark estimator)."""
    out = 0
    best = float("inf")
    for rep in range(reps + 1):  # rep 0 = warmup
        j = make_join()
        t0 = time.perf_counter()
        out = 0
        while True:
            b = j.next_batch()
            if b is None:
                break
            out += b.n_active
            if hasattr(b, "release"):
                b.release()
        dt = time.perf_counter() - t0
        if rep > 0:
            best = min(best, dt)
    return out, best


def bench_merge_join(rng, n=60000, n_keys=6000, batch=4096):
    from repro.core.batch import BatchPool

    l = _sorted_rel(rng, n, n_keys)
    r = _sorted_rel(rng, n, n_keys)

    def make():
        pool = BatchPool()
        return MergeJoin(
            MaterializedSource((0, 1), l, 0, batch, pool=pool),
            MaterializedSource((0, 2), r, 0, batch, pool=pool),
            0,
            pool=pool,
        )

    return _drain_timed(make)


def bench_row_merge_join(rng, n=60000, n_keys=6000):
    l = _sorted_rel(rng, n, n_keys)
    r = _sorted_rel(rng, n, n_keys)

    class _RowSrc(RowSort):
        pass

    left = MaterializedSource((0, 1), l, 0)
    right = MaterializedSource((0, 2), r, 0)
    from repro.core.operators.adapters import BatchToRow

    j = RowMergeJoin(BatchToRow(left), BatchToRow(right), 0)
    t0 = time.perf_counter()
    out = 0
    while j.next_row() is not None:
        out += 1
    dt = time.perf_counter() - t0
    return out, dt


def bench_lookup_join(rng, n_probe=200000, n_build=50000, n_keys=20000, batch=4096):
    from repro.core.batch import BatchPool
    from repro.core.operators.lookup_join import LookupJoin

    p = _sorted_rel(rng, n_probe, n_keys)
    b = _sorted_rel(rng, n_build, n_keys)

    def make():
        pool = BatchPool()
        return LookupJoin(
            MaterializedSource((0, 1), p, 0, batch, pool=pool),
            MaterializedSource((0, 2), b, 0, batch, pool=pool),
            0,
            pool=pool,
        )

    return _drain_timed(make)


def bench_hash_vs_sort_merge(rng, n=200_000, multi_key=False, reps=3,
                             oracle_n=None):
    """The §11 acceptance workloads: 200k-row unsorted high-cardinality
    joins. ``sort_merge`` is the pre-PR plan (PSort on BOTH inputs feeding
    MergeJoin — what every unsorted binary join paid); ``hash`` is the
    radix-partitioned HashJoin probing the same streams unsorted.

    single-key: 100k distinct codes, ~2 rows per key on each side.
    multi-key (the ISSUE-5 >=5x acceptance row): two shared variables
    whose COMPOSITE is high-cardinality (~200k pairs) but whose primary
    alone is low-distinct (2k) — the merge join can only sort/merge on
    the primary and must expand every primary-run cross product before
    the secondary-key equality pass discards ~99% of it (§3.2 Multiple
    Join Keys); the hash join keys on the packed composite and never
    materializes the blowup.

    Exact multiset parity is asserted against the legacy row engine
    (RowHashJoin; ``oracle_n`` caps the slice the row oracle chews
    through in fast/CI mode)."""
    from repro.core.operators.hash_join import HashJoin
    from repro.core.operators.sort import SortByVarOp
    from repro.core.legacy.operators import RowHashJoin

    if multi_key:
        lv, rv, keys = (0, 1, 2), (0, 1, 3), (0, 1)
        l = np.stack([rng.randint(0, n // 100, n), rng.randint(0, 100, n),
                      rng.randint(0, 1000, n)]).astype(np.int32)
        r = np.stack([rng.randint(0, n // 100, n), rng.randint(0, 100, n),
                      rng.randint(0, 1000, n)]).astype(np.int32)
    else:
        lv, rv, keys = (0, 1), (0, 2), (0,)
        l = np.stack([rng.permutation(n) % (n // 2),
                      rng.randint(0, 1000, n)]).astype(np.int32)
        r = np.stack([rng.permutation(n) % (n // 2),
                      rng.randint(0, 1000, n)]).astype(np.int32)

    def make_hash():
        pool = BatchPool()
        return HashJoin(
            MaterializedSource(lv, l, None, 4096, pool=pool),
            MaterializedSource(rv, r, None, 4096, pool=pool),
            keys, pool=pool,
        )

    def make_sort_merge():
        pool = BatchPool()
        return MergeJoin(
            SortByVarOp(MaterializedSource(lv, l, None, 4096, pool=pool),
                        0, pool=pool),
            SortByVarOp(MaterializedSource(rv, r, None, 4096, pool=pool),
                        0, pool=pool),
            0, pool=pool,
        )

    out_h, dt_h = _drain_timed(make_hash, reps)
    out_m, dt_m = _drain_timed(make_sort_merge, reps if not multi_key else 1)
    assert out_h == out_m, (out_h, out_m)

    # legacy row-engine oracle: exact multiset parity on the (possibly
    # sliced) workload
    oracle_n = n if oracle_n is None else min(oracle_n, n)
    lo, ro = l[:, :oracle_n], r[:, :oracle_n]
    t0 = time.perf_counter()
    j = RowHashJoin(
        BatchToRow(MaterializedSource(lv, lo, None, 4096)),
        BatchToRow(MaterializedSource(rv, ro, None, 4096)),
        keys,
    )
    out_vars = tuple(dict.fromkeys(lv + rv))
    row_out = {}
    while True:
        rrow = j.next_row()
        if rrow is None:
            break
        key = tuple(rrow[v] for v in out_vars)
        row_out[key] = row_out.get(key, 0) + 1
    dt_r = time.perf_counter() - t0

    chk = HashJoin(
        MaterializedSource(lv, lo, None, 4096),
        MaterializedSource(rv, ro, None, 4096), keys,
    )
    assert tuple(chk.var_ids()) == out_vars
    got = {}
    n_chk = 0
    while True:
        b = chk.next_batch()
        if b is None:
            break
        for rrow in b.compact().to_rows_array().tolist():
            key = tuple(rrow)
            got[key] = got.get(key, 0) + 1
            n_chk += 1
    assert got == row_out, "hash join != legacy row engine"
    return (out_h, dt_h), (out_m, dt_m), (n_chk, dt_r, oracle_n)


def bench_grace_hash_join(rng, n=200_000, reps=3, oracle_n=None):
    """The §15 out-of-core acceptance workload: the same unsorted 200k-row
    high-cardinality join as ``hash_join_batch``, but the grace run gets a
    memory budget of 25% of the build relation's bytes — both inputs fan
    out to disk-backed partitions, the build loads one partition at a time,
    and everything non-resident spills.  ``resident`` is the pre-PR
    behavior (whole build hash-resident, ``memory_budget=None``) on the
    identical data.  Asserted inside: resident/grace multiset parity,
    exact parity vs the legacy row engine on an ``oracle_n`` slice,
    spill counters > 0, and an empty spill dir afterwards (the take-frees-
    eagerly file lifecycle)."""
    from repro.core.legacy.operators import RowHashJoin
    from repro.core.operators.base import close_tree
    from repro.core.operators.hash_join import HashJoin

    lv, rv, keys = (0, 1), (0, 2), (0,)
    l = np.stack([rng.permutation(n) % (n // 2),
                  rng.randint(0, 1000, n)]).astype(np.int32)
    r = np.stack([rng.permutation(n) % (n // 2),
                  rng.randint(0, 1000, n)]).astype(np.int32)
    budget = max(int(r.nbytes) // 4, 4096)
    spill_dir = tempfile.mkdtemp(prefix="barq-bench-grace-")
    last: dict = {}

    def make_resident():
        pool = BatchPool()
        return HashJoin(
            MaterializedSource(lv, l, None, 4096, pool=pool),
            MaterializedSource(rv, r, None, 4096, pool=pool),
            keys, pool=pool,
        )

    def make_grace():
        pool = BatchPool()
        j = HashJoin(
            MaterializedSource(lv, l, None, 4096, pool=pool),
            MaterializedSource(rv, r, None, 4096, pool=pool),
            keys, pool=pool, grace=True,
            memory_budget=budget, spill_dir=spill_dir,
        )
        last["op"] = j
        return j

    try:
        out_res, dt_res = _drain_timed(make_resident, reps)
        out_g, dt_g = _drain_timed(make_grace, reps)
        assert out_g == out_res, (out_g, out_res)
        extra = dict(last["op"].stats.extra)
        close_tree(last["op"])
        assert extra.get("spill_files", 0) > 0, extra
        assert extra.get("spill_bytes", 0) > 0, extra
        leftovers = os.listdir(spill_dir)
        assert not leftovers, f"grace join leaked spill files: {leftovers}"
    finally:
        shutil.rmtree(spill_dir, ignore_errors=True)

    # legacy row-engine oracle on a slice: exact multiset parity through
    # the full partition/spill/reload path (§15 acceptance)
    oracle_n = n if oracle_n is None else min(oracle_n, n)
    lo, ro = l[:, :oracle_n], r[:, :oracle_n]
    o_budget = max(int(ro.nbytes) // 4, 2048)
    o_dir = tempfile.mkdtemp(prefix="barq-bench-grace-oracle-")
    try:
        t0 = time.perf_counter()
        j = RowHashJoin(
            BatchToRow(MaterializedSource(lv, lo, None, 4096)),
            BatchToRow(MaterializedSource(rv, ro, None, 4096)),
            keys,
        )
        out_vars = tuple(dict.fromkeys(lv + rv))
        row_out: dict = {}
        while True:
            rrow = j.next_row()
            if rrow is None:
                break
            key = tuple(rrow[v] for v in out_vars)
            row_out[key] = row_out.get(key, 0) + 1
        dt_oracle = time.perf_counter() - t0

        chk = HashJoin(
            MaterializedSource(lv, lo, None, 4096),
            MaterializedSource(rv, ro, None, 4096),
            keys, grace=True, memory_budget=o_budget, spill_dir=o_dir,
        )
        got: dict = {}
        while True:
            b = chk.next_batch()
            if b is None:
                break
            for rrow in b.compact().to_rows_array().tolist():
                key = tuple(rrow)
                got[key] = got.get(key, 0) + 1
        close_tree(chk)
        assert got == row_out, "grace hash join != legacy row engine"
    finally:
        shutil.rmtree(o_dir, ignore_errors=True)
    return (out_res, dt_res), (out_g, dt_g), extra, (oracle_n, dt_oracle)


def bench_partitioned_groupby(rng, n=200_000, n_keys=20_000, reps=3):
    """Partitioned GROUP BY (§15) vs the resident SortGroupBy it falls back
    from: same unsorted two-key aggregation workload, the partitioned run
    under a budget of ~10% of the grouped columns' bytes.  Group outputs
    are asserted equal as sorted multisets (each group lands in exactly
    one partition, so per-partition aggregation is exact, not a merge of
    partials) and the partitioned run must actually spill."""
    from repro.core.operators.aggregate import PartitionedGroupBy
    from repro.core.operators.base import close_tree

    d, keys, k2, vals = _agg_workload(rng, n, n_keys)
    perm = rng.permutation(n)  # unsorted: the shape the fallback pays for
    cols = np.stack([keys[perm], k2[perm], vals[perm]])
    budget = max(int(cols.nbytes) // 10, 4096)
    spill_dir = tempfile.mkdtemp(prefix="barq-bench-pgroup-")
    pool = BatchPool()
    last: dict = {}

    def make_resident():
        src = MaterializedSource((0, 2, 1), cols, None, 4096)
        return SortGroupBy(src, (0, 2), _AGG_SPECS, d, pool=pool)

    def make_partitioned():
        src = MaterializedSource((0, 2, 1), cols, None, 4096)
        g = PartitionedGroupBy(
            src, (0, 2), _AGG_SPECS, d, 4096, pool=pool,
            memory_budget=budget, spill_dir=spill_dir, n_parts=16,
        )
        last["op"] = g
        return g

    def rows_of(make):
        out = []
        op = make()
        while True:
            b = op.next_batch()
            if b is None:
                break
            c = b.compact()
            out.extend(map(tuple, c.to_rows_array().tolist()))
            c.release()
        close_tree(op)
        return sorted(out)

    try:
        out_res, dt_res = _drain_timed(make_resident, reps)
        out_p, dt_p = _drain_timed(make_partitioned, reps)
        extra = dict(last["op"].stats.extra)
        assert out_p == out_res, (out_p, out_res)
        assert extra.get("spill_files", 0) > 0, extra
        assert rows_of(make_partitioned) == rows_of(make_resident), (
            "partitioned group-by != resident SortGroupBy")
        leftovers = os.listdir(spill_dir)
        assert not leftovers, f"partitioned group-by leaked: {leftovers}"
    finally:
        shutil.rmtree(spill_dir, ignore_errors=True)
    return (out_res, dt_res), (out_p, dt_p), extra


def bench_telemetry_overhead(rng, n=200_000, reps=5):
    """Scoped-ledger cost (DESIGN.md §13): the 200k-row single-key hash
    join drained with no active trace (global ledger only) vs inside a
    ``trace_query`` scope with per-dispatch kernel events on. The §13
    acceptance bar is <5% overhead; the real cost per dispatch is a
    contextvar read + two perf_counter calls + Counter updates.

    The off/on drains are interleaved rep-by-rep (off, on, off, on, ...)
    and each side takes its best: measuring one whole side after the
    other lets CPU-frequency/allocator drift between the two windows
    masquerade as multi-percent "overhead" on a ~60ms workload."""
    from repro.core import telemetry
    from repro.core.operators.hash_join import HashJoin

    lv, rv, keys = (0, 1), (0, 2), (0,)
    l = np.stack([rng.permutation(n) % (n // 2),
                  rng.randint(0, 1000, n)]).astype(np.int32)
    r = np.stack([rng.permutation(n) % (n // 2),
                  rng.randint(0, 1000, n)]).astype(np.int32)

    def make():
        pool = BatchPool()
        return HashJoin(
            MaterializedSource(lv, l, None, 4096, pool=pool),
            MaterializedSource(rv, r, None, 4096, pool=pool),
            keys, pool=pool,
        )

    def drain(j):
        out = 0
        while True:
            b = j.next_batch()
            if b is None:
                return out
            out += b.n_active
            if hasattr(b, "release"):
                b.release()

    best_off = best_on = float("inf")
    out_off = out_on = n_disp = 0
    for rep in range(reps + 1):  # rep 0 = warmup, excluded from best
        t0 = time.perf_counter()
        out_off = drain(make())
        dt_off = time.perf_counter() - t0

        j = make()
        tr = telemetry.QueryTrace("bench_telemetry_overhead")
        t0 = time.perf_counter()
        with telemetry.trace_query(trace=tr):
            out_on = drain(j)
        dt_on = time.perf_counter() - t0

        if rep > 0:
            best_off = min(best_off, dt_off)
            best_on = min(best_on, dt_on)
        n_disp = tr.ledger.total()
    assert out_on == out_off, (out_on, out_off)
    assert n_disp > 0, "traced drain recorded no kernel dispatches"
    return out_off, best_off, best_on, n_disp


def _expr_workload(rng, n):
    """The acceptance workload (ISSUE 3): conjunctive FILTER + arithmetic
    + one string predicate over >= 100k rows. Codes 0..999 decode to their
    own integer value; the string column draws from a small term set so
    the dictionary-domain trick has real distinct-term reuse."""
    from repro.core.batch import ColumnBatch

    d = Dictionary()
    for v in range(1000):  # numeric terms so '>' hits the value side-array
        d.encode(int(v))
    strs = ['"apple"', '"applesauce"', '"apricot"', '"banana"', '"cherry"',
            '"grape"', '"peach"', '"pear"']
    scodes = np.asarray([d.encode(s) for s in strs], np.int32)
    a = rng.randint(0, 1000, n).astype(np.int32)
    b = rng.randint(0, 1000, n).astype(np.int32)
    s = scodes[rng.randint(0, len(scodes), n)]
    batch = ColumnBatch.from_columns((0, 1, 2), [a, b, s], capacity=n)
    expr = And((
        Cmp(">", Arith("+", VarRef(0), VarRef(1)), Lit(900)),
        Cmp("!=", VarRef(0), VarRef(1)),
        Func("strstarts", (VarRef(2), Lit('"ap"'))),
    ))
    return d, batch, expr


def bench_expression(rng, n=200_000, reps=3):
    """Interpreted tree walk vs expression VM (numpy oracle / jnp ref /
    fused Pallas kernel). Returns per-backend (n_selected, best_seconds);
    all four masks are asserted identical row-for-row."""
    d, batch, expr = _expr_workload(rng, n)
    prog = compile_expr(expr, d, "mask")

    def timed(fn, r):
        out, best = None, float("inf")
        for rep in range(r + 1):  # rep 0 = warmup (jit compile etc.)
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0) if rep else best
        return out, best

    results = {}
    masks = {}
    masks["tree_walk"], t = timed(lambda: eval_expr_mask(expr, batch, d), 1)
    results["tree_walk"] = t
    for be in ("numpy", "jax", "pallas"):
        masks[be], t = timed(
            lambda be=be: eval_program_mask(prog, batch, d, backend=be), reps
        )
        results[be] = t
    for k, m in masks.items():  # exact row parity across every regime
        np.testing.assert_array_equal(m, masks["numpy"], err_msg=k)
    return int(masks["numpy"].sum()), results, len(prog.instrs)


def _path_store(rng, n_edges, branch=2):
    """Chain-of-trees closure workload: a forest of ``branch``-ary trees
    (the LSQB/BSBM-style transitive-hierarchy shape), >= n_edges edges."""
    from repro.core import QuadStore

    quads = np.zeros((n_edges, 4), dtype=np.int32)
    store = QuadStore()
    pid = store.dict.encode(":child")
    gid = store.dict.encode(":default")
    # nodes 1..n_edges point at parent (i-1)//branch — one big shallow tree
    for i in range(n_edges):
        quads[i] = (
            store.dict.encode(f":n{i + 1}"),
            pid,
            store.dict.encode(f":n{i // branch}"),
            gid,
        )
    store.add_encoded(quads)
    return store.build()


def bench_path_vectorized(rng, n_edges=10000, reps=3):
    """The §8 frontier engine: full `:child+` closure over the tree."""
    from repro.core.batch import BatchPool
    from repro.core.operators.path import PathExpand
    from repro.core.paths.expr import PClosure, PLink
    from repro.core.algebra import V

    store = _path_store(rng, n_edges)
    metrics = {}

    def make():
        pool = BatchPool()
        op = PathExpand(
            store, PClosure(PLink(":child"), 1), V(0), V(1), pool=pool
        )
        metrics["op"] = op
        metrics["pool"] = pool
        return op

    out, dt = _drain_timed(make, reps=reps)
    op, pool = metrics["op"], metrics["pool"]
    extra = dict(op.stats.extra)
    extra.update({f"pool_{k}": v for k, v in pool.stats().items()
                  if k in ("allocations", "reuses")})
    return out, dt, extra


def bench_path_row(rng, n_edges=10000, reps=1):
    """RowTransitivePath — the per-source scalar BFS baseline."""
    from repro.core.legacy.property_path import RowTransitivePath

    store = _path_store(rng, n_edges)
    best = float("inf")
    out = 0
    for rep in range(reps + 1):
        op = RowTransitivePath(store, ":child", 0, 1)
        t0 = time.perf_counter()
        out = 0
        while op.next_row() is not None:
            out += 1
        dt = time.perf_counter() - t0
        if rep > 0:
            best = min(best, dt)
    return out, best


def bench_streaming_group(rng, n=1_000_000, n_keys=50000):
    d = Dictionary()
    keys = np.sort(rng.randint(0, n_keys, n)).astype(np.int32)
    vals = rng.randint(0, 100, n).astype(np.int32)
    # encode values so numeric aggregation has the side-array
    for v in range(100):
        d.encode(int(v))
    src = MaterializedSource((0, 1), np.stack([keys, vals]), 0, 4096)
    g = StreamingGroupBy(src, 0, [AggSpec("count", None, False, 9)], d)
    t0 = time.perf_counter()
    rows = 0
    while True:
        b = g.next_batch()
        if b is None:
            break
        rows += b.n_active
    dt = time.perf_counter() - t0
    return rows, dt


# the ISSUE-4 acceptance workload: many-groups aggregation with the full
# function repertoire, including a DISTINCT aggregate (the pre-PR scalar
# carry looped Python-level over every group run here)
_AGG_SPECS = [
    AggSpec("count", None, False, 9),
    AggSpec("sum", 1, False, 10),
    AggSpec("avg", 1, False, 11),
    AggSpec("sum", 1, True, 12),
]


def _agg_workload(rng, n, n_keys):
    d = Dictionary()
    for v in range(100):
        d.encode(int(v))
    keys = np.sort(rng.randint(0, n_keys, n)).astype(np.int32)
    k2 = rng.randint(0, 4, n).astype(np.int32)
    vals = rng.randint(0, 100, n).astype(np.int32)
    return d, keys, k2, vals


def bench_aggregation(rng, n=200_000, n_keys=20_000, reps=3, oracle_n=None):
    """Streaming (sorted single key) vs sort-based (two keys, unsorted)
    vs the legacy row hash aggregation; the streaming and row results are
    asserted equal row-for-row (the row engine is the oracle).

    ``oracle_n`` caps how many rows the per-row oracle chews through —
    fast/CI mode shrinks it so the smoke gate stays fast while the parity
    assertion still runs on real data (a sorted prefix of the workload)."""
    d, keys, k2, vals = _agg_workload(rng, n, n_keys)
    oracle_n = n if oracle_n is None else min(oracle_n, n)
    okeys, ovals = keys[:oracle_n], vals[:oracle_n]  # prefix stays sorted
    pool = BatchPool()

    def make_streaming(k=keys, v=vals):
        src = MaterializedSource((0, 1), np.stack([k, v]), 0, 4096)
        return StreamingGroupBy(src, 0, _AGG_SPECS, d, pool=pool)

    def make_sorted():
        src = MaterializedSource((0, 2, 1), np.stack([keys, k2, vals]), None, 4096)
        return SortGroupBy(src, (0, 2), _AGG_SPECS, d, pool=pool)

    def make_row():
        src = MaterializedSource((0, 1), np.stack([okeys, ovals]), 0, 4096)
        return RowGroupBy(BatchToRow(src), (0,), _AGG_SPECS, d)

    out_s, dt_s = _drain_timed(make_streaming, reps)
    out_m, dt_m = _drain_timed(make_sorted, reps)

    # row baseline (the §5 oracle) — one rep, it is orders slower
    t0 = time.perf_counter()
    row_rows = {}
    op = make_row()
    while True:
        r = op.next_row()
        if r is None:
            break
        row_rows[r[0]] = tuple(r.get(a.out) for a in _AGG_SPECS)
    dt_r = time.perf_counter() - t0

    # exact parity: streaming output == row-engine output (same slice)
    chk = make_streaming(okeys, ovals)
    n_chk = 0
    while True:
        b = chk.next_batch()
        if b is None:
            break
        for row in b.to_rows_array():
            want = row_rows[int(row[0])]
            got = tuple(None if c == -1 else int(c) for c in row[1:])
            assert got == want, (int(row[0]), got, want)
            n_chk += 1
        b.release()
    assert n_chk == len(row_rows), (n_chk, len(row_rows))

    # multi-key parity: the packed-key SortGroupBy path == row hash on the
    # same slice (covers pack_group_keys + the gid -> key back-translation)
    ok2 = k2[:oracle_n]

    def multi_src():
        return MaterializedSource(
            (0, 2, 1), np.stack([okeys, ok2, ovals]), None, 4096)

    row_multi = {}
    op = RowGroupBy(BatchToRow(multi_src()), (0, 2), _AGG_SPECS, d)
    while True:
        r = op.next_row()
        if r is None:
            break
        row_multi[(r[0], r[2])] = tuple(r.get(a.out) for a in _AGG_SPECS)
    chk = SortGroupBy(multi_src(), (0, 2), _AGG_SPECS, d, pool=pool)
    n_chk = 0
    while True:
        b = chk.next_batch()
        if b is None:
            break
        for row in b.to_rows_array():
            want = row_multi[(int(row[0]), int(row[1]))]
            got = tuple(None if c == -1 else int(c) for c in row[2:])
            assert got == want, ((int(row[0]), int(row[1])), got, want)
            n_chk += 1
        b.release()
    assert n_chk == len(row_multi), (n_chk, len(row_multi))
    return (out_s, dt_s), (out_m, dt_m), (len(row_rows), dt_r, oracle_n)


def _sip_store(n: int, sel: float = 0.01):
    """Selective multi-join workload (DESIGN.md §12): three n-row relations
    :p1/:p2/:p3 over all entities, one :rare relation over the first
    ``sel``-fraction of them (<5% build-side selectivity per ISSUE-6).
    Rare entities are interned FIRST so their dictionary codes cluster in
    a narrow range — the shape where SIP code-range narrowing pays (the
    probe scans seek straight to the rare window instead of streaming all
    n rows)."""
    from repro.core import QuadStore

    store = QuadStore()
    n_rare = max(int(n * sel), 1)
    for i in range(n_rare):
        store.add(f":e{i}", ":rare", f":r{i % 50}")
    for i in range(n):
        store.add(f":e{i}", ":p1", f":x{i % 1000}")
        store.add(f":e{i}", ":p2", f":y{i % 1000}")
        store.add(f":e{i}", ":p3", f":z{i % 1000}")
    return store.build(), n_rare


_SIP_Q = ("SELECT ?a ?x ?y ?z ?r "
          "{ ?a :p1 ?x . ?a :p2 ?y . ?a :p3 ?z . ?a :rare ?r }")


def bench_sip(n=200_000, reps=3):
    """End-to-end engine A/B: identical query + store, EngineConfig.sip
    on vs off (same planner otherwise), plus the legacy row engine as
    the exact-multiset parity oracle."""
    from repro.core import Engine, EngineConfig
    from repro.core.profiler import collect_stats
    from repro.kernels import ops as KOPS

    store, n_rare = _sip_store(n)

    def timed(cfg):
        # plan once, time execution only: the serve layer caches plans
        # (and the plan is identical across reps anyway), so the A/B
        # measures what SIP changes — the execution
        eng = Engine(store, cfg)
        node, vt = eng.parse(_SIP_Q)
        phys = eng.plan(node)
        best, res = float("inf"), None
        for rep in range(reps + 1):  # rep 0 = warmup
            t0 = time.perf_counter()
            r = eng.execute_plan(phys, vt)
            dt = time.perf_counter() - t0
            if rep > 0 and dt < best:
                best, res = dt, r
        return best, res

    t_on, r_on = timed(EngineConfig(sip="on"))
    t_off, r_off = timed(EngineConfig(sip="off"))
    stats_on = collect_stats(r_on.root)

    # exact multiset parity: sip on == sip off == legacy row engine
    rows_on = sorted(map(tuple, r_on.rows.tolist()))
    assert rows_on == sorted(map(tuple, r_off.rows.tolist()))
    t0 = time.perf_counter()
    r_leg = Engine(store, EngineConfig(engine="legacy")).execute(_SIP_Q)
    t_leg = time.perf_counter() - t0
    assert rows_on == sorted(map(tuple, r_leg.rows.tolist()))

    # the Pallas bloom kernels must actually dispatch on the same workload
    before = KOPS.dispatch_count("bloom_probe")
    eng = Engine(store, EngineConfig(sip="on", sip_backend="pallas"))
    r_pal = eng.execute(_SIP_Q)
    assert KOPS.dispatch_count("bloom_probe") > before or KOPS.dispatch_count(
        "bloom_build"
    ) > 0, "pallas bloom kernels never dispatched"
    assert sorted(map(tuple, r_pal.rows.tolist())) == rows_on

    return {
        "t_on": t_on,
        "t_off": t_off,
        "t_legacy": t_leg,
        "rows": len(rows_on),
        "n_rare": n_rare,
        "scanned_on": int(stats_on["rows_scanned"]),
        "scanned_off": int(collect_stats(r_off.root)["rows_scanned"]),
    }


def bench_feedback_loop(scale=0.05, reps=5):
    """Cardinality-feedback payoff + recording cost (DESIGN.md §14).

    Payoff: LSQB q6 (the paper's motivating query — its intermediate
    join blowup is exactly what independence-assumption estimators get
    wrong) runs twice on one apply-mode engine. Run 1 plans cold and
    misestimates past the MISEST bar; run 2 re-plans with the observed
    per-node cardinalities and its worst q-error must collapse to <= 2.

    Cost: the same query under ``observe`` (record actuals, never read
    them) vs ``off``, interleaved best-of-N like the §13 telemetry bench
    — the recording path is one post-drain tree walk plus EWMA updates,
    so it must stay in the telemetry-overhead noise class (<5%).

    Also asserts ``off`` is a true no-op: its EXPLAIN output is
    byte-identical to a default-config engine's."""
    from repro.core import Engine, EngineConfig
    from repro.core.profiler import collect_stats
    from repro.data import LSQB_QUERIES, generate_social_graph

    store, meta = generate_social_graph(scale=scale)
    q = LSQB_QUERIES["q6"]

    eng = Engine(store, EngineConfig(engine="barq",
                                     cardinality_feedback="apply"))
    t0 = time.perf_counter()
    r1 = eng.execute(q)
    t1 = time.perf_counter() - t0
    q_run1 = collect_stats(r1.root).get("max_q_error", 1.0)
    t0 = time.perf_counter()
    r2 = eng.execute(q)
    t2 = time.perf_counter() - t0
    q_run2 = collect_stats(r2.root).get("max_q_error", 1.0)
    assert r1.n_rows == r2.n_rows, "feedback re-plan changed the answer"
    assert q_run1 >= 4.0, (
        f"workload no longer misestimates cold (q={q_run1:.1f}); "
        f"the payoff case needs a MISEST-grade query")
    assert q_run2 <= 2.0, (
        f"feedback did not converge: run-2 max_q_error={q_run2:.2f} > 2")

    # off must be a byte-level no-op vs a default engine
    plan_off = Engine(store, EngineConfig(
        engine="barq", cardinality_feedback="off")).explain(q)
    plan_default = Engine(store, EngineConfig(engine="barq")).explain(q)
    assert plan_off == plan_default, "cardinality_feedback=off changed plans"

    # recording overhead: observe vs off, interleaved best-of-N
    best_off = best_obs = float("inf")
    for rep in range(reps + 1):  # rep 0 = warmup
        e_off = Engine(store, EngineConfig(engine="barq",
                                           cardinality_feedback="off"))
        t0 = time.perf_counter()
        r_off = e_off.execute(q)
        dt_off = time.perf_counter() - t0

        e_obs = Engine(store, EngineConfig(engine="barq",
                                           cardinality_feedback="observe"))
        t0 = time.perf_counter()
        r_obs = e_obs.execute(q)
        dt_obs = time.perf_counter() - t0

        assert r_obs.n_rows == r_off.n_rows
        if rep > 0:
            best_off = min(best_off, dt_off)
            best_obs = min(best_obs, dt_obs)

    return {
        "rows": r1.n_rows,
        "n_triples": meta["n_triples"],
        "q_run1": q_run1,
        "q_run2": q_run2,
        "t_run1": t1,
        "t_run2": t2,
        "t_off": best_off,
        "t_observe": best_obs,
    }


def run(seed: int = 0, fast: bool = False) -> str:
    """``fast`` is the CI smoke mode: tiny sizes so kernel regressions in
    the path subsystem fail the gate quickly without benchmark-scale cost."""
    rng = np.random.RandomState(seed)
    suite = Suite("Operator microbenchmarks (Listing 1/5 profiles)")

    out, dt = bench_merge_join(rng, n=12000 if fast else 60000,
                               n_keys=1200 if fast else 6000)
    suite.add("merge_join_batch", dt * 1e6, f"tuples_out={out};Mtps={out / dt / 1e6:.1f}")
    out_r, dt_r = bench_row_merge_join(rng, n=2000 if fast else 8000,
                                       n_keys=200 if fast else 800)
    suite.add("merge_join_row", dt_r * 1e6,
              f"tuples_out={out_r};Mtps={out_r / dt_r / 1e6:.3f}")

    out_l, dt_l = bench_lookup_join(rng, n_probe=40000 if fast else 200000,
                                    n_build=10000 if fast else 50000,
                                    n_keys=4000 if fast else 20000)
    suite.add("lookup_join_batch", dt_l * 1e6,
              f"tuples_out={out_l};Mtps={out_l / dt_l / 1e6:.1f}")

    # hash-join suite (DESIGN.md §11): 200k-row unsorted high-cardinality
    # joins, radix-hash vs the pre-PR double-PSort+MergeJoin plan, exact
    # multiset parity vs the legacy row engine asserted inside. The
    # multi-key row is the ISSUE-5 acceptance comparison (>= 5x floor on
    # the full-size run): merge can only order on the primary var and
    # pays the §3.2 secondary-key expansion blowup.
    n_hj = 40_000 if fast else 200_000
    oracle_hj = 5_000 if fast else None
    (o_h, t_h), (o_sm, t_sm), (o_r, t_r, n_r) = bench_hash_vs_sort_merge(
        rng, n=n_hj, multi_key=False, oracle_n=oracle_hj)
    suite.add("hash_join_batch", t_h * 1e6,
              f"tuples_out={o_h};Mtps={o_h / t_h / 1e6:.1f};"
              f"speedup_vs_sort_merge={t_sm / t_h:.1f}x")
    suite.add("sort_merge_join_batch", t_sm * 1e6,
              f"tuples_out={o_sm};Mtps={o_sm / t_sm / 1e6:.1f}")
    (o_h2, t_h2), (o_sm2, t_sm2), (o_r2, t_r2, n_r2) = bench_hash_vs_sort_merge(
        rng, n=n_hj, multi_key=True, oracle_n=oracle_hj)
    speedup = t_sm2 / t_h2
    suite.add("hash_join_multikey_batch", t_h2 * 1e6,
              f"tuples_out={o_h2};Mtps={o_h2 / t_h2 / 1e6:.1f};"
              f"speedup_vs_sort_merge={speedup:.1f}x")
    suite.add("sort_merge_join_multikey_batch", t_sm2 * 1e6,
              f"tuples_out={o_sm2};Mtps={o_sm2 / t_sm2 / 1e6:.1f}")
    suite.add("hash_join_row_oracle", (t_r + t_r2) * 1e6,
              f"tuples_out={o_r + o_r2};rows={n_r + n_r2};"
              f"Mtps={(o_r + o_r2) / 1e6 / (t_r + t_r2):.3f}")
    if not fast:
        assert speedup >= 5.0, f"acceptance: hash vs sort+merge {speedup:.1f}x < 5x"

    # out-of-core suite (DESIGN.md §15): grace hash join under a budget of
    # 25% of the build bytes vs the resident build on identical data, and
    # partitioned GROUP BY at ~10% of the grouped columns vs SortGroupBy.
    # Parity (incl. the legacy row oracle for the join), spill counters > 0,
    # and empty-spill-dir lifecycle are asserted inside both benches. The
    # *_resident rows are the pre-PR paths re-measured on this box — the
    # regression gate pairs them against the 'before' section so the budget
    # gating added to HashJoin/SortGroupBy shows up if it taxes them. Both
    # benches get dedicated rng streams (not the shared cursor) so a paired
    # baseline can regenerate the byte-identical workload in isolation.
    (o_gres, t_gres), (o_g, t_g), gex, (n_go, t_go) = bench_grace_hash_join(
        np.random.RandomState(seed + 915), n=n_hj,
        oracle_n=5_000 if fast else 20_000)
    suite.add("grace_hash_join_resident", t_gres * 1e6,
              f"tuples_out={o_gres};Mtps={o_gres / t_gres / 1e6:.1f};"
              f"memory_budget=None")
    suite.add("grace_hash_join_batch", t_g * 1e6,
              f"tuples_out={o_g};Mtps={o_g / t_g / 1e6:.1f};"
              f"spilled_mb={gex.get('spill_bytes', 0) / 1e6:.1f};"
              f"spill_files={gex.get('spill_files', 0)};"
              f"parts={gex.get('grace_partitions', 0)};"
              f"slowdown_vs_resident={t_g / t_gres:.2f}x")
    suite.add("grace_hash_join_row_oracle", t_go * 1e6,
              f"rows={n_go};legacy row engine, exact multiset parity vs "
              f"the spilling grace path asserted")
    (o_gbres, t_gbres), (o_gb, t_gb), gbex = bench_partitioned_groupby(
        np.random.RandomState(seed + 916), n=n_hj, n_keys=n_hj // 10)
    suite.add("partitioned_groupby_resident", t_gbres * 1e6,
              f"groups={o_gbres};Mtps={o_gbres / t_gbres / 1e6:.2f};"
              f"single-argsort SortGroupBy, memory_budget=None")
    suite.add("partitioned_groupby_batch", t_gb * 1e6,
              f"groups={o_gb};"
              f"spilled_mb={gbex.get('spill_bytes', 0) / 1e6:.1f};"
              f"spill_files={gbex.get('spill_files', 0)};"
              f"slowdown_vs_resident={t_gb / t_gbres:.2f}x")
    if not fast:
        # acceptance: out-of-core execution pays I/O, not blowup — the
        # grace join stays within 8x of the fully-resident build even
        # with the build side 4x over budget
        grace_slowdown = t_g / t_gres
        assert grace_slowdown < 8.0, (
            f"acceptance: grace join {grace_slowdown:.1f}x >= 8x resident")

    # telemetry-overhead suite (DESIGN.md §13): same hash-join workload,
    # traced vs untraced drain. Acceptance: <5% on the full-size run
    # (best-of-N on both sides keeps the comparison off the noise floor).
    o_t, t_toff, t_ton, n_disp = bench_telemetry_overhead(
        rng, n=40_000 if fast else 200_000)
    overhead_pct = (t_ton - t_toff) / t_toff * 100.0
    suite.add("hash_join_telemetry_on", t_ton * 1e6,
              f"tuples_out={o_t};dispatches={n_disp};"
              f"overhead_vs_off={overhead_pct:.1f}%")
    suite.add("hash_join_telemetry_off", t_toff * 1e6,
              f"tuples_out={o_t};global ledger only")

    # cardinality-feedback suite (DESIGN.md §14): LSQB q6 twice on one
    # apply-mode engine (run 2 re-plans from observed cardinalities and
    # must land at q-error <= 2), plus observe-vs-off recording overhead
    # on the same query. Off-mode byte-identity and the q-error bars are
    # asserted inside the bench at both scales.
    fb = bench_feedback_loop(scale=0.02 if fast else 0.05)
    suite.add("feedback_q6_apply_run1", fb["t_run1"] * 1e6,
              f"rows={fb['rows']};max_q_error={fb['q_run1']:.1f};cold plan")
    suite.add("feedback_q6_apply_run2", fb["t_run2"] * 1e6,
              f"rows={fb['rows']};max_q_error={fb['q_run2']:.2f};"
              f"replanned from observed cardinalities")
    fb_overhead = (fb["t_observe"] - fb["t_off"]) / fb["t_off"] * 100.0
    suite.add("feedback_q6_observe", fb["t_observe"] * 1e6,
              f"rows={fb['rows']};overhead_vs_off={fb_overhead:.1f}%")
    suite.add("feedback_q6_off", fb["t_off"] * 1e6,
              f"rows={fb['rows']};no recording")
    if not fast:
        assert overhead_pct < 5.0, (
            f"acceptance: telemetry overhead {overhead_pct:.1f}% >= 5%")

    # expression VM suite (DESIGN.md §9): interpreted tree walk vs VM
    # backends on the FILTER acceptance workload (arith + conjunction +
    # dictionary-domain string predicate; exact parity asserted inside)
    n_expr = 40_000 if fast else 200_000
    nsel, expr_t, n_ops = bench_expression(rng, n=n_expr)
    mrows = n_expr / 1e6
    suite.add("expr_filter_tree_walk", expr_t["tree_walk"] * 1e6,
              f"selected={nsel};Mtps={mrows / expr_t['tree_walk']:.2f}")
    for be in ("numpy", "jax", "pallas"):
        suite.add(
            f"expr_filter_vm_{be}", expr_t[be] * 1e6,
            f"selected={nsel};ops={n_ops};Mtps={mrows / expr_t[be]:.1f};"
            f"speedup_vs_tree={expr_t['tree_walk'] / expr_t[be]:.1f}x",
        )

    rows, dtg = bench_streaming_group(rng, n=200_000 if fast else 1_000_000,
                                      n_keys=10000 if fast else 50000)
    suite.add("streaming_groupby_1M", dtg * 1e6,
              f"groups={rows};Mtps={1.0 / dtg:.1f}")

    # grouping-engine suite (DESIGN.md §10): segmented-reduction streaming
    # vs packed-key sort-based vs legacy row hash; exact parity of BOTH
    # batch paths against the row oracle is asserted inside. The reported
    # speedup_vs_row is per-tuple vs the legacy ROW engine; the ISSUE-4
    # acceptance comparison (>= 5x over the pre-PR scalar-carry BATCH
    # operator) is recorded as before/after in BENCH_PR4.json
    n_agg = 40_000 if fast else 200_000
    k_agg = 4_000 if fast else 20_000
    (o_s, t_s), (o_m, t_m), (o_r, t_r, n_r) = bench_aggregation(
        rng, n=n_agg, n_keys=k_agg, oracle_n=5_000 if fast else None)
    mrows = n_agg / 1e6
    # the row oracle may run a smaller slice in fast mode: compare
    # per-tuple costs so the reported speedup stays meaningful
    speedup = (t_r / n_r) / (t_s / n_agg)
    suite.add("agg_streaming_batch", t_s * 1e6,
              f"groups={o_s};Mtps={mrows / t_s:.1f};"
              f"speedup_vs_row={speedup:.1f}x")
    suite.add("agg_sort_multikey_batch", t_m * 1e6,
              f"groups={o_m};Mtps={mrows / t_m:.1f}")
    suite.add("agg_row_hash", t_r * 1e6,
              f"groups={o_r};rows={n_r};Mtps={n_r / 1e6 / t_r:.3f}")

    # property-path closure: vectorized frontier engine vs row baseline
    # (DESIGN.md §8; acceptance floor is 3x on the 10k-edge tree)
    n_edges = 2000 if fast else 10000
    out_p, dt_p, extra = bench_path_vectorized(rng, n_edges=n_edges)
    suite.add(
        "path_closure_batch", dt_p * 1e6,
        f"pairs={out_p};Mtps={out_p / dt_p / 1e6:.1f};"
        f"rounds={extra.get('frontier_rounds')};"
        f"dedup_ratio={extra.get('dedup_ratio')};"
        f"pool_alloc={extra.get('pool_allocations')};"
        f"pool_reuse={extra.get('pool_reuses')}",
    )
    out_pr, dt_pr = bench_path_row(rng, n_edges=n_edges)
    assert out_pr == out_p, (out_pr, out_p)  # row engine is the oracle
    suite.add("path_closure_row", dt_pr * 1e6,
              f"pairs={out_pr};Mtps={out_pr / dt_pr / 1e6:.3f};"
              f"speedup_vs_row={dt_pr / dt_p:.1f}x")

    # SIP suite (DESIGN.md §12): selective multi-join, 200k-row probe
    # relations, <5% build-side selectivity with a clustered code range.
    # Exact multiset parity sip-on == sip-off == legacy row engine and a
    # Pallas bloom dispatch are asserted inside.
    sip = bench_sip(n=40_000 if fast else 200_000)
    sip_speedup = sip["t_off"] / sip["t_on"]
    suite.add("sip_on_engine", sip["t_on"] * 1e6,
              f"rows={sip['rows']};scanned={sip['scanned_on']};"
              f"speedup_vs_sip_off={sip_speedup:.1f}x")
    suite.add("sip_off_engine", sip["t_off"] * 1e6,
              f"rows={sip['rows']};scanned={sip['scanned_off']}")
    suite.add("sip_row_oracle", sip["t_legacy"] * 1e6,
              f"rows={sip['rows']};legacy row engine, exact multiset "
              f"parity asserted")
    if not fast:
        # Acceptance gate: the deterministic invariant is the overfetch
        # reduction (rows the scans skip thanks to the pushed filters) —
        # wall-clock ratio on this workload swings 2.3–4x with machine
        # load, so it gets a loose floor while the scanned-rows ratio
        # (56.6x at this selectivity) carries the tight one.
        scan_ratio = sip["scanned_off"] / max(sip["scanned_on"], 1)
        assert scan_ratio >= 40.0, (
            f"acceptance: SIP scanned-rows reduction {scan_ratio:.1f}x < 40x")
        assert sip_speedup >= 2.0, (
            f"acceptance: SIP on vs off {sip_speedup:.1f}x < 2x")
    return suite.emit()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    print(run(args.seed, fast=args.fast))
