"""Shared benchmark harness utilities."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import Engine, EngineConfig
from repro.core.profiler import collect_stats


def time_query(store, query: str, engine: str, warmup: int = 1, runs: int = 3,
               **cfg_kwargs) -> Dict[str, float]:
    """Average execution time (paper §5.1: warm-up runs then test runs)."""
    times: List[float] = []
    n_rows = 0
    scanned = 0
    for i in range(warmup + runs):
        e = Engine(store, EngineConfig(engine=engine, **cfg_kwargs))
        t0 = time.perf_counter()
        r = e.execute(query)
        dt = time.perf_counter() - t0
        if i >= warmup:
            times.append(dt)
            n_rows = r.n_rows
            scanned = collect_stats(r.root)["rows_scanned"]
    return {
        "mean_s": float(np.mean(times)),
        "std_s": float(np.std(times)),
        "rows": n_rows,
        "rows_scanned": scanned,
    }


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


class Suite:
    def __init__(self, title: str):
        self.title = title
        self.lines: List[str] = []

    def add(self, name: str, us: float, derived: str):
        self.lines.append(row(name, us, derived))

    def emit(self) -> str:
        """CSV block; benchmarks.run re-parses it for --json output."""
        head = f"# {self.title}\nname,us_per_call,derived"
        return head + "\n" + "\n".join(self.lines)
