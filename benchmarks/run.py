"""Benchmark harness — one suite per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV blocks per suite:
  Fig 6a  LSQB CPU-bound joins           (bench_lsqb)
  Fig 6b  BSBM Explore OLTP              (bench_bsbm_explore)
  Fig 6c  BSBM Business Intelligence     (bench_bsbm_bi)
  List. 3 adaptive vs fixed batch size   (bench_adaptive)
  List. 1/5 operator microbenchmarks     (bench_operators)
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller scales")
    ap.add_argument("--suite", default="all",
                    choices=("all", "lsqb", "explore", "bi", "adaptive", "ops"))
    args = ap.parse_args()
    f = args.fast

    from benchmarks import (
        bench_adaptive,
        bench_bsbm_bi,
        bench_bsbm_explore,
        bench_lsqb,
        bench_operators,
    )

    suites = {
        "lsqb": lambda: bench_lsqb.run(scale=0.03 if f else 0.05,
                                       runs=2 if f else 3),
        "explore": lambda: bench_bsbm_explore.run(scale=0.1 if f else 0.2,
                                                  runs=3 if f else 5),
        "bi": lambda: bench_bsbm_bi.run(scale=0.08 if f else 0.15,
                                        runs=2 if f else 3),
        "adaptive": lambda: bench_adaptive.run(scale=0.1 if f else 0.2,
                                               runs=3 if f else 5),
        "ops": lambda: bench_operators.run(),
    }
    selected = suites if args.suite == "all" else {args.suite: suites[args.suite]}
    for name, fn in selected.items():
        t0 = time.time()
        print(fn())
        print(f"# suite {name} finished in {time.time() - t0:.1f}s\n", flush=True)


if __name__ == "__main__":
    main()
