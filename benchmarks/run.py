"""Benchmark harness — one suite per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--json out.json]

Prints ``name,us_per_call,derived`` CSV blocks per suite:
  Fig 6a  LSQB CPU-bound joins           (bench_lsqb)
  Fig 6b  BSBM Explore OLTP              (bench_bsbm_explore)
  Fig 6c  BSBM Business Intelligence     (bench_bsbm_bi)
  List. 3 adaptive vs fixed batch size   (bench_adaptive)
  List. 1/5 operator microbenchmarks     (bench_operators)

With ``--json <path>`` the same per-suite ``us_per_call`` rows are written
as a JSON document (suite → [{name, us_per_call, derived}]) so perf
trajectories can be tracked across PRs (see BENCH_PR1.json).

With ``--trace-out <path>`` an end-to-end telemetry smoke runs after the
suites: one LSQB query executes under EXPLAIN ANALYZE (report printed),
its QueryTrace is written as Chrome-trace JSON (loadable in Perfetto),
and a small served workload's metrics registry is written next to it as
``<path>.metrics.json`` — CI uploads both as artifacts. The smoke also
exercises the PR 8 workload-history surface (DESIGN.md §14): the served
workload runs under ``cardinality_feedback="apply"`` with a flight
recorder attached, a misestimating query's first run must trigger a
q-error flight capture (bundle under ``artifacts/flight/``), the
OpenMetrics exposition is written as ``<path>.metrics.prom`` and passes
``validate_openmetrics``, and the workload repository JSONL round-trips
through save/load as ``<path>.workload.jsonl``.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List


def _parse_rows(csv_block: str) -> List[Dict[str, object]]:
    """CSV block emitted by benchmarks.common.Suite → row dicts."""
    rows: List[Dict[str, object]] = []
    for line in csv_block.splitlines():
        if line.startswith("#") or line.startswith("name,") or not line.strip():
            continue
        name, us, derived = line.split(",", 2)
        rows.append({"name": name, "us_per_call": float(us), "derived": derived})
    return rows


def telemetry_smoke(trace_out: str, fast: bool = True) -> None:
    """EXPLAIN ANALYZE + trace/metrics export smoke (DESIGN.md §13):
    exercises the full telemetry surface end-to-end and leaves artifacts
    CI can upload. Validates the trace is well-formed Chrome-trace JSON."""
    from repro.core import Engine, EngineConfig
    from repro.data import LSQB_QUERIES, generate_social_graph
    from repro.serve.query_server import QueryServer

    store, meta = generate_social_graph(scale=0.02 if fast else 0.05)
    engine = Engine(store, EngineConfig(engine="barq"))
    res = engine.execute(LSQB_QUERIES["q6"])
    print(f"# EXPLAIN ANALYZE lsqb q6 ({meta['n_triples']} triples, "
          f"{res.n_rows} rows):")
    print(res.explain_analyze())
    res.trace.save_chrome_trace(trace_out)
    with open(trace_out) as fh:
        doc = json.load(fh)
    assert doc["traceEvents"], "trace export produced no events"
    assert all("ph" in ev and "pid" in ev for ev in doc["traceEvents"])
    print(f"# wrote {trace_out} ({len(doc['traceEvents'])} events)")

    from repro.serve.flight_recorder import FlightRecorder
    from repro.serve.metrics import validate_openmetrics
    from repro.serve.workload_repo import WorkloadRepository

    # served workload under cardinality feedback with a flight recorder:
    # q6's first run misestimates badly enough (planner has no history)
    # that the q-error trigger must capture a bundle (DESIGN.md §14)
    flight = FlightRecorder(out_dir="artifacts/flight", q_error_threshold=16.0)
    server = QueryServer(
        store,
        EngineConfig(engine="barq", cardinality_feedback="apply"),
        flight=flight,
    )
    reqs = [("q1", LSQB_QUERIES["q1"]), ("q6", LSQB_QUERIES["q6"])] * 3
    server.run_workload(reqs, warmup=2)
    metrics_out = trace_out + ".metrics.json"
    server.metrics.save(metrics_out)
    print(f"# wrote {metrics_out}")

    assert flight.n_captures >= 1, "flight recorder captured no outlier"
    bundle_dir = sorted(
        os.path.join("artifacts/flight", p)
        for p in os.listdir("artifacts/flight")
    )[-1]
    for fname in ("trace.json", "explain.txt", "meta.json"):
        assert os.path.exists(os.path.join(bundle_dir, fname)), (
            f"missing {fname} in bundle"
        )
    with open(os.path.join(bundle_dir, "meta.json")) as fh:
        meta_doc = json.load(fh)
    assert meta_doc["reasons"], "capture bundle records no trigger reason"
    print(f"# flight capture: {bundle_dir} (reasons: {meta_doc['reasons']})")

    # the repeated q6 must have re-planned with observed cardinalities:
    # a fresh run's worst plan-node q-error collapses vs the cold first run
    r_warm = server.execute("q6-warm", LSQB_QUERIES["q6"])
    assert r_warm.max_q_error <= 4.0, (
        f"feedback did not converge: warm q6 max_q_error={r_warm.max_q_error}"
    )
    print(f"# feedback loop: warm q6 max_q_error={r_warm.max_q_error:.2f} "
          f"(cold run triggered the capture above)")

    prom_out = trace_out + ".metrics.prom"
    exposition = server.openmetrics()
    families = validate_openmetrics(exposition)
    with open(prom_out, "w") as fh:
        fh.write(exposition)
    print(f"# wrote {prom_out} ({len(families)} metric families, "
          f"format-validated)")

    workload_out = trace_out + ".workload.jsonl"
    n_saved = server.workload.save(workload_out)
    reloaded = WorkloadRepository()
    n_loaded = reloaded.load(workload_out)
    assert n_loaded == n_saved, "workload JSONL did not round-trip"
    assert len(reloaded.feedback.snapshot()) == len(
        server.workload.feedback.snapshot()
    ), "feedback store did not round-trip"
    print(f"# wrote {workload_out} ({n_saved} fingerprints, "
          f"{len(reloaded.feedback.snapshot())} feedback entries, "
          f"reload-verified)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller scales")
    ap.add_argument("--suite", default="all",
                    choices=("all", "lsqb", "explore", "bi", "adaptive", "ops"))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-suite us_per_call results as JSON")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="run the telemetry smoke and write Chrome-trace "
                         "JSON (+ .metrics.json) artifacts")
    args = ap.parse_args()
    f = args.fast

    from benchmarks import (
        bench_adaptive,
        bench_bsbm_bi,
        bench_bsbm_explore,
        bench_lsqb,
        bench_operators,
    )

    suites = {
        "lsqb": lambda: bench_lsqb.run(scale=0.03 if f else 0.05,
                                       runs=2 if f else 3),
        "explore": lambda: bench_bsbm_explore.run(scale=0.1 if f else 0.2,
                                                  runs=3 if f else 5),
        "bi": lambda: bench_bsbm_bi.run(scale=0.08 if f else 0.15,
                                        runs=2 if f else 3),
        "adaptive": lambda: bench_adaptive.run(scale=0.1 if f else 0.2,
                                               runs=3 if f else 5),
        "ops": lambda: bench_operators.run(fast=f),
    }
    selected = suites if args.suite == "all" else {args.suite: suites[args.suite]}
    report: Dict[str, object] = {}
    for name, fn in selected.items():
        t0 = time.time()
        out = fn()
        print(out)
        print(f"# suite {name} finished in {time.time() - t0:.1f}s\n", flush=True)
        report[name] = _parse_rows(out)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"# wrote {args.json}")
    if args.trace_out:
        telemetry_smoke(args.trace_out, fast=f)


if __name__ == "__main__":
    main()
