"""Benchmark harness — one suite per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--json out.json]

Prints ``name,us_per_call,derived`` CSV blocks per suite:
  Fig 6a  LSQB CPU-bound joins           (bench_lsqb)
  Fig 6b  BSBM Explore OLTP              (bench_bsbm_explore)
  Fig 6c  BSBM Business Intelligence     (bench_bsbm_bi)
  List. 3 adaptive vs fixed batch size   (bench_adaptive)
  List. 1/5 operator microbenchmarks     (bench_operators)

With ``--json <path>`` the same per-suite ``us_per_call`` rows are written
as a JSON document (suite → [{name, us_per_call, derived}]) so perf
trajectories can be tracked across PRs (see BENCH_PR1.json).
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List


def _parse_rows(csv_block: str) -> List[Dict[str, object]]:
    """CSV block emitted by benchmarks.common.Suite → row dicts."""
    rows: List[Dict[str, object]] = []
    for line in csv_block.splitlines():
        if line.startswith("#") or line.startswith("name,") or not line.strip():
            continue
        name, us, derived = line.split(",", 2)
        rows.append({"name": name, "us_per_call": float(us), "derived": derived})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller scales")
    ap.add_argument("--suite", default="all",
                    choices=("all", "lsqb", "explore", "bi", "adaptive", "ops"))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-suite us_per_call results as JSON")
    args = ap.parse_args()
    f = args.fast

    from benchmarks import (
        bench_adaptive,
        bench_bsbm_bi,
        bench_bsbm_explore,
        bench_lsqb,
        bench_operators,
    )

    suites = {
        "lsqb": lambda: bench_lsqb.run(scale=0.03 if f else 0.05,
                                       runs=2 if f else 3),
        "explore": lambda: bench_bsbm_explore.run(scale=0.1 if f else 0.2,
                                                  runs=3 if f else 5),
        "bi": lambda: bench_bsbm_bi.run(scale=0.08 if f else 0.15,
                                        runs=2 if f else 3),
        "adaptive": lambda: bench_adaptive.run(scale=0.1 if f else 0.2,
                                               runs=3 if f else 5),
        "ops": lambda: bench_operators.run(fast=f),
    }
    selected = suites if args.suite == "all" else {args.suite: suites[args.suite]}
    report: Dict[str, object] = {}
    for name, fn in selected.items():
        t0 = time.time()
        out = fn()
        print(out)
        print(f"# suite {name} finished in {time.time() - t0:.1f}s\n", flush=True)
        report[name] = _parse_rows(out)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
