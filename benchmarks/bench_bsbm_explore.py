"""Fig. 6b — BSBM Explore (OLTP point lookups): BARQ vs legacy aQET.
This is the legacy engine's home turf; the paper's claim is *parity*
(mean/median reduction of only 3/5 ms), enabled by adaptive batch sizing."""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import Suite, time_query
from repro.data import BSBM_EXPLORE_TEMPLATES, generate_ecommerce_graph, instantiate_explore


def run(scale: float = 0.2, runs: int = 5, instances: int = 4) -> str:
    store, meta = generate_ecommerce_graph(scale=scale)
    rng = np.random.RandomState(11)
    suite = Suite(
        f"BSBM Explore (Fig 6b) scale={scale} triples={meta['n_triples']} aQET"
    )
    for name, tpl in BSBM_EXPLORE_TEMPLATES.items():
        bt, lt = [], []
        for _ in range(instances):
            q = instantiate_explore(tpl, meta, rng)
            bt.append(time_query(store, q, "barq", runs=runs)["mean_s"])
            lt.append(time_query(store, q, "legacy", runs=runs)["mean_s"])
        b, l = float(np.mean(bt)), float(np.mean(lt))
        suite.add(f"explore_{name}_barq", b * 1e6,
                  f"legacy_ratio={l / max(b, 1e-9):.2f}x")
        suite.add(f"explore_{name}_legacy", l * 1e6, "")
    return suite.emit()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--runs", type=int, default=5)
    a = ap.parse_args()
    print(run(a.scale, a.runs))
