"""Fig. 6c — BSBM Business Intelligence: analytical aggregation queries.
Paper: BARQ wins the mix by 9.1%, largest single-query gain ~41% (their Q3,
merge-join dominated — our b3/b4 are the analogues)."""

from __future__ import annotations

import argparse

from benchmarks.common import Suite, time_query
from repro.data import BSBM_BI_QUERIES, generate_ecommerce_graph


def run(scale: float = 0.15, runs: int = 3) -> str:
    store, meta = generate_ecommerce_graph(scale=scale)
    suite = Suite(
        f"BSBM BI (Fig 6c) scale={scale} triples={meta['n_triples']}"
    )
    total_b = total_l = 0.0
    for name, q in BSBM_BI_QUERIES.items():
        b = time_query(store, q, "barq", runs=runs)
        l = time_query(store, q, "legacy", runs=runs)
        total_b += b["mean_s"]
        total_l += l["mean_s"]
        suite.add(f"bi_{name}_barq", b["mean_s"] * 1e6,
                  f"rows={b['rows']};speedup={l['mean_s'] / max(b['mean_s'], 1e-9):.1f}x")
        suite.add(f"bi_{name}_legacy", l["mean_s"] * 1e6, "")
    suite.add("bi_total_barq", total_b * 1e6,
              f"mix_ratio={total_l / max(total_b, 1e-9):.2f}x (paper: 1.09x)")
    return suite.emit()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.15)
    ap.add_argument("--runs", type=int, default=3)
    a = ap.parse_args()
    print(run(a.scale, a.runs))
