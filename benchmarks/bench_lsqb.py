"""Fig. 6a — LSQB (CPU-bound joins): BARQ vs legacy per query + total
throughput ratio. The paper reports 3.4x total throughput, with the big
joins (Q6/Q9) ~83% faster; the per-tuple interpretation gap between
jitted-batch and Python-row execution makes the ratio larger here
(DESIGN.md §2 maps JVM virtual calls -> Python dispatch)."""

from __future__ import annotations

import argparse

from benchmarks.common import Suite, time_query
from repro.data import LSQB_QUERIES, generate_social_graph


def run(scale: float = 0.05, runs: int = 3, profile: bool = False) -> str:
    store, meta = generate_social_graph(scale=scale)
    suite = Suite(
        f"LSQB (Fig 6a) scale={scale} triples={meta['n_triples']} "
        f"barq vs legacy, {runs} runs"
    )
    total_barq = total_legacy = 0.0
    for name, q in LSQB_QUERIES.items():
        b = time_query(store, q, "barq", runs=runs)
        l = time_query(store, q, "legacy", runs=runs)
        total_barq += b["mean_s"]
        total_legacy += l["mean_s"]
        suite.add(
            f"lsqb_{name}_barq", b["mean_s"] * 1e6,
            f"rows={b['rows']};speedup_vs_legacy={l['mean_s'] / max(b['mean_s'], 1e-9):.1f}x",
        )
        suite.add(f"lsqb_{name}_legacy", l["mean_s"] * 1e6, f"rows={l['rows']}")
    suite.add(
        "lsqb_total_barq", total_barq * 1e6,
        f"throughput_ratio={total_legacy / max(total_barq, 1e-9):.2f}x (paper: 3.4x)",
    )

    # beyond-paper fused whole-BGP path on the motivating query (q6):
    # compile once, then measure the steady-state fused count
    import time

    from repro.core.fused import fused_q6_count

    fused_q6_count(store)  # compile + warm
    t0 = time.perf_counter()
    for _ in range(runs):
        n = fused_q6_count(store)
    dt = (time.perf_counter() - t0) / runs
    op_time = time_query(store, LSQB_QUERIES["q6"], "barq", runs=runs)["mean_s"]
    suite.add(
        "lsqb_q6_barq_fused", dt * 1e6,
        f"count={n};speedup_vs_operator_barq={op_time / max(dt, 1e-9):.1f}x",
    )
    if profile:
        from repro.core import Engine, EngineConfig

        e = Engine(store, EngineConfig(engine="barq"))
        r = e.execute(LSQB_QUERIES["q6"])
        print(r.profile())
    return suite.emit()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--runs", type=int, default=3)
    ap.add_argument("--profile", action="store_true")
    a = ap.parse_args()
    print(run(a.scale, a.runs, a.profile))
