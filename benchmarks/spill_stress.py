"""Low-memory stress gate (DESIGN.md §15) — CI's out-of-core smoke.

    PYTHONPATH=src python -m benchmarks.spill_stress [--json artifacts/spill_stress.json]

Three scenarios, each with exact parity against an unconstrained run and
hard assertions on the spill machinery itself:

  1. ``grace_join``: 200k x 200k unsorted join under a budget of 10% of
     the build bytes — spill counters must be non-zero and the spill dir
     must come back empty (take-frees-eagerly lifecycle).
  2. ``skew_recursion``: 80% of the build mass on one key — the top-level
     partition holding it blows the budget, so level-1 recursive
     re-partitioning MUST fire (``repartitions > 0``).
  3. ``engine_query``: an end-to-end engine run (join + GROUP BY +
     DISTINCT in one query) under ``EngineConfig.memory_budget`` small
     enough that the planner marks every blocking operator grace; row
     parity vs an unconstrained engine, EXPLAIN carries the grace marks,
     and the executor's try/finally teardown leaves no ``*.npy`` behind.

The per-scenario spill statistics are written as a JSON document for CI
to upload — the artifact is the evidence that the stress actually
stressed (a budget bump that silently stops spilling shows up as zeros
in the artifact even before an assertion notices).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import tempfile

import numpy as np


def _drain_rows(op):
    rows = []
    while True:
        b = op.next_batch()
        if b is None:
            break
        c = b.compact()
        rows.extend(map(tuple, c.to_rows_array().tolist()))
        c.release()
    return sorted(rows)


def _leaks(d):
    return glob.glob(os.path.join(d, "**", "*.npy"), recursive=True)


def stress_grace_join(n=200_000, seed=0) -> dict:
    from repro.core.batch import BatchPool
    from repro.core.operators.base import close_tree
    from repro.core.operators.hash_join import HashJoin
    from repro.core.operators.sort import MaterializedSource

    rng = np.random.RandomState(seed)
    l = np.stack([rng.permutation(n) % (n // 2),
                  rng.randint(0, 1000, n)]).astype(np.int32)
    r = np.stack([rng.permutation(n) % (n // 2),
                  rng.randint(0, 1000, n)]).astype(np.int32)

    def mk(budget, spill_dir):
        pool = BatchPool()
        return HashJoin(
            MaterializedSource((0, 1), l, None, 4096, pool=pool),
            MaterializedSource((0, 2), r, None, 4096, pool=pool),
            (0,), pool=pool,
            memory_budget=budget, spill_dir=spill_dir,
            grace=True if budget else None,
        )

    base = _drain_rows(mk(None, None))
    d = tempfile.mkdtemp(prefix="stress-grace-")
    try:
        j = mk(int(r.nbytes) // 10, d)
        assert _drain_rows(j) == base, "grace join parity broke under budget"
        extra = dict(j.stats.extra)
        close_tree(j)
        assert extra.get("spill_files", 0) > 0, extra
        assert extra.get("spill_bytes", 0) > 0, extra
        assert not _leaks(d), f"leaked: {_leaks(d)}"
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return {"rows": len(base), "budget_frac": 0.1, **{
        k: extra[k] for k in sorted(extra) if isinstance(extra[k], (int, float))
    }}


def stress_skew_recursion(n=120_000, seed=8) -> dict:
    from repro.core.operators.base import close_tree
    from repro.core.operators.hash_join import HashJoin
    from repro.core.operators.sort import MaterializedSource

    rng = np.random.RandomState(seed)
    lk = np.where(rng.rand(n) < 0.8, 7, rng.randint(0, 2000, n)).astype(np.int32)
    rk = np.where(rng.rand(n) < 0.8, 7, rng.randint(0, 2000, n)).astype(np.int32)
    l = np.stack([lk, rng.randint(0, 10, n)]).astype(np.int32)
    r = np.stack([rk, rng.randint(0, 10, n)]).astype(np.int32)

    def mk(budget, spill_dir):
        return HashJoin(
            MaterializedSource((0, 1), l, None, 4096),
            MaterializedSource((0, 2), r, None, 4096),
            (0,), "semi",
            memory_budget=budget, spill_dir=spill_dir,
            grace=True if budget else None,
        )

    base = _drain_rows(mk(None, None))
    d = tempfile.mkdtemp(prefix="stress-skew-")
    try:
        j = mk(int(r.nbytes) // 10, d)
        assert _drain_rows(j) == base, "skewed grace join parity broke"
        extra = dict(j.stats.extra)
        close_tree(j)
        assert extra.get("repartitions", 0) > 0, (
            f"skewed build never re-partitioned: {extra}")
        assert not _leaks(d), f"leaked: {_leaks(d)}"
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return {"rows": len(base), "skew": 0.8, **{
        k: extra[k] for k in sorted(extra) if isinstance(extra[k], (int, float))
    }}


_Q = ("SELECT ?x (COUNT(*) AS ?c) (SUM(?g) AS ?s) "
      "{ ?a :knows ?x . ?b :likes ?x . ?b :age ?g } GROUP BY ?x")


def stress_engine_query(n=30_000, seed=3) -> dict:
    from repro.core import Engine, EngineConfig, QuadStore
    from repro.core import profiler

    rng = np.random.RandomState(seed)
    store = QuadStore()
    for i in range(n):
        store.add(f":s{i:06d}", ":knows", f":o{rng.randint(0, 500):05d}")
    for i in range(n * 2 // 3):
        store.add(f":t{i:06d}", ":likes", f":o{rng.randint(0, 500):05d}")
        store.add(f":t{i:06d}", ":age", int(rng.randint(0, 100)))
    qs = store.build()

    base_eng = Engine(qs, EngineConfig(engine="barq", join_strategy="hash"))
    base = sorted(map(tuple, base_eng.execute(_Q).rows.tolist()))

    # ~n*4 bytes: well under every blocking operator's estimated footprint
    # at either scale, so the planner must mark them all grace
    budget = n * 4
    d = tempfile.mkdtemp(prefix="stress-engine-")
    try:
        eng = Engine(qs, EngineConfig(
            engine="barq", join_strategy="hash",
            memory_budget=budget, spill_dir=d,
        ))
        ex = eng.explain(_Q)
        assert "grace" in ex, f"no grace marks in plan:\n{ex}"
        res = eng.execute(_Q)
        assert sorted(map(tuple, res.rows.tolist())) == base, (
            "budgeted engine run lost parity")
        stats = profiler.collect_stats(res.root)
        assert stats.get("spill_files", 0) > 0, stats
        assert not _leaks(d), f"leaked: {_leaks(d)}"
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return {
        "rows": len(base),
        "memory_budget": budget,
        "spill_bytes": int(stats.get("spill_bytes", 0)),
        "spill_files": int(stats.get("spill_files", 0)),
        "grace_partitions": int(stats.get("grace_partitions", 0)),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-scenario spill statistics as JSON")
    ap.add_argument("--fast", action="store_true", help="smaller sizes")
    args = ap.parse_args()
    f = args.fast
    report = {}
    for name, fn in (
        ("grace_join", lambda: stress_grace_join(n=40_000 if f else 200_000)),
        ("skew_recursion",
         lambda: stress_skew_recursion(n=40_000 if f else 120_000)),
        ("engine_query", lambda: stress_engine_query(n=8_000 if f else 30_000)),
    ):
        report[name] = fn()
        print(f"# {name}: {json.dumps(report[name])}")
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"# wrote {args.json}")
    print("# spill stress passed: all scenarios spilled, re-partitioned "
          "where forced, and left no files behind")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
