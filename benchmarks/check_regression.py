"""Cross-PR benchmark regression gate (ISSUE-6 satellite).

Compares the committed BENCH_PR<N>.json of the current PR against the most
recent prior BENCH_PR*.json that reports the same metric, and fails if any
shared metric regressed by more than the threshold (default 1.15x on
us_per_call, lower is better).

Benchmark workloads legitimately change between PRs (sizes, key counts), so
two rows are only comparable when their workload signature matches: the
size-describing tokens inside the ``derived`` field (tuples_out=, rows=,
groups=, pairs=, selected=, n=). Rows whose signature changed are reported
as skipped, not compared — a gate that screams every time a workload is
retuned trains people to ignore it.

Prior-PR numbers were recorded on whatever machine state that PR's author
had; wall-clock drifts across boxes and across months. When the current
file's ``before`` section carries a row with the same (suite, name) and the
same workload signature, that row is a *paired* baseline — the pre-PR code
re-measured on the same machine in the same session — and it supersedes the
prior-PR file for that metric (reported as "vs <file> (paired before)").
A paired baseline cannot hide a real regression: it is the same workload on
the same box, just without the PR's diff applied.

Beyond the cross-PR ratio check, rows that self-report a relative cost in
their ``derived`` field (tokens named ``overhead*`` with a ``%`` value —
the §13 telemetry-tracing and §14 feedback-recording benches) are gated
against an absolute cap (default 5%): observability that taxes the hot
path more than that is a regression even if it is "new" this PR and has
no prior row to compare against.

Usage:
    python -m benchmarks.check_regression            # newest BENCH_PR*.json
    python -m benchmarks.check_regression --current BENCH_PR6.json
    python -m benchmarks.check_regression --threshold 1.15 --overhead-cap 5
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import Dict, Optional, Tuple

# derived-field tokens that describe workload size; if any of these differ
# between two rows of the same name, the rows measure different work
_SIG_TOKENS = ("tuples_out", "rows", "groups", "pairs", "selected", "n")

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _pr_number(path: str) -> int:
    m = re.search(r"BENCH_PR(\d+)\.json$", path)
    return int(m.group(1)) if m else -1


def _workload_sig(derived: str) -> Tuple[Tuple[str, str], ...]:
    sig = []
    for tok in str(derived).split(";"):
        if "=" not in tok:
            continue
        k, v = tok.split("=", 1)
        if k.strip() in _SIG_TOKENS:
            sig.append((k.strip(), v.strip()))
    return tuple(sorted(sig))


def _section_rows(path: str, section: str) -> Dict[Tuple[str, str], dict]:
    """(suite, metric_name) -> row, from one section of a bench file."""
    with open(path) as f:
        data = json.load(f)
    rows: Dict[Tuple[str, str], dict] = {}
    for suite, entries in data.get(section, {}).items():
        for row in entries:
            rows[(suite, row["name"])] = row
    return rows


def _after_rows(path: str) -> Dict[Tuple[str, str], dict]:
    return _section_rows(path, "after")


def _overhead_tokens(derived: str) -> Dict[str, float]:
    """``overhead*=X%`` tokens from a derived field — self-reported
    relative costs the absolute cap applies to."""
    out: Dict[str, float] = {}
    for tok in str(derived).split(";"):
        if "=" not in tok:
            continue
        k, v = tok.split("=", 1)
        k, v = k.strip(), v.strip()
        if k.startswith("overhead") and v.endswith("%"):
            try:
                out[k] = float(v[:-1])
            except ValueError:
                continue
    return out


def check(
    current_path: str, threshold: float = 1.15, root: str = REPO_ROOT,
    overhead_cap: float = 5.0,
) -> int:
    """Returns the number of regressions (0 = gate passes)."""
    current_pr = _pr_number(current_path)
    priors = sorted(
        (
            p
            for p in glob.glob(os.path.join(root, "BENCH_PR*.json"))
            if 0 <= _pr_number(p) < current_pr
        ),
        key=_pr_number,
        reverse=True,
    )
    current = _after_rows(current_path)
    if not current:
        print(f"error: no 'after' rows in {current_path}")
        return 1

    # most recent prior value per metric
    baseline: Dict[Tuple[str, str], Tuple[dict, str]] = {}
    for p in priors:
        for key, row in _after_rows(p).items():
            baseline.setdefault(key, (row, os.path.basename(p)))

    # paired same-machine baselines from the current file's 'before'
    # section take precedence over older files (matching signature only)
    cur_name = os.path.basename(current_path)
    for key, brow in _section_rows(current_path, "before").items():
        crow = current.get(key)
        if crow is not None and _workload_sig(
            brow.get("derived", "")
        ) == _workload_sig(crow.get("derived", "")):
            baseline[key] = (brow, f"{cur_name} (paired before)")

    regressions, compared, skipped = 0, 0, 0
    for key, row in sorted(current.items()):
        prior = baseline.get(key)
        if prior is None:
            continue  # new metric this PR: nothing to compare against
        prow, psrc = prior
        if _workload_sig(row.get("derived", "")) != _workload_sig(
            prow.get("derived", "")
        ):
            skipped += 1
            print(f"skip  {key[0]}/{key[1]}: workload changed vs {psrc}")
            continue
        cur, old = float(row["us_per_call"]), float(prow["us_per_call"])
        ratio = cur / max(old, 1e-9)
        compared += 1
        tag = "REGRESSION" if ratio > threshold else "ok"
        print(
            f"{tag:>10}  {key[0]}/{key[1]}: {old:.1f} -> {cur:.1f} us "
            f"({ratio:.2f}x vs {psrc})"
        )
        if ratio > threshold:
            regressions += 1

    # absolute cap on self-reported overhead percentages (no prior needed)
    overhead_checked = 0
    for key, row in sorted(current.items()):
        for tok, pct in _overhead_tokens(row.get("derived", "")).items():
            overhead_checked += 1
            over = pct > overhead_cap
            tag = "REGRESSION" if over else "ok"
            print(
                f"{tag:>10}  {key[0]}/{key[1]}: {tok}={pct:.1f}% "
                f"(cap {overhead_cap:.1f}%)"
            )
            if over:
                regressions += 1

    print(
        f"\n{compared} compared, {skipped} skipped (workload changed), "
        f"{overhead_checked} overhead token(s) capped at {overhead_cap:.1f}%, "
        f"{regressions} regression(s) beyond {threshold:.2f}x"
    )
    return regressions


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--current",
        default=None,
        help="bench file for this PR (default: highest-numbered BENCH_PR*.json)",
    )
    ap.add_argument("--threshold", type=float, default=1.15)
    ap.add_argument("--overhead-cap", type=float, default=5.0,
                    help="absolute cap (%%) on overhead*= derived tokens")
    args = ap.parse_args(argv)

    current = args.current
    if current is None:
        candidates = sorted(
            glob.glob(os.path.join(REPO_ROOT, "BENCH_PR*.json")), key=_pr_number
        )
        if not candidates:
            print("error: no BENCH_PR*.json files found")
            return 1
        current = candidates[-1]
    elif not os.path.isabs(current):
        current = os.path.join(REPO_ROOT, current)
    print(f"current: {os.path.basename(current)}")
    return 1 if check(current, args.threshold,
                      overhead_cap=args.overhead_cap) else 0


if __name__ == "__main__":
    sys.exit(main())
