PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test smoke bench regression stress lint

# tier-1 gate: full test suite + the operator microbenchmark suite as an
# allocation/perf smoke test (see DESIGN.md §6) + the cross-PR benchmark
# regression check over the committed BENCH_PR*.json files (DESIGN.md §12)
# + barqlint over the merged tree (DESIGN.md §16)
check: lint test smoke regression

# barqlint (DESIGN.md §16): AST static analysis of pool ownership, kernel
# registry, OpStats and dtype discipline. Exit 1 on any finding; whole
# run stays under 10 seconds (asserted by tests/test_analysis.py).
lint:
	$(PYTHON) -m repro.analysis.lint src benchmarks examples tests

test:
	$(PYTHON) -m pytest -q

# the smoke also runs the telemetry end-to-end (EXPLAIN ANALYZE on an
# LSQB query + Chrome-trace/metrics JSON export, plus the §14 workload
# surface: format-validated OpenMetrics exposition, workload-repository
# JSONL round-trip, feedback-loop convergence, and an induced flight
# capture under artifacts/flight/) and leaves the artifacts under
# artifacts/ for CI to upload
smoke:
	mkdir -p artifacts
	$(PYTHON) -m benchmarks.run --fast --suite ops \
	  --json artifacts/bench_ops.json --trace-out artifacts/lsqb_q6.trace.json

# static gate: newest committed BENCH_PR*.json vs the most recent prior
# file reporting the same metric on the same workload; fails beyond 1.15x.
# A paired pre-PR baseline in the current file's 'before' section (same
# row, same box/session) supersedes the prior-PR number for that metric.
# Also caps self-reported overhead*=X% derived tokens (telemetry tracing,
# feedback recording) at 5% absolute
regression:
	$(PYTHON) -m benchmarks.check_regression

bench:
	$(PYTHON) -m benchmarks.run --json bench_results.json

# low-memory stress gate (DESIGN.md §15): grace join under 10% of build
# bytes, a skewed build that must recursively re-partition, and an
# end-to-end engine query under EngineConfig.memory_budget — parity,
# spill counters > 0, and empty-spill-dir lifecycle asserted; the
# per-scenario spill statistics land in artifacts/ for CI to upload
stress:
	mkdir -p artifacts
	$(PYTHON) -m benchmarks.spill_stress --json artifacts/spill_stress.json
