PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test smoke bench

# tier-1 gate: full test suite + the operator microbenchmark suite as an
# allocation/perf smoke test (see DESIGN.md §6)
check: test smoke

test:
	$(PYTHON) -m pytest -q

smoke:
	$(PYTHON) -m benchmarks.run --fast --suite ops

bench:
	$(PYTHON) -m benchmarks.run --json bench_results.json
