"""LSQB-like social network generator + query set (paper §5, Fig. 6a).

LSQB [Mhedhbi et al., GRADES-NDA'21] measures join throughput on subgraph
counting queries over an LDBC-style social network, deliberately without
selective constants. We generate the same *shape* of data at configurable
scale: Person-knows-Person (heavy-tailed degree), Person-hasInterest-Tag,
Person-isLocatedIn-City, Person-studyAt-University, plus Comment/Post
replyOf edges for the larger queries. Queries Q1–Q9 mirror the LSQB
pattern structure (2-hop, stars, triangles, anti-joins); Q6 and Q9 are the
paper's motivating examples.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.storage import QuadStore


def _powerlaw_targets(rng, n: int, count: int, alpha: float = 1.6) -> np.ndarray:
    """Sample ``count`` targets in [0, n) with a heavy-tailed preference."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    return rng.choice(n, size=count, p=probs)


def generate_social_graph(
    scale: float = 0.1, seed: int = 42
) -> Tuple[QuadStore, Dict[str, int]]:
    """scale 0.1 ~ 60K triples; 0.3 ~ 200K; 1.0 ~ 700K (laptop-sized
    LSQB analogue; the paper's SF 0.3 has 7.3M — same shape, smaller N)."""
    rng = np.random.RandomState(seed)
    n_person = max(int(3000 * scale), 50)
    n_tag = max(int(300 * scale), 20)
    n_city = max(int(60 * scale), 10)
    n_univ = max(int(30 * scale), 5)
    n_msg = max(int(2000 * scale), 50)

    store = QuadStore()
    d = store.dict

    # pre-encode entity terms (bulk, vectorized loading path)
    person_ids = np.asarray([d.encode(f":person{i}") for i in range(n_person)], np.int32)
    tag_ids = np.asarray([d.encode(f":tag{i}") for i in range(n_tag)], np.int32)
    city_ids = np.asarray([d.encode(f":city{i}") for i in range(n_city)], np.int32)
    univ_ids = np.asarray([d.encode(f":univ{i}") for i in range(n_univ)], np.int32)
    msg_ids = np.asarray([d.encode(f":msg{i}") for i in range(n_msg)], np.int32)
    p_knows = d.encode(":knows")
    p_interest = d.encode(":hasInterest")
    p_located = d.encode(":isLocatedIn")
    p_study = d.encode(":studyAt")
    p_reply = d.encode(":replyOf")
    p_creator = d.encode(":hasCreator")
    p_type = d.encode("rdf:type")
    c_person = d.encode(":Person")
    c_msg = d.encode(":Message")
    g = d.encode(":default")

    quads = []

    # knows: ~avg degree 18, heavy-tailed, deduped, no self-loops
    n_knows = n_person * 18
    src = rng.randint(0, n_person, n_knows)
    dst = _powerlaw_targets(rng, n_person, n_knows)
    ok = src != dst
    knows = np.unique(np.stack([src[ok], dst[ok]], axis=1), axis=0)
    quads.append(
        np.stack(
            [
                person_ids[knows[:, 0]],
                np.full(len(knows), p_knows, np.int32),
                person_ids[knows[:, 1]],
                np.full(len(knows), g, np.int32),
            ],
            axis=1,
        )
    )

    # interests: ~4 per person, skewed tags
    n_int = n_person * 4
    ps = rng.randint(0, n_person, n_int)
    ts = _powerlaw_targets(rng, n_tag, n_int)
    ints = np.unique(np.stack([ps, ts], axis=1), axis=0)
    quads.append(
        np.stack(
            [
                person_ids[ints[:, 0]],
                np.full(len(ints), p_interest, np.int32),
                tag_ids[ints[:, 1]],
                np.full(len(ints), g, np.int32),
            ],
            axis=1,
        )
    )

    # city / university / types
    cities = rng.randint(0, n_city, n_person)
    quads.append(
        np.stack(
            [
                person_ids,
                np.full(n_person, p_located, np.int32),
                city_ids[cities],
                np.full(n_person, g, np.int32),
            ],
            axis=1,
        )
    )
    study_mask = rng.rand(n_person) < 0.6
    sp = person_ids[study_mask]
    quads.append(
        np.stack(
            [
                sp,
                np.full(len(sp), p_study, np.int32),
                univ_ids[rng.randint(0, n_univ, len(sp))],
                np.full(len(sp), g, np.int32),
            ],
            axis=1,
        )
    )
    quads.append(
        np.stack(
            [
                person_ids,
                np.full(n_person, p_type, np.int32),
                np.full(n_person, c_person, np.int32),
                np.full(n_person, g, np.int32),
            ],
            axis=1,
        )
    )

    # messages: creator + reply chains
    creators = rng.randint(0, n_person, n_msg)
    quads.append(
        np.stack(
            [
                msg_ids,
                np.full(n_msg, p_creator, np.int32),
                person_ids[creators],
                np.full(n_msg, g, np.int32),
            ],
            axis=1,
        )
    )
    reply_to = rng.randint(0, n_msg, n_msg)
    ok = reply_to < np.arange(n_msg)  # DAG
    rm = msg_ids[ok]
    quads.append(
        np.stack(
            [
                rm,
                np.full(len(rm), p_reply, np.int32),
                msg_ids[reply_to[ok]],
                np.full(len(rm), g, np.int32),
            ],
            axis=1,
        )
    )
    quads.append(
        np.stack(
            [
                msg_ids,
                np.full(n_msg, p_type, np.int32),
                np.full(n_msg, c_msg, np.int32),
                np.full(n_msg, g, np.int32),
            ],
            axis=1,
        )
    )

    store.add_encoded(np.concatenate(quads, axis=0))
    store.build()
    meta = dict(
        n_person=n_person,
        n_tag=n_tag,
        n_knows=len(knows),
        n_triples=store.n_quads,
    )
    return store, meta


# LSQB-analogue queries. Q6/Q9 are the paper's motivating examples
# (Figure 1 / Listing 1 / Listing 5).
LSQB_QUERIES: Dict[str, str] = {
    # Q1: 1-hop neighbourhood with interests (simple star)
    "q1": """
        SELECT (COUNT(*) AS ?count) {
          ?p1 :knows ?p2 .
          ?p2 :hasInterest ?tag .
        }
    """,
    # Q2: co-location pairs
    "q2": """
        SELECT (COUNT(*) AS ?count) {
          ?p1 :isLocatedIn ?city .
          ?p2 :isLocatedIn ?city .
          FILTER (?p1 != ?p2)
        }
    """,
    # Q3: triangles with interest restriction
    "q3": """
        SELECT (COUNT(*) AS ?count) {
          ?p1 :knows ?p2 .
          ?p2 :knows ?p3 .
          ?p3 :knows ?p1 .
          ?p1 :hasInterest ?tag .
        }
    """,
    # Q4: message reply chains to creators
    "q4": """
        SELECT (COUNT(*) AS ?count) {
          ?m1 :replyOf ?m2 .
          ?m2 :hasCreator ?p .
          ?p :hasInterest ?tag .
        }
    """,
    # Q5: 2-hop with university co-study
    "q5": """
        SELECT (COUNT(*) AS ?count) {
          ?p1 :studyAt ?u .
          ?p2 :studyAt ?u .
          ?p1 :knows ?p2 .
        }
    """,
    # Q6: the paper's motivating example (Figure 1): directed 2-hop paths
    # with interest tags, excluding trivial cycles
    "q6": """
        SELECT (COUNT(*) AS ?count) {
          ?person1 :knows ?person2 .
          ?person2 :knows ?person3 .
          ?person3 :hasInterest ?tag .
          FILTER (?person1 != ?person3)
        }
    """,
    # Q7: optional interests over 2-hop (left join load)
    "q7": """
        SELECT (COUNT(*) AS ?count) {
          ?p1 :knows ?p2 .
          OPTIONAL { ?p2 :hasInterest ?tag }
        }
    """,
    # Q8: co-interest without acquaintance (anti-join)
    "q8": """
        SELECT (COUNT(*) AS ?count) {
          ?p1 :hasInterest ?t .
          ?p2 :hasInterest ?t .
          FILTER (?p1 != ?p2)
          MINUS { ?p1 :knows ?p2 }
        }
    """,
    # Q9: Q6 plus FILTER NOT EXISTS triangle elimination (paper §5.2:
    # 'Q9 just adds a FILTER NOT EXISTS condition'; Stardog evaluates it
    # with the MINUS anti-join)
    "q9": """
        SELECT (COUNT(*) AS ?count) {
          ?person1 :knows ?person2 .
          ?person2 :knows ?person3 .
          ?person3 :hasInterest ?tag .
          FILTER (?person1 != ?person3)
          MINUS { ?person3 :knows ?person1 }
        }
    """,
}
