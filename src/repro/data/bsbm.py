"""BSBM-like e-commerce generator + Explore/BI query sets (paper §5,
Fig. 6b/6c).

The Berlin SPARQL Benchmark [Bizer & Schultz '09] models an e-commerce
scenario: Products with types/features/producers, Offers from Vendors,
Reviews from Persons. The Explore use case is OLTP-style template queries
with selective constants (the overfetching stress test of §3.4 — the
example query of that section is reproduced as template E2); the BI use
case aggregates over larger slices.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.storage import QuadStore


def generate_ecommerce_graph(
    scale: float = 0.1, seed: int = 7
) -> Tuple[QuadStore, Dict[str, int]]:
    """scale 0.1 ~ 90K triples, 1.0 ~ 900K. Shape mirrors BSBM: ~20
    products per type, ~18 features per product, ~8 offers, ~2 reviews."""
    rng = np.random.RandomState(seed)
    n_product = max(int(4000 * scale), 100)
    n_type = max(n_product // 20, 5)
    n_feature = max(int(800 * scale), 40)
    n_producer = max(n_product // 40, 5)
    n_vendor = max(int(40 * scale), 5)
    n_person = max(int(300 * scale), 20)
    n_offer = n_product * 8
    n_review = n_product * 2

    store = QuadStore()
    d = store.dict
    P = lambda name: d.encode(name)  # noqa: E731

    product_ids = np.asarray([P(f":product{i}") for i in range(n_product)], np.int32)
    type_ids = np.asarray([P(f":ProductType{i}") for i in range(n_type)], np.int32)
    feat_ids = np.asarray([P(f":feature{i}") for i in range(n_feature)], np.int32)
    producer_ids = np.asarray([P(f":producer{i}") for i in range(n_producer)], np.int32)
    vendor_ids = np.asarray([P(f":vendor{i}") for i in range(n_vendor)], np.int32)
    person_ids = np.asarray([P(f":reviewer{i}") for i in range(n_person)], np.int32)
    offer_ids = np.asarray([P(f":offer{i}") for i in range(n_offer)], np.int32)
    review_ids = np.asarray([P(f":review{i}") for i in range(n_review)], np.int32)
    price_ids = np.asarray([P(int(p)) for p in range(1, 2001)], np.int32)
    rating_ids = np.asarray([P(int(r)) for r in range(1, 11)], np.int32)

    p_type = P("rdf:type")
    p_feature = P(":productFeature")
    p_producer = P(":producer")
    p_offer_product = P(":product")
    p_vendor = P(":vendor")
    p_price = P(":price")
    p_review_product = P(":reviewFor")
    p_reviewer = P(":reviewer")
    p_rating = P(":rating")
    g = P(":default")

    def col(x, n):
        return np.full(n, x, np.int32)

    quads = []
    # product -> type (skewed type popularity)
    types = rng.randint(0, n_type, n_product)
    quads.append(np.stack([product_ids, col(p_type, n_product), type_ids[types], col(g, n_product)], 1))
    # product -> features (~18)
    nf = n_product * 18
    pf_p = rng.randint(0, n_product, nf)
    pf_f = rng.randint(0, n_feature, nf)
    pf = np.unique(np.stack([pf_p, pf_f], 1), axis=0)
    quads.append(np.stack([product_ids[pf[:, 0]], col(p_feature, len(pf)), feat_ids[pf[:, 1]], col(g, len(pf))], 1))
    # product -> producer
    prod = rng.randint(0, n_producer, n_product)
    quads.append(np.stack([product_ids, col(p_producer, n_product), producer_ids[prod], col(g, n_product)], 1))
    # offers
    op = rng.randint(0, n_product, n_offer)
    quads.append(np.stack([offer_ids, col(p_offer_product, n_offer), product_ids[op], col(g, n_offer)], 1))
    ov = rng.randint(0, n_vendor, n_offer)
    quads.append(np.stack([offer_ids, col(p_vendor, n_offer), vendor_ids[ov], col(g, n_offer)], 1))
    oprice = rng.randint(0, 2000, n_offer)
    quads.append(np.stack([offer_ids, col(p_price, n_offer), price_ids[oprice], col(g, n_offer)], 1))
    # reviews
    rp = rng.randint(0, n_product, n_review)
    quads.append(np.stack([review_ids, col(p_review_product, n_review), product_ids[rp], col(g, n_review)], 1))
    rr = rng.randint(0, n_person, n_review)
    quads.append(np.stack([review_ids, col(p_reviewer, n_review), person_ids[rr], col(g, n_review)], 1))
    rrat = rng.randint(0, 10, n_review)
    quads.append(np.stack([review_ids, col(p_rating, n_review), rating_ids[rrat], col(g, n_review)], 1))

    store.add_encoded(np.concatenate(quads, axis=0))
    store.build()
    meta = dict(
        n_product=n_product,
        n_type=n_type,
        n_offer=n_offer,
        n_triples=store.n_quads,
    )
    return store, meta


# -- Explore use case: selective templates with a %TYPE%/%PRODUCT% placeholder
# (instantiated with random constants per run, like the BSBM driver) --------

BSBM_EXPLORE_TEMPLATES: Dict[str, str] = {
    # E1: products of a type with a given feature (BSBM Q1 analogue)
    "e1": """
        SELECT ?product {
          ?product rdf:type %TYPE% .
          ?product :productFeature ?feature .
          FILTER (?feature = %FEATURE%)
        } LIMIT 10
    """,
    # E2: the overfetching example of paper §3.4, verbatim shape
    "e2": """
        SELECT * {
          ?product rdf:type %TYPE% .
          ?product :productFeature ?feature .
          ?product :producer ?producer .
          ?offer :product ?product .
        }
    """,
    # E3: product detail point lookup (BSBM Q2 analogue)
    "e3": """
        SELECT ?feature ?producer {
          %PRODUCT% :productFeature ?feature .
          %PRODUCT% :producer ?producer .
        }
    """,
    # E4: offers for one product below a price (BSBM Q8 analogue)
    "e4": """
        SELECT ?offer ?price {
          ?offer :product %PRODUCT% .
          ?offer :price ?price .
          FILTER (?price < 500)
        }
    """,
    # E5: reviews for one product with ratings (BSBM Q7 analogue)
    "e5": """
        SELECT ?review ?rating ?reviewer {
          ?review :reviewFor %PRODUCT% .
          ?review :rating ?rating .
          ?review :reviewer ?reviewer .
        }
    """,
}


def instantiate_explore(template: str, meta: Dict[str, int], rng) -> str:
    q = template
    if "%TYPE%" in q:
        q = q.replace("%TYPE%", f":ProductType{rng.randint(meta['n_type'])}")
    if "%FEATURE%" in q:
        q = q.replace("%FEATURE%", ":feature0")
    if "%PRODUCT%" in q:
        q = q.replace("%PRODUCT%", f":product{rng.randint(meta['n_product'])}")
    return q


# -- BI use case: analytical aggregations (no selective constants) ------------

BSBM_BI_QUERIES: Dict[str, str] = {
    # B1: offer count + avg price per vendor
    "b1": """
        SELECT ?vendor (COUNT(*) AS ?offers) (AVG(?price) AS ?avgPrice) {
          ?offer :vendor ?vendor .
          ?offer :price ?price .
        } GROUP BY ?vendor
    """,
    # B2: products per type ordered by count (paper BI Q3 analogue: join-heavy)
    "b2": """
        SELECT ?type (COUNT(*) AS ?n) {
          ?product rdf:type ?type .
          ?product :productFeature ?feature .
        } GROUP BY ?type ORDER BY DESC(?n) LIMIT 10
    """,
    # B3: avg rating per producer (3-way join + aggregation)
    "b3": """
        SELECT ?producer (AVG(?rating) AS ?avg) {
          ?review :reviewFor ?product .
          ?review :rating ?rating .
          ?product :producer ?producer .
        } GROUP BY ?producer
    """,
    # B4: reviewers per vendor via shared products (amplifying join chain)
    "b4": """
        SELECT ?vendor (COUNT(DISTINCT ?reviewer) AS ?reviewers) {
          ?offer :vendor ?vendor .
          ?offer :product ?product .
          ?review :reviewFor ?product .
          ?review :reviewer ?reviewer .
        } GROUP BY ?vendor
    """,
    # B5: price stats per product type
    "b5": """
        SELECT ?type (MIN(?price) AS ?lo) (MAX(?price) AS ?hi) {
          ?product rdf:type ?type .
          ?offer :product ?product .
          ?offer :price ?price .
        } GROUP BY ?type
    """,
    # B6: feature co-occurrence volume (CPU-bound self join)
    "b6": """
        SELECT (COUNT(*) AS ?n) {
          ?p1 :productFeature ?f .
          ?p2 :productFeature ?f .
          FILTER (?p1 != ?p2)
        }
    """,
    # B7: high-rated products per vendor
    "b7": """
        SELECT ?vendor (COUNT(*) AS ?n) {
          ?offer :vendor ?vendor .
          ?offer :product ?product .
          ?review :reviewFor ?product .
          ?review :rating ?rating .
          FILTER (?rating >= 8)
        } GROUP BY ?vendor
    """,
    # B8: producers with no reviews (anti-join aggregate)
    "b8": """
        SELECT (COUNT(DISTINCT ?product) AS ?n) {
          ?product :producer ?producer .
          MINUS { ?review :reviewFor ?product }
        }
    """,
}
