from repro.data.lsqb import LSQB_QUERIES, generate_social_graph  # noqa: F401
from repro.data.bsbm import (  # noqa: F401
    BSBM_BI_QUERIES,
    BSBM_EXPLORE_TEMPLATES,
    generate_ecommerce_graph,
    instantiate_explore,
)
