"""Batched SPARQL query serving — the end-to-end driver for the paper's
kind of system (a query engine serves queries; examples/serve_queries.py).

Requests are (query_text, arrival_time); the server executes them through
a shared Engine with per-request latency accounting and a reusable plan
cache keyed by the query template. The adaptive batch sizer inside the
engine is the paper's §3.4 mechanism; this layer adds the serving loop,
workload mix, and percentile reporting the evaluation section uses.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import Engine, EngineConfig, QuadStore
from repro.core import algebra as A
from repro.core import planner as PL


@dataclasses.dataclass
class RequestResult:
    query_id: str
    n_rows: int
    latency_s: float


class QueryServer:
    def __init__(self, store: QuadStore, cfg: Optional[EngineConfig] = None):
        self.store = store
        self.engine = Engine(store, cfg or EngineConfig())
        self._plan_cache: Dict[str, Tuple[PL.Phys, A.VarTable]] = {}

    def _plan_for(self, text: str) -> Tuple[PL.Phys, A.VarTable]:
        # cache key is a hash of the query text itself — the caller's
        # query_id is a reporting label only, so two different queries
        # sharing an id can never silently reuse the wrong cached plan.
        # The engine's plan fingerprint (join strategy, SIP mode, …) is
        # folded in too: swapping the engine config must not serve a plan
        # shaped under the old knobs.
        key = hashlib.sha256(
            f"{self.engine.plan_fingerprint()}\n{text}".encode()
        ).hexdigest()
        hit = self._plan_cache.get(key)
        if hit is None:
            node, vt = self.engine.parse(text)
            hit = (self.engine.plan(node), vt)
            self._plan_cache[key] = hit
        return hit

    def execute(self, key: str, text: str) -> RequestResult:
        t0 = time.perf_counter()
        phys, vt = self._plan_for(text)
        res = self.engine.execute_plan(phys, vt)
        return RequestResult(key, res.n_rows, time.perf_counter() - t0)

    def run_workload(
        self, requests: List[Tuple[str, str]], warmup: int = 0
    ) -> Dict[str, float]:
        for key, text in requests[:warmup]:
            self.execute(key, text)
        results = [self.execute(k, t) for k, t in requests[warmup:]]
        lats = np.asarray([r.latency_s for r in results])
        return {
            "n_requests": len(results),
            "total_rows": int(sum(r.n_rows for r in results)),
            "qps": len(results) / max(lats.sum(), 1e-9),
            "mean_ms": float(lats.mean() * 1e3),
            "p50_ms": float(np.percentile(lats, 50) * 1e3),
            "p99_ms": float(np.percentile(lats, 99) * 1e3),
        }
