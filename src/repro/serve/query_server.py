"""Batched SPARQL query serving — the end-to-end driver for the paper's
kind of system (a query engine serves queries; examples/serve_queries.py).

Requests are (query_text, arrival_time); the server executes them through
a shared Engine with per-request latency accounting and a reusable plan
cache keyed by the query template. The adaptive batch sizer inside the
engine is the paper's §3.4 mechanism; this layer adds the serving loop,
workload mix, and percentile reporting the evaluation section uses.

Every request runs inside its own QueryTrace (DESIGN.md §13), so kernel
dispatches and pool counters are attributed to exactly one request even
though all requests share one Engine (and its warm buffer arena). The
per-request ledgers and pool deltas aggregate into ``self.metrics`` — a
``MetricsRegistry`` with sliding-window percentiles, QPS, plan-cache
hit/miss, and JSON/OpenMetrics export.

PR 8 threads workload history through the same path (DESIGN.md §14):
each request is attributed to its canonical template fingerprint and
recorded in a ``WorkloadRepository`` (latency/row histograms, kernel
rollups, per-plan-node observed cardinalities, regression detection),
and an optional ``FlightRecorder`` captures trace + EXPLAIN ANALYZE
bundles for outlier requests. The engine shares the repository's
``CardinalityFeedback`` store, so under
``EngineConfig.cardinality_feedback="apply"`` a repeated query re-plans
with the cardinalities its previous runs actually observed.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import Engine, EngineConfig, QuadStore
from repro.core import algebra as A
from repro.core import planner as PL
from repro.core import profiler
from repro.core import telemetry
from repro.serve.flight_recorder import FlightRecorder
from repro.serve.metrics import MetricsRegistry
from repro.serve.workload_repo import WorkloadRepository


@dataclasses.dataclass
class RequestResult:
    query_id: str
    n_rows: int
    latency_s: float
    # per-request attribution (None/empty when engine telemetry is off)
    trace: Optional[telemetry.QueryTrace] = None
    kernel_dispatches: int = 0
    kernel_wall_s: float = 0.0
    pool_delta: Dict[str, int] = dataclasses.field(default_factory=dict)
    plan_cache_hit: bool = False
    # workload-history attribution (DESIGN.md §14)
    fingerprint: str = ""
    max_q_error: float = 0.0
    regression: Optional[dict] = None
    flight_bundle: Optional[str] = None


class QueryServer:
    def __init__(
        self,
        store: QuadStore,
        cfg: Optional[EngineConfig] = None,
        workload: Optional[WorkloadRepository] = None,
        flight: Optional[FlightRecorder] = None,
    ):
        self.store = store
        self.workload = workload if workload is not None else WorkloadRepository()
        # the engine records per-plan-node actual cardinalities into the
        # repository's feedback store; whether the planner *reads* them
        # back is the engine's cardinality_feedback knob
        self.engine = Engine(store, cfg or EngineConfig(),
                             feedback=self.workload.feedback)
        self.flight = flight
        self._plan_cache: Dict[str, Tuple[PL.Phys, A.VarTable, str]] = {}
        self.metrics = MetricsRegistry()

    def _plan_for(self, text: str) -> Tuple[PL.Phys, A.VarTable, str]:
        # cache key is a hash of the query text itself — the caller's
        # query_id is a reporting label only, so two different queries
        # sharing an id can never silently reuse the wrong cached plan.
        # The engine's plan fingerprint (join strategy, SIP mode, …) is
        # folded in too: swapping the engine config must not serve a plan
        # shaped under the old knobs, and under feedback=apply it advances
        # with the feedback store's version so new observations re-plan.
        key = hashlib.sha256(
            f"{self.engine.plan_fingerprint()}\n{text}".encode()
        ).hexdigest()
        hit = self._plan_cache.get(key)
        self.metrics.observe_plan_cache(hit is not None)
        if hit is None:
            node, vt = self.engine.parse(text)
            hit = (self.engine.plan(node), vt, telemetry.query_fingerprint(node))
            self._plan_cache[key] = hit
        return hit

    def execute(self, key: str, text: str) -> RequestResult:
        t0 = time.perf_counter()
        misses_before = self.metrics.plan_cache_misses
        phys, vt, qfp = self._plan_for(text)
        res = self.engine.execute_plan(phys, vt)
        latency = time.perf_counter() - t0
        tr = res.trace
        pool_delta = res.pool_delta()
        stats = profiler.collect_stats(res.root)
        self.metrics.observe_request(
            latency,
            n_rows=res.n_rows,
            ledger=tr.ledger if tr is not None else None,
            pool_delta=pool_delta,
            spill_bytes=int(stats.get("spill_bytes", 0)),
            spill_files=int(stats.get("spill_files", 0)),
            adaptive_switches=int(stats.get("adaptive_switches", 0)),
        )
        max_q = float(stats.get("max_q_error", 0.0))
        obs = self.workload.observe(
            qfp,
            latency,
            rows=res.n_rows,
            ledger=tr.ledger if tr is not None else None,
            max_q_error=max_q,
            query_text=text,
        )
        bundle = None
        if self.flight is not None:
            bundle = self.flight.observe(
                qfp,
                latency,
                baseline_p99_s=obs["baseline_p99_s"],
                max_q_error=max_q,
                trace=tr,
                # rendered only if a trigger fires — EXPLAIN ANALYZE over
                # the already-executed tree costs a walk, not a re-run
                explain_fn=res.explain_analyze,
                query_text=text,
            )
        return RequestResult(
            key,
            res.n_rows,
            latency,
            trace=tr,
            kernel_dispatches=tr.ledger.total() if tr is not None else 0,
            kernel_wall_s=tr.ledger.total_wall_s() if tr is not None else 0.0,
            pool_delta=pool_delta,
            plan_cache_hit=self.metrics.plan_cache_misses == misses_before,
            fingerprint=qfp,
            max_q_error=max_q,
            regression=obs["regression"],
            flight_bundle=bundle,
        )

    def explain_analyze(self, text: str) -> str:
        """EXPLAIN ANALYZE through the server's plan cache (counts as a
        cache touch but not as a served request in the latency window)."""
        phys, vt, _qfp = self._plan_for(text)
        return self.engine.execute_plan(phys, vt).explain_analyze()

    def metrics_snapshot(self, window_s: float = 60.0) -> dict:
        snap = self.metrics.snapshot(window_s)
        snap["workload"] = self.workload.snapshot()
        # regressions at top level too: dashboards alert on this key
        snap["regressions"] = list(self.workload.regressions)
        if self.flight is not None:
            snap["flight"] = self.flight.snapshot()
        return snap

    def metrics_json(self, indent: Optional[int] = 2,
                     window_s: float = 60.0) -> str:
        import json

        return json.dumps(self.metrics_snapshot(window_s), indent=indent)

    def openmetrics(self, window_s: float = 60.0, top_n: int = 20) -> str:
        """OpenMetrics text exposition of the registry plus per-fingerprint
        workload series (scrape endpoint body)."""
        return self.metrics.to_openmetrics(
            workload=self.workload, window_s=window_s, top_n=top_n
        )

    def run_workload(
        self, requests: List[Tuple[str, str]], warmup: int = 0
    ) -> Dict[str, float]:
        for key, text in requests[:warmup]:
            self.execute(key, text)
        results = [self.execute(k, t) for k, t in requests[warmup:]]
        lats = np.asarray([r.latency_s for r in results])
        return {
            "n_requests": len(results),
            "total_rows": int(sum(r.n_rows for r in results)),
            "qps": len(results) / max(lats.sum(), 1e-9),
            "mean_ms": float(lats.mean() * 1e3),
            "p50_ms": float(np.percentile(lats, 50) * 1e3),
            "p99_ms": float(np.percentile(lats, 99) * 1e3),
            "kernel_dispatches": int(sum(r.kernel_dispatches for r in results)),
            "kernel_wall_ms": float(
                sum(r.kernel_wall_s for r in results) * 1e3
            ),
            "plan_cache_hit_rate": float(
                sum(r.plan_cache_hit for r in results) / max(len(results), 1)
            ),
        }
