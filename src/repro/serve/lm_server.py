"""Continuous-batching LM decode service (the adaptive-batching tie-in of
DESIGN.md §3: the engine's §3.4 controller reused for serving admission).

A fixed pool of batch slots runs the jitted decode step; finished requests
free slots; queued requests are admitted between steps. The admission
batch size is driven by an AdaptiveBatchSizer observing the service's
recent occupancy pattern the same way a BARQ scan observes its consumer:
bursts of arrivals grow the admission quantum, droughts shrink it (keeping
admission work — prefill — small when the pool is latency-bound).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive import AdaptiveBatchSizer
from repro.models import transformer as TF
from repro.parallel.sharding import MeshAxes


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (len,) int32
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class LMServer:
    def __init__(self, cfg: TF.TransformerConfig, params, n_slots: int = 8,
                 cache_len: int = 256, seed: int = 0):
        self.cfg = dataclasses.replace(cfg, remat="none")
        self.params = params
        self.axes = MeshAxes()
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.cache = TF.init_cache(self.cfg, n_slots, cache_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, dtype=np.int32)
        self.queue: List[Request] = []
        self.sizer = AdaptiveBatchSizer(initial=2, min_size=1,
                                        max_size=n_slots)
        self._decode = jax.jit(
            lambda p, c, t, pos: TF.decode_step(p, self.cfg, self.axes, c, t, pos)
        )
        self.steps = 0

    # -- client API -------------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run_until_drained(self, max_steps: int = 10000) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        while (self.queue or any(r is not None for r in self.slot_req)):
            self.step(out)
            if self.steps > max_steps:
                raise RuntimeError("serving did not drain")
        return out

    # -- engine ------------------------------------------------------------------

    def _admit(self) -> None:
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        if not free or not self.queue:
            if not self.queue:
                self.sizer.on_skip()  # drought: shrink the admission quantum
            return
        quantum = self.sizer.on_next()
        for slot in free[:quantum]:
            if not self.queue:
                break
            req = self.queue.pop(0)
            self.slot_req[slot] = req
            # per-slot prefill through the shared decode step; the final
            # feed's logits produce the first generated token
            logits = None
            for t, tok in enumerate(req.prompt.tolist()):
                logits = self._step_one_slot(slot, tok, t)
            self.slot_pos[slot] = len(req.prompt)
            req.generated.append(int(jnp.argmax(logits[slot, 0])))

    def _step_one_slot(self, slot: int, token: int, pos: int):
        toks = np.zeros((self.n_slots, 1), np.int32)
        # non-target rows write to the reserved dump slot: pos = -1 maps to
        # cache index cache_len-1 (never used by live positions, see
        # _retire's cache_len-1 bound) and stores pos=-1 = invalid
        poss = np.full((self.n_slots, 1), -1, np.int32)
        toks[slot, 0] = token
        poss[slot, 0] = pos
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(poss)
        )
        return logits

    def step(self, out: Dict[int, List[int]]) -> None:
        self._admit()
        self._retire(out)  # admission may already satisfy max_new == 1
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        poss = np.full((self.n_slots, 1), -1, np.int32)  # inactive -> dump slot
        for i in active:
            req = self.slot_req[i]
            toks[i, 0] = req.generated[-1]
            poss[i, 0] = self.slot_pos[i]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(poss)
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        for i in active:
            req = self.slot_req[i]
            req.generated.append(int(nxt[i]))
            self.slot_pos[i] += 1
        self._retire(out)
        self.steps += 1

    def _retire(self, out: Dict[int, List[int]]) -> None:
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if len(req.generated) >= req.max_new or self.slot_pos[i] >= self.cache_len - 1:
                req.done = True
                out[req.rid] = req.generated[: req.max_new]
                self.slot_req[i] = None
                self.slot_pos[i] = 0
                # invalidate the slot's cache so the next tenant cannot
                # attend to stale keys
                self.cache["pos"] = self.cache["pos"].at[:, i, :].set(-1)
