"""Flight recorder: always-on trace ring + trigger-on-outlier capture
(DESIGN.md §14).

Tracing every query is cheap enough to leave on (the scoped
``QueryTrace`` already rides along with each served request), but
*keeping* every trace is not. The flight recorder holds the last
``ring_size`` traces in memory and writes a full diagnostic bundle to
disk only when a request looks anomalous:

* **latency trigger** — the request took more than ``latency_factor`` ×
  the p99 the WorkloadRepository has established for this fingerprint
  (no baseline yet → no latency trigger; a cold template's first slow
  run is not an outlier, it's the baseline forming);
* **q-error trigger** — EXPLAIN ANALYZE's worst plan-node q-error is at
  or above ``q_error_threshold``, i.e. the planner was catastrophically
  wrong about cardinalities regardless of how fast the query happened
  to run.

A capture bundle is a directory under ``out_dir`` holding the Chrome
trace (``trace.json``, open in Perfetto), the EXPLAIN ANALYZE report
(``explain.txt``, rendered lazily — the callable only runs when a
trigger actually fires), and ``meta.json`` with the trigger reason and
the numbers behind it. Disk usage is bounded by ``max_captures``; after
that the recorder keeps ringing in memory but stops writing.
"""

from __future__ import annotations

import collections
import json
import os
import time
from typing import Callable, Deque, Optional

from repro.core.telemetry import QueryTrace


class FlightRecorder:
    def __init__(
        self,
        out_dir: str = "artifacts/flight",
        ring_size: int = 32,
        latency_factor: float = 3.0,
        q_error_threshold: float = 16.0,
        max_captures: int = 16,
    ) -> None:
        assert latency_factor > 1.0 and q_error_threshold > 1.0
        self.out_dir = out_dir
        self.latency_factor = latency_factor
        self.q_error_threshold = q_error_threshold
        self.max_captures = max_captures
        self.ring: Deque[dict] = collections.deque(maxlen=ring_size)
        self.n_captures = 0
        self.n_observed = 0
        self._seq = 0

    def observe(
        self,
        fingerprint: str,
        latency_s: float,
        baseline_p99_s: float = 0.0,
        max_q_error: Optional[float] = None,
        trace: Optional[QueryTrace] = None,
        explain_fn: Optional[Callable[[], str]] = None,
        query_text: str = "",
        ts: Optional[float] = None,
    ) -> Optional[str]:
        """Ring the request; capture a bundle if a trigger fires. Returns
        the bundle directory path when a capture was written, else None."""
        ts = time.time() if ts is None else ts
        self.n_observed += 1
        reasons = []
        if baseline_p99_s > 0.0 and latency_s > self.latency_factor * baseline_p99_s:
            reasons.append("latency")
        if max_q_error is not None and max_q_error >= self.q_error_threshold:
            reasons.append("q_error")
        entry = {
            "fingerprint": fingerprint,
            "latency_s": round(float(latency_s), 6),
            "baseline_p99_s": round(float(baseline_p99_s), 6),
            "max_q_error": None if max_q_error is None else round(max_q_error, 2),
            "reasons": reasons,
            "ts": ts,
            "trace": trace,
        }
        self.ring.append(entry)
        if not reasons or self.n_captures >= self.max_captures:
            return None
        return self._capture(entry, explain_fn, query_text)

    def _capture(
        self,
        entry: dict,
        explain_fn: Optional[Callable[[], str]],
        query_text: str,
    ) -> str:
        self._seq += 1
        name = "{:.0f}_{}_{}_{:03d}".format(
            entry["ts"],
            entry["fingerprint"][:8] or "anon",
            "-".join(entry["reasons"]),
            self._seq,
        )
        bundle = os.path.join(self.out_dir, name)
        os.makedirs(bundle, exist_ok=True)
        trace = entry["trace"]
        if trace is not None:
            trace.save_chrome_trace(os.path.join(bundle, "trace.json"))
        if explain_fn is not None:
            try:
                explain = explain_fn()
            except Exception as e:  # a broken explain must not kill the request
                explain = f"<explain failed: {e}>"
            with open(os.path.join(bundle, "explain.txt"), "w") as f:
                f.write(explain if explain.endswith("\n") else explain + "\n")
        meta = {k: v for k, v in entry.items() if k != "trace"}
        meta["query"] = query_text[:2000]
        meta["thresholds"] = {
            "latency_factor": self.latency_factor,
            "q_error_threshold": self.q_error_threshold,
        }
        with open(os.path.join(bundle, "meta.json"), "w") as f:
            json.dump(meta, f, indent=2)
        self.n_captures += 1
        return bundle

    def snapshot(self) -> dict:
        return {
            "observed": self.n_observed,
            "captures": self.n_captures,
            "ring": [
                {k: v for k, v in e.items() if k != "trace"} for e in self.ring
            ],
        }
