"""Serving metrics registry (DESIGN.md §13).

Production serving needs aggregate observability on top of per-query
traces: latency percentiles over a sliding window, throughput, plan-cache
effectiveness, and where kernel time went across the whole request mix.
``MetricsRegistry`` is that aggregation point — ``QueryServer`` feeds it
one observation per request (latency, rows, the request's scoped
``KernelLedger``, and its pool-counter delta) and exports the whole thing
as JSON for dashboards / the benchmark reports.

Only stdlib is imported (collections, json, time) plus the telemetry
module — percentiles are computed by interpolation over a sorted copy of
the window, so this stays importable anywhere.
"""

from __future__ import annotations

import collections
import json
import time
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.telemetry import KernelLedger


def _percentile(sorted_vals: List[float], p: float) -> float:
    """Linear-interpolated percentile of an already-sorted list (matches
    numpy.percentile's default method; no numpy dependency here)."""
    if not sorted_vals:
        return 0.0
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    rank = (p / 100.0) * (len(sorted_vals) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = rank - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


class SlidingWindow:
    """Bounded window of (timestamp, value) observations.

    Percentiles are over the last ``maxlen`` observations; rates (QPS) are
    over the observations that fall inside the trailing ``window_s``
    seconds, so an idle server's QPS decays to zero instead of reporting
    its lifetime average."""

    def __init__(self, maxlen: int = 1024) -> None:
        self._obs: Deque[Tuple[float, float]] = collections.deque(maxlen=maxlen)

    def add(self, value: float, ts: Optional[float] = None) -> None:
        self._obs.append((time.monotonic() if ts is None else ts, value))

    def __len__(self) -> int:
        return len(self._obs)

    def values(self) -> List[float]:
        return [v for _t, v in self._obs]

    def percentile(self, p: float) -> float:
        return _percentile(sorted(self.values()), p)

    def mean(self) -> float:
        vals = self.values()
        return sum(vals) / len(vals) if vals else 0.0

    def rate(self, window_s: float = 60.0, now: Optional[float] = None) -> float:
        """Observations per second over the trailing ``window_s``."""
        if not self._obs:
            return 0.0
        now = time.monotonic() if now is None else now
        cutoff = now - window_s
        n = sum(1 for t, _v in self._obs if t >= cutoff)
        if n == 0:
            return 0.0
        span = max(now - max(self._obs[0][0], cutoff), 1e-9)
        return n / span


class MetricsRegistry:
    """Server-lifetime aggregation of per-request telemetry."""

    def __init__(self, window: int = 1024) -> None:
        self.latencies = SlidingWindow(window)
        self.n_requests = 0
        self.n_rows = 0
        self.n_errors = 0
        self.plan_cache_hits = 0
        self.plan_cache_misses = 0
        # cumulative kernel attribution across all observed requests
        self.kernels = KernelLedger()
        # summed per-request pool deltas (allocations, reuses, ...)
        self.pool: collections.Counter = collections.Counter()
        self.started = time.monotonic()

    # -- feeding ------------------------------------------------------------

    def observe_plan_cache(self, hit: bool) -> None:
        if hit:
            self.plan_cache_hits += 1
        else:
            self.plan_cache_misses += 1

    def observe_request(
        self,
        latency_s: float,
        n_rows: int = 0,
        ledger: Optional[KernelLedger] = None,
        pool_delta: Optional[Dict[str, int]] = None,
        error: bool = False,
        ts: Optional[float] = None,
    ) -> None:
        self.n_requests += 1
        self.n_rows += int(n_rows)
        if error:
            self.n_errors += 1
        self.latencies.add(float(latency_s), ts=ts)
        if ledger is not None:
            self.kernels.merge(ledger)
        if pool_delta:
            self.pool.update(pool_delta)

    # -- reading ------------------------------------------------------------

    def qps(self, window_s: float = 60.0) -> float:
        return self.latencies.rate(window_s)

    def plan_cache_hit_rate(self) -> float:
        n = self.plan_cache_hits + self.plan_cache_misses
        return self.plan_cache_hits / n if n else 0.0

    def snapshot(self, window_s: float = 60.0) -> dict:
        """JSON-able registry state: request/latency stats over the sliding
        window, plan-cache effectiveness, kernel and pool attribution."""
        return {
            "uptime_s": round(time.monotonic() - self.started, 3),
            "requests": {
                "count": self.n_requests,
                "rows": self.n_rows,
                "errors": self.n_errors,
                "qps": round(self.qps(window_s), 3),
                "mean_ms": round(self.latencies.mean() * 1e3, 4),
                "p50_ms": round(self.latencies.percentile(50) * 1e3, 4),
                "p99_ms": round(self.latencies.percentile(99) * 1e3, 4),
            },
            "plan_cache": {
                "hits": self.plan_cache_hits,
                "misses": self.plan_cache_misses,
                "hit_rate": round(self.plan_cache_hit_rate(), 4),
            },
            "kernels": self.kernels.snapshot(),
            "pool": dict(self.pool),
        }

    def to_json(self, indent: Optional[int] = None, window_s: float = 60.0) -> str:
        return json.dumps(self.snapshot(window_s), indent=indent)

    def save(self, path: str, window_s: float = 60.0) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2, window_s=window_s))
