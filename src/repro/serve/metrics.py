"""Serving metrics registry (DESIGN.md §13).

Production serving needs aggregate observability on top of per-query
traces: latency percentiles over a sliding window, throughput, plan-cache
effectiveness, and where kernel time went across the whole request mix.
``MetricsRegistry`` is that aggregation point — ``QueryServer`` feeds it
one observation per request (latency, rows, the request's scoped
``KernelLedger``, and its pool-counter delta) and exports the whole thing
as JSON for dashboards / the benchmark reports.

PR 8 adds the exporter surface (DESIGN.md §14): a fixed-bucket latency
histogram on the registry, an OpenMetrics/Prometheus text exposition
(``to_openmetrics``) covering the registry plus an optional
WorkloadRepository's per-fingerprint gauges, and ``validate_openmetrics``
— a strict format checker the benchmark smoke runs over every emitted
exposition (TYPE-before-samples, suffix rules per metric type, cumulative
histogram buckets with +Inf, terminating ``# EOF``).

Only stdlib is imported (collections, json, re, time) plus the telemetry
module — percentiles are computed by interpolation over a sorted copy of
the window, so this stays importable anywhere.
"""

from __future__ import annotations

import collections
import json
import re
import time
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.telemetry import KernelLedger


def _percentile(sorted_vals: List[float], p: float) -> float:
    """Linear-interpolated percentile of an already-sorted list (matches
    numpy.percentile's default method; no numpy dependency here). Empty
    input returns 0.0; ``p`` is clamped into [0, 100] so a caller typo
    can never index out of range."""
    if not sorted_vals:
        return 0.0
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    p = min(max(p, 0.0), 100.0)
    rank = (p / 100.0) * (len(sorted_vals) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = rank - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


class SlidingWindow:
    """Bounded window of (timestamp, value) observations.

    Percentiles are over the last ``maxlen`` observations; rates (QPS) are
    over the observations that fall inside the trailing ``window_s``
    seconds, so an idle server's QPS decays to zero instead of reporting
    its lifetime average."""

    def __init__(self, maxlen: int = 1024) -> None:
        self._obs: Deque[Tuple[float, float]] = collections.deque(maxlen=maxlen)

    def add(self, value: float, ts: Optional[float] = None) -> None:
        self._obs.append((time.monotonic() if ts is None else ts, value))

    def __len__(self) -> int:
        return len(self._obs)

    def values(self) -> List[float]:
        return [v for _t, v in self._obs]

    def percentile(self, p: float) -> float:
        return _percentile(sorted(self.values()), p)

    def mean(self) -> float:
        vals = self.values()
        return sum(vals) / len(vals) if vals else 0.0

    def rate(self, window_s: float = 60.0, now: Optional[float] = None) -> float:
        """Observations per second over the trailing ``window_s``. A window
        holding zero or one observation reports 0.0 — a single sample
        spans no time, and dividing by its epsilon-age would report an
        absurd ~1e9/s rate on the first request."""
        if len(self._obs) < 2:
            return 0.0
        now = time.monotonic() if now is None else now
        cutoff = now - window_s
        n = sum(1 for t, _v in self._obs if t >= cutoff)
        if n < 2:
            return 0.0
        span = max(now - max(self._obs[0][0], cutoff), 1e-9)
        return n / span


class LatencyHistogram:
    """Fixed-bound cumulative histogram (Prometheus ``le`` semantics).

    The sliding window above answers "p99 right now"; this answers "the
    lifetime latency distribution" in a form scrape-based systems can
    aggregate across servers. Bounds are log-spaced seconds chosen for
    sub-millisecond-to-multi-second query engines."""

    DEFAULT_BOUNDS = (
        0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
        0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
    )

    def __init__(self, bounds: Optional[Tuple[float, ...]] = None) -> None:
        self.bounds: Tuple[float, ...] = tuple(bounds or self.DEFAULT_BOUNDS)
        # per-bucket (non-cumulative) counts; +Inf bucket is the last slot
        self._counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.sum += float(value)
        self.count += 1
        for i, b in enumerate(self.bounds):
            if value <= b:
                self._counts[i] += 1
                return
        self._counts[-1] += 1

    def cumulative(self) -> List[Tuple[str, int]]:
        """[(le_label, cumulative_count), ...] ending with ("+Inf", count)."""
        out: List[Tuple[str, int]] = []
        acc = 0
        for b, c in zip(self.bounds, self._counts):
            acc += c
            out.append((format(b, "g"), acc))
        out.append(("+Inf", self.count))
        return out

    def snapshot(self) -> dict:
        return {
            "buckets": {le: c for le, c in self.cumulative()},
            "sum": round(self.sum, 6),
            "count": self.count,
        }

    def merge_snapshot(self, snap: dict) -> None:
        """Accumulate a persisted snapshot with identical bounds (report
        tooling merges saved registries; cumulative counts de-cumulate
        first)."""
        prev = 0
        buckets = snap.get("buckets", {})
        for i, b in enumerate(self.bounds):
            cum = int(buckets.get(format(b, "g"), prev))
            self._counts[i] += cum - prev
            prev = cum
        self._counts[-1] += int(buckets.get("+Inf", prev)) - prev
        self.sum += float(snap.get("sum", 0.0))
        self.count += int(snap.get("count", 0))


class MetricsRegistry:
    """Server-lifetime aggregation of per-request telemetry."""

    def __init__(self, window: int = 1024) -> None:
        self.latencies = SlidingWindow(window)
        self.latency_hist = LatencyHistogram()
        self.n_requests = 0
        self.n_rows = 0
        self.n_errors = 0
        self.plan_cache_hits = 0
        self.plan_cache_misses = 0
        # cumulative kernel attribution across all observed requests
        self.kernels = KernelLedger()
        # summed per-request pool deltas (allocations, reuses, ...)
        self.pool: collections.Counter = collections.Counter()
        # out-of-core / adaptive execution counters (DESIGN.md §15):
        # grace-join + partitioned-aggregate spill volume and mid-plan
        # strategy switches, summed across requests
        self.spill_bytes = 0
        self.spill_files = 0
        self.adaptive_switches = 0
        self.started = time.monotonic()

    # -- feeding ------------------------------------------------------------

    def observe_plan_cache(self, hit: bool) -> None:
        if hit:
            self.plan_cache_hits += 1
        else:
            self.plan_cache_misses += 1

    def observe_request(
        self,
        latency_s: float,
        n_rows: int = 0,
        ledger: Optional[KernelLedger] = None,
        pool_delta: Optional[Dict[str, int]] = None,
        error: bool = False,
        ts: Optional[float] = None,
        spill_bytes: int = 0,
        spill_files: int = 0,
        adaptive_switches: int = 0,
    ) -> None:
        self.n_requests += 1
        self.n_rows += int(n_rows)
        if error:
            self.n_errors += 1
        self.latencies.add(float(latency_s), ts=ts)
        self.latency_hist.observe(float(latency_s))
        if ledger is not None:
            self.kernels.merge(ledger)
        if pool_delta:
            self.pool.update(pool_delta)
        self.spill_bytes += int(spill_bytes)
        self.spill_files += int(spill_files)
        self.adaptive_switches += int(adaptive_switches)

    # -- reading ------------------------------------------------------------

    def qps(self, window_s: float = 60.0) -> float:
        return self.latencies.rate(window_s)

    def plan_cache_hit_rate(self) -> float:
        n = self.plan_cache_hits + self.plan_cache_misses
        return self.plan_cache_hits / n if n else 0.0

    def snapshot(self, window_s: float = 60.0) -> dict:
        """JSON-able registry state: request/latency stats over the sliding
        window, plan-cache effectiveness, kernel and pool attribution."""
        return {
            "uptime_s": round(time.monotonic() - self.started, 3),
            "requests": {
                "count": self.n_requests,
                "rows": self.n_rows,
                "errors": self.n_errors,
                "qps": round(self.qps(window_s), 3),
                "mean_ms": round(self.latencies.mean() * 1e3, 4),
                "p50_ms": round(self.latencies.percentile(50) * 1e3, 4),
                "p99_ms": round(self.latencies.percentile(99) * 1e3, 4),
            },
            "plan_cache": {
                "hits": self.plan_cache_hits,
                "misses": self.plan_cache_misses,
                "hit_rate": round(self.plan_cache_hit_rate(), 4),
            },
            "kernels": self.kernels.snapshot(),
            "pool": dict(self.pool),
            "execution": {
                "spill_bytes": self.spill_bytes,
                "spill_files": self.spill_files,
                "adaptive_switches": self.adaptive_switches,
            },
            "latency_hist": self.latency_hist.snapshot(),
        }

    def to_json(self, indent: Optional[int] = None, window_s: float = 60.0) -> str:
        return json.dumps(self.snapshot(window_s), indent=indent)

    def save(self, path: str, window_s: float = 60.0) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2, window_s=window_s))

    # -- OpenMetrics exposition (DESIGN.md §14) -----------------------------

    def to_openmetrics(
        self,
        workload=None,
        window_s: float = 60.0,
        top_n: int = 20,
    ) -> str:
        """Render the registry (and optionally a WorkloadRepository) in
        OpenMetrics text format for scrape-based monitoring.

        Conventions followed (and enforced by :func:`validate_openmetrics`):
        counter families are declared without the ``_total`` suffix but
        every counter sample carries it; histograms expose cumulative
        ``_bucket{le=...}`` series ending at ``+Inf`` plus ``_sum`` and
        ``_count``; the exposition terminates with ``# EOF``. Per-fingerprint
        workload series are capped at ``top_n`` fingerprints by total wall
        time so label cardinality stays bounded no matter how diverse the
        workload is."""
        w = _OMWriter()
        w.gauge("barq_uptime_seconds", "Seconds since the metrics registry was created",
                [(None, time.monotonic() - self.started)])
        w.counter("barq_requests", "Requests observed",
                  [(None, self.n_requests)])
        w.counter("barq_request_errors", "Requests that raised",
                  [(None, self.n_errors)])
        w.counter("barq_result_rows", "Result rows returned across all requests",
                  [(None, self.n_rows)])
        w.gauge("barq_qps", "Requests per second over the trailing window",
                [(None, self.qps(window_s))])
        w.gauge(
            "barq_request_latency_quantile_seconds",
            "Sliding-window latency quantiles",
            [({"quantile": q}, self.latencies.percentile(float(q)) )
             for q in ("50", "90", "99")],
        )
        w.histogram(
            "barq_request_latency_seconds",
            "Request latency distribution (lifetime)",
            self.latency_hist,
        )
        w.counter(
            "barq_plan_cache_requests",
            "Plan-cache lookups by outcome",
            [({"result": "hit"}, self.plan_cache_hits),
             ({"result": "miss"}, self.plan_cache_misses)],
        )
        w.gauge("barq_plan_cache_hit_ratio", "Plan-cache hit rate",
                [(None, self.plan_cache_hit_rate())])
        kernel_counts = sorted(self.kernels.backend_counts.items())
        w.counter(
            "barq_kernel_dispatches",
            "Kernel dispatches by kernel and backend",
            [({"kernel": n, "backend": b}, c) for (n, b), c in kernel_counts],
        )
        w.counter(
            "barq_kernel_wall_seconds",
            "Inclusive kernel wall time by kernel and backend",
            [({"kernel": n, "backend": b}, v)
             for (n, b), v in sorted(self.kernels.backend_wall_s.items())],
        )
        w.counter(
            "barq_pool_events",
            "Batch-pool events (allocations, reuses, releases, bytes)",
            [({"event": k}, v) for k, v in sorted(self.pool.items())],
        )
        w.counter("barq_spill_bytes",
                  "Bytes spilled by grace joins and partitioned aggregates",
                  [(None, self.spill_bytes)])
        w.counter("barq_spill_files",
                  "Spill files written by out-of-core operators",
                  [(None, self.spill_files)])
        w.counter("barq_adaptive_switches",
                  "Mid-plan operator strategy switches (merge->hash, "
                  "resident->grace)",
                  [(None, self.adaptive_switches)])
        if workload is not None:
            top = workload.top_by_wall(top_n)
            w.counter(
                "barq_fingerprint_requests",
                "Requests per query fingerprint (top fingerprints by wall time)",
                [({"fingerprint": r["fingerprint"]}, r["n"]) for r in top],
            )
            w.counter(
                "barq_fingerprint_wall_seconds",
                "Total wall time per query fingerprint",
                [({"fingerprint": r["fingerprint"]}, r["wall_s"]) for r in top],
            )
            w.gauge(
                "barq_fingerprint_p99_seconds",
                "Recent p99 latency per query fingerprint",
                [({"fingerprint": r["fingerprint"]}, r["p99_s"]) for r in top],
            )
            w.gauge(
                "barq_fingerprint_max_q_error",
                "Worst plan-node cardinality q-error seen per fingerprint",
                [({"fingerprint": r["fingerprint"]}, r["max_q_error"]) for r in top],
            )
            w.gauge(
                "barq_latency_regressions",
                "Fingerprints currently flagged as latency regressions",
                [(None, len(workload.regressions))],
            )
            if workload.feedback is not None:
                w.gauge(
                    "barq_feedback_entries",
                    "Plan-node fingerprints with observed cardinalities",
                    [(None, len(workload.feedback.snapshot()))],
                )
        return w.render()


class _OMWriter:
    """Tiny OpenMetrics text-format serializer.

    One ``family(...)`` call per metric family keeps the TYPE/HELP header
    adjacent to its samples, which is exactly the ordering the format
    requires."""

    def __init__(self) -> None:
        self._lines: List[str] = []

    @staticmethod
    def _fmt_value(v) -> str:
        f = float(v)
        if f == int(f) and abs(f) < 1e15:
            return str(int(f))
        return repr(f)

    @staticmethod
    def _fmt_labels(labels: Optional[Dict[str, str]]) -> str:
        if not labels:
            return ""
        inner = ",".join(
            '{}="{}"'.format(
                k,
                str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n"),
            )
            for k, v in labels.items()
        )
        return "{" + inner + "}"

    def _family(self, name: str, mtype: str, help_text: str) -> None:
        self._lines.append(f"# TYPE {name} {mtype}")
        self._lines.append(f"# HELP {name} {help_text}")

    def gauge(self, name, help_text, samples) -> None:
        self._family(name, "gauge", help_text)
        for labels, v in samples:
            self._lines.append(f"{name}{self._fmt_labels(labels)} {self._fmt_value(v)}")

    def counter(self, name, help_text, samples) -> None:
        self._family(name, "counter", help_text)
        for labels, v in samples:
            self._lines.append(
                f"{name}_total{self._fmt_labels(labels)} {self._fmt_value(v)}"
            )

    def histogram(self, name, help_text, hist: LatencyHistogram) -> None:
        self._family(name, "histogram", help_text)
        for le, c in hist.cumulative():
            self._lines.append(
                f'{name}_bucket{{le="{le}"}} {c}'
            )
        self._lines.append(f"{name}_sum {self._fmt_value(hist.sum)}")
        self._lines.append(f"{name}_count {hist.count}")

    def render(self) -> str:
        return "\n".join(self._lines + ["# EOF"]) + "\n"


_OM_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_OM_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^{}]*\})?"
    r" (?P<value>[^ ]+)(?: (?P<ts>[0-9.eE+-]+))?$"
)
_OM_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def validate_openmetrics(text: str) -> List[str]:
    """Strict structural check of an OpenMetrics exposition; raises
    ``ValueError`` on the first violation and returns the list of family
    names on success.

    Checks: every sample's family is declared by a preceding ``# TYPE``
    line; counter samples use the ``_total`` suffix; histogram samples use
    only ``_bucket``/``_sum``/``_count`` with cumulative non-decreasing
    ``le`` buckets ending at ``+Inf`` whose final count equals ``_count``;
    sample values parse as floats; the exposition ends with exactly one
    ``# EOF`` line. The benchmark smoke runs this over every exposition the
    server emits so a format drift fails CI rather than a scrape."""
    lines = text.split("\n")
    if lines and lines[-1] == "":
        lines = lines[:-1]
    if not lines or lines[-1] != "# EOF":
        raise ValueError("exposition must terminate with '# EOF'")
    if "# EOF" in lines[:-1]:
        raise ValueError("'# EOF' must appear exactly once, at the end")
    types: Dict[str, str] = {}
    families: List[str] = []
    # per-histogram bucket state for cumulativity checks
    hist_buckets: Dict[str, List[Tuple[float, float]]] = {}
    hist_counts: Dict[str, float] = {}
    for lineno, line in enumerate(lines[:-1], 1):
        if not line:
            raise ValueError(f"line {lineno}: blank lines are not allowed")
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4:
                raise ValueError(f"line {lineno}: malformed TYPE line")
            _, _, name, mtype = parts
            if not _OM_NAME_RE.match(name):
                raise ValueError(f"line {lineno}: invalid metric name {name!r}")
            if mtype not in ("counter", "gauge", "histogram", "summary",
                            "info", "stateset", "unknown"):
                raise ValueError(f"line {lineno}: unknown metric type {mtype!r}")
            if name in types:
                raise ValueError(f"line {lineno}: duplicate TYPE for {name!r}")
            types[name] = mtype
            families.append(name)
            continue
        if line.startswith("# HELP "):
            continue
        if line.startswith("#"):
            raise ValueError(f"line {lineno}: unknown comment directive")
        m = _OM_SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample line {line!r}")
        sample = m.group("name")
        try:
            value = float(m.group("value"))
        except ValueError:
            raise ValueError(f"line {lineno}: non-numeric value {m.group('value')!r}")
        if m.group("labels"):
            body = m.group("labels")[1:-1]
            if body and _OM_LABEL_RE.sub("", body).strip(", ") != "":
                raise ValueError(f"line {lineno}: malformed labels {body!r}")
        # map the sample back to its family, honoring typed suffixes
        family = None
        for suffix in ("_total", "_bucket", "_sum", "_count", ""):
            base = sample[: len(sample) - len(suffix)] if suffix else sample
            if sample.endswith(suffix) and base in types:
                family = base
                break
        if family is None:
            raise ValueError(
                f"line {lineno}: sample {sample!r} has no preceding TYPE declaration"
            )
        mtype = types[family]
        suffix = sample[len(family):]
        if mtype == "counter":
            if suffix != "_total":
                raise ValueError(
                    f"line {lineno}: counter sample must use '_total' suffix"
                )
            if value < 0:
                raise ValueError(f"line {lineno}: counter value must be >= 0")
        elif mtype == "gauge":
            if suffix != "":
                raise ValueError(f"line {lineno}: gauge sample must not be suffixed")
        elif mtype == "histogram":
            if suffix == "_bucket":
                labels = dict(_OM_LABEL_RE.findall(m.group("labels") or ""))
                if "le" not in labels:
                    raise ValueError(
                        f"line {lineno}: histogram bucket missing 'le' label"
                    )
                le = float("inf") if labels["le"] == "+Inf" else float(labels["le"])
                buckets = hist_buckets.setdefault(family, [])
                if buckets and (le <= buckets[-1][0] or value < buckets[-1][1]):
                    raise ValueError(
                        f"line {lineno}: histogram buckets must be cumulative "
                        f"with increasing 'le'"
                    )
                buckets.append((le, value))
            elif suffix == "_count":
                hist_counts[family] = value
            elif suffix != "_sum":
                raise ValueError(
                    f"line {lineno}: histogram sample must be _bucket/_sum/_count"
                )
    for family, buckets in hist_buckets.items():
        if not buckets or buckets[-1][0] != float("inf"):
            raise ValueError(f"histogram {family!r} missing '+Inf' bucket")
        if family in hist_counts and buckets[-1][1] != hist_counts[family]:
            raise ValueError(
                f"histogram {family!r}: '+Inf' bucket != _count sample"
            )
    return families
