"""Workload-history repository (DESIGN.md §14).

The serving metrics registry answers "how is the server doing"; this
module answers "how is each *query shape* doing". Requests are grouped by
their canonical template fingerprint (``core.telemetry.query_fingerprint``
— literals, whitespace, and variable names normalized away), and per
fingerprint the repository accumulates latency/row histograms, kernel
rollups, worst-seen cardinality q-error, and a recent-latency window for
p99 baselines. Two consumers hang off that history:

* **Cardinality feedback** — the repository owns (or is handed) a
  ``CardinalityFeedback`` store; the engine records per-plan-node observed
  cardinalities into it and the planner reads them back under
  ``EngineConfig.cardinality_feedback="apply"``. Persisting the repository
  persists the feedback store too, so a restarted server re-plans with
  yesterday's observed cardinalities immediately.
* **Regression detection** — each observation is compared against the
  fingerprint's established p99; a latency excursion past
  ``regression_factor`` × baseline (with enough history to make the
  baseline meaningful) is recorded on ``repository.regressions`` and
  surfaced through ``QueryServer.metrics_snapshot()``.

Persistence is line-oriented JSON (one fingerprint per line plus a meta
header and a feedback-state line), so saves stream, loads merge, and a
truncated file loses only its tail. Everything here is stdlib-only.
"""

from __future__ import annotations

import collections
import json
import math
import os
import time
from typing import Deque, Dict, List, Optional

from repro.core.telemetry import CardinalityFeedback, KernelLedger
from repro.serve.metrics import _percentile

# recent-latency window per fingerprint: big enough for a stable p99,
# small enough that thousands of fingerprints stay cheap
_RECENT_WINDOW = 128
# a regression verdict needs at least this many prior samples — a p99 over
# three observations is noise, not a baseline
_MIN_BASELINE_SAMPLES = 16


def _log2_bucket(value: float, unit: float) -> int:
    """Sparse histogram bucket: floor(log2(value/unit)), clamped at 0.
    With unit=1e-6 a 370 µs latency lands in bucket 8 (256–512 µs)."""
    v = value / unit
    if v < 1.0:
        return 0
    return int(math.log2(v)) + 1


class FingerprintStats:
    """Accumulated history for one query template."""

    __slots__ = (
        "fingerprint", "n", "n_errors", "wall_s", "rows", "max_q_error",
        "latency_hist", "rows_hist", "kernel_counts", "kernel_wall_s",
        "recent", "first_seen", "last_seen", "example",
    )

    def __init__(self, fingerprint: str) -> None:
        self.fingerprint = fingerprint
        self.n = 0
        self.n_errors = 0
        self.wall_s = 0.0
        self.rows = 0
        self.max_q_error = 0.0
        # sparse log2 histograms: latency in µs, result rows in rows
        self.latency_hist: collections.Counter = collections.Counter()
        self.rows_hist: collections.Counter = collections.Counter()
        self.kernel_counts: collections.Counter = collections.Counter()
        self.kernel_wall_s: Dict[str, float] = collections.defaultdict(float)
        self.recent: Deque[float] = collections.deque(maxlen=_RECENT_WINDOW)
        self.first_seen = 0.0
        self.last_seen = 0.0
        self.example = ""

    def p99_s(self) -> float:
        return _percentile(sorted(self.recent), 99.0)

    def mean_s(self) -> float:
        return self.wall_s / self.n if self.n else 0.0

    def observe(
        self,
        latency_s: float,
        rows: int,
        ledger: Optional[KernelLedger] = None,
        max_q_error: Optional[float] = None,
        error: bool = False,
        ts: Optional[float] = None,
    ) -> None:
        ts = time.time() if ts is None else ts
        if not self.n:
            self.first_seen = ts
        self.last_seen = max(self.last_seen, ts)
        self.n += 1
        if error:
            self.n_errors += 1
        self.wall_s += float(latency_s)
        self.rows += int(rows)
        self.latency_hist[_log2_bucket(latency_s, 1e-6)] += 1
        self.rows_hist[_log2_bucket(float(max(rows, 0)), 1.0)] += 1
        if max_q_error is not None:
            self.max_q_error = max(self.max_q_error, float(max_q_error))
        if ledger is not None:
            self.kernel_counts.update(ledger.counts)
            for k, v in ledger.wall_s.items():
                self.kernel_wall_s[k] += v
        self.recent.append(float(latency_s))

    # -- persistence --------------------------------------------------------

    def to_record(self) -> dict:
        return {
            "fingerprint": self.fingerprint,
            "n": self.n,
            "n_errors": self.n_errors,
            "wall_s": round(self.wall_s, 6),
            "rows": self.rows,
            "max_q_error": round(self.max_q_error, 3),
            "latency_hist": {str(k): v for k, v in sorted(self.latency_hist.items())},
            "rows_hist": {str(k): v for k, v in sorted(self.rows_hist.items())},
            "kernel_counts": dict(self.kernel_counts),
            "kernel_wall_s": {k: round(v, 6) for k, v in self.kernel_wall_s.items()},
            "recent": [round(v, 6) for v in self.recent],
            "first_seen": self.first_seen,
            "last_seen": self.last_seen,
            "example": self.example,
        }

    def merge_record(self, rec: dict) -> None:
        """Fold a persisted record into this stats object (load-time merge:
        a live repository loading yesterday's file keeps today's counts)."""
        self.n += int(rec.get("n", 0))
        self.n_errors += int(rec.get("n_errors", 0))
        self.wall_s += float(rec.get("wall_s", 0.0))
        self.rows += int(rec.get("rows", 0))
        self.max_q_error = max(self.max_q_error, float(rec.get("max_q_error", 0.0)))
        for k, v in rec.get("latency_hist", {}).items():
            self.latency_hist[int(k)] += int(v)
        for k, v in rec.get("rows_hist", {}).items():
            self.rows_hist[int(k)] += int(v)
        self.kernel_counts.update(rec.get("kernel_counts", {}))
        for k, v in rec.get("kernel_wall_s", {}).items():
            self.kernel_wall_s[k] += float(v)
        # persisted recent samples are older than anything live: prepend
        loaded = [float(v) for v in rec.get("recent", [])]
        live = list(self.recent)
        self.recent.clear()
        self.recent.extend((loaded + live)[-_RECENT_WINDOW:])
        fs = float(rec.get("first_seen", 0.0))
        if fs and (not self.first_seen or fs < self.first_seen):
            self.first_seen = fs
        self.last_seen = max(self.last_seen, float(rec.get("last_seen", 0.0)))
        if not self.example:
            self.example = rec.get("example", "")


class WorkloadRepository:
    """Per-fingerprint workload history with bounded memory and JSONL
    persistence."""

    def __init__(
        self,
        max_fingerprints: int = 512,
        feedback: Optional[CardinalityFeedback] = None,
        regression_factor: float = 2.0,
        max_regressions: int = 64,
    ) -> None:
        assert regression_factor > 1.0
        self.max_fingerprints = max_fingerprints
        self.regression_factor = regression_factor
        self.feedback = feedback if feedback is not None else CardinalityFeedback()
        self._stats: Dict[str, FingerprintStats] = {}
        self.regressions: Deque[dict] = collections.deque(maxlen=max_regressions)
        self.n_evicted = 0

    def __len__(self) -> int:
        return len(self._stats)

    def get(self, fingerprint: str) -> Optional[FingerprintStats]:
        return self._stats.get(fingerprint)

    def _stats_for(self, fingerprint: str) -> FingerprintStats:
        st = self._stats.get(fingerprint)
        if st is None:
            if len(self._stats) >= self.max_fingerprints:
                # evict the least-recently-seen template; its history is the
                # least likely to be consulted again
                victim = min(self._stats.values(), key=lambda s: s.last_seen)
                del self._stats[victim.fingerprint]
                self.n_evicted += 1
            st = self._stats[fingerprint] = FingerprintStats(fingerprint)
        return st

    def observe(
        self,
        fingerprint: str,
        latency_s: float,
        rows: int = 0,
        ledger: Optional[KernelLedger] = None,
        max_q_error: Optional[float] = None,
        error: bool = False,
        query_text: str = "",
        ts: Optional[float] = None,
    ) -> dict:
        """Record one request; returns ``{"baseline_p99_s": ..,
        "regression": rec-or-None}`` so callers (flight recorder, server)
        can react without a second lookup. The baseline p99 is computed
        *before* this observation enters the window — an outlier must not
        raise the bar it is judged against."""
        st = self._stats_for(fingerprint)
        baseline_p99 = st.p99_s()
        established = st.n >= _MIN_BASELINE_SAMPLES and baseline_p99 > 0.0
        regression = None
        if established and latency_s > self.regression_factor * baseline_p99:
            regression = {
                "fingerprint": fingerprint,
                "latency_s": round(float(latency_s), 6),
                "baseline_p99_s": round(baseline_p99, 6),
                "factor": round(latency_s / baseline_p99, 2),
                "ts": time.time() if ts is None else ts,
            }
            self.regressions.append(regression)
        st.observe(latency_s, rows, ledger=ledger, max_q_error=max_q_error,
                   error=error, ts=ts)
        if query_text and not st.example:
            st.example = query_text[:500]
        return {"baseline_p99_s": baseline_p99, "regression": regression}

    # -- reading ------------------------------------------------------------

    def top_by_wall(self, n: int = 20) -> List[dict]:
        """Top fingerprints by total wall time — the exporter's and the
        report's shared ranking."""
        ranked = sorted(self._stats.values(), key=lambda s: -s.wall_s)[:n]
        return [
            {
                "fingerprint": s.fingerprint,
                "n": s.n,
                "wall_s": round(s.wall_s, 6),
                "rows": s.rows,
                "mean_s": round(s.mean_s(), 6),
                "p99_s": round(s.p99_s(), 6),
                "max_q_error": round(s.max_q_error, 2),
                "example": s.example,
            }
            for s in ranked
        ]

    def qerror_leaderboard(self, n: int = 20) -> List[dict]:
        ranked = sorted(
            (s for s in self._stats.values() if s.max_q_error > 0),
            key=lambda s: -s.max_q_error,
        )[:n]
        return [
            {
                "fingerprint": s.fingerprint,
                "max_q_error": round(s.max_q_error, 2),
                "n": s.n,
                "wall_s": round(s.wall_s, 6),
                "example": s.example,
            }
            for s in ranked
        ]

    def snapshot(self, top_n: int = 20) -> dict:
        return {
            "fingerprints": len(self._stats),
            "evicted": self.n_evicted,
            "feedback_entries": len(self.feedback.snapshot()),
            "top_by_wall": self.top_by_wall(top_n),
            "qerror_leaderboard": self.qerror_leaderboard(top_n),
            "regressions": list(self.regressions),
        }

    # -- persistence --------------------------------------------------------

    def save(self, path: str) -> int:
        """Write the repository as JSONL: a meta header, one line per
        fingerprint, one feedback-state line, recent regressions. Returns
        the number of fingerprint lines written."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        n = 0
        with open(path, "w") as f:
            f.write(json.dumps({
                "kind": "meta", "format": "barq-workload-v1",
                "saved_at": time.time(),
                "fingerprints": len(self._stats),
                "evicted": self.n_evicted,
            }) + "\n")
            for st in sorted(self._stats.values(), key=lambda s: -s.wall_s):
                f.write(json.dumps({"kind": "fingerprint", **st.to_record()}) + "\n")
                n += 1
            f.write(json.dumps({
                "kind": "feedback", "state": self.feedback.snapshot(),
            }) + "\n")
            for rec in self.regressions:
                f.write(json.dumps({"kind": "regression", **rec}) + "\n")
        return n

    def load(self, path: str) -> int:
        """Merge a saved repository into this one (count-weighted for the
        feedback store, additive for histograms/counters). Unknown line
        kinds are skipped so the format can grow. Returns the number of
        fingerprint records merged."""
        n = 0
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                kind = rec.get("kind")
                if kind == "fingerprint":
                    self._stats_for(rec["fingerprint"]).merge_record(rec)
                    n += 1
                elif kind == "feedback":
                    self.feedback.merge(rec.get("state", {}))
                elif kind == "regression":
                    self.regressions.append(
                        {k: v for k, v in rec.items() if k != "kind"}
                    )
        return n
