"""Pallas TPU kernel: vectorized binary search over sorted keys.

The batch analogue of the storage seek behind skip() (paper §3.2 Skip
phase) and the probe-side lookup of the LookupJoin. position(q) = number of
keys < q (side='left') or <= q (side='right'), computed gather-free as a
comparison-matrix reduction, accumulated across key tiles through output
revisiting (TPU grids execute sequentially, so the (q_block, key_tile) grid
accumulates in-place in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Q_BLOCK = 512
K_TILE = 2048
_PAD_KEY = jnp.iinfo(jnp.int32).max  # never counted


def _kernel(keys_ref, q_ref, out_ref, *, left: bool):
    k_idx = pl.program_id(1)
    keys = keys_ref[...]  # (K_TILE,)
    q = q_ref[...]  # (Q_BLOCK,)
    m = (keys[:, None] < q[None, :]) if left else (keys[:, None] <= q[None, :])
    counts = jnp.sum(m.astype(jnp.int32), axis=0)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = counts

    @pl.when(k_idx != 0)
    def _acc():
        out_ref[...] = out_ref[...] + counts


@functools.partial(jax.jit, static_argnames=("side", "interpret"))
def sorted_search_pallas(
    keys: jax.Array, queries: jax.Array, side: str = "left", interpret: bool = True
) -> jax.Array:
    n, m = keys.shape[0], queries.shape[0]
    n_pad = pl.cdiv(max(n, 1), K_TILE) * K_TILE
    m_pad = pl.cdiv(max(m, 1), Q_BLOCK) * Q_BLOCK
    keys_p = jnp.full((n_pad,), _PAD_KEY, jnp.int32).at[:n].set(keys.astype(jnp.int32))
    qs_p = jnp.zeros((m_pad,), jnp.int32).at[:m].set(queries.astype(jnp.int32))

    grid = (m_pad // Q_BLOCK, n_pad // K_TILE)
    out = pl.pallas_call(
        functools.partial(_kernel, left=(side == "left")),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K_TILE,), lambda i, j: (j,)),
            pl.BlockSpec((Q_BLOCK,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((Q_BLOCK,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m_pad,), jnp.int32),
        interpret=interpret,
    )(keys_p, qs_p)
    return out[:m]
