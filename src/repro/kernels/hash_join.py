"""Pallas TPU kernel: hash-join probe over a radix-partitioned build side.

The build side is laid out by ``hash_build`` (kernels.ops): rows grouped by
multiplicative-hash partition id — the radix_partition kernel supplies the
ids and the histogram — and key-sorted within each partition, so a probe
key's matches occupy one contiguous run. This kernel locates that run.

TPU adaptation: a per-probe binary search is a chain of data-dependent
HBM gathers — the exact access pattern the hardware punishes. Instead the
run boundaries are computed **gather-free** by *counting*: in the
(partition, key) lexicographic order, a probe's run starts at the number
of build rows that order strictly below it and ends at the number that
order at-or-below it. Build rows stream tile-by-tile through VMEM and each
tile contributes a comparison-matrix count to the resident (lo, hi)
output block — the same tiled select-accumulate idiom as gather_emit and
frontier_dedup. Keys are int32 (hi, lo) pairs compared lexicographically
(hi >= 0, see vecops §11 header); no int64 anywhere, x64 stays off.

Grid: (n_build_tiles, n_probe_blocks); outputs are indexed by the probe
block only, so they stay resident across the build-tile axis. Build
padding rows carry pid = INT32_MAX, which orders above every real
(pid < n_parts) probe and therefore contributes zero to both counts.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

N_TILE = 2048  # build rows streamed per chunk
BLOCK = 512  # probe keys per grid step

_PAD_PID = np.int32(np.iinfo(np.int32).max)


def _kernel(bpid_ref, bhi_ref, blo_ref, qpid_ref, qhi_ref, qlo_ref,
            lo_ref, hi_ref):
    nc = pl.program_id(0)
    bp, bh, bl = bpid_ref[...], bhi_ref[...], blo_ref[...]  # (N_TILE,)
    qp, qh, ql = qpid_ref[...], qhi_ref[...], qlo_ref[...]  # (BLOCK,)

    # (N_TILE, BLOCK) triple-lexicographic comparison matrices
    bp2, qp2 = bp[:, None], qp[None, :]
    bh2, qh2 = bh[:, None], qh[None, :]
    bl2, ql2 = bl[:, None], ql[None, :]
    lt = (bp2 < qp2) | (
        (bp2 == qp2) & ((bh2 < qh2) | ((bh2 == qh2) & (bl2 < ql2)))
    )
    eq = (bp2 == qp2) & (bh2 == qh2) & (bl2 == ql2)
    n_lt = jnp.sum(lt.astype(jnp.int32), axis=0)
    n_le = n_lt + jnp.sum(eq.astype(jnp.int32), axis=0)

    @pl.when(nc == 0)
    def _init():
        lo_ref[...] = n_lt
        hi_ref[...] = n_le

    @pl.when(nc != 0)
    def _acc():
        lo_ref[...] += n_lt
        hi_ref[...] += n_le


@functools.partial(jax.jit, static_argnames=("interpret",))
def hash_probe_pallas(
    bpid: jax.Array,  # (N,) int32 build partition ids, partition-grouped
    bhi: jax.Array,  # (N,) int32 build key hi (>= 0), sorted within pid
    blo: jax.Array,  # (N,) int32 build key lo
    qpid: jax.Array,  # (C,) int32 probe partition ids
    qhi: jax.Array,  # (C,) int32 probe key hi
    qlo: jax.Array,  # (C,) int32 probe key lo
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (lo, hi) int32 run boundaries per probe key."""
    n = bpid.shape[0]
    c = qpid.shape[0]
    n_chunks = pl.cdiv(max(n, 1), N_TILE)
    n_pad = n_chunks * N_TILE
    c_blocks = pl.cdiv(max(c, 1), BLOCK)
    c_pad = c_blocks * BLOCK

    bpid = jnp.pad(bpid.astype(jnp.int32), (0, n_pad - n),
                   constant_values=_PAD_PID)
    bhi = jnp.pad(bhi.astype(jnp.int32), (0, n_pad - n))
    blo = jnp.pad(blo.astype(jnp.int32), (0, n_pad - n))
    qpid = jnp.pad(qpid.astype(jnp.int32), (0, c_pad - c))
    qhi = jnp.pad(qhi.astype(jnp.int32), (0, c_pad - c))
    qlo = jnp.pad(qlo.astype(jnp.int32), (0, c_pad - c))

    grid = (n_chunks, c_blocks)
    src = pl.BlockSpec((N_TILE,), lambda nc, cb: (nc,))
    qry = pl.BlockSpec((BLOCK,), lambda nc, cb: (cb,))
    out = pl.BlockSpec((BLOCK,), lambda nc, cb: (cb,))

    lo, hi = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[src, src, src, qry, qry, qry],
        out_specs=[out, out],
        out_shape=[
            jax.ShapeDtypeStruct((c_pad,), jnp.int32),
            jax.ShapeDtypeStruct((c_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(bpid, bhi, blo, qpid, qhi, qlo)
    return lo[:c], hi[:c]
