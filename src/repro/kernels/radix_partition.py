"""Pallas TPU kernel: multiplicative-hash radix partitioning.

Assigns each key a partition id and builds the partition histogram — the
planning step of the distributed all_to_all exchange behind partitioned
joins and aggregations (DESIGN.md §2.1). The histogram accumulates across
the sequential TPU grid via output revisiting; counting is a gather-free
one-hot comparison-matrix reduction.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK = 2048
_HASH_MULT = np.uint32(0x9E3779B1)


def _kernel(keys_ref, pid_ref, hist_ref, *, n_parts: int):
    b = pl.program_id(0)
    keys = keys_ref[...]
    h = (keys.astype(jnp.uint32) * _HASH_MULT) >> np.uint32(16)
    pid = (h & np.uint32(n_parts - 1)).astype(jnp.int32)
    pid = jnp.where(keys == jnp.iinfo(jnp.int32).min, -1, pid)  # padding
    pid_ref[...] = pid

    parts = jax.lax.iota(jnp.int32, n_parts)
    sel = parts[:, None] == pid[None, :]  # (P, BLOCK)
    counts = jnp.sum(sel.astype(jnp.int32), axis=1)

    @pl.when(b == 0)
    def _init():
        hist_ref[...] = counts

    @pl.when(b != 0)
    def _acc():
        hist_ref[...] = hist_ref[...] + counts


@functools.partial(jax.jit, static_argnames=("n_parts", "interpret"))
def radix_partition_pallas(
    keys: jax.Array, n_parts: int, interpret: bool = True
) -> Tuple[jax.Array, jax.Array]:
    assert n_parts & (n_parts - 1) == 0, "n_parts must be a power of two"
    n = keys.shape[0]
    n_pad = pl.cdiv(max(n, 1), BLOCK) * BLOCK
    keys_p = (
        jnp.full((n_pad,), jnp.iinfo(jnp.int32).min, jnp.int32)
        .at[:n]
        .set(keys.astype(jnp.int32))
    )
    pid, hist = pl.pallas_call(
        functools.partial(_kernel, n_parts=n_parts),
        grid=(n_pad // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((n_parts,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n_parts,), jnp.int32),
        ],
        interpret=interpret,
    )(keys_p)
    return pid[:n], hist
