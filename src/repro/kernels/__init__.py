"""Pallas TPU kernels for the engine's compute hot spots (DESIGN.md §2):

    join_expand      — merge-join Build-phase cross-product materialization
    sorted_search    — vectorized binary search (batched skip()/seek)
    segment_reduce   — segmented scan for streaming aggregation
    expr_eval        — fused expression-VM program evaluation (§9)
    frontier_dedup   — property-path BFS delta-frontier masks
    gather_emit      — fused join emission (gather + NULL-extend + keys)
    radix_partition  — distributed-exchange partitioning

``repro.kernels.ops`` dispatches numpy / jnp-ref / pallas-interpret
backends; ``repro.kernels.ref`` holds the pure-jnp oracles.
"""
