"""Pallas TPU kernel: merge-join Build-phase expansion (paper §3.2).

Materializes output slots [base, base+count) of a grouped cross product as
(left_idx, right_idx) gather indices. This is the hot loop of the paper —
the top merge join of LSQB Q6 emits 288M rows through it (Listing 5).

TPU adaptation: the per-slot binary search over cumulative group offsets and
the per-group parameter gathers are computed **gather-free** as comparison
matrices + select-accumulate over the group axis — pure VPU int32 ops on
(G_TILE, BLOCK) tiles held in VMEM, no dynamic indexing. One-hot selects
replace random-access loads, which is the idiomatic TPU trade (HBM gathers
are latency-bound; VMEM-resident broadcast-compare-reduce is throughput-
bound). See DESIGN.md §2.

Grid: (num_output_blocks,). Per call, G <= G_MAX groups (the ops.py wrapper
splits larger probes into group chunks).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512  # output slots per grid step
G_MAX = 2048  # max groups per kernel invocation (VMEM: G_MAX*BLOCK*4B tiles)


def _kernel(cum_hi_ref, cum_lo_ref, lstarts_ref, rstarts_ref, rlens_ref,
            base_ref, total_ref, li_ref, ri_ref):
    b = pl.program_id(0)
    g_tile = cum_hi_ref.shape[0]
    t = base_ref[0] + b * BLOCK + jax.lax.iota(jnp.int32, BLOCK)  # (BLOCK,)

    # group id = #groups whose output range ends at/before t
    cum_hi = cum_hi_ref[...]  # (G,) end offset of each group's output
    m = cum_hi[:, None] <= t[None, :]  # (G, BLOCK) comparison matrix
    gid = jnp.sum(m.astype(jnp.int32), axis=0)  # (BLOCK,)

    # one-hot select of per-group parameters (gather-free)
    gids = jax.lax.iota(jnp.int32, g_tile)
    sel = gids[:, None] == gid[None, :]  # (G, BLOCK)

    def pick(ref):
        return jnp.sum(jnp.where(sel, ref[...][:, None], 0), axis=0)

    cum_lo = pick(cum_lo_ref)
    ls = pick(lstarts_ref)
    rs = pick(rstarts_ref)
    rl = jnp.maximum(pick(rlens_ref), 1)

    w = t - cum_lo
    li = ls + w // rl
    ri = rs + w % rl
    valid = t < total_ref[0]
    li_ref[...] = jnp.where(valid, li, -1)
    ri_ref[...] = jnp.where(valid, ri, -1)


@functools.partial(jax.jit, static_argnames=("count", "interpret"))
def join_expand_pallas(
    lstarts: jax.Array,
    llens: jax.Array,  # unused by the kernel (cum encodes the products)
    rstarts: jax.Array,
    rlens: jax.Array,
    cum: jax.Array,  # (G+1,) int32 cumulative output offsets
    base,
    count: int,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    del llens
    g = lstarts.shape[0]
    assert g <= G_MAX, f"split probes beyond {G_MAX} groups in the wrapper"
    n_blocks = pl.cdiv(count, BLOCK)
    padded = n_blocks * BLOCK

    cum = cum.astype(jnp.int32)
    total = cum[-1:]
    cum_hi, cum_lo = cum[1:], cum[:-1]
    base_arr = jnp.asarray([base], dtype=jnp.int32)

    grid = (n_blocks,)
    full = pl.BlockSpec((g,), lambda i: (0,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    out = pl.BlockSpec((BLOCK,), lambda i: (i,))
    li, ri = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[full, full, full, full, full, scalar, scalar],
        out_specs=[out, out],
        out_shape=[
            jax.ShapeDtypeStruct((padded,), jnp.int32),
            jax.ShapeDtypeStruct((padded,), jnp.int32),
        ],
        interpret=interpret,
    )(cum_hi, cum_lo, lstarts, rstarts, rlens, base_arr, total)
    return li[:count], ri[:count]
