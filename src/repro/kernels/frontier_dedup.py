"""Pallas TPU kernel: frontier dedup for the property-path BFS engine.

One semi-naive BFS round produces a lexicographically sorted candidate
frontier of (source, node) int32 pairs; the delta frontier keeps a pair iff
it is (a) the first occurrence inside the batch and (b) not already in the
(sorted) visited set. (a) is a shifted-neighbor comparison; (b) is computed
gather-free as an equality-matrix reduction over visited tiles — the same
output-revisiting accumulation pattern as the sorted_search kernel (TPU
grids run sequentially, so the (cand_block, vis_tile) grid accumulates
match counts in-place in VMEM). Pairs stay as two int32 columns: no int64
composite key is ever formed, so the kernel runs with x64 disabled.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

C_BLOCK = 512
V_TILE = 2048
_PAD = jnp.iinfo(jnp.int32).min  # visited padding: matches no candidate


def _kernel(vh_ref, vl_ref, ch_ref, cl_ref, ph_ref, pl_ref, out_ref):
    v_idx = pl.program_id(1)
    vh, vl = vh_ref[...], vl_ref[...]  # (V_TILE,)
    ch, cl = ch_ref[...], cl_ref[...]  # (C_BLOCK,)
    hits = jnp.sum(
        ((vh[:, None] == ch[None, :]) & (vl[:, None] == cl[None, :])).astype(
            jnp.int32
        ),
        axis=0,
    )

    @pl.when(v_idx == 0)
    def _init():
        # fold the adjacent-unique test in on the first visited tile:
        # ph/pl carry each candidate's left neighbor (host-shifted, so the
        # test stays local to the block even at block boundaries)
        dup_prev = (ph_ref[...] == ch) & (pl_ref[...] == cl)
        out_ref[...] = hits + dup_prev.astype(jnp.int32)

    @pl.when(v_idx != 0)
    def _acc():
        out_ref[...] = out_ref[...] + hits


@functools.partial(jax.jit, static_argnames=("interpret",))
def frontier_dedup_pallas(
    cand_hi: jax.Array,
    cand_lo: jax.Array,
    vis_hi: jax.Array,
    vis_lo: jax.Array,
    interpret: bool = True,
) -> jax.Array:
    """(C,) bool mask — see vecops.frontier_dedup for the contract."""
    c, v = cand_hi.shape[0], vis_hi.shape[0]
    c_pad = pl.cdiv(max(c, 1), C_BLOCK) * C_BLOCK
    v_pad = pl.cdiv(max(v, 1), V_TILE) * V_TILE

    def pad_c(a, fill):
        return jnp.full((c_pad,), fill, jnp.int32).at[:c].set(a.astype(jnp.int32))

    ch = pad_c(cand_hi, _PAD)
    cl = pad_c(cand_lo, _PAD)
    # left-neighbor columns; the first candidate gets a sentinel neighbor
    ph = jnp.full((c_pad,), _PAD, jnp.int32).at[1:c].set(cand_hi[: c - 1].astype(jnp.int32))
    pl_ = jnp.full((c_pad,), _PAD, jnp.int32).at[1:c].set(cand_lo[: c - 1].astype(jnp.int32))
    vh = jnp.full((v_pad,), _PAD, jnp.int32).at[:v].set(vis_hi.astype(jnp.int32))
    vl = jnp.full((v_pad,), _PAD, jnp.int32).at[:v].set(vis_lo.astype(jnp.int32))

    grid = (c_pad // C_BLOCK, v_pad // V_TILE)
    counts = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((V_TILE,), lambda i, j: (j,)),
            pl.BlockSpec((V_TILE,), lambda i, j: (j,)),
            pl.BlockSpec((C_BLOCK,), lambda i, j: (i,)),
            pl.BlockSpec((C_BLOCK,), lambda i, j: (i,)),
            pl.BlockSpec((C_BLOCK,), lambda i, j: (i,)),
            pl.BlockSpec((C_BLOCK,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((C_BLOCK,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((c_pad,), jnp.int32),
        interpret=interpret,
    )(vh, vl, ch, cl, ph, pl_)
    return counts[:c] == 0
