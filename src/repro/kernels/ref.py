"""Pure-jnp oracles for every Pallas kernel (jit-compatible, static shapes).

These mirror repro.core.vecops (numpy) semantics exactly, but with the
static-shape contracts the TPU kernels need:

  * join_expand    — materialize output slots [base, base+C) of a grouped
                     cross product as (left_idx, right_idx);
  * sorted_search  — vectorized binary search (the batched skip()/seek);
  * segment_scan   — segmented inclusive scan over sorted keys (the
                     building block of streaming aggregation);
  * expr_eval      — whole expression-VM programs → (value, error);
  * radix_partition— multiplicative-hash partition ids + histogram
                     (the distributed exchange planner).

Every function here is the `ref` side of a tests/test_kernels.py sweep.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

_HASH_MULT = jnp.uint32(0x9E3779B1)


# ---------------------------------------------------------------------------
# join_expand
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("count",))
def join_expand(
    lstarts: jax.Array,  # (G,) int32
    llens: jax.Array,  # (G,) int32
    rstarts: jax.Array,  # (G,) int32
    rlens: jax.Array,  # (G,) int32
    cum: jax.Array,  # (G+1,) int64/int32 cumulative output offsets
    base,  # scalar int
    count: int,  # static output count
) -> Tuple[jax.Array, jax.Array]:
    t = base + jnp.arange(count, dtype=cum.dtype)
    g = jnp.searchsorted(cum, t, side="right") - 1
    g = jnp.clip(g, 0, lstarts.shape[0] - 1)
    w = t - cum[g]
    rl = jnp.maximum(rlens[g].astype(cum.dtype), 1)
    li = lstarts[g] + (w // rl).astype(jnp.int32)
    ri = rstarts[g] + (w % rl).astype(jnp.int32)
    valid = t < cum[-1]
    return jnp.where(valid, li, -1).astype(jnp.int32), jnp.where(valid, ri, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# gather_emit (fused join emission; DESIGN.md §2.3)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("lsel", "rsel", "pairs"))
def gather_emit(
    lcols: jax.Array,  # (KL, NL) int32
    rcols: jax.Array,  # (KR, NR) int32 (callers pad empty sides to width 1)
    li: jax.Array,  # (C,) int32 gather rows into lcols
    ri: jax.Array,  # (C,) int32 gather rows into rcols; -1 = virtual NULL row
    lsel: Tuple[int, ...],  # static: lcols rows to emit (-1 = NULL column)
    rsel: Tuple[int, ...],  # static: rcols rows to emit after the left block
    pairs: Tuple[Tuple[int, int], ...],  # static secondary key comparisons
) -> Tuple[jax.Array, jax.Array]:
    """Mirror of vecops.gather_emit: (K, C) emitted block + (C,) validity."""
    c = li.shape[0]
    rvalid = ri >= 0
    ric = jnp.where(rvalid, ri, 0)
    null = jnp.full((c,), -1, dtype=jnp.int32)
    rows = []
    for row in lsel:
        rows.append(null if row < 0 else lcols[row][li])
    for row in rsel:
        rows.append(null if row < 0 else jnp.where(rvalid, rcols[row][ric], -1))
    out = (
        jnp.stack(rows).astype(jnp.int32)
        if rows
        else jnp.zeros((0, c), dtype=jnp.int32)
    )
    mask = jnp.ones((c,), dtype=bool)
    for lrow, rrow in pairs:
        mask &= ~rvalid | (lcols[lrow][li] == rcols[rrow][ric])
    return out, mask


# ---------------------------------------------------------------------------
# sorted_search
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("side",))
def sorted_search(keys: jax.Array, queries: jax.Array, side: str = "left") -> jax.Array:
    return jnp.searchsorted(keys, queries, side=side).astype(jnp.int32)


# ---------------------------------------------------------------------------
# segment_scan (sorted keys)
# ---------------------------------------------------------------------------


_COMBINE = {
    "sum": jnp.add,
    "count": jnp.add,
    "min": jnp.minimum,
    "max": jnp.maximum,
}
_IDENT = {"sum": 0.0, "count": 0.0, "min": jnp.inf, "max": -jnp.inf}


@functools.partial(jax.jit, static_argnames=("op",))
def segment_scan(keys: jax.Array, values: jax.Array, op: str = "sum") -> jax.Array:
    """Segmented inclusive scan: out[i] = reduce of values over the maximal
    run of equal keys ending at i. For sorted keys, key[i]==key[i-d] implies
    the whole span is one run, so a log-step doubling scan is exact."""
    n = keys.shape[0]
    combine = _COMBINE[op]
    out = values.astype(jnp.float32)
    d = 1
    while d < n:
        prev = jnp.concatenate([jnp.full((d,), _IDENT[op], out.dtype), out[:-d]])
        prev_key = jnp.concatenate([jnp.full((d,), -1, keys.dtype), keys[:-d]])
        out = jnp.where(keys == prev_key, combine(out, prev), out)
        d *= 2
    return out


@functools.partial(jax.jit, static_argnames=("op",))
def segment_totals(keys: jax.Array, values: jax.Array, op: str = "sum") -> Tuple[jax.Array, jax.Array]:
    """(run_end_mask, totals): totals[i] is the full-run aggregate where
    run_end_mask[i] (i is the last position of its run), else the scan."""
    scan = segment_scan(keys, values, op)
    nxt = jnp.concatenate([keys[1:], jnp.full((1,), -1, keys.dtype)])
    return keys != nxt, scan


# ---------------------------------------------------------------------------
# frontier_dedup (property-path BFS rounds, DESIGN.md §8)
# ---------------------------------------------------------------------------


_DEDUP_V_TILE = 2048


@jax.jit
def _frontier_dedup_tile(
    vh: jax.Array, vl: jax.Array, ch: jax.Array, cl: jax.Array
) -> jax.Array:
    """Per-tile membership counts: equality-matrix reduction over one
    (V_TILE,) visited tile — the same tiled idiom as the Pallas kernel."""
    return jnp.sum(
        ((vh[:, None] == ch[None, :]) & (vl[:, None] == cl[None, :])).astype(
            jnp.int32
        ),
        axis=0,
    )


def frontier_dedup(
    cand_hi: jax.Array,  # (C,) int32, lexicographically sorted with cand_lo
    cand_lo: jax.Array,  # (C,) int32
    vis_hi: jax.Array,  # (V,) int32, lexicographically sorted with vis_lo
    vis_lo: jax.Array,  # (V,) int32
) -> jax.Array:
    """Mirror of vecops.frontier_dedup: adjacent-unique within the sorted
    candidate batch, minus visited-set members. Pairs stay as two int32
    columns (no int64 composite — x64 stays off); membership streams the
    visited set through fixed-size tiles so peak memory is O(V_TILE * C),
    not O(V * C)."""
    c = int(cand_hi.shape[0])
    v = int(vis_hi.shape[0])
    first = jnp.ones((c,), dtype=bool)
    if c > 1:
        adj = (cand_hi[1:] != cand_hi[:-1]) | (cand_lo[1:] != cand_lo[:-1])
        first = first.at[1:].set(adj)
    if v and c:
        counts = jnp.zeros((c,), dtype=jnp.int32)
        pad = (-v) % _DEDUP_V_TILE
        # candidates are non-negative codes; -1 padding never matches
        vh = jnp.pad(vis_hi, (0, pad), constant_values=-1)
        vl = jnp.pad(vis_lo, (0, pad), constant_values=-1)
        for t in range(0, v, _DEDUP_V_TILE):
            counts = counts + _frontier_dedup_tile(
                vh[t : t + _DEDUP_V_TILE], vl[t : t + _DEDUP_V_TILE],
                cand_hi, cand_lo,
            )
        first &= counts == 0
    return first


# ---------------------------------------------------------------------------
# expr_eval (expression VM programs; DESIGN.md §9)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("prog",))
def expr_eval(icols: jax.Array, fcols: jax.Array, prog) -> Tuple[jax.Array, jax.Array]:
    """(value float32, error bool) for a compiled ExprProgram over an input
    block — the shared VM interpreter, unrolled under jit (the program is
    the static argument). This is what XLA-TPU would run without the fused
    Pallas kernel."""
    from repro.core.exprs.vm import _interp

    return _interp(jnp, prog, icols, fcols, jnp.float32)


# ---------------------------------------------------------------------------
# radix_partition
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_parts",))
def radix_partition(keys: jax.Array, n_parts: int) -> Tuple[jax.Array, jax.Array]:
    """(partition_ids, histogram). n_parts must be a power of two."""
    h = (keys.astype(jnp.uint32) * _HASH_MULT) >> jnp.uint32(16)
    pid = (h & jnp.uint32(n_parts - 1)).astype(jnp.int32)
    hist = jnp.sum(
        jax.nn.one_hot(pid, n_parts, dtype=jnp.int32), axis=0
    )
    return pid, hist


# ---------------------------------------------------------------------------
# hash join: partitioned build reorder + probe (DESIGN.md §11)
# ---------------------------------------------------------------------------
#
# Keys are int32 (hi, lo) pairs compared lexicographically with hi >= 0
# (see vecops §11 header); int64 composites are avoided so x64 stays off.


@jax.jit
def hash_build_order(
    pid: jax.Array, key_hi: jax.Array, key_lo: jax.Array
) -> jax.Array:
    """Permutation grouping rows by partition id, key-sorted within each
    partition (XLA sort; on TPU the partition/histogram step is the Pallas
    kernel, the reorder is a plain device sort)."""
    return jnp.lexsort((key_lo, key_hi, pid)).astype(jnp.int32)


def _pair_less(ah, al, bh, bl):
    return (ah < bh) | ((ah == bh) & (al < bl))


@functools.partial(jax.jit, static_argnames=("side",))
def hash_probe(
    spid: jax.Array,  # unused; kept for wrapper signature parity
    skey_hi: jax.Array,  # (N,) int32 build keys, partition-grouped + sorted
    skey_lo: jax.Array,
    qpid: jax.Array,  # (C,) int32 probe partition ids
    qkey_hi: jax.Array,
    qkey_lo: jax.Array,
    part_starts: jax.Array,  # (P+1,) int32
    side: str = "left",
) -> jax.Array:
    """Segmented binary search: position of each probe key inside its
    partition's sorted slice. 32 halving steps cover any int32-sized
    partition; every step is one vectorized gather + compare."""
    n = max(int(skey_lo.shape[0]), 1)
    lo = part_starts[qpid].astype(jnp.int32)
    hi = part_starts[qpid + 1].astype(jnp.int32)

    def step(_, lh):
        lo, hi = lh
        mid = (lo + hi) >> 1
        m = jnp.minimum(mid, n - 1)
        vh, vl = skey_hi[m], skey_lo[m]
        if side == "left":
            go = _pair_less(vh, vl, qkey_hi, qkey_lo)
        else:
            go = ~_pair_less(qkey_hi, qkey_lo, vh, vl)
        go &= lo < hi
        return jnp.where(go, mid + 1, lo), jnp.where((lo < hi) & ~go, mid, hi)

    lo, hi = jax.lax.fori_loop(0, 32, step, (lo, hi))
    return lo


# ---------------------------------------------------------------------------
# blocked bloom filter (SIP prefilters, DESIGN.md §12)
# ---------------------------------------------------------------------------

_BLOOM_MULT2 = jnp.uint32(0x85EBCA6B)


def _bloom_hash(keys: jax.Array, n_words: int):
    """Same address computation as vecops.bloom_hash, bit for bit."""
    u = keys.astype(jnp.uint32)
    h1 = u * _HASH_MULT
    h2 = u * _BLOOM_MULT2
    word = ((h1 >> jnp.uint32(18)) & jnp.uint32(n_words - 1)).astype(jnp.int32)
    b1 = h1 & jnp.uint32(31)
    b2 = (h2 >> jnp.uint32(13)) & jnp.uint32(31)
    bits = (jnp.uint32(1) << b1) | (jnp.uint32(1) << b2)
    return word, bits


@functools.partial(jax.jit, static_argnames=("n_words",))
def bloom_build(keys: jax.Array, n_words: int) -> jax.Array:
    """(n_words,) uint32 filter words. jax has no scatter-OR, so the OR is
    decomposed per bit plane: scatter-ADD each key's 32 bit indicators into
    a (n_words, 32) count table, then any nonzero count sets that bit."""
    word, bits = _bloom_hash(keys, n_words)
    planes = ((bits[:, None] >> jnp.arange(32, dtype=jnp.uint32)[None, :])
              & jnp.uint32(1)).astype(jnp.int32)
    counts = jnp.zeros((n_words, 32), jnp.int32).at[word].add(planes)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(
        jnp.where(counts > 0, weights[None, :], jnp.uint32(0)),
        axis=1, dtype=jnp.uint32,
    )


@jax.jit
def bloom_probe(words: jax.Array, queries: jax.Array) -> jax.Array:
    word, bits = _bloom_hash(queries, int(words.shape[0]))
    return (words[word] & bits) == bits
