"""Pallas TPU kernels: blocked bloom filter build + membership probe.

The sideways-information-passing prefilter (DESIGN.md §12): a hash/merge
join's build side is summarized as one uint32 word per block, two bits per
key, and probe-side scans test membership batch-at-a-time before the join
ever sees the rows. Both kernels are gather/scatter-free: addressing is a
one-hot comparison matrix against the word tile, so they run on the same
(block, tile) sequential-grid accumulation pattern as frontier_dedup.

  * build — scatter-OR decomposed per bit plane: a one-hot (word × key)
    matmul against the key's 32 bit indicators counts how many keys set
    each (word, bit); any nonzero count sets the bit. OR across key blocks
    accumulates in-place in VMEM (output revisiting).
  * probe — each query gathers its word via a one-hot sum over word tiles
    (exactly one tile matches), then checks both bits in the jitted
    epilogue.

Address computation must match vecops.bloom_hash bit for bit — the parity
sweeps in tests/test_sip.py hold all three backends to identical words.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

K_BLOCK = 1024  # build keys per grid step
Q_BLOCK = 1024  # probe queries per grid step
W_TILE = 1024  # filter words resident per grid step
_PAD = jnp.iinfo(jnp.int32).min
_MULT1 = np.uint32(0x9E3779B1)
_MULT2 = np.uint32(0x85EBCA6B)


def _hash(keys, n_words: int):
    u = keys.astype(jnp.uint32)
    h1 = u * _MULT1
    h2 = u * _MULT2
    word = ((h1 >> np.uint32(18)) & np.uint32(n_words - 1)).astype(jnp.int32)
    bits = (jnp.uint32(1) << (h1 & np.uint32(31))) | (
        jnp.uint32(1) << ((h2 >> np.uint32(13)) & np.uint32(31))
    )
    return word, bits


def _build_kernel(keys_ref, out_ref, *, n_words: int):
    i = pl.program_id(0)  # word tile
    j = pl.program_id(1)  # key block
    keys = keys_ref[...]  # (K_BLOCK,)
    word, bits = _hash(keys, n_words)
    rel = word - i * W_TILE
    sel = (keys != _PAD) & (rel >= 0) & (rel < W_TILE)
    rel = jnp.where(sel, rel, 0)
    # (K_BLOCK, 32) bit indicators, zeroed for padding/out-of-tile keys
    planes = (
        (bits[:, None] >> jnp.arange(32, dtype=jnp.uint32)[None, :])
        & jnp.uint32(1)
    ).astype(jnp.int32) * sel[:, None].astype(jnp.int32)
    onehot = (
        jax.lax.iota(jnp.int32, W_TILE)[:, None] == rel[None, :]
    ).astype(jnp.int32)  # (W_TILE, K_BLOCK)
    counts = jnp.dot(onehot, planes)  # (W_TILE, 32) keys setting each bit
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    tile_or = jnp.sum(
        jnp.where(counts > 0, weights[None, :], jnp.uint32(0)),
        axis=1, dtype=jnp.uint32,
    )

    @pl.when(j == 0)
    def _init():
        out_ref[...] = tile_or

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] | tile_or


@functools.partial(jax.jit, static_argnames=("n_words", "interpret"))
def bloom_build_pallas(
    keys: jax.Array, n_words: int, interpret: bool = True
) -> jax.Array:
    """(n_words,) uint32 filter words — see vecops.bloom_build."""
    assert n_words & (n_words - 1) == 0, "n_words must be a power of two"
    n = keys.shape[0]
    k_pad = pl.cdiv(max(n, 1), K_BLOCK) * K_BLOCK
    w_pad = pl.cdiv(n_words, W_TILE) * W_TILE
    keys_p = (
        jnp.full((k_pad,), _PAD, jnp.int32).at[:n].set(keys.astype(jnp.int32))
    )
    words = pl.pallas_call(
        functools.partial(_build_kernel, n_words=n_words),
        grid=(w_pad // W_TILE, k_pad // K_BLOCK),
        in_specs=[pl.BlockSpec((K_BLOCK,), lambda i, j: (j,))],
        out_specs=pl.BlockSpec((W_TILE,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((w_pad,), jnp.uint32),
        interpret=interpret,
    )(keys_p)
    return words[:n_words]


def _probe_kernel(words_ref, q_ref, out_ref, *, n_words: int):
    j = pl.program_id(1)  # word tile
    words = words_ref[...]  # (W_TILE,) uint32
    q = q_ref[...]  # (Q_BLOCK,)
    word, _ = _hash(q, n_words)
    rel = word - j * W_TILE
    sel = (rel >= 0) & (rel < W_TILE)
    rel = jnp.where(sel, rel, 0)
    onehot = (
        jax.lax.iota(jnp.int32, W_TILE)[:, None] == rel[None, :]
    ) & sel[None, :]
    vals = jnp.sum(
        jnp.where(onehot, words[:, None], jnp.uint32(0)),
        axis=0, dtype=jnp.uint32,
    )

    @pl.when(j == 0)
    def _init():
        out_ref[...] = vals

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + vals  # exactly one tile is nonzero


@functools.partial(jax.jit, static_argnames=("interpret",))
def bloom_probe_pallas(
    words: jax.Array, queries: jax.Array, interpret: bool = True
) -> jax.Array:
    """(C,) bool membership mask — see vecops.bloom_probe."""
    n_words = int(words.shape[0])
    c = queries.shape[0]
    q_pad = pl.cdiv(max(c, 1), Q_BLOCK) * Q_BLOCK
    w_pad = pl.cdiv(n_words, W_TILE) * W_TILE
    q_p = (
        jnp.full((q_pad,), _PAD, jnp.int32)
        .at[:c]
        .set(queries.astype(jnp.int32))
    )
    words_p = (
        jnp.zeros((w_pad,), jnp.uint32).at[:n_words].set(words)
    )
    gathered = pl.pallas_call(
        functools.partial(_probe_kernel, n_words=n_words),
        grid=(q_pad // Q_BLOCK, w_pad // W_TILE),
        in_specs=[
            pl.BlockSpec((W_TILE,), lambda i, j: (j,)),
            pl.BlockSpec((Q_BLOCK,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((Q_BLOCK,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((q_pad,), jnp.uint32),
        interpret=interpret,
    )(words_p, q_p)
    _, bits = _hash(q_p[:c], n_words)
    return (gathered[:c] & bits) == bits
