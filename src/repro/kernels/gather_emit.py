"""Pallas TPU kernel: fused join emission (gather_emit, DESIGN.md §2.3).

One kernel dispatch materializes an output block of a join: gather the
emitted left/right source rows through the (li, ri) index vectors, NULL-
extend virtual right rows (ri == -1, the left_outer padding), and evaluate
the secondary join-key equality pairs into the combined validity mask —
the work MergeJoin/LookupJoin emission previously did column-by-column in
Python with intermediate whole-window materializations.

TPU adaptation: random-access gathers are HBM-latency-bound, so — like
join_expand.py — the gather is computed **gather-free**: the source is
streamed chunk-by-chunk through VMEM and each chunk contributes a one-hot
comparison-matrix select-accumulate into the resident output tile. Every
index hits exactly one chunk, so summing partials over the chunk axis of
the grid reconstructs the gather exactly. The secondary-key mask and the
virtual-row NULL fill run in the same kernel on the final chunk, while the
gathered tile is still in VMEM — that is the fusion.

Grid: (n_source_chunks, n_output_blocks); output tiles are indexed by the
output block only, so they stay resident across the chunk axis.

Layout contract (enforced by the kernels.ops wrapper): the *emitted* rows
of each source come first and the rows referenced by the k-th equality
pair sit at tail position K - n_pairs + k of their source.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_TILE = 512  # source rows streamed per chunk
BLOCK = 256  # output slots per grid step

_NULL = -1


def _kernel(lsrc_ref, rsrc_ref, li_ref, ri_ref, lout_ref, rout_ref, mask_ref,
            *, n_pairs: int, n_chunks: int):
    nc = pl.program_id(0)
    n0 = nc * N_TILE
    li = li_ref[...]  # (BLOCK,)
    ri = ri_ref[...]
    offs = jax.lax.iota(jnp.int32, N_TILE)

    # one-hot chunk-local selects; indices outside [n0, n0+N_TILE) (and the
    # virtual ri == -1 rows) match nothing and contribute zero
    sel_l = (li[None, :] - n0) == offs[:, None]  # (N_TILE, BLOCK)
    sel_r = (ri[None, :] - n0) == offs[:, None]

    kl = lsrc_ref.shape[0]
    kr = rsrc_ref.shape[0]
    partial_l = jnp.stack(
        [jnp.sum(jnp.where(sel_l, lsrc_ref[k][:, None], 0), axis=0) for k in range(kl)]
    )
    partial_r = jnp.stack(
        [jnp.sum(jnp.where(sel_r, rsrc_ref[k][:, None], 0), axis=0) for k in range(kr)]
    )

    @pl.when(nc == 0)
    def _init():
        lout_ref[...] = partial_l
        rout_ref[...] = partial_r

    @pl.when(nc != 0)
    def _accumulate():
        lout_ref[...] += partial_l
        rout_ref[...] += partial_r

    @pl.when(nc == n_chunks - 1)
    def _finalize():  # mask + NULL-extension while the tile is in VMEM
        lg = lout_ref[...]
        rg = rout_ref[...]
        virtual = ri < 0
        m = jnp.ones_like(ri)
        for p in range(n_pairs):
            eq = lg[kl - n_pairs + p] == rg[kr - n_pairs + p]
            m = m * jnp.where(virtual | eq, 1, 0)
        rout_ref[...] = jnp.where(virtual[None, :], _NULL, rg)
        mask_ref[...] = m


@functools.partial(jax.jit, static_argnames=("n_pairs", "interpret"))
def gather_emit_pallas(
    lsrc: jax.Array,  # (KL, NL) int32: emit rows first, pair-left rows at tail
    rsrc: jax.Array,  # (KR, NR) int32: emit rows first, pair-right rows at tail
    li: jax.Array,  # (C,) int32
    ri: jax.Array,  # (C,) int32; -1 = virtual NULL right row
    n_pairs: int,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (lout (KL, C), rout (KR, C), mask (C,) int32)."""
    kl, nl = lsrc.shape
    kr, nr = rsrc.shape
    c = li.shape[0]
    n = max(nl, nr, 1)
    n_chunks = pl.cdiv(n, N_TILE)
    n_pad = n_chunks * N_TILE
    c_blocks = pl.cdiv(c, BLOCK)
    c_pad = c_blocks * BLOCK

    lsrc = jnp.pad(lsrc.astype(jnp.int32), ((0, 0), (0, n_pad - nl)))
    rsrc = jnp.pad(rsrc.astype(jnp.int32), ((0, 0), (0, n_pad - nr)))
    # pad li with 0 (a real row; the padded output slots are sliced off) and
    # ri with -1 (virtual, selects nothing)
    li = jnp.pad(li.astype(jnp.int32), (0, c_pad - c))
    ri = jnp.pad(ri.astype(jnp.int32), (0, c_pad - c), constant_values=_NULL)

    grid = (n_chunks, c_blocks)
    src_l = pl.BlockSpec((kl, N_TILE), lambda nc, cb: (0, nc))
    src_r = pl.BlockSpec((kr, N_TILE), lambda nc, cb: (0, nc))
    idx = pl.BlockSpec((BLOCK,), lambda nc, cb: (cb,))
    out_l = pl.BlockSpec((kl, BLOCK), lambda nc, cb: (0, cb))
    out_r = pl.BlockSpec((kr, BLOCK), lambda nc, cb: (0, cb))
    out_m = pl.BlockSpec((BLOCK,), lambda nc, cb: (cb,))

    lout, rout, mask = pl.pallas_call(
        functools.partial(_kernel, n_pairs=n_pairs, n_chunks=n_chunks),
        grid=grid,
        in_specs=[src_l, src_r, idx, idx],
        out_specs=[out_l, out_r, out_m],
        out_shape=[
            jax.ShapeDtypeStruct((kl, c_pad), jnp.int32),
            jax.ShapeDtypeStruct((kr, c_pad), jnp.int32),
            jax.ShapeDtypeStruct((c_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(lsrc, rsrc, li, ri)
    return lout[:, :c], rout[:, :c], mask[:c]
