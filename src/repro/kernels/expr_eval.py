"""Pallas TPU kernel: fused expression-VM evaluation (DESIGN.md §9.3).

One kernel dispatch per batch evaluates an *entire* compiled expression
program — arithmetic, comparisons, three-valued logic, IF/COALESCE and the
pre-broadcast dictionary-domain predicate columns — over a block of the
referenced columns only. The program is a static argument: the shared
interpreter (core/exprs/vm._interp) unrolls instruction-by-instruction at
trace time, so each hot expression compiles to its own fused kernel whose
register file lives entirely in VMEM. This generalizes and replaces the
old conjunction-only filter_eval kernel: any FILTER/BIND/left-join
condition the compiler can lower now runs in one dispatch.

Inputs: icols (KI, N) int32 — dictionary-code columns then trinary
predicate columns; fcols (KF, N) float32 — numeric side-array decodes
(NaN = non-numeric/NULL). Outputs: (value float32, error bool) for the
program's output register; the FILTER mask is value != 0 & ~error.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.exprs.bytecode import ExprProgram
from repro.core.exprs.vm import _interp

# wide blocks: the register file is a handful of (BLOCK,) vectors, so VMEM
# stays small even at 8k lanes, and fewer grid steps amortize dispatch
# (and, on CPU, interpret-mode) overhead across more rows
BLOCK = 8192


def _kernel(icols_ref, fcols_ref, val_ref, err_ref, *, prog: ExprProgram):
    val, err = _interp(jnp, prog, icols_ref[...], fcols_ref[...], jnp.float32)
    val_ref[...] = val
    err_ref[...] = err


@functools.partial(jax.jit, static_argnames=("prog", "interpret"))
def expr_eval_pallas(
    icols: jax.Array,
    fcols: jax.Array,
    prog: ExprProgram,
    interpret: bool = True,
):
    ki, n = icols.shape
    kf = fcols.shape[0]
    n_pad = pl.cdiv(max(n, 1), BLOCK) * BLOCK
    # padding rows: NULL codes / NaN values — they evaluate to errors that
    # the final slice drops
    icols_p = jnp.full((ki, n_pad), -1, jnp.int32).at[:, :n].set(
        icols.astype(jnp.int32)
    )
    fcols_p = jnp.full((kf, n_pad), jnp.nan, jnp.float32).at[:, :n].set(
        fcols.astype(jnp.float32)
    )
    val, err = pl.pallas_call(
        functools.partial(_kernel, prog=prog),
        grid=(n_pad // BLOCK,),
        in_specs=[
            pl.BlockSpec((ki, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((kf, BLOCK), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.bool_),
        ],
        interpret=interpret,
    )(icols_p, fcols_p)
    return val[:n], err[:n]
