"""Pallas TPU kernel: segmented inclusive scan over sorted keys —
the vectorized core of streaming aggregation (paper §3.3).

out[i] = reduce(values over the maximal run of equal keys ending at i).
Within a block: log-step doubling scan (for sorted keys, key[i]==key[i-d]
implies the whole span is one run, so doubling is exact). Across blocks:
the TPU grid is sequential, so a VMEM scratch carries (last_key, last_acc)
— the batch-boundary carry merge the paper describes for associative
aggregates ('aggregate within a batch and merge the results across
batches').
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 1024
_SENTINEL = jnp.iinfo(jnp.int32).min
_IDENT = {"sum": 0.0, "count": 0.0, "min": float("inf"), "max": float("-inf")}
_COMBINE = {
    "sum": jnp.add,
    "count": jnp.add,
    "min": jnp.minimum,
    "max": jnp.maximum,
}


def _kernel(keys_ref, vals_ref, out_ref, carry_key, carry_val, *, op: str):
    b = pl.program_id(0)
    keys = keys_ref[...]
    out = vals_ref[...].astype(jnp.float32)
    combine = _COMBINE[op]
    ident = jnp.float32(_IDENT[op])

    # in-block segmented doubling scan
    d = 1
    while d < BLOCK:
        prev = jnp.concatenate([jnp.full((d,), ident, jnp.float32), out[:-d]])
        prev_key = jnp.concatenate([jnp.full((d,), _SENTINEL, jnp.int32), keys[:-d]])
        out = jnp.where(keys == prev_key, combine(out, prev), out)
        d *= 2

    @pl.when(b == 0)
    def _init():
        carry_key[0] = jnp.int32(_SENTINEL)
        carry_val[0] = ident

    # merge the carried run (first run of this block only, keys are sorted)
    ck, cv = carry_key[0], carry_val[0]
    out = jnp.where(keys == ck, combine(out, cv), out)

    out_ref[...] = out
    carry_key[0] = keys[BLOCK - 1]
    carry_val[0] = out[BLOCK - 1]


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def segment_scan_pallas(
    keys: jax.Array, values: jax.Array, op: str = "sum", interpret: bool = True
) -> jax.Array:
    n = keys.shape[0]
    n_pad = pl.cdiv(max(n, 1), BLOCK) * BLOCK
    keys_p = jnp.full((n_pad,), _SENTINEL + 1, jnp.int32).at[:n].set(
        keys.astype(jnp.int32)
    )
    vals_p = (
        jnp.full((n_pad,), _IDENT[op], jnp.float32)
        .at[:n]
        .set(values.astype(jnp.float32))
    )
    out = pl.pallas_call(
        functools.partial(_kernel, op=op),
        grid=(n_pad // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.int32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(keys_p, vals_p)
    return out[:n]
