"""Jit'd public wrappers + backend dispatch for the BARQ kernels.

Backends:
  numpy  — repro.core.vecops (CPU default, the engine's data plane here);
  jax    — repro.kernels.ref jnp mirrors (jit; what XLA-TPU would run
           without custom kernels);
  pallas — the Pallas TPU kernels, executed in interpret mode on CPU
           (validated against both other backends in tests/test_kernels.py).

Select globally with REPRO_KERNEL_BACKEND or per call with backend=...
"""

from __future__ import annotations

import functools
import inspect
import os
import time
from typing import Optional, Tuple

import numpy as np

from repro.core import telemetry
from repro.core import vecops

_DEFAULT = os.environ.get("REPRO_KERNEL_BACKEND", "numpy")

# Process-wide dispatch ledger: every public wrapper below counts one entry
# per call under its kernel name. Observability for tests and benchmarks —
# e.g. a grouped query must show segment_reduce > 0 or the "vectorized
# grouping" claim is hollow (tests/test_aggregate.py pins this).
#
# Since DESIGN.md §13 this Counter is the ``counts`` table of the
# process-global telemetry.KernelLedger. It ALWAYS accumulates; when a
# query-scoped trace is active (telemetry.trace_query), each dispatch is
# additionally attributed — with per-dispatch wall time, by kernel name
# and backend — to that trace's own ledger, so interleaved queries on one
# server never misattribute each other's kernel work.
DISPATCH_COUNTS = telemetry.global_ledger().counts


def dispatch_count(name: Optional[str] = None) -> int:
    """Total kernel dispatches (or for one kernel) since process start /
    last reset — always the process-global view, unaffected by any active
    query-scoped ledger."""
    if name is None:
        return sum(DISPATCH_COUNTS.values())
    return DISPATCH_COUNTS[name]


def reset_dispatch_counts() -> None:
    telemetry.global_ledger().clear()


def _backend(override: Optional[str]) -> str:
    return override or _DEFAULT


def _ledgered(fn):
    """Instrument a public kernel wrapper: one ledger entry (count + wall
    seconds, keyed by kernel name and resolved backend) per call, routed
    through telemetry.record_dispatch — the active query trace if one is
    installed, always the process-global ledger. Wall time is inclusive:
    wrappers that internally dispatch other wrappers (hash_build →
    radix_partition) tick both entries, exactly as the pre-§13 counters
    did."""
    bidx = list(inspect.signature(fn).parameters).index("backend")
    name = fn.__name__

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        be = kwargs.get("backend")
        if be is None and len(args) > bidx:
            be = args[bidx]
        be = be or _DEFAULT
        t0 = time.perf_counter()
        try:
            return fn(*args, **kwargs)
        finally:
            telemetry.record_dispatch(name, be, t0, time.perf_counter() - t0)

    return wrapper


# -- join_expand ---------------------------------------------------------------


@_ledgered
def join_expand(
    lstarts, llens, rstarts, rlens, cum, base: int, count: int,
    backend: Optional[str] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    be = _backend(backend)
    if be == "numpy":
        return vecops.expand_cross(lstarts, llens, rstarts, rlens, cum, base, count)
    if be == "jax":
        from repro.kernels import ref

        li, ri = ref.join_expand(lstarts, llens, rstarts, rlens, cum, base, count)
        return np.asarray(li), np.asarray(ri)
    if be == "pallas":
        from repro.kernels.join_expand import G_MAX, join_expand_pallas

        if len(lstarts) <= G_MAX:
            li, ri = join_expand_pallas(
                lstarts, llens, rstarts, rlens, cum, base, count
            )
            return np.asarray(li), np.asarray(ri)
        # split oversized probes into group chunks
        lis, ris = [], []
        emitted = 0
        g0 = int(np.searchsorted(cum, base, side="right") - 1)
        while emitted < count:
            g1 = min(g0 + G_MAX, len(lstarts))
            chunk_cum = cum[g0 : g1 + 1]
            avail = int(chunk_cum[-1]) - (base + emitted)
            take = min(count - emitted, avail)
            li, ri = join_expand_pallas(
                lstarts[g0:g1],
                llens[g0:g1],
                rstarts[g0:g1],
                rlens[g0:g1],
                (chunk_cum - chunk_cum[0]).astype(np.int32),
                base + emitted - int(chunk_cum[0]),
                take,
            )
            lis.append(np.asarray(li))
            ris.append(np.asarray(ri))
            emitted += take
            g0 = g1
        return np.concatenate(lis), np.concatenate(ris)
    raise ValueError(be)


# -- gather_emit ---------------------------------------------------------------


@_ledgered
def gather_emit(
    lcols,
    rcols,
    li,
    ri,
    lsel=(),
    rsel=(),
    pairs=(),
    backend: Optional[str] = None,
    out: Optional[np.ndarray] = None,
    out_offset: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Fused join emission (see vecops.gather_emit for the contract):
    gather emitted rows through (li, ri), NULL-extend virtual right rows
    (ri == -1), and fold secondary-key equality ``pairs`` into the validity
    mask — one dispatch per output block instead of per column."""
    be = _backend(backend)
    lsel, rsel, pairs = tuple(lsel), tuple(rsel), tuple(pairs)
    if be == "numpy":
        return vecops.gather_emit(lcols, rcols, li, ri, lsel, rsel, pairs,
                                  out, out_offset)
    c = int(len(li))
    k = len(lsel) + len(rsel)
    lcols = np.ascontiguousarray(lcols, dtype=np.int32)
    # normalize a missing/empty right side to a 1-wide dummy addressed only
    # by virtual (-1) indices, so the jitted paths keep static shapes
    if rcols is None or rcols.shape[1] == 0:
        kr_src = 1 if rcols is None else max(int(rcols.shape[0]), 1)
        rcols_n = np.full((kr_src, 1), -1, dtype=np.int32)
        ri_n = np.full(c, -1, dtype=np.int32)
    else:
        rcols_n = np.ascontiguousarray(rcols, dtype=np.int32)
        ri_n = np.asarray(ri, dtype=np.int32)
    li_n = np.asarray(li, dtype=np.int32)

    if be == "jax":
        from repro.kernels import ref

        block, mask = ref.gather_emit(lcols, rcols_n, li_n, ri_n, lsel, rsel, pairs)
        block, mask = np.asarray(block), np.asarray(mask)
    elif be == "pallas":
        from repro.kernels.gather_emit import gather_emit_pallas

        # kernel layout: emitted rows first, pair rows at the source tails
        lrows = [max(r, 0) for r in lsel] + [lp for lp, _ in pairs]
        rrows = [max(r, 0) for r in rsel] + [rp for _, rp in pairs]
        lsrc = lcols[lrows] if lrows else np.zeros((1, max(lcols.shape[1], 1)), np.int32)
        rsrc = rcols_n[rrows] if rrows else np.zeros((1, rcols_n.shape[1]), np.int32)
        lout, rout, maski = gather_emit_pallas(lsrc, rsrc, li_n, ri_n, len(pairs))
        lout, rout = np.asarray(lout), np.asarray(rout)
        block = np.concatenate([lout[: len(lsel)], rout[: len(rsel)]], axis=0)
        mask = np.asarray(maski).astype(bool)
    else:
        raise ValueError(be)

    if any(r < 0 for r in lsel + rsel) and not block.flags.writeable:
        block = block.copy()  # jit outputs are read-only
    for j, row in enumerate(lsel):  # -1 emit rows = NULL columns
        if row < 0:
            block[j] = -1
    for j, row in enumerate(rsel):
        if row < 0:
            block[len(lsel) + j] = -1
    if out is not None:
        view = out[:k, out_offset : out_offset + c]
        view[...] = block
        return view, mask
    return block, mask


# -- sorted_search ---------------------------------------------------------------


@_ledgered
def sorted_search(keys, queries, side: str = "left", backend: Optional[str] = None):
    be = _backend(backend)
    if be == "numpy":
        return vecops.sorted_search(keys, queries, side)
    if be == "jax":
        from repro.kernels import ref

        return np.asarray(ref.sorted_search(keys, queries, side))
    if be == "pallas":
        from repro.kernels.sorted_search import sorted_search_pallas

        return np.asarray(sorted_search_pallas(keys, queries, side))
    raise ValueError(be)


# -- frontier_dedup ---------------------------------------------------------------


@_ledgered
def frontier_dedup(
    cand_hi, cand_lo, vis_hi, vis_lo, backend: Optional[str] = None
) -> np.ndarray:
    """Delta-frontier mask for one property-path BFS round: keep each
    lexicographically sorted (source, node) candidate pair iff it is the
    first occurrence in the batch and absent from the sorted visited set
    (see vecops.frontier_dedup)."""
    be = _backend(backend)
    if be == "numpy":
        return vecops.frontier_dedup(cand_hi, cand_lo, vis_hi, vis_lo)
    cand_hi = np.asarray(cand_hi, dtype=np.int32)
    cand_lo = np.asarray(cand_lo, dtype=np.int32)
    vis_hi = np.asarray(vis_hi, dtype=np.int32)
    vis_lo = np.asarray(vis_lo, dtype=np.int32)
    if be == "jax":
        from repro.kernels import ref

        return np.asarray(ref.frontier_dedup(cand_hi, cand_lo, vis_hi, vis_lo))
    if be == "pallas":
        from repro.kernels.frontier_dedup import frontier_dedup_pallas

        return np.asarray(frontier_dedup_pallas(cand_hi, cand_lo, vis_hi, vis_lo))
    raise ValueError(be)


# -- segment aggregation ---------------------------------------------------------------


@_ledgered
def segment_reduce(keys, values, func: str, backend: Optional[str] = None,
                   seg=None):
    """(run_keys, per-run aggregates) over sorted keys. ``seg`` is the
    optional precomputed (run_keys, lengths, seg_ids) of the key column
    (see vecops.segment_reduce); the scan backends derive boundaries
    in-kernel and ignore it."""
    be = _backend(backend)
    if be == "numpy":
        return vecops.segment_reduce(keys, values, func, seg)
    # jax / pallas: segmented scan then pick run ends
    keys = np.asarray(keys)
    n = len(keys)
    if n == 0:
        return keys.astype(np.int32), np.zeros(0, dtype=np.float64)
    vals = (
        np.ones(n, dtype=np.float32)
        if func == "count" or values is None
        else np.asarray(values, dtype=np.float32)
    )
    op = "sum" if func == "count" else func
    if be == "jax":
        from repro.kernels import ref

        scan = np.asarray(ref.segment_scan(keys, vals, op))
    elif be == "pallas":
        from repro.kernels.segment_reduce import segment_scan_pallas

        scan = np.asarray(segment_scan_pallas(keys, vals, op))
    else:
        raise ValueError(be)
    run_end = np.empty(n, dtype=bool)
    run_end[-1] = True
    run_end[:-1] = keys[1:] != keys[:-1]
    return keys[run_end].astype(np.int32), scan[run_end].astype(np.float64)


# -- expression VM (DESIGN.md §9) -------------------------------------------


@_ledgered
def expr_eval(prog, icols, fcols, backend: Optional[str] = None):
    """Evaluate a compiled ExprProgram over an input block: (value, error)
    numpy arrays for the output register. The numpy path is the float64
    oracle; jax runs the jit'd float32 reference; pallas runs the fused
    kernel (whole program, one dispatch per batch)."""
    be = _backend(backend)
    icols = np.ascontiguousarray(icols, dtype=np.int32)
    if be == "numpy":
        from repro.core.exprs.vm import _interp

        val, err = _interp(np, prog, icols, np.asarray(fcols, np.float64),
                           np.float64)
        return np.asarray(val), np.asarray(err)
    fcols = np.ascontiguousarray(fcols, dtype=np.float32)
    if be == "jax":
        from repro.kernels import ref

        val, err = ref.expr_eval(icols, fcols, prog)
        return np.asarray(val), np.asarray(err)
    if be == "pallas":
        from repro.kernels.expr_eval import expr_eval_pallas

        val, err = expr_eval_pallas(icols, fcols, prog)
        return np.asarray(val), np.asarray(err)
    raise ValueError(be)


# -- radix partition ---------------------------------------------------------------


@_ledgered
def radix_partition(keys, n_parts: int, backend: Optional[str] = None):
    be = _backend(backend)
    if be == "numpy":
        pid = vecops.hash_partition(np.asarray(keys), n_parts)
        return pid, vecops.partition_histogram(pid, n_parts)
    if be == "jax":
        from repro.kernels import ref

        pid, hist = ref.radix_partition(keys, n_parts)
        return np.asarray(pid), np.asarray(hist)
    if be == "pallas":
        from repro.kernels.radix_partition import radix_partition_pallas

        pid, hist = radix_partition_pallas(keys, n_parts)
        return np.asarray(pid), np.asarray(hist)
    raise ValueError(be)


# -- hash join: build / probe (DESIGN.md §11) --------------------------------------
#
# The join key is an int32 (hi, lo) pair compared lexicographically;
# single-variable keys pass key_hi=None (see vecops §11 header). The build
# step reuses the radix_partition kernel for bucketing (its dispatch is
# counted separately), then reorders rows by (partition, key) — an XLA/host
# sort; sorting inside Pallas is not profitable on TPU. The probe step is
# where the Pallas path runs its own kernel (gather-free counting search).


@_ledgered
def hash_build(
    key_hi, key_lo, n_parts: int, backend: Optional[str] = None
) -> Tuple[np.ndarray, np.ndarray]:
    """Partitioned build layout for ``hash_probe``: returns
    (order, part_starts) where ``order`` permutes build rows into
    partition-grouped, key-sorted position and ``part_starts`` is the
    (P+1,) prefix-sum of the partition histogram."""
    be = _backend(backend)
    key_lo = np.asarray(key_lo, dtype=np.int32)
    mixed = vecops.mix_pair(key_hi, key_lo)
    pid, hist = radix_partition(mixed, n_parts, backend=be)
    part_starts = np.concatenate(
        [np.zeros(1, np.int32), np.cumsum(hist, dtype=np.int64)]
    ).astype(np.int32)
    if be == "numpy":
        order = vecops.hash_build_order(pid, key_hi, key_lo, n_parts)
    elif be in ("jax", "pallas"):
        from repro.kernels import ref

        hi = (
            np.zeros(len(key_lo), np.int32)
            if key_hi is None
            else np.asarray(key_hi, np.int32)
        )
        order = np.asarray(ref.hash_build_order(pid, hi, key_lo))
    else:
        raise ValueError(be)
    return order, part_starts


@_ledgered
def hash_probe(
    spid,
    skey_hi,
    skey_lo,
    qkey_hi,
    qkey_lo,
    part_starts,
    n_parts: int,
    backend: Optional[str] = None,
    cache: Optional[dict] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """(lo, hi) match-run boundaries of each probe key in a hash_build
    layout: build rows [lo[i], hi[i]) carry probe i's exact key. ``spid``
    is the partition id per *reordered* build row (repeat of arange over
    the histogram). ``cache`` is a per-build dict the operator threads
    through consecutive probe batches so build-side derivations (the
    global composite) are computed once, not per batch."""
    be = _backend(backend)
    skey_lo = np.asarray(skey_lo, dtype=np.int32)
    qkey_lo = np.asarray(qkey_lo, dtype=np.int32)
    if len(skey_lo) == 0 or len(qkey_lo) == 0:
        z = np.zeros(len(qkey_lo), np.int32)
        return z, z.copy()
    qpid = vecops.hash_partition(vecops.mix_pair(qkey_hi, qkey_lo), n_parts)
    if be == "numpy":
        return vecops.hash_probe_positions(
            spid, skey_hi, skey_lo, qpid, qkey_hi, qkey_lo, part_starts,
            cache=cache,
        )
    z_s = np.zeros(len(skey_lo), np.int32)
    z_q = np.zeros(len(qkey_lo), np.int32)
    shi = z_s if skey_hi is None else np.asarray(skey_hi, np.int32)
    qhi = z_q if qkey_hi is None else np.asarray(qkey_hi, np.int32)
    if be == "jax":
        from repro.kernels import ref

        lo = ref.hash_probe(spid, shi, skey_lo, qpid, qhi, qkey_lo,
                            part_starts, side="left")
        hi = ref.hash_probe(spid, shi, skey_lo, qpid, qhi, qkey_lo,
                            part_starts, side="right")
        return np.asarray(lo), np.asarray(hi)
    if be == "pallas":
        from repro.kernels.hash_join import hash_probe_pallas

        lo, hi = hash_probe_pallas(
            np.asarray(spid, np.int32), shi, skey_lo, qpid, qhi, qkey_lo
        )
        return np.asarray(lo), np.asarray(hi)
    raise ValueError(be)


# -- bloom filter: SIP prefilters (DESIGN.md §12) ----------------------------------


@_ledgered
def bloom_build(
    keys, n_words: Optional[int] = None, backend: Optional[str] = None
) -> Tuple[np.ndarray, int, int]:
    """(words, lo, hi): blocked bloom filter words (uint32) plus the
    min/max code range of the build keys — the payload of a SipFilter.
    ``n_words`` defaults to vecops.bloom_n_words(len(keys))."""
    be = _backend(backend)
    keys = np.ascontiguousarray(keys, dtype=np.int32)
    if n_words is None:
        n_words = vecops.bloom_n_words(len(keys))
    if be == "numpy" or len(keys) == 0:
        return vecops.bloom_build(keys, n_words)
    lo, hi = int(keys.min()), int(keys.max())
    if be == "jax":
        from repro.kernels import ref

        return np.asarray(ref.bloom_build(keys, n_words)), lo, hi
    if be == "pallas":
        from repro.kernels.bloom_filter import bloom_build_pallas

        return np.asarray(bloom_build_pallas(keys, n_words)), lo, hi
    raise ValueError(be)


@_ledgered
def bloom_probe(words, queries, backend: Optional[str] = None) -> np.ndarray:
    """(C,) bool membership mask over ``queries`` — no false negatives."""
    be = _backend(backend)
    queries = np.ascontiguousarray(queries, dtype=np.int32)
    if be == "numpy":
        return vecops.bloom_probe(words, queries)
    if len(queries) == 0:
        return np.zeros(0, dtype=bool)
    if be == "jax":
        from repro.kernels import ref

        return np.asarray(ref.bloom_probe(words, queries))
    if be == "pallas":
        from repro.kernels.bloom_filter import bloom_probe_pallas

        return np.asarray(bloom_probe_pallas(words, queries))
    raise ValueError(be)
