"""Pallas TPU kernel: fused vectorized FILTER evaluation (paper §3.1).

Evaluates a conjunction of per-column comparisons (var-vs-var or
var-vs-constant over dictionary codes) in one pass over the referenced
columns only, producing the batch's new validity mask — the
selection-vector update without touching unreferenced columns. The
predicate spec is static, so each FILTER expression compiles to its own
fused kernel (the cheap half of the paper's 'compile hot expressions'
future-work note).

Spec entries: (col_idx, op_code, rhs_col_idx | -1, const); op codes index
('=', '!=', '<', '<=', '>', '>=').
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _kernel(cols_ref, out_ref, *, spec):
    cols = cols_ref[...]  # (K, BLOCK)
    mask = jnp.ones((cols.shape[1],), dtype=jnp.bool_)
    for col, op, rhs_col, const in spec:
        a = cols[col]
        b = cols[rhs_col] if rhs_col >= 0 else jnp.int32(const)
        m = [a == b, a != b, a < b, a <= b, a > b, a >= b][op]
        mask = jnp.logical_and(mask, m)
    out_ref[...] = mask


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def filter_eval_pallas(
    cols: jax.Array,
    spec: Tuple[Tuple[int, int, int, int], ...],
    interpret: bool = True,
) -> jax.Array:
    k, n = cols.shape
    n_pad = pl.cdiv(max(n, 1), BLOCK) * BLOCK
    cols_p = jnp.zeros((k, n_pad), jnp.int32).at[:, :n].set(cols.astype(jnp.int32))
    out = pl.pallas_call(
        functools.partial(_kernel, spec=spec),
        grid=(n_pad // BLOCK,),
        in_specs=[pl.BlockSpec((k, BLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.bool_),
        interpret=interpret,
    )(cols_p)
    return out[:n]
