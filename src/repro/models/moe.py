"""Mixture-of-Experts FFN block: top-k routing with capacity-based scatter
dispatch (GShard-style capacity, sort-free scatter placement).

Dispatch is the same gather/segment problem as the engine's Build/compact
kernels (DESIGN.md §4): tokens are scattered into per-expert buffers of
static capacity C = ceil(tokens*top_k/E)*cf (overflow dropped, probs
renormalized), expert FFNs run as one batched einsum over the stacked
(E, d, f) weights — sharded over the model axis (expert parallelism) —
and results scatter-add back weighted by router probabilities. A Switch-
style load-balancing auxiliary loss is returned via a side channel.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax

from repro.compat import shard_map
import jax.numpy as jnp

from repro.models.layers import _dense_init
from repro.parallel.sharding import MeshAxes, constrain


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    capacity_factor: float = 1.25
    n_shared_experts: int = 0  # dense always-on experts (DeepSeek-style)
    # dispatch implementation (§Perf lever):
    #   scatter  — pjit-level capacity scatter (baseline; XLA SPMD picks the
    #              collective strategy, which all-gathers tokens)
    #   ep_psum  — shard_map expert parallelism: activations are replicated
    #              across the model axis (as the TP layout already leaves
    #              them), every device dispatches ONLY into its local expert
    #              shard, combine is one psum over the model axis
    impl: str = "scatter"


def init_moe(key, d_model: int, cfg: MoEConfig) -> Dict:
    ks = jax.random.split(key, 5)
    e, f = cfg.n_experts, cfg.d_expert_ff
    p = {
        "w_router": _dense_init(ks[0], (d_model, e)),
        "experts": {
            "w_gate": _dense_init(ks[1], (e, d_model, f)),
            "w_up": _dense_init(ks[2], (e, d_model, f)),
            "w_down": _dense_init(ks[3], (e, f, d_model)),
        },
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _dense_init(kss[0], (d_model, fs)),
            "w_up": _dense_init(kss[1], (d_model, fs)),
            "w_down": _dense_init(kss[2], (fs, d_model)),
        }
    return p


def moe_block(p, cfg: MoEConfig, axes: MeshAxes, x: jax.Array) -> jax.Array:
    if cfg.impl == "ep_psum":
        return _moe_block_ep_psum(p, cfg, axes, x)
    return _moe_block_scatter(p, cfg, axes, x)


def _moe_block_scatter(p, cfg: MoEConfig, axes: MeshAxes, x: jax.Array) -> jax.Array:
    """x: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = int(math.ceil(n * k / e * cfg.capacity_factor))

    xt = x.reshape(n, d)
    router_logits = (xt @ p["w_router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)  # (n, e)
    top_p, top_e = jax.lax.top_k(probs, k)  # (n, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # flatten assignments and compute slot within each expert's buffer via
    # sort-based ranking (O(nk log nk) memory-lean; the cumulative-one-hot
    # alternative materializes an (nk, E) matrix)
    flat_e = top_e.reshape(-1)  # (n*k,)
    nk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=sorted_e.dtype))
    rank_sorted = jnp.arange(nk, dtype=jnp.int32) - start[sorted_e].astype(jnp.int32)
    slot = jnp.zeros((nk,), jnp.int32).at[order].set(rank_sorted)
    keep = slot < cap

    token_idx = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    safe_e = jnp.where(keep, flat_e, 0)
    safe_slot = jnp.where(keep, slot, cap - 1)

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[safe_e, safe_slot].set(
        jnp.where(keep[:, None], xt[token_idx], 0), mode="drop"
    )
    buf = constrain(buf, axes, "mp", None, None)  # expert-parallel

    we = p["experts"]
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, we["w_gate"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", buf, we["w_up"].astype(x.dtype))
    y = jnp.einsum("ecf,efd->ecd", g * u, we["w_down"].astype(x.dtype))
    y = constrain(y, axes, "mp", None, None)

    # combine: gather each assignment's expert output, weight by router prob
    out_flat = y[safe_e, safe_slot]  # (n*k, d)
    w = jnp.where(keep, top_p.reshape(-1), 0.0).astype(x.dtype)
    out = jax.ops.segment_sum(out_flat * w[:, None], token_idx, num_segments=n)

    if cfg.n_shared_experts:
        sh = p["shared"]
        gs = jax.nn.silu(xt @ sh["w_gate"].astype(x.dtype))
        us = xt @ sh["w_up"].astype(x.dtype)
        out = out + (gs * us) @ sh["w_down"].astype(x.dtype)

    return out.reshape(b, s, d)


def _dispatch_local(xt, probs, cfg: MoEConfig, we_local, my_shard, n_shards):
    """Per-device expert-parallel dispatch: tokens are fully visible
    (replicated over the model axis); only assignments routed to this
    device's expert shard are materialized and computed. Returns the
    partial output (n, d) — summing partials over shards (psum) yields the
    full MoE output because expert shards are disjoint."""
    n, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    e_local = e // n_shards
    cap = int(math.ceil(n * k / e * cfg.capacity_factor))

    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(-1)
    nk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=sorted_e.dtype))
    rank_sorted = jnp.arange(nk, dtype=jnp.int32) - start[sorted_e].astype(jnp.int32)
    slot = jnp.zeros((nk,), jnp.int32).at[order].set(rank_sorted)

    local_e = flat_e - my_shard * e_local
    mine = (local_e >= 0) & (local_e < e_local) & (slot < cap)
    token_idx = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    safe_e = jnp.where(mine, local_e, 0)
    safe_slot = jnp.where(mine, slot, cap - 1)

    buf = jnp.zeros((e_local, cap, d), xt.dtype)
    buf = buf.at[safe_e, safe_slot].set(
        jnp.where(mine[:, None], xt[token_idx], 0), mode="drop"
    )
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, we_local["w_gate"].astype(xt.dtype)))
    u = jnp.einsum("ecd,edf->ecf", buf, we_local["w_up"].astype(xt.dtype))
    y = jnp.einsum("ecf,efd->ecd", g * u, we_local["w_down"].astype(xt.dtype))

    out_flat = y[safe_e, safe_slot]
    w = jnp.where(mine, top_p.reshape(-1), 0.0).astype(xt.dtype)
    return jax.ops.segment_sum(out_flat * w[:, None], token_idx, num_segments=n)


def _moe_block_ep_psum(p, cfg: MoEConfig, axes: MeshAxes, x: jax.Array) -> jax.Array:
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty or axes.mp not in mesh.shape:
        # no mesh (smoke tests): single-shard path, numerically identical
        xt = x.reshape(b * s, d)
        probs = jax.nn.softmax(
            (xt @ p["w_router"].astype(x.dtype)).astype(jnp.float32), axis=-1
        )
        out = _dispatch_local(xt, probs, cfg, p["experts"], 0, 1)
        if cfg.n_shared_experts:
            out = out + _shared(p, xt)
        return out.reshape(b, s, d)

    n_shards = mesh.shape[axes.mp]
    dp_axes = tuple(a for a in axes.dp if a in mesh.shape)

    def local(xt, router_w, experts_local):
        probs = jax.nn.softmax(
            (xt @ router_w.astype(xt.dtype)).astype(jnp.float32), axis=-1
        )
        my = jax.lax.axis_index(axes.mp)
        partial = _dispatch_local(xt, probs, cfg, experts_local, my, n_shards)
        return jax.lax.psum(partial, axes.mp)

    xt = x.reshape(b * s, d)
    dp_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    out = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(dp_spec, None), P(None, None), P(axes.mp, None, None)),
        out_specs=P(dp_spec, None),
    )(xt, p["w_router"], p["experts"])
    if cfg.n_shared_experts:
        out = out + _shared(p, xt)
    return out.reshape(b, s, d)


def _shared(p, xt):
    sh = p["shared"]
    gs = jax.nn.silu(xt @ sh["w_gate"].astype(xt.dtype))
    us = xt @ sh["w_up"].astype(xt.dtype)
    return (gs * us) @ sh["w_down"].astype(xt.dtype)


def load_balance_loss(router_probs: jax.Array, top_e: jax.Array, n_experts: int):
    """Switch-transformer aux loss: E * sum_e f_e * P_e."""
    me = jnp.mean(jax.nn.one_hot(top_e[..., 0], n_experts), axis=0)
    pe = jnp.mean(router_probs, axis=0)
    return n_experts * jnp.sum(me * pe)
