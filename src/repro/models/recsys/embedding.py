"""EmbeddingBag in JAX (the brief: 'JAX has no native EmbeddingBag —
implement it with jnp.take + jax.ops.segment_sum; this IS part of the
system').

Tables are row-sharded over the model axis (classic recsys model
parallelism); lookups are jnp.take gathers that XLA SPMD turns into the
all-gather/all-to-all traffic the roofline attributes to recsys cells.
The quotient-remainder option [arXiv:1909.02107] compresses huge vocabs.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def init_table(key, n_rows: int, dim: int, scale: float = 0.01):
    return jax.random.normal(key, (n_rows, dim), jnp.float32) * scale


def embedding_bag(
    table: jax.Array,
    indices: jax.Array,
    segment_ids: Optional[jax.Array] = None,
    n_segments: Optional[int] = None,
    combiner: str = "sum",
    weights: Optional[jax.Array] = None,
) -> jax.Array:
    """Gather rows and segment-reduce.

    indices: (nnz,) int32 (-1 = padding); segment_ids: (nnz,) bag id per
    index (None => one index per bag, identity). Returns (n_segments, dim).
    """
    valid = indices >= 0
    rows = jnp.take(table, jnp.maximum(indices, 0), axis=0)
    rows = jnp.where(valid[:, None], rows, 0.0)
    if weights is not None:
        rows = rows * weights[:, None]
    if segment_ids is None:
        return rows
    assert n_segments is not None
    s = jax.ops.segment_sum(rows, segment_ids, num_segments=n_segments)
    if combiner == "sum":
        return s
    if combiner == "mean":
        cnt = jax.ops.segment_sum(
            valid.astype(jnp.float32), segment_ids, num_segments=n_segments
        )
        return s / jnp.maximum(cnt[:, None], 1.0)
    raise ValueError(combiner)


def qr_embedding_lookup(q_table: jax.Array, r_table: jax.Array,
                        indices: jax.Array, n_collisions: int) -> jax.Array:
    """Quotient-remainder trick: emb[i] = Q[i // m] * R[i % m]."""
    q = jnp.take(q_table, jnp.maximum(indices, 0) // n_collisions, axis=0)
    r = jnp.take(r_table, jnp.maximum(indices, 0) % n_collisions, axis=0)
    out = q * r
    return jnp.where((indices >= 0)[:, None], out, 0.0)
