"""DCN-v2 [arXiv:2008.13535]: 13 dense + 26 sparse features, embed_dim 16,
3 full-rank cross layers, MLP 1024-1024-512, sigmoid CTR head.

Sparse embedding tables use Criteo-style vocab sizes (heavy-tailed; the
largest tables dominate memory and are row-sharded over the model axis).
Four serving shapes: train (65k batch), p99 online (512), bulk offline
scoring (262k), and retrieval scoring of 1M candidates against one query
via a dot-product tower (batched matmul, not a loop).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.recsys.embedding import embedding_bag, init_table
from repro.parallel.sharding import MeshAxes, constrain

# Criteo Kaggle display-advertising vocab sizes (26 categorical fields),
# clipped: the public dataset's exact sizes vary per day; these are the
# standard rounded sizes used by DLRM reference implementations.
CRITEO_VOCABS: Tuple[int, ...] = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145,
    5683, 8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4,
    7046547, 18, 15, 286181, 105, 142572,
)


@dataclasses.dataclass(frozen=True)
class DCNConfig:
    name: str = "dcn-v2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp_dims: Tuple[int, ...] = (1024, 1024, 512)
    vocab_sizes: Tuple[int, ...] = CRITEO_VOCABS
    max_table_rows: int = 0  # 0 = full Criteo sizes; >0 clips (smoke tests)
    # §Perf levers
    table_dtype: str = "float32"  # bf16 halves table memory + grad traffic
    qr_threshold: int = 0  # >0: quotient-remainder for tables above this

    @property
    def d_interact(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim

    def table_rows(self, i: int) -> int:
        v = self.vocab_sizes[i % len(self.vocab_sizes)]
        return min(v, self.max_table_rows) if self.max_table_rows else v

    def padded_rows(self, i: int) -> int:
        """Row-sharded tables pad to a multiple of 512 so the row dim
        divides the model axis on both meshes; lookups stay mod table_rows,
        padding rows are never addressed."""
        v = self.table_rows(i)
        return int(-(-v // 512) * 512) if v >= 16384 else v


def _uses_qr(cfg: DCNConfig, i: int) -> bool:
    return bool(cfg.qr_threshold) and cfg.table_rows(i) > cfg.qr_threshold


_QR_COLLISIONS = 4096


def init_params(cfg: DCNConfig, key) -> Dict:
    keys = jax.random.split(key, cfg.n_sparse + cfg.n_cross_layers + len(cfg.mlp_dims) + 2)
    dt = jnp.bfloat16 if cfg.table_dtype == "bf16" else jnp.float32
    tables = {}
    for i in range(cfg.n_sparse):
        if _uses_qr(cfg, i):
            # quotient-remainder trick [arXiv:1909.02107]: two small tables
            q_rows = int(-(-cfg.table_rows(i) // _QR_COLLISIONS))
            q_rows = int(-(-q_rows // 512) * 512)
            k1, k2 = jax.random.split(keys[i])
            tables[f"t{i}"] = {
                "q": init_table(k1, q_rows, cfg.embed_dim).astype(dt),
                "r": init_table(k2, _QR_COLLISIONS, cfg.embed_dim).astype(dt),
            }
        else:
            tables[f"t{i}"] = init_table(
                keys[i], cfg.padded_rows(i), cfg.embed_dim
            ).astype(dt)
    d = cfg.d_interact
    cross = []
    for l in range(cfg.n_cross_layers):
        k = keys[cfg.n_sparse + l]
        cross.append(
            {"w": jax.random.normal(k, (d, d), jnp.float32) / jnp.sqrt(d),
             "b": jnp.zeros((d,), jnp.float32)}
        )
    mlp = []
    dims = (d,) + cfg.mlp_dims
    for l in range(len(cfg.mlp_dims)):
        k = keys[cfg.n_sparse + cfg.n_cross_layers + l]
        mlp.append(
            {"w": jax.random.normal(k, (dims[l], dims[l + 1]), jnp.float32)
             / jnp.sqrt(dims[l]),
             "b": jnp.zeros((dims[l + 1],), jnp.float32)}
        )
    k_out = keys[-1]
    return {
        "tables": tables,
        "cross": cross,
        "mlp": mlp,
        "w_out": jax.random.normal(k_out, (cfg.mlp_dims[-1] + d, 1), jnp.float32) * 0.01,
    }


def param_specs(cfg: DCNConfig, axes: MeshAxes):
    from repro.parallel.sharding import tree_spec

    def rule(path, leaf):
        if path and path[0] == "tables" and leaf.ndim == 2:
            # row-shard the big tables; tiny ones replicate
            return P(axes.mp, None) if leaf.shape[0] >= 16384 else P(None, None)
        return P(*([None] * leaf.ndim))  # qr sub-tables fall through here too

    shape_tree = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    return tree_spec(shape_tree, rule)


def features(params, cfg: DCNConfig, axes: MeshAxes, dense, sparse) -> jax.Array:
    """dense: (B, 13) float32; sparse: (B, 26) int32 -> (B, d_interact)."""
    b = dense.shape[0]
    embs = []
    for i in range(cfg.n_sparse):
        idx = sparse[:, i] % cfg.table_rows(i)
        t = params["tables"][f"t{i}"]
        if isinstance(t, dict):  # quotient-remainder compressed table
            from repro.models.recsys.embedding import qr_embedding_lookup

            e = qr_embedding_lookup(t["q"], t["r"], idx, _QR_COLLISIONS)
        else:
            e = embedding_bag(t, idx)  # (B, dim) bag of 1
        embs.append(e.astype(jnp.float32))
    x = jnp.concatenate([jnp.log1p(jnp.abs(dense))] + embs, axis=-1)
    return constrain(x, axes, "dp", None)


def interact(params, cfg: DCNConfig, x0: jax.Array) -> jax.Array:
    """DCN-v2 cross network: x_{l+1} = x0 * (W x_l + b) + x_l, then MLP."""
    x = x0
    for lp in params["cross"]:
        x = x0 * (x @ lp["w"] + lp["b"]) + x
    h = x
    for lp in params["mlp"]:
        h = jax.nn.relu(h @ lp["w"] + lp["b"])
    return jnp.concatenate([x, h], axis=-1)


def logits(params, cfg: DCNConfig, axes: MeshAxes, dense, sparse) -> jax.Array:
    x0 = features(params, cfg, axes, dense, sparse)
    z = interact(params, cfg, x0)
    return (z @ params["w_out"])[:, 0]


def loss_fn(params, cfg: DCNConfig, axes: MeshAxes, dense, sparse, labels) -> jax.Array:
    lg = logits(params, cfg, axes, dense, sparse).astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(lg, 0) - lg * y + jnp.log1p(jnp.exp(-jnp.abs(lg))))


# -- retrieval scoring: 1 query vs n_candidates ------------------------------------


def query_embedding(params, cfg: DCNConfig, axes: MeshAxes, dense, sparse) -> jax.Array:
    """Query tower: the MLP branch output as the query vector (B, d_q)."""
    x0 = features(params, cfg, axes, dense, sparse)
    h = x0
    for lp in params["mlp"]:
        h = jax.nn.relu(h @ lp["w"] + lp["b"])
    return h


def retrieval_scores(params, cfg: DCNConfig, axes: MeshAxes, dense, sparse,
                     candidates: jax.Array) -> jax.Array:
    """candidates: (n_cand, d_q) precomputed item tower embeddings, sharded
    over all axes. Scores = one batched matmul + top-k, never a loop."""
    q = query_embedding(params, cfg, axes, dense, sparse)  # (B, d_q)
    cands = constrain(candidates, axes, "dp+mp", None)
    scores = q @ cands.T  # (B, n_cand)
    return jax.lax.top_k(scores, 100)[0]
