"""Decoder-only transformer LM (dense + MoE), scan-over-layers, GQA,
qk-norm, KV-cache decode, sliding-window long-context serving.

Covers the five assigned LM architectures (qwen3-8b, deepseek-7b,
command-r-plus-104b, qwen3-moe-30b-a3b, moonshot-v1-16b-a3b). Sharding:
DP over (pod, data) for batch; TP over model for heads / ffn / vocab;
EP over model for MoE experts; decode KV caches shard sequence over model
(split-K decode — XLA SPMD inserts the cross-shard softmax reductions).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.moe import MoEConfig, init_moe, moe_block
from repro.parallel.sharding import MeshAxes, constrain


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qk_norm: bool = False
    rope_theta: float = 1e6
    moe: Optional[MoEConfig] = None
    window: Optional[int] = None  # sliding-window serving (long_500k)
    remat: str = "full"  # none | full | dots
    unroll_layers: bool = False  # dry-run: per-layer HLO for exact cost analysis
    seq_parallel: bool = False  # shard activations over (dp, mp) — §Perf lever
    microbatches: int = 1  # gradient accumulation — §Perf memory lever

    @property
    def attn(self) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            qk_norm=self.qk_norm,
            rope_theta=self.rope_theta,
        )

    def param_count(self) -> int:
        d, f, v, hd = self.d_model, self.d_ff, self.vocab, self.head_dim
        attn = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.moe:
            ffn = self.moe.n_experts * 3 * d * self.moe.d_expert_ff + d * self.moe.n_experts
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + v * d + d

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        d = self.d_model
        attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
        ffn = self.moe.top_k * 3 * d * self.moe.d_expert_ff + d * self.moe.n_experts
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + self.vocab * d + d


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(cfg: TransformerConfig, key):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "ln2": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(ks[0], cfg.attn),
    }
    if cfg.moe:
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.moe)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff)
    return p


def init_params(cfg: TransformerConfig, key) -> Dict[str, Any]:
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers_p = jax.vmap(partial(_init_layer, cfg))(layer_keys)
    return {
        "embed": L.init_embedding(k_emb, cfg.vocab, cfg.d_model),
        "layers": layers_p,  # stacked (L, ...)
        "ln_f": L.init_rmsnorm(cfg.d_model),
    }


def param_specs(cfg: TransformerConfig, axes: MeshAxes):
    mp = axes.mp

    def rule(path: Tuple[str, ...], leaf):
        name = path[-1]
        stacked = path[0] == "layers"  # leading L axis from scan stacking

        def wrap(*dims):
            return P(*((None,) + dims if stacked else dims))

        if name == "table":
            return P(mp, None)  # vocab-sharded embedding
        if name == "scale":
            return wrap(None) if leaf.ndim == (2 if stacked else 1) else P(None)
        if "experts" in path:
            # stacked MoE expert weights: (L, E, d, f) -> experts over mp
            return wrap(mp, None, None)
        if name == "w_router":
            return wrap(None, None)
        if name in ("wq", "wk", "wv", "w_gate", "w_up"):
            return wrap(None, mp)
        if name in ("wo", "w_down"):
            return wrap(mp, None)
        return P(*([None] * leaf.ndim))

    from repro.parallel.sharding import tree_spec

    return tree_spec(jax.eval_shape(lambda k: init_params(cfg, k),
                                    jax.random.PRNGKey(0)), rule)


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------


def _layer_fwd(cfg: TransformerConfig, axes: MeshAxes, h, lp, positions):
    if cfg.seq_parallel:
        # sequence parallelism: activations shard (batch over dp, seq over
        # mp); XLA all-gathers the sequence axis around attention only
        h = constrain(h, axes, "dp", "mp", None)
    else:
        h = constrain(h, axes, "dp", None, None)
    a = L.attention(lp["attn"], cfg.attn, L.rmsnorm(lp["ln1"], h), positions,
                    causal=True, window=cfg.window)
    h = h + a
    x = L.rmsnorm(lp["ln2"], h)
    if cfg.moe:
        f = moe_block(lp["moe"], cfg.moe, axes, x)
    else:
        f = L.mlp(lp["mlp"], x)
    return h + f


def forward_hidden(params, cfg: TransformerConfig, axes: MeshAxes, tokens):
    b, s = tokens.shape
    h = L.embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def step(h, lp):
        out = _layer_fwd(cfg, axes, h, lp, positions)
        return out, None

    if cfg.remat != "none":
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat == "dots"
            else jax.checkpoint_policies.nothing_saveable
        )
        step = jax.checkpoint(step, policy=policy)
    if cfg.unroll_layers:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            h, _ = step(h, lp)
    else:
        h, _ = jax.lax.scan(step, h, params["layers"])
    return L.rmsnorm(params["ln_f"], h)


def loss_fn(params, cfg: TransformerConfig, axes: MeshAxes, tokens, labels):
    h = forward_hidden(params, cfg, axes, tokens)
    logits = L.logits_from_hidden(params["embed"], h)
    logits = constrain(logits, axes, "dp", None, "mp")
    return L.cross_entropy(logits, labels, cfg.vocab)


def grads_fn(params, cfg: TransformerConfig, axes: MeshAxes, tokens, labels):
    """(loss, grads) with optional gradient accumulation over microbatches
    (cfg.microbatches splits the batch axis; peak activation memory divides
    accordingly — §Perf memory lever)."""
    if cfg.microbatches <= 1:
        return jax.value_and_grad(loss_fn)(params, cfg, axes, tokens, labels)
    m = cfg.microbatches
    b = tokens.shape[0]
    assert b % m == 0, "batch must divide microbatches"
    tok_m = tokens.reshape(m, b // m, -1)
    lab_m = labels.reshape(m, b // m, -1)

    def one(carry, xs):
        loss_acc, grad_acc = carry
        t, l = xs
        loss, g = jax.value_and_grad(loss_fn)(params, cfg, axes, t, l)
        grad_acc = jax.tree.map(jnp.add, grad_acc, g)
        return (loss_acc + loss, grad_acc), None

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if cfg.unroll_layers:
        # analysis mode: unrolled so cost analysis counts every microbatch
        carry = (jnp.float32(0), zero)
        for i in range(m):
            carry, _ = one(carry, (tok_m[i], lab_m[i]))
        loss_sum, grads = carry
    else:
        (loss_sum, grads), _ = jax.lax.scan(one, (jnp.float32(0), zero), (tok_m, lab_m))
    return loss_sum / m, jax.tree.map(lambda g: g / m, grads)


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------


def cache_shapes(cfg: TransformerConfig, batch: int, cache_len: int):
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jax.ShapeDtypeStruct((cfg.n_layers, batch, cache_len, kv, hd), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((cfg.n_layers, batch, cache_len, kv, hd), jnp.bfloat16),
        "pos": jax.ShapeDtypeStruct((cfg.n_layers, batch, cache_len), jnp.int32),
    }


def cache_specs(axes: MeshAxes):
    dp = axes.resolve("dp")
    mp = axes.mp
    return {
        "k": P(None, dp, mp, None, None),  # sequence split-K over model axis
        "v": P(None, dp, mp, None, None),
        "pos": P(None, dp, mp),
    }


def init_cache(cfg: TransformerConfig, batch: int, cache_len: int):
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((cfg.n_layers, batch, cache_len, kv, hd), jnp.bfloat16),
        "v": jnp.zeros((cfg.n_layers, batch, cache_len, kv, hd), jnp.bfloat16),
        "pos": jnp.full((cfg.n_layers, batch, cache_len), -1, jnp.int32),
    }


def prefill(params, cfg: TransformerConfig, axes: MeshAxes, tokens):
    """Run the prompt, return (last-token logits, filled cache).
    Cache length = prompt length (padded externally if needed)."""
    b, s = tokens.shape
    h = L.embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def step(h, lp):
        h = constrain(h, axes, "dp", None, None)
        x = L.rmsnorm(lp["ln1"], h)
        q, k, v = L._qkv(lp["attn"], cfg.attn, x, positions)
        scores = L._gqa_scores(q, k, cfg.attn)
        ii = positions[:, :, None, None]
        jj = positions[:, None, None, :]
        mask = jj <= ii
        if cfg.window is not None:
            mask = mask & (jj > ii - cfg.window)
        probs = jax.nn.softmax(jnp.where(mask, scores, -1e30), axis=-1)
        a = L._gqa_mix(probs, v, cfg.attn).reshape(b, s, -1) @ lp["attn"]["wo"].astype(h.dtype)
        h = h + a
        x2 = L.rmsnorm(lp["ln2"], h)
        f = moe_block(lp["moe"], cfg.moe, axes, x2) if cfg.moe else L.mlp(lp["mlp"], x2)
        return h + f, (k, v)

    if cfg.remat != "none":
        step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.unroll_layers:
        ks_l, vs_l = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            h, (k_i, v_i) = step(h, lp)
            ks_l.append(k_i)
            vs_l.append(v_i)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)
    else:
        h, (ks, vs) = jax.lax.scan(step, h, params["layers"])
    h = L.rmsnorm(params["ln_f"], h)
    logits = L.logits_from_hidden(params["embed"], h[:, -1:, :])
    cache = {
        "k": ks,
        "v": vs,
        "pos": jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (cfg.n_layers, b, s)
        ),
    }
    return logits, cache


def decode_step(params, cfg: TransformerConfig, axes: MeshAxes, cache, token, pos):
    """token: (b, 1) int32; pos: (b, 1) int32 absolute position.
    Returns (logits (b, 1, V), new cache). Cache layout: rolling buffer of
    length cache_len (= window for sliding-window serving)."""
    b = token.shape[0]
    h = L.embed(params["embed"], token)

    def step(h, xs):
        lp, ck, cv, cp = xs
        h = constrain(h, axes, "dp", None, None)
        x = L.rmsnorm(lp["ln1"], h)
        a, ck, cv, cp = L.attention_decode(lp["attn"], cfg.attn, x, ck, cv, cp, pos)
        h = h + a
        x2 = L.rmsnorm(lp["ln2"], h)
        f = moe_block(lp["moe"], cfg.moe, axes, x2) if cfg.moe else L.mlp(lp["mlp"], x2)
        return h + f, (ck, cv, cp)

    if cfg.unroll_layers:
        ks_l, vs_l, ps_l = [], [], []
        for i in range(cfg.n_layers):
            xs = jax.tree.map(
                lambda x: x[i],
                (params["layers"], cache["k"], cache["v"], cache["pos"]),
            )
            h, (k_i, v_i, p_i) = step(h, xs)
            ks_l.append(k_i)
            vs_l.append(v_i)
            ps_l.append(p_i)
        ks, vs, ps = jnp.stack(ks_l), jnp.stack(vs_l), jnp.stack(ps_l)
    else:
        h, (ks, vs, ps) = jax.lax.scan(
            step, h, (params["layers"], cache["k"], cache["v"], cache["pos"])
        )
    h = L.rmsnorm(params["ln_f"], h)
    logits = L.logits_from_hidden(params["embed"], h)
    logits = constrain(logits, axes, "dp", None, "mp")
    return logits, {"k": ks, "v": vs, "pos": ps}
