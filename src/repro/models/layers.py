"""Shared transformer layers: RMSNorm, rotary embedding, GQA attention
(optionally qk-norm, sliding window), SwiGLU MLP, embedding, sharded-safe
cross entropy. Pure-function style: init_* returns a param pytree,
matching apply functions take (params, x, ...).

Mixed precision: params fp32, compute bf16 (cast at entry), reductions
(norms, softmax, logsumexp) fp32.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _dense_init(key, shape, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e6) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA + optional qk-norm)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 1e6
    use_bias: bool = False


def init_attention(key, cfg: AttnConfig):
    ks = jax.random.split(key, 4)
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": _dense_init(ks[0], (d, h * hd)),
        "wk": _dense_init(ks[1], (d, k * hd)),
        "wv": _dense_init(ks[2], (d, k * hd)),
        "wo": _dense_init(ks[3], (h * hd, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _qkv(p, cfg: AttnConfig, x, positions):
    b, s, _ = x.shape
    h, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    kk = (x @ p["wk"].astype(x.dtype)).reshape(b, s, k, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, k, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        kk = rmsnorm(p["k_norm"], kk)
    q = rope(q, positions, cfg.rope_theta)
    kk = rope(kk, positions, cfg.rope_theta)
    return q, kk, v


def _gqa_scores(q, k, cfg: AttnConfig):
    """q: (b, sq, h, hd), k: (b, sk, kv, hd) -> (b, sq, h, sk) fp32."""
    b, sq, h, hd = q.shape
    kv = cfg.n_kv_heads
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    s = jnp.einsum("bqkgh,bskh->bqkgs", qg, k, preferred_element_type=jnp.float32)
    return s.reshape(b, sq, h, k.shape[1]) / math.sqrt(hd)


def _gqa_mix(probs, v, cfg: AttnConfig):
    """probs: (b, sq, h, sk) fp32, v: (b, sk, kv, hd) -> (b, sq, h, hd)."""
    b, sq, h, sk = probs.shape
    kv = cfg.n_kv_heads
    g = h // kv
    pg = probs.reshape(b, sq, kv, g, sk)
    out = jnp.einsum("bqkgs,bskh->bqkgh", pg.astype(v.dtype), v)
    return out.reshape(b, sq, h, -1)


def attention(p, cfg: AttnConfig, x, positions, causal: bool = True,
              window: Optional[int] = None):
    """Full self-attention over x: (b, s, d)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    scores = _gqa_scores(q, k, cfg)
    ii = positions[:, :, None, None]  # query pos
    jj = positions[:, None, None, :]  # key pos — positions (b, s)
    mask = jj <= ii if causal else jnp.ones_like(scores, dtype=bool)
    if window is not None:
        mask = mask & (jj > ii - window)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_mix(probs, v, cfg)
    return out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def attention_decode(p, cfg: AttnConfig, x, cache_k, cache_v, cache_pos,
                     positions):
    """One-token decode: x (b, 1, d); cache_{k,v} (b, S, kv, hd) already
    rope'd; cache_pos (b, S) int32 key positions (-1 = empty slot).
    Returns (out, new_k, new_v) with the token written at its slot."""
    b = x.shape[0]
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    slot = positions % cache_k.shape[1]  # rolling buffer (sliding window)

    def write(cache, val):
        return jax.vmap(
            lambda c, v_, s_: jax.lax.dynamic_update_slice(c, v_, (s_, 0, 0))
        )(cache, val, slot[:, 0])

    cache_k = write(cache_k, k_new)
    cache_v = write(cache_v, v_new)
    cache_pos = jax.vmap(
        lambda cp, ps, s_: jax.lax.dynamic_update_slice(cp, ps, (s_,))
    )(cache_pos, positions, slot[:, 0])

    scores = _gqa_scores(q, cache_k, cfg)  # (b, 1, h, S)
    valid = (cache_pos >= 0) & (cache_pos <= positions[:, :1])
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_mix(probs, cache_v, cfg)
    out = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
    return out, cache_k, cache_v, cache_pos


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d_model, d_ff)),
        "w_up": _dense_init(ks[1], (d_model, d_ff)),
        "w_down": _dense_init(ks[2], (d_ff, d_model)),
    }


def mlp(p, x):
    g = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_up"].astype(x.dtype)
    return (g * u) @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding + loss
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int):
    return {"table": _dense_init(key, (vocab, d_model), scale=0.02)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0).astype(jnp.bfloat16)


def logits_from_hidden(p_embed, h):
    return h @ p_embed["table"].T.astype(h.dtype)


def cross_entropy(logits, labels, vocab: int) -> jax.Array:
    """Sharding-friendly CE: one-hot multiply-reduce (fuses under SPMD even
    with vocab-sharded logits; no cross-shard gather)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, vocab, dtype=lf.dtype)
    label_logit = jnp.sum(lf * onehot, axis=-1)
    return jnp.mean(lse - label_logit)
