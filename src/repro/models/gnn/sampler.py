"""Neighbor samplers for minibatch GNN training (GraphSAGE fanout 25-10 /
15-10 shapes).

Two implementations with identical output contracts (padded static-shape
subgraph blocks):

  * CSRSampler   — classic CSR-adjacency uniform fanout sampling (numpy);
  * BARQSampler  — the same sampling expressed as BARQ merge-join scans
    over the sorted quad store: seeds ⋈ :edge triples is exactly a
    (sorted-seed × SPO-index) merge join, and fanout capping is batch
    truncation per group. This is the paper's engine acting as the
    framework's data pipeline (DESIGN.md §3).

Output block (for L=2 layers, seeds B, fanouts f1, f2):
  nodes:   (B + B*f1 + B*f1*f2,) int32 global node ids (-1 padding)
  edge_src/edge_dst: (B*f1 + B*f1*f2,) int32 *local* indices into nodes
  seed_mask: which local nodes are seeds (loss is computed there)
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.algebra import K, TriplePattern, V, VarTable
from repro.core.batch import ColumnBatch
from repro.core.operators.merge_join import MergeJoin
from repro.core.operators.scan import IndexScan
from repro.core.operators.sort import MaterializedSource
from repro.core.storage import QuadStore


@dataclasses.dataclass
class SampledBlock:
    nodes: np.ndarray  # (n_total,) global ids, -1 pad
    edge_src: np.ndarray  # (n_edges,) local idx, -1 pad
    edge_dst: np.ndarray
    seed_mask: np.ndarray  # (n_total,) bool
    labels: np.ndarray  # (n_total,) int32 (global label table gathered)


class CSRSampler:
    def __init__(self, edge_index: np.ndarray, n_nodes: int, seed: int = 0):
        """edge_index: (2, E) src->dst. Builds CSR over outgoing edges."""
        src, dst = edge_index
        order = np.argsort(src, kind="stable")
        self.dst_sorted = dst[order].astype(np.int32)
        self.indptr = np.searchsorted(
            src[order], np.arange(n_nodes + 1), side="left"
        ).astype(np.int64)
        self.n_nodes = n_nodes
        self.rng = np.random.RandomState(seed)

    def sample_neighbors(self, nodes: np.ndarray, fanout: int) -> np.ndarray:
        """(len(nodes), fanout) neighbor ids, -1 padded."""
        out = np.full((len(nodes), fanout), -1, dtype=np.int32)
        for i, u in enumerate(nodes):
            if u < 0:
                continue
            lo, hi = self.indptr[u], self.indptr[u + 1]
            deg = hi - lo
            if deg == 0:
                continue
            if deg <= fanout:
                out[i, :deg] = self.dst_sorted[lo:hi]
            else:
                sel = self.rng.choice(deg, size=fanout, replace=False)
                out[i] = self.dst_sorted[lo + sel]
        return out

    def sample_block(self, seeds: np.ndarray, fanouts: List[int],
                     labels: Optional[np.ndarray] = None) -> SampledBlock:
        return _assemble_block(self, seeds, fanouts, labels)


class BARQSampler:
    """Fanout sampling as vectorized merge joins over the quad store."""

    def __init__(self, store: QuadStore, edge_pred, seed: int = 0):
        self.store = store
        self.edge_pred = edge_pred
        self.rng = np.random.RandomState(seed)
        self.vt = VarTable()
        self.n_nodes = len(store.dict)

    def sample_neighbors(self, nodes: np.ndarray, fanout: int) -> np.ndarray:
        """Join sorted seeds against the (?s :edge ?o) scan; cap each
        group at ``fanout`` rows."""
        valid = nodes[nodes >= 0]
        if len(valid) == 0:
            return np.full((len(nodes), fanout), -1, np.int32)
        v_s, v_o = self.vt.var("s"), self.vt.var("o")
        uniq = np.unique(valid).astype(np.int32)
        seeds_src = MaterializedSource((v_s,), uniq[None, :], v_s, name="Seeds")
        scan = IndexScan(
            self.store,
            TriplePattern(V(v_s), K(self.edge_pred), V(v_o)),
            want_sorted_var=v_s,
        )
        join = MergeJoin(seeds_src, scan, v_s)
        # drain join; group rows per seed, sample fanout
        per_seed = {}
        while True:
            b = join.next_batch()
            if b is None:
                break
            cb = b.compact()
            if not cb.n_rows:
                continue
            ss = cb.column(v_s)
            oo = cb.column(v_o)
            for s_val, o_val in zip(ss.tolist(), oo.tolist()):
                per_seed.setdefault(s_val, []).append(o_val)
        out = np.full((len(nodes), fanout), -1, dtype=np.int32)
        for i, u in enumerate(nodes):
            nb = per_seed.get(int(u))
            if not nb:
                continue
            if len(nb) <= fanout:
                out[i, : len(nb)] = nb
            else:
                sel = self.rng.choice(len(nb), size=fanout, replace=False)
                out[i] = np.asarray(nb, np.int32)[sel]
        return out

    def sample_block(self, seeds: np.ndarray, fanouts: List[int],
                     labels: Optional[np.ndarray] = None) -> SampledBlock:
        return _assemble_block(self, seeds, fanouts, labels)


def _assemble_block(sampler, seeds: np.ndarray, fanouts: List[int],
                    labels: Optional[np.ndarray]) -> SampledBlock:
    seeds = np.asarray(seeds, dtype=np.int32)
    levels = [seeds]
    edges_src_g: List[np.ndarray] = []
    edges_dst_g: List[np.ndarray] = []
    frontier = seeds
    for f in fanouts:
        nbrs = sampler.sample_neighbors(frontier, f)  # (len(frontier), f)
        src = nbrs.reshape(-1)
        dst = np.repeat(frontier, f)
        dst = np.where(src >= 0, dst, -1)
        edges_src_g.append(src)
        edges_dst_g.append(dst)
        levels.append(src)
        frontier = src
    nodes = np.concatenate(levels)
    n_total = len(nodes)
    # map global -> local (first occurrence wins; padding stays -1)
    local = {}
    nodes_local = np.full(n_total, -1, np.int32)
    for i, u in enumerate(nodes.tolist()):
        if u < 0:
            continue
        if u not in local:
            local[u] = i
        nodes_local[i] = local[u]

    def to_local(arr):
        return np.asarray(
            [local.get(int(u), -1) if u >= 0 else -1 for u in arr], np.int32
        )

    e_src = to_local(np.concatenate(edges_src_g))
    e_dst = to_local(np.concatenate(edges_dst_g))
    seed_mask = np.zeros(n_total, bool)
    seed_mask[: len(seeds)] = seeds >= 0
    lab = np.zeros(n_total, np.int32)
    if labels is not None:
        ok = nodes >= 0
        lab[ok] = labels[nodes[ok]]
    return SampledBlock(nodes, e_src, e_dst, seed_mask, lab)
