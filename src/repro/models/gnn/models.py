"""The four assigned GNN architectures.

  graphsage-reddit  [arXiv:1706.02216]  2L, d=128, mean aggregator, 25-10 fanout
  gat-cora          [arXiv:1710.10903]  2L, d=8, 8 heads, attention aggregator
  gin-tu            [arXiv:1810.00826]  5L, d=64, sum aggregator, learnable eps
  dimenet           [arXiv:2003.03123]  6 blocks, d=128, bilinear=8, sph=7, rad=6

All take a Graph of padded static shapes (DESIGN.md §4): node features
(N, F), edge_index (2, E) int32 with -1 padding, optional labels / 3D
positions / triplet lists (DimeNet). Each exposes init(key, cfg) and
loss(params, cfg, graph) for the train_step, plus apply() for inference.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax

from repro.compat import shard_map
import jax.numpy as jnp

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class GraphShape:
    n_nodes: int
    n_edges: int
    d_feat: int
    n_classes: int = 16
    n_triplets: int = 0  # DimeNet only
    n_graphs: int = 1  # batched molecule graphs


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str  # graphsage | gat | gin | dimenet
    n_layers: int
    d_hidden: int
    n_heads: int = 1
    aggregator: str = "mean"
    # dimenet extras
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6


def make_graph_inputs(shape: GraphShape, rng_seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Concrete random graph (smoke tests); dry-run uses ShapeDtypeStructs
    of identical structure."""
    rng = jax.random.PRNGKey(rng_seed)
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    g = {
        "x": jax.random.normal(k1, (shape.n_nodes, shape.d_feat), jnp.float32),
        "edge_src": jax.random.randint(k2, (shape.n_edges,), 0, shape.n_nodes, jnp.int32),
        "edge_dst": jax.random.randint(k3, (shape.n_edges,), 0, shape.n_nodes, jnp.int32),
        "labels": jax.random.randint(k4, (shape.n_nodes,), 0, shape.n_classes, jnp.int32),
        "label_mask": jnp.ones((shape.n_nodes,), jnp.float32),
    }
    if shape.n_triplets:
        # triplets (k->j->i): indices into the edge list
        g["trip_kj"] = jax.random.randint(k5, (shape.n_triplets,), 0, shape.n_edges, jnp.int32)
        g["trip_ji"] = jax.random.randint(k5, (shape.n_triplets,), 0, shape.n_edges, jnp.int32)
        g["pos"] = jax.random.normal(k5, (shape.n_nodes, 3), jnp.float32)
    return g


def graph_input_specs(shape: GraphShape) -> Dict[str, jax.ShapeDtypeStruct]:
    s = {
        "x": jax.ShapeDtypeStruct((shape.n_nodes, shape.d_feat), jnp.float32),
        "edge_src": jax.ShapeDtypeStruct((shape.n_edges,), jnp.int32),
        "edge_dst": jax.ShapeDtypeStruct((shape.n_edges,), jnp.int32),
        "labels": jax.ShapeDtypeStruct((shape.n_nodes,), jnp.int32),
        "label_mask": jax.ShapeDtypeStruct((shape.n_nodes,), jnp.float32),
    }
    if shape.n_triplets:
        s["trip_kj"] = jax.ShapeDtypeStruct((shape.n_triplets,), jnp.int32)
        s["trip_ji"] = jax.ShapeDtypeStruct((shape.n_triplets,), jnp.int32)
        s["pos"] = jax.ShapeDtypeStruct((shape.n_nodes, 3), jnp.float32)
    return s


# ---------------------------------------------------------------------------
# GraphSAGE (mean aggregator)
# ---------------------------------------------------------------------------


def init_graphsage(key, cfg: GNNConfig, shape: GraphShape):
    dims = [shape.d_feat] + [cfg.d_hidden] * cfg.n_layers
    layers = []
    for i in range(cfg.n_layers):
        k1, k2, key = jax.random.split(key, 3)
        layers.append(
            {"w_self": C._dense(k1, (dims[i], dims[i + 1])),
             "w_neigh": C._dense(k2, (dims[i], dims[i + 1]))}
        )
    kout, _ = jax.random.split(key)
    return {"layers": layers, "w_out": C._dense(kout, (cfg.d_hidden, shape.n_classes))}


def apply_graphsage(params, cfg: GNNConfig, g):
    x = g["x"]
    n = x.shape[0]
    for lp in params["layers"]:
        msgs = C.gather_src(x, g["edge_src"])
        agg = C.scatter_mean(msgs, g["edge_dst"], n)
        x = jax.nn.relu(x @ lp["w_self"] + agg @ lp["w_neigh"])
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)
    return x @ params["w_out"]


# ---------------------------------------------------------------------------
# GAT
# ---------------------------------------------------------------------------


def init_gat(key, cfg: GNNConfig, shape: GraphShape):
    layers = []
    d_in = shape.d_feat
    for i in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        h = cfg.n_heads if i < cfg.n_layers - 1 else 1
        d_out = cfg.d_hidden if i < cfg.n_layers - 1 else shape.n_classes
        layers.append(
            {
                "w": C._dense(k1, (d_in, h * d_out)),
                "a_src": C._dense(k2, (h, d_out)),
                "a_dst": C._dense(k3, (h, d_out)),
            }
        )
        d_in = h * d_out
    return {"layers": layers}


def apply_gat(params, cfg: GNNConfig, g):
    x = g["x"]
    n = x.shape[0]
    n_layers = len(params["layers"])
    for i, lp in enumerate(params["layers"]):
        h = lp["a_src"].shape[0]
        d_out = lp["a_src"].shape[1]
        z = (x @ lp["w"]).reshape(n, h, d_out)
        s_src = jnp.einsum("nhd,hd->nh", z, lp["a_src"])
        s_dst = jnp.einsum("nhd,hd->nh", z, lp["a_dst"])
        src, dst = g["edge_src"], g["edge_dst"]
        ssafe, dsafe = jnp.maximum(src, 0), jnp.maximum(dst, 0)
        scores = jax.nn.leaky_relu(s_src[ssafe] + s_dst[dsafe], 0.2)  # (E, H)
        alpha = C.edge_softmax(scores, dst, n)  # (E, H)
        msgs = z[ssafe] * alpha[:, :, None]  # (E, H, D)
        agg = C.scatter_sum(msgs.reshape(-1, h * d_out), dst, n).reshape(n, h, d_out)
        if i < n_layers - 1:
            x = jax.nn.elu(agg).reshape(n, h * d_out)
        else:
            x = agg.mean(axis=1)
    return x


# ---------------------------------------------------------------------------
# GIN
# ---------------------------------------------------------------------------


def init_gin(key, cfg: GNNConfig, shape: GraphShape):
    dims = [shape.d_feat] + [cfg.d_hidden] * cfg.n_layers
    layers = []
    for i in range(cfg.n_layers):
        k1, k2, key = jax.random.split(key, 3)
        layers.append(
            {
                "eps": jnp.zeros(()),  # learnable
                "w1": C._dense(k1, (dims[i], cfg.d_hidden)),
                "w2": C._dense(k2, (cfg.d_hidden, dims[i + 1])),
            }
        )
    kout, _ = jax.random.split(key)
    return {"layers": layers, "w_out": C._dense(kout, (cfg.d_hidden, shape.n_classes))}


def apply_gin(params, cfg: GNNConfig, g):
    x = g["x"]
    n = x.shape[0]
    for lp in params["layers"]:
        msgs = C.gather_src(x, g["edge_src"])
        agg = C.scatter_sum(msgs, g["edge_dst"], n)
        h = (1.0 + lp["eps"]) * x + agg
        x = jax.nn.relu(jax.nn.relu(h @ lp["w1"]) @ lp["w2"])
    return x @ params["w_out"]


# ---------------------------------------------------------------------------
# DimeNet (directional message passing; simplified basis — DESIGN.md §4)
# ---------------------------------------------------------------------------


def init_dimenet(key, cfg: GNNConfig, shape: GraphShape):
    d = cfg.d_hidden
    ks = jax.random.split(key, 6 + cfg.n_layers * 6)
    p = {
        "embed_x": C._dense(ks[0], (shape.d_feat, d)),
        "rbf_w": C._dense(ks[1], (cfg.n_radial, d)),
        "edge_mlp": C._dense(ks[2], (3 * d, d)),
        "blocks": [],
        "out_w1": C._dense(ks[3], (d, d)),
        "out_w2": C._dense(ks[4], (d, shape.n_classes)),
    }
    for b in range(cfg.n_layers):
        o = 5 + b * 6
        p["blocks"].append(
            {
                "w_kj": C._dense(ks[o], (d, d)),
                "w_sbf": C._dense(ks[o + 1], (cfg.n_spherical * cfg.n_radial, cfg.n_bilinear)),
                "w_bil": jax.random.normal(ks[o + 2], (cfg.n_bilinear, d, d)) / math.sqrt(d),
                "w_rbf": C._dense(ks[o + 3], (cfg.n_radial, d)),
                "w_upd1": C._dense(ks[o + 4], (d, d)),
                "w_upd2": C._dense(ks[o + 5], (d, d)),
            }
        )
    return p


def _bessel_rbf(dist, n_radial: int, cutoff: float = 5.0):
    """sin(n pi d/c)/d radial basis [DimeNet eq. 7]."""
    d = jnp.maximum(dist, 1e-3)[:, None]
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)[None, :]
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d


def _angular_sbf(angle, dist, n_spherical: int, n_radial: int, cutoff: float = 5.0):
    """Simplified spherical basis: cos(l*angle) x Bessel(d) outer products
    (exact spherical Bessel functions replaced by their leading harmonics;
    orthogonal on the same domain — documented simplification)."""
    ca = jnp.cos(angle[:, None] * jnp.arange(n_spherical, dtype=jnp.float32)[None, :])
    rb = _bessel_rbf(dist, n_radial, cutoff)  # (T, n_radial)
    return (ca[:, :, None] * rb[:, None, :]).reshape(angle.shape[0], -1)


def apply_dimenet(params, cfg: GNNConfig, g):
    node_out = dimenet_node_messages(params, cfg, g)
    h = jax.nn.silu(node_out @ params["out_w1"])
    return h @ params["out_w2"]


def dimenet_node_messages(params, cfg: GNNConfig, g):
    """Everything up to (and including) the edge→node scatter. Factored out
    so the edge-partitioned distributed path can psum the per-shard node
    partials before the output MLP (§Perf: gnn_impl='partitioned')."""
    x = g["x"] @ params["embed_x"]  # (N, d)
    pos = g["pos"]
    src, dst = g["edge_src"], g["edge_dst"]
    ssafe, dsafe = jnp.maximum(src, 0), jnp.maximum(dst, 0)
    evalid = (src >= 0)[:, None]

    dvec = pos[dsafe] - pos[ssafe]  # (E, 3)
    dist = jnp.linalg.norm(dvec + 1e-9, axis=-1)
    rbf = _bessel_rbf(dist, cfg.n_radial)  # (E, n_radial)

    m = jnp.concatenate([x[ssafe], x[dsafe], rbf @ params["rbf_w"]], axis=-1)
    m = jax.nn.silu(m @ params["edge_mlp"]) * evalid  # (E, d) edge messages

    kj, ji = jnp.maximum(g["trip_kj"], 0), jnp.maximum(g["trip_ji"], 0)
    tvalid = (g["trip_kj"] >= 0) & (g["trip_ji"] >= 0)
    # angle between edge kj and edge ji
    v1, v2 = dvec[kj], dvec[ji]
    cosang = jnp.sum(v1 * v2, -1) / jnp.maximum(
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1), 1e-9
    )
    angle = jnp.arccos(jnp.clip(cosang, -1 + 1e-6, 1 - 1e-6))
    sbf = _angular_sbf(angle, dist[kj], cfg.n_spherical, cfg.n_radial)  # (T, S*R)

    n_edges = src.shape[0]
    for blk in params["blocks"]:
        # directional message passing: edge kj -> edge ji modulated by angle
        mk = jax.nn.silu(m @ blk["w_kj"])[kj]  # (T, d)
        sb = sbf @ blk["w_sbf"]  # (T, n_bilinear)
        inter = jnp.einsum("tb,bde,td->te", sb, blk["w_bil"], mk)  # (T, d)
        inter = jnp.where(tvalid[:, None], inter, 0.0)
        agg = jax.ops.segment_sum(inter, ji, num_segments=n_edges)  # (E, d)
        upd = m + jax.nn.silu((agg + rbf @ blk["w_rbf"]) @ blk["w_upd1"])
        m = jax.nn.silu(upd @ blk["w_upd2"]) * evalid

    n = x.shape[0]
    return C.scatter_sum(m, dst, n)


def dimenet_loss_partitioned(params, cfg: GNNConfig, g, mesh, axis_names):
    """Edge-partitioned DimeNet (DESIGN.md §Perf / DistDGL-style locality):

      * node features / positions / labels REPLICATED (N·F fits per device);
      * edge + triplet arrays sharded over every mesh axis, with the
        locality contract that triplet indices point into the local edge
        shard (the pipeline samples triplets per edge partition);
      * all directional message passing is shard-local — the only
        cross-device traffic is ONE psum of the (N, d_hidden) node partials
        (+ the param-grad psums AD inserts), replacing the baseline's
        all-gathers of the (E, d) edge-message tensor.
    """
    from jax.sharding import PartitionSpec as P

    edge_keys = ("edge_src", "edge_dst", "trip_kj", "trip_ji")
    rep_keys = tuple(k for k in g if k not in edge_keys)

    def local(params, g_rep, g_edge):
        gl = {**g_rep, **g_edge}
        partial = dimenet_node_messages(params, cfg, gl)
        node_out = jax.lax.psum(partial, axis_names)
        h = jax.nn.silu(node_out @ params["out_w1"])
        logits = h @ params["out_w2"]
        return C.cross_entropy_nodes(logits, gl["labels"], gl.get("label_mask"))

    shard = axis_names if len(axis_names) > 1 else axis_names[0]
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(
            jax.tree.map(lambda _: P(), params),
            {k: P() for k in rep_keys},
            {k: P(shard) for k in edge_keys},
        ),
        out_specs=P(),
    )(params, {k: g[k] for k in rep_keys}, {k: g[k] for k in edge_keys})


# ---------------------------------------------------------------------------
# dispatch + loss
# ---------------------------------------------------------------------------

_INIT = {
    "graphsage": init_graphsage,
    "gat": init_gat,
    "gin": init_gin,
    "dimenet": init_dimenet,
}
_APPLY = {
    "graphsage": apply_graphsage,
    "gat": apply_gat,
    "gin": apply_gin,
    "dimenet": apply_dimenet,
}


def init(key, cfg: GNNConfig, shape: GraphShape):
    return _INIT[cfg.kind](key, cfg, shape)


def apply(params, cfg: GNNConfig, g):
    return _APPLY[cfg.kind](params, cfg, g)


def loss(params, cfg: GNNConfig, g):
    logits = apply(params, cfg, g)
    return C.cross_entropy_nodes(logits, g["labels"], g.get("label_mask"))
