"""GNN message-passing primitives in JAX.

JAX has no CSR/CSC sparse (BCOO only), so message passing is implemented —
per the brief — as edge-index gather → transform → segment_sum/segment_max
scatter over node ids. Edge lists are static-shape with -1 padding (padded
edges scatter into a dump row). Node features shard over all mesh axes
(dp+mp); the gather of source features across shards is where XLA inserts
the collectives the roofline table attributes to GNN cells.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _dense(key, shape, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return jax.random.normal(key, shape, jnp.float32) * scale


def gather_src(x: jax.Array, edge_src: jax.Array) -> jax.Array:
    """x: (N, F); edge_src: (E,) int32 with -1 padding -> (E, F)."""
    safe = jnp.maximum(edge_src, 0)
    msg = jnp.take(x, safe, axis=0)
    return jnp.where((edge_src >= 0)[:, None], msg, 0.0)


def scatter_sum(msgs: jax.Array, edge_dst: jax.Array, n_nodes: int) -> jax.Array:
    """msgs: (E, F) -> (N, F) summed per destination (padding -> dump row)."""
    safe = jnp.where(edge_dst >= 0, edge_dst, n_nodes)
    out = jax.ops.segment_sum(msgs, safe, num_segments=n_nodes + 1)
    return out[:n_nodes]


def scatter_max(msgs: jax.Array, edge_dst: jax.Array, n_nodes: int) -> jax.Array:
    safe = jnp.where(edge_dst >= 0, edge_dst, n_nodes)
    out = jax.ops.segment_max(msgs, safe, num_segments=n_nodes + 1)
    return jnp.where(jnp.isfinite(out[:n_nodes]), out[:n_nodes], 0.0)


def scatter_mean(msgs: jax.Array, edge_dst: jax.Array, n_nodes: int) -> jax.Array:
    s = scatter_sum(msgs, edge_dst, n_nodes)
    ones = jnp.where(edge_dst >= 0, 1.0, 0.0)[:, None]
    cnt = scatter_sum(ones, edge_dst, n_nodes)
    return s / jnp.maximum(cnt, 1.0)


def edge_softmax(scores: jax.Array, edge_dst: jax.Array, n_nodes: int) -> jax.Array:
    """Per-destination softmax over incoming edge scores.
    scores: (E, H) -> normalized (E, H). Padding edges get weight 0."""
    pad = edge_dst < 0
    neg = jnp.where(pad[:, None], -jnp.inf, scores)
    mx = scatter_max(neg, edge_dst, n_nodes)  # (N, H)
    safe = jnp.maximum(edge_dst, 0)
    shifted = jnp.exp(jnp.where(pad[:, None], -jnp.inf, scores - mx[safe]))
    shifted = jnp.where(pad[:, None], 0.0, shifted)
    denom = scatter_sum(shifted, edge_dst, n_nodes)
    return shifted / jnp.maximum(denom[safe], 1e-16)


def degree_norm(edge_src, edge_dst, n_nodes: int) -> jax.Array:
    """GCN-style 1/sqrt(d_i d_j) per edge."""
    ones = jnp.where(edge_dst >= 0, 1.0, 0.0)[:, None]
    deg = scatter_sum(ones, edge_dst, n_nodes)[:, 0] + 1.0
    si = jnp.maximum(edge_src, 0)
    di = jnp.maximum(edge_dst, 0)
    return jax.lax.rsqrt(deg[si] * deg[di])


def cross_entropy_nodes(logits: jax.Array, labels: jax.Array,
                        mask: Optional[jax.Array] = None) -> jax.Array:
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
    per = lse - ll
    if mask is not None:
        return jnp.sum(per * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(per)
