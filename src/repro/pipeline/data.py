"""Deterministic, resumable data pipelines.

Every batch is a pure function of (seed, step) — restart/resume needs no
replay log, and elastic re-sharding just changes how the same global batch
is split (DESIGN.md §5). Token batches are synthetic (zipfian unigram text
analogue); graph pipelines wrap the neighbor samplers; recsys batches
mirror Criteo field statistics.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np


def token_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> Dict:
    rng = np.random.RandomState((seed * 1_000_003 + step) % (2**31 - 1))
    # zipfian unigrams: realistic softmax difficulty without a corpus
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(vocab, size=(batch, seq + 1), p=probs).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def recsys_batch(seed: int, step: int, batch: int, n_dense: int, n_sparse: int,
                 vocab_sizes) -> Dict:
    rng = np.random.RandomState((seed * 997 + step) % (2**31 - 1))
    dense = rng.lognormal(0, 2, size=(batch, n_dense)).astype(np.float32)
    sparse = np.stack(
        [rng.randint(0, max(int(v), 1), size=batch) for v in vocab_sizes[:n_sparse]],
        axis=1,
    ).astype(np.int32)
    # clicks correlated with a hidden linear signal for learnability
    w = np.random.RandomState(seed).randn(n_dense)
    logit = np.log1p(dense) @ w * 0.3 - 0.5
    labels = (rng.rand(batch) < 1 / (1 + np.exp(-logit))).astype(np.int32)
    return {"dense": dense, "sparse": sparse, "labels": labels}


@dataclasses.dataclass
class GraphPipeline:
    """Minibatch GNN pipeline over a neighbor sampler (CSR or BARQ-backed)."""

    sampler: object  # CSRSampler | BARQSampler
    labels: np.ndarray
    n_seed_nodes: int
    batch_nodes: int
    fanouts: List[int]
    seed: int = 0

    def batch(self, step: int):
        rng = np.random.RandomState((self.seed * 7919 + step) % (2**31 - 1))
        seeds = rng.randint(0, self.n_seed_nodes, self.batch_nodes).astype(np.int32)
        return self.sampler.sample_block(seeds, self.fanouts, self.labels)


def block_to_model_inputs(block, d_feat: int, feature_fn: Optional[Callable] = None):
    """SampledBlock -> the dict the GNN models consume. Features default to
    deterministic hashes of global node id (id-keyed synthetic features)."""
    n = len(block.nodes)
    if feature_fn is None:
        base = (block.nodes.astype(np.int64) % 977).astype(np.float32)[:, None]
        freq = np.arange(1, d_feat + 1, dtype=np.float32)[None, :]
        x = np.sin(base * freq / 977.0)
    else:
        x = feature_fn(block.nodes)
    return {
        "x": x.astype(np.float32),
        "edge_src": block.edge_src,
        "edge_dst": block.edge_dst,
        "labels": block.labels,
        "label_mask": block.seed_mask.astype(np.float32),
    }
