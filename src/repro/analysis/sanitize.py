"""Pool sanitizer — runtime shadow ownership tracking (DESIGN.md §16).

The BatchPool ownership protocol (DESIGN.md §2.3) is a single-owner MOVE
discipline enforced by convention: exactly one holder owns a pooled
batch's buffers; ``release()`` returns them; ``with_mask``/``compact``
MOVE them. A violation doesn't fail at the faulting line — it corrupts
whatever query recycles the buffer next.

``EngineConfig.sanitize`` (env ``BARQ_SANITIZE=1``) swaps the arena for a
``SanitizingBatchPool``:

  * released buffers are **poisoned** with a sentinel fill, so stale reads
    through an aliased view produce loud garbage instead of plausible ids;
  * touching a batch after its release/MOVE raises ``SanitizeError``
    naming the operator that allocated it and the creation site;
  * returning the same buffers to the pool twice raises;
  * ``drain()`` (and ``leaks()``) report batches that were never released,
    with their creation sites.

Tracking lives in a process-global ``PoolSanitizer`` installed into
``repro.core.batch._SANITIZER``; the hooks in ColumnBatch are a single
``is None`` test when no sanitizing pool has ever been constructed, and
batches from plain pools stay untracked either way — ``sanitize=False``
behavior is unchanged.
"""

from __future__ import annotations

import sys
import weakref
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core import batch as _B
from repro.core.batch import BatchPool, ColumnBatch

# int32 sentinel written over every released column buffer: any value this
# large is outside every dictionary, so a stale read fails loudly downstream
POISON = np.int32(-559038737)  # 0xDEADBEEF as int32


class SanitizeError(RuntimeError):
    """A BatchPool ownership-protocol violation, attributed to the
    allocating operator and creation site."""


def _creation_site() -> str:
    """file:line of the nearest caller outside batch.py / sanitize.py —
    frame-walk instead of traceback.extract_stack to keep per-allocation
    cost in the nanoseconds."""
    f = sys._getframe(2)
    while f is not None:
        fn = f.f_code.co_filename
        if not fn.endswith(("batch.py", "sanitize.py")):
            return f"{fn.rsplit('/', 1)[-1]}:{f.f_lineno}"
        f = f.f_back
    return "<unknown>"


class PoolSanitizer:
    """Shadow ownership table for batches of sanitizing pools.

    States per tracked batch: LIVE (in ``_live``) → RELEASED or MOVED
    (tombstone attribute ``_san_state`` on the batch object itself, so
    id-reuse after GC can never misattribute). Batches from plain pools
    are never entered and every hook is a dict-miss no-op for them."""

    def __init__(self) -> None:
        self._live: Dict[int, dict] = {}  # id(batch) -> info
        self._op_stack: List[str] = []
        # batches GC'd while still owning buffers: the release discipline
        # was violated even though Python reclaimed the memory
        self.gc_leaks: List[dict] = []
        self.use_after_release_errors = 0
        self.double_release_errors = 0

    # -- operator attribution (pushed by BatchOperator.next_batch) ----------

    def push_op(self, name: str) -> None:
        self._op_stack.append(name)

    def pop_op(self) -> None:
        if self._op_stack:
            self._op_stack.pop()

    def current_op(self) -> str:
        return self._op_stack[-1] if self._op_stack else "<no operator>"

    # -- lifecycle hooks (called from repro.core.batch) ---------------------

    def on_create(self, b: ColumnBatch) -> None:
        if not getattr(b.pool, "_sanitized", False):
            return
        info = {
            "op": self.current_op(),
            "site": _creation_site(),
            "vars": b.var_ids,
            "capacity": b.capacity,
            "pool": b.pool,
            "key": id(b),
        }
        info["ref"] = weakref.ref(b, lambda _ref, info=info: self._on_gc(info))
        self._live[id(b)] = info
        b.__dict__["_san_state"] = None  # LIVE

    def _on_gc(self, info: dict) -> None:
        if self._live.get(info["key"]) is info:
            del self._live[info["key"]]
            self.gc_leaks.append(info)

    def on_release(self, b: ColumnBatch) -> None:
        info = self._live.pop(id(b), None)
        if info is not None:
            b.__dict__["_san_state"] = ("released", self.current_op(), info)

    def on_move(self, src: ColumnBatch, dst: ColumnBatch) -> None:
        info = self._live.pop(id(src), None)
        if info is None:
            return
        src.__dict__["_san_state"] = ("moved", self.current_op(), info)
        dst_info = dict(info, key=id(dst))
        dst_info["ref"] = weakref.ref(
            dst, lambda _ref, info=dst_info: self._on_gc(info)
        )
        self._live[id(dst)] = dst_info
        dst.__dict__["_san_state"] = None

    def on_access(self, b: ColumnBatch) -> None:
        state = b.__dict__.get("_san_state")
        if state is None:
            return
        kind, by_op, info = state
        self.use_after_release_errors += 1
        raise SanitizeError(
            f"use-after-{kind}: batch vars={info['vars']} "
            f"cap={info['capacity']} allocated by {info['op']} at "
            f"{info['site']} was {kind} by {by_op}; current operator "
            f"{self.current_op()} must not touch it"
        )

    def double_release(self, pool: "SanitizingBatchPool") -> None:
        self.double_release_errors += 1
        raise SanitizeError(
            f"double-release: buffers already sitting in the pool returned "
            f"again by {self.current_op()} — two batches share ownership"
        )

    # -- reporting ----------------------------------------------------------

    def leaks(self, pool: Optional[BatchPool] = None) -> List[dict]:
        """Batches still owning buffers (never released/moved), plus any
        GC'd without release; optionally filtered to one pool."""
        out = [
            dict(info)
            for info in self._live.values()
            if pool is None or info["pool"] is pool
        ]
        out.extend(
            dict(info)
            for info in self.gc_leaks
            if pool is None or info["pool"] is pool
        )
        return out

    def leak_report(self, pool: Optional[BatchPool] = None) -> List[str]:
        return [
            f"leaked batch vars={i['vars']} cap={i['capacity']} "
            f"allocated by {i['op']} at {i['site']}"
            for i in self.leaks(pool)
        ]

    def clear(self, pool: Optional[BatchPool] = None) -> None:
        if pool is None:
            self._live.clear()
            self.gc_leaks.clear()
        else:
            self._live = {
                k: v for k, v in self._live.items() if v["pool"] is not pool
            }
            self.gc_leaks = [v for v in self.gc_leaks if v["pool"] is not pool]


_GLOBAL: Optional[PoolSanitizer] = None


def global_sanitizer() -> PoolSanitizer:
    """The process-wide tracker shared by every SanitizingBatchPool (one
    table keeps the ColumnBatch hooks a single global check)."""
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = PoolSanitizer()
    return _GLOBAL


class SanitizingBatchPool(BatchPool):
    """Drop-in BatchPool with shadow ownership tracking + poisoned frees.

    Construction installs the global sanitizer into the batch module's
    hook point; plain pools created before or after are unaffected
    (their batches are never entered into the table)."""

    _sanitized = True

    def __init__(self, max_per_bucket: int = 32,
                 sanitizer: Optional[PoolSanitizer] = None) -> None:
        super().__init__(max_per_bucket)
        self.sanitizer = sanitizer if sanitizer is not None else global_sanitizer()
        _B._SANITIZER = self.sanitizer
        # ids of column buffers currently sitting in the free stacks —
        # the double-release detector
        self._free_ids: Set[int] = set()

    def acquire(self, n_vars: int, capacity: int) -> Tuple[np.ndarray, np.ndarray]:
        cols, mask = super().acquire(n_vars, capacity)
        self._free_ids.discard(id(cols))
        return cols, mask

    def release(self, cols: np.ndarray, mask: np.ndarray,
                used: Optional[int] = None) -> None:
        if id(cols) in self._free_ids:
            self.sanitizer.double_release(self)
        # poison: stale aliased reads see loud garbage, and every padding
        # row looks active so an un-reset mask can't hide one. ``used``
        # (the batch's n_rows) bounds the region that ever held exposed
        # data — everything past it has been poison/NULL since the last
        # recycle, so re-filling it would only burn memory bandwidth.
        if used is None:
            cols.fill(POISON)
            mask.fill(True)
        else:
            cols[:, :used] = POISON
            mask[:used] = True
        super().release(cols, mask)
        key = (int(cols.shape[0]), int(cols.shape[1]))
        stack = self._free.get(key)
        if stack and stack[-1][0] is cols:  # actually pooled (not dropped)
            self._free_ids.add(id(cols))

    def drain(self) -> None:
        report = self.sanitizer.leak_report(self)
        self._free_ids.clear()
        super().drain()
        if report:
            raise SanitizeError(
                f"{len(report)} batch(es) leaked at drain:\n  "
                + "\n  ".join(report)
            )

    def leaks(self) -> List[dict]:
        return self.sanitizer.leaks(self)
