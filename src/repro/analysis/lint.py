"""barqlint — static invariant analyzer for the batch engine.

The batch pipeline is correct only while every operator honors contracts
the type system can't see: the BatchPool release()/MOVE ownership protocol
(DESIGN.md §2.3), the kernel trio + ledger convention (§13), the OpStats
``extra`` naming scheme, and dtype discipline on kernel hot paths. barqlint
walks the AST (stdlib ``ast``, no dependencies) and turns violations into
file:line diagnostics. Run it as::

    python -m repro.analysis.lint src/

Exit status is the number of files with findings capped at 1, so CI can
gate on it. Individual findings are suppressed with a trailing comment on
the offending line::

    buf = ColumnBatch.alloc(vars, cap, pool)  # barqlint: disable=POOL001

and whole files opt out of a rule with ``# barqlint: disable-file=RULE``
on any line. The rule catalog lives in DESIGN.md §16; each rule's
contract is proven live by a seeded-violation fixture under
``tests/fixtures/lint_bad/`` (excluded from the default walk).
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Set, Tuple

# directories never linted by the default walk: the seeded-violation
# corpus would otherwise fail CI by design
DEFAULT_EXCLUDES: Tuple[str, ...] = ("lint_bad", "__pycache__", ".git")

_SNAKE = re.compile(r"^[a-z][a-z0-9_]*$")
_SUPPRESS = re.compile(
    r"#\s*barqlint:\s*disable(?P<file>-file)?\s*=\s*(?P<rules>[A-Z0-9_,\s]+)"
)


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class FileContext:
    """One parsed source file plus everything rules need to scope
    themselves: path predicates and the suppression table."""

    def __init__(self, path: Path, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=str(path))
        parts = path.as_posix()
        self.in_kernels = "/kernels/" in parts or parts.endswith("kernels/ops.py")
        self.is_kernel_ops = parts.endswith("kernels/ops.py")
        self.is_vecops = path.name == "vecops.py"
        self.line_suppress: Dict[int, Set[str]] = {}
        self.file_suppress: Set[str] = set()
        for i, line in enumerate(source.splitlines(), start=1):
            m = _SUPPRESS.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group("rules").split(",") if r.strip()}
            if m.group("file"):
                self.file_suppress |= rules
            else:
                self.line_suppress.setdefault(i, set()).update(rules)

    def suppressed(self, rule: str, line: int) -> bool:
        if rule in self.file_suppress:
            return True
        return rule in self.line_suppress.get(line, set())

    def diag(self, rule: str, node_or_line, message: str) -> Diagnostic:
        line = (
            node_or_line
            if isinstance(node_or_line, int)
            else getattr(node_or_line, "lineno", 1)
        )
        return Diagnostic(rule, self.path.as_posix(), line, message)


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------

RuleFn = Callable[[FileContext], Iterable[Diagnostic]]


@dataclasses.dataclass(frozen=True)
class Rule:
    rule_id: str
    summary: str
    check: RuleFn


RULES: Dict[str, Rule] = {}


def rule(rule_id: str, summary: str) -> Callable[[RuleFn], RuleFn]:
    def deco(fn: RuleFn) -> RuleFn:
        RULES[rule_id] = Rule(rule_id, summary, fn)
        return fn

    return deco


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

# constructors whose result owns pooled buffers (DESIGN.md §2.3): the
# assigned name must be consumed — released, returned, stored, or moved
_ACQUIRERS = ("from_columns", "alloc", "with_mask", "compact")


def _is_acquire_call(node: ast.AST, include_next_batch: bool = False) -> bool:
    if not isinstance(node, ast.Call) or not isinstance(node.func, ast.Attribute):
        return False
    name = node.func.attr
    if name in _ACQUIRERS:
        return True
    return include_next_batch and name == "next_batch"


def _functions(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _name_loads(fn: ast.AST, name: str) -> List[ast.Name]:
    return [
        n
        for n in ast.walk(fn)
        if isinstance(n, ast.Name) and n.id == name and isinstance(n.ctx, ast.Load)
    ]


# ---------------------------------------------------------------------------
# pool discipline
# ---------------------------------------------------------------------------


@rule("POOL001", "pooled batch acquired but never consumed")
def _pool001(ctx: FileContext) -> Iterator[Diagnostic]:
    """A name bound to a buffer-acquiring constructor (``from_columns``,
    ``alloc``, ``with_mask``, ``compact``) that is never referenced again
    leaks its buffers: nothing can release or MOVE them. A bare acquiring
    call whose result is discarded is the same bug without the name."""
    for fn in _functions(ctx.tree):
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Expr) and _is_acquire_call(stmt.value):
                yield ctx.diag(
                    "POOL001",
                    stmt,
                    f"result of .{stmt.value.func.attr}() is discarded; the "
                    "acquired buffers can never be released",
                )
                continue
            if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
                continue
            target = stmt.targets[0]
            if not isinstance(target, ast.Name) or not _is_acquire_call(stmt.value):
                continue
            end = getattr(stmt, "end_lineno", stmt.lineno)
            later = [
                n
                for n in _name_loads(fn, target.id)
                if n.lineno > end
                or (n.lineno == stmt.lineno and n.col_offset > target.col_offset)
            ]
            # loads inside the acquiring expression itself don't count
            inner = {id(n) for n in ast.walk(stmt.value)}
            later = [n for n in later if id(n) not in inner]
            if not later:
                yield ctx.diag(
                    "POOL001",
                    stmt,
                    f"'{target.id}' is bound to .{stmt.value.func.attr}() but "
                    "never consumed (release/return/store) afterwards",
                )


@rule("POOL002", "operator buffers batches across calls without _close")
def _pool002(ctx: FileContext) -> Iterator[Diagnostic]:
    """An operator class whose ``_next`` machinery parks acquired batches
    on ``self`` holds pooled buffers between calls; without a ``_close``
    (or ``close``) hook, ``close_tree`` cannot reclaim them when the query
    ends early (LIMIT, error) — a structural leak."""
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        methods = {
            m.name: m
            for m in node.body
            if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        if "_next" not in methods:
            continue
        if "_close" in methods or "close" in methods:
            continue
        offender: Optional[ast.AST] = None
        for m in methods.values():
            acquired: Set[str] = set()
            for stmt in ast.walk(m):
                if (
                    isinstance(stmt, ast.Assign)
                    and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and _is_acquire_call(stmt.value, include_next_batch=True)
                ):
                    acquired.add(stmt.targets[0].id)
                if not isinstance(stmt, ast.Assign):
                    continue
                stores_self = any(
                    isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"
                    for t in stmt.targets
                )
                if not stores_self:
                    continue
                holds_batch = any(
                    _is_acquire_call(v, include_next_batch=True)
                    or (
                        isinstance(v, ast.Name)
                        and isinstance(v.ctx, ast.Load)
                        and v.id in acquired
                    )
                    for v in ast.walk(stmt.value)
                )
                if holds_batch:
                    offender = stmt
                    break
            if offender is not None:
                break
        if offender is not None:
            yield ctx.diag(
                "POOL002",
                node,
                f"class '{node.name}' parks acquired batches on self "
                f"(line {offender.lineno}) but defines no _close/close hook "
                "for close_tree to reclaim them",
            )


def _guarded_nodes(fn: ast.AST) -> Set[int]:
    """ids of statements nested under an If or Try inside ``fn`` — the
    shapes that make a second close() call a no-op."""
    guarded: Set[int] = set()

    def mark(node: ast.AST) -> None:
        for child in ast.walk(node):
            guarded.add(id(child))

    for node in ast.walk(fn):
        if isinstance(node, ast.If):
            for stmt in node.body + node.orelse:
                mark(stmt)
        elif isinstance(node, ast.Try):
            for stmt in node.body + node.finalbody:
                mark(stmt)
            for h in node.handlers:
                for stmt in h.body:
                    mark(stmt)
    return guarded


@rule("POOL003", "close() is not idempotent: unguarded resource mutation")
def _pool003(ctx: FileContext) -> Iterator[Diagnostic]:
    """``close_tree`` may visit an operator more than once (shared
    subtrees, retry paths), so ``close``/``_close`` must be idempotent.
    ``self.X.release()`` / ``self.X.unlink()`` straight at body level —
    with no guard and no ``self.X = None`` clear — fails or double-frees
    on the second call. Calls to ``.close()`` are exempt: close is
    idempotent by this very contract."""
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for m in node.body:
            if not isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if m.name not in ("close", "_close"):
                continue
            guarded = _guarded_nodes(m)
            cleared: Set[str] = {
                t.attr
                for stmt in ast.walk(m)
                if isinstance(stmt, ast.Assign)
                for t in stmt.targets
                if isinstance(t, ast.Attribute)
                and isinstance(t.value, ast.Name)
                and t.value.id == "self"
            }
            for stmt in ast.walk(m):
                if id(stmt) in guarded or not isinstance(stmt, ast.Expr):
                    continue
                call = stmt.value
                if not isinstance(call, ast.Call):
                    continue
                f = call.func
                if not isinstance(f, ast.Attribute) or f.attr not in (
                    "release",
                    "unlink",
                ):
                    continue
                obj = f.value
                if not (
                    isinstance(obj, ast.Attribute)
                    and isinstance(obj.value, ast.Name)
                    and obj.value.id == "self"
                ):
                    continue
                if obj.attr in cleared:
                    continue  # self.X.release(); self.X = None — idempotent
                yield ctx.diag(
                    "POOL003",
                    stmt,
                    f"'self.{obj.attr}.{f.attr}()' in {node.name}.{m.name} is "
                    "neither guarded nor followed by clearing the attribute; "
                    "a second close() double-frees",
                )


# ---------------------------------------------------------------------------
# kernel-registry discipline
# ---------------------------------------------------------------------------


def _public_kernels(ctx: FileContext) -> Iterator[ast.FunctionDef]:
    """Public kernel wrappers in kernels/ops.py: top-level defs with a
    ``backend`` parameter. Helpers (``dispatch_count``, ...) have no
    backend knob and are exempt."""
    for node in ctx.tree.body:
        if not isinstance(node, ast.FunctionDef) or node.name.startswith("_"):
            continue
        argnames = [a.arg for a in node.args.args + node.args.kwonlyargs]
        if "backend" in argnames:
            yield node


@rule("KERN001", "public kernel wrapper missing @_ledgered")
def _kern001(ctx: FileContext) -> Iterator[Diagnostic]:
    """Every public kernel in kernels/ops.py must be @_ledgered so each
    dispatch lands in DISPATCH_COUNTS / the scoped query ledger — tests
    and EXPLAIN ANALYZE key on those counts (DESIGN.md §13)."""
    if not ctx.is_kernel_ops:
        return
    for fn in _public_kernels(ctx):
        decorated = any(
            isinstance(d, ast.Name) and d.id == "_ledgered" for d in fn.decorator_list
        )
        if not decorated:
            yield ctx.diag(
                "KERN001",
                fn,
                f"kernel wrapper '{fn.name}' is not @_ledgered: its "
                "dispatches never reach DISPATCH_COUNTS",
            )


@rule("KERN002", "kernel wrapper missing a backend of the numpy/jax/pallas trio")
def _kern002(ctx: FileContext) -> Iterator[Diagnostic]:
    """Each public kernel dispatches the full trio: the numpy oracle
    (vecops), the jnp reference, and the Pallas kernel. A wrapper that
    drops one silently diverges from the validation matrix in
    tests/test_kernels.py."""
    if not ctx.is_kernel_ops:
        return
    for fn in _public_kernels(ctx):
        strings = {
            n.value
            for n in ast.walk(fn)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)
        }
        uses_vecops = any(
            isinstance(n, ast.Attribute)
            and isinstance(n.value, ast.Name)
            and n.value.id == "vecops"
            for n in ast.walk(fn)
        )
        missing = [
            be
            for be, ok in (
                ("numpy", "numpy" in strings or uses_vecops),
                ("jax", "jax" in strings),
                ("pallas", "pallas" in strings),
            )
            if not ok
        ]
        if missing:
            yield ctx.diag(
                "KERN002",
                fn,
                f"kernel wrapper '{fn.name}' does not dispatch the "
                f"{'/'.join(missing)} backend(s) of the trio",
            )


# cross-file source cache for KERN003 (module path -> source text)
_OPS_SOURCE_CACHE: Dict[Path, str] = {}


@rule("KERN003", "Pallas kernel not wired into the ops.py dispatcher")
def _kern003(ctx: FileContext) -> Iterator[Diagnostic]:
    """Every ``*_pallas`` kernel defined under kernels/ must be referenced
    by kernels/ops.py — an unwired kernel is dead code that silently drops
    out of the backend-parity matrix."""
    if not ctx.in_kernels or ctx.is_kernel_ops:
        return
    defs = [
        n
        for n in ctx.tree.body
        if isinstance(n, ast.FunctionDef) and n.name.endswith("_pallas")
    ]
    if not defs:
        return
    ops_path = ctx.path.parent / "ops.py"
    if ops_path not in _OPS_SOURCE_CACHE:
        try:
            _OPS_SOURCE_CACHE[ops_path] = ops_path.read_text()
        except OSError:
            _OPS_SOURCE_CACHE[ops_path] = ""
    ops_src = _OPS_SOURCE_CACHE[ops_path]
    if not ops_src:
        return  # standalone kernel module (fixtures): nothing to wire into
    for fn in defs:
        if fn.name not in ops_src:
            yield ctx.diag(
                "KERN003",
                fn,
                f"'{fn.name}' is defined but never referenced by "
                "kernels/ops.py — unreachable from the dispatcher",
            )


# ---------------------------------------------------------------------------
# OpStats conventions
# ---------------------------------------------------------------------------


def _extra_stores(tree: ast.AST) -> Iterator[Tuple[ast.AST, str, ast.AST]]:
    """(node, key, value) for every string-literal store into an OpStats
    ``extra`` dict: subscript assignment or .update({...}) literal."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if (
                    isinstance(t, ast.Subscript)
                    and isinstance(t.value, ast.Attribute)
                    and t.value.attr == "extra"
                    and isinstance(t.slice, ast.Constant)
                    and isinstance(t.slice.value, str)
                ):
                    yield node, t.slice.value, node.value
        elif (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "update"
            and isinstance(node.func.value, ast.Attribute)
            and node.func.value.attr == "extra"
            and node.args
            and isinstance(node.args[0], ast.Dict)
        ):
            for k, v in zip(node.args[0].keys, node.args[0].values):
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    yield node, k.value, v


@rule("STAT001", "OpStats extra key is not snake_case")
def _stat001(ctx: FileContext) -> Iterator[Diagnostic]:
    """``stats.extra`` keys feed EXPLAIN ANALYZE and the serving metrics
    exporter verbatim; a camelCase or dashed key breaks every downstream
    grep and dashboard convention."""
    for node, key, _value in _extra_stores(ctx.tree):
        if not _SNAKE.match(key):
            yield ctx.diag(
                "STAT001",
                node,
                f"extra key '{key}' is not snake_case",
            )


@rule("STAT002", "OpStats _ms/_bytes counter assigned a non-numeric value")
def _stat002(ctx: FileContext) -> Iterator[Diagnostic]:
    """Keys ending in ``_ms``/``_bytes`` are numeric counters by contract:
    the profiler sums and formats them. A string value poisons the
    aggregation one query later."""
    for node, key, value in _extra_stores(ctx.tree):
        if not key.endswith(("_ms", "_bytes")):
            continue
        is_stringy = (
            (isinstance(value, ast.Constant) and isinstance(value.value, str))
            or isinstance(value, ast.JoinedStr)
            or (
                isinstance(value, ast.Call)
                and isinstance(value.func, ast.Name)
                and value.func.id in ("str", "repr", "format")
            )
        )
        if is_stringy:
            yield ctx.diag(
                "STAT002",
                node,
                f"counter '{key}' must stay numeric; assigning a string "
                "breaks profiler aggregation",
            )


# ---------------------------------------------------------------------------
# dtype discipline (kernels/ + vecops.py)
# ---------------------------------------------------------------------------

# constructor -> index of its positional dtype slot
_DTYPE_CTORS = {"zeros": 1, "ones": 1, "empty": 1, "full": 2, "array": 1}


@rule("DTYPE001", "un-dtyped numpy constructor on a kernel hot path")
def _dtype001(ctx: FileContext) -> Iterator[Diagnostic]:
    """In kernels/ and vecops.py a constructor without an explicit dtype
    silently produces float64 (or a platform-default int), upcasting the
    int32 data plane and doubling memory traffic on the hot path."""
    if not (ctx.in_kernels or ctx.is_vecops):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call) or not isinstance(node.func, ast.Attribute):
            continue
        ctor = node.func.attr
        if ctor not in _DTYPE_CTORS:
            continue
        mod = node.func.value
        if not (isinstance(mod, ast.Name) and mod.id in ("np", "jnp", "numpy")):
            continue
        if any(kw.arg == "dtype" for kw in node.keywords):
            continue
        if len(node.args) > _DTYPE_CTORS[ctor]:
            continue  # positional dtype slot filled
        yield ctx.diag(
            "DTYPE001",
            node,
            f"{mod.id}.{ctor}(...) without an explicit dtype defaults to "
            "float64 on the kernel hot path",
        )


@rule("DTYPE002", "builtin float/int used as a dtype")
def _dtype002(ctx: FileContext) -> Iterator[Diagnostic]:
    """``dtype=float`` / ``astype(int)`` mean float64/platform-int — write
    the numpy scalar type (np.float32, np.int32, ...) so the width is a
    reviewed decision, not an accident."""
    if not (ctx.in_kernels or ctx.is_vecops):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if (
                kw.arg == "dtype"
                and isinstance(kw.value, ast.Name)
                and kw.value.id in ("float", "int")
            ):
                yield ctx.diag(
                    "DTYPE002",
                    node,
                    f"dtype={kw.value.id} is the 64-bit builtin; name the "
                    "numpy width explicitly",
                )
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "astype"
            and node.args
            and isinstance(node.args[0], ast.Name)
            and node.args[0].id in ("float", "int")
        ):
            yield ctx.diag(
                "DTYPE002",
                node,
                f"astype({node.args[0].id}) upcasts to the 64-bit builtin; "
                "name the numpy width explicitly",
            )


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def iter_py_files(paths: Iterable[Path]) -> Iterator[Path]:
    for p in paths:
        p = Path(p)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if any(part in DEFAULT_EXCLUDES for part in f.parts):
                    continue
                yield f


def lint_file(
    path: Path, select: Optional[Iterable[str]] = None
) -> List[Diagnostic]:
    """All diagnostics for one file (fixture tests call this directly —
    it does not apply the default-walk excludes)."""
    path = Path(path)
    try:
        source = path.read_text()
    except (OSError, UnicodeDecodeError) as e:
        return [Diagnostic("PARSE", path.as_posix(), 1, f"unreadable: {e}")]
    try:
        ctx = FileContext(path, source)
    except SyntaxError as e:
        return [
            Diagnostic("PARSE", path.as_posix(), e.lineno or 1, f"syntax error: {e.msg}")
        ]
    wanted = set(select) if select else set(RULES)
    out: List[Diagnostic] = []
    for rule_id in sorted(wanted):
        r = RULES.get(rule_id)
        if r is None:
            continue
        for d in r.check(ctx):
            if not ctx.suppressed(d.rule, d.line):
                out.append(d)
    out.sort(key=lambda d: (d.path, d.line, d.rule))
    return out


def lint_paths(
    paths: Iterable[Path], select: Optional[Iterable[str]] = None
) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    for f in iter_py_files(paths):
        out.extend(lint_file(f, select=select))
    return out


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="barqlint: static invariant checks for the batch engine",
    )
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument(
        "--select",
        help="comma-separated rule ids to run (default: all)",
        default=None,
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog and exit"
    )
    args = ap.parse_args(argv)
    if args.list_rules:
        for rid in sorted(RULES):
            print(f"{rid}  {RULES[rid].summary}")
        return 0
    if not args.paths:
        ap.error("the following arguments are required: paths")
    select = args.select.split(",") if args.select else None
    diags = lint_paths([Path(p) for p in args.paths], select=select)
    for d in diags:
        print(d.render())
    n_files = len(list(iter_py_files([Path(p) for p in args.paths])))
    print(
        f"barqlint: {len(diags)} finding(s) in {n_files} file(s), "
        f"{len(RULES)} rules"
    )
    return 1 if diags else 0


if __name__ == "__main__":
    sys.exit(main())
