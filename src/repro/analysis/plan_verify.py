"""PlanVerifier — post-planning structural invariant checks (DESIGN.md §16).

The planner maintains several invariants by construction: merge joins only
over inputs sorted by the join variable, SIP annotations only on sides
where pruning is sound (`Planner._push_sip`), grace/adaptive marks only
where the budget and order-safety walks permit, and a fingerprint +
cardinality estimate on every node. A planner regression that breaks one
of these doesn't fail at plan time — it surfaces as silently wrong results
(an unsorted merge join) or a latent crash three operators downstream.

``verify_plan`` re-derives each invariant from the plan alone and raises
``PlanInvariantError`` naming the offending node. The Engine runs it under
``EngineConfig.verify_plans`` (env ``BARQ_VERIFY_PLANS=1``) right after
planning, so CI can execute the whole suite with verification on.

The checks deliberately mirror — but do not call — the planner's own
walks: an independent re-derivation is what makes this a verifier rather
than a tautology.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Set, Tuple

from repro.core import planner as PL


class PlanInvariantError(RuntimeError):
    """A physical plan violates a structural invariant; the message names
    the offending node and the check that failed."""


@dataclasses.dataclass(frozen=True)
class PlanDiagnostic:
    check: str  # V-FP | V-SCHEMA | V-SORT | V-SIP | V-GRACE | V-ADAPTIVE
    node: str  # rendered node name, e.g. "PMergeJoin(?3)"
    message: str

    def render(self) -> str:
        return f"[{self.check}] {self.node}: {self.message}"


_CHILD_FIELDS = ("child", "left", "right", "probe", "build")


def _children(n: PL.Phys):
    for fld in _CHILD_FIELDS:
        c = getattr(n, fld, None)
        if isinstance(c, PL.PhysNode):
            yield c


def _node_name(n: PL.Phys) -> str:
    var = getattr(n, "var", None)
    if var is not None:
        return f"{type(n).__name__}(?{var})"
    keys = getattr(n, "keys", None)
    if keys:
        return f"{type(n).__name__}({','.join('?%d' % k for k in keys)})"
    return type(n).__name__


class PlanVerifier:
    def __init__(self, plan: PL.Phys):
        self.plan = plan
        self.diags: List[PlanDiagnostic] = []
        # sid -> (exporting join, list of leaves carrying the annotation)
        self._exports: Dict[int, PL.Phys] = {}
        self._consumers: Dict[int, List[PL.Phys]] = {}

    def verify(self) -> List[PlanDiagnostic]:
        self._walk(self.plan)
        self._check_adaptive(self.plan, order_needed=False)
        self._check_sip()
        return self.diags

    def _flag(self, check: str, node: PL.Phys, message: str) -> None:
        self.diags.append(PlanDiagnostic(check, _node_name(node), message))

    # -- per-node structural checks -----------------------------------------

    def _walk(self, n: PL.Phys) -> None:
        for c in _children(n):
            self._walk(c)
        self._check_identity(n)
        self._check_schema(n)
        self._check_sorted(n)
        self._check_grace(n)
        self._collect_sip(n)

    def _check_identity(self, n: PL.Phys) -> None:
        """Every node carries a fingerprint (feedback key) and a finite,
        non-negative cardinality estimate (costing/EXPLAIN input)."""
        if not n.fp:
            self._flag("V-FP", n, "node has no fingerprint; "
                       "annotate_fingerprints never ran over this plan")
        est = n.est_rows
        if not isinstance(est, (int, float)) or not math.isfinite(est) or est < 0:
            self._flag("V-FP", n, f"est_rows={est!r} is not a finite "
                       "non-negative number")

    def _check_schema(self, n: PL.Phys) -> None:
        """Variable coverage: every variable an operator consumes must be
        produced by its input — the translator would otherwise fail (or
        worse, index the wrong column) at runtime."""
        if isinstance(n, PL.PSort):
            if n.var not in PL.phys_vars(n.child):
                self._flag("V-SCHEMA", n,
                           f"sort var ?{n.var} not produced by its input")
        elif isinstance(n, PL.PMergeJoin):
            for side, sub in (("left", n.left), ("right", n.right)):
                if n.var not in PL.phys_vars(sub):
                    self._flag("V-SCHEMA", n,
                               f"join var ?{n.var} missing from the {side} input")
        elif isinstance(n, (PL.PLookupJoin,)):
            for side, sub in (("probe", n.probe), ("build", n.build)):
                if n.var not in PL.phys_vars(sub):
                    self._flag("V-SCHEMA", n,
                               f"join var ?{n.var} missing from the {side} input")
        elif isinstance(n, PL.PHashJoin):
            for k in n.keys:
                for side, sub in (("probe", n.probe), ("build", n.build)):
                    if k not in PL.phys_vars(sub):
                        self._flag("V-SCHEMA", n,
                                   f"join key ?{k} missing from the {side} input")
        elif isinstance(n, PL.PExtend):
            if n.var in PL.phys_vars(n.child):
                self._flag("V-SCHEMA", n,
                           f"BIND target ?{n.var} is already bound below")
        elif isinstance(n, PL.PProject):
            cv = set(PL.phys_vars(n.child))
            for v in n.vars:
                if v not in cv:
                    self._flag("V-SCHEMA", n,
                               f"projected var ?{v} not produced by its input")
        elif isinstance(n, PL.PGroup):
            cv = set(PL.phys_vars(n.child))
            for v in n.group_vars:
                if v not in cv:
                    self._flag("V-SCHEMA", n,
                               f"group var ?{v} not produced by its input")
            for a in n.aggs:
                if a.var is not None and a.var not in cv:
                    self._flag("V-SCHEMA", n,
                               f"aggregate input ?{a.var} not produced by its input")
        elif isinstance(n, PL.PDistinct):
            if (n.streaming_var is not None
                    and n.streaming_var not in PL.phys_vars(n.child)):
                self._flag("V-SCHEMA", n,
                           f"streaming var ?{n.streaming_var} not produced "
                           "by its input")
        elif isinstance(n, PL.PSlice):
            if n.offset < 0 or (n.limit is not None and n.limit < 0):
                self._flag("V-SCHEMA", n,
                           f"negative slice bounds limit={n.limit} "
                           f"offset={n.offset}")

    def _check_sorted(self, n: PL.Phys) -> None:
        """Sortedness claims vs consumer requirements: a merge join or
        streaming group/distinct over an input that is *not* actually
        sorted by the claimed variable produces silently wrong results."""
        if isinstance(n, PL.PMergeJoin):
            for side, sub in (("left", n.left), ("right", n.right)):
                sb = PL.phys_sorted_by(sub)
                if sb != n.var:
                    self._flag("V-SORT", n,
                               f"{side} input is sorted by "
                               f"{'nothing' if sb is None else '?%d' % sb}, "
                               f"but the merge join needs ?{n.var}")
        elif isinstance(n, PL.PGroup) and n.streaming and n.group_vars:
            if len(n.group_vars) != 1:
                self._flag("V-SORT", n,
                           "streaming grouping claims "
                           f"{len(n.group_vars)} group vars; only a single "
                           "sorted var can stream")
            elif PL.phys_sorted_by(n.child) != n.group_vars[0]:
                self._flag("V-SORT", n,
                           f"streaming grouping on ?{n.group_vars[0]} over an "
                           "input not sorted by it")
        elif isinstance(n, PL.PDistinct) and n.streaming_var is not None:
            if PL.phys_sorted_by(n.child) != n.streaming_var:
                self._flag("V-SORT", n,
                           f"streaming distinct on ?{n.streaming_var} over an "
                           "input not sorted by it")

    def _check_grace(self, n: PL.Phys) -> None:
        """Grace (partitioned / out-of-core) marks only where the budget
        walk's gating permits: a grace mark on an ineligible shape lowers
        to an operator that can't honor it (DESIGN.md §15)."""
        if isinstance(n, PL.PHashJoin) and n.grace:
            if not n.keys:
                self._flag("V-GRACE", n,
                           "grace build on a key-less (degenerate) hash join")
            if n.grace_parts < 2:
                self._flag("V-GRACE", n,
                           f"grace build with grace_parts={n.grace_parts} (< 2)")
        elif isinstance(n, PL.PGroup) and n.grace:
            if not n.group_vars:
                self._flag("V-GRACE", n, "partitioned grouping without group vars")
            if n.streaming:
                self._flag("V-GRACE", n,
                           "grace and streaming are mutually exclusive: "
                           "sorted runs reduce in-place without a budget")
            if n.grace_parts < 2:
                self._flag("V-GRACE", n,
                           f"partitioned grouping with grace_parts={n.grace_parts}")
        elif isinstance(n, PL.PDistinct) and n.grace:
            if n.streaming_var is not None:
                self._flag("V-GRACE", n,
                           "grace and streaming distinct are mutually exclusive")
            if n.grace_parts < 2:
                self._flag("V-GRACE", n,
                           f"partitioned distinct with grace_parts={n.grace_parts}")

    # -- adaptive-join gating (mirror of Planner._mark_adaptive) -------------

    def _check_adaptive(self, n: PL.Phys, order_needed: bool) -> None:
        """adaptive_ok only where NO ancestor consumes the join's output
        order — re-derived top-down, independently of the planner's walk."""
        if isinstance(n, PL.PMergeJoin):
            if n.adaptive_ok and order_needed:
                self._flag("V-ADAPTIVE", n,
                           "adaptive_ok on a merge join whose output order an "
                           "ancestor consumes; a mid-plan merge->hash switch "
                           "would break that consumer")
            self._check_adaptive(n.left, True)
            self._check_adaptive(n.right, True)
            return
        if isinstance(n, (PL.PSort, PL.POrderBy)):
            self._check_adaptive(n.child, False)
            return
        if isinstance(n, PL.PGroup):
            self._check_adaptive(n.child, n.streaming)
            return
        if isinstance(n, PL.PDistinct):
            self._check_adaptive(n.child, n.streaming_var is not None)
            return
        if isinstance(n, (PL.PFilter, PL.PHaving, PL.PProject, PL.PExtend,
                          PL.PSlice)):
            self._check_adaptive(n.child, order_needed)
            return
        if isinstance(n, (PL.PHashJoin, PL.PLookupJoin)):
            self._check_adaptive(n.probe, order_needed)
            self._check_adaptive(n.build, False)
            return
        if isinstance(n, (PL.PCross, PL.PUnion)):
            self._check_adaptive(n.left, False)
            self._check_adaptive(n.right, False)
            return
        for c in _children(n):
            self._check_adaptive(c, True)

    # -- SIP soundness (mirror of Planner._push_sip) -------------------------

    def _collect_sip(self, n: PL.Phys) -> None:
        if isinstance(n, (PL.PScan, PL.PPathExpand)):
            for ann in n.sip:
                self._consumers.setdefault(ann.sid, []).append(n)
        for ann in getattr(n, "sip_exports", ()):
            if ann.sid in self._exports:
                self._flag("V-SIP", n,
                           f"sip #{ann.sid} exported twice")
            self._exports[ann.sid] = n

    def _sound_leaves(self, n: PL.Phys, var: int, acc: Set[int]) -> None:
        """ids of leaves a prefilter on ``var`` may soundly reach from
        ``n`` — the read-only mirror of the planner's _push_sip descent."""
        if isinstance(n, (PL.PScan, PL.PPathExpand)):
            if var in n.pattern.vars():
                acc.add(id(n))
            return
        if isinstance(n, (PL.PSort, PL.PFilter, PL.PHaving, PL.PDistinct,
                          PL.POrderBy)):
            self._sound_leaves(n.child, var, acc)
            return
        if isinstance(n, PL.PExtend):
            if var != n.var:
                self._sound_leaves(n.child, var, acc)
            return
        if isinstance(n, PL.PProject):
            if var in n.vars:
                self._sound_leaves(n.child, var, acc)
            return
        if isinstance(n, PL.PGroup):
            if var in n.group_vars:
                self._sound_leaves(n.child, var, acc)
            return
        if isinstance(n, (PL.PUnion, PL.PCross)):
            self._sound_leaves(n.left, var, acc)
            self._sound_leaves(n.right, var, acc)
            return
        if isinstance(n, PL.PMergeJoin):
            if n.mode == "inner":
                self._sound_leaves(n.left, var, acc)
                self._sound_leaves(n.right, var, acc)
            elif n.mode in ("semi", "anti", "left_outer"):
                self._sound_leaves(n.left, var, acc)
            return
        if isinstance(n, (PL.PHashJoin, PL.PLookupJoin)):
            if n.mode == "inner":
                self._sound_leaves(n.probe, var, acc)
                self._sound_leaves(n.build, var, acc)
            elif n.mode in ("semi", "anti", "left_outer"):
                self._sound_leaves(n.probe, var, acc)
            return
        # PSlice / PPathScan: a prefilter must never cross (pruning below a
        # LIMIT changes which rows survive it)

    def _check_sip(self) -> None:
        for sid, leaves in self._consumers.items():
            join = self._exports.get(sid)
            if join is None:
                for leaf in leaves:
                    self._flag("V-SIP", leaf,
                               f"consumes sip #{sid} that no join exports; "
                               "the prefilter would wait forever")
                continue
            ann = next(a for a in join.sip_exports if a.sid == sid)
            if join.mode not in ("inner", "semi"):
                self._flag("V-SIP", join,
                           f"sip #{sid} exported from a {join.mode} join; "
                           "only inner/semi build sides are summarizable")
            if isinstance(join, PL.PHashJoin):
                if ann.var not in join.keys:
                    self._flag("V-SIP", join,
                               f"sip #{sid} on ?{ann.var}, which is not a "
                               "join key")
                probe_side = join.probe
            else:  # PMergeJoin
                if ann.var != join.var:
                    self._flag("V-SIP", join,
                               f"sip #{sid} on ?{ann.var}, but the merge "
                               f"join key is ?{join.var}")
                exportable = isinstance(join.right, PL.PSort) or (
                    isinstance(join.right, PL.PScan)
                    and join.right.sort_var == join.var
                )
                if not exportable:
                    self._flag("V-SIP", join,
                               f"sip #{sid} summarizes a build side that is "
                               "neither a Sort nor a sorted scan — nothing "
                               "materializes the summary")
                probe_side = join.left
            sound: Set[int] = set()
            self._sound_leaves(probe_side, ann.var, sound)
            for leaf in leaves:
                if id(leaf) not in sound:
                    self._flag("V-SIP", leaf,
                               f"carries sip #{sid} outside the exporting "
                               "join's sound (probe/left) region — pruning "
                               "here can drop surviving rows")
        for sid, join in self._exports.items():
            if sid not in self._consumers:
                self._flag("V-SIP", join,
                           f"exports sip #{sid} that no leaf consumes")


def verify_plan(plan: PL.Phys, collect: bool = False) -> List[PlanDiagnostic]:
    """Verify a physical plan. Returns the diagnostics list; unless
    ``collect`` is set, any finding raises ``PlanInvariantError`` naming
    the first offending node."""
    diags = PlanVerifier(plan).verify()
    if diags and not collect:
        head = diags[0]
        more = f" (+{len(diags) - 1} more)" if len(diags) > 1 else ""
        raise PlanInvariantError(head.render() + more)
    return diags
