"""Correctness tooling for the batch engine (DESIGN.md §16).

Three layers, each machine-checking a contract that previously lived only
in review:

  * ``repro.analysis.lint`` — **barqlint**, an AST-based static analyzer
    over the source tree: pool ownership discipline, kernel-registry
    discipline, OpStats conventions, dtype discipline. Run as
    ``python -m repro.analysis.lint src/``.
  * ``repro.analysis.plan_verify`` — **PlanVerifier**, a post-planning
    structural checker the Engine runs under ``EngineConfig.verify_plans``:
    sortedness claims, SIP soundness, grace/adaptive gating, fingerprint
    and schema coverage.
  * ``repro.analysis.sanitize`` — **pool sanitizer**, a runtime shadow
    ownership tracker enabled by ``EngineConfig.sanitize``: poisoned
    releases, double-release / use-after-release errors attributed to the
    allocating operator, and leak reports at drain.
"""

# Lazy re-exports: ``python -m repro.analysis.lint`` executes the package
# __init__ first, and an eager ``from .lint import ...`` here would leave a
# half-initialized module in sys.modules for runpy to warn about.
_EXPORTS = {
    "Diagnostic": "lint",
    "RULES": "lint",
    "lint_file": "lint",
    "lint_paths": "lint",
    "PlanInvariantError": "plan_verify",
    "verify_plan": "plan_verify",
    "PoolSanitizer": "sanitize",
    "SanitizeError": "sanitize",
    "SanitizingBatchPool": "sanitize",
}


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f"repro.analysis.{mod}"), name)


__all__ = [
    "Diagnostic",
    "RULES",
    "lint_file",
    "lint_paths",
    "PlanInvariantError",
    "verify_plan",
    "PoolSanitizer",
    "SanitizeError",
    "SanitizingBatchPool",
]
