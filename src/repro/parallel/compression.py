"""Gradient compression for the DP all-reduce: error-feedback int8
quantization (1-bit-Adam family; DESIGN.md §5).

Wraps a loss's gradient tree: each leaf is quantized to int8 with a
per-leaf fp32 scale before the cross-replica psum, dequantized after, and
the quantization residual is carried to the next step (error feedback keeps
the compressed SGD unbiased in the limit). 4x wire reduction on the DP
gradient traffic; enable per-config (``grad_compression='int8_ef'``) for
the collective-bound cells.

Implemented as explicit functions so it can run inside shard_map (manual
psum) or as a host-level transform in the single-host trainer.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residuals):
    """(quantized tree, scales tree, new residuals). residuals carries the
    error-feedback state (same structure as grads, fp32)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return q, s, corrected - deq

    qs = jax.tree.map(lambda g, r: one(g, r)[0], grads, residuals)
    ss = jax.tree.map(lambda g, r: one(g, r)[1], grads, residuals)
    new_r = jax.tree.map(lambda g, r: one(g, r)[2], grads, residuals)
    return qs, ss, new_r


def decompress_tree(qs, ss, like):
    return jax.tree.map(
        lambda q, s, l: dequantize_int8(q, s).astype(l.dtype), qs, ss, like
    )


def psum_compressed(grads, residuals, axis_name: str):
    """Inside shard_map: error-feedback int8 all-reduce of a gradient tree.
    int8 payloads are psum'd as int32 partial sums (hardware all-reduces
    integers exactly), then rescaled by the shared max-scale."""
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        # shared scale across replicas so the integer sum is coherent
        local_max = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12)
        scale = jax.lax.pmax(local_max, axis_name) / 127.0
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, axis_name)
        mean = total.astype(jnp.float32) * scale / n
        residual = corrected - q.astype(jnp.float32) * scale
        return mean.astype(g.dtype), residual

    means = jax.tree.map(lambda g, r: one(g, r)[0], grads, residuals)
    new_r = jax.tree.map(lambda g, r: one(g, r)[1], grads, residuals)
    return means, new_r


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
