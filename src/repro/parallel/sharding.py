"""Mesh-axis abstraction + partition-spec helpers.

Models describe sharding against *logical* roles — dp (data-parallel
batch axis), mp (model/tensor-parallel axis) — and MeshAxes binds the roles
to the concrete mesh: ("data","model") single-pod, ("pod","data","model")
multi-pod. The pod axis extends data parallelism across pods (DESIGN.md §5),
so dp = ("pod","data") on the multi-pod mesh and every spec written against
roles works on both meshes unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: Tuple[str, ...] = ("data",)
    mp: str = "model"

    @staticmethod
    def for_mesh(mesh) -> "MeshAxes":
        names = tuple(mesh.axis_names)
        if "pod" in names:
            return MeshAxes(dp=("pod", "data"), mp="model")
        return MeshAxes(dp=("data",), mp="model")

    def resolve(self, role: Optional[str]):
        """role -> concrete axis entry for PartitionSpec."""
        if role is None:
            return None
        if role == "dp":
            return self.dp if len(self.dp) > 1 else self.dp[0]
        if role == "mp":
            return self.mp
        if role == "dp+mp":  # fully flattened (e.g. GNN node dim)
            return tuple(self.dp) + (self.mp,)
        raise ValueError(role)


def spec(axes: MeshAxes, *roles: Optional[str]) -> PartitionSpec:
    """spec(axes, 'dp', None, 'mp') -> PartitionSpec over concrete axes."""
    return PartitionSpec(*[axes.resolve(r) for r in roles])


def constrain(x, axes: MeshAxes, *roles: Optional[str]):
    """Apply a logical sharding constraint inside jit. No-op outside a mesh
    context (single-device smoke tests)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover - older jax
        mesh = None
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(x, spec(axes, *roles))


def tree_spec(param_tree, rule_fn) -> dict:
    """Build a PartitionSpec tree by applying rule_fn(path, leaf) over the
    param tree. rule_fn returns a PartitionSpec."""
    flat = jax.tree_util.tree_flatten_with_path(param_tree)
    leaves, treedef = flat
    specs = []
    for path, leaf in leaves:
        keys = tuple(
            p.key if hasattr(p, "key") else str(p.idx) for p in path
        )
        specs.append(rule_fn(keys, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)
