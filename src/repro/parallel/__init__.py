from repro.parallel.sharding import MeshAxes, constrain, spec  # noqa: F401
