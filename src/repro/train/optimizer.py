"""AdamW + schedules, implemented directly (no optax dependency).

Optimizer state mirrors the parameter tree (same PartitionSpecs), so it
shards and checkpoints with the params. Global-norm clipping runs in fp32;
moments are fp32 regardless of param dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptimizerConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)  # noqa: E731
    return {"mu": zeros(params), "nu": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.float32(0)))


def adamw_update(cfg: OptimizerConfig, params, grads, state,
                 decay_mask: Optional[Callable[[Tuple[str, ...]], bool]] = None):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    flat_p = jax.tree_util.tree_flatten_with_path(params)
    paths = [tuple(getattr(k, "key", str(getattr(k, "idx", k))) for k in path)
             for path, _ in flat_p[0]]

    def upd(p, g, mu, nu, path):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        step_ = mhat / (jnp.sqrt(nhat) + cfg.eps)
        do_decay = True if decay_mask is None else decay_mask(path)
        wd = cfg.weight_decay if (do_decay and p.ndim >= 2) else 0.0
        newp = p.astype(jnp.float32) - lr * (step_ + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), mu, nu

    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_mu = treedef.flatten_up_to(state["mu"])
    leaves_nu = treedef.flatten_up_to(state["nu"])
    out_p, out_mu, out_nu = [], [], []
    for p, g, mu, nu, path in zip(leaves_p, leaves_g, leaves_mu, leaves_nu, paths):
        np_, nmu, nnu = upd(p, g, mu, nu, path)
        out_p.append(np_)
        out_mu.append(nmu)
        out_nu.append(nnu)
    new_params = jax.tree_util.tree_unflatten(treedef, out_p)
    new_state = {
        "mu": jax.tree_util.tree_unflatten(treedef, out_mu),
        "nu": jax.tree_util.tree_unflatten(treedef, out_nu),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
