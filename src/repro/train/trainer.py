"""Fault-tolerant training loop (DESIGN.md §5).

Production posture on one box:
  * checkpoint/restart — CheckpointManager saves every ``ckpt_every``
    steps (async); on (re)start the trainer restores the latest complete
    checkpoint and the data pipeline fast-forwards (step-keyed seeds,
    nothing to replay);
  * preemption — SIGTERM/SIGINT trigger a final synchronous save before
    exit (the TPU preemption-notice pattern);
  * straggler/hang watchdog — a step exceeding ``watchdog_factor`` × the
    trailing median is logged with its factor (on a real fleet this feeds
    the scheduler's hot-swap of the slow host);
  * crash-retry — transient step failures (OOM, interconnect) retry from
    the last checkpoint up to ``max_restarts`` times (simulated fault
    injection in tests via ``fault_hook``).
"""

from __future__ import annotations

import dataclasses
import logging
import signal
import statistics
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax

from repro.train.checkpoint import CheckpointManager

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    log_every: int = 10
    watchdog_factor: float = 3.0
    max_restarts: int = 2


class Trainer:
    """Drives jitted train_step(state, batch) -> (state, metrics)."""

    def __init__(
        self,
        cfg: TrainerConfig,
        train_step: Callable,
        init_state: Callable[[], Any],
        batches: Callable[[int], Any],  # step -> batch (deterministic, resumable)
        state_shardings=None,
        fault_hook: Optional[Callable[[int], None]] = None,
    ):
        self.cfg = cfg
        self.train_step = train_step
        self.init_state = init_state
        self.batches = batches
        self.state_shardings = state_shardings
        self.fault_hook = fault_hook
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts)
        self._preempted = False
        self.step_times: list = []
        self.metrics_history: list = []

    # -- preemption ------------------------------------------------------------

    def _install_signal_handlers(self):
        def handler(signum, frame):
            log.warning("preemption signal %s received; checkpointing", signum)
            self._preempted = True

        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # not the main thread (tests)

    # -- main loop ------------------------------------------------------------

    def _restore_or_init(self):
        latest = self.ckpt.latest_step()
        state = self.init_state()
        if latest is not None:
            like = jax.tree.map(lambda x: x, state)
            state, manifest = self.ckpt.restore(latest, like, self.state_shardings)
            log.info("restored checkpoint at step %d", latest)
            return state, int(manifest["step"])
        return state, 0

    def run(self) -> Dict[str, Any]:
        self._install_signal_handlers()
        restarts = 0
        while True:
            try:
                return self._run_once()
            except KeyboardInterrupt:
                raise
            except Exception as e:  # transient failure -> restart from ckpt
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise
                log.warning("step failed (%s); restart %d/%d from checkpoint",
                            e, restarts, self.cfg.max_restarts)

    def _run_once(self) -> Dict[str, Any]:
        state, start_step = self._restore_or_init()
        last_metrics: Dict[str, Any] = {}
        for step in range(start_step, self.cfg.total_steps):
            if self.fault_hook is not None:
                self.fault_hook(step)  # test-injected failures
            t0 = time.perf_counter()
            batch = self.batches(step)
            state, metrics = self.train_step(state, batch)
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            self._watchdog(step, dt)
            last_metrics = {k: float(v) for k, v in metrics.items()}
            self.metrics_history.append({"step": step + 1, **last_metrics})
            if (step + 1) % self.cfg.log_every == 0:
                log.info("step %d: %s (%.3fs)", step + 1, last_metrics, dt)
            if (step + 1) % self.cfg.ckpt_every == 0 or self._preempted:
                self.ckpt.save(step + 1, state)
                if self._preempted:
                    self.ckpt.wait()
                    log.warning("exiting after preemption checkpoint at %d", step + 1)
                    return {"step": step + 1, "preempted": True, **last_metrics}
        self.ckpt.save(self.cfg.total_steps, state)
        self.ckpt.wait()
        return {"step": self.cfg.total_steps, "preempted": False, **last_metrics}

    def _watchdog(self, step: int, dt: float) -> None:
        hist = self.step_times[-50:-1]
        if len(hist) >= 5:
            med = statistics.median(hist)
            if dt > self.cfg.watchdog_factor * med:
                log.warning(
                    "straggler watchdog: step %d took %.3fs (%.1fx median %.3fs)",
                    step, dt, dt / med, med,
                )
