"""Sharded, async, fault-tolerant checkpointing (DESIGN.md §5).

Layout per step:
    <dir>/step_000123.tmp/        — written first
        proc00.npz                — this process's param/opt shards
        manifest.json             — tree structure, leaf shapes/dtypes,
                                    PartitionSpecs, mesh shape, step
    <dir>/step_000123/            — atomic rename after all writes land

Restore picks the latest *complete* directory (a crash mid-write leaves
only .tmp, which is ignored and garbage-collected), so a preempted job
always resumes from a consistent state. Saving runs on a background thread
(training continues; ``wait()`` joins before the next save or exit).
Elastic restore: leaves are saved as full (host-gathered) arrays at
laptop scale, so any new mesh shape can re-shard them on load — the
resharding path 512→256/1024 chips would stream shard-wise through the
same manifest instead.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        self.wait()
        # pull to host synchronously (cheap at laptop scale; async device
        # donation would snapshot before dispatching the next step)
        flat, _ = _flatten(tree)
        host = [(k, np.asarray(v)) for k, v in flat]
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def _write(self, step: int, host: List[Tuple[str, np.ndarray]], extra: Dict):
        try:
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "proc00.npz"), **dict(host))
            manifest = {
                "step": step,
                "keys": [k for k, _ in host],
                "shapes": {k: list(v.shape) for k, v in host},
                "dtypes": {k: str(v.dtype) for k, v in host},
                "time": time.time(),
                "extra": extra,
                "n_processes": 1,
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)
        # drop orphaned tmp dirs from crashes
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int], like_tree, shardings=None):
        """Restore into the structure of ``like_tree`` (shapes must match);
        device_put with ``shardings`` re-shards for the current mesh
        (elastic restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        data = np.load(os.path.join(path, "proc00.npz"))
        flat, treedef = _flatten(like_tree)
        leaves = []
        for key, like in flat:
            arr = data[key]
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"checkpoint leaf {key} shape {arr.shape} != expected {like.shape}"
                )
            leaves.append(arr.astype(like.dtype))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        return tree, manifest
