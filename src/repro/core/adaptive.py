"""Adaptive batch sizing (paper §3.4).

A scan has no information on how its parent will consume the batch; a fixed
batch size overfetches badly under skip-heavy consumers (merge joins in
OLTP-style plans) and underfetches under scan-heavy consumers (pipeline
breakers like Sort). BARQ observes the pattern of next()/skip()/reset()
calls the operator *receives* and adapts the number of rows produced per
next() call.

Controller policy (bucketed to powers of two for the static-shape compile
cache, DESIGN.md §2):
  * every skip() between two next() calls is evidence of selective
    consumption -> shrink (halve);
  * a streak of next() calls with no intervening skip() is evidence of
    full consumption -> grow (double), saturating at ``max_size``.
The paper's profile (Listing 3c vs 3b) shows exactly this behaviour: scans
under a skip-heavy merge join settle small, pipeline-breaker inputs grow to
the cap. ``reset()`` restores the initial size (a new consumer epoch).
"""

from __future__ import annotations

from repro.core.batch import MAX_BATCH, MIN_BATCH


class AdaptiveBatchSizer:
    def __init__(
        self,
        initial: int = 64,
        min_size: int = MIN_BATCH,
        max_size: int = MAX_BATCH,
        grow_streak: int = 2,
        enabled: bool = True,
    ) -> None:
        self.min_size = min_size
        self.max_size = max_size
        self.initial = max(min(initial, max_size), min_size)
        self.grow_streak = grow_streak
        self.enabled = enabled
        self._size = self.initial
        self._streak = 0  # consecutive next() calls without a skip()
        self._skipped_since_next = False

    @property
    def size(self) -> int:
        return self._size

    def on_next(self) -> int:
        """Called when the operator receives next(); returns rows to produce."""
        if not self.enabled:
            return self._size
        if self._skipped_since_next:
            self._skipped_since_next = False
            self._streak = 0
            self._size = max(self.min_size, self._size // 2)
        else:
            self._streak += 1
            if self._streak >= self.grow_streak:
                self._streak = 0
                self._size = min(self.max_size, self._size * 2)
        return self._size

    def on_skip(self) -> None:
        self._skipped_since_next = True

    def on_reset(self) -> None:
        self._size = self.initial
        self._streak = 0
        self._skipped_since_next = False
