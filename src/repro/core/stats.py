"""Cardinality estimation for the cost-based optimizer (paper §2.2.2).

Stardog's estimation stack: precomputed graph statistics (predicate
cardinality, distinct subjects/objects per predicate), characteristic sets
enhanced with count-min sketches, and independence heuristics. We implement
the same shape at laptop scale:

  * exact pattern ranges (the sorted indexes give them in O(log n));
  * per-predicate distinct-subject/object counts;
  * characteristic sets (the set of predicates each subject has) for
    star-join estimation [Neumann & Moerkotte, ICDE'11];
  * a count-min sketch over subject frequencies for bound-term estimates
    on skewed graphs [Cormode & Muthukrishnan '05].

Join estimates use the System-R containment rule
|A ⋈_v B| ≈ |A|·|B| / max(d_A(v), d_B(v)).
"""

from __future__ import annotations

import math
from collections import Counter
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.algebra import K, PathPattern, TriplePattern, V
from repro.core.paths.expr import PAlt, PClosure, PInv, PLink, PSeq
from repro.core.storage import INDEX_ORDERS, QuadStore

# depth cap for closure estimation: BFS deeper than this contributes little
# to the *estimate* (real evaluation is exact; this only prices plans)
CLOSURE_DEPTH_CAP = 16


class CountMinSketch:
    def __init__(self, width: int = 2048, depth: int = 4, seed: int = 7):
        rng = np.random.RandomState(seed)
        self.width = width
        self.depth = depth
        self.salts = rng.randint(1, 2**31 - 1, size=depth).astype(np.uint32)
        self.table = np.zeros((depth, width), dtype=np.int64)

    def _rows(self, keys: np.ndarray) -> np.ndarray:
        keys = keys.astype(np.uint32)
        return np.stack(
            [((keys * s) >> np.uint32(16)) % self.width for s in self.salts]
        )

    def add_many(self, keys: np.ndarray) -> None:
        rows = self._rows(keys)
        for d in range(self.depth):
            np.add.at(self.table[d], rows[d], 1)

    def estimate(self, key: int) -> int:
        rows = self._rows(np.asarray([key]))
        return int(min(self.table[d, rows[d, 0]] for d in range(self.depth)))


class GraphStats:
    def __init__(self, store: QuadStore):
        self.store = store
        spoc = store.index_array("spoc")
        self.n_quads = len(spoc)
        preds = spoc[:, 1]
        self.pred_count: Dict[int, int] = dict(
            zip(*[a.tolist() for a in np.unique(preds, return_counts=True)])
        )
        # distinct subjects/objects per predicate (posc is sorted by p,o,s)
        self.distinct_subj: Dict[int, int] = {}
        self.distinct_obj: Dict[int, int] = {}
        for p in self.pred_count:
            m = preds == p
            self.distinct_subj[p] = int(len(np.unique(spoc[m, 0])))
            self.distinct_obj[p] = int(len(np.unique(spoc[m, 2])))
        self.total_distinct_subj = int(len(np.unique(spoc[:, 0]))) or 1
        self.total_distinct_obj = int(len(np.unique(spoc[:, 2]))) or 1
        # characteristic sets: predicate-set signature -> #subjects
        self.char_sets: Counter = Counter()
        if self.n_quads:
            order = np.lexsort((preds, spoc[:, 0]))
            ss, pp = spoc[order, 0], preds[order]
            boundaries = np.nonzero(np.diff(ss))[0] + 1
            start = 0
            for end in list(boundaries) + [len(ss)]:
                sig = frozenset(np.unique(pp[start:end]).tolist())
                self.char_sets[sig] += 1
                start = end
        # count-min sketch over subject occurrence frequencies
        self.subj_sketch = CountMinSketch()
        if self.n_quads:
            self.subj_sketch.add_many(spoc[:, 0])

    # -- estimates ----------------------------------------------------------------

    def pattern_cardinality(self, pattern: TriplePattern) -> int:
        bound = self._bound(pattern)
        return self.store.pattern_cardinality(bound)

    def distinct_values(self, pattern: TriplePattern, var: int) -> int:
        """Estimated distinct bindings for ``var`` in the pattern's result."""
        card = max(self.pattern_cardinality(pattern), 1)
        p_id = (
            self.store.dict.lookup(pattern.p.term)
            if isinstance(pattern.p, K)
            else None
        )
        role = None
        for r, sl in enumerate((pattern.s, pattern.p, pattern.o)):
            if isinstance(sl, V) and sl.id == var:
                role = r
                break
        if role == 0:  # subject
            d = self.distinct_subj.get(p_id, self.total_distinct_subj)
        elif role == 2:  # object
            d = self.distinct_obj.get(p_id, self.total_distinct_obj)
        else:  # predicate or graph var
            d = max(len(self.pred_count), 1)
        return max(1, min(d, card))

    # -- property-path estimates (DESIGN.md §8) ------------------------------------

    @staticmethod
    def closure_multiplier(card: int, d_subj: int, d_obj: int) -> float:
        """Estimated |transitive closure| / |edge relation|.

        Replaces the old hard-coded 3-hop multiplier: with average
        out-degree k = card / d_subj, the per-source reachable set is the
        geometric series sum_{d=1..D} k^d capped at d_obj (every reachable
        node is some edge's object), with D = log_k(d_obj) capped at
        CLOSURE_DEPTH_CAP. For thin graphs (k <= 1, chains/trees) the
        series degenerates and the estimate is the capped average depth.
        """
        if card <= 0:
            return 1.0
        d_subj = max(d_subj, 1)
        d_obj = max(d_obj, 1)
        k = card / d_subj
        if k <= 1.0:
            reach = float(min(d_obj, CLOSURE_DEPTH_CAP))
        else:
            depth = min(math.log(d_obj, k), float(CLOSURE_DEPTH_CAP))
            reach = min(float(d_obj), k * (k ** depth - 1.0) / (k - 1.0))
        return max(reach / k, 1.0)

    def _path_expr_stats(self, expr) -> Tuple[float, int, int]:
        """(cardinality, distinct subjects, distinct objects) of a path
        expression's pair relation."""
        if isinstance(expr, PLink):
            pid = self.store.dict.lookup(expr.pred)
            if pid is None or pid not in self.pred_count:
                return 0.0, 1, 1
            return (
                float(self.pred_count[pid]),
                self.distinct_subj.get(pid, 1),
                self.distinct_obj.get(pid, 1),
            )
        if isinstance(expr, PInv):
            c, ds, do = self._path_expr_stats(expr.sub)
            return c, do, ds
        if isinstance(expr, PSeq):
            c, ds, do = self._path_expr_stats(expr.parts[0])
            for part in expr.parts[1:]:
                c2, ds2, do2 = self._path_expr_stats(part)
                c = self.join_cardinality(max(int(c), 1), max(int(c2), 1), do, ds2)
                do = do2
            return c, min(ds, int(max(c, 1))), min(do, int(max(c, 1)))
        if isinstance(expr, PAlt):
            c = ds = do = 0
            for part in expr.parts:
                c2, ds2, do2 = self._path_expr_stats(part)
                c, ds, do = c + c2, ds + ds2, do + do2
            return c, max(ds, 1), max(do, 1)
        if isinstance(expr, PClosure):
            c, ds, do = self._path_expr_stats(expr.sub)
            n_nodes = max(self.total_distinct_subj, self.total_distinct_obj)
            if expr.max_hops == 1:  # 'p?': sub ∪ identity
                return c + n_nodes, ds, do
            c = c * self.closure_multiplier(int(c), ds, do)
            if expr.min_hops == 0:  # 'p*': closure ∪ identity
                c += n_nodes
            return c, ds, do
        raise TypeError(type(expr))

    def path_cardinality(self, pattern: PathPattern) -> int:
        """Result-size estimate for a PathPattern, bound endpoints applied
        with the same containment logic as triple patterns."""
        card, ds, do = self._path_expr_stats(pattern.expr)
        if isinstance(pattern.s, K):
            card /= max(ds, 1)
        if isinstance(pattern.o, K):
            card /= max(do, 1)
        return max(int(card), 0)

    def path_distinct_values(self, pattern: PathPattern, var: int) -> int:
        card, ds, do = self._path_expr_stats(pattern.expr)
        d = 1
        if isinstance(pattern.s, V) and pattern.s.id == var:
            d = ds
        if isinstance(pattern.o, V) and pattern.o.id == var:
            d = max(d, do)
        return max(1, min(d, int(max(card, 1))))

    def star_cardinality(self, pred_ids: frozenset) -> int:
        """Characteristic-set estimate: subjects having all given predicates."""
        return sum(c for sig, c in self.char_sets.items() if pred_ids <= sig)

    def join_cardinality(
        self,
        card_a: int,
        card_b: int,
        d_a: int,
        d_b: int,
    ) -> float:
        return card_a * card_b / max(d_a, d_b, 1)

    def semi_join_cardinality(
        self,
        card_a: int,
        d_a: int,
        d_b: int,
        anti: bool = False,
    ) -> float:
        """Semi-join estimate under the same containment assumption as
        join_cardinality: the smaller key domain is contained in the
        larger, so a left row finds a match with probability
        min(d_a, d_b) / d_a. ``anti`` returns the complement. This is what
        semi/anti selectivity flows through (replacing the old flat
        left * 0.5, which ignored the right side entirely and skewed the
        hash-vs-merge strategy choice)."""
        match_frac = min(d_a, d_b) / max(d_a, 1)
        frac = (1.0 - match_frac) if anti else match_frac
        return card_a * min(max(frac, 0.0), 1.0)

    def _bound(self, pattern: TriplePattern):
        bound = [None, None, None, None]
        for role, sl in enumerate(
            (pattern.s, pattern.p, pattern.o, pattern.g or None)
        ):
            if isinstance(sl, K):
                tid = self.store.dict.lookup(sl.term)
                bound[role] = -1 if tid is None else tid
        return bound
