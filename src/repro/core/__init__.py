"""BARQ core: vectorized SPARQL query execution in JAX/numpy.

Public API:
    QuadStore     — sorted in-memory quad indexes + dictionary
    Engine        — parse/optimize/translate/execute pipeline
    EngineConfig  — engine selection (barq | legacy | mixed), adaptive batching
"""

from repro.core.dictionary import Dictionary  # noqa: F401
from repro.core.executor import Engine, EngineConfig, QueryResult  # noqa: F401
from repro.core.storage import QuadStore  # noqa: F401
