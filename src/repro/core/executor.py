"""Translator + executor (paper §4): physical plan → operator tree.

The translator decides, per operator, whether to instantiate the BARQ
(batch) or legacy (row) implementation, inserting batch↔row adapters at
engine boundaries (§4.2 Interoperability). Selection policy mirrors §4.2:

  * engine='barq'   — all-BARQ tree (every operator here has a batch impl);
  * engine='legacy' — all-row tree (the baseline of §5);
  * engine='mixed'  — BARQ for scans/joins/filters (the operators the paper
    vectorized first), row implementations for aggregation/sort/distinct,
    with adapters in between — demonstrating the gradual-migration path.

``Engine`` is the public entry point: parse/encode → optimize → translate →
execute → decode (the pipeline of Fig. 2).
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import algebra as A
from repro.core import telemetry
from repro.core import planner as PL
from repro.core.adaptive import AdaptiveBatchSizer
from repro.core.batch import NULL_ID, BatchPool, bucket_for
from repro.core.dictionary import Dictionary
from repro.core.legacy import operators as LOP
from repro.core.operators.adapters import BatchToRow, RowToBatch
from repro.core.operators.aggregate import (
    PartitionedDistinct,
    PartitionedGroupBy,
    SortDistinct,
    SortGroupBy,
    StreamingDistinct,
    StreamingGroupBy,
)
from repro.core.operators.base import BatchOperator, close_tree
from repro.core.operators.cross import CrossJoin
from repro.core.operators.lookup_join import LookupJoin
from repro.core.operators.merge_join import MergeJoin
from repro.core.operators.scan import IndexScan
from repro.core.operators.simple import (
    _UNSET as _UNSET_PROG,
    ExtendOp,
    FilterOp,
    ProjectOp,
    SliceOp,
    UnionOp,
)
from repro.core.operators.sort import OrderByOp, SortByVarOp
from repro.core.profiler import profile_tree
from repro.core.sip import SipFilter
from repro.core.stats import GraphStats
from repro.core.storage import QuadStore

AnyOp = Union[BatchOperator, LOP.RowOperator]


def _make_pool(cfg: EngineConfig) -> BatchPool:
    """The engine's buffer arena; under ``cfg.sanitize`` a shadow-tracked
    one that poisons releases and attributes leaks (DESIGN.md §16)."""
    if cfg.sanitize:
        from repro.analysis.sanitize import SanitizingBatchPool

        return SanitizingBatchPool(cfg.pool_max_per_bucket)
    return BatchPool(cfg.pool_max_per_bucket)


def _planner_program(p):
    """Planner program marker -> operator argument: None means the plan
    never went through a dictionary-aware planner (operators try one lazy
    compile); False means the planner already found the expression
    uncompilable (operators use the tree walk, no retry)."""
    if p is None:
        return _UNSET_PROG
    return p or None


@dataclasses.dataclass
class EngineConfig:
    engine: str = "barq"  # barq | legacy | mixed
    adaptive_batching: bool = True
    initial_batch: int = 64
    max_batch: int = 4096
    allow_child_skip: bool = True
    spill_dir: Optional[str] = None
    # join emission batch size: None = default (256); fixed-batch ablations
    # (bench_adaptive) set it so the joins follow the experiment too
    join_initial_batch: Optional[int] = None
    # binary-join physical strategy: None = cost-based (DESIGN.md §11),
    # "hash" / "merge" force one path (parity tests, ablations)
    join_strategy: Optional[str] = None
    # sideways information passing (DESIGN.md §12): None = cost-gated,
    # "on" = push prefilters wherever sound, "off" = disabled
    sip: Optional[str] = None
    # kernel backend for the bloom summaries (None = REPRO_KERNEL_BACKEND)
    sip_backend: Optional[str] = None
    # buffer pooling (DESIGN.md §2.3): recycle batch buffers through an
    # Engine-owned arena so steady-state execution is allocation-free and
    # repeated queries start warm
    pool_buffers: bool = True
    pool_max_per_bucket: int = 32
    # query telemetry (DESIGN.md §13): record a QueryTrace per execution
    # (spans + scoped kernel ledger + operator lane). Cheap enough to be
    # on by default; False skips trace creation entirely
    telemetry: bool = True
    # cardinality feedback (DESIGN.md §14): "off" = no history, "observe" =
    # record per-node actuals into the feedback store without touching
    # plans, "apply" = planner overrides estimates with observed history
    # (repeated misestimated queries re-plan with real cardinalities)
    cardinality_feedback: str = "off"
    # out-of-core execution (DESIGN.md §15): bytes of operator state a
    # pipeline breaker may keep resident. None = unlimited (pre-§15
    # behavior, plans byte-identical); set it and hash joins over budget
    # go grace (partition + spill to spill_dir), group-by/distinct run
    # partitioned.
    memory_budget: Optional[int] = None
    # mid-plan re-strategy (DESIGN.md §15): "on" defers order-insensitive
    # merge joins' sort-vs-hash choice to runtime (post-drain misestimate
    # check); "off" keeps the planner's static pick
    adaptive_join: str = "off"
    # correctness tooling (DESIGN.md §16). verify_plans runs the
    # PlanVerifier's structural invariant checks on every planned query;
    # sanitize wraps the buffer arena in shadow ownership tracking
    # (poisoned releases, use-after-release / double-release / leak
    # detection). Both default from the environment so CI can run the
    # whole suite hardened without touching call sites.
    verify_plans: bool = dataclasses.field(
        default_factory=lambda: os.environ.get("BARQ_VERIFY_PLANS", "") == "1"
    )
    sanitize: bool = dataclasses.field(
        default_factory=lambda: os.environ.get("BARQ_SANITIZE", "") == "1"
    )


class Translator:
    def __init__(self, store: QuadStore, cfg: EngineConfig,
                 pool: Optional[BatchPool] = None):
        self.store = store
        self.cfg = cfg
        # ``pool`` lets an Engine share one warm arena across queries;
        # standalone Translators keep making their own
        self.pool: Optional[BatchPool] = None
        if cfg.pool_buffers and cfg.engine != "legacy":
            self.pool = pool if pool is not None else _make_pool(cfg)
        # SIP runtime handles, keyed by annotation sid: consuming leaves
        # and exporting joins resolve to the same SipFilter object. Fresh
        # per Translator, so a plan reused through the server's plan cache
        # never sees stale summaries.
        self._sip_registry: Dict[int, SipFilter] = {}

    def _sip_filter(self, ann: "PL.PSipFilter") -> SipFilter:
        sf = self._sip_registry.get(ann.sid)
        if sf is None:
            sf = SipFilter(ann.var, sid=ann.sid, backend=self.cfg.sip_backend)
            self._sip_registry[ann.sid] = sf
        return sf

    # -- entry ------------------------------------------------------------------

    def translate(self, plan: PL.Phys) -> AnyOp:
        if self.cfg.engine == "legacy":
            return self._row(plan)
        op = self._build(plan)
        return op

    def _sizer(self, initial: Optional[int] = None) -> AdaptiveBatchSizer:
        # clamp the configured size to the compiled capacity buckets so
        # every operator's requests stay on the static-shape grid
        return AdaptiveBatchSizer(
            initial=min(
                bucket_for(initial or self.cfg.initial_batch),
                bucket_for(self.cfg.max_batch),
            ),
            max_size=self.cfg.max_batch,
            enabled=self.cfg.adaptive_batching,
        )

    def _join_sizer(self) -> AdaptiveBatchSizer:
        return self._sizer(self.cfg.join_initial_batch or 256)

    # -- engine-aware build (barq / mixed) ---------------------------------------------

    def _build(self, n: PL.Phys) -> AnyOp:
        """Lower one Phys node, stamping the planner's cardinality estimate
        (+ its source) and node fingerprint onto the produced operator's
        stats (EXPLAIN ANALYZE / feedback-recording input)."""
        op = self._build_node(n)
        est = getattr(n, "est_rows", 0.0)
        if est and op.stats.est_rows is None:
            op.stats.est_rows = float(est)
            op.stats.est_source = getattr(n, "est_source", "stats")
        if op.stats.node_fp is None:
            op.stats.node_fp = getattr(n, "fp", "") or None
        return op

    def _build_node(self, n: PL.Phys) -> AnyOp:
        mixed = self.cfg.engine == "mixed"
        if isinstance(n, PL.PScan):
            return IndexScan(
                self.store, n.pattern, n.sort_var, sizer=self._sizer(),
                pool=self.pool,
                sip_filters=[self._sip_filter(a) for a in n.sip],
            )
        if isinstance(n, PL.PPathExpand):
            # vectorized frontier engine (DESIGN.md §8): paths run on the
            # batch pipeline like every other leaf
            from repro.core.operators.path import PathExpand

            return PathExpand(
                self.store, n.pattern.expr, n.pattern.s, n.pattern.o,
                batch_size=self.cfg.max_batch, pool=self.pool,
                sip_filters=[self._sip_filter(a) for a in n.sip],
            )
        if isinstance(n, PL.PPathScan):
            # pre-§8 physical plans: row-based `+` bridged via adapter
            return RowToBatch(self._path_op(n), self.cfg.max_batch, pool=self.pool)
        if isinstance(n, PL.PSort):
            child = self._build(n.child)
            if mixed:
                # row-based sort consuming (possibly) batch input: adapter in
                # between, then back to batches at the pipeline break (§4.2)
                row_child = self._to_row(child)
                return RowToBatch(
                    LOP.RowSort(row_child, var=n.var), self.cfg.max_batch,
                    pool=self.pool,
                )
            return SortByVarOp(
                self._to_batch(child), n.var, self.cfg.max_batch, pool=self.pool
            )
        if isinstance(n, PL.PMergeJoin):
            if (
                self.cfg.adaptive_join == "on"
                and n.adaptive_ok
                and not n.sip_exports
                and isinstance(n.right, PL.PSort)
                and n.right.var == n.var
            ):
                # mid-plan re-strategy (DESIGN.md §15): the planned Sort is
                # a pipeline breaker, so defer sort-vs-hash until the build
                # input's true cardinality is known. Only sound when no
                # ancestor consumes this join's order (adaptive_ok) and no
                # SIP export hangs off the build window.
                from repro.core.operators.adaptive_join import AdaptiveMergeJoin

                return AdaptiveMergeJoin(
                    self._to_batch(self._build(n.left)),
                    self._to_batch(self._build(n.right.child)),
                    n.var,
                    mode=n.mode,
                    post_filter=n.post_filter,
                    dictionary=self.store.dict,
                    post_program=n.post_program,
                    pool=self.pool,
                    spill_dir=self.cfg.spill_dir,
                    est_build=getattr(n.right, "est_rows", 0.0) or 0.0,
                    memory_budget=self.cfg.memory_budget,
                )
            left = self._to_batch(self._build(n.left))
            right = self._to_batch(self._build(n.right))
            # SIP export (DESIGN.md §12): the build window summarizes as a
            # full bloom off a Sort's materialization, or a free O(1) code
            # range off a sorted scan; anything else stays pass-through
            for ann in n.sip_exports:
                sf = self._sip_filter(ann)
                if isinstance(right, SortByVarOp):
                    sf.bind(lambda r=right, v=ann.var: ("keys", r.sip_keys(v)))
                elif isinstance(right, IndexScan) and right.sorted_by() == ann.var:
                    sf.bind(lambda r=right: ("range",) + r.sip_code_range())
            return MergeJoin(
                left,
                right,
                n.var,
                mode=n.mode,
                post_filter=n.post_filter,
                dictionary=self.store.dict,
                sizer=self._join_sizer(),  # honors EngineConfig.join_initial_batch
                spill_dir=self.cfg.spill_dir,
                allow_child_skip=self.cfg.allow_child_skip,
                pool=self.pool,
                post_program=n.post_program,
            )
        if isinstance(n, PL.PLookupJoin):
            probe = self._to_batch(self._build(n.probe))
            build = self._to_batch(self._build(n.build))
            return LookupJoin(probe, build, n.var, n.mode, pool=self.pool)
        if isinstance(n, PL.PHashJoin):
            from repro.core.operators.hash_join import HashJoin

            op = HashJoin(
                self._to_batch(self._build(n.probe)),
                self._to_batch(self._build(n.build)),
                n.keys,
                mode=n.mode,
                post_filter=n.post_filter,
                dictionary=self.store.dict,
                sizer=self._join_sizer(),
                pool=self.pool,
                post_program=n.post_program,
                memory_budget=self.cfg.memory_budget,
                spill_dir=self.cfg.spill_dir,
                grace=True if n.grace else None,
                grace_parts=n.grace_parts,
            )
            # SIP export: reuse the materialized build layout as bloom keys
            for ann in n.sip_exports:
                self._sip_filter(ann).bind(
                    lambda j=op, v=ann.var: ("keys", j.sip_keys(v))
                )
            return op
        if isinstance(n, PL.PCross):
            return CrossJoin(
                self._to_batch(self._build(n.left)),
                self._to_batch(self._build(n.right)),
                pool=self.pool,
            )
        if isinstance(n, PL.PFilter):
            return FilterOp(
                self._to_batch(self._build(n.child)), n.expr, self.store.dict,
                program=_planner_program(n.program),
            )
        if isinstance(n, PL.PExtend):
            return ExtendOp(
                self._to_batch(self._build(n.child)), n.var, n.expr,
                self.store.dict, pool=self.pool,
                program=_planner_program(n.program),
            )
        if isinstance(n, PL.PProject):
            child = self._build(n.child)
            if isinstance(child, LOP.RowOperator):
                return LOP.RowProject(child, n.vars)
            return ProjectOp(child, n.vars, pool=self.pool)
        if isinstance(n, PL.PDistinct):
            child = self._build(n.child)
            if mixed:
                return LOP.RowDistinct(self._to_row(child))
            bchild = self._to_batch(child)
            if n.streaming_var is not None and bchild.sorted_by() == n.streaming_var:
                return StreamingDistinct(bchild, n.streaming_var)
            if n.grace:
                return PartitionedDistinct(
                    bchild, self.cfg.max_batch, pool=self.pool,
                    memory_budget=self.cfg.memory_budget,
                    spill_dir=self.cfg.spill_dir,
                    n_parts=n.grace_parts or 16,
                )
            return SortDistinct(bchild, self.cfg.max_batch)
        if isinstance(n, PL.PGroup):
            child = self._build(n.child)
            if mixed:
                return LOP.RowGroupBy(
                    self._to_row(child), n.group_vars, n.aggs, self.store.dict
                )
            bchild = self._to_batch(child)
            if n.streaming and len(n.group_vars) <= 1:
                gv = n.group_vars[0] if n.group_vars else None
                if gv is None or bchild.sorted_by() == gv:
                    return StreamingGroupBy(
                        bchild, gv, n.aggs, self.store.dict,
                        self.cfg.max_batch, pool=self.pool,
                    )
            if n.grace and n.group_vars:
                return PartitionedGroupBy(
                    bchild, n.group_vars, n.aggs, self.store.dict,
                    self.cfg.max_batch, pool=self.pool,
                    memory_budget=self.cfg.memory_budget,
                    spill_dir=self.cfg.spill_dir,
                    n_parts=n.grace_parts or 16,
                )
            return SortGroupBy(
                bchild, n.group_vars, n.aggs, self.store.dict,
                self.cfg.max_batch, pool=self.pool,
            )
        if isinstance(n, PL.PHaving):
            # HAVING: expression-VM filter over the aggregate output
            child = self._build(n.child)
            if isinstance(child, LOP.RowOperator):  # mixed: row grouping
                return LOP.RowFilter(child, n.expr, self.store.dict)
            return FilterOp(
                self._to_batch(child), n.expr, self.store.dict,
                program=_planner_program(n.program), name="Having",
            )
        if isinstance(n, PL.POrderBy):
            child = self._build(n.child)
            if mixed:
                return RowToBatch(
                    LOP.RowSort(
                        self._to_row(child), keys=n.keys, dictionary=self.store.dict
                    ),
                    self.cfg.max_batch,
                    pool=self.pool,
                )
            return OrderByOp(
                self._to_batch(child), n.keys, self.store.dict,
                self.cfg.max_batch, pool=self.pool,
            )
        if isinstance(n, PL.PSlice):
            child = self._build(n.child)
            if isinstance(child, LOP.RowOperator):
                return LOP.RowLimit(child, n.limit, n.offset)
            return SliceOp(child, n.limit, n.offset)
        if isinstance(n, PL.PUnion):
            return UnionOp(
                self._to_batch(self._build(n.left)),
                self._to_batch(self._build(n.right)),
                pool=self.pool,
            )
        raise TypeError(type(n))

    # -- adapters ------------------------------------------------------------------

    def _to_batch(self, op: AnyOp) -> BatchOperator:
        if isinstance(op, BatchOperator):
            return op
        return RowToBatch(op, self.cfg.max_batch, pool=self.pool)

    def _to_row(self, op: AnyOp) -> LOP.RowOperator:
        if isinstance(op, LOP.RowOperator):
            return op
        return BatchToRow(op)

    def _path_op(self, n: "PL.PPathScan") -> LOP.RowOperator:
        from repro.core.algebra import V
        from repro.core.legacy.property_path import RowTransitivePath

        pat = n.pattern
        if not isinstance(pat.p, A.K):
            raise ValueError(
                "property paths require a constant predicate, got a "
                "variable in the predicate position"
            )
        assert isinstance(pat.s, V) and isinstance(pat.o, V), (
            "bound-endpoint paths are planned as filters over the closure"
        )
        return RowTransitivePath(self.store, pat.p.term, pat.s.id, pat.o.id)

    # -- all-row build (legacy engine, §5 baseline) -----------------------------------------

    def _row(self, n: PL.Phys) -> LOP.RowOperator:
        op = self._row_node(n)
        est = getattr(n, "est_rows", 0.0)
        if est and op.stats.est_rows is None:
            op.stats.est_rows = float(est)
            op.stats.est_source = getattr(n, "est_source", "stats")
        if op.stats.node_fp is None:
            op.stats.node_fp = getattr(n, "fp", "") or None
        return op

    def _row_node(self, n: PL.Phys) -> LOP.RowOperator:
        if isinstance(n, PL.PScan):
            return LOP.RowScan(self.store, n.pattern, n.sort_var)
        if isinstance(n, PL.PPathExpand):
            from repro.core.legacy.property_path import RowPathScan

            return RowPathScan(
                self.store, n.pattern.expr, n.pattern.s, n.pattern.o
            )
        if isinstance(n, PL.PPathScan):
            return self._path_op(n)
        if isinstance(n, PL.PSort):
            return LOP.RowSort(self._row(n.child), var=n.var)
        if isinstance(n, PL.PMergeJoin):
            return LOP.RowMergeJoin(
                self._row(n.left), self._row(n.right), n.var, mode=n.mode,
                post_filter=n.post_filter, dictionary=self.store.dict,
            )
        if isinstance(n, PL.PLookupJoin):
            # legacy uses sort+merge for the same plan shape
            probe = self._row(n.probe)
            build = LOP.RowSort(self._row(n.build), var=n.var)
            if probe.sorted_by() != n.var:
                probe = LOP.RowSort(probe, var=n.var)
            return LOP.RowMergeJoin(probe, build, n.var, mode=n.mode)
        if isinstance(n, PL.PHashJoin):
            return LOP.RowHashJoin(
                self._row(n.probe), self._row(n.build), n.keys, mode=n.mode,
                post_filter=n.post_filter, dictionary=self.store.dict,
            )
        if isinstance(n, PL.PCross):
            # block nested loop via bind join over a constant
            left = self._row(n.left)
            rplan = n.right

            def factory(_code, rplan=rplan):
                return self._row(rplan)

            return _RowCross(left, lambda: self._row(rplan))
        if isinstance(n, PL.PFilter):
            return LOP.RowFilter(self._row(n.child), n.expr, self.store.dict)
        if isinstance(n, PL.PExtend):
            return _RowExtend(self._row(n.child), n.var, n.expr, self.store.dict)
        if isinstance(n, PL.PProject):
            return LOP.RowProject(self._row(n.child), n.vars)
        if isinstance(n, PL.PDistinct):
            return LOP.RowDistinct(self._row(n.child))
        if isinstance(n, PL.PGroup):
            return LOP.RowGroupBy(
                self._row(n.child), n.group_vars, n.aggs, self.store.dict
            )
        if isinstance(n, PL.PHaving):
            return LOP.RowFilter(self._row(n.child), n.expr, self.store.dict)
        if isinstance(n, PL.POrderBy):
            return LOP.RowSort(
                self._row(n.child), keys=n.keys, dictionary=self.store.dict
            )
        if isinstance(n, PL.PSlice):
            return LOP.RowLimit(self._row(n.child), n.limit, n.offset)
        if isinstance(n, PL.PUnion):
            return LOP.RowUnion(self._row(n.left), self._row(n.right))
        raise TypeError(type(n))


class _RowCross(LOP.RowOperator):
    def __init__(self, left: LOP.RowOperator, right_factory):
        self.left = left
        self.right_factory = right_factory
        self._lrow: Optional[dict] = None
        self._right: Optional[LOP.RowOperator] = None
        probe = right_factory()
        lv = tuple(left.var_ids())
        self._vars = lv + tuple(v for v in probe.var_ids() if v not in lv)
        super().__init__("Cross", "(row)")

    def var_ids(self):
        return self._vars

    def children(self):
        return [self.left]

    def _next(self):
        while True:
            if self._lrow is None:
                self._lrow = self.left.next_row()
                if self._lrow is None:
                    return None
                self._right = self.right_factory()
            r = self._right.next_row()
            if r is None:
                self._lrow = None
                continue
            out = dict(self._lrow)
            out.update(r)
            return out

    def _reset(self):
        self.left.reset()
        self._lrow = None


class _RowExtend(LOP.RowOperator):
    def __init__(self, child: LOP.RowOperator, var: int, expr, dictionary: Dictionary):
        from repro.core.expressions import eval_expr_values
        from repro.core.legacy.operators import _row_to_batch

        self.child, self.var, self.expr, self.dictionary = child, var, expr, dictionary
        self._eval = eval_expr_values
        self._to_batch = _row_to_batch
        super().__init__("Bind", "(row)")

    def var_ids(self):
        return self.child.var_ids() + (self.var,)

    def sorted_by(self):
        return self.child.sorted_by()

    def children(self):
        return [self.child]

    def _next(self):
        r = self.child.next_row()
        if r is None:
            return None
        b = self._to_batch(r, self.child.var_ids())
        vals, ok = self._eval(self.expr, b, self.dictionary)
        out = dict(r)
        if ok[0]:
            v = float(vals[0])
            out[self.var] = self.dictionary.encode(int(v) if v.is_integer() else v)
        return out

    def _reset(self):
        self.child.reset()


# ---------------------------------------------------------------------------
# public engine facade
# ---------------------------------------------------------------------------


class QueryResult:
    def __init__(self, var_table: A.VarTable, proj: Tuple[int, ...],
                 rows: np.ndarray, root: AnyOp,
                 pool: Optional[BatchPool] = None,
                 pool_base: Optional[Dict[str, int]] = None,
                 trace: Optional[telemetry.QueryTrace] = None):
        self.var_table = var_table
        self.proj = proj
        self.rows = rows  # (n, n_proj) int32 codes
        self.root = root
        self.pool = pool  # buffer arena (may be Engine-shared and warm)
        # pool counters bracketing this execution: profile()/pool_delta()
        # report this query's contribution, not the arena's lifetime
        # totals — and the end snapshot is frozen here so later queries on
        # the same warm arena can't leak into this result's report
        self.pool_base = pool_base
        self.pool_final: Optional[Dict[str, int]] = (
            dict(pool.stats()) if pool is not None else None
        )
        self.trace = trace  # QueryTrace, or None with telemetry disabled

    @property
    def n_rows(self) -> int:
        return int(self.rows.shape[0])

    def decoded(self, dictionary: Dictionary) -> List[dict]:
        names = [self.var_table.name(v) for v in self.proj]
        out = []
        for row in self.rows:
            out.append(
                {
                    nm: (None if c == NULL_ID else dictionary.decode(int(c)))
                    for nm, c in zip(names, row)
                }
            )
        return out

    def pool_delta(self) -> Dict[str, int]:
        """This query's pool counters (end-of-execution snapshot minus the
        pre-execution one)."""
        if self.pool_final is None:
            return {}
        from repro.core.profiler import _pool_delta

        return _pool_delta(self.pool_final, self.pool_base)

    def profile(self, analyze: bool = False) -> str:
        return profile_tree(self.root, self.var_table,
                            pool=self.pool_final,
                            pool_base=self.pool_base, analyze=analyze)

    def explain_analyze(self) -> str:
        """EXPLAIN ANALYZE report: per-operator actual vs planner-estimated
        rows with MISEST flags at q-error >= profiler.QERROR_FLAG."""
        return self.profile(analyze=True)


class Engine:
    """Public API: Engine(store).execute(plan | sparql_text)."""

    def __init__(self, store: QuadStore, cfg: Optional[EngineConfig] = None,
                 feedback: Optional[telemetry.CardinalityFeedback] = None):
        self.store = store
        self.cfg = cfg or EngineConfig()
        self.stats = GraphStats(store)
        mode = self.cfg.cardinality_feedback or "off"
        assert mode in ("off", "observe", "apply"), mode
        # cardinality feedback store (DESIGN.md §14): caller-shared (the
        # serving layer hands in its WorkloadRepository's store) or
        # Engine-owned. "observe" records without applying; "apply" also
        # hands it to the planner.
        self.feedback: Optional[telemetry.CardinalityFeedback] = None
        if mode != "off":
            self.feedback = (
                feedback if feedback is not None
                else telemetry.CardinalityFeedback()
            )
        assert (self.cfg.adaptive_join or "off") in ("off", "on")
        self.planner = PL.Planner(
            self.stats,
            barq_enabled=self.cfg.engine != "legacy",
            dictionary=store.dict,
            join_strategy=self.cfg.join_strategy,
            sip=self.cfg.sip,
            feedback=self.feedback if mode == "apply" else None,
            memory_budget=self.cfg.memory_budget,
            adaptive_join=self.cfg.adaptive_join,
        )
        # Engine-owned warm arena (DESIGN.md §2.3/§13): shared across this
        # Engine's queries so repeated traffic skips cold-start allocations.
        # Per-query attribution comes from pool_base snapshots, not resets.
        self.pool: Optional[BatchPool] = (
            _make_pool(self.cfg)
            if self.cfg.pool_buffers and self.cfg.engine != "legacy"
            else None
        )

    def plan_fingerprint(self) -> str:
        """Identity of every config knob that changes plan shape. Plan
        caches keyed on query text alone serve a stale shape after a
        config change — fold this in (see serve.query_server). Under
        ``cardinality_feedback="apply"`` the feedback store's version is
        folded in too: new observations must invalidate cached plans, or
        a repeated query would never re-plan against its history."""
        base = (
            f"{self.cfg.engine}|{self.cfg.join_strategy}|{self.cfg.sip}"
            f"|mb{self.cfg.memory_budget}|aj{self.cfg.adaptive_join}"
        )
        if self.cfg.cardinality_feedback == "apply" and self.feedback is not None:
            base += f"|fb{self.feedback.version}"
        return base

    def parse(self, text: str) -> Tuple[A.PlanNode, A.VarTable]:
        from repro.core.parser import parse_query

        return parse_query(text)

    def plan(self, node: A.PlanNode) -> PL.Phys:
        phys = self.planner.plan(node)
        if self.cfg.verify_plans:
            # structural invariant checks (DESIGN.md §16): raises
            # PlanInvariantError naming the node on a malformed plan
            from repro.analysis.plan_verify import verify_plan

            verify_plan(phys)
        return phys

    def execute_plan(
        self, phys: PL.Phys, var_table: Optional[A.VarTable] = None,
        trace: Optional[telemetry.QueryTrace] = None,
    ) -> QueryResult:
        if trace is None and self.cfg.telemetry:
            trace = telemetry.QueryTrace()
        if trace is None:
            return self._run_plan(phys, var_table, None)
        with telemetry.trace_query(trace=trace):
            return self._run_plan(phys, var_table, trace)

    def _run_plan(
        self, phys: PL.Phys, var_table: Optional[A.VarTable],
        trace: Optional[telemetry.QueryTrace],
    ) -> QueryResult:
        pool = self.pool
        pool_base = dict(pool.stats()) if pool is not None else None
        t0 = time.perf_counter()
        translator = Translator(self.store, self.cfg, pool=pool)
        op = translator.translate(phys)
        if trace is not None:
            trace.add_span("translate", "query", t0, time.perf_counter() - t0)
        pool = translator.pool
        if pool_base is None and pool is not None:
            pool_base = {}  # translator-local arena: delta == absolute
        proj = tuple(
            phys_v for phys_v in PL.phys_vars(phys)
        )
        t0 = time.perf_counter()
        try:
            if isinstance(op, LOP.RowOperator):
                rows = op.drain()
                arr = np.full((len(rows), len(proj)), NULL_ID, dtype=np.int32)
                for i, r in enumerate(rows):
                    for j, v in enumerate(proj):
                        arr[i, j] = r.get(v, int(NULL_ID))
            else:
                # streaming drain: copy each batch's projection out, then give
                # the buffers straight back to the arena — the release() side of
                # the zero-copy pipeline (DESIGN.md §2.3)
                blocks = []
                while True:
                    b = op.next_batch()
                    if b is None:
                        break
                    if not b.n_active:
                        b.release()
                        continue
                    cb = b.compact()
                    order = [cb.col_index(v) for v in proj]
                    blocks.append(cb.columns[order, : cb.n_rows].T)  # fancy-index copy
                    cb.release()
                arr = (
                    np.concatenate(blocks, axis=0)
                    if blocks
                    else np.zeros((0, len(proj)), dtype=np.int32)
                )
        finally:
            # operator teardown: drop spill files and window buffers even
            # when the drain raised mid-query (DESIGN.md §15). Stats stay
            # intact, so EXPLAIN ANALYZE / feedback below still work.
            close_tree(op)
        if pool is not None and pool is not self.pool:
            # translator-local arena: return its memory now. The Engine's
            # shared pool stays warm — its recycled buffers (bounded by
            # max_per_bucket per shape) seed the next query.
            pool.drain()
        if trace is not None:
            trace.add_span("execute", "query", t0, time.perf_counter() - t0,
                           rows=int(arr.shape[0]))
            trace.add_operator_tree(op)
        if self.feedback is not None:
            self._record_actuals(op)
        return QueryResult(var_table or A.VarTable(), proj, arr, op, pool,
                           pool_base=pool_base, trace=trace)

    def _record_actuals(self, root: AnyOp) -> None:
        """Feed the drained tree's actual output rows into the feedback
        store, keyed by node fingerprint. Pass-through chains (Sort over
        Scan, ...) share one fingerprint — record it once, from the
        topmost operator (identical counts by construction)."""
        seen = set()

        def walk(op) -> None:
            fp = op.stats.node_fp
            if fp and fp not in seen:
                seen.add(fp)
                self.feedback.record(fp, op.stats.results)
            for c in op.children():
                walk(c)

        walk(root)

    def execute(self, node_or_text: Union[str, A.PlanNode],
                var_table: Optional[A.VarTable] = None,
                trace: Optional[telemetry.QueryTrace] = None) -> QueryResult:
        if trace is None and self.cfg.telemetry:
            label = (
                " ".join(node_or_text.split())[:120]
                if isinstance(node_or_text, str) else "query"
            )
            trace = telemetry.QueryTrace(label)
        if trace is None:
            if isinstance(node_or_text, str):
                node, var_table = self.parse(node_or_text)
            else:
                node = node_or_text
            return self._run_plan(self.plan(node), var_table, None)
        with telemetry.trace_query(trace=trace):
            if isinstance(node_or_text, str):
                with trace.span("parse"):
                    node, var_table = self.parse(node_or_text)
            else:
                node = node_or_text
            with trace.span("plan"):
                phys = self.plan(node)
            return self._run_plan(phys, var_table, trace)

    # -- EXPLAIN / EXPLAIN ANALYZE ------------------------------------------

    def explain(self, node_or_text: Union[str, A.PlanNode],
                var_table: Optional[A.VarTable] = None) -> str:
        """The chosen physical plan (no execution)."""
        if isinstance(node_or_text, str):
            node, var_table = self.parse(node_or_text)
        else:
            node = node_or_text
        return PL.explain(self.plan(node), var_table)

    def explain_analyze(self, node_or_text: Union[str, A.PlanNode],
                        var_table: Optional[A.VarTable] = None) -> str:
        """Execute and render per-operator estimated vs actual rows with
        misestimate flags (DESIGN.md §13)."""
        return self.execute(node_or_text, var_table).explain_analyze()
