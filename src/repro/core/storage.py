"""Sorted quad storage (paper §2.2.1).

Stardog stores RDF quads as lexicographically sorted collections of four
64-bit numbers in several orders, backed by RocksDB, and scans support a
``skip()`` (seek) to the next row with key >= target. Here the storage tier
is in-memory: each index is an (N, 4) int32 array sorted lexicographically
by its permutation, and ``skip()`` is a staged binary search. The scan API
(`range_for_pattern`, `read`, `seek`) preserves seek/range semantics so a
disk tier could slot underneath without touching the engine.

Index selection mirrors Stardog: not all 24 permutations are kept — SPOC,
POSC and OSPC cover every bound-prefix combination a triple pattern needs
(subject-bound, predicate-bound, object-bound), with CSPO optional for named
graphs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.dictionary import Dictionary, Term

# column roles in a quad
S, P, O, C = 0, 1, 2, 3

INDEX_ORDERS: Dict[str, Tuple[int, int, int, int]] = {
    "spoc": (S, P, O, C),
    "posc": (P, O, S, C),
    "ospc": (O, S, P, C),
    # predicate-subject order: lets ?s <p> ?o scans come out sorted by
    # subject, which is what BGP merge joins on subjects want.
    "psoc": (P, S, O, C),
}


def _lexsort_rows(arr: np.ndarray) -> np.ndarray:
    # np.lexsort sorts by last key first
    order = np.lexsort((arr[:, 3], arr[:, 2], arr[:, 1], arr[:, 0]))
    return arr[order]


@dataclasses.dataclass
class ScanRange:
    """A contiguous row range [lo, hi) within one index."""

    index: str
    lo: int
    hi: int

    def __len__(self) -> int:
        return self.hi - self.lo


class QuadStore:
    """In-memory sorted quad indexes + dictionary."""

    def __init__(self, dictionary: Optional[Dictionary] = None) -> None:
        self.dict = dictionary or Dictionary()
        self._indexes: Dict[str, np.ndarray] = {}
        # contiguous per-column copies of each index: searchsorted on a
        # strided column view of the (N, 4) C-order array copies the whole
        # column before binary-searching, turning every range_for_pattern /
        # seek into an O(N) memcpy instead of an O(log N) probe.
        self._index_cols: Dict[str, list] = {}
        self._pending: list = []
        self.n_quads = 0

    # -- loading -------------------------------------------------------------

    def add(self, s: Term, p: Term, o: Term, g: Term = ":default") -> None:
        self._pending.append(
            (
                self.dict.encode(s),
                self.dict.encode(p),
                self.dict.encode(o),
                self.dict.encode(g),
            )
        )

    def add_encoded(self, quads: np.ndarray) -> None:
        """Bulk-add already-encoded (N, 4) int32 quads."""
        self._pending.append(np.asarray(quads, dtype=np.int32))

    def build(self) -> "QuadStore":
        """Sort and freeze the indexes (file-ingestion analogue)."""
        parts = []
        for item in self._pending:
            if isinstance(item, np.ndarray):
                parts.append(item.reshape(-1, 4))
            else:
                parts.append(np.asarray([item], dtype=np.int32))
        raw = (
            np.concatenate(parts, axis=0)
            if parts
            else np.zeros((0, 4), dtype=np.int32)
        )
        self._pending = []
        # dedupe (RDF graphs are sets of triples)
        raw = np.unique(raw, axis=0)
        self.n_quads = len(raw)
        for name, perm in INDEX_ORDERS.items():
            idx = _lexsort_rows(raw[:, list(perm)])
            self._indexes[name] = idx
            self._index_cols[name] = [
                np.ascontiguousarray(idx[:, i]) for i in range(4)
            ]
        return self

    # -- pattern evaluation ----------------------------------------------------

    def index_array(self, name: str) -> np.ndarray:
        return self._indexes[name]

    def choose_index(
        self, bound: Sequence[Optional[int]], want_sorted_role: Optional[int]
    ) -> str:
        """Pick the index whose order puts bound roles first and the desired
        output-sort role next. ``bound`` is (s, p, o, c) with None = free."""
        best, best_score = "spoc", -1
        for name, perm in INDEX_ORDERS.items():
            score = 0
            i = 0
            # bound roles must form a prefix of the index order
            while i < 4 and bound[perm[i]] is not None:
                score += 4
                i += 1
            n_bound = sum(b is not None for b in bound)
            if score // 4 < n_bound:
                continue  # some bound role is not in the prefix: unusable
            if want_sorted_role is not None and i < 4 and perm[i] == want_sorted_role:
                score += 2
            if score > best_score:
                best, best_score = name, score
        if best_score < 0:
            # no index has all bound roles in prefix — fall back to spoc with
            # post-filtering (engine handles residual equality checks)
            return "spoc"
        return best

    def range_for_pattern(
        self, index: str, bound: Sequence[Optional[int]]
    ) -> ScanRange:
        """Binary-search the row range matching the bound prefix."""
        cols = self._index_cols[index]
        perm = INDEX_ORDERS[index]
        lo, hi = 0, len(self._indexes[index])
        for col_pos in range(4):
            role = perm[col_pos]
            v = bound[role]
            if v is None:
                break
            col = cols[col_pos][lo:hi]  # contiguous 1-D slice: O(log N)
            # needle must match the column dtype: a Python-int needle makes
            # numpy promote and cast the whole column (O(N)) before searching
            v = np.int32(v)
            lo_off = np.searchsorted(col, v, side="left")
            hi_off = np.searchsorted(col, v, side="right")
            lo, hi = lo + int(lo_off), lo + int(hi_off)
        return ScanRange(index, lo, hi)

    def read(self, rng: ScanRange, start: int, count: int) -> np.ndarray:
        """Read up to ``count`` rows at offset ``start`` within the range.
        Rows come back in index order (permuted columns)."""
        lo = rng.lo + start
        hi = min(lo + count, rng.hi)
        return self._indexes[rng.index][lo:hi]

    def seek(self, rng: ScanRange, start: int, sort_col_pos: int, target: int) -> int:
        """skip(): offset (>= start) of first row whose key at ``sort_col_pos``
        within the index order is >= target. This is the RocksDB seek
        analogue the BARQ merge join drives (paper §3.2 Skip phase)."""
        col = self._index_cols[rng.index][sort_col_pos][rng.lo + start : rng.hi]
        return start + int(np.searchsorted(col, np.int32(target), side="left"))

    # -- stats for the optimizer ------------------------------------------------

    def pattern_cardinality(self, bound: Sequence[Optional[int]]) -> int:
        idx = self.choose_index(bound, None)
        return len(self.range_for_pattern(idx, bound))
