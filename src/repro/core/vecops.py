"""Vectorized data-plane primitives — numpy reference backend.

Every per-batch computation in the BARQ operators funnels through these
functions. They have three interchangeable implementations:

  * this module — numpy, the engine's default CPU backend and the oracle;
  * ``repro.kernels.ref`` — pure-jnp mirrors (jit-compiled);
  * ``repro.kernels.*`` — Pallas TPU kernels (validated in interpret mode).

``repro.kernels.ops`` dispatches between them. Operators never hand-roll
per-row loops — that is the point of the paper.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# run / group detection (merge-join Probe phase, paper §3.2)
# ---------------------------------------------------------------------------


def run_boundaries(keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Runs of equal values in a sorted key column.

    Returns (values, starts, lengths): values[i] is the key of run i which
    occupies keys[starts[i] : starts[i] + lengths[i]].
    """
    n = len(keys)
    if n == 0:
        e = np.zeros(0, dtype=np.int32)
        return e, e, e
    is_start = np.empty(n, dtype=bool)
    is_start[0] = True
    np.not_equal(keys[1:], keys[:-1], out=is_start[1:])
    starts = np.nonzero(is_start)[0].astype(np.int32)
    lengths = np.diff(np.append(starts, n)).astype(np.int32)
    return keys[starts].astype(np.int32), starts, lengths


def probe_groups(
    lvals: np.ndarray,
    rvals: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """Match left runs against right runs by key (both sorted ascending,
    values unique within each side). Returns (left_run_idx, right_run_idx)
    for every matching pair — the paper's 'input groups'."""
    pos = np.searchsorted(rvals, lvals, side="left")
    pos_c = np.minimum(pos, max(len(rvals) - 1, 0))
    hit = (len(rvals) > 0) & (rvals[pos_c] == lvals) if len(rvals) else np.zeros(
        len(lvals), dtype=bool
    )
    li = np.nonzero(hit)[0].astype(np.int32)
    return li, pos[li].astype(np.int32)


# ---------------------------------------------------------------------------
# cross-product materialization (merge-join Build phase, paper §3.2)
# ---------------------------------------------------------------------------


def group_output_offsets(
    llens: np.ndarray, rlens: np.ndarray
) -> np.ndarray:
    """cum[i] = total output rows of groups < i; cum[-1] = grand total.
    Output rows of group g = left_len[g] * right_len[g] (cross product)."""
    counts = llens.astype(np.int64) * rlens.astype(np.int64)
    return np.concatenate([[0], np.cumsum(counts)])


def expand_cross(
    lstarts: np.ndarray,
    llens: np.ndarray,
    rstarts: np.ndarray,
    rlens: np.ndarray,
    cum: np.ndarray,
    base: int,
    count: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Materialize output slots [base, base+count) of the grouped cross
    product as (left_row_idx, right_row_idx) gather indices.

    For global output slot t: find its group g (binary search over cum),
    within-group offset w = t - cum[g]; then
        left_row  = lstarts[g] + w // rlens[g]     (left expanded)
        right_row = rstarts[g] + w %  rlens[g]     (right repeated)
    — exactly the paper's 'expand left by right range length, repeat right
    by left range length', computed slot-parallel so the TPU kernel is a
    pure map over the output block.
    """
    # the slots [base, base+count) are contiguous, so instead of a per-slot
    # binary search the group ids are a run-length expansion of the (few)
    # groups the window spans: O(count + groups) instead of O(count log G)
    hi = base + count
    g0 = int(np.searchsorted(cum, base, side="right")) - 1
    g1 = int(np.searchsorted(cum, hi, side="left"))
    seg = np.minimum(cum[g0 + 1 : g1 + 1], hi) - np.maximum(cum[g0:g1], base)
    g = np.repeat(np.arange(g0, g1, dtype=np.intp), seg)
    # stay in int32 while the offsets fit — int64 div/mod is ~2x slower and
    # dominates the Build phase otherwise
    dt = np.int32 if int(cum[-1]) < np.iinfo(np.int32).max else np.int64
    t = np.arange(base, hi, dtype=dt)
    w = t - cum[g].astype(dt)
    # unit-length runs need no div/mod: the within-group offset walks the
    # other side directly. Lookup joins always hit the llens==1 case (every
    # probe row is a length-1 left range).
    if llens[g0:g1].max(initial=1) == 1:
        li = lstarts[g]
        ri = rstarts[g] + w.astype(np.int32)
    elif rlens[g0:g1].max(initial=1) == 1:
        li = lstarts[g] + w.astype(np.int32)
        ri = rstarts[g]
    else:
        rl = rlens[g].astype(dt)
        li = lstarts[g] + (w // rl).astype(np.int32)
        ri = rstarts[g] + (w % rl).astype(np.int32)
    return np.asarray(li, dtype=np.int32), np.asarray(ri, dtype=np.int32)


# ---------------------------------------------------------------------------
# fused gather-emit (merge/lookup join Build emission, DESIGN.md §2.3)
# ---------------------------------------------------------------------------

_NULL = np.int32(-1)  # == batch.NULL_ID (kept local to avoid an import cycle)


def _take(src: np.ndarray, idx: np.ndarray, out: np.ndarray) -> None:
    """Gather src[idx] straight into ``out``, skipping the temporary that
    fancy indexing would allocate. Falls back when the destination isn't
    contiguous (np.take requires it)."""
    if out.flags.c_contiguous and src.flags.c_contiguous:
        np.take(src, idx, out=out, mode="clip")
    else:
        out[...] = src[idx]


def gather_emit(
    lcols: np.ndarray,
    rcols: Optional[np.ndarray],
    li: np.ndarray,
    ri: Optional[np.ndarray],
    lsel: Tuple[int, ...],
    rsel: Tuple[int, ...],
    pairs: Tuple[Tuple[int, int], ...],
    out: Optional[np.ndarray] = None,
    out_offset: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Fused join emission: gather + NULL-extend + secondary-key equality.

    One primitive replaces the per-column Python loops and the intermediate
    whole-window materializations of the join emit paths:

      lcols: (KL, NL) int32 source columns (left / probe side);
      rcols: (KR, NR) int32 source columns (right / build side), or None;
      li:    (C,) int32 row gather indices into lcols;
      ri:    (C,) int32 row gather indices into rcols, or None. ri == -1
             marks a *virtual NULL row* (left_outer padding): right outputs
             become NULL_ID and pair comparisons auto-pass for that slot.
      lsel:  source-row ids of lcols to emit, in output order. A -1 entry
             emits a NULL_ID column (schema alignment in concat_batches).
      rsel:  source-row ids of rcols to emit after the left block.
      pairs: (l_row, r_row) secondary join-key comparisons (paper §3.2
             Multiple Join Keys) folded into the returned validity mask.
      out:   optional (>=len(lsel)+len(rsel), >=out_offset+C) destination;
             rows [0, K) of out[:, out_offset:out_offset+C] are written in
             place (the pooled-buffer zero-copy path). A fresh array is
             allocated when omitted.

    Returns (out_block, mask): the (K, C) emitted block and the (C,) bool
    combined validity mask.
    """
    C = int(len(li))
    K = len(lsel) + len(rsel)
    if out is None:
        out = np.empty((K, C), dtype=np.int32)
        view = out
    else:
        view = out[:K, out_offset : out_offset + C]

    if ri is None:
        rvalid = None
        ric = None
    else:
        rvalid = ri >= 0
        if rvalid.all():
            rvalid = None  # fast path: no virtual rows
            ric = ri
        else:
            ric = np.where(rvalid, ri, 0)

    for j, row in enumerate(lsel):
        if row < 0:
            view[j] = _NULL
        else:
            _take(lcols[row], li, view[j])
    r_empty = rcols is None or rcols.shape[1] == 0
    for j, row in enumerate(rsel):
        dst = view[len(lsel) + j]
        if row < 0 or r_empty:
            dst[:] = _NULL
        elif rvalid is None:
            _take(rcols[row], ric, dst)
        else:
            np.copyto(dst, np.where(rvalid, rcols[row, ric], _NULL))

    mask = np.ones(C, dtype=bool)
    for lrow, rrow in pairs:
        lv = lcols[lrow, li]
        rv = np.zeros(C, dtype=np.int32) if r_empty else rcols[rrow, ric]
        eq = lv == rv
        mask &= eq if rvalid is None else (~rvalid | eq)
    return view, mask


# ---------------------------------------------------------------------------
# frontier dedup (property-path BFS rounds, DESIGN.md §8)
# ---------------------------------------------------------------------------


def _pair_key(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    """Composite int64 sort key for non-negative int32 (hi, lo) pairs."""
    return (hi.astype(np.int64) << 32) | lo.astype(np.int64)


def frontier_dedup(
    cand_hi: np.ndarray,
    cand_lo: np.ndarray,
    vis_hi: np.ndarray,
    vis_lo: np.ndarray,
) -> np.ndarray:
    """Validity mask over a lexicographically sorted candidate frontier.

    Inputs are (source, node) pairs as two int32 columns, both the
    candidate batch and the visited set sorted lexicographically by
    (hi, lo). mask[j] is True iff candidate j is the first occurrence of
    its pair within the batch (adjacent-unique) AND the pair is absent
    from the visited set — the semi-naive delta of a BFS round. With an
    empty visited set this is plain sort-unique (relation dedup).
    """
    c = int(len(cand_hi))
    mask = np.ones(c, dtype=bool)
    if c == 0:
        return mask
    np.logical_or(
        cand_hi[1:] != cand_hi[:-1], cand_lo[1:] != cand_lo[:-1], out=mask[1:]
    )
    if len(vis_hi):
        key_c = _pair_key(cand_hi, cand_lo)
        key_v = _pair_key(vis_hi, vis_lo)
        pos = np.searchsorted(key_v, key_c, side="left")
        inb = pos < len(key_v)
        member = np.zeros(c, dtype=bool)
        member[inb] = key_v[np.minimum(pos[inb], len(key_v) - 1)] == key_c[inb]
        mask &= ~member
    return mask


def merge_sorted_pairs(
    a_hi: np.ndarray, a_lo: np.ndarray, b_hi: np.ndarray, b_lo: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Merge two lexicographically sorted, mutually disjoint pair sets into
    one sorted pair set (the visited-set growth step; O(|a| + |b|)). The
    result never aliases ``b`` — callers pass views into recycled buffers."""
    if not len(b_hi):
        return a_hi, a_lo
    if not len(a_hi):
        return b_hi.copy(), b_lo.copy()
    pos = np.searchsorted(_pair_key(a_hi, a_lo), _pair_key(b_hi, b_lo))
    return (
        np.insert(a_hi, pos, b_hi),
        np.insert(a_lo, pos, b_lo),
    )


# ---------------------------------------------------------------------------
# sorted search (vectorized skip()/seek, paper §3.2 Skip phase)
# ---------------------------------------------------------------------------


def sorted_search(keys: np.ndarray, queries: np.ndarray, side: str = "left") -> np.ndarray:
    """Positions of ``queries`` in sorted ``keys`` (galloping seek)."""
    return np.searchsorted(keys, queries, side=side).astype(np.int32)


# ---------------------------------------------------------------------------
# selection-vector ops (paper §3.1)
# ---------------------------------------------------------------------------


def compact_indices(mask: np.ndarray) -> np.ndarray:
    """Selection vector from validity mask (prefix-sum compaction)."""
    return np.nonzero(mask)[0].astype(np.int32)


def multiway_equal_mask(cols_l: np.ndarray, cols_r: np.ndarray) -> np.ndarray:
    """Vectorized secondary-join-key equality (paper §3.2 Multiple Join
    Keys): rows where every secondary key pair matches."""
    return np.all(cols_l == cols_r, axis=0)


# ---------------------------------------------------------------------------
# composite group keys (multi-key GROUP BY, DESIGN.md §10)
# ---------------------------------------------------------------------------


def pack_group_keys(
    key_cols: np.ndarray,
    spans: Optional[Sequence[int]] = None,
) -> Optional[np.ndarray]:
    """Pack a (k, n) block of int32 group-key columns (NULL_ID == -1
    allowed) into ONE int64 composite key whose ordering and equality match
    the lexicographic order of the columns — so multi-key grouping needs a
    single-key argsort instead of a k-column lexsort.

    With ``spans=None`` (grouping), columns pack most-significant-first
    with per-column ranges max+2 (codes shift by one so NULL packs as 0).
    When the range product would overflow 63 bits, falls back to a
    lexsort-based dense rank, which preserves both ordering and group
    boundaries.

    With explicit ``spans`` (multi-variable hash-join keys: the packing
    must be identical across probe batches, so the ranges are fixed up
    front from the build side), values at or above their span clamp to the
    span's last slot. Callers must size each span with one spare sentinel
    slot above the build side's maximum shifted value (span >= max+3 for
    codes up to max), so clamped out-of-range probe values land on a slot
    no build key occupies — they can then never falsely match, and
    probe-probe collisions are harmless because probe keys are only ever
    compared against build keys. Returns None when the span product
    overflows 62 bits (the caller falls back to primary-key hashing +
    pairwise verification); the rank fallback is not available because
    ranks are not stable across batches."""
    key_cols = np.asarray(key_cols)
    k, n = key_cols.shape
    assert k >= 1
    if spans is not None:
        assert len(spans) == k
        if math.prod(int(s) for s in spans) >= 1 << 62:
            return None
        packed = np.minimum(key_cols[0].astype(np.int64) + 1, spans[0] - 1)
        for c, s in zip(key_cols[1:], spans[1:]):
            packed = packed * int(s) + np.minimum(
                c.astype(np.int64) + 1, int(s) - 1
            )
        return packed
    packed = key_cols[0].astype(np.int64) + 1
    span = int(key_cols[0].max(initial=-1)) + 2
    for c in key_cols[1:]:
        r = int(c.max(initial=-1)) + 2
        if span * r >= 1 << 62:
            order = np.lexsort(tuple(key_cols[::-1]))
            srt = key_cols[:, order]
            change = np.zeros(n, dtype=bool)
            if n:
                change[0] = True
                for row in srt:
                    change[1:] |= row[1:] != row[:-1]
            out = np.empty(n, dtype=np.int64)
            out[order] = np.cumsum(change) - 1
            return out
        packed = packed * r + (c.astype(np.int64) + 1)
        span *= r
    return packed


# ---------------------------------------------------------------------------
# sorted segment aggregation (paper §3.3)
# ---------------------------------------------------------------------------

AGG_INIT = {
    "count": 0.0,
    "sum": 0.0,
    "min": np.inf,
    "max": -np.inf,
}


def segment_reduce(
    keys: np.ndarray,
    values: Optional[np.ndarray],
    func: str,
    seg: Optional[Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-run aggregate over a batch sorted by ``keys``.

    Returns (run_keys, partials). ``values`` is float64 (already decoded via
    the numeric side-array) or None for COUNT(*). Associative partials merge
    across batches in the streaming operator (paper: count/min/max/avg are
    associative and merge across batches).

    ``seg`` optionally carries precomputed (run_keys, lengths, seg_ids) for
    ``keys`` so a caller issuing one reduction per statistic over the same
    key column (the streaming GROUP BY) skips the per-call boundary
    re-derivation; seg_ids may be None and is derived on demand.
    """
    if seg is None:
        run_keys, _, lengths = run_boundaries(keys)
        seg_ids = None
    else:
        run_keys, lengths, seg_ids = seg
    n_runs = len(run_keys)
    if n_runs == 0:
        return run_keys, np.zeros(0, dtype=np.float64)
    if func == "count":
        return run_keys, lengths.astype(np.float64)
    if seg_ids is None:
        seg_ids = np.repeat(np.arange(n_runs), lengths)
    assert values is not None
    if func == "sum":
        out = np.zeros(n_runs, dtype=np.float64)
        np.add.at(out, seg_ids, values)
    elif func == "min":
        out = np.full(n_runs, np.inf, dtype=np.float64)
        np.minimum.at(out, seg_ids, values)
    elif func == "max":
        out = np.full(n_runs, -np.inf, dtype=np.float64)
        np.maximum.at(out, seg_ids, values)
    else:
        raise ValueError(func)
    return run_keys, out


# ---------------------------------------------------------------------------
# hash partitioning (distributed exchange; DESIGN.md §2.1)
# ---------------------------------------------------------------------------

_HASH_MULT = np.uint32(0x9E3779B1)  # Fibonacci hashing


def hash_partition(keys: np.ndarray, n_parts: int) -> np.ndarray:
    """Multiplicative-hash partition id per key (n_parts power of two)."""
    h = (keys.astype(np.uint32) * _HASH_MULT) >> np.uint32(16)
    return (h & np.uint32(n_parts - 1)).astype(np.int32)


def partition_histogram(part_ids: np.ndarray, n_parts: int) -> np.ndarray:
    return np.bincount(part_ids, minlength=n_parts).astype(np.int32)


# ---------------------------------------------------------------------------
# radix-partitioned hash join primitives (DESIGN.md §11)
# ---------------------------------------------------------------------------
#
# The logical join key is an int32 (hi, lo) pair compared lexicographically:
# single-variable keys pass hi=None (all-zero) and lo=the code column
# (NULL_ID == -1 is an ordinary value that equals itself, matching the
# merge-join and row-engine semantics); multi-variable keys pack through
# pack_group_keys(spans=...) into a non-negative int64 split as
# hi = packed >> 31, lo = packed & 0x7FFFFFFF. hi is always >= 0.

_MIX_MULT = np.uint32(0x85EBCA6B)  # murmur3 fmix constant


def mix_pair(key_hi: Optional[np.ndarray], key_lo: np.ndarray) -> np.ndarray:
    """Fold an (hi, lo) key pair into one int32 hash input; identity for
    single-column keys so their partition ids match radix_partition on the
    raw codes. INT32_MIN is remapped (it is the Pallas radix_partition
    kernel's padding sentinel; single-column inputs are dictionary codes
    >= -1 and can never hit it, but a xor-mix can)."""
    lo = np.asarray(key_lo, dtype=np.int32)
    if key_hi is None:
        return lo
    mixed = (
        lo.view(np.uint32)
        ^ (np.asarray(key_hi, dtype=np.int32).view(np.uint32) * _MIX_MULT)
    ).view(np.int32)
    sentinel = np.iinfo(np.int32).min
    if (mixed == sentinel).any():
        mixed = np.where(mixed == sentinel, np.int32(0), mixed)
    return mixed


def _pair_comp(key_hi: Optional[np.ndarray], key_lo: np.ndarray) -> np.ndarray:
    """int64 composite preserving (hi, lo) lexicographic order (hi >= 0).
    Values are non-negative and < 2^63 (single-column keys < 2^32)."""
    lo64 = np.asarray(key_lo, np.int32).astype(np.int64) + (1 << 31)
    if key_hi is None:
        return lo64
    return (np.asarray(key_hi, np.int32).astype(np.int64) << 32) | lo64


def _pid_shift(n_parts: int) -> int:
    """Bits available for the key below the partition id in a global
    (pid, key) int64 composite."""
    return 63 - max(int(n_parts - 1).bit_length(), 1)


def hash_build_order(
    pid: np.ndarray,
    key_hi: Optional[np.ndarray],
    key_lo: np.ndarray,
    n_parts: int,
) -> np.ndarray:
    """Build-side reorder permutation: rows grouped by partition id, key-
    sorted within each partition — the two-level layout hash_probe
    searches. When the (pid, key) pair fits one int64 word (always for
    single-column keys; pair keys whenever the pack spans leave room for
    the partition bits) this is ONE stable argsort — numpy's stable sort
    on integer dtypes is a radix sort, so the build is O(n), not a
    comparison sort. The rare oversized pair keys fall back to lexsort."""
    lo = np.asarray(key_lo, dtype=np.int32)
    packed = _pair_comp(key_hi, lo)
    shift = _pid_shift(n_parts)
    if key_hi is None or int(packed.max(initial=0)) < (1 << shift):
        comp = (pid.astype(np.int64) << shift) | packed
        return np.argsort(comp, kind="stable").astype(np.int32)
    return np.lexsort((lo, np.asarray(key_hi, np.int32), pid)).astype(np.int32)


def hash_probe_positions(
    spid: np.ndarray,
    skey_hi: Optional[np.ndarray],
    skey_lo: np.ndarray,
    qpid: np.ndarray,
    qkey_hi: Optional[np.ndarray],
    qkey_lo: np.ndarray,
    part_starts: np.ndarray,
    cache: Optional[dict] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """(lo, hi) match-run positions of each probe key in the partitioned
    build layout: build rows [lo[i], hi[i]) carry probe i's exact key.

    The steady-state path folds (pid, key) into one global int64 composite
    and answers both run boundaries with two searchsorted passes; ``cache``
    (one dict per build, threaded through kernels.ops by the operator)
    keeps the build-side composite across probe batches so the per-batch
    cost is the searches alone. Pair keys too wide to share a word with
    the partition bits take a vectorized segmented binary search inside
    each probe's partition slice instead (every iteration advances all
    probes one halving step — O(probes · log max_partition))."""
    n_parts = len(part_starts) - 1
    shift = _pid_shift(n_parts)
    if (
        cache is not None
        and skey_hi is None
        and "tables" not in cache
        and len(skey_lo)
    ):
        # single-column keys are dictionary codes — a dense, bounded
        # domain. When it is small enough, upgrade the partition directory
        # to a direct-addressed run table (the limiting case of radix
        # partitioning: every key its own bucket): probe cost drops from a
        # binary search to two gathers per key. Runs stay contiguous in
        # the (pid, key) layout, so the table just records them.
        max_b = int(skey_lo.max())
        domain = max_b + 2  # +1 shift so NULL_ID (-1) owns slot 0
        if domain <= max(4 * len(skey_lo), 1 << 16):
            is_start = np.empty(len(skey_lo), dtype=bool)
            is_start[0] = True
            np.not_equal(skey_lo[1:], skey_lo[:-1], out=is_start[1:])
            if n_parts > 1:  # equal keys never span partitions; pid breaks runs too
                np.logical_or(
                    is_start[1:], spid[1:] != spid[:-1], out=is_start[1:]
                )
            starts = np.nonzero(is_start)[0].astype(np.int32)
            lengths = np.diff(np.append(starts, len(skey_lo))).astype(np.int32)
            lo_t = np.zeros(domain + 1, np.int32)  # last slot = sentinel
            len_t = np.zeros(domain + 1, np.int32)
            slot = skey_lo[starts].astype(np.int64) + 1
            lo_t[slot] = starts
            len_t[slot] = lengths
            cache["tables"] = (lo_t, len_t, domain)
        else:
            cache["tables"] = None
    if (
        cache is not None
        and skey_hi is None
        and cache.get("tables") is not None
    ):
        lo_t, len_t, domain = cache["tables"]
        idx = qkey_lo.astype(np.int64) + 1
        idx = np.where(idx < domain, idx, domain)  # out-of-domain -> sentinel
        lo = lo_t[idx]
        return lo, lo + len_t[idx]
    if cache is not None and "comp_b" in cache:
        comp_b = cache["comp_b"]
    else:
        packed_b = _pair_comp(skey_hi, skey_lo)
        if skey_hi is None or int(packed_b.max(initial=0)) < (1 << shift):
            comp_b = (spid.astype(np.int64) << shift) | packed_b
        else:
            comp_b = None  # oversized pair keys: segmented search
        if cache is not None:
            cache["comp_b"] = comp_b
    packed_q = _pair_comp(qkey_hi, qkey_lo)
    if comp_b is not None and (
        qkey_hi is None or int(packed_q.max(initial=0)) < (1 << shift)
    ):
        comp_q = (qpid.astype(np.int64) << shift) | packed_q
        lo = np.searchsorted(comp_b, comp_q, side="left")
        hi = np.searchsorted(comp_b, comp_q, side="right")
        return lo.astype(np.int32), hi.astype(np.int32)
    # fallback: per-partition binary search on the (hi, lo) composite,
    # both boundaries advanced in one halving loop
    comp_seg = _pair_comp(skey_hi, skey_lo)
    n_b = max(len(comp_seg), 1)
    seg_lo = part_starts[qpid].astype(np.int64)
    seg_hi = part_starts[qpid + 1].astype(np.int64)
    llo, lhi = seg_lo.copy(), seg_hi.copy()
    rlo, rhi = seg_lo, seg_hi.copy()
    while True:
        l_act = llo < lhi
        r_act = rlo < rhi
        if not (l_act.any() or r_act.any()):
            break
        lmid = (llo + lhi) >> 1
        rmid = (rlo + rhi) >> 1
        lgo = (comp_seg[np.minimum(lmid, n_b - 1)] < packed_q) & l_act
        rgo = (comp_seg[np.minimum(rmid, n_b - 1)] <= packed_q) & r_act
        llo = np.where(lgo, lmid + 1, llo)
        lhi = np.where(l_act & ~lgo, lmid, lhi)
        rlo = np.where(rgo, rmid + 1, rlo)
        rhi = np.where(r_act & ~rgo, rmid, rhi)
    return llo.astype(np.int32), rlo.astype(np.int32)


# ---------------------------------------------------------------------------
# blocked bloom filter (sideways information passing, DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# One uint32 word per block; each key sets two bits of one word, both derived
# from two independent multiplicative hashes of the raw int32 code (NULL_ID
# == -1 hashes like any other value — it equals itself in joins). A probe is
# a member iff both its bits are set in its word: no false negatives, false
# positives bounded by the words-per-key ratio chosen in bloom_n_words.

_BLOOM_MULT2 = np.uint32(0x85EBCA6B)  # murmur3 fmix constant, decorrelates h2


def bloom_n_words(n_keys: int) -> int:
    """Power-of-two word count targeting ~16 bits per key (two probes in a
    32-bit word at half load keeps the false-positive rate around 1-2%)."""
    n = 1
    while n * 2 < max(n_keys, 1) and n < (1 << 20):
        n *= 2
    return n


def bloom_hash(keys: np.ndarray, n_words: int) -> Tuple[np.ndarray, np.ndarray]:
    """(word index, bit pattern) per key — the shared address computation
    every backend must reproduce exactly (parity-swept in test_sip)."""
    u = np.asarray(keys, dtype=np.int32).astype(np.uint32)
    h1 = u * _HASH_MULT
    h2 = u * _BLOOM_MULT2
    word = ((h1 >> np.uint32(18)) & np.uint32(n_words - 1)).astype(np.int32)
    b1 = h1 & np.uint32(31)
    b2 = (h2 >> np.uint32(13)) & np.uint32(31)
    bits = (np.uint32(1) << b1) | (np.uint32(1) << b2)
    return word, bits


def bloom_build(keys: np.ndarray, n_words: int) -> Tuple[np.ndarray, int, int]:
    """(words, lo, hi): the blocked bloom filter plus the min/max code range
    of the build side. An empty build returns the empty range (0, -1)."""
    keys = np.asarray(keys, dtype=np.int32)
    words = np.zeros(n_words, dtype=np.uint32)
    if len(keys) == 0:
        return words, 0, -1
    word, bits = bloom_hash(keys, n_words)
    np.bitwise_or.at(words, word, bits)
    return words, int(keys.min()), int(keys.max())


def bloom_probe(words: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Membership mask: True where the query's two bits are both set.
    False positives possible, false negatives never."""
    queries = np.asarray(queries, dtype=np.int32)
    word, bits = bloom_hash(queries, len(words))
    return (words[word] & bits) == bits
