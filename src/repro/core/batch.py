"""Columnar solution batches — the BARQ data unit (paper §3.1).

A batch holds one int32 column per query variable (dictionary-encoded RDF
term IDs) plus a validity mask. The paper uses a *selection vector* (sorted
dense position list of active rows); on TPU the idiomatic carrier is a
bitmask, because masked SIMD lanes are free while SV indirection implies
gathers (see DESIGN.md §2). ``selection_vector()`` materializes the paper's
representation on demand (used at materialization boundaries and by the
batch→row adapter).

Shapes are static per capacity bucket so every per-batch kernel compiles
once per (n_vars, capacity) signature. Buffers are recycled through a
``BatchPool`` arena keyed by that same signature (DESIGN.md §2.3): on the
steady state a query's data plane performs zero buffer allocations — each
operator's output batches reuse the buffers its consumer released.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# NULL marker constant (paper §3.1 "NULLs"): OPTIONAL can leave variables
# unbound inside an aligned batch. Valid dictionary IDs are >= 0.
NULL_ID = np.int32(-1)

# Pool-sanitizer hook point (DESIGN.md §16). None until the first
# SanitizingBatchPool is constructed (repro.analysis.sanitize installs its
# tracker here); every lifecycle hook below is a single ``is None`` test
# when sanitizing is off, and batches of plain pools stay untracked even
# when it is on.
_SANITIZER = None

# Power-of-two capacity buckets (paper: adaptive batch size <= 512; we keep
# the same spirit with a bounded set of compiled shapes, DESIGN.md §2).
MIN_BATCH = 32
MAX_BATCH = 4096
BATCH_BUCKETS: Tuple[int, ...] = tuple(
    1 << p for p in range(MIN_BATCH.bit_length() - 1, MAX_BATCH.bit_length())
)


def bucket_for(n: int) -> int:
    """Smallest capacity bucket holding ``n`` rows."""
    for b in BATCH_BUCKETS:
        if n <= b:
            return b
    return MAX_BATCH


class BatchPool:
    """Arena of recycled batch buffers, keyed by (n_vars, capacity).

    The release()/acquire() cycle makes steady-state execution
    allocation-free: the number of fresh allocations is bounded by the
    number of batches simultaneously alive, which is O(plan depth), not
    O(batches emitted) (DESIGN.md §2.3). ``drain()`` returns the arena's
    memory at end of query.

    Counters feed the profiler: ``allocations``/``bytes_allocated`` count
    fresh numpy buffers, ``reuses`` recycled ones, and ``bytes_copied`` is
    credited by the join windows / concat paths for every byte of column
    data they physically move.
    """

    def __init__(self, max_per_bucket: int = 32) -> None:
        self.max_per_bucket = max_per_bucket
        self._free: Dict[Tuple[int, int], List[Tuple[np.ndarray, np.ndarray]]] = {}
        self.allocations = 0
        self.reuses = 0
        self.releases = 0
        # fresh buffers permanently retired: returned over a full stack, or
        # swept by drain(). Feeds the counters() conservation law.
        self.dropped = 0
        self.bytes_allocated = 0
        self.bytes_copied = 0

    def acquire(self, n_vars: int, capacity: int) -> Tuple[np.ndarray, np.ndarray]:
        """A (columns, mask) buffer pair; contents are UNINITIALIZED."""
        stack = self._free.get((n_vars, capacity))
        if stack:
            self.reuses += 1
            return stack.pop()
        self.allocations += 1
        cols = np.empty((n_vars, capacity), dtype=np.int32)
        mask = np.empty(capacity, dtype=bool)
        self.bytes_allocated += cols.nbytes + mask.nbytes
        return cols, mask

    def release(self, cols: np.ndarray, mask: np.ndarray) -> None:
        self.releases += 1
        key = (int(cols.shape[0]), int(cols.shape[1]))
        stack = self._free.setdefault(key, [])
        if len(stack) < self.max_per_bucket:
            stack.append((cols, mask))
        else:
            self.dropped += 1

    def drain(self) -> None:
        """Drop every recycled buffer (end-of-query teardown)."""
        self.dropped += sum(len(s) for s in self._free.values())
        self._free.clear()

    def stats(self) -> Dict[str, int]:
        return {
            "allocations": self.allocations,
            "reuses": self.reuses,
            "releases": self.releases,
            "bytes_allocated": self.bytes_allocated,
            "bytes_copied": self.bytes_copied,
        }

    def counters(self) -> Dict[str, int]:
        """Buffer conservation snapshot (DESIGN.md §16): every fresh buffer
        is live (owned by a batch), pooled (in a free stack), or retired
        — so after a query fully drains its operators,
        ``allocs == releases + pooled`` and ``live == 0``."""
        pooled = sum(len(s) for s in self._free.values())
        return {
            "allocs": self.allocations,
            "releases": self.dropped,
            "pooled": pooled,
            "live": self.allocations - self.dropped - pooled,
            "acquires": self.allocations + self.reuses,
            "recycles": self.releases,
        }


@dataclasses.dataclass
class ColumnBatch:
    """A batch of solutions in columnar layout.

    Attributes:
      var_ids:  static tuple of variable ids, one per column (sorted order
                not required; position is the column index).
      columns:  int32 array of shape (n_vars, capacity).
      mask:     bool array (capacity,) — True for active rows. The TPU
                carrier for the paper's selection vector.
      n_rows:   number of *physically filled* rows (<= capacity). Rows in
                [n_rows, capacity) are padding and always masked out.
      sorted_by: var id the active rows are non-decreasing in, or None.
      pool:     owning BatchPool, or None for unpooled buffers. Exactly one
                holder owns the buffers; transforms that share them
                (with_mask) MOVE ownership to the derived batch. The final
                consumer calls release() after copying data out.
    """

    var_ids: Tuple[int, ...]
    columns: np.ndarray
    mask: np.ndarray
    n_rows: int
    sorted_by: Optional[int] = None
    pool: Optional[BatchPool] = None

    # -- constructors -----------------------------------------------------

    @staticmethod
    def from_columns(
        var_ids: Sequence[int],
        cols: Sequence[np.ndarray],
        sorted_by: Optional[int] = None,
        capacity: Optional[int] = None,
        pool: Optional[BatchPool] = None,
    ) -> "ColumnBatch":
        var_ids = tuple(int(v) for v in var_ids)
        n = int(cols[0].shape[0]) if cols else 0
        cap = capacity or bucket_for(max(n, 1))
        if pool is not None:
            # pool-aware fast path: write into a recycled buffer instead of
            # zero-filling a fresh one (DESIGN.md §2.3)
            data, mask = pool.acquire(len(var_ids), cap)
            mask[:n] = True
            mask[n:] = False
        else:
            data = np.full((len(var_ids), cap), NULL_ID, dtype=np.int32)
            mask = np.zeros(cap, dtype=bool)
            mask[:n] = True
        for i, c in enumerate(cols):
            data[i, :n] = np.asarray(c, dtype=np.int32)
        if pool is not None and n < cap:
            data[:, n:] = NULL_ID  # deterministic padding on recycled memory
        b = ColumnBatch(var_ids, data, mask, n, sorted_by, pool)
        if pool is not None and _SANITIZER is not None:
            _SANITIZER.on_create(b)
        return b

    @staticmethod
    def alloc(
        var_ids: Sequence[int],
        capacity: int,
        pool: Optional[BatchPool] = None,
        sorted_by: Optional[int] = None,
    ) -> "ColumnBatch":
        """A writable batch for kernel emit paths: columns content is
        undefined, mask is all-False, n_rows is 0. The writer fills
        columns[:, :n], sets mask[:n] and n_rows, and must NULL-fill
        columns[:, n:] when it stops short of capacity."""
        var_ids = tuple(int(v) for v in var_ids)
        if pool is not None:
            data, mask = pool.acquire(len(var_ids), capacity)
            mask[:] = False
        else:
            data = np.full((len(var_ids), capacity), NULL_ID, dtype=np.int32)
            mask = np.zeros(capacity, dtype=bool)
        b = ColumnBatch(var_ids, data, mask, 0, sorted_by, pool)
        if pool is not None and _SANITIZER is not None:
            _SANITIZER.on_create(b)
        return b

    @staticmethod
    def empty(var_ids: Sequence[int], capacity: int = MIN_BATCH) -> "ColumnBatch":
        var_ids = tuple(int(v) for v in var_ids)
        data = np.full((len(var_ids), capacity), NULL_ID, dtype=np.int32)
        return ColumnBatch(var_ids, data, np.zeros(capacity, dtype=bool), 0, None)

    # -- pooling ----------------------------------------------------------

    def release(self) -> None:
        """Return the buffers to the owning pool. Idempotent; no-op for
        unpooled batches. The caller must not touch columns/mask after."""
        pool, self.pool = self.pool, None
        if pool is not None:
            if _SANITIZER is not None:
                _SANITIZER.on_release(self)
            if getattr(pool, "_sanitized", False):
                # only [:, :n_rows] ever held exposed data; poisoning just
                # that region keeps the release cost proportional to use
                pool.release(self.columns, self.mask, used=self.n_rows)
            else:
                pool.release(self.columns, self.mask)

    def _guard(self) -> None:
        """Use-after-release tripwire: raises SanitizeError when the
        sanitizer is installed and this batch's buffers were released or
        MOVEd. A single global ``is None`` test otherwise; the tombstone
        probe is inlined so tracked-but-live batches stay cheap."""
        if _SANITIZER is not None and self.__dict__.get("_san_state") is not None:
            _SANITIZER.on_access(self)

    # -- accessors ---------------------------------------------------------

    @property
    def capacity(self) -> int:
        return int(self.columns.shape[1])

    @property
    def n_active(self) -> int:
        self._guard()
        return int(self.mask[: self.n_rows].sum()) if self.n_rows else 0

    def col_index(self, var: int) -> int:
        return self.var_ids.index(var)

    def column(self, var: int) -> np.ndarray:
        """Raw (uncompacted) column including inactive rows."""
        self._guard()
        return self.columns[self.col_index(var), : self.n_rows]

    def selection_vector(self) -> np.ndarray:
        """The paper's SV: sorted dense indices of active rows."""
        self._guard()
        return np.nonzero(self.mask[: self.n_rows])[0].astype(np.int32)

    def active_column(self, var: int) -> np.ndarray:
        return self.column(var)[self.mask[: self.n_rows]]

    # -- transforms ----------------------------------------------------------

    def compact(self) -> "ColumnBatch":
        """Drop inactive rows (materialization boundary). Buffer ownership
        moves to the compacted batch; when rows are actually dropped the
        source buffers are recycled (fancy indexing copied the data out)."""
        self._guard()
        if self.n_active == self.n_rows:
            return self
        sel = self.selection_vector()
        cols = [self.columns[i, sel] for i in range(len(self.var_ids))]
        out = ColumnBatch.from_columns(self.var_ids, cols, self.sorted_by, pool=self.pool)
        self.release()
        return out

    def project(self, keep: Sequence[int]) -> "ColumnBatch":
        keep = tuple(int(v) for v in keep)
        idx = [self.col_index(v) for v in keep]
        sb = self.sorted_by if self.sorted_by in keep else None
        # row fancy-indexing copies, so the projected batch is unpooled and
        # this batch keeps ownership of its buffers; the mask is only shared
        # when that ownership can't be released out from under the copy
        m = self.mask if self.pool is None else self.mask.copy()
        return ColumnBatch(keep, self.columns[idx], m, self.n_rows, sb)

    def with_mask(self, mask: np.ndarray) -> "ColumnBatch":
        self._guard()
        if self.pool is not None:
            # pooled batches are single-owner: narrow the mask in place and
            # MOVE buffer ownership to the derived batch (zero-copy)
            np.logical_and(self.mask, mask, out=self.mask)
            pool, self.pool = self.pool, None
            out = ColumnBatch(
                self.var_ids, self.columns, self.mask, self.n_rows, self.sorted_by, pool
            )
            if _SANITIZER is not None:
                _SANITIZER.on_move(self, out)
            return out
        m = self.mask & mask
        return ColumnBatch(self.var_ids, self.columns, m, self.n_rows, self.sorted_by)

    def rows(self) -> Iterable[Dict[int, int]]:
        """Row-major view (the batch→row adapter uses this; copy-free per
        the paper §4.2 — values are read straight out of the columns)."""
        self._guard()
        for r in range(self.n_rows):
            if self.mask[r]:
                yield {
                    v: int(self.columns[i, r])
                    for i, v in enumerate(self.var_ids)
                    if self.columns[i, r] != NULL_ID
                }

    def to_rows_array(self) -> np.ndarray:
        """Active rows as (n_active, n_vars) int32 — for tests/oracles."""
        self._guard()
        sel = self.selection_vector()
        return self.columns[:, sel].T.copy()


def concat_batches(
    batches: Sequence[ColumnBatch],
    var_ids: Optional[Sequence[int]] = None,
    pool: Optional[BatchPool] = None,
    release_inputs: bool = False,
) -> ColumnBatch:
    """Concatenate batches, aligning schemas and NULL-filling missing vars.

    Built on the fused gather_emit primitive: each input batch is gathered
    straight into the output buffer at its offset (one pass per source, no
    intermediate per-column materialization). With ``pool``, the output
    buffer is recycled; with ``release_inputs``, consumed batches return
    their buffers to the pool."""
    from repro.core import vecops

    if not batches:
        return ColumnBatch.empty(tuple(var_ids or ()))
    if var_ids is None:
        seen: Dict[int, None] = {}
        for b in batches:
            for v in b.var_ids:
                seen.setdefault(v, None)
        var_ids = tuple(seen)
    var_ids = tuple(int(v) for v in var_ids)
    total = sum(b.n_active for b in batches)
    # bucket capacities top out at MAX_BATCH; a materialization-sized concat
    # gets an exact-size buffer instead of a silently clipped one
    cap = bucket_for(max(total, 1))
    if total > cap:
        cap = total
    out = ColumnBatch.alloc(var_ids, cap, pool)
    pos = 0
    for b in batches:
        sel = b.selection_vector()
        n = len(sel)
        if n:
            src_rows = tuple(
                b.var_ids.index(v) if v in b.var_ids else -1 for v in var_ids
            )
            vecops.gather_emit(
                b.columns, None, sel, None, src_rows, (), (),
                out=out.columns, out_offset=pos,
            )
            if pool is not None:  # NULL-filled missing vars aren't copies
                pool.bytes_copied += sum(1 for r in src_rows if r >= 0) * n * 4
            pos += n
        if release_inputs:
            b.release()
    if total < cap:
        out.columns[:, total:] = NULL_ID
    out.mask[:total] = True
    out.n_rows = total
    return out
