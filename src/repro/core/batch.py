"""Columnar solution batches — the BARQ data unit (paper §3.1).

A batch holds one int32 column per query variable (dictionary-encoded RDF
term IDs) plus a validity mask. The paper uses a *selection vector* (sorted
dense position list of active rows); on TPU the idiomatic carrier is a
bitmask, because masked SIMD lanes are free while SV indirection implies
gathers (see DESIGN.md §2). ``selection_vector()`` materializes the paper's
representation on demand (used at materialization boundaries and by the
batch→row adapter).

Shapes are static per capacity bucket so every per-batch kernel compiles
once per (n_vars, capacity) signature.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

# NULL marker constant (paper §3.1 "NULLs"): OPTIONAL can leave variables
# unbound inside an aligned batch. Valid dictionary IDs are >= 0.
NULL_ID = np.int32(-1)

# Power-of-two capacity buckets (paper: adaptive batch size <= 512; we keep
# the same spirit with a bounded set of compiled shapes, DESIGN.md §2).
MIN_BATCH = 32
MAX_BATCH = 4096
BATCH_BUCKETS: Tuple[int, ...] = tuple(
    1 << p for p in range(MIN_BATCH.bit_length() - 1, MAX_BATCH.bit_length())
)


def bucket_for(n: int) -> int:
    """Smallest capacity bucket holding ``n`` rows."""
    for b in BATCH_BUCKETS:
        if n <= b:
            return b
    return MAX_BATCH


@dataclasses.dataclass
class ColumnBatch:
    """A batch of solutions in columnar layout.

    Attributes:
      var_ids:  static tuple of variable ids, one per column (sorted order
                not required; position is the column index).
      columns:  int32 array of shape (n_vars, capacity).
      mask:     bool array (capacity,) — True for active rows. The TPU
                carrier for the paper's selection vector.
      n_rows:   number of *physically filled* rows (<= capacity). Rows in
                [n_rows, capacity) are padding and always masked out.
      sorted_by: var id the active rows are non-decreasing in, or None.
    """

    var_ids: Tuple[int, ...]
    columns: np.ndarray
    mask: np.ndarray
    n_rows: int
    sorted_by: Optional[int] = None

    # -- constructors -----------------------------------------------------

    @staticmethod
    def from_columns(
        var_ids: Sequence[int],
        cols: Sequence[np.ndarray],
        sorted_by: Optional[int] = None,
        capacity: Optional[int] = None,
    ) -> "ColumnBatch":
        var_ids = tuple(int(v) for v in var_ids)
        n = int(cols[0].shape[0]) if cols else 0
        cap = capacity or bucket_for(max(n, 1))
        data = np.full((len(var_ids), cap), NULL_ID, dtype=np.int32)
        for i, c in enumerate(cols):
            data[i, :n] = np.asarray(c, dtype=np.int32)
        mask = np.zeros(cap, dtype=bool)
        mask[:n] = True
        return ColumnBatch(var_ids, data, mask, n, sorted_by)

    @staticmethod
    def empty(var_ids: Sequence[int], capacity: int = MIN_BATCH) -> "ColumnBatch":
        var_ids = tuple(int(v) for v in var_ids)
        data = np.full((len(var_ids), capacity), NULL_ID, dtype=np.int32)
        return ColumnBatch(var_ids, data, np.zeros(capacity, dtype=bool), 0, None)

    # -- accessors ---------------------------------------------------------

    @property
    def capacity(self) -> int:
        return int(self.columns.shape[1])

    @property
    def n_active(self) -> int:
        return int(self.mask[: self.n_rows].sum()) if self.n_rows else 0

    def col_index(self, var: int) -> int:
        return self.var_ids.index(var)

    def column(self, var: int) -> np.ndarray:
        """Raw (uncompacted) column including inactive rows."""
        return self.columns[self.col_index(var), : self.n_rows]

    def selection_vector(self) -> np.ndarray:
        """The paper's SV: sorted dense indices of active rows."""
        return np.nonzero(self.mask[: self.n_rows])[0].astype(np.int32)

    def active_column(self, var: int) -> np.ndarray:
        return self.column(var)[self.mask[: self.n_rows]]

    # -- transforms ----------------------------------------------------------

    def compact(self) -> "ColumnBatch":
        """Drop inactive rows (materialization boundary)."""
        if self.n_active == self.n_rows:
            return self
        sel = self.selection_vector()
        cols = [self.columns[i, sel] for i in range(len(self.var_ids))]
        return ColumnBatch.from_columns(self.var_ids, cols, self.sorted_by)

    def project(self, keep: Sequence[int]) -> "ColumnBatch":
        keep = tuple(int(v) for v in keep)
        idx = [self.col_index(v) for v in keep]
        sb = self.sorted_by if self.sorted_by in keep else None
        return ColumnBatch(keep, self.columns[idx], self.mask, self.n_rows, sb)

    def with_mask(self, mask: np.ndarray) -> "ColumnBatch":
        m = self.mask & mask
        return ColumnBatch(self.var_ids, self.columns, m, self.n_rows, self.sorted_by)

    def rows(self) -> Iterable[Dict[int, int]]:
        """Row-major view (the batch→row adapter uses this; copy-free per
        the paper §4.2 — values are read straight out of the columns)."""
        for r in range(self.n_rows):
            if self.mask[r]:
                yield {
                    v: int(self.columns[i, r])
                    for i, v in enumerate(self.var_ids)
                    if self.columns[i, r] != NULL_ID
                }

    def to_rows_array(self) -> np.ndarray:
        """Active rows as (n_active, n_vars) int32 — for tests/oracles."""
        sel = self.selection_vector()
        return self.columns[:, sel].T.copy()


def concat_batches(
    batches: Sequence[ColumnBatch], var_ids: Optional[Sequence[int]] = None
) -> ColumnBatch:
    """Concatenate batches, aligning schemas and NULL-filling missing vars."""
    if not batches:
        return ColumnBatch.empty(tuple(var_ids or ()))
    if var_ids is None:
        seen: Dict[int, None] = {}
        for b in batches:
            for v in b.var_ids:
                seen.setdefault(v, None)
        var_ids = tuple(seen)
    var_ids = tuple(int(v) for v in var_ids)
    total = sum(b.n_active for b in batches)
    out = np.full((len(var_ids), max(total, 1)), NULL_ID, dtype=np.int32)
    pos = 0
    for b in batches:
        sel = b.selection_vector()
        n = len(sel)
        if n == 0:
            continue
        for j, v in enumerate(var_ids):
            if v in b.var_ids:
                out[j, pos : pos + n] = b.columns[b.col_index(v), sel]
        pos += n
    cols = [out[j, :total] for j in range(len(var_ids))]
    return ColumnBatch.from_columns(var_ids, cols, None)
