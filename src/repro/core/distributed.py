"""Distributed BARQ: partitioned joins/aggregation via shard_map (beyond
paper — the multi-pod posture of DESIGN.md §2.1/§5).

Stardog's BARQ is single-node; scaling the same vectorized operators to a
TPU pod follows the classic Volcano exchange-operator recipe (the paper
cites Graefe [8] for exactly this): hash-partition both relations on the
join key (radix_partition kernel), exchange buckets with one all_to_all,
then run the *local* vectorized merge join per device. Keys are co-located
after the exchange, so local results concatenate to the global result;
COUNT-style queries reduce with one psum.

Everything here is static-shape: per-device bucket capacity is
ceil(n_local/P)*slack, rows beyond capacity are counted in an overflow
counter (monitoring surfaces it; production would re-run with higher
slack — same contract as MoE capacity dropping).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax

from repro.compat import shard_map
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_SENTINEL = jnp.iinfo(jnp.int32).max
_HASH_MULT = np.uint32(0x9E3779B1)

AXIS = "shard"


def engine_mesh(devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    return Mesh(np.asarray(devices), (AXIS,))


# ---------------------------------------------------------------------------
# exchange
# ---------------------------------------------------------------------------


def _exchange(rows: jax.Array, keys: jax.Array, n_parts: int, cap: int):
    """Inside shard_map: route rows to the device owning hash(key).

    rows: (C, n_local) int32; keys: (n_local,). Returns (C, n_parts*cap)
    received rows (padded with sentinel keys) + overflow count.
    """
    n_local = keys.shape[0]
    h = (keys.astype(jnp.uint32) * _HASH_MULT) >> np.uint32(16)
    pid = (h & np.uint32(n_parts - 1)).astype(jnp.int32)

    order = jnp.argsort(pid)
    pid_s = pid[order]
    rows_s = rows[:, order]
    keys_s = keys[order]

    # position of each row within its bucket
    start = jnp.searchsorted(pid_s, jnp.arange(n_parts, dtype=jnp.int32), side="left")
    within = jnp.arange(n_local, dtype=jnp.int32) - start[pid_s]
    ok = within < cap
    overflow = jnp.sum(~ok)

    buf_keys = jnp.full((n_parts, cap), _SENTINEL, jnp.int32)
    buf_rows = jnp.full((rows.shape[0], n_parts, cap), _SENTINEL, jnp.int32)
    iw = jnp.where(ok, within, cap - 1)  # clamp; overflow rows overwritten last
    buf_keys = buf_keys.at[pid_s, iw].set(jnp.where(ok, keys_s, _SENTINEL))
    buf_rows = buf_rows.at[:, pid_s, iw].set(
        jnp.where(ok[None, :], rows_s, _SENTINEL)
    )

    recv_keys = jax.lax.all_to_all(buf_keys, AXIS, 0, 0, tiled=False)
    recv_rows = jax.lax.all_to_all(buf_rows, AXIS, 1, 1, tiled=False)
    return (
        recv_rows.reshape(rows.shape[0], -1),
        recv_keys.reshape(-1),
        overflow,
    )


def _local_sorted(keys: jax.Array, rows: jax.Array):
    order = jnp.argsort(keys)  # sentinels sort to the end
    return keys[order], rows[:, order]


# ---------------------------------------------------------------------------
# distributed join (count + materialized-capacity forms)
# ---------------------------------------------------------------------------


def _join_count_local(lkeys, rkeys) -> jax.Array:
    """#matches of the sorted local shards (sentinel-padded)."""
    lo = jnp.searchsorted(rkeys, lkeys, side="left")
    hi = jnp.searchsorted(rkeys, lkeys, side="right")
    valid = lkeys != _SENTINEL
    return jnp.sum(jnp.where(valid, hi - lo, 0).astype(jnp.int32))


def make_join_count(mesh: Mesh, cap_factor: float = 2.0):
    """Returns jitted f(left_rows, right_rows, lkey_idx, rkey_idx) -> (count,
    overflow). Inputs are (C, N) int32 relations sharded on axis 1."""
    n_parts = mesh.devices.size

    def local(lrows, rrows):
        lkeys = lrows[0]
        rkeys = rrows[0]
        lcap = int(np.ceil(lkeys.shape[0] * cap_factor / n_parts))
        rcap = int(np.ceil(rkeys.shape[0] * cap_factor / n_parts))
        lrows2, lkeys2, lof = _exchange(lrows, lkeys, n_parts, lcap)
        rrows2, rkeys2, rof = _exchange(rrows, rkeys, n_parts, rcap)
        lkeys3, _ = _local_sorted(lkeys2, lrows2)
        rkeys3, _ = _local_sorted(rkeys2, rrows2)
        cnt = _join_count_local(lkeys3, rkeys3)
        total = jax.lax.psum(cnt, AXIS)
        of = jax.lax.psum(lof + rof, AXIS)
        return total, of

    shmapped = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, AXIS), P(None, AXIS)),
        out_specs=(P(), P()),
    )
    return jax.jit(shmapped)


def make_join_materialize(mesh: Mesh, out_cap_per_device: int, cap_factor: float = 2.0):
    """Materializing variant: returns per-device joined key column + left/
    right payload row indices up to a static capacity (overflow counted).
    Output: (keys (P*cap,), n_valid per device summed, overflow)."""
    n_parts = mesh.devices.size
    out_cap = out_cap_per_device

    def local(lrows, rrows):
        lkeys_raw = lrows[0]
        rkeys_raw = rrows[0]
        lcap = int(np.ceil(lkeys_raw.shape[0] * cap_factor / n_parts))
        rcap = int(np.ceil(rkeys_raw.shape[0] * cap_factor / n_parts))
        lrows2, lkeys2, lof = _exchange(lrows, lkeys_raw, n_parts, lcap)
        rrows2, rkeys2, rof = _exchange(rrows, rkeys_raw, n_parts, rcap)
        lkeys, lrows3 = _local_sorted(lkeys2, lrows2)
        rkeys, rrows3 = _local_sorted(rkeys2, rrows2)

        lo = jnp.searchsorted(rkeys, lkeys, side="left")
        hi = jnp.searchsorted(rkeys, lkeys, side="right")
        valid = lkeys != _SENTINEL
        counts = jnp.where(valid, hi - lo, 0)
        cum = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)]).astype(
            jnp.int32
        )
        total = cum[-1]
        # expand to out_cap slots (join_expand ref semantics)
        t = jnp.arange(out_cap, dtype=jnp.int32)
        g = jnp.clip(jnp.searchsorted(cum, t, side="right") - 1, 0, lkeys.shape[0] - 1)
        w = t - cum[g]
        li = g
        ri = lo[g] + w
        ok = t < total
        out_keys = jnp.where(ok, lkeys[li], _SENTINEL)
        out_li = jnp.where(ok, li, -1)
        out_ri = jnp.where(ok, ri, -1)
        of = jax.lax.psum(lof + rof + jnp.maximum(total - out_cap, 0), AXIS)
        n = jax.lax.psum(jnp.minimum(total, out_cap).astype(jnp.int32), AXIS)
        return out_keys, out_li, out_ri, n, of

    shmapped = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, AXIS), P(None, AXIS)),
        out_specs=(P(AXIS), P(AXIS), P(AXIS), P(), P()),
    )
    return jax.jit(shmapped)


def make_group_count(mesh: Mesh, cap_factor: float = 2.0, max_groups_per_dev: int = 1 << 16):
    """Distributed GROUP BY key COUNT(*): exchange by key hash, local sorted
    segment counts. Keys are co-located, so local runs are globally correct.
    Returns per-device (keys, counts) padded to max_groups_per_dev."""
    n_parts = mesh.devices.size

    def local(rows):
        keys_raw = rows[0]
        cap = int(np.ceil(keys_raw.shape[0] * cap_factor / n_parts))
        _, keys2, of = _exchange(rows, keys_raw, n_parts, cap)
        keys = jnp.sort(keys2)
        valid = keys != _SENTINEL
        is_start = jnp.concatenate(
            [valid[:1], (keys[1:] != keys[:-1]) & valid[1:]]
        )
        gid = jnp.cumsum(is_start.astype(jnp.int32)) - 1
        counts = jax.ops.segment_sum(
            valid.astype(jnp.int32), jnp.where(valid, gid, max_groups_per_dev - 1),
            num_segments=max_groups_per_dev,
        )
        first_pos = jnp.where(
            is_start, jnp.arange(keys.shape[0], dtype=jnp.int32), keys.shape[0] - 1
        )
        starts = jnp.concatenate(
            [
                jnp.sort(jnp.where(is_start, first_pos, jnp.iinfo(jnp.int32).max)),
                jnp.full((max_groups_per_dev,), jnp.iinfo(jnp.int32).max, jnp.int32),
            ]
        )[:max_groups_per_dev]
        gkeys = jnp.where(
            starts < keys.shape[0], keys[jnp.clip(starts, 0, keys.shape[0] - 1)], _SENTINEL
        )
        return gkeys, counts, jax.lax.psum(of, AXIS)

    shmapped = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, AXIS),),
        out_specs=(P(AXIS), P(AXIS), P()),
    )
    return jax.jit(shmapped)


# ---------------------------------------------------------------------------
# host-side convenience for tests / examples
# ---------------------------------------------------------------------------


def shard_relation(mesh: Mesh, rows: np.ndarray) -> jax.Array:
    """Pad a (C, N) relation to the mesh size and device_put it sharded."""
    n_dev = mesh.devices.size
    c, n = rows.shape
    n_pad = int(np.ceil(max(n, 1) / n_dev) * n_dev)
    out = np.full((c, n_pad), _SENTINEL, dtype=np.int32)
    out[:, :n] = rows
    return jax.device_put(out, NamedSharding(mesh, P(None, AXIS)))
