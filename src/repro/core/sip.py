"""Sideways information passing: the runtime SipFilter handle (DESIGN.md §12).

A SipFilter carries a summary of a join's build side — the min/max code
range plus a blocked bloom filter over the build keys — from the join that
produces it *sideways* into the probe-side Scan/PathExpand leaves, which
consume it before the join ever sees their rows:

  * sorted leaves narrow to the code range through the existing skip()
    machinery (seek to lo, stop past hi) and bloom-mask inside the range;
  * unsorted leaves apply the range + bloom membership test as a batch
    mask (no false negatives, so this is a pure prefilter: both engines
    return exactly the same multiset with SIP on or off).

The filter is lazy: the translator binds a provider closure onto the
exporting join, and the first consuming leaf forces it. For a HashJoin the
provider runs the build phase (already materialized before any probe batch
is pulled); for a MergeJoin whose build side is a Sort pipeline breaker it
forces the sort's materialization; a merely-sorted build side yields a
range-only filter (its min/max keys are O(1) reads off the index).

Providers return ("keys", np.ndarray) for a full bloom+range summary,
("range", lo, hi) for range-only, or None when nothing can be derived —
the filter then stays a pass-through forever.
"""

from __future__ import annotations

from time import perf_counter
from typing import Callable, Optional, Tuple

import numpy as np

from repro.kernels import ops as KOPS


class SipFilter:
    def __init__(self, var: int, sid: int = 0, backend: Optional[str] = None):
        self.var = var
        self.sid = sid
        self.backend = backend
        self._provider: Optional[Callable] = None
        self._ready = False
        self._available = False
        self.words: Optional[np.ndarray] = None
        self.lo = 0
        self.hi = -1  # (0, -1) == provably empty build side
        # counters surfaced through OpStats.extra by the consuming leaves
        self.rows_tested = 0
        self.rows_pruned = 0
        self.probe_dispatches = 0
        self.build_ms = 0.0

    # -- producer side -----------------------------------------------------

    def bind(self, provider: Callable) -> None:
        """Attach the build-side summary provider (translator wiring)."""
        self._provider = provider

    def reset(self) -> None:
        """Invalidate the summary (the exporting join was reset)."""
        self._ready = False
        self._available = False
        self.words = None
        self.lo, self.hi = 0, -1

    def ensure(self) -> None:
        if self._ready:
            return
        self._ready = True
        payload = self._provider() if self._provider is not None else None
        if payload is None:
            return  # pass-through: nothing derivable from the build side
        t0 = perf_counter()
        if payload[0] == "keys":
            keys = np.ascontiguousarray(payload[1], dtype=np.int32)
            self.words, self.lo, self.hi = KOPS.bloom_build(
                keys, backend=self.backend
            )
        else:  # ("range", lo, hi)
            _, self.lo, self.hi = payload
        self.build_ms += (perf_counter() - t0) * 1e3
        self._available = True

    # -- consumer side -----------------------------------------------------

    def code_range(self) -> Optional[Tuple[int, int]]:
        """(lo, hi) inclusive build-key range, or None for pass-through.
        hi < lo means the build side is empty: nothing can match."""
        self.ensure()
        return (self.lo, self.hi) if self._available else None

    def mask(self, codes: np.ndarray) -> Optional[np.ndarray]:
        """Bool keep-mask over ``codes`` (range + bloom membership), or
        None for pass-through. Conservative: may keep non-members (bloom
        false positives), never drops a member."""
        self.ensure()
        if not self._available:
            return None
        m = (codes >= self.lo) & (codes <= self.hi)
        if self.words is not None and m.any():
            self.probe_dispatches += 1
            m &= KOPS.bloom_probe(self.words, codes, backend=self.backend)
        self.rows_tested += len(codes)
        self.rows_pruned += int(len(codes) - m.sum())
        return m
