"""Fused whole-BGP execution (beyond paper — DESIGN.md §2.1).

Counts accumulate in int32 (x64 is disabled jax-wide); stores at the
scale where chain counts exceed 2^31 should flip jax_enable_x64.

The paper chose vectorization over code generation partly for
observability, noting the approaches can be combined later ('often used
SPARQL expressions … can be compiled', §3.1). On TPU, XLA *is* the code
generator: for hot query shapes the engine compiles the entire merge-join
pipeline into one jitted function over whole sorted relations — no
per-batch host round-trips, and counting without materialization where
the algebra allows it.

Two fused shapes are provided (the LSQB family the paper's motivating
example comes from):

  fused_chain_count — COUNT(*) of p1 ⋈ p2 ⋈ … ⋈ pk chains: weights
                      propagate right-to-left via searchsorted prefix
                      sums; intermediates never materialize.
  fused_q6_count    — the paper's Figure-1 query (2-hop :knows +
                      interests + FILTER ?a != ?c): the inequality is
                      pushed into closed form,
                         count = Σ chains − Σ_{mutual (a,b)} tags(a),
                      so even the paper's 46.7M-row intermediate never
                      exists.

Both validate against the operator engine (tests/test_fused.py) and
benchmark as 'barq_fused' rows in bench_lsqb.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.storage import QuadStore


def _pred_edges_sorted_by_subject(store: QuadStore, pred: str) -> np.ndarray:
    """(2, n) [subject, object] rows of one predicate, subject-sorted."""
    pid = store.dict.lookup(pred)
    if pid is None:
        return np.zeros((2, 0), dtype=np.int32)
    arr = store.index_array("psoc")  # (p, s, o, c) lexicographic
    lo = int(np.searchsorted(arr[:, 0], pid, side="left"))
    hi = int(np.searchsorted(arr[:, 0], pid, side="right"))
    return arr[lo:hi, 1:3].T.astype(np.int32)


@jax.jit
def _count_per_key(sorted_keys: jax.Array, queries: jax.Array) -> jax.Array:
    lo = jnp.searchsorted(sorted_keys, queries, side="left")
    hi = jnp.searchsorted(sorted_keys, queries, side="right")
    return (hi - lo).astype(jnp.int32)


@jax.jit
def _fold_weights(next_subj: jax.Array, w_next: jax.Array,
                  cur_obj: jax.Array) -> jax.Array:
    """weight(edge e of current relation) = Σ weights of next-relation rows
    whose subject equals e.object — a run-sum via prefix sums."""
    cw = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(w_next)])
    lo = jnp.searchsorted(next_subj, cur_obj, side="left")
    hi = jnp.searchsorted(next_subj, cur_obj, side="right")
    return cw[hi] - cw[lo]


def fused_chain_count(store: QuadStore, preds: List[str]) -> int:
    """COUNT(*) of ?x0 p1 ?x1 . ?x1 p2 ?x2 . … (left-deep chain BGP)."""
    rels = [_pred_edges_sorted_by_subject(store, p) for p in preds]
    if any(r.shape[1] == 0 for r in rels):
        return 0
    w = jnp.ones(rels[-1].shape[1], dtype=jnp.int32)
    for i in range(len(rels) - 2, -1, -1):
        w = _fold_weights(
            jnp.asarray(rels[i + 1][0]), w, jnp.asarray(rels[i][1])
        )
    return int(jnp.sum(w))


@jax.jit
def _q6_kernel(k_subj, k_obj, i_subj):
    # tags(c) for every knows edge (b, c)
    w2 = _count_per_key(i_subj, k_obj)
    # chains through each first-hop edge (a, b) = Σ_{(b, c)} tags(c)
    per_edge = _fold_weights(k_subj, w2, k_obj)
    total = jnp.sum(per_edge)

    # correction for ?a != ?c: chains with c == a exist iff (b, a) ∈ knows;
    # each mutual pair contributes tags(a). Membership test via composite
    # sorted keys (the relation is (subj, obj)-lex sorted already).
    base = jnp.maximum(jnp.max(k_subj), jnp.max(k_obj)).astype(jnp.int32) + 2
    comp = k_subj.astype(jnp.int32) * base + k_obj.astype(jnp.int32)
    rev = k_obj.astype(jnp.int32) * base + k_subj.astype(jnp.int32)
    pos = jnp.searchsorted(comp, rev, side="left")
    pos_c = jnp.clip(pos, 0, comp.shape[0] - 1)
    mutual = comp[pos_c] == rev
    tags_a = _count_per_key(i_subj, k_subj)
    correction = jnp.sum(jnp.where(mutual, tags_a, 0))
    return total - correction


def fused_q6_count(store: QuadStore, knows=":knows",
                   interest=":hasInterest") -> int:
    """The paper's Figure-1 query, fully fused (zero materialization)."""
    k = _pred_edges_sorted_by_subject(store, knows)
    it = _pred_edges_sorted_by_subject(store, interest)
    if k.shape[1] == 0 or it.shape[1] == 0:
        return 0
    return int(
        _q6_kernel(jnp.asarray(k[0]), jnp.asarray(k[1]), jnp.asarray(it[0]))
    )
