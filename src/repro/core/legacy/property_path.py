"""Row-based property-path operator (SPARQL `?x :p+ ?y`).

The paper's §4 names recursive operators — property paths — as the class
that is NOT vectorized in BARQ ('batch-based evaluation of joins or
filters has been thoroughly studied, this is less true for recursive
operators'). Faithfully, the operator exists only in the row-based engine;
the translator keeps it row-based under every engine mode and bridges it
into batch plans with a RowToBatch adapter — the §4.2 integration story
exercised end-to-end.

Evaluation: per-source BFS over the subject-sorted predicate range
(transitive closure, min_hops=1). Sources are enumerated in subject order,
so the output is sorted by the subject variable and merge-joins can
consume it directly.
"""

from __future__ import annotations

from typing import List, Optional, Set, Tuple

import numpy as np

from repro.core.legacy.operators import Row, RowOperator
from repro.core.storage import QuadStore


class RowTransitivePath(RowOperator):
    def __init__(self, store: QuadStore, pred, var_s: int, var_o: int):
        self.store = store
        self.var_s, self.var_o = var_s, var_o
        pid = store.dict.lookup(pred)
        arr = store.index_array("psoc")  # (p, s, o, c)
        if pid is None:
            self.edges = np.zeros((0, 2), dtype=np.int32)
        else:
            lo = int(np.searchsorted(arr[:, 0], pid, side="left"))
            hi = int(np.searchsorted(arr[:, 0], pid, side="right"))
            self.edges = arr[lo:hi, 1:3]  # (s, o), subject-sorted
        self.subjects = np.unique(self.edges[:, 0]) if len(self.edges) else np.zeros(0, np.int32)
        self._src_idx = 0
        self._targets: List[int] = []
        self._t_idx = 0
        super().__init__("PathScan", f"(?v{var_s}, +, ?v{var_o}) row-based")

    def var_ids(self) -> Tuple[int, ...]:
        return (self.var_s, self.var_o)

    def sorted_by(self) -> Optional[int]:
        return self.var_s

    def _successors(self, node: int) -> np.ndarray:
        lo = int(np.searchsorted(self.edges[:, 0], node, side="left"))
        hi = int(np.searchsorted(self.edges[:, 0], node, side="right"))
        return self.edges[lo:hi, 1]

    def _bfs(self, src: int) -> List[int]:
        seen: Set[int] = set()
        frontier = [src]
        order: List[int] = []
        while frontier:
            nxt: List[int] = []
            for u in frontier:
                for v in self._successors(u).tolist():
                    if v not in seen:
                        seen.add(v)
                        order.append(v)
                        nxt.append(v)
            frontier = nxt
        return sorted(order)  # deterministic object order within a subject

    def _next(self) -> Optional[Row]:
        while True:
            if self._t_idx < len(self._targets):
                src = int(self.subjects[self._src_idx - 1])
                tgt = self._targets[self._t_idx]
                self._t_idx += 1
                return {self.var_s: src, self.var_o: tgt}
            if self._src_idx >= len(self.subjects):
                return None
            src = int(self.subjects[self._src_idx])
            self._src_idx += 1
            self._targets = self._bfs(src)
            self._t_idx = 0
            self.stats.rows_scanned += len(self._targets)

    def _skip(self, var: int, target: int) -> None:
        assert var == self.var_s
        # gallop the source cursor; discard the in-flight target list if the
        # current source falls below the target
        pos = int(np.searchsorted(self.subjects, target, side="left"))
        if pos > self._src_idx - 1:
            self._src_idx = pos
            self._targets, self._t_idx = [], 0
        elif self._src_idx >= 1 and int(self.subjects[self._src_idx - 1]) < target:
            self._targets, self._t_idx = [], 0

    def _reset(self) -> None:
        self._src_idx = 0
        self._targets, self._t_idx = [], 0
