"""Row-based property-path operators — the correctness oracle.

The paper's §4 names recursive operators — property paths — as the class
that is NOT vectorized in BARQ. The vectorized subsystem
(repro.core.paths) now lifts them onto the batch pipeline; these row/set
implementations survive as (a) the legacy engine's path evaluator and
(b) the independent oracle the parity tests and benchmarks compare
against: ``eval_path_pairs`` evaluates any path expression with pure
Python sets — no shared code with the kernel path.

RowTransitivePath keeps the original per-source scalar BFS for `+` (the
§5-style row baseline the micro-benchmarks measure speedup against).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.algebra import K, Slot, V
from repro.core.legacy.operators import Row, RowOperator
from repro.core.paths.expr import (
    PAlt,
    PathExpr,
    PClosure,
    PInv,
    PLink,
    PSeq,
    matches_zero_length,
    path_repr,
)
from repro.core.storage import QuadStore


class RowTransitivePath(RowOperator):
    def __init__(self, store: QuadStore, pred, var_s: int, var_o: int):
        self.store = store
        self.var_s, self.var_o = var_s, var_o
        pid = store.dict.lookup(pred)
        arr = store.index_array("psoc")  # (p, s, o, c)
        if pid is None:
            self.edges = np.zeros((0, 2), dtype=np.int32)
        else:
            lo = int(np.searchsorted(arr[:, 0], pid, side="left"))
            hi = int(np.searchsorted(arr[:, 0], pid, side="right"))
            self.edges = arr[lo:hi, 1:3]  # (s, o), subject-sorted
        self.subjects = np.unique(self.edges[:, 0]) if len(self.edges) else np.zeros(0, np.int32)
        self._src_idx = 0
        self._targets: List[int] = []
        self._t_idx = 0
        super().__init__("PathScan", f"(?v{var_s}, +, ?v{var_o}) row-based")

    def var_ids(self) -> Tuple[int, ...]:
        return (self.var_s, self.var_o)

    def sorted_by(self) -> Optional[int]:
        return self.var_s

    def _successors(self, node: int) -> np.ndarray:
        lo = int(np.searchsorted(self.edges[:, 0], node, side="left"))
        hi = int(np.searchsorted(self.edges[:, 0], node, side="right"))
        return self.edges[lo:hi, 1]

    def _bfs(self, src: int) -> List[int]:
        seen: Set[int] = set()
        frontier = [src]
        order: List[int] = []
        while frontier:
            nxt: List[int] = []
            for u in frontier:
                for v in self._successors(u).tolist():
                    if v not in seen:
                        seen.add(v)
                        order.append(v)
                        nxt.append(v)
            frontier = nxt
        return sorted(order)  # deterministic object order within a subject

    def _next(self) -> Optional[Row]:
        while True:
            if self._t_idx < len(self._targets):
                src = int(self.subjects[self._src_idx - 1])
                tgt = self._targets[self._t_idx]
                self._t_idx += 1
                return {self.var_s: src, self.var_o: tgt}
            if self._src_idx >= len(self.subjects):
                return None
            src = int(self.subjects[self._src_idx])
            self._src_idx += 1
            self._targets = self._bfs(src)
            self._t_idx = 0
            self.stats.rows_scanned += len(self._targets)

    def _skip(self, var: int, target: int) -> None:
        assert var == self.var_s
        # gallop the source cursor; discard the in-flight target list if the
        # current source falls below the target
        pos = int(np.searchsorted(self.subjects, target, side="left"))
        if pos > self._src_idx - 1:
            self._src_idx = pos
            self._targets, self._t_idx = [], 0
        elif self._src_idx >= 1 and int(self.subjects[self._src_idx - 1]) < target:
            self._targets, self._t_idx = [], 0

    def _reset(self) -> None:
        self._src_idx = 0
        self._targets, self._t_idx = [], 0


# ---------------------------------------------------------------------------
# set-based oracle for arbitrary path expressions
# ---------------------------------------------------------------------------


def _graph_domain(store: QuadStore) -> Set[int]:
    """Zero-length path domain: every term used as subject or object."""
    spoc = store.index_array("spoc")
    return set(spoc[:, 0].tolist()) | set(spoc[:, 2].tolist())


def eval_path_pairs(store: QuadStore, expr: PathExpr) -> Set[Tuple[int, int]]:
    """All (subject, object) code pairs of a path expression, computed
    with Python sets (deliberately kernel-free: the parity oracle)."""
    if isinstance(expr, PLink):
        pid = store.dict.lookup(expr.pred)
        if pid is None:
            return set()
        arr = store.index_array("psoc")
        lo = int(np.searchsorted(arr[:, 0], pid, side="left"))
        hi = int(np.searchsorted(arr[:, 0], pid, side="right"))
        return {(int(s), int(o)) for s, o in arr[lo:hi, 1:3]}
    if isinstance(expr, PInv):
        return {(o, s) for s, o in eval_path_pairs(store, expr.sub)}
    if isinstance(expr, PSeq):
        pairs = eval_path_pairs(store, expr.parts[0])
        for part in expr.parts[1:]:
            nxt: Dict[int, Set[int]] = {}
            for s, o in eval_path_pairs(store, part):
                nxt.setdefault(s, set()).add(o)
            pairs = {(s, z) for s, o in pairs for z in nxt.get(o, ())}
        return pairs
    if isinstance(expr, PAlt):
        out: Set[Tuple[int, int]] = set()
        for part in expr.parts:
            out |= eval_path_pairs(store, part)
        return out
    if isinstance(expr, PClosure):
        base = eval_path_pairs(store, expr.sub)
        if expr.max_hops == 1:
            pairs = set(base)
        else:
            adj: Dict[int, Set[int]] = {}
            for s, o in base:
                adj.setdefault(s, set()).add(o)
            pairs = set()
            for src in adj:
                seen: Set[int] = set()
                frontier = [src]
                while frontier:
                    nxt_frontier: List[int] = []
                    for u in frontier:
                        for v in adj.get(u, ()):
                            if v not in seen:
                                seen.add(v)
                                nxt_frontier.append(v)
                    frontier = nxt_frontier
                pairs |= {(src, t) for t in seen}
        if expr.min_hops == 0:
            pairs |= {(d, d) for d in _graph_domain(store)}
        return pairs
    raise TypeError(type(expr))


class RowPathScan(RowOperator):
    """Legacy-engine evaluator for arbitrary path patterns: materializes
    ``eval_path_pairs`` filtered by bound endpoints, emits rows sorted by
    the subject (then object) variable."""

    def __init__(self, store: QuadStore, expr: PathExpr, s_slot: Slot, o_slot: Slot):
        self.store = store
        self.expr = expr
        self.s_slot, self.o_slot = s_slot, o_slot
        pairs = eval_path_pairs(store, expr)
        if matches_zero_length(expr):
            # a bound endpoint matches itself via the empty walk even when
            # the term never appears in the graph
            for sl in (s_slot, o_slot):
                if isinstance(sl, K):
                    tid = store.dict.lookup(sl.term)
                    if tid is not None:
                        pairs.add((tid, tid))
        if isinstance(s_slot, K):
            sid = store.dict.lookup(s_slot.term)
            pairs = {p for p in pairs if p[0] == sid}
        if isinstance(o_slot, K):
            oid = store.dict.lookup(o_slot.term)
            pairs = {p for p in pairs if p[1] == oid}
        if (
            isinstance(s_slot, V)
            and isinstance(o_slot, V)
            and s_slot.id == o_slot.id
        ):
            pairs = {p for p in pairs if p[0] == p[1]}
        self.pairs = sorted(pairs)
        self._i = 0
        super().__init__("PathScan", f"({path_repr(expr)}) row-based")

    def var_ids(self) -> Tuple[int, ...]:
        out = []
        for sl in (self.s_slot, self.o_slot):
            if isinstance(sl, V) and sl.id not in out:
                out.append(sl.id)
        return tuple(out)

    def sorted_by(self) -> Optional[int]:
        if isinstance(self.s_slot, V):
            return self.s_slot.id
        return self.o_slot.id if isinstance(self.o_slot, V) else None

    def _next(self) -> Optional[Row]:
        if self._i >= len(self.pairs):
            return None
        s, o = self.pairs[self._i]
        self._i += 1
        row: Row = {}
        if isinstance(self.s_slot, V):
            row[self.s_slot.id] = s
        if isinstance(self.o_slot, V):
            row[self.o_slot.id] = o
        return row

    def _skip(self, var: int, target: int) -> None:
        if var != self.sorted_by():
            return
        col = 0 if isinstance(self.s_slot, V) else 1
        while self._i < len(self.pairs) and self.pairs[self._i][col] < target:
            self._i += 1

    def _reset(self) -> None:
        self._i = 0
