"""The legacy tuple-at-a-time Volcano engine (paper §2.2.3) — the baseline.

Each operator returns a single solution per ``next()`` call; sorted
operators additionally support ``skip(target)`` repositioning (§2.2.3).
Rows are dicts {var_id: code}. The per-tuple virtual-call overhead the
paper measures against is, here, per-tuple Python dispatch — the honest
analogue of JVM virtual calls (DESIGN.md §2).

The evaluation in §5 requires this engine: every benchmark reports
BARQ vs legacy on identical plans.
"""

from __future__ import annotations

import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.algebra import AggSpec, Expr, K, SortKey, TriplePattern, V
from repro.core.batch import NULL_ID, ColumnBatch
from repro.core.dictionary import Dictionary
from repro.core.expressions import eval_expr_mask, eval_expr_values
from repro.core.operators.base import OpStats
from repro.core.storage import INDEX_ORDERS, QuadStore, ScanRange

Row = Dict[int, int]


class RowOperator:
    def __init__(self, name: str, detail: str = "") -> None:
        self.stats = OpStats(name, detail)

    def next_row(self) -> Optional[Row]:
        self.stats.next_calls += 1
        t0 = time.perf_counter()
        r = self._next()
        self.stats.wall_time += time.perf_counter() - t0
        if r is not None:
            self.stats.results += 1
        return r

    def skip(self, var: int, target: int) -> None:
        self.stats.skip_calls += 1
        self._skip(var, target)

    def reset(self) -> None:
        self.stats.reset_calls += 1
        self._reset()

    def var_ids(self) -> Tuple[int, ...]:
        raise NotImplementedError

    def sorted_by(self) -> Optional[int]:
        return None

    def supports_skip(self) -> bool:
        return self.sorted_by() is not None

    def children(self) -> List["RowOperator"]:
        return []

    def _next(self) -> Optional[Row]:
        raise NotImplementedError

    def _skip(self, var: int, target: int) -> None:
        raise NotImplementedError

    def _reset(self) -> None:
        raise NotImplementedError

    def drain(self) -> List[Row]:
        out = []
        while True:
            r = self.next_row()
            if r is None:
                return out
            out.append(r)


class RowScan(RowOperator):
    """Tuple-at-a-time index scan with storage seek on skip()."""

    def __init__(self, store: QuadStore, pattern: TriplePattern,
                 want_sorted_var: Optional[int] = None):
        self.store = store
        self.pattern = pattern
        self._dead = False
        bound: List[Optional[int]] = [None, None, None, None]
        for role, sl in enumerate((pattern.s, pattern.p, pattern.o, pattern.g)):
            if isinstance(sl, K):
                tid = store.dict.lookup(sl.term)
                if tid is None:
                    self._dead = True
                    tid = -1
                bound[role] = tid
        self.bound = bound
        self.role_of_var: Dict[int, int] = {}
        self.residual_pairs: List[Tuple[int, int]] = []
        for role, sl in enumerate((pattern.s, pattern.p, pattern.o, pattern.g)):
            if isinstance(sl, V):
                if sl.id in self.role_of_var:
                    self.residual_pairs.append((self.role_of_var[sl.id], role))
                else:
                    self.role_of_var[sl.id] = role
        want_role = self.role_of_var.get(want_sorted_var) if want_sorted_var is not None else None
        self.index = store.choose_index(bound, want_role)
        self.perm = INDEX_ORDERS[self.index]
        self._vars = tuple(self.role_of_var)
        self.var_col_pos = {v: self.perm.index(r) for v, r in self.role_of_var.items()}
        n_bound = 0
        while n_bound < 4 and bound[self.perm[n_bound]] is not None:
            n_bound += 1
        self._sort_col_pos = n_bound if n_bound < 4 else None
        self._sorted_var = None
        if self._sort_col_pos is not None:
            role = self.perm[self._sort_col_pos]
            for v, r in self.role_of_var.items():
                if r == role:
                    self._sorted_var = v
        self.range: ScanRange = (
            ScanRange(self.index, 0, 0) if self._dead
            else store.range_for_pattern(self.index, bound)
        )
        self.offset = 0
        super().__init__("Scan", "(row)")

    def var_ids(self) -> Tuple[int, ...]:
        return self._vars

    def sorted_by(self) -> Optional[int]:
        return self._sorted_var

    def _next(self) -> Optional[Row]:
        while self.offset < len(self.range):
            row = self.store.read(self.range, self.offset, 1)[0]
            self.offset += 1
            self.stats.rows_scanned += 1
            ok = True
            for ra, rb in self.residual_pairs:
                if row[self.perm.index(ra)] != row[self.perm.index(rb)]:
                    ok = False
                    break
            if ok:
                return {v: int(row[self.var_col_pos[v]]) for v in self._vars}
        return None

    def _skip(self, var: int, target: int) -> None:
        assert var == self._sorted_var
        self.offset = self.store.seek(self.range, self.offset, self._sort_col_pos, target)

    def _reset(self) -> None:
        self.offset = 0

    def estimated_rows(self) -> int:
        return len(self.range)


class RowMergeJoin(RowOperator):
    """Classic one-tuple-at-a-time merge join with skip() (paper §2.2.3).
    ``post_filter`` implements the SPARQL LeftJoin condition: a row pair
    only counts as a match if the expression holds on the joined row (so a
    fully-filtered group still yields the NULL-extended left row)."""

    def __init__(self, left: RowOperator, right: RowOperator, join_var: int,
                 mode: str = "inner", post_filter=None, dictionary=None):
        assert left.sorted_by() == join_var and right.sorted_by() == join_var
        assert mode in ("inner", "left_outer", "semi", "anti")
        self.left, self.right, self.v, self.mode = left, right, join_var, mode
        self.post_filter = post_filter
        self.dictionary = dictionary
        lv, rv = tuple(left.var_ids()), tuple(right.var_ids())
        self.shared = tuple(x for x in lv if x in rv)
        self._vars = lv if mode in ("semi", "anti") else lv + tuple(
            x for x in rv if x not in lv
        )
        self._lrow: Optional[Row] = None
        self._rgroup: List[Row] = []
        self._rgroup_key: Optional[int] = None
        self._rnext: Optional[Row] = None
        self._gi = 0  # cursor within right group
        self._right_done = False
        self._lrow_matched = False
        super().__init__("MergeJoin", f"(?v{join_var}) row mode={mode}")

    def var_ids(self) -> Tuple[int, ...]:
        return self._vars

    def sorted_by(self) -> Optional[int]:
        return None if self.mode == "left_outer" else self.v

    def children(self) -> List[RowOperator]:
        return [self.left, self.right]

    def _advance_left(self) -> None:
        self._lrow = self.left.next_row()
        self._gi = 0
        self._lrow_matched = False

    def _load_right_group(self, key: int) -> None:
        """Position the right group buffer at the first key >= key."""
        if self._rgroup_key is not None and self._rgroup_key == key:
            return
        if self._rgroup_key is not None and self._rgroup_key > key:
            return
        # gallop via skip
        if self._rnext is None and not self._right_done:
            if self.right.supports_skip():
                self.right.skip(self.v, key)
            self._rnext = self.right.next_row()
            if self._rnext is None:
                self._right_done = True
        while self._rnext is not None and self._rnext[self.v] < key:
            if self.right.supports_skip():
                self.right.skip(self.v, key)
            self._rnext = self.right.next_row()
            if self._rnext is None:
                self._right_done = True
        self._rgroup = []
        self._rgroup_key = None
        if self._rnext is None:
            return
        gkey = self._rnext[self.v]
        self._rgroup_key = gkey
        while self._rnext is not None and self._rnext[self.v] == gkey:
            self._rgroup.append(self._rnext)
            self._rnext = self.right.next_row()
            if self._rnext is None:
                self._right_done = True

    def _next(self) -> Optional[Row]:
        while True:
            if self._lrow is None:
                self._advance_left()
                if self._lrow is None:
                    return None
            k = self._lrow[self.v]
            self._load_right_group(k)
            if self._rgroup_key != k:
                # no match for this left row
                lr = self._lrow
                self._advance_left()
                if self.mode == "left_outer":
                    return dict(lr)
                if self.mode == "anti":
                    return dict(lr)
                continue
            # matched group
            if self.mode == "anti":
                # check secondary keys
                if self._anti_semi_match(self._lrow):
                    self._advance_left()
                    continue
                lr = self._lrow
                self._advance_left()
                return dict(lr)
            if self.mode == "semi":
                lr = self._lrow
                matched = self._anti_semi_match(lr)
                self._advance_left()
                if matched:
                    return dict(lr)
                continue
            # inner / left_outer: iterate group
            while self._gi < len(self._rgroup):
                rrow = self._rgroup[self._gi]
                self._gi += 1
                ok = all(self._lrow.get(s) == rrow.get(s) for s in self.shared)
                if ok:
                    out = dict(self._lrow)
                    for kk, vv in rrow.items():
                        out.setdefault(kk, vv)
                    if self.post_filter is not None and not self._expr_ok(out):
                        continue  # not a match under the join condition
                    self._lrow_matched = True
                    return out
            lr, was_matched = self._lrow, self._lrow_matched
            self._advance_left()
            if self.mode == "left_outer" and not was_matched:
                return dict(lr)

    def _anti_semi_match(self, lrow: Row) -> bool:
        return any(
            all(lrow.get(s) == r.get(s) for s in self.shared) for r in self._rgroup
        )

    def _expr_ok(self, row: Row) -> bool:
        b = _row_to_batch(row, self._vars)
        return bool(eval_expr_mask(self.post_filter, b, self.dictionary)[0])

    def _skip(self, var: int, target: int) -> None:
        assert var == self.v
        if self.left.supports_skip():
            self.left.skip(var, target)
        self._lrow = None
        self._gi = 0

    def _reset(self) -> None:
        self.left.reset()
        self.right.reset()
        self._lrow = None
        self._rgroup, self._rgroup_key, self._rnext = [], None, None
        self._right_done = False
        self._gi = 0


class RowHashJoin(RowOperator):
    """Classic hash join — the row engine's general join for unsorted
    inputs (the legacy translation of PHashJoin). The build side loads
    into a key-tuple → rows dict; probe rows stream through. Unbound key
    slots hash as None and match each other, mirroring the batch engine's
    NULL_ID-equals-itself semantics. An empty key tuple is the degenerate
    constant-key join (cross / NULL-extending cross / exists-anything),
    the shape the disjoint OPTIONAL and FILTER NOT EXISTS fixes need.
    ``post_filter`` is the SPARQL LeftJoin condition: a probe row whose
    matches all fail it still emits, NULL-extended."""

    def __init__(self, probe: RowOperator, build: RowOperator,
                 keys: Sequence[int], mode: str = "inner",
                 post_filter=None, dictionary=None):
        assert mode in ("inner", "left_outer", "semi", "anti")
        self.probe, self.build = probe, build
        self.keys = tuple(keys)
        self.mode = mode
        self.post_filter = post_filter
        self.dictionary = dictionary
        pv, bv = tuple(probe.var_ids()), tuple(build.var_ids())
        self.shared = tuple(x for x in pv if x in bv)
        self._vars = pv if mode in ("semi", "anti") else pv + tuple(
            x for x in bv if x not in pv
        )
        self._table: Optional[Dict[Tuple, List[Row]]] = None
        self._emit: List[Row] = []
        self._ei = 0  # cursor into _emit (front-pops would be O(n) each)
        super().__init__(
            "HashJoin", f"({','.join(f'?v{k}' for k in self.keys)}) row mode={mode}"
        )

    def var_ids(self) -> Tuple[int, ...]:
        return self._vars

    def sorted_by(self) -> Optional[int]:
        if self.mode == "left_outer" and self.post_filter is not None:
            return None
        return self.probe.sorted_by()

    def children(self) -> List[RowOperator]:
        return [self.probe, self.build]

    def _ensure_table(self) -> None:
        if self._table is not None:
            return
        self._table = {}
        while True:
            r = self.build.next_row()
            if r is None:
                break
            key = tuple(r.get(k) for k in self.keys)
            self._table.setdefault(key, []).append(r)

    def _expr_ok(self, row: Row) -> bool:
        b = _row_to_batch(row, self._vars)
        return bool(eval_expr_mask(self.post_filter, b, self.dictionary)[0])

    def _next(self) -> Optional[Row]:
        self._ensure_table()
        while True:
            if self._ei < len(self._emit):
                r = self._emit[self._ei]
                self._ei += 1
                return r
            lrow = self.probe.next_row()
            if lrow is None:
                return None
            group = self._table.get(tuple(lrow.get(k) for k in self.keys), [])
            matches = [
                r for r in group
                if all(lrow.get(s) == r.get(s) for s in self.shared)
            ]
            if self.mode == "semi":
                if matches:
                    return dict(lrow)
                continue
            if self.mode == "anti":
                if not matches:
                    return dict(lrow)
                continue
            out_rows = []
            for r in matches:
                out = dict(lrow)
                for k, v in r.items():
                    out.setdefault(k, v)
                if self.post_filter is not None and not self._expr_ok(out):
                    continue
                out_rows.append(out)
            if self.mode == "left_outer" and not out_rows:
                out_rows.append(dict(lrow))
            if self.mode == "inner" and not out_rows:
                continue
            self._emit = out_rows
            self._ei = 0

    def _skip(self, var: int, target: int) -> None:
        # buffered rows at or above the target must survive the gallop
        self._emit = [
            r for r in self._emit[self._ei:] if r.get(var, -1) >= target
        ]
        self._ei = 0
        self.probe.skip(var, target)

    def _reset(self) -> None:
        self.probe.reset()
        self.build.reset()
        self._table = None
        self._emit = []
        self._ei = 0


class RowFilter(RowOperator):
    def __init__(self, child: RowOperator, expr: Expr, dictionary: Dictionary):
        self.child, self.expr, self.dictionary = child, expr, dictionary
        super().__init__("Filter", "(row)")

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def sorted_by(self) -> Optional[int]:
        return self.child.sorted_by()

    def children(self) -> List[RowOperator]:
        return [self.child]

    def _row_ok(self, row: Row) -> bool:
        b = _row_to_batch(row, self.child.var_ids())
        return bool(eval_expr_mask(self.expr, b, self.dictionary)[0])

    def _next(self) -> Optional[Row]:
        while True:
            r = self.child.next_row()
            if r is None:
                return None
            if self._row_ok(r):
                return r

    def _skip(self, var: int, target: int) -> None:
        self.child.skip(var, target)

    def _reset(self) -> None:
        self.child.reset()


def _row_to_batch(row: Row, vars_: Sequence[int]) -> ColumnBatch:
    cols = [np.asarray([row.get(v, int(NULL_ID))], dtype=np.int32) for v in vars_]
    return ColumnBatch.from_columns(tuple(vars_), cols)


class RowProject(RowOperator):
    def __init__(self, child: RowOperator, keep: Sequence[int]):
        self.child, self.keep = child, tuple(keep)
        super().__init__("Project", "(row)")

    def var_ids(self) -> Tuple[int, ...]:
        return self.keep

    def sorted_by(self) -> Optional[int]:
        sb = self.child.sorted_by()
        return sb if sb in self.keep else None

    def children(self) -> List[RowOperator]:
        return [self.child]

    def _next(self) -> Optional[Row]:
        r = self.child.next_row()
        if r is None:
            return None
        return {v: r[v] for v in self.keep if v in r}

    def _skip(self, var: int, target: int) -> None:
        self.child.skip(var, target)

    def _reset(self) -> None:
        self.child.reset()


class RowDistinct(RowOperator):
    def __init__(self, child: RowOperator):
        self.child = child
        self._seen: set = set()
        super().__init__("Distinct", "(row hash)")

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def children(self) -> List[RowOperator]:
        return [self.child]

    def _next(self) -> Optional[Row]:
        while True:
            r = self.child.next_row()
            if r is None:
                return None
            key = tuple(sorted(r.items()))
            if key not in self._seen:
                self._seen.add(key)
                return r

    def _reset(self) -> None:
        self.child.reset()
        self._seen.clear()


class RowGroupBy(RowOperator):
    """Hash-based GROUP BY (the legacy engine's general algorithm)."""

    def __init__(self, child: RowOperator, group_vars: Sequence[int],
                 aggs: Sequence[AggSpec], dictionary: Dictionary):
        self.child = child
        self.group_vars = tuple(group_vars)
        self.aggs = list(aggs)
        self.dictionary = dictionary
        self._out: Optional[Iterator] = None
        super().__init__("Group", "(row hash)")

    def var_ids(self) -> Tuple[int, ...]:
        return self.group_vars + tuple(a.out for a in self.aggs)

    def children(self) -> List[RowOperator]:
        return [self.child]

    def _fresh_state(self) -> List[dict]:
        return [dict(count=0.0, bound=0.0, sum=0.0, min=np.inf, max=-np.inf,
                     nn=0.0, distinct=set()) for _ in self.aggs]

    def _build(self) -> Iterator[Row]:
        groups: Dict[Tuple, List] = {}
        while True:
            r = self.child.next_row()
            if r is None:
                break
            key = tuple(r.get(v, int(NULL_ID)) for v in self.group_vars)
            st = groups.get(key)
            if st is None:
                st = self._fresh_state()
                groups[key] = st
            for ai, a in enumerate(self.aggs):
                s = st[ai]
                s["count"] += 1
                if a.var is None:
                    continue
                code = r.get(a.var)
                if code is None:
                    continue  # unbound rows never feed an aggregate
                s["bound"] += 1
                if a.distinct:
                    # dedup by bound code; the aggregate function applies
                    # over the distinct set at finalization
                    s["distinct"].add(code)
                    continue
                v = self.dictionary.numeric_of(np.asarray([code]))[0]
                if not np.isnan(v):
                    s["nn"] += 1
                    s["sum"] += v
                    s["min"] = min(s["min"], v)
                    s["max"] = max(s["max"], v)
        if not groups and not self.group_vars:
            groups[()] = self._fresh_state()
        for key, st in groups.items():
            row = {v: key[i] for i, v in enumerate(self.group_vars)}
            for ai, a in enumerate(self.aggs):
                s = st[ai]
                if a.distinct and a.var is not None:
                    codes = np.asarray(sorted(s["distinct"]), dtype=np.int64)
                    vals = self.dictionary.numeric_of(codes)
                    ok = ~np.isnan(vals)
                    nums = vals[ok]
                    if a.func == "count":
                        val = float(len(codes))  # distinct bound terms
                    elif a.func == "sum":
                        val = float(nums.sum()) if len(nums) else 0.0
                    elif a.func == "min":
                        val = float(nums.min()) if len(nums) else None
                    elif a.func == "max":
                        val = float(nums.max()) if len(nums) else None
                    elif a.func == "avg":
                        val = float(nums.mean()) if len(nums) else None
                    else:
                        raise ValueError(a.func)
                elif a.func == "count" and a.var is None:
                    val = s["count"]
                elif a.func == "count":
                    val = s["bound"]  # SPARQL: COUNT counts bound terms
                elif a.func == "sum":
                    val = s["sum"]
                elif a.func == "min":
                    val = s["min"] if s["nn"] else None
                elif a.func == "max":
                    val = s["max"] if s["nn"] else None
                elif a.func == "avg":
                    val = s["sum"] / s["nn"] if s["nn"] else None
                else:
                    raise ValueError(a.func)
                if val is None:
                    continue  # empty / non-numeric group: leave unbound
                enc = int(val) if float(val).is_integer() else float(val)
                row[a.out] = self.dictionary.encode(enc)
            yield row

    def _next(self) -> Optional[Row]:
        if self._out is None:
            self._out = self._build()
        return next(self._out, None)

    def _reset(self) -> None:
        self.child.reset()
        self._out = None


class RowSort(RowOperator):
    def __init__(self, child: RowOperator, var: Optional[int] = None,
                 keys: Optional[Sequence[SortKey]] = None,
                 dictionary: Optional[Dictionary] = None):
        self.child = child
        self.var = var
        self.keys = keys
        self.dictionary = dictionary
        self._rows: Optional[List[Row]] = None
        self._i = 0
        super().__init__("Sort", f"(?v{var})" if var is not None else "(order by)")

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def sorted_by(self) -> Optional[int]:
        return self.var

    def children(self) -> List[RowOperator]:
        return [self.child]

    def _ensure(self) -> None:
        if self._rows is not None:
            return
        rows = self.child.drain()
        if self.var is not None:
            rows.sort(key=lambda r: r.get(self.var, int(NULL_ID)))
        else:
            def key(r):
                ks = []
                for k in self.keys:
                    code = r.get(k.var, int(NULL_ID))
                    v = self.dictionary.numeric_of(np.asarray([code]))[0]
                    nan = np.isnan(v)
                    prim = np.inf if nan else (v if k.ascending else -v)
                    tie = (code if k.ascending else -code) if nan else 0
                    ks.extend([prim, tie])
                return tuple(ks)
            rows.sort(key=key)
        self._rows = rows

    def _next(self) -> Optional[Row]:
        self._ensure()
        if self._i >= len(self._rows):
            return None
        r = self._rows[self._i]
        self._i += 1
        return r

    def _skip(self, var: int, target: int) -> None:
        assert var == self.var
        self._ensure()
        while self._i < len(self._rows) and self._rows[self._i].get(var, -1) < target:
            self._i += 1

    def _reset(self) -> None:
        self.child.reset()
        self._rows = None
        self._i = 0


class RowLimit(RowOperator):
    def __init__(self, child: RowOperator, limit: Optional[int], offset: int = 0):
        self.child = child
        self.limit, self.offset = limit, offset
        self._seen = 0
        self._emitted = 0
        super().__init__("Slice", "(row)")

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def sorted_by(self) -> Optional[int]:
        return self.child.sorted_by()

    def children(self) -> List[RowOperator]:
        return [self.child]

    def _next(self) -> Optional[Row]:
        while True:
            if self.limit is not None and self._emitted >= self.limit:
                return None
            r = self.child.next_row()
            if r is None:
                return None
            self._seen += 1
            if self._seen <= self.offset:
                continue
            self._emitted += 1
            return r

    def _reset(self) -> None:
        self.child.reset()
        self._seen = self._emitted = 0


class RowUnion(RowOperator):
    def __init__(self, left: RowOperator, right: RowOperator):
        self.left, self.right = left, right
        lv = tuple(left.var_ids())
        self._vars = lv + tuple(v for v in right.var_ids() if v not in lv)
        self._on_right = False
        super().__init__("Union", "(row)")

    def var_ids(self) -> Tuple[int, ...]:
        return self._vars

    def children(self) -> List[RowOperator]:
        return [self.left, self.right]

    def _next(self) -> Optional[Row]:
        if not self._on_right:
            r = self.left.next_row()
            if r is not None:
                return r
            self._on_right = True
        return self.right.next_row()

    def _reset(self) -> None:
        self.left.reset()
        self.right.reset()
        self._on_right = False


class RowBindJoin(RowOperator):
    """Block-based bind join (paper §4.2 footnote 14): pull a block of ~1K
    left tuples, push their join-key bindings into the right side (re-scoped
    via skip), evaluate, repeat. The legacy optimizer prefers this plan shape
    for amplifying joins (paper Listing 4)."""

    def __init__(self, left: RowOperator, right_factory, join_var: int,
                 block_size: int = 1024):
        self.left = left
        self.right_factory = right_factory  # (code,) -> RowOperator for bound key
        self.v = join_var
        self.block_size = block_size
        self._block: List[Row] = []
        self._bi = 0
        self._right: Optional[RowOperator] = None
        self._left_done = False
        lv = tuple(left.var_ids())
        probe = right_factory(0)
        self._vars = lv + tuple(x for x in probe.var_ids() if x not in lv)
        super().__init__("BindJoin", f"(?v{join_var}) block={block_size}")

    def var_ids(self) -> Tuple[int, ...]:
        return self._vars

    def children(self) -> List[RowOperator]:
        return [self.left]

    def _next(self) -> Optional[Row]:
        while True:
            if self._right is not None:
                r = self._right.next_row()
                while r is not None:
                    lrow = self._block[self._bi]
                    if all(lrow.get(k) == r.get(k) for k in r if k in lrow):
                        out = dict(lrow)
                        out.update(r)
                        return out
                    r = self._right.next_row()
                self._right = None
                self._bi += 1
            if self._bi < len(self._block):
                lrow = self._block[self._bi]
                self._right = self.right_factory(lrow[self.v])
                continue
            if self._left_done:
                return None
            self._block = []
            self._bi = 0
            while len(self._block) < self.block_size:
                lr = self.left.next_row()
                if lr is None:
                    self._left_done = True
                    break
                self._block.append(lr)
            if not self._block and self._left_done:
                return None

    def _reset(self) -> None:
        self.left.reset()
        self._block, self._bi, self._right = [], 0, None
        self._left_done = False
