from repro.core.legacy.operators import (  # noqa: F401
    RowBindJoin,
    RowDistinct,
    RowFilter,
    RowGroupBy,
    RowLimit,
    RowMergeJoin,
    RowOperator,
    RowProject,
    RowScan,
    RowSort,
    RowUnion,
)
