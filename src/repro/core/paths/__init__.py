"""Vectorized property-path subsystem (SPARQL 1.1 paths, DESIGN.md §8).

BARQ (§4) leaves recursive operators on the row engine; this package lifts
them onto the batch pipeline: path expressions compile to edge *relations*
(sorted (src, dst) pair arrays) and closures run as semi-naive
delta-frontier BFS where every round expands the whole frontier with the
same kernels the join operators use (sorted_search + gather-style
expansion) plus a dedicated frontier_dedup kernel.
"""

from repro.core.paths.expr import (
    PAlt,
    PathExpr,
    PClosure,
    PInv,
    PLink,
    PSeq,
    path_repr,
)
from repro.core.paths.engine import PathEngine, PathResult

__all__ = [
    "PAlt",
    "PClosure",
    "PInv",
    "PLink",
    "PSeq",
    "PathExpr",
    "PathEngine",
    "PathResult",
    "path_repr",
]
