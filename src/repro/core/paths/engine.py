"""Batched property-path evaluation: semi-naive delta-frontier BFS.

A path expression compiles to an *edge relation* — two int32 arrays
(src, dst), lexicographically sorted and deduplicated:

  * PLink  — a psoc index slice (already (s, o)-sorted per predicate);
  * PInv   — the sub-relation with columns swapped and re-sorted;
  * PSeq   — relational composition (successor lookup + expansion, the
             same sorted_search/join_expand/gather_emit kernels the merge
             join uses);
  * PAlt   — union + relation dedup;
  * PClosure — the frontier engine below (``+``/``*``), or a single
             union with the identity relation (``?``).

Closure runs as multi-source BFS where one *round* expands the whole
frontier as one batch: successor ranges via ``sorted_search``, candidate
(source, node) pairs via ``join_expand`` + ``gather_emit`` windows written
straight into pooled buffers, then one ``frontier_dedup`` kernel call
(adjacent-unique + visited-set mask over the sorted candidates) yields the
delta frontier — semi-naive evaluation: only last round's discoveries are
ever expanded. Steady-state rounds perform O(1) BatchPool fetches
(candidate / sorted / frontier buffers recycle through the arena).

The visited set doubles as the result: it is exactly the closure pairs,
kept sorted by (source, node) throughout, so the operator can emit
subject-sorted batches without a final sort.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import vecops
from repro.core.batch import BatchPool
from repro.core.paths.expr import (
    PAlt,
    PathExpr,
    PClosure,
    PInv,
    PLink,
    PSeq,
    matches_zero_length,
)
from repro.core.storage import QuadStore
from repro.kernels import ops

# expansion window: candidates are materialized into the round buffer in
# chunks of this many output slots (bounds the join_expand working set)
EXPAND_WINDOW = 4096
_EMPTY = np.zeros(0, dtype=np.int32)


def _pow2_cap(n: int) -> int:
    """Power-of-two buffer capacity >= max(n, 32) — pow2 capacities make
    pooled buffers reusable across rounds with different frontier sizes."""
    return 1 << max(int(n) - 1, 31).bit_length()


@dataclasses.dataclass
class PathCounters:
    """Per-evaluation frontier metrics (surfaced by the profiler)."""

    rounds: int = 0
    frontier_total: int = 0  # sum of frontier sizes over rounds
    frontier_peak: int = 0
    candidates: int = 0  # expansion outputs before dedup
    discovered: int = 0  # delta-frontier pairs after dedup

    @property
    def dedup_ratio(self) -> float:
        """discovered / candidates — 1.0 means no wasted expansion."""
        return self.discovered / self.candidates if self.candidates else 1.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "frontier_rounds": self.rounds,
            "frontier_peak": self.frontier_peak,
            "dedup_in": self.candidates,
            "dedup_out": self.discovered,
        }


@dataclasses.dataclass
class PathResult:
    """Sorted, deduplicated (src, dst) pair relation."""

    src: np.ndarray
    dst: np.ndarray

    def __len__(self) -> int:
        return int(len(self.src))

    def swapped(self) -> "PathResult":
        order = np.lexsort((self.src, self.dst))
        return PathResult(
            np.ascontiguousarray(self.dst[order]),
            np.ascontiguousarray(self.src[order]),
        )


class _Arena:
    """Thin (2, cap) int32 buffer pool view over BatchPool: the frontier
    engine's working sets ride the same arena as the operators' batches,
    so its alloc/reuse traffic shows up in the pool counters."""

    def __init__(self, pool: Optional[BatchPool]):
        self.pool = pool
        self._masks: Dict[int, np.ndarray] = {}

    def acquire(self, n: int) -> np.ndarray:
        cap = _pow2_cap(n)
        if self.pool is None:
            return np.empty((2, cap), dtype=np.int32)
        cols, mask = self.pool.acquire(2, cap)
        self._masks[id(cols)] = mask
        return cols

    def release(self, cols: Optional[np.ndarray]) -> None:
        if cols is None or self.pool is None:
            return
        mask = self._masks.pop(id(cols), None)
        if mask is None:
            mask = np.empty(cols.shape[1], dtype=bool)
        self.pool.release(cols, mask)


class PathEngine:
    """Compiles path expressions against one store and runs closures."""

    def __init__(
        self,
        store: QuadStore,
        pool: Optional[BatchPool] = None,
        backend: Optional[str] = None,
    ):
        self.store = store
        self.arena = _Arena(pool)
        self.backend = backend
        self.counters = PathCounters()
        self._domain: Optional[np.ndarray] = None

    # -- public -------------------------------------------------------------

    def evaluate(
        self,
        expr: PathExpr,
        seeds: Optional[np.ndarray] = None,
        reverse: bool = False,
    ) -> PathResult:
        """Pairs of ``expr``. With ``seeds`` (sorted unique int32 codes) the
        result is restricted to pairs whose subject (or object, when
        ``reverse`` — bound-object expansion over flipped edges) is a seed;
        a top-level unbounded closure then runs BFS from the seeds only
        instead of materializing the whole closure."""
        if (
            seeds is not None
            and isinstance(expr, PClosure)
            and expr.max_hops == -1
        ):
            base = self.relation(expr.sub)
            if reverse:
                base = base.swapped()
            res = self._closure(base, seeds)
            if expr.min_hops == 0:
                res = _union(res, PathResult(seeds, seeds))
            return res.swapped() if reverse else res
        rel = self.relation(expr)
        if seeds is None:
            return rel
        if reverse:
            rel = rel.swapped()
        keep = np.isin(rel.src, seeds)
        res = PathResult(rel.src[keep], rel.dst[keep])
        if matches_zero_length(expr):
            # bound endpoints reach themselves via the empty walk even when
            # off-graph (the relation's identity only spans graph nodes)
            res = _union(res, PathResult(seeds, seeds))
        return res.swapped() if reverse else res

    # -- relation compilation ----------------------------------------------

    def relation(self, expr: PathExpr) -> PathResult:
        if isinstance(expr, PLink):
            return self._link(expr.pred)
        if isinstance(expr, PInv):
            return self.relation(expr.sub).swapped()
        if isinstance(expr, PSeq):
            rel = self.relation(expr.parts[0])
            for part in expr.parts[1:]:
                rel = self._compose(rel, self.relation(part))
            return rel
        if isinstance(expr, PAlt):
            parts = [self.relation(p) for p in expr.parts]
            return _dedup_rel(
                np.concatenate([p.src for p in parts]),
                np.concatenate([p.dst for p in parts]),
                self.backend,
            )
        if isinstance(expr, PClosure):
            sub = self.relation(expr.sub)
            if expr.max_hops == 1:  # 'p?': one hop or zero
                res = sub
            else:
                seeds = np.unique(sub.src).astype(np.int32)
                res = self._closure(sub, seeds)
            if expr.min_hops == 0:
                dom = self._graph_domain()
                res = _union(res, PathResult(dom, dom))
            return res
        raise TypeError(type(expr))

    def _link(self, pred) -> PathResult:
        pid = self.store.dict.lookup(pred)
        if pid is None:
            return PathResult(_EMPTY, _EMPTY)
        arr = self.store.index_array("psoc")  # (p, s, o, c) lex-sorted
        lo = int(np.searchsorted(arr[:, 0], pid, side="left"))
        hi = int(np.searchsorted(arr[:, 0], pid, side="right"))
        src = np.ascontiguousarray(arr[lo:hi, 1])
        dst = np.ascontiguousarray(arr[lo:hi, 2])
        # the slice is (s, o)-sorted; the same triple in several named
        # graphs duplicates pairs, so run the adjacent-unique mask
        mask = ops.frontier_dedup(src, dst, _EMPTY, _EMPTY, backend=self.backend)
        if not mask.all():
            src, dst = src[mask], dst[mask]
        return PathResult(src, dst)

    def _graph_domain(self) -> np.ndarray:
        """All terms used as subject or object (the zero-length path
        domain; DESIGN.md §8)."""
        if self._domain is None:
            spoc = self.store.index_array("spoc")
            self._domain = np.unique(
                np.concatenate([spoc[:, 0], spoc[:, 2]])
            ).astype(np.int32)
        return self._domain

    # -- composition ---------------------------------------------------------

    def _compose(self, a: PathResult, b: PathResult) -> PathResult:
        """a ∘ b: pairs (x, z) with (x, y) ∈ a, (y, z) ∈ b."""
        if not len(a) or not len(b):
            return PathResult(_EMPTY, _EMPTY)
        srcs, dsts = self._expand(a.dst, b.src, b.dst, a.src)
        return _dedup_rel(srcs, dsts, self.backend)

    def _expand(
        self,
        probe_nodes: np.ndarray,
        rel_src: np.ndarray,
        rel_dst: np.ndarray,
        carry: np.ndarray,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """One batched successor expansion: for row i, every rel edge whose
        src equals probe_nodes[i] emits (carry[i], rel_dst[edge]). Returns
        the raw (pre-dedup) pair arrays."""
        be = self.backend
        lo = ops.sorted_search(rel_src, probe_nodes, "left", backend=be)
        hi = ops.sorted_search(rel_src, probe_nodes, "right", backend=be)
        lens = (hi - lo).astype(np.int32)
        n = len(probe_nodes)
        ones = np.ones(n, dtype=np.int32)
        idx = np.arange(n, dtype=np.int32)
        cum = vecops.group_output_offsets(ones, lens)
        total = int(cum[-1])
        if total == 0:
            return _EMPTY, _EMPTY
        out = self.arena.acquire(total)
        lcols = np.ascontiguousarray(carry[None, :])
        rcols = np.ascontiguousarray(rel_dst[None, :])
        base = 0
        while base < total:
            count = min(EXPAND_WINDOW, total - base)
            li, ri = ops.join_expand(idx, ones, lo, lens, cum, base, count, backend=be)
            ops.gather_emit(
                lcols, rcols, li, ri, (0,), (0,), (),
                backend=be, out=out, out_offset=base,
            )
            base += count
        src = out[0, :total].copy()
        dst = out[1, :total].copy()
        self.arena.release(out)
        return src, dst

    # -- the frontier engine -------------------------------------------------

    def _closure(self, rel: PathResult, seeds: np.ndarray) -> PathResult:
        """Transitive closure restricted to ``seeds`` (sorted unique), via
        semi-naive delta-frontier iteration. Result pairs are (seed, node),
        node reached in >= 1 hops, sorted by (seed, node)."""
        c = self.counters
        n_seed = len(seeds)
        vis_hi, vis_lo = _EMPTY, _EMPTY  # (seed_idx, node), lex-sorted
        if n_seed == 0 or not len(rel):
            return PathResult(_EMPTY, _EMPTY)
        # round-0 frontier: the seeds themselves (not part of the result —
        # min_hops >= 1; a cycle back to the seed re-discovers it normally)
        f_buf = self.arena.acquire(n_seed)
        f_buf[0, :n_seed] = np.arange(n_seed, dtype=np.int32)
        f_buf[1, :n_seed] = seeds
        n_f = n_seed
        while n_f:
            c.rounds += 1
            c.frontier_total += n_f
            c.frontier_peak = max(c.frontier_peak, n_f)
            cand_src, cand_dst, cand_buf, total = self._expand_frontier(
                f_buf, n_f, rel
            )
            self.arena.release(f_buf)
            f_buf = None
            if total == 0:
                self.arena.release(cand_buf)
                break
            c.candidates += total
            # host sort (lexicographic), then one dedup kernel call
            order = np.lexsort((cand_dst, cand_src))
            sort_buf = self.arena.acquire(total)
            np.take(cand_src, order, out=sort_buf[0, :total])
            np.take(cand_dst, order, out=sort_buf[1, :total])
            self.arena.release(cand_buf)
            keep = ops.frontier_dedup(
                sort_buf[0, :total], sort_buf[1, :total], vis_hi, vis_lo,
                backend=self.backend,
            )
            new_idx = np.nonzero(keep)[0]
            n_f = len(new_idx)
            c.discovered += n_f
            if n_f:
                f_buf = self.arena.acquire(n_f)
                np.take(sort_buf[0, :total], new_idx, out=f_buf[0, :n_f])
                np.take(sort_buf[1, :total], new_idx, out=f_buf[1, :n_f])
                vis_hi, vis_lo = vecops.merge_sorted_pairs(
                    vis_hi, vis_lo, f_buf[0, :n_f], f_buf[1, :n_f]
                )
            self.arena.release(sort_buf)
        self.arena.release(f_buf)
        # visited == closure pairs; map seed indices back to codes (sorted
        # seeds keep the (src, dst) order lexicographic)
        return PathResult(seeds[vis_hi].astype(np.int32), vis_lo)

    def _expand_frontier(self, f_buf: np.ndarray, n_f: int, rel: PathResult):
        """Expand a whole frontier batch; returns (src, dst, buffer, total)
        where src/dst are views into the pooled buffer."""
        be = self.backend
        nodes = f_buf[1, :n_f]
        lo = ops.sorted_search(rel.src, nodes, "left", backend=be)
        hi = ops.sorted_search(rel.src, nodes, "right", backend=be)
        lens = (hi - lo).astype(np.int32)
        ones = np.ones(n_f, dtype=np.int32)
        idx = np.arange(n_f, dtype=np.int32)
        cum = vecops.group_output_offsets(ones, lens)
        total = int(cum[-1])
        out = self.arena.acquire(total)
        if total:
            lcols = np.ascontiguousarray(f_buf[0:1, :n_f])
            rcols = np.ascontiguousarray(rel.dst[None, :])
            base = 0
            while base < total:
                count = min(EXPAND_WINDOW, total - base)
                li, ri = ops.join_expand(
                    idx, ones, lo, lens, cum, base, count, backend=be
                )
                ops.gather_emit(
                    lcols, rcols, li, ri, (0,), (0,), (),
                    backend=be, out=out, out_offset=base,
                )
                base += count
        return out[0, :total], out[1, :total], out, total


# -- relation helpers ---------------------------------------------------------


def _dedup_rel(src: np.ndarray, dst: np.ndarray, backend=None) -> PathResult:
    if not len(src):
        return PathResult(_EMPTY, _EMPTY)
    order = np.lexsort((dst, src))
    src = np.ascontiguousarray(src[order], dtype=np.int32)
    dst = np.ascontiguousarray(dst[order], dtype=np.int32)
    mask = ops.frontier_dedup(src, dst, _EMPTY, _EMPTY, backend=backend)
    if not mask.all():
        src, dst = src[mask], dst[mask]
    return PathResult(src, dst)


def _union(a: PathResult, b: PathResult) -> PathResult:
    return _dedup_rel(
        np.concatenate([a.src, b.src]), np.concatenate([a.dst, b.dst])
    )
