"""Property-path expression AST (SPARQL 1.1 §9.1 subset).

Grammar covered (parser.py):

    Path     := Alt
    Alt      := Seq ('|' Seq)*
    Seq      := Step ('/' Step)*
    Step     := '^' Elt | Elt
    Elt      := Primary ('+' | '*' | '?')?
    Primary  := <constant predicate> | '(' Path ')'

The AST is deliberately tiny and hashable: the planner estimates over it,
the engine compiles it to edge relations, and explain/profile print it via
``path_repr``. Predicates are stored as *terms* (strings), not dictionary
codes — encoding happens inside the engine, which is the only layer that
owns a store.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple, Union


@dataclasses.dataclass(frozen=True)
class PLink:
    """A single constant predicate step."""

    pred: object  # Term (str / number)


@dataclasses.dataclass(frozen=True)
class PInv:
    """Inverse step ``^p`` — follow edges object→subject."""

    sub: "PathExpr"


@dataclasses.dataclass(frozen=True)
class PSeq:
    """Sequence ``a/b`` — relational composition, left to right."""

    parts: Tuple["PathExpr", ...]


@dataclasses.dataclass(frozen=True)
class PAlt:
    """Alternation ``a|b`` — union of pair relations."""

    parts: Tuple["PathExpr", ...]


@dataclasses.dataclass(frozen=True)
class PClosure:
    """Closure: ``+`` (min_hops=1), ``*`` (min_hops=0) and ``?``
    (min_hops=0, max_hops=1)."""

    sub: "PathExpr"
    min_hops: int  # 0 or 1
    max_hops: int = -1  # -1 = unbounded


PathExpr = Union[PLink, PInv, PSeq, PAlt, PClosure]


def path_repr(e: PathExpr) -> str:
    """Canonical display form (used by explain/profile/tests)."""
    if isinstance(e, PLink):
        return str(e.pred)
    if isinstance(e, PInv):
        return f"^{_paren(e.sub)}"
    if isinstance(e, PSeq):
        return "/".join(_paren(p) for p in e.parts)
    if isinstance(e, PAlt):
        return "|".join(_paren(p) for p in e.parts)
    if isinstance(e, PClosure):
        if e.max_hops == 1:
            mod = "?"
        elif e.min_hops == 0:
            mod = "*"
        else:
            mod = "+"
        return f"{_paren(e.sub)}{mod}"
    raise TypeError(type(e))


def _paren(e: PathExpr) -> str:
    if isinstance(e, (PSeq, PAlt)):
        return f"({path_repr(e)})"
    return path_repr(e)


def matches_zero_length(e: PathExpr) -> bool:
    """True if the path matches the empty (zero-hop) walk; a bound
    endpoint then pairs with itself even when absent from the graph."""
    if isinstance(e, PClosure):
        return e.min_hops == 0
    if isinstance(e, PSeq):
        return all(matches_zero_length(p) for p in e.parts)
    if isinstance(e, PAlt):
        return any(matches_zero_length(p) for p in e.parts)
    if isinstance(e, PInv):
        return matches_zero_length(e.sub)
    return False


def simple_transitive_pred(e: PathExpr):
    """The predicate term if ``e`` is exactly ``p+`` (the legacy
    RowTransitivePath shape), else None."""
    if (
        isinstance(e, PClosure)
        and e.min_hops == 1
        and e.max_hops == -1
        and isinstance(e.sub, PLink)
    ):
        return e.sub.pred
    return None
