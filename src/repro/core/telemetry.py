"""Query-scoped telemetry (DESIGN.md §13).

The paper chose vectorization over code generation because the operator
tree stays observable (§3.1). This module makes that observability
*query-scoped* instead of process-global, so a server interleaving many
queries through one Engine can attribute every kernel dispatch, span and
buffer to exactly one request:

  KernelLedger   — dispatch counts and wall seconds keyed by kernel name
                   and by (kernel, backend). One process-global instance
                   backs ``kernels.ops.DISPATCH_COUNTS`` (its ``counts``
                   Counter IS that object); one per-query instance lives
                   on each QueryTrace.
  QueryTrace     — span recorder for the query lifecycle (parse → plan →
                   translate → execute), a per-query KernelLedger, and a
                   per-dispatch kernel event log. Exports Chrome-trace
                   JSON (``chrome-tracing`` / Perfetto ``traceEvents``
                   format) so traces open directly in ui.perfetto.dev.
  trace_query()  — contextvar scope installing a QueryTrace as the active
                   attribution target. Kernel dispatches recorded while a
                   trace is active land in BOTH the trace's ledger and
                   the process-global one — the global ledger keeps its
                   "since process start / last reset" semantics for
                   existing callers, the scoped ledger gives exact
                   per-query attribution even under interleaving.

PR 8 adds the workload-history primitives (DESIGN.md §14):

  query_fingerprint()    — canonical sha256 template key over the parsed
                           algebra: literals and instantiated entity
                           constants normalize to typed placeholders,
                           variables to first-appearance indices, so the
                           template instances of BSBM-style traffic share
                           one key regardless of spelling.
  CardinalityFeedback    — per-plan-node observed cardinalities keyed by
                           the planner's stable node fingerprint. The
                           executor records actual row counts after each
                           drain; the planner (EngineConfig.
                           cardinality_feedback="apply") overrides its
                           estimates with the observed history.

Only stdlib is imported here at module scope: ``kernels.ops`` imports
this module, so it must never (transitively) import the kernels package.
The fingerprint walkers lazily import ``repro.core.algebra`` inside the
function bodies for the same reason.
"""

from __future__ import annotations

import collections
import hashlib
import json
import time
from contextlib import contextmanager
from contextvars import ContextVar
from typing import Dict, List, Optional, Tuple


class KernelLedger:
    """Dispatch counts + wall-time for one attribution scope.

    Wall times are *inclusive* per public kernel wrapper: ``hash_build``
    internally dispatches ``radix_partition``, so both entries tick and
    the build's seconds include the partition's (same convention as the
    operator tree's self+children wall_time).
    """

    __slots__ = ("counts", "wall_s", "backend_counts", "backend_wall_s")

    def __init__(self, counts: Optional[collections.Counter] = None) -> None:
        # ``counts`` may be an externally owned Counter (kernels.ops keeps
        # DISPATCH_COUNTS' identity by handing it in here)
        self.counts: collections.Counter = (
            collections.Counter() if counts is None else counts
        )
        self.wall_s: Dict[str, float] = collections.defaultdict(float)
        self.backend_counts: collections.Counter = collections.Counter()
        self.backend_wall_s: Dict[Tuple[str, str], float] = collections.defaultdict(
            float
        )

    def record(self, name: str, backend: str, dt: float) -> None:
        self.counts[name] += 1
        self.wall_s[name] += dt
        self.backend_counts[(name, backend)] += 1
        self.backend_wall_s[(name, backend)] += dt

    def merge(self, other: "KernelLedger") -> None:
        """Accumulate another ledger (serving metrics aggregate request
        ledgers into a server-lifetime one)."""
        self.counts.update(other.counts)
        for k, v in other.wall_s.items():
            self.wall_s[k] += v
        self.backend_counts.update(other.backend_counts)
        for k, v in other.backend_wall_s.items():
            self.backend_wall_s[k] += v

    def total(self) -> int:
        return sum(self.counts.values())

    def total_wall_s(self) -> float:
        return sum(self.wall_s.values())

    def clear(self) -> None:
        self.counts.clear()
        self.wall_s.clear()
        self.backend_counts.clear()
        self.backend_wall_s.clear()

    def snapshot(self) -> dict:
        """JSON-able view: per-kernel counts/ms plus the per-backend
        breakdown keyed ``kernel/backend``."""
        return {
            "dispatches": dict(self.counts),
            "wall_ms": {k: round(v * 1e3, 4) for k, v in self.wall_s.items()},
            "by_backend": {
                f"{n}/{b}": c for (n, b), c in sorted(self.backend_counts.items())
            },
            "by_backend_wall_ms": {
                f"{n}/{b}": round(v * 1e3, 4)
                for (n, b), v in sorted(self.backend_wall_s.items())
            },
        }


# process-global fallback ledger — kernels.ops aliases its ``counts`` as
# DISPATCH_COUNTS, keeping the pre-§13 module API intact
_GLOBAL_LEDGER = KernelLedger()

_ACTIVE_TRACE: "ContextVar[Optional[QueryTrace]]" = ContextVar(
    "repro_active_trace", default=None
)


def global_ledger() -> KernelLedger:
    return _GLOBAL_LEDGER


def current_trace() -> Optional["QueryTrace"]:
    """The QueryTrace installed for the current context, if any."""
    return _ACTIVE_TRACE.get()


def record_dispatch(name: str, backend: str, t0: float, dt: float) -> None:
    """Attribute one kernel dispatch: to the active query trace when one
    is installed, and always to the process-global ledger."""
    tr = _ACTIVE_TRACE.get()
    if tr is not None:
        tr.ledger.record(name, backend, dt)
        if tr.kernel_events:
            tr._kernels.append((name, backend, t0, dt))
    _GLOBAL_LEDGER.record(name, backend, dt)


@contextmanager
def trace_query(label: str = "query", trace: Optional["QueryTrace"] = None):
    """Install ``trace`` (or a fresh QueryTrace) as the active attribution
    scope. ``trace=None`` with a falsy label yields None and installs
    nothing — callers can pass a disabled trace straight through."""
    tr = trace if trace is not None else QueryTrace(label)
    token = _ACTIVE_TRACE.set(tr)
    try:
        yield tr
    finally:
        _ACTIVE_TRACE.reset(token)


# Perfetto renders one horizontal lane per (pid, tid); we use three fixed
# lanes: query-lifecycle spans, kernel dispatches, operator tree.
_TID_QUERY, _TID_KERNELS, _TID_OPERATORS = 1, 2, 3


class QueryTrace:
    """Span + kernel-event recorder for one query execution."""

    def __init__(self, label: str = "query", kernel_events: bool = True) -> None:
        self.label = label
        self.kernel_events = kernel_events
        self.ledger = KernelLedger()
        self.t0 = time.perf_counter()
        # (name, category, start_s, dur_s, args) — start in perf_counter time
        self.spans: List[Tuple[str, str, float, float, dict]] = []
        # (kernel, backend, start_s, dur_s)
        self._kernels: List[Tuple[str, str, float, float]] = []
        # (label, depth, start_s, dur_s, args) — synthesized operator lane
        self._operators: List[Tuple[str, float, float, dict]] = []

    # -- recording ----------------------------------------------------------

    @contextmanager
    def span(self, name: str, cat: str = "query", **args):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.spans.append((name, cat, t0, time.perf_counter() - t0, args))

    def add_span(self, name: str, cat: str, t0: float, dur: float, **args) -> None:
        """Record an externally timed span (perf_counter timebase)."""
        self.spans.append((name, cat, t0, dur, args))

    def span_bounds(self, name: str) -> Optional[Tuple[float, float]]:
        for n, _cat, t0, dur, _a in self.spans:
            if n == name:
                return t0, dur
        return None

    def add_operator_tree(self, root, start: Optional[float] = None) -> None:
        """Synthesize the operator lane from the tree's post-hoc OpStats:
        each operator becomes one complete event whose duration is its
        inclusive wall_time, children laid out sequentially inside the
        parent's window (wall_time is self+children, so they nest)."""
        if start is None:
            bounds = self.span_bounds("execute")
            start = bounds[0] if bounds else self.t0

        def walk(op, t: float) -> None:
            s = op.stats
            args = {"results": s.results, "next_calls": s.next_calls}
            if getattr(s, "est_rows", None) is not None:
                args["est_rows"] = round(float(s.est_rows), 1)
            self._operators.append((f"{s.name}{s.detail}", t, s.wall_time, args))
            tc = t
            for c in op.children():
                walk(c, tc)
                tc += c.stats.wall_time

        walk(root, start)

    # -- export -------------------------------------------------------------

    def _us(self, t: float) -> float:
        return (t - self.t0) * 1e6

    def chrome_events(self) -> List[dict]:
        ev: List[dict] = []
        for tid, name in (
            (_TID_QUERY, "query"),
            (_TID_KERNELS, "kernels"),
            (_TID_OPERATORS, "operators"),
        ):
            ev.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": 1,
                    "tid": tid,
                    "args": {"name": name},
                }
            )
        for name, cat, t0, dur, args in self.spans:
            ev.append(
                {
                    "name": name,
                    "cat": cat,
                    "ph": "X",
                    "ts": self._us(t0),
                    "dur": dur * 1e6,
                    "pid": 1,
                    "tid": _TID_QUERY,
                    "args": dict(args),
                }
            )
        for kname, backend, t0, dur in self._kernels:
            ev.append(
                {
                    "name": kname,
                    "cat": "kernel",
                    "ph": "X",
                    "ts": self._us(t0),
                    "dur": dur * 1e6,
                    "pid": 1,
                    "tid": _TID_KERNELS,
                    "args": {"backend": backend},
                }
            )
        for label, t0, dur, args in self._operators:
            ev.append(
                {
                    "name": label,
                    "cat": "operator",
                    "ph": "X",
                    "ts": self._us(t0),
                    "dur": dur * 1e6,
                    "pid": 1,
                    "tid": _TID_OPERATORS,
                    "args": dict(args),
                }
            )
        return ev

    def to_chrome_trace(self) -> dict:
        """The chrome://tracing / Perfetto ``traceEvents`` document."""
        return {
            "traceEvents": self.chrome_events(),
            "displayTimeUnit": "ms",
            "otherData": {"query": self.label},
        }

    def chrome_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_chrome_trace(), indent=indent)

    def save_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.chrome_json())

    def summary(self) -> dict:
        """Compact JSON-able digest: span durations + the kernel ledger."""
        return {
            "query": self.label,
            "spans_ms": {
                name: round(dur * 1e3, 4) for name, _c, _t, dur, _a in self.spans
            },
            "kernels": self.ledger.snapshot(),
        }


# ---------------------------------------------------------------------------
# query fingerprinting (DESIGN.md §14)
# ---------------------------------------------------------------------------

# Term classification for placeholder normalization. Terms are
# str | int | float (repro.core.dictionary.Term): quoted strings are RDF
# literals, everything else stringy is an IRI/prefixed name.


def _term_class(term) -> str:
    if isinstance(term, bool) or isinstance(term, (int, float)):
        return "<num>"
    if isinstance(term, str) and term.startswith('"'):
        return "<str>"
    return "<iri>"


def canonical_var_map(node) -> Dict[int, int]:
    """Variable id -> canonical index by first appearance in a pre-order
    walk of the logical algebra. Two spellings of the same template get
    identical maps, so fingerprints (template and node) are independent
    of parser-assigned variable ids."""
    order: Dict[int, int] = {}

    def visit(vid: int) -> None:
        if vid not in order:
            order[vid] = len(order)

    for tok in _algebra_tokens(node, canon=None, on_var=visit):
        pass
    return order


def _algebra_tokens(node, canon: Optional[Dict[int, int]], on_var=None):
    """Token stream over the logical algebra: structure tags, canonical
    variables, kept IRI constants in predicate position, and typed
    placeholders for instantiated constants. ``canon=None`` emits raw var
    ids (used while *building* the canonical map); ``on_var`` observes
    every variable in pre-order."""
    from repro.core import algebra as A

    def var_tok(vid: int) -> str:
        if on_var is not None:
            on_var(vid)
        return f"?{vid if canon is None else canon.get(vid, vid)}"

    def slot_tok(sl, keep: bool) -> str:
        if isinstance(sl, A.V):
            return var_tok(sl.id)
        return f"K:{sl.term}" if keep else _term_class(sl.term)

    def expr_toks(e):
        if e is None:
            return
        if isinstance(e, A.VarRef):
            yield var_tok(e.var)
        elif isinstance(e, A.Lit):
            yield _term_class(e.value)
        elif isinstance(e, A.Cmp):
            yield f"cmp:{e.op}("
            yield from expr_toks(e.lhs)
            yield from expr_toks(e.rhs)
            yield ")"
        elif isinstance(e, A.Arith):
            yield f"arith:{e.op}("
            yield from expr_toks(e.lhs)
            yield from expr_toks(e.rhs)
            yield ")"
        elif isinstance(e, (A.And, A.Or)):
            yield ("and(" if isinstance(e, A.And) else "or(")
            for t in e.terms:
                yield from expr_toks(t)
            yield ")"
        elif isinstance(e, A.Not):
            yield "not("
            yield from expr_toks(e.term)
            yield ")"
        elif isinstance(e, A.Bound):
            yield f"bound({var_tok(e.var)})"
        elif isinstance(e, A.Func):
            yield f"func:{e.name}("
            for a in e.args:
                yield from expr_toks(a)
            yield ")"
        else:
            yield f"expr:{type(e).__name__}"

    def pattern_toks(p):
        if isinstance(p, A.PathPattern):
            from repro.core.paths.expr import path_repr

            yield "PATH("
            yield slot_tok(p.s, keep=False)
            yield path_repr(p.expr)
            yield slot_tok(p.o, keep=False)
            yield ")"
            return
        yield "TP("
        yield slot_tok(p.s, keep=False)
        # the predicate defines the template's structure; subjects and
        # objects are the instantiated entities that vary per instance
        yield slot_tok(p.p, keep=True)
        yield slot_tok(p.o, keep=False)
        if p.g is not None:
            yield slot_tok(p.g, keep=True)
        if p.path:
            yield f"path:{p.path}"
        yield ")"

    def walk(n):
        if isinstance(n, A.BGP):
            yield "BGP("
            for p in n.patterns:
                yield from pattern_toks(p)
            yield ")"
        elif isinstance(n, A.Filter):
            yield "FILTER("
            yield from expr_toks(n.expr)
            yield from walk(n.child)
            yield ")"
        elif isinstance(n, (A.Join, A.Minus, A.NotExists, A.Union)):
            yield f"{type(n).__name__.upper()}("
            yield from walk(n.left)
            yield from walk(n.right)
            yield ")"
        elif isinstance(n, A.LeftJoin):
            yield "LEFTJOIN("
            yield from walk(n.left)
            yield from walk(n.right)
            yield from expr_toks(n.expr)
            yield ")"
        elif isinstance(n, A.Extend):
            yield f"BIND({var_tok(n.var)}"
            yield from expr_toks(n.expr)
            yield from walk(n.child)
            yield ")"
        elif isinstance(n, A.Project):
            yield "PROJECT("
            for v in n.vars:
                yield var_tok(v)
            yield from walk(n.child)
            yield ")"
        elif isinstance(n, A.Distinct):
            yield "DISTINCT("
            yield from walk(n.child)
            yield ")"
        elif isinstance(n, A.GroupAgg):
            yield "GROUP("
            for v in n.group_vars:
                yield var_tok(v)
            for a in n.aggs:
                mod = "distinct " if a.distinct else ""
                av = var_tok(a.var) if a.var is not None else "*"
                yield f"agg:{mod}{a.func}({av})->{var_tok(a.out)}"
            yield from walk(n.child)
            yield from expr_toks(n.having)
            yield ")"
        elif isinstance(n, A.OrderBy):
            yield "ORDERBY("
            for k in n.keys:
                yield f"{var_tok(k.var)}:{'asc' if k.ascending else 'desc'}"
            yield from walk(n.child)
            yield ")"
        elif isinstance(n, A.Slice):
            yield f"SLICE({n.limit}:{n.offset}"
            yield from walk(n.child)
            yield ")"
        else:
            yield f"NODE:{type(n).__name__}"

    yield from walk(node)


def query_fingerprint(node) -> str:
    """Canonical sha256 template key over a parsed logical plan: literals
    and instantiated subject/object constants become typed placeholders,
    variables become first-appearance indices, whitespace never enters.
    Instances of one query template share a fingerprint."""
    canon = canonical_var_map(node)
    toks = list(_algebra_tokens(node, canon=canon))
    return hashlib.sha256("\x1f".join(toks).encode()).hexdigest()


# ---------------------------------------------------------------------------
# cardinality feedback store (DESIGN.md §14)
# ---------------------------------------------------------------------------


class CardinalityFeedback:
    """Observed per-plan-node cardinalities keyed by the planner's stable
    node fingerprint (planner.annotate_fingerprints).

    The executor records each operator's actual output rows after a full
    drain; estimates decay toward recent observations through an EWMA so
    data drift is tracked without unbounded history. ``version`` bumps on
    every record — plan caches fold it into their key under
    ``cardinality_feedback="apply"`` so a repeated query re-plans against
    fresh history instead of serving the stale shape.

    Lives in core (stdlib-only) because the Planner consults it; the
    serving layer's WorkloadRepository owns and persists one."""

    __slots__ = ("alpha", "max_entries", "version", "_obs")

    def __init__(self, alpha: float = 0.5, max_entries: int = 4096) -> None:
        self.alpha = alpha
        self.max_entries = max_entries
        self.version = 0
        # node_fp -> [ewma_rows, n_observations]
        self._obs: Dict[str, List[float]] = {}

    def __len__(self) -> int:
        return len(self._obs)

    def record(self, node_fp: str, actual_rows: float) -> None:
        if not node_fp:
            return
        e = self._obs.get(node_fp)
        if e is None:
            if len(self._obs) >= self.max_entries:
                # bounded store: evict the least-observed fingerprint
                drop = min(self._obs, key=lambda k: self._obs[k][1])
                del self._obs[drop]
            self._obs[node_fp] = [float(actual_rows), 1]
        else:
            e[0] += self.alpha * (float(actual_rows) - e[0])
            e[1] += 1
        self.version += 1

    def lookup(self, node_fp: str) -> Optional[float]:
        e = self._obs.get(node_fp)
        return e[0] if e is not None else None

    def observations(self, node_fp: str) -> int:
        e = self._obs.get(node_fp)
        return int(e[1]) if e is not None else 0

    def snapshot(self) -> dict:
        """JSON-able state: {node_fp: [ewma_rows, n]}."""
        return {k: [round(v[0], 3), int(v[1])] for k, v in self._obs.items()}

    def merge(self, state: Dict[str, List[float]]) -> None:
        """Merge a persisted snapshot: existing entries combine by
        observation-count-weighted average (load order must not matter
        more than sample counts do)."""
        for fp, (rows, n) in state.items():
            n = max(int(n), 1)
            e = self._obs.get(fp)
            if e is None:
                if len(self._obs) >= self.max_entries:
                    drop = min(self._obs, key=lambda k: self._obs[k][1])
                    del self._obs[drop]
                self._obs[fp] = [float(rows), n]
            else:
                tot = e[1] + n
                e[0] = (e[0] * e[1] + float(rows) * n) / tot
                e[1] = tot
            self.version += 1
