"""Query-scoped telemetry (DESIGN.md §13).

The paper chose vectorization over code generation because the operator
tree stays observable (§3.1). This module makes that observability
*query-scoped* instead of process-global, so a server interleaving many
queries through one Engine can attribute every kernel dispatch, span and
buffer to exactly one request:

  KernelLedger   — dispatch counts and wall seconds keyed by kernel name
                   and by (kernel, backend). One process-global instance
                   backs ``kernels.ops.DISPATCH_COUNTS`` (its ``counts``
                   Counter IS that object); one per-query instance lives
                   on each QueryTrace.
  QueryTrace     — span recorder for the query lifecycle (parse → plan →
                   translate → execute), a per-query KernelLedger, and a
                   per-dispatch kernel event log. Exports Chrome-trace
                   JSON (``chrome-tracing`` / Perfetto ``traceEvents``
                   format) so traces open directly in ui.perfetto.dev.
  trace_query()  — contextvar scope installing a QueryTrace as the active
                   attribution target. Kernel dispatches recorded while a
                   trace is active land in BOTH the trace's ledger and
                   the process-global one — the global ledger keeps its
                   "since process start / last reset" semantics for
                   existing callers, the scoped ledger gives exact
                   per-query attribution even under interleaving.

Only stdlib is imported here: ``kernels.ops`` imports this module, so it
must never (transitively) import the kernels package.
"""

from __future__ import annotations

import collections
import json
import time
from contextlib import contextmanager
from contextvars import ContextVar
from typing import Dict, List, Optional, Tuple


class KernelLedger:
    """Dispatch counts + wall-time for one attribution scope.

    Wall times are *inclusive* per public kernel wrapper: ``hash_build``
    internally dispatches ``radix_partition``, so both entries tick and
    the build's seconds include the partition's (same convention as the
    operator tree's self+children wall_time).
    """

    __slots__ = ("counts", "wall_s", "backend_counts", "backend_wall_s")

    def __init__(self, counts: Optional[collections.Counter] = None) -> None:
        # ``counts`` may be an externally owned Counter (kernels.ops keeps
        # DISPATCH_COUNTS' identity by handing it in here)
        self.counts: collections.Counter = (
            collections.Counter() if counts is None else counts
        )
        self.wall_s: Dict[str, float] = collections.defaultdict(float)
        self.backend_counts: collections.Counter = collections.Counter()
        self.backend_wall_s: Dict[Tuple[str, str], float] = collections.defaultdict(
            float
        )

    def record(self, name: str, backend: str, dt: float) -> None:
        self.counts[name] += 1
        self.wall_s[name] += dt
        self.backend_counts[(name, backend)] += 1
        self.backend_wall_s[(name, backend)] += dt

    def merge(self, other: "KernelLedger") -> None:
        """Accumulate another ledger (serving metrics aggregate request
        ledgers into a server-lifetime one)."""
        self.counts.update(other.counts)
        for k, v in other.wall_s.items():
            self.wall_s[k] += v
        self.backend_counts.update(other.backend_counts)
        for k, v in other.backend_wall_s.items():
            self.backend_wall_s[k] += v

    def total(self) -> int:
        return sum(self.counts.values())

    def total_wall_s(self) -> float:
        return sum(self.wall_s.values())

    def clear(self) -> None:
        self.counts.clear()
        self.wall_s.clear()
        self.backend_counts.clear()
        self.backend_wall_s.clear()

    def snapshot(self) -> dict:
        """JSON-able view: per-kernel counts/ms plus the per-backend
        breakdown keyed ``kernel/backend``."""
        return {
            "dispatches": dict(self.counts),
            "wall_ms": {k: round(v * 1e3, 4) for k, v in self.wall_s.items()},
            "by_backend": {
                f"{n}/{b}": c for (n, b), c in sorted(self.backend_counts.items())
            },
            "by_backend_wall_ms": {
                f"{n}/{b}": round(v * 1e3, 4)
                for (n, b), v in sorted(self.backend_wall_s.items())
            },
        }


# process-global fallback ledger — kernels.ops aliases its ``counts`` as
# DISPATCH_COUNTS, keeping the pre-§13 module API intact
_GLOBAL_LEDGER = KernelLedger()

_ACTIVE_TRACE: "ContextVar[Optional[QueryTrace]]" = ContextVar(
    "repro_active_trace", default=None
)


def global_ledger() -> KernelLedger:
    return _GLOBAL_LEDGER


def current_trace() -> Optional["QueryTrace"]:
    """The QueryTrace installed for the current context, if any."""
    return _ACTIVE_TRACE.get()


def record_dispatch(name: str, backend: str, t0: float, dt: float) -> None:
    """Attribute one kernel dispatch: to the active query trace when one
    is installed, and always to the process-global ledger."""
    tr = _ACTIVE_TRACE.get()
    if tr is not None:
        tr.ledger.record(name, backend, dt)
        if tr.kernel_events:
            tr._kernels.append((name, backend, t0, dt))
    _GLOBAL_LEDGER.record(name, backend, dt)


@contextmanager
def trace_query(label: str = "query", trace: Optional["QueryTrace"] = None):
    """Install ``trace`` (or a fresh QueryTrace) as the active attribution
    scope. ``trace=None`` with a falsy label yields None and installs
    nothing — callers can pass a disabled trace straight through."""
    tr = trace if trace is not None else QueryTrace(label)
    token = _ACTIVE_TRACE.set(tr)
    try:
        yield tr
    finally:
        _ACTIVE_TRACE.reset(token)


# Perfetto renders one horizontal lane per (pid, tid); we use three fixed
# lanes: query-lifecycle spans, kernel dispatches, operator tree.
_TID_QUERY, _TID_KERNELS, _TID_OPERATORS = 1, 2, 3


class QueryTrace:
    """Span + kernel-event recorder for one query execution."""

    def __init__(self, label: str = "query", kernel_events: bool = True) -> None:
        self.label = label
        self.kernel_events = kernel_events
        self.ledger = KernelLedger()
        self.t0 = time.perf_counter()
        # (name, category, start_s, dur_s, args) — start in perf_counter time
        self.spans: List[Tuple[str, str, float, float, dict]] = []
        # (kernel, backend, start_s, dur_s)
        self._kernels: List[Tuple[str, str, float, float]] = []
        # (label, depth, start_s, dur_s, args) — synthesized operator lane
        self._operators: List[Tuple[str, float, float, dict]] = []

    # -- recording ----------------------------------------------------------

    @contextmanager
    def span(self, name: str, cat: str = "query", **args):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.spans.append((name, cat, t0, time.perf_counter() - t0, args))

    def add_span(self, name: str, cat: str, t0: float, dur: float, **args) -> None:
        """Record an externally timed span (perf_counter timebase)."""
        self.spans.append((name, cat, t0, dur, args))

    def span_bounds(self, name: str) -> Optional[Tuple[float, float]]:
        for n, _cat, t0, dur, _a in self.spans:
            if n == name:
                return t0, dur
        return None

    def add_operator_tree(self, root, start: Optional[float] = None) -> None:
        """Synthesize the operator lane from the tree's post-hoc OpStats:
        each operator becomes one complete event whose duration is its
        inclusive wall_time, children laid out sequentially inside the
        parent's window (wall_time is self+children, so they nest)."""
        if start is None:
            bounds = self.span_bounds("execute")
            start = bounds[0] if bounds else self.t0

        def walk(op, t: float) -> None:
            s = op.stats
            args = {"results": s.results, "next_calls": s.next_calls}
            if getattr(s, "est_rows", None) is not None:
                args["est_rows"] = round(float(s.est_rows), 1)
            self._operators.append((f"{s.name}{s.detail}", t, s.wall_time, args))
            tc = t
            for c in op.children():
                walk(c, tc)
                tc += c.stats.wall_time

        walk(root, start)

    # -- export -------------------------------------------------------------

    def _us(self, t: float) -> float:
        return (t - self.t0) * 1e6

    def chrome_events(self) -> List[dict]:
        ev: List[dict] = []
        for tid, name in (
            (_TID_QUERY, "query"),
            (_TID_KERNELS, "kernels"),
            (_TID_OPERATORS, "operators"),
        ):
            ev.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": 1,
                    "tid": tid,
                    "args": {"name": name},
                }
            )
        for name, cat, t0, dur, args in self.spans:
            ev.append(
                {
                    "name": name,
                    "cat": cat,
                    "ph": "X",
                    "ts": self._us(t0),
                    "dur": dur * 1e6,
                    "pid": 1,
                    "tid": _TID_QUERY,
                    "args": dict(args),
                }
            )
        for kname, backend, t0, dur in self._kernels:
            ev.append(
                {
                    "name": kname,
                    "cat": "kernel",
                    "ph": "X",
                    "ts": self._us(t0),
                    "dur": dur * 1e6,
                    "pid": 1,
                    "tid": _TID_KERNELS,
                    "args": {"backend": backend},
                }
            )
        for label, t0, dur, args in self._operators:
            ev.append(
                {
                    "name": label,
                    "cat": "operator",
                    "ph": "X",
                    "ts": self._us(t0),
                    "dur": dur * 1e6,
                    "pid": 1,
                    "tid": _TID_OPERATORS,
                    "args": dict(args),
                }
            )
        return ev

    def to_chrome_trace(self) -> dict:
        """The chrome://tracing / Perfetto ``traceEvents`` document."""
        return {
            "traceEvents": self.chrome_events(),
            "displayTimeUnit": "ms",
            "otherData": {"query": self.label},
        }

    def chrome_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_chrome_trace(), indent=indent)

    def save_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.chrome_json())

    def summary(self) -> dict:
        """Compact JSON-able digest: span durations + the kernel ledger."""
        return {
            "query": self.label,
            "spans_ms": {
                name: round(dur * 1e3, 4) for name, _c, _t, dur, _a in self.spans
            },
            "kernels": self.ledger.snapshot(),
        }
