"""Cost-based query planner: logical → physical plans (paper §2.2.2, §4.2).

The planner keeps the paper's architecture: ONE optimizer and cost model for
both executors. Join ordering is greedy smallest-expansion-first over the
System-R containment estimate; physical selection prefers merge joins when
the inputs arrive sorted (sorted indexes make them nearly free, §2.2.1),
a LookupJoin when the build side is small, and otherwise chooses by cost
between Sort pipeline breakers + merge and the radix-partitioned hash
join (DESIGN.md §11) — so unsorted OPTIONAL/MINUS/mid-plan inputs no
longer force two O(n log n) sorts. EngineConfig.join_strategy forces one
path for parity tests and ablations.

The single BARQ-awareness concession the paper describes (§4.2 Component
Isolation) is reproduced: merge joins expected to produce substantially
more results than either input ('amplifying joins') get a lower cost when
BARQ is enabled, because most of their work happens in-memory inside the
join. The flag flips plan choice exactly the way Listing 4 vs Listing 1
differ (bind-join plan for the legacy engine, pure merge-join plan for
BARQ).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple, Union as TUnion

from repro.core import algebra as A
from repro.core import telemetry
from repro.core.stats import GraphStats

# ---------------------------------------------------------------------------
# physical plan nodes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PhysNode:
    est_rows: float = dataclasses.field(default=0.0, init=False)
    # where est_rows came from: "stats" (cost model) or "feedback"
    # (observed-cardinality override, DESIGN.md §14)
    est_source: str = dataclasses.field(default="stats", init=False, repr=False)
    # stable node fingerprint (annotate_fingerprints): the key observed
    # cardinalities are recorded and looked up under. Empty until computed.
    fp: str = dataclasses.field(default="", init=False, repr=False)
    # the set of source fingerprints this node's inner-join tree covers —
    # inner joins hash the *unordered* union, so (A⋈B)⋈C and A⋈(C⋈B) and
    # the hash/merge/lookup variants of the same logical join share one
    # fingerprint (cardinality doesn't depend on order or strategy)
    srcs: FrozenSet[str] = dataclasses.field(
        default_factory=frozenset, init=False, repr=False
    )


@dataclasses.dataclass
class PSipFilter:
    """Sideways-information-passing annotation (DESIGN.md §12): a probe-
    side leaf carrying one of these prefilters its output through a
    bloom/code-range summary of the exporting join's build side. ``sid``
    links the consuming leaf to the exporting join (which lists the same
    annotation in ``sip_exports``) across the translator."""

    var: int
    sid: int
    source: str  # "hash_build" | "merge_build"


@dataclasses.dataclass
class PScan(PhysNode):
    pattern: A.TriplePattern
    sort_var: Optional[int]  # variable the scan should come out sorted by
    sip: Tuple[PSipFilter, ...] = ()


@dataclasses.dataclass
class PPathScan(PhysNode):
    """Transitive property path ?s :p+ ?o — row-based only (paper §4).
    Kept for programmatically built plans; the planner now emits
    PPathExpand for every path (DESIGN.md §8)."""

    pattern: A.TriplePattern  # path == '+', constant predicate


@dataclasses.dataclass
class PPathExpand(PhysNode):
    """Vectorized property path: semi-naive delta-frontier BFS over the
    batch pipeline (DESIGN.md §8). ``seed_side`` records the planner's
    bound-endpoint choice: 'subject' seeds forward BFS (bound or
    enumerated subjects), 'object' seeds reverse BFS over flipped edges."""

    pattern: A.PathPattern
    seed_side: str = "subject"
    sip: Tuple[PSipFilter, ...] = ()


@dataclasses.dataclass
class PSort(PhysNode):
    child: "Phys"
    var: int


@dataclasses.dataclass
class PMergeJoin(PhysNode):
    left: "Phys"
    right: "Phys"
    var: int
    mode: str = "inner"
    post_filter: Optional[A.Expr] = None
    amplifying: bool = False  # output >> inputs: the BARQ sweet spot
    # left-join condition compiled by the expression VM (planner-cached)
    post_program: Optional[object] = None
    sip_exports: Tuple[PSipFilter, ...] = ()
    # mid-plan re-strategy eligibility (DESIGN.md §15): set by the planner
    # only where no ancestor consumes this join's sort order, so the
    # executor may lower an AdaptiveMergeJoin that switches merge->hash
    # when the build-side actual blows the estimate. Fingerprint-neutral.
    adaptive_ok: bool = dataclasses.field(default=False, compare=False)


@dataclasses.dataclass
class PLookupJoin(PhysNode):
    probe: "Phys"
    build: "Phys"
    var: int
    mode: str = "inner"


@dataclasses.dataclass
class PHashJoin(PhysNode):
    """Radix-partitioned hash join (DESIGN.md §11): the build side is
    materialized into a partitioned hash layout, the probe side streams
    through unsorted — chosen by cost when sorting the inputs for a merge
    join would dominate. ``keys`` may be empty: the degenerate
    constant-key join (cross / NULL-extending cross / exists-anything)
    that disjoint OPTIONAL and FILTER NOT EXISTS lower onto."""

    probe: "Phys"
    build: "Phys"
    keys: Tuple[int, ...] = ()
    mode: str = "inner"
    post_filter: Optional[A.Expr] = None
    post_program: Optional[object] = None
    sip_exports: Tuple[PSipFilter, ...] = ()
    # partitioning as a tracked physical property (DESIGN.md §15): grace
    # marks a budget-directed out-of-core build; grace_parts is the chosen
    # top-level fan-out, exp_spill_bytes the costing-time spill expectation
    # rendered by explain(). All fingerprint-neutral — strategy, not shape.
    grace: bool = dataclasses.field(default=False, compare=False)
    grace_parts: int = dataclasses.field(default=0, compare=False)
    exp_spill_bytes: float = dataclasses.field(default=0.0, compare=False)


@dataclasses.dataclass
class PCross(PhysNode):
    left: "Phys"
    right: "Phys"


@dataclasses.dataclass
class PFilter(PhysNode):
    expr: A.Expr
    child: "Phys"
    # ExprProgram compiled at plan time and cached on the node, so a plan
    # reused through the server's plan cache never re-lowers (DESIGN.md §9)
    program: Optional[object] = None


@dataclasses.dataclass
class PExtend(PhysNode):
    var: int
    expr: A.Expr
    child: "Phys"
    program: Optional[object] = None  # value-mode ExprProgram


@dataclasses.dataclass
class PProject(PhysNode):
    vars: Tuple[int, ...]
    child: "Phys"


@dataclasses.dataclass
class PDistinct(PhysNode):
    child: "Phys"
    streaming_var: Optional[int]  # set => DISTINCT-via-skip applies
    # budget-directed partitioned dedup (DESIGN.md §15)
    grace: bool = dataclasses.field(default=False, compare=False)
    grace_parts: int = dataclasses.field(default=0, compare=False)


@dataclasses.dataclass
class PGroup(PhysNode):
    child: "Phys"
    group_vars: Tuple[int, ...]
    aggs: Tuple[A.AggSpec, ...]
    streaming: bool  # single sorted group var
    # budget-directed partitioned grouping (DESIGN.md §15)
    grace: bool = dataclasses.field(default=False, compare=False)
    grace_parts: int = dataclasses.field(default=0, compare=False)


@dataclasses.dataclass
class PHaving(PhysNode):
    """HAVING: a mask-mode expression-VM filter stage over the aggregate
    output (DESIGN.md §10). Kept distinct from PFilter so plans show the
    post-grouping stage and translators can keep row/batch parity."""

    expr: A.Expr
    child: "Phys"
    program: Optional[object] = None  # plan-time compiled ExprProgram


@dataclasses.dataclass
class POrderBy(PhysNode):
    child: "Phys"
    keys: Tuple[A.SortKey, ...]


@dataclasses.dataclass
class PSlice(PhysNode):
    child: "Phys"
    limit: Optional[int]
    offset: int


@dataclasses.dataclass
class PUnion(PhysNode):
    left: "Phys"
    right: "Phys"


Phys = TUnion[
    PScan, PPathScan, PPathExpand, PSort, PMergeJoin, PLookupJoin,
    PHashJoin, PCross, PFilter, PExtend, PProject, PDistinct, PGroup,
    PHaving, POrderBy, PSlice, PUnion,
]


def phys_vars(n: Phys) -> Tuple[int, ...]:
    if isinstance(n, (PScan, PPathScan, PPathExpand)):
        return n.pattern.vars()
    if isinstance(n, (PSort, PFilter, PHaving, PSlice)):
        return phys_vars(n.child)
    if isinstance(n, PDistinct):
        return phys_vars(n.child)
    if isinstance(n, PExtend):
        return tuple(dict.fromkeys(phys_vars(n.child) + (n.var,)))
    if isinstance(n, PProject):
        return n.vars
    if isinstance(n, PMergeJoin):
        lv = phys_vars(n.left)
        if n.mode in ("semi", "anti"):
            return lv
        return tuple(dict.fromkeys(lv + phys_vars(n.right)))
    if isinstance(n, PLookupJoin):
        lv = phys_vars(n.probe)
        if n.mode in ("semi", "anti"):
            return lv
        return tuple(dict.fromkeys(lv + phys_vars(n.build)))
    if isinstance(n, PHashJoin):
        lv = phys_vars(n.probe)
        if n.mode in ("semi", "anti"):
            return lv
        return tuple(dict.fromkeys(lv + phys_vars(n.build)))
    if isinstance(n, (PCross, PUnion)):
        return tuple(dict.fromkeys(phys_vars(n.left) + phys_vars(n.right)))
    if isinstance(n, PGroup):
        return n.group_vars + tuple(a.out for a in n.aggs)
    if isinstance(n, POrderBy):
        return phys_vars(n.child)
    raise TypeError(type(n))


def phys_sorted_by(n: Phys) -> Optional[int]:
    if isinstance(n, PScan):
        return n.sort_var
    if isinstance(n, PPathScan):
        return n.pattern.s.id if isinstance(n.pattern.s, A.V) else None
    if isinstance(n, PPathExpand):
        if isinstance(n.pattern.s, A.V):
            return n.pattern.s.id
        return n.pattern.o.id if isinstance(n.pattern.o, A.V) else None
    if isinstance(n, PSort):
        return n.var
    if isinstance(n, PMergeJoin):
        return None if n.mode == "left_outer" else n.var
    if isinstance(n, PLookupJoin):
        return phys_sorted_by(n.probe)
    if isinstance(n, PHashJoin):
        # probe order survives; tracked left_outer (a join condition, or a
        # multi-key join whose packing may fall back to pair tracking)
        # emits its NULL-extended rows after each batch's expansions,
        # breaking the interleave. A grace build re-orders the probe side
        # by partition, so it preserves nothing (DESIGN.md §15).
        if n.grace:
            return None
        if n.mode == "left_outer" and (
            n.post_filter is not None or len(n.keys) > 1
        ):
            return None
        return phys_sorted_by(n.probe)
    if isinstance(n, (PFilter, PHaving, PSlice)):
        return phys_sorted_by(n.child)
    if isinstance(n, PExtend):
        return phys_sorted_by(n.child)
    if isinstance(n, PProject):
        sb = phys_sorted_by(n.child)
        return sb if sb in n.vars else None
    if isinstance(n, PDistinct):
        if n.grace:
            # partitioned dedup emits partition-major, never sorted —
            # unlike SortDistinct whose np.unique output is ordered
            return None
        return n.streaming_var or (
            phys_vars(n.child)[0] if len(phys_vars(n.child)) == 1 else None
        )
    if isinstance(n, PGroup):
        return n.group_vars[0] if n.streaming and n.group_vars else None
    return None


# ---------------------------------------------------------------------------
# node fingerprints (DESIGN.md §14)
# ---------------------------------------------------------------------------

# Every Phys node gets a stable fingerprint identifying *what it computes*
# (not how): constants stay literal (cardinality depends on them), variables
# canonicalize through the query's first-appearance map, and physical
# details that can't change output cardinality — sort vars, seed sides,
# join strategy, SIP annotations — are excluded. The executor records each
# operator's actual row count under this key; the planner's feedback
# override looks the same key up on the next plan of the same (or any
# same-shaped) query.


def _fp_hash(label: str) -> str:
    return hashlib.sha256(label.encode()).hexdigest()[:16]


def _fp_slot(sl, canon: Dict[int, int]) -> str:
    if isinstance(sl, A.V):
        return f"?{canon.get(sl.id, sl.id)}"
    return f"K:{sl.term}"


def _fp_expr(e, canon: Dict[int, int]) -> str:
    if e is None:
        return ""
    if isinstance(e, A.VarRef):
        return f"?{canon.get(e.var, e.var)}"
    if isinstance(e, A.Lit):
        return f"L:{e.value!r}"
    if isinstance(e, A.Cmp):
        return f"({_fp_expr(e.lhs, canon)}{e.op}{_fp_expr(e.rhs, canon)})"
    if isinstance(e, A.Arith):
        return f"({_fp_expr(e.lhs, canon)}{e.op}{_fp_expr(e.rhs, canon)})"
    if isinstance(e, A.And):
        return "and(" + ",".join(_fp_expr(t, canon) for t in e.terms) + ")"
    if isinstance(e, A.Or):
        return "or(" + ",".join(_fp_expr(t, canon) for t in e.terms) + ")"
    if isinstance(e, A.Not):
        return f"not({_fp_expr(e.term, canon)})"
    if isinstance(e, A.Bound):
        return f"bound(?{canon.get(e.var, e.var)})"
    if isinstance(e, A.Func):
        return f"{e.name}(" + ",".join(_fp_expr(a, canon) for a in e.args) + ")"
    return type(e).__name__


def _leaf_label(p, canon: Dict[int, int]) -> str:
    """Fingerprint label for a BGP leaf (TriplePattern or PathPattern)."""
    if isinstance(p, A.PathPattern):
        from repro.core.paths.expr import path_repr

        return (
            f"path({_fp_slot(p.s, canon)},{path_repr(p.expr)},"
            f"{_fp_slot(p.o, canon)})"
        )
    parts = [_fp_slot(p.s, canon), _fp_slot(p.p, canon), _fp_slot(p.o, canon)]
    if p.g is not None:
        parts.append(_fp_slot(p.g, canon))
    if p.path:
        parts.append(f"+{p.path}")
    return f"scan({','.join(parts)})"


def _srcs_label(srcs: FrozenSet[str]) -> str:
    return ",".join(sorted(srcs))


def _join_fp(
    mode: str, post_filter, left: "Phys", right: "Phys", canon: Dict[int, int]
) -> Tuple[str, FrozenSet[str]]:
    """Fingerprint for a join over two (already-fingerprinted) subplans.
    Plain inner joins hash the unordered union of source sets; everything
    order-sensitive (semi/anti/left_outer, or a join condition) hashes the
    ordered pair of source sets plus the condition."""
    if mode == "inner" and post_filter is None:
        srcs = left.srcs | right.srcs
        return _fp_hash("join{" + _srcs_label(srcs) + "}"), srcs
    label = (
        f"{mode}[{_fp_expr(post_filter, canon)}]"
        f"({_srcs_label(left.srcs)}|{_srcs_label(right.srcs)})"
    )
    fp = _fp_hash(label)
    return fp, frozenset((fp,))


# unary nodes that preserve their child's cardinality 1:1 share the child's
# fingerprint — one observation covers the whole pass-through chain
_PASS_THROUGH = (PSort, PProject, POrderBy, PExtend)


def annotate_fingerprints(n: Phys, canon: Dict[int, int]) -> None:
    """Bottom-up fingerprint computation over a physical plan. Idempotent:
    nodes fingerprinted during planning (feedback consultation) keep their
    values; only unset nodes are computed."""
    if n.fp:
        return
    for fld in ("child", "left", "right", "probe", "build"):
        c = getattr(n, fld, None)
        if isinstance(c, PhysNode):
            annotate_fingerprints(c, canon)
    if isinstance(n, (PScan, PPathExpand, PPathScan)):
        n.fp = _fp_hash(_leaf_label(n.pattern, canon))
        n.srcs = frozenset((n.fp,))
    elif isinstance(n, _PASS_THROUGH):
        n.fp, n.srcs = n.child.fp, n.child.srcs
    elif isinstance(n, PFilter):
        # selections commute with inner joins, so a filter joins the
        # source set as a pseudo-source atom: σ_E(A⋈B⋈C) and σ_E(A⋈B)⋈C
        # fingerprint identically no matter where the planner placed it
        n.srcs = n.child.srcs | frozenset((f"σ[{_fp_expr(n.expr, canon)}]",))
        n.fp = _fp_hash("join{" + _srcs_label(n.srcs) + "}")
    elif isinstance(n, PHaving):
        n.fp = _fp_hash(
            f"having[{_fp_expr(n.expr, canon)}]" + "{"
            + _srcs_label(n.child.srcs) + "}"
        )
        n.srcs = frozenset((n.fp,))
    elif isinstance(n, PDistinct):
        n.fp = _fp_hash("distinct{" + _srcs_label(n.child.srcs) + "}")
        n.srcs = frozenset((n.fp,))
    elif isinstance(n, PGroup):
        gv = ",".join(f"?{canon.get(v, v)}" for v in n.group_vars)
        aggs = ";".join(
            f"{'d' if a.distinct else ''}{a.func}"
            f"({'*' if a.var is None else '?%s' % canon.get(a.var, a.var)})"
            for a in n.aggs
        )
        n.fp = _fp_hash(
            f"group[{gv}|{aggs}]" + "{" + _srcs_label(n.child.srcs) + "}"
        )
        n.srcs = frozenset((n.fp,))
    elif isinstance(n, PSlice):
        n.fp = _fp_hash(
            f"slice[{n.limit}:{n.offset}]" + "{"
            + _srcs_label(n.child.srcs) + "}"
        )
        n.srcs = frozenset((n.fp,))
    elif isinstance(n, PMergeJoin):
        n.fp, n.srcs = _join_fp(n.mode, n.post_filter, n.left, n.right, canon)
    elif isinstance(n, PLookupJoin):
        n.fp, n.srcs = _join_fp(n.mode, None, n.probe, n.build, canon)
    elif isinstance(n, PHashJoin):
        n.fp, n.srcs = _join_fp(n.mode, n.post_filter, n.probe, n.build, canon)
    elif isinstance(n, PCross):
        n.fp, n.srcs = _join_fp("inner", None, n.left, n.right, canon)
    elif isinstance(n, PUnion):
        n.fp = _fp_hash(
            "union("
            + "|".join(
                sorted((_srcs_label(n.left.srcs), _srcs_label(n.right.srcs)))
            )
            + ")"
        )
        n.srcs = frozenset((n.fp,))
    else:
        n.fp = _fp_hash(type(n).__name__)
        n.srcs = frozenset((n.fp,))


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------


# hash-join cost constants (DESIGN.md §11 strategy table): building the
# partitioned layout touches every build row a few times (partition, reorder,
# probe bookkeeping), a sort costs ~ n log2 n row moves. The constants only
# need to be right about the crossover, not the absolute times.
_HASH_BUILD_FACTOR = 4.0
# extra per-row cost when an over-budget hash build must run as a grace
# join (partition fan-out + spill I/O on both sides, DESIGN.md §15)
_GRACE_SPILL_FACTOR = 2.0


def _sort_cost(n: float) -> float:
    n = max(n, 2.0)
    return n * math.log2(n)


class Planner:
    def __init__(
        self,
        stats: GraphStats,
        barq_enabled: bool = True,
        dictionary=None,
        join_strategy: Optional[str] = None,
        sip: Optional[str] = None,
        feedback: Optional[telemetry.CardinalityFeedback] = None,
        memory_budget: Optional[int] = None,
        adaptive_join: Optional[str] = None,
    ):
        assert join_strategy in (None, "hash", "merge")
        assert sip in (None, "on", "off")
        assert adaptive_join in (None, "on", "off")
        self.stats = stats
        # partitioned substrate (DESIGN.md §15): bytes of working memory a
        # single build/sort may assume resident. None disables every
        # budget-aware decision — plans are byte-identical to pre-§15.
        self.memory_budget = memory_budget
        # "on" marks order-insensitive merge joins adaptive_ok so the
        # executor can re-strategize merge->hash on observed misestimates
        self.adaptive_join = adaptive_join
        # observed-cardinality feedback store (DESIGN.md §14): when set,
        # estimates at every choke point — leaf cards, join ordering, the
        # generic binary-join estimate — prefer recorded actuals over the
        # cost model, and a final pass stamps est_source="feedback"
        self.feedback = feedback
        # canonical var map of the query being planned (fingerprint input)
        self._canon: Dict[int, int] = {}
        # sideways information passing (DESIGN.md §12): None = cost-gated
        # (push a prefilter when the build side looks selective), "on" =
        # always push where sound, "off" = never annotate
        self.sip = sip
        self._sip_counter = 0
        # §4.2: the one cost-model tweak — amplifying merge joins get cheaper
        # when BARQ executes them
        self.barq_enabled = barq_enabled
        # EngineConfig.join_strategy: None = cost-based choice between the
        # sort+merge and radix-hash paths; "hash"/"merge" force one (tests,
        # ablations)
        self.join_strategy = join_strategy
        # expression VM: FILTER / BIND / left-join conditions compile once
        # at plan time; programs are cached per (expr, mode) across the
        # whole plan (and across plans, for a long-lived planner)
        self.dictionary = dictionary if dictionary is not None else getattr(
            getattr(stats, "store", None), "dict", None
        )
        self._prog_cache: dict = {}

    # -- public -------------------------------------------------------------------

    def plan(self, node: A.PlanNode) -> Phys:
        self._canon = telemetry.canonical_var_map(node)
        phys = self._plan(node)
        if self.sip != "off":
            self._sip_walk(phys)
        annotate_fingerprints(phys, self._canon)
        if self.feedback is not None:
            self._apply_feedback(phys)
        if self.memory_budget is not None:
            # after feedback: budget decisions should see history-corrected
            # cardinalities, not just the cost model's
            self._budget_walk(phys)
        if self.adaptive_join == "on":
            self._mark_adaptive(phys, order_needed=False)
        return phys

    # -- budget-aware physical properties (DESIGN.md §15) -----------------------

    @staticmethod
    def _est_bytes(n: Phys) -> float:
        return max(n.est_rows, 0.0) * max(len(phys_vars(n)), 1) * 4.0

    def _grace_parts_for(self, nbytes: float) -> int:
        # average partition should fit half the budget (probe partitions
        # share the other half); power of two, capped at 256
        half = max(self.memory_budget // 2, 1)
        p = 1
        while p * half < nbytes and p < 256:
            p *= 2
        return max(p, 2)

    def _budget_walk(self, n: Phys) -> None:
        """Post-pass marking partitioning as a physical property: hash
        builds whose estimated bytes exceed the budget become grace builds,
        unsorted GROUP BY/DISTINCT over budget consume the partitioned
        layout instead of the whole-input sort."""
        for fld in ("child", "left", "right", "probe", "build"):
            c = getattr(n, fld, None)
            if isinstance(c, PhysNode):
                self._budget_walk(c)
        if isinstance(n, PHashJoin) and n.keys:
            bb = self._est_bytes(n.build)
            if bb > self.memory_budget:
                n.grace = True
                n.grace_parts = self._grace_parts_for(bb)
                n.exp_spill_bytes = max(
                    bb + self._est_bytes(n.probe) - self.memory_budget, 0.0
                )
        elif isinstance(n, PGroup) and n.group_vars:
            if self._est_bytes(n.child) > self.memory_budget:
                if n.streaming and isinstance(n.child, PSort):
                    # the PSort existed only to force streaming grouping;
                    # the partitioned path groups unsorted input directly
                    n.child = n.child.child
                    n.streaming = False
                if not n.streaming:
                    # naturally sorted streaming input needs no budget: it
                    # reduces run-by-run without materializing
                    n.grace = True
                    n.grace_parts = self._grace_parts_for(
                        self._est_bytes(n.child)
                    )
        elif isinstance(n, PDistinct) and n.streaming_var is None:
            if self._est_bytes(n.child) > self.memory_budget:
                n.grace = True
                n.grace_parts = self._grace_parts_for(self._est_bytes(n.child))

    def _mark_adaptive(self, n: Phys, order_needed: bool) -> None:
        """Top-down order-sensitivity walk: a PMergeJoin is adaptive_ok
        only when NO ancestor consumes its output order — switching
        merge->hash mid-plan re-orders emission, so an order-consuming
        parent (another merge join, a streaming group/distinct, ORDER BY
        assumptions) must pin the strategy."""
        if isinstance(n, PMergeJoin):
            n.adaptive_ok = not order_needed
            # both inputs feed a merge: their order is always consumed
            self._mark_adaptive(n.left, True)
            self._mark_adaptive(n.right, True)
            return
        if isinstance(n, (PSort, POrderBy)):
            # a sort above re-establishes any order: children are free
            self._mark_adaptive(n.child, False)
            return
        if isinstance(n, PGroup):
            self._mark_adaptive(n.child, n.streaming)
            return
        if isinstance(n, PDistinct):
            self._mark_adaptive(n.child, n.streaming_var is not None)
            return
        if isinstance(n, (PFilter, PHaving, PProject, PExtend, PSlice)):
            self._mark_adaptive(n.child, order_needed)
            return
        if isinstance(n, (PHashJoin, PLookupJoin)):
            # the probe side's order flows through; the build side is
            # materialized wholesale, so its order never matters
            self._mark_adaptive(n.probe, order_needed)
            self._mark_adaptive(n.build, False)
            return
        if isinstance(n, (PCross, PUnion)):
            self._mark_adaptive(n.left, False)
            self._mark_adaptive(n.right, False)
            return
        for fld in ("child", "left", "right", "probe", "build"):
            c = getattr(n, fld, None)
            if isinstance(c, PhysNode):
                self._mark_adaptive(c, True)  # unknown parent: be safe

    def _apply_feedback(self, n: Phys) -> None:
        """Final pass: override every node's estimate with its observed
        cardinality where history exists, tagging the source so EXPLAIN
        renders ``est=N(source=feedback)`` and EXPLAIN ANALYZE q-errors
        reflect the history-corrected numbers."""
        for fld in ("child", "left", "right", "probe", "build"):
            c = getattr(n, fld, None)
            if isinstance(c, PhysNode):
                self._apply_feedback(c)
        obs = self.feedback.lookup(n.fp)
        if obs is not None:
            n.est_rows = obs
            n.est_source = "feedback"

    def _feedback_est(self, fp: str, default: float) -> float:
        if self.feedback is None:
            return default
        obs = self.feedback.lookup(fp)
        return default if obs is None else obs

    def compile_expr(self, expr: A.Expr, mode: str):
        """ExprProgram for ``expr``; ``False`` (cached) when the expression
        is outside the VM surface — operators then use the interpreted
        tree walk without re-attempting compilation; None when no
        dictionary is attached."""
        if self.dictionary is None or expr is None:
            return None
        key = (expr, mode)
        if key not in self._prog_cache:
            from repro.core.exprs import ExprCompileError, compile_expr

            try:
                self._prog_cache[key] = compile_expr(expr, self.dictionary, mode)
            except ExprCompileError:
                self._prog_cache[key] = False  # known uncompilable
        return self._prog_cache[key]

    def _pfilter(self, expr: A.Expr, child: Phys, sel: float = 0.5) -> Phys:
        out = PFilter(expr, child, program=self.compile_expr(expr, "mask"))
        out.est_rows = child.est_rows * sel
        return out

    # -- sideways information passing (DESIGN.md §12) ---------------------------

    # auto mode pushes a prefilter only when the build side is estimated
    # to be meaningfully smaller than the probe stream it would prune
    _SIP_GATE = 0.5

    def _sip_wanted(self, build_est: float, probe_est: float) -> bool:
        if self.sip == "on":
            return True
        return build_est < self._SIP_GATE * max(probe_est, 1.0)

    def _sip_walk(self, n: Phys) -> None:
        """Post-pass over the final physical plan: for every inner/semi
        hash or merge join whose build side looks selective, push a
        PSipFilter annotation into the probe-side leaves. Runs bottom-up
        so inner joins' filters land before outer ones'."""
        for fld in ("child", "left", "right", "probe", "build"):
            c = getattr(n, fld, None)
            if isinstance(c, PhysNode):
                self._sip_walk(c)
        if (
            isinstance(n, PHashJoin)
            and n.mode in ("inner", "semi")
            and n.keys
            and self._sip_wanted(n.build.est_rows, n.probe.est_rows)
        ):
            for var in n.keys:
                ann = PSipFilter(var, self._sip_counter, "hash_build")
                if self._push_sip(n.probe, ann):
                    self._sip_counter += 1
                    n.sip_exports = n.sip_exports + (ann,)
        if isinstance(n, PMergeJoin) and n.mode in ("inner", "semi"):
            # the right side must either be a pipeline breaker (PSort —
            # full bloom summary for free) or a sorted leaf (O(1)
            # range-only summary); anything else would force an extra
            # materialization just to summarize it
            exportable = isinstance(n.right, PSort) or (
                isinstance(n.right, PScan) and n.right.sort_var == n.var
            )
            if exportable and self._sip_wanted(n.right.est_rows, n.left.est_rows):
                ann = PSipFilter(n.var, self._sip_counter, "merge_build")
                if self._push_sip(n.left, ann):
                    self._sip_counter += 1
                    n.sip_exports = n.sip_exports + (ann,)

    def _push_sip(self, n: Phys, ann: PSipFilter) -> bool:
        """Descend toward leaves binding ann.var; attach where sound.
        A SIP prefilter may only remove rows whose ann.var value is
        certainly absent from the exporting join's build side, so it can
        cross any operator for which 'prune child rows with var not in S'
        never changes rows the top join would keep: filters, sorts,
        distinct, both union branches, the probe/left side of inner,
        semi, anti and left-outer joins, and grouping keyed on the var.
        It must NOT cross a nullable (optional) side, an anti subtrahend,
        a slice, or an aggregate input whose group keys don't include the
        var."""
        v = ann.var
        if isinstance(n, PScan):
            if v in n.pattern.vars():
                n.sip = n.sip + (ann,)
                return True
            return False
        if isinstance(n, PPathExpand):
            if v in n.pattern.vars():
                n.sip = n.sip + (ann,)
                return True
            return False
        if isinstance(n, (PSort, PFilter, PHaving, PDistinct, POrderBy)):
            return self._push_sip(n.child, ann)
        if isinstance(n, PExtend):
            # BIND introduces n.var fresh — if that's the filtered var it
            # originates here, not in any leaf below
            return False if v == n.var else self._push_sip(n.child, ann)
        if isinstance(n, PProject):
            return v in n.vars and self._push_sip(n.child, ann)
        if isinstance(n, PGroup):
            # sound only on a group key: pruning rows of a v∉S group
            # removes that whole group, which the top join drops anyway
            return v in n.group_vars and self._push_sip(n.child, ann)
        if isinstance(n, (PUnion, PCross)):
            a = self._push_sip(n.left, ann)
            b = self._push_sip(n.right, ann)
            return a or b
        if isinstance(n, PMergeJoin):
            if n.mode == "inner":
                a = self._push_sip(n.left, ann)
                b = self._push_sip(n.right, ann)
                return a or b
            if n.mode in ("semi", "anti", "left_outer"):
                return self._push_sip(n.left, ann)
            return False
        if isinstance(n, (PHashJoin, PLookupJoin)):
            if n.mode == "inner":
                a = self._push_sip(n.probe, ann)
                b = self._push_sip(n.build, ann)
                return a or b
            if n.mode in ("semi", "anti", "left_outer"):
                return self._push_sip(n.probe, ann)
            return False
        return False  # PSlice, PPathScan: stop

    # -- logical dispatch -------------------------------------------------------------

    def _plan(self, node: A.PlanNode) -> Phys:
        if isinstance(node, A.BGP):
            return self._plan_bgp(node.patterns, [])
        if isinstance(node, A.Filter):
            # push filters into BGP join ordering when possible (§2.2.2)
            if isinstance(node.child, A.BGP):
                return self._plan_bgp(node.child.patterns, [node.expr])
            child = self._plan(node.child)
            return self._pfilter(node.expr, child)
        if isinstance(node, A.Join):
            return self._plan_binary_join(node.left, node.right, "inner", None)
        if isinstance(node, A.LeftJoin):
            return self._plan_binary_join(node.left, node.right, "left_outer", node.expr)
        if isinstance(node, A.Minus):
            return self._plan_binary_join(node.left, node.right, "anti", None)
        if isinstance(node, A.NotExists):
            # anti-semi-join like Minus, EXCEPT with disjoint variable sets
            # (see _plan_binary_join): there NOT EXISTS removes every left
            # row as soon as the inner pattern has any solution
            return self._plan_binary_join(
                node.left, node.right, "not_exists", None
            )
        if isinstance(node, A.Union):
            l, r = self._plan(node.left), self._plan(node.right)
            out = PUnion(l, r)
            out.est_rows = l.est_rows + r.est_rows
            return out
        if isinstance(node, A.Extend):
            child = self._plan(node.child)
            out = PExtend(
                node.var, node.expr, child,
                program=self.compile_expr(node.expr, "value"),
            )
            out.est_rows = child.est_rows
            return out
        if isinstance(node, A.Project):
            child = self._plan(node.child)
            out = PProject(tuple(node.vars), child)
            out.est_rows = child.est_rows
            return out
        if isinstance(node, A.Distinct):
            child = self._plan(node.child)
            cvars = phys_vars(child)
            sv = None
            if len(cvars) == 1 and phys_sorted_by(child) == cvars[0]:
                sv = cvars[0]
            out = PDistinct(child, sv)
            out.est_rows = max(child.est_rows * 0.5, 1)
            return out
        if isinstance(node, A.GroupAgg):
            child = self._plan(node.child)
            gv = tuple(node.group_vars)
            streaming = (len(gv) == 1 and phys_sorted_by(child) == gv[0]) or len(gv) == 0
            # resort to enable streaming aggregation when cheap (§3.3)
            if len(gv) == 1 and not streaming:
                child = PSort(child, gv[0])
                child.est_rows = child.child.est_rows
                streaming = True
            out = PGroup(child, gv, tuple(node.aggs), streaming)
            out.est_rows = max(child.est_rows * 0.1, 1)
            if node.having is not None:
                h = PHaving(
                    node.having, out,
                    program=self.compile_expr(node.having, "mask"),
                )
                h.est_rows = max(out.est_rows * 0.5, 1)
                return h
            return out
        if isinstance(node, A.OrderBy):
            child = self._plan(node.child)
            out = POrderBy(child, tuple(node.keys))
            out.est_rows = child.est_rows
            return out
        if isinstance(node, A.Slice):
            child = self._plan(node.child)
            out = PSlice(child, node.limit, node.offset)
            out.est_rows = min(
                child.est_rows, node.limit if node.limit is not None else child.est_rows
            )
            return out
        raise TypeError(f"cannot plan {type(node)}")

    # -- BGP join ordering (greedy System-R style) ---------------------------------------

    @staticmethod
    def _normalize_pattern(p):
        """Fold the legacy TriplePattern path='+' shorthand into a
        PathPattern so one code path prices and plans every path."""
        if isinstance(p, A.TriplePattern) and p.path == "+":
            if not isinstance(p.p, A.K):
                raise ValueError(
                    "property paths require a constant predicate, got "
                    f"variable predicate in {p}"
                )
            from repro.core.paths.expr import PClosure, PLink

            return A.PathPattern(p.s, PClosure(PLink(p.p.term), min_hops=1), p.o)
        return p

    def _pattern_card(self, p) -> float:
        """Cardinality for a BGP leaf: triple patterns from the index
        ranges, paths from the stats-based closure estimate (replacing the
        old hard-coded 3-hop multiplier). With a feedback store attached,
        an observed actual for the same leaf fingerprint wins."""
        if isinstance(p, A.PathPattern):
            est = max(self.stats.path_cardinality(p), 0)
        else:
            est = max(self.stats.pattern_cardinality(p), 0)
        if self.feedback is None:
            return est
        return self._feedback_est(
            _fp_hash(_leaf_label(self._normalize_pattern(p), self._canon)), est
        )

    def _pattern_distinct(self, p, var: int) -> int:
        if isinstance(p, A.PathPattern):
            return self.stats.path_distinct_values(p, var)
        return self.stats.distinct_values(p, var)

    # beyond this many patterns the exact DP's subset enumeration (3^n)
    # would dominate planning time; fall back to the greedy loop
    _BUSHY_MAX = 8

    def _plan_bgp(self, patterns: Sequence[A.TriplePattern], filters: List[A.Expr]) -> Phys:
        assert patterns
        remaining = [self._normalize_pattern(p) for p in patterns]
        if 3 <= len(remaining) <= self._BUSHY_MAX:
            plan = self._plan_bgp_bushy(remaining, list(filters))
            if plan is not None:
                return plan
        return self._plan_bgp_greedy(remaining, filters)

    def _plan_bgp_bushy(self, pats: List, filters: List[A.Expr]) -> Optional[Phys]:
        """Bounded exact join ordering: bitmask DP over connected pattern
        subsets (System-R generalized to bushy trees). Each DP state keeps
        the cheapest plan for one subset under the §11 cost model with
        SIP-aware probe discounts, so shapes like (A⋈B)⋈(C⋈D) — which the
        greedy linear loop can never emit — win when two small
        intermediate results exist. Returns None for disconnected BGPs
        (the greedy loop's cartesian handling covers those)."""
        n = len(pats)
        leaves: List[Phys] = []
        for p in pats:
            leaf = self._leaf(p)
            leaf.est_rows = self._pattern_card(p)
            leaves.append(leaf)
        vsets = [frozenset(p.vars()) for p in pats]
        # variable set per subset mask
        vmask = {0: frozenset()}
        for m in range(1, 1 << n):
            low = m & -m
            vmask[m] = vmask[m ^ low] | vsets[low.bit_length() - 1]
        # best[mask] = (cost, plan)
        best: dict = {1 << i: (leaves[i].est_rows, leaves[i]) for i in range(n)}
        for m in sorted(range(1, 1 << n), key=lambda x: bin(x).count("1")):
            if bin(m).count("1") < 2:
                continue
            sub = (m - 1) & m
            while sub:
                oth = m ^ sub
                if sub < oth and sub in best and oth in best and (
                    vmask[sub] & vmask[oth]
                ):
                    ca, pa = best[sub]
                    cb, pb = best[oth]
                    join, jc = self._join_subplans(pa, pb)
                    tot = ca + cb + jc
                    if m not in best or tot < best[m][0]:
                        best[m] = (tot, join)
                sub = (sub - 1) & m
        full = (1 << n) - 1
        if full not in best:
            return None
        plan = best[full][1]
        return self._attach_filters(plan, filters)

    def _join_subplans(self, left: Phys, right: Phys) -> Tuple[Phys, float]:
        """Join two DP subplans: pick the join var (preferring an already
        sorted side), estimate output, and choose merge vs hash by the
        §11 cost model. The hash probe pass is discounted by the SIP
        survival fraction min(d_probe, d_build)/d_probe — the same
        containment assumption stats.semi_join_cardinality uses — since
        an annotated probe leaf never streams rows the build side can't
        match. Never mutates its inputs (losing DP candidates share
        subtrees with winners)."""
        lv, rv = phys_vars(left), phys_vars(right)
        shared = [v for v in lv if v in rv]
        jv = shared[0]
        for v in shared:
            if phys_sorted_by(left) == v or phys_sorted_by(right) == v:
                jv = v
                break
        d_l = self._distinct_estimate(left, jv)
        d_r = self._distinct_estimate(right, jv)
        est = self.stats.join_cardinality(
            max(int(left.est_rows), 1), max(int(right.est_rows), 1), d_l, d_r
        )
        amplifying = est > 4 * max(left.est_rows, right.est_rows)
        if self.barq_enabled and amplifying:
            est *= 0.5  # §4.2: amplifying merge joins are cheap under BARQ
        if self.feedback is not None:
            # observed cardinality for this join's source set (order- and
            # strategy-insensitive) beats the containment estimate — and
            # flows into the DP cost, so ordering re-plans under history
            annotate_fingerprints(left, self._canon)
            annotate_fingerprints(right, self._canon)
            est = self._feedback_est(
                _join_fp("inner", None, left, right, self._canon)[0], est
            )
        ln = max(left.est_rows, 1.0)
        rn = max(right.est_rows, 1.0)
        l_sorted = phys_sorted_by(left) == jv
        r_sorted = phys_sorted_by(right) == jv
        merge_cost = est + ln + rn
        if not l_sorted:
            merge_cost += _sort_cost(ln)
        if not r_sorted:
            merge_cost += _sort_cost(rn)
        # hash: build the smaller side, stream the bigger one
        if ln >= rn:
            probe, build, pn, bn, d_p, d_b = left, right, ln, rn, d_l, d_r
        else:
            probe, build, pn, bn, d_p, d_b = right, left, rn, ln, d_r, d_l
        sip_f = 1.0
        if self.sip != "off" and self._sip_wanted(bn, pn):
            sip_f = max(min(d_p, d_b) / max(d_p, 1), 0.05)
        hash_cost = _HASH_BUILD_FACTOR * bn + pn * sip_f + est
        if (
            self.memory_budget is not None
            and bn * max(len(phys_vars(build)), 1) * 4.0 > self.memory_budget
        ):
            # over-budget build goes grace: both sides pay a partition
            # pass plus spill I/O (DESIGN.md §15 budget costing)
            hash_cost += _GRACE_SPILL_FACTOR * (bn + pn)
        if self.join_strategy == "merge" or (
            self.join_strategy != "hash"
            and (l_sorted and r_sorted or merge_cost <= hash_cost)
        ):
            if not l_sorted:
                s = PSort(left, jv)
                s.est_rows = left.est_rows
                left = s
            if not r_sorted:
                s = PSort(right, jv)
                s.est_rows = right.est_rows
                right = s
            out: Phys = PMergeJoin(left, right, jv)
            out.amplifying = amplifying
            out.est_rows = est
            return out, merge_cost
        keys = tuple(v for v in phys_vars(probe) if v in phys_vars(build))
        if isinstance(probe, PScan) and probe.sort_var is None:
            # a hash probe doesn't need sorted input, but asking the scan
            # to come out sorted by the join var is free (index choice)
            # and lets a pushed SIP filter narrow it by code range via
            # seek instead of just masking (copy: DP leaves are shared
            # across candidate plans)
            p2 = PScan(probe.pattern, jv, sip=probe.sip)
            p2.est_rows = probe.est_rows
            probe = p2
        out = PHashJoin(probe=probe, build=build, keys=keys)
        out.est_rows = est
        return out, hash_cost

    def _attach_filters(self, plan: Phys, filters: List[A.Expr]) -> Phys:
        """Place each pushed-down filter at the lowest node that covers
        its variables (post-pass over the DP-chosen shape — the greedy
        loop instead interleaves placement with ordering)."""
        if not filters:
            return plan

        def place(node: Phys) -> Phys:
            for fld in ("child", "left", "right", "probe", "build"):
                c = getattr(node, fld, None)
                if isinstance(c, PhysNode):
                    setattr(node, fld, place(c))
            for f in list(filters):
                if set(A.expr_vars(f)) <= set(phys_vars(node)):
                    filters.remove(f)
                    node = self._pfilter(f, node)
            return node

        plan = place(plan)
        for f in filters:  # vars never all bound: evaluate at the top
            plan = self._pfilter(f, plan)
        return plan

    def _plan_bgp_greedy(self, remaining: List, filters: List[A.Expr]) -> Phys:
        cards = {id(p): self._pattern_card(p) for p in remaining}
        # start from the most selective pattern
        first = min(remaining, key=lambda p: cards[id(p)])
        remaining.remove(first)
        current: Phys = self._leaf(first)
        current.est_rows = cards[id(first)]
        current_vars = set(first.vars())
        pending_filters = list(filters)

        while remaining:
            # pick the joinable pattern with the smallest estimated output
            best, best_est, best_var = None, None, None
            for p in remaining:
                shared = [v for v in p.vars() if v in current_vars]
                if not shared:
                    continue
                jv = self._choose_join_var(current, p, shared)
                d_a = self._distinct_estimate(current, jv)
                d_b = self._pattern_distinct(p, jv)
                est = self.stats.join_cardinality(
                    max(int(current.est_rows), 1), cards[id(p)], d_a, d_b
                )
                if self.barq_enabled and est > 4 * max(current.est_rows, cards[id(p)]):
                    # §4.2: amplifying merge joins are cheaper under BARQ
                    est *= 0.5
                if self.feedback is not None:
                    # history for (current ⋈ p)'s source set steers the
                    # greedy pick just like it steers the DP
                    annotate_fingerprints(current, self._canon)
                    leaf_fp = _fp_hash(_leaf_label(p, self._canon))
                    srcs = current.srcs | frozenset((leaf_fp,))
                    est = self._feedback_est(
                        _fp_hash("join{" + ",".join(sorted(srcs)) + "}"), est
                    )
                if best_est is None or est < best_est:
                    best, best_est, best_var = p, est, jv
            if best is None:
                # disconnected: cartesian with the smallest remaining pattern
                best = min(remaining, key=lambda p: cards[id(p)])
                remaining.remove(best)
                rhs: Phys = self._leaf(best)
                rhs.est_rows = cards[id(best)]
                current = PCross(current, rhs)
                current.est_rows = current.left.est_rows * rhs.est_rows
                current_vars |= set(best.vars())
            else:
                remaining.remove(best)
                current = self._make_join(current, best, best_var, best_est)
                current_vars |= set(best.vars())
            current, pending_filters = self._apply_ready_filters(
                current, current_vars, pending_filters
            )

        for f in pending_filters:
            current = self._pfilter(f, current)
        return current

    def _apply_ready_filters(self, current: Phys, cvars: set, filters: List[A.Expr]):
        ready = [f for f in filters if set(A.expr_vars(f)) <= cvars]
        rest = [f for f in filters if f not in ready]
        for f in ready:
            current = self._pfilter(f, current)
        return current, rest

    def _choose_join_var(self, current: Phys, p: A.TriplePattern, shared: List[int]) -> int:
        # prefer the current plan's existing sort var to avoid a re-sort
        sb = phys_sorted_by(current)
        if sb in shared:
            return sb
        return shared[0]

    def _distinct_estimate(self, n: Phys, var: int) -> int:
        if isinstance(n, PScan):
            return self.stats.distinct_values(n.pattern, var)
        return max(int(n.est_rows ** 0.5), 1)

    def _leaf(self, p, sort_var: Optional[int] = None) -> Phys:
        p = self._normalize_pattern(p)
        if isinstance(p, A.PathPattern):
            # seed-side choice: a bound object flips the edges and runs
            # BFS backwards from it; otherwise seed forward from the
            # (bound or enumerated) subjects
            seed = (
                "object"
                if isinstance(p.o, A.K) and isinstance(p.s, A.V)
                else "subject"
            )
            return PPathExpand(p, seed_side=seed)
        return PScan(p, sort_var)

    def _make_join(self, left: Phys, p: A.TriplePattern, jv: int, est: float) -> Phys:
        right: Phys = self._leaf(p, jv)
        right.est_rows = self._pattern_card(p)
        left_sorted = phys_sorted_by(left) == jv
        if not left_sorted:
            if (
                self.join_strategy != "hash"
                and left.est_rows <= 4096
                and isinstance(left, (PScan, PFilter))
            ):
                # small unsorted left: lookup-join into the scan instead
                if phys_sorted_by(right) != jv:
                    s = PSort(right, jv)
                    s.est_rows = right.est_rows
                    right = s
                out = PLookupJoin(probe=right, build=left, var=jv)
                out.est_rows = est
                return out
            # unsorted mid-plan input: hash-join it against the pattern
            # when that beats re-sorting it (DESIGN.md §11) — the probe
            # side streams unsorted, only the pattern is materialized
            if self._choose_join_strategy(left, right, jv, est) == "hash":
                shared = tuple(
                    v for v in phys_vars(left) if v in phys_vars(right)
                )
                out = PHashJoin(probe=left, build=right, keys=shared)
                out.est_rows = est
                return out
            left = PSort(left, jv)
            left.est_rows = left.child.est_rows
        if phys_sorted_by(right) != jv:
            s = PSort(right, jv)
            s.est_rows = right.est_rows
            right = s
        join = PMergeJoin(left, right, jv)
        join.est_rows = est
        join.amplifying = est > 4 * max(left.est_rows, right.est_rows)
        return join

    # -- generic binary joins (OPTIONAL / MINUS / subplans) -------------------------------

    def _binary_join_estimate(
        self, left: Phys, right: Phys, jv: int, mode: str
    ) -> float:
        """Output estimate for a generic binary join, flowing through the
        stats object so the hash-vs-merge choice below prices output cost
        from the same number the plan reports. semi/anti estimates use the
        containment-based semi-join selectivity (NOT the old flat
        left * 0.5, which ignored the right side entirely)."""
        d_l = self._distinct_estimate(left, jv)
        d_r = self._distinct_estimate(right, jv)
        card_l = max(int(left.est_rows), 1)
        card_r = max(int(right.est_rows), 1)
        if mode in ("semi", "anti", "not_exists"):
            return self.stats.semi_join_cardinality(
                card_l, d_l, d_r, anti=mode != "semi"
            )
        est = self.stats.join_cardinality(card_l, card_r, d_l, d_r)
        if mode == "left_outer":
            # a left join emits at least one row per left row
            est = max(est, left.est_rows)
        return est

    def _choose_join_strategy(
        self, left: Phys, right: Phys, jv: int, est: float
    ) -> str:
        """Sort+merge vs radix-hash (DESIGN.md §11 strategy table). Merge
        pays one PSort per unsorted input plus a linear pass; hash pays a
        constant-factor build over the right side and streams the probe
        side unsorted. With both inputs already sorted the merge join is
        nearly free and always wins."""
        if self.join_strategy in ("hash", "merge"):
            return self.join_strategy
        l_sorted = phys_sorted_by(left) == jv
        r_sorted = phys_sorted_by(right) == jv
        if l_sorted and r_sorted:
            return "merge"
        ln = max(left.est_rows, 1.0)
        rn = max(right.est_rows, 1.0)
        merge_cost = ln + rn + est
        if not l_sorted:
            merge_cost += _sort_cost(ln)
        if not r_sorted:
            merge_cost += _sort_cost(rn)
        hash_cost = _HASH_BUILD_FACTOR * rn + ln + est
        if (
            self.memory_budget is not None
            and rn * max(len(phys_vars(right)), 1) * 4.0 > self.memory_budget
        ):
            hash_cost += _GRACE_SPILL_FACTOR * (rn + ln)
        return "hash" if hash_cost < merge_cost else "merge"

    def _plan_binary_join(
        self,
        lnode: A.PlanNode,
        rnode: A.PlanNode,
        mode: str,
        expr: Optional[A.Expr],
    ) -> Phys:
        left = self._plan(lnode)
        right = self._plan(rnode)
        lv, rv = phys_vars(left), phys_vars(right)
        shared = [v for v in lv if v in rv]
        if not shared:
            if mode == "inner":
                out = PCross(left, right)
                out.est_rows = left.est_rows * right.est_rows
                return out
            if mode == "anti":
                # MINUS with disjoint domains keeps everything (§8.3.3:
                # no shared variable -> every pair is incompatible)
                return left
            if mode == "not_exists":
                # NOT EXISTS diverges from MINUS here: any inner solution
                # removes ALL left rows. The degenerate constant-key anti
                # hash join is exactly that shape.
                out = PHashJoin(left, right, (), mode="anti")
                out.est_rows = left.est_rows * 0.5
                return out
            # left_outer without shared vars: SPARQL left join must keep
            # every left row even when the optional side is empty — the
            # NULL-extending constant-key hash join, not a plain PCross
            # (which returns zero rows on an empty right side)
            out = PHashJoin(
                left, right, (), mode="left_outer", post_filter=expr,
                post_program=self.compile_expr(expr, "mask"),
            )
            out.est_rows = max(left.est_rows, left.est_rows * right.est_rows)
            return out
        jv = shared[0]
        # prefer a shared var an input is already sorted by
        for v in shared:
            if phys_sorted_by(left) == v or phys_sorted_by(right) == v:
                jv = v
                break
        est = self._binary_join_estimate(left, right, jv, mode)
        join_mode = "anti" if mode == "not_exists" else mode
        if self.feedback is not None:
            annotate_fingerprints(left, self._canon)
            annotate_fingerprints(right, self._canon)
            est = self._feedback_est(
                _join_fp(join_mode, expr, left, right, self._canon)[0], est
            )
        if self._choose_join_strategy(left, right, jv, est) == "hash":
            out = PHashJoin(
                left, right, tuple(shared), mode=join_mode, post_filter=expr,
                post_program=self.compile_expr(expr, "mask"),
            )
            out.est_rows = est
            return out
        if phys_sorted_by(left) != jv:
            s = PSort(left, jv)
            s.est_rows = left.est_rows
            left = s
        if phys_sorted_by(right) != jv:
            s = PSort(right, jv)
            s.est_rows = right.est_rows
            right = s
        out = PMergeJoin(
            left, right, jv, mode=join_mode, post_filter=expr,
            post_program=self.compile_expr(expr, "mask"),
        )
        out.est_rows = est
        return out


def explain(n: Phys, var_table: Optional[A.VarTable] = None, indent: int = 0) -> str:
    pad = "  " * indent

    def estf(node) -> str:
        # ``(source=feedback)`` marks history-overridden estimates; plans
        # built without a feedback store render byte-identically to pre-§14
        src = (
            "(source=feedback)"
            if getattr(node, "est_source", "stats") == "feedback"
            else ""
        )
        return f"est={node.est_rows:.0f}{src}"

    def vname(v):
        return f"?{var_table.name(v)}" if var_table else f"?v{v}"

    def sip_in(node) -> str:
        if not getattr(node, "sip", ()):
            return ""
        anns = ", ".join(
            f"SipFilter({vname(f.var)}#{f.sid})" for f in node.sip
        )
        return f" sip=[{anns}]"

    def sip_out(node) -> str:
        if not getattr(node, "sip_exports", ()):
            return ""
        anns = ", ".join(f"{vname(f.var)}#{f.sid}" for f in node.sip_exports)
        return f" sip-export=[{anns}]"

    if isinstance(n, PScan):
        t = []
        for sl in (n.pattern.s, n.pattern.p, n.pattern.o):
            t.append(vname(sl.id) if isinstance(sl, A.V) else str(sl.term))
        return f"{pad}Scan({', '.join(t)}) {estf(n)}{sip_in(n)}"
    if isinstance(n, PPathExpand):
        from repro.core.paths.expr import path_repr

        s = vname(n.pattern.s.id) if isinstance(n.pattern.s, A.V) else str(n.pattern.s.term)
        o = vname(n.pattern.o.id) if isinstance(n.pattern.o, A.V) else str(n.pattern.o.term)
        return (
            f"{pad}PathExpand({s}, {path_repr(n.pattern.expr)}, {o}) "
            f"[seed={n.seed_side}] {estf(n)}{sip_in(n)}"
        )
    if isinstance(n, PSort):
        return f"{pad}Sort({vname(n.var)})\n" + explain(n.child, var_table, indent + 1)
    if isinstance(n, PMergeJoin):
        amp = " AMPLIFYING" if n.amplifying else ""
        if n.adaptive_ok:
            amp += " adaptive"
        return (
            f"{pad}MergeJoin({vname(n.var)}, {n.mode}){amp} "
            f"{estf(n)}{sip_out(n)}\n"
            + explain(n.left, var_table, indent + 1)
            + "\n"
            + explain(n.right, var_table, indent + 1)
        )
    if isinstance(n, PLookupJoin):
        return (
            f"{pad}LookupJoin({vname(n.var)}, {n.mode}) {estf(n)}\n"
            + explain(n.probe, var_table, indent + 1)
            + "\n"
            + explain(n.build, var_table, indent + 1)
        )
    if isinstance(n, PHashJoin):
        keys = ", ".join(vname(k) for k in n.keys) if n.keys else "<const>"
        grace = (
            f" grace parts={n.grace_parts}"
            f" spill≈{n.exp_spill_bytes / 1e6:.1f}MB"
            if n.grace
            else ""
        )
        return (
            f"{pad}HashJoin({keys}, {n.mode}){grace} {estf(n)}{sip_out(n)}\n"
            + explain(n.probe, var_table, indent + 1)
            + "\n"
            + explain(n.build, var_table, indent + 1)
        )
    if isinstance(n, PCross):
        return (
            f"{pad}Cross {estf(n)}\n"
            + explain(n.left, var_table, indent + 1)
            + "\n"
            + explain(n.right, var_table, indent + 1)
        )
    if isinstance(n, PFilter):
        return f"{pad}Filter {estf(n)}\n" + explain(n.child, var_table, indent + 1)
    if isinstance(n, PHaving):
        return f"{pad}Having {estf(n)}\n" + explain(n.child, var_table, indent + 1)
    if isinstance(n, PExtend):
        return f"{pad}Bind({vname(n.var)})\n" + explain(n.child, var_table, indent + 1)
    if isinstance(n, PProject):
        return f"{pad}Project\n" + explain(n.child, var_table, indent + 1)
    if isinstance(n, PDistinct):
        if n.grace:
            kind = f"partitioned parts={n.grace_parts}"
        else:
            kind = "streaming" if n.streaming_var is not None else "sort"
        return f"{pad}Distinct[{kind}]\n" + explain(n.child, var_table, indent + 1)
    if isinstance(n, PGroup):
        if n.grace:
            kind = f"partitioned parts={n.grace_parts}"
        else:
            kind = "streaming" if n.streaming else "sort"
        return f"{pad}Group[{kind}]\n" + explain(n.child, var_table, indent + 1)
    if isinstance(n, POrderBy):
        return f"{pad}OrderBy\n" + explain(n.child, var_table, indent + 1)
    if isinstance(n, PSlice):
        return f"{pad}Slice\n" + explain(n.child, var_table, indent + 1)
    if isinstance(n, PUnion):
        return (
            f"{pad}Union\n"
            + explain(n.left, var_table, indent + 1)
            + "\n"
            + explain(n.right, var_table, indent + 1)
        )
    return f"{pad}{type(n).__name__}"
