"""Vectorized SPARQL expression evaluation over columnar batches.

Two evaluation regimes (paper §2.2.1): code-only expressions (equality /
inequality between variables or against constants) run directly on the
int32 dictionary codes; value expressions (<, <=, arithmetic) decode
operands through the dictionary's float64 numeric side-array with one
vectorized take. Rows whose operands are non-numeric or NULL evaluate to
an 'error' (SPARQL semantics) and are excluded by FILTER.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.algebra import And, Arith, Bound, Cmp, Expr, Lit, Not, Or, VarRef
from repro.core.batch import NULL_ID, ColumnBatch
from repro.core.dictionary import Dictionary, _numeric_value

_CMP = {
    "=": np.equal,
    "!=": np.not_equal,
    "<": np.less,
    "<=": np.less_equal,
    ">": np.greater,
    ">=": np.greater_equal,
}
_ARITH = {"+": np.add, "-": np.subtract, "*": np.multiply, "/": np.divide}


def _codes(e: Expr, batch: ColumnBatch, d: Optional[Dictionary]) -> Optional[np.ndarray]:
    """int32 codes for a leaf, or None if not a code-addressable leaf."""
    if isinstance(e, VarRef):
        return batch.column(e.var)
    if isinstance(e, Lit):
        if d is None:
            raise ValueError("dictionary required for constant in expression")
        tid = d.lookup(e.value)
        n = batch.n_rows
        return np.full(n, NULL_ID if tid is None else tid, dtype=np.int32)
    return None


def _numeric(e: Expr, batch: ColumnBatch, d: Optional[Dictionary]) -> Tuple[np.ndarray, np.ndarray]:
    """(values float64, valid bool) for an arithmetic/value expression."""
    n = batch.n_rows
    if isinstance(e, VarRef):
        codes = batch.column(e.var)
        assert d is not None, "dictionary required for value comparisons"
        vals = d.numeric_of(codes)
        return vals, ~np.isnan(vals)
    if isinstance(e, Lit):
        v = _numeric_value(e.value)
        return np.full(n, v), np.full(n, not np.isnan(v), dtype=bool)
    if isinstance(e, Arith):
        lv, lok = _numeric(e.lhs, batch, d)
        rv, rok = _numeric(e.rhs, batch, d)
        with np.errstate(divide="ignore", invalid="ignore"):
            out = _ARITH[e.op](lv, rv)
        ok = lok & rok & np.isfinite(out)
        return out, ok
    raise TypeError(f"not a value expression: {type(e)}")


def eval_expr_mask(
    e: Expr, batch: ColumnBatch, d: Optional[Dictionary] = None
) -> np.ndarray:
    """Boolean mask over the batch capacity: True where the expression is
    true (SPARQL 'error' rows are False). ANDed with the batch mask by the
    caller (selection-vector update, paper §3.1)."""
    n = batch.n_rows
    m = np.zeros(batch.capacity, dtype=bool)
    m[:n] = _eval(e, batch, d)
    return m


def _eval(e: Expr, batch: ColumnBatch, d: Optional[Dictionary]) -> np.ndarray:
    n = batch.n_rows
    if isinstance(e, And):
        out = np.ones(n, dtype=bool)
        for t in e.terms:
            out &= _eval(t, batch, d)
        return out
    if isinstance(e, Or):
        out = np.zeros(n, dtype=bool)
        for t in e.terms:
            out |= _eval(t, batch, d)
        return out
    if isinstance(e, Not):
        # NOT(error) is error -> False either way for filtering purposes of
        # pure boolean terms; we approximate by complementing
        return ~_eval(e.term, batch, d)
    if isinstance(e, Bound):
        return batch.column(e.var) != NULL_ID
    if isinstance(e, Cmp):
        if e.op in ("=", "!="):
            lc = _codes(e.lhs, batch, d)
            rc = _codes(e.rhs, batch, d)
            if lc is not None and rc is not None:
                ok = (lc != NULL_ID) & (rc != NULL_ID)
                return _CMP[e.op](lc, rc) & ok
        lv, lok = _numeric(e.lhs, batch, d)
        rv, rok = _numeric(e.rhs, batch, d)
        return _CMP[e.op](lv, rv) & lok & rok
    if isinstance(e, (VarRef, Lit)):
        # effective boolean value of a term: non-null / non-zero
        c = _codes(e, batch, d)
        return c != NULL_ID
    raise TypeError(f"unsupported expression node {type(e)}")


def eval_expr_values(
    e: Expr, batch: ColumnBatch, d: Dictionary
) -> Tuple[np.ndarray, np.ndarray]:
    """Numeric values for BIND (Extend): returns (float64 values, valid)."""
    return _numeric(e, batch, d)
