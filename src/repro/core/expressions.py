"""Interpreted per-node expression evaluation over columnar batches.

This is the legacy tree walk: each algebra node evaluates recursively with
numpy per node (strings per *row*) — the baseline the vectorized
expression VM (core/exprs/, DESIGN.md §9) is measured against, and the
expression engine of the row-based executor. Two evaluation regimes
(paper §2.2.1): code-only expressions (equality / inequality between
variables or against constants) run directly on the int32 dictionary
codes; value expressions (<, <=, arithmetic) decode operands through the
dictionary's float64 numeric side-array with one vectorized take.

Three-valued SPARQL semantics are exact and must match the VM bit for bit
(tests/test_exprs.py pins parity): every boolean node evaluates to
(value, error) pairs. Historical bugs fixed with the error channel:
``NOT(error)`` previously complemented (it must stay error) and
``true || error`` previously produced error (a definite true dominates).
Builtin calls (algebra.Func) share their per-term semantics with the VM
through core/exprs/terms.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.algebra import (
    And, Arith, Bound, Cmp, Expr, Func, Lit, Not, Or, VarRef,
)
from repro.core.batch import NULL_ID, ColumnBatch
from repro.core.dictionary import Dictionary, _numeric_value
from repro.core.exprs import terms as T

_CMP = {
    "=": np.equal,
    "!=": np.not_equal,
    "<": np.less,
    "<=": np.less_equal,
    ">": np.greater,
    ">=": np.greater_equal,
}
_ARITH = {"+": np.add, "-": np.subtract, "*": np.multiply, "/": np.divide}

BoolErr = Tuple[np.ndarray, np.ndarray]  # (value bool, error bool) per row


def _codes(e: Expr, batch: ColumnBatch, d: Optional[Dictionary]) -> Optional[np.ndarray]:
    """int32 codes for a leaf, or None if not a code-addressable leaf."""
    if isinstance(e, VarRef):
        return batch.column(e.var)
    if isinstance(e, Lit):
        if d is None:
            raise ValueError("dictionary required for constant in expression")
        tid = d.lookup(e.value)
        n = batch.n_rows
        # a term absent from the dictionary is a real term that matches no
        # row: use a fresh sentinel code (== len(d)), NOT the NULL id —
        # 'bound but unequal' is false, never an error
        return np.full(n, len(d) if tid is None else tid, dtype=np.int32)
    return None


def _numeric(e: Expr, batch: ColumnBatch, d: Optional[Dictionary]) -> Tuple[np.ndarray, np.ndarray]:
    """(values float64, valid bool) for a value-context expression."""
    n = batch.n_rows
    if isinstance(e, VarRef):
        codes = batch.column(e.var)
        assert d is not None, "dictionary required for value comparisons"
        vals = d.numeric_of(codes)
        return vals, ~np.isnan(vals)
    if isinstance(e, Lit):
        v = _numeric_value(e.value)
        return np.full(n, v), np.full(n, np.isfinite(v), dtype=bool)
    if isinstance(e, Arith):
        lv, lok = _numeric(e.lhs, batch, d)
        rv, rok = _numeric(e.rhs, batch, d)
        with np.errstate(divide="ignore", invalid="ignore"):
            out = _ARITH[e.op](lv, rv)
        ok = lok & rok & np.isfinite(out)
        return out, ok
    if isinstance(e, Func) and e.name == "if":
        cv, cerr = _eval(e.args[0], batch, d)
        tv, tok = _numeric(e.args[1], batch, d)
        fv, fok = _numeric(e.args[2], batch, d)
        vals = np.where(cv, tv, fv)
        ok = ~cerr & np.where(cv, tok, fok)
        return vals, ok
    if isinstance(e, Func) and e.name == "coalesce":
        vals, ok = _numeric(e.args[0], batch, d)
        for arg in e.args[1:]:
            av, aok = _numeric(arg, batch, d)
            vals = np.where(ok, vals, av)
            ok = ok | aok
        return vals, ok
    # boolean-shaped node in value context (BIND(?a > ?b AS ?x)): 0/1
    v, err = _eval(e, batch, d)
    return v.astype(np.float64), ~err


def eval_expr_mask(
    e: Expr, batch: ColumnBatch, d: Optional[Dictionary] = None
) -> np.ndarray:
    """Boolean mask over the batch capacity: True where the expression is
    (three-valued) true — 'error' rows are excluded. ANDed with the batch
    mask by the caller (selection-vector update, paper §3.1)."""
    n = batch.n_rows
    m = np.zeros(batch.capacity, dtype=bool)
    v, err = _eval(e, batch, d)
    m[:n] = v & ~err
    return m


def _tri_rows(
    name: str, args: Tuple, e: Expr, batch: ColumnBatch, d: Optional[Dictionary]
) -> BoolErr:
    """Per-row trinary term test — the interpreted (per-row decode)
    counterpart of the VM's dictionary-domain tables."""
    assert d is not None, "dictionary required for term predicates"
    fn = T.term_predicate(name, args)
    if isinstance(e, Lit):  # constant subject: one term, not a column
        tri = fn(e.value)
        full = np.full(batch.n_rows, True)
        return full & (tri == T.TRUE), full & (tri == T.ERROR)
    codes = _codes(e, batch, d)
    if codes is None:
        raise TypeError(f"{name} subject must be a term (variable/constant)")
    n_terms = len(d)
    tri = np.fromiter(
        (
            T.ERROR if c < 0 else (T.FALSE if c >= n_terms else fn(d.decode(int(c))))
            for c in codes
        ),
        dtype=np.int32,
        count=len(codes),
    )
    return tri == T.TRUE, tri == T.ERROR


def _eval(e: Expr, batch: ColumnBatch, d: Optional[Dictionary]) -> BoolErr:
    """Boolean-context evaluation: (value, error) row pairs."""
    n = batch.n_rows
    if isinstance(e, And):
        # Kleene: a row errs iff some term errs and no term is definitely
        # false (false && error == false)
        v = np.ones(n, dtype=bool)
        any_err = np.zeros(n, dtype=bool)
        any_false = np.zeros(n, dtype=bool)
        for t in e.terms:
            tv, terr = _eval(t, batch, d)
            any_err |= terr
            any_false |= ~tv & ~terr
            v &= tv & ~terr
        return v, any_err & ~any_false
    if isinstance(e, Or):
        any_true = np.zeros(n, dtype=bool)
        any_err = np.zeros(n, dtype=bool)
        for t in e.terms:
            tv, terr = _eval(t, batch, d)
            any_true |= tv & ~terr
            any_err |= terr
        # a definite true dominates error (true || error == true)
        return any_true, any_err & ~any_true
    if isinstance(e, Not):
        v, err = _eval(e.term, batch, d)
        # NOT(error) stays error
        return ~v & ~err, err
    if isinstance(e, Bound):
        return batch.column(e.var) != NULL_ID, np.zeros(n, dtype=bool)
    if isinstance(e, Cmp):
        if e.op in ("=", "!="):
            if isinstance(e.lhs, Lit) and isinstance(e.rhs, Lit):
                # term identity folds directly — dictionary-absent terms
                # must not collide through the shared sentinel code
                v = (e.lhs.value == e.rhs.value) == (e.op == "=")
                return np.full(n, v), np.zeros(n, dtype=bool)
            lc = _codes(e.lhs, batch, d)
            rc = _codes(e.rhs, batch, d)
            if lc is not None and rc is not None:
                err = (lc == NULL_ID) | (rc == NULL_ID)
                return _CMP[e.op](lc, rc) & ~err, err
        lv, lok = _numeric(e.lhs, batch, d)
        rv, rok = _numeric(e.rhs, batch, d)
        ok = lok & rok
        return _CMP[e.op](lv, rv) & ok, ~ok
    if isinstance(e, Func):
        return _eval_func(e, batch, d)
    if isinstance(e, (VarRef, Lit)):
        # effective boolean value of a term (SPARQL 17.2.2): numbers by
        # value, strings by emptiness, IRIs / unbound are type errors
        return _tri_rows("ebv", (), e, batch, d)
    if isinstance(e, Arith):
        v, ok = _numeric(e, batch, d)
        return (v != 0) & ok, ~ok
    raise TypeError(f"unsupported expression node {type(e)}")


def _eval_func(e: Func, batch: ColumnBatch, d: Optional[Dictionary]) -> BoolErr:
    n = batch.n_rows
    name = e.name
    if name == "if":
        cv, cerr = _eval(e.args[0], batch, d)
        tv, terr = _eval(e.args[1], batch, d)
        fv, ferr = _eval(e.args[2], batch, d)
        v = np.where(cv, tv, fv)
        err = cerr | np.where(cv, terr, ferr)
        return v & ~err, err
    if name == "coalesce":
        v, err = _eval(e.args[0], batch, d)
        for arg in e.args[1:]:
            av, aerr = _eval(arg, batch, d)
            v = np.where(err, av, v)
            err = err & aerr
        return v & ~err, err
    if name == "in":
        # expr IN (list) == chained || of equalities (Kleene error rules)
        any_true = np.zeros(n, dtype=bool)
        any_err = np.zeros(n, dtype=bool)
        for item in e.args[1:]:
            iv, ierr = _eval(Cmp("=", e.args[0], item), batch, d)
            any_true |= iv & ~ierr
            any_err |= ierr
        return any_true, any_err & ~any_true
    if name == "sameterm":
        if isinstance(e.args[0], Lit) and isinstance(e.args[1], Lit):
            v = e.args[0].value == e.args[1].value
            return np.full(n, v), np.zeros(n, dtype=bool)
        lc = _codes(e.args[0], batch, d)
        rc = _codes(e.args[1], batch, d)
        if lc is None or rc is None:
            raise TypeError("sameTerm arguments must be terms")
        err = (lc == NULL_ID) | (rc == NULL_ID)
        return (lc == rc) & ~err, err
    for a in e.args[1:]:
        if not isinstance(a, Lit):
            raise TypeError(f"{name} pattern arguments must be constants")
    return _tri_rows(name, tuple(a.value for a in e.args[1:]), e.args[0], batch, d)


def eval_expr_values(
    e: Expr, batch: ColumnBatch, d: Dictionary
) -> Tuple[np.ndarray, np.ndarray]:
    """Numeric values for BIND (Extend): returns (float64 values, valid)."""
    return _numeric(e, batch, d)
