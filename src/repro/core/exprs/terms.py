"""Per-term semantics shared by the expression VM and the legacy tree walk.

SPARQL term tests and string predicates are *functions of the term alone*
(not of the row), so the VM evaluates them once per distinct dictionary
entry and broadcasts the result to rows with one vectorized ``take``
(DESIGN.md §9.4). The legacy interpreted walk applies the same per-term
functions row-by-row. Sharing this module is what guarantees the two
evaluation regimes agree bit-for-bit.

Every predicate returns trinary {FALSE, TRUE, ERROR}: SPARQL builtins
raise a type error on non-string / non-matching operands, and three-valued
logic must see that as 'error', not 'false' (SparqLog's EBV tables).

Term shapes in this engine (core/dictionary.py): python int/float are
numeric literals; a str starting with '"' is a string literal (quotes kept
in the stored term, typed-literal shorthand '"lex"^^dt' allowed); any
other str is an IRI / prefixed name.
"""

from __future__ import annotations

import re
from typing import Callable, Tuple

from repro.core.dictionary import Term

FALSE, TRUE, ERROR = 0, 1, 2


def _as_tri(b: bool) -> int:
    return TRUE if b else FALSE


def is_string_literal(term: Term) -> bool:
    return isinstance(term, str) and term.startswith('"')


def is_iri(term: Term) -> bool:
    return isinstance(term, str) and not term.startswith('"')


def lexical(term: Term) -> str:
    """Lexical form of a string literal (quotes / datatype tag stripped)."""
    assert isinstance(term, str)
    end = term.rfind('"')
    return term[1:end] if end > 0 else term[1:]


def _str_arg(term: Term) -> str:
    """Argument coercion for string predicates: literal lexical form only;
    numbers and IRIs are a type error (strict SPARQL 17.4.3)."""
    if not is_string_literal(term):
        raise TypeError(term)
    return lexical(term)


def _const_str(arg: Term) -> str:
    """Constant pattern argument: accept a quoted literal or a bare str."""
    if isinstance(arg, str):
        return lexical(arg) if arg.startswith('"') else arg
    raise TypeError(f"string constant expected, got {arg!r}")


def ebv(term: Term) -> int:
    """Effective boolean value of a term (SPARQL 17.2.2): numbers by value
    (0 and NaN are false), string literals by emptiness, IRIs have no EBV
    (type error)."""
    if isinstance(term, bool):
        return _as_tri(term)
    if isinstance(term, (int, float)):
        return _as_tri(term == term and term != 0)  # NaN -> false per xsd
    if is_string_literal(term):
        return _as_tri(len(lexical(term)) > 0)
    return ERROR


def term_predicate(name: str, args: Tuple[Term, ...]) -> Callable[[Term], int]:
    """The trinary per-term function for a builtin test. ``args`` are the
    constant arguments (pattern strings, regex flags); the term being
    tested is the callable's input."""
    if name == "ebv":
        return ebv
    if name == "isnumeric":
        return lambda t: _as_tri(isinstance(t, (int, float)))
    if name == "isiri":
        return lambda t: _as_tri(is_iri(t))
    if name == "isliteral":
        return lambda t: _as_tri(
            isinstance(t, (int, float)) or is_string_literal(t)
        )
    if name in ("strstarts", "strends", "contains"):
        pat = _const_str(args[0])

        def _sp(t: Term, name=name, pat=pat) -> int:
            try:
                s = _str_arg(t)
            except TypeError:
                return ERROR
            if name == "strstarts":
                return _as_tri(s.startswith(pat))
            if name == "strends":
                return _as_tri(s.endswith(pat))
            return _as_tri(pat in s)

        return _sp
    if name == "regex":
        flags = 0
        if len(args) > 1 and "i" in _const_str(args[1]):
            flags |= re.IGNORECASE
        rx = re.compile(_const_str(args[0]), flags)

        def _re(t: Term, rx=rx) -> int:
            try:
                s = _str_arg(t)
            except TypeError:
                return ERROR
            return _as_tri(rx.search(s) is not None)

        return _re
    raise ValueError(f"unknown term predicate {name!r}")
