"""Bytecode format of the vectorized expression VM (DESIGN.md §9).

A compiled expression is a flat, register-based, straight-line program: a
tuple of ``(opcode, dst, a, b, c)`` int32 instructions plus the static
input plan. Registers are *columns*: the executor holds a value plane
(float64 on the numpy oracle, float32 on the jnp / Pallas backends) and a
parallel boolean **error plane** — SPARQL's three-valued logic carried
explicitly, so ``!``/``&&``/``||``/``COALESCE``/``IF`` are exact
(true / false / error per row).

Operand domains follow the paper's §2.2.1 split:

  * code-domain ops (EQ_CODE, EQ_CONST, BOUND, TEST) read int32 dictionary
    codes straight from the input block ``icols`` — equality, bound-ness,
    term tests and dictionary-domain string predicates never decode;
  * value-domain ops (LOAD_NUM, arithmetic, ordered comparisons) run over
    the pre-decoded float block ``fcols`` (one vectorized ``take`` through
    the dictionary's numeric side-array per referenced column).

Booleans live in the value plane as 0.0/1.0, so logic ops and IF/COALESCE
are plane-agnostic. The program is a frozen, hashable dataclass: it is the
static argument that specializes the jit'd jnp reference and the fused
Pallas kernel (one compiled kernel per program, one dispatch per batch).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.dictionary import Term

# ---------------------------------------------------------------------------
# opcodes
# ---------------------------------------------------------------------------

(
    LOAD_NUM,    # dst <- fcols[a]; err = isnan
    LOAD_CONST,  # dst <- consts[a]; err = non-finite const (folded 1/0)
    BOUND,       # dst <- icols[a] != NULL; err = false
    EQ_CODE,     # dst <- icols[a] == icols[b]; err = either NULL
    NE_CODE,     # dst <- icols[a] != icols[b]; err = either NULL
    EQ_CONST,    # dst <- icols[a] == b (code constant); err = icols[a] NULL
    NE_CONST,    # dst <- icols[a] != b; err = icols[a] NULL
    TEST,        # dst <- icols[a] (trinary pred column); err also on icols[b] NULL
    ADD,         # dst <- r[a] + r[b]; err propagates, nonfinite -> err
    SUB,
    MUL,
    DIV,         # division by zero / nonfinite -> err (xsd:decimal semantics)
    LT,          # dst <- r[a] < r[b]; err propagates
    LE,
    GT,
    GE,
    EQ_NUM,      # value-domain equality (computed operands)
    NE_NUM,
    NOT,         # dst <- !truthy(r[a]); err = r[a].err
    AND,         # Kleene: false dominates error
    OR,          # Kleene: true dominates error
    IF,          # dst <- truthy(r[a]) ? r[b] : r[c]; cond error -> error
    COALESCE,    # dst <- r[a] unless its row errs, else r[b]
) = range(23)

OP_NAMES = (
    "load_num", "load_const", "bound", "eq_code", "ne_code", "eq_const",
    "ne_const", "test", "add", "sub", "mul", "div", "lt", "le", "gt", "ge",
    "eq_num", "ne_num", "not", "and", "or", "if", "coalesce",
)

# instruction classes (used by the executor and the disassembler)
CODE_OPS = frozenset((BOUND, EQ_CODE, NE_CODE, EQ_CONST, NE_CONST, TEST))
ARITH_OPS = {ADD: "+", SUB: "-", MUL: "*", DIV: "/"}
CMP_OPS = {LT: "<", LE: "<=", GT: ">", GE: ">=", EQ_NUM: "=", NE_NUM: "!="}

Instr = Tuple[int, int, int, int, int]  # (op, dst, a, b, c)


@dataclasses.dataclass(frozen=True)
class TableSpec:
    """A dictionary-domain predicate input: ``func(args...)`` evaluated once
    per distinct term (terms.term_predicate), broadcast to rows with one
    take. Materializes as a trinary {0,1,2} int32 row of ``icols``."""

    func: str
    args: Tuple[Term, ...]
    var: int  # the tested variable (its code column carries NULL-ness)


@dataclasses.dataclass(frozen=True)
class ExprProgram:
    """A compiled expression. Frozen + hashable: jit static argument.

    Input block layout (built per batch by vm.prepare_inputs):
      icols[0 : len(code_vars)]              int32 code columns, NULL = -1;
      icols[len(code_vars) : + len(tables)]  trinary predicate columns;
      fcols[0 : len(num_vars)]               float numeric decodes (NaN =
                                             non-numeric or NULL).
    """

    instrs: Tuple[Instr, ...]
    n_regs: int
    out_reg: int
    consts: Tuple[float, ...]
    code_vars: Tuple[int, ...]
    num_vars: Tuple[int, ...]
    tables: Tuple[TableSpec, ...]
    source_ops: int  # pre-folding/CSE node count of the algebra tree

    @property
    def n_icols(self) -> int:
        return len(self.code_vars) + len(self.tables)

    @property
    def n_fcols(self) -> int:
        return len(self.num_vars)

    def vars(self) -> Tuple[int, ...]:
        out = self.code_vars + tuple(t.var for t in self.tables) + self.num_vars
        return tuple(dict.fromkeys(out))


def disassemble(prog: ExprProgram) -> str:
    """Human-readable listing (tests pin compiler output against this)."""
    lines = []
    for op, dst, a, b, c in prog.instrs:
        nm = OP_NAMES[op]
        if op == LOAD_CONST:
            lines.append(f"r{dst} = const {prog.consts[a]}")
        elif op == LOAD_NUM:
            lines.append(f"r{dst} = num ?v{prog.num_vars[a]}")
        elif op == BOUND:
            lines.append(f"r{dst} = bound ?v{prog.code_vars[a]}")
        elif op in (EQ_CODE, NE_CODE):
            s = "==" if op == EQ_CODE else "!="
            lines.append(
                f"r{dst} = code ?v{prog.code_vars[a]} {s} ?v{prog.code_vars[b]}"
            )
        elif op in (EQ_CONST, NE_CONST):
            s = "==" if op == EQ_CONST else "!="
            lines.append(f"r{dst} = code ?v{prog.code_vars[a]} {s} #{b}")
        elif op == TEST:
            t = prog.tables[a - len(prog.code_vars)]
            lines.append(f"r{dst} = {t.func}{t.args} ?v{t.var}")
        elif op in ARITH_OPS:
            lines.append(f"r{dst} = r{a} {ARITH_OPS[op]} r{b}")
        elif op in CMP_OPS:
            lines.append(f"r{dst} = r{a} {CMP_OPS[op]} r{b}")
        elif op == NOT:
            lines.append(f"r{dst} = !r{a}")
        elif op in (AND, OR):
            lines.append(f"r{dst} = r{a} {'&&' if op == AND else '||'} r{b}")
        elif op == IF:
            lines.append(f"r{dst} = if r{a} then r{b} else r{c}")
        elif op == COALESCE:
            lines.append(f"r{dst} = coalesce(r{a}, r{b})")
        else:  # pragma: no cover - exhaustive above
            lines.append(f"r{dst} = {nm} {a} {b} {c}")
    lines.append(f"ret r{prog.out_reg}  [{prog.n_regs} regs]")
    return "\n".join(lines)
