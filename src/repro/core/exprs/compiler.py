"""algebra.Expr -> ExprProgram lowering (DESIGN.md §9.2).

One pass builds SSA straight-line code with three online optimizations:

  * operand classification — every instruction is pinned to the code
    domain (int32 dictionary codes: equality, BOUND, term tests,
    dictionary-domain string predicates) or the value domain (float
    numeric side-array decodes: arithmetic, ordered comparisons), the
    paper's §2.2.1 split, so the executor never decodes a column that is
    only ever compared by identity;
  * constant folding — a peephole over the emitted stream: arithmetic /
    comparisons whose operands are both constants collapse to LOAD_CONST
    (non-finite results keep SPARQL error semantics: LOAD_CONST errs on
    non-finite values, so folded 1/0 still evaluates to 'error');
  * common-subexpression elimination — emission is hash-consed on the
    full instruction, so syntactically repeated subtrees (the FILTER-dense
    SP²Bench shape) evaluate once per batch.

A final linear-scan pass renames SSA registers onto a minimal register
pool (operands are read before the destination is written, so a register
freed by its last use can be the destination of the same instruction).
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from repro.core import algebra as A
from repro.core.dictionary import Dictionary, _numeric_value
from repro.core.exprs import bytecode as B
from repro.core.exprs import terms as T

# ops eligible for the constant-folding peephole
_FOLD = {
    B.ADD: lambda a, b: a + b,
    B.SUB: lambda a, b: a - b,
    B.MUL: lambda a, b: a * b,
    B.DIV: lambda a, b: a / b if b != 0 else math.inf if a > 0 else -math.inf if a < 0 else math.nan,
    B.LT: lambda a, b: float(a < b),
    B.LE: lambda a, b: float(a <= b),
    B.GT: lambda a, b: float(a > b),
    B.GE: lambda a, b: float(a >= b),
    B.EQ_NUM: lambda a, b: float(a == b),
    B.NE_NUM: lambda a, b: float(a != b),
}

_CMP_TO_OP = {"<": B.LT, "<=": B.LE, ">": B.GT, ">=": B.GE,
              "=": B.EQ_NUM, "!=": B.NE_NUM}
_ARITH_TO_OP = {"+": B.ADD, "-": B.SUB, "*": B.MUL, "/": B.DIV}

# boolean-shaped algebra nodes: their register already holds 0/1
_BOOL_NODES = (A.Cmp, A.And, A.Or, A.Not, A.Bound)
_TEST_FUNCS = frozenset(
    ("isnumeric", "isiri", "isliteral", "strstarts", "strends",
     "contains", "regex")
)


class ExprCompileError(ValueError):
    pass


class _Builder:
    def __init__(self, dictionary: Optional[Dictionary]):
        self.d = dictionary
        self.instrs: List[B.Instr] = []
        self.memo: Dict[B.Instr, int] = {}
        self.const_of: Dict[int, float] = {}  # SSA reg -> known const value
        self.consts: List[float] = []
        self.const_idx: Dict[float, int] = {}
        self.code_vars: List[int] = []
        self.code_idx: Dict[int, int] = {}
        self.num_vars: List[int] = []
        self.num_idx: Dict[int, int] = {}
        self.tables: List[B.TableSpec] = []
        self.table_idx: Dict[B.TableSpec, int] = {}

    # -- input slots -------------------------------------------------------

    def _code_col(self, var: int) -> int:
        if var not in self.code_idx:
            self.code_idx[var] = len(self.code_vars)
            self.code_vars.append(var)
        return self.code_idx[var]

    def _num_col(self, var: int) -> int:
        if var not in self.num_idx:
            self.num_idx[var] = len(self.num_vars)
            self.num_vars.append(var)
        return self.num_idx[var]

    def _table_col(self, spec: B.TableSpec) -> int:
        """Absolute icols index of a predicate table column (tables sit
        after the code columns; resolved after build in _finish)."""
        if spec not in self.table_idx:
            self.table_idx[spec] = len(self.tables)
            self.tables.append(spec)
        return self.table_idx[spec]

    def _need_dict(self) -> Dictionary:
        if self.d is None:
            raise ExprCompileError(
                "dictionary required to compile constants / term predicates"
            )
        return self.d

    def _encode(self, term) -> int:
        # encode (not lookup): a term absent from the data gets a fresh
        # code that matches no row — 'bound but unequal' is false, not the
        # NULL sentinel (which would wrongly make the comparison an error)
        return self._need_dict().encode(term)

    # -- emission (CSE + constant folding) ---------------------------------

    def emit(self, op: int, a: int = 0, b: int = 0, c: int = 0) -> int:
        key = (op, a, b, c)
        hit = self.memo.get(key)
        if hit is not None:
            return hit
        if op in _FOLD and a in self.const_of and b in self.const_of:
            va, vb = self.const_of[a], self.const_of[b]
            if math.isfinite(va) and math.isfinite(vb):
                return self.const(_FOLD[op](va, vb))
        dst = len(self.instrs)  # SSA: one fresh register per instruction
        self.instrs.append((op, dst, a, b, c))
        self.memo[key] = dst
        if op == B.LOAD_CONST:
            self.const_of[dst] = self.consts[a]
        return dst

    def const(self, v: float) -> int:
        v = float(v)
        if v not in self.const_idx:
            self.const_idx[v] = len(self.consts)
            self.consts.append(v)
        return self.emit(B.LOAD_CONST, self.const_idx[v])

    # -- lowering ----------------------------------------------------------

    def value(self, e: A.Expr) -> int:
        """Lower in value context: the result register holds a float
        (booleans as 0/1, errors in the error plane)."""
        if isinstance(e, A.VarRef):
            return self.emit(B.LOAD_NUM, self._num_col(e.var))
        if isinstance(e, A.Lit):
            return self.const(_numeric_value(e.value))
        if isinstance(e, A.Arith):
            return self.emit(
                _ARITH_TO_OP[e.op], self.value(e.lhs), self.value(e.rhs)
            )
        if isinstance(e, A.Func) and e.name in ("if", "coalesce"):
            return self._func(e, "value")
        if isinstance(e, _BOOL_NODES) or isinstance(e, A.Func):
            return self.boolean(e)  # 0/1 float is a fine value
        raise ExprCompileError(f"cannot lower {type(e).__name__} as a value")

    def boolean(self, e: A.Expr) -> int:
        """Lower in boolean context (EBV applied where SPARQL requires)."""
        if isinstance(e, A.And):
            reg = self.boolean(e.terms[0])
            for t in e.terms[1:]:
                reg = self.emit(B.AND, reg, self.boolean(t))
            return reg
        if isinstance(e, A.Or):
            reg = self.boolean(e.terms[0])
            for t in e.terms[1:]:
                reg = self.emit(B.OR, reg, self.boolean(t))
            return reg
        if isinstance(e, A.Not):
            return self.emit(B.NOT, self.boolean(e.term))
        if isinstance(e, A.Bound):
            return self.emit(B.BOUND, self._code_col(e.var))
        if isinstance(e, A.Cmp):
            return self._cmp(e)
        if isinstance(e, A.Func):
            return self._func(e)
        if isinstance(e, A.VarRef):
            # EBV of a term variable: dictionary-domain table (numbers by
            # value, strings by emptiness, IRIs -> error)
            return self._test("ebv", (), e.var)
        if isinstance(e, A.Lit):
            tri = T.ebv(e.value)
            return self.const(math.nan if tri == T.ERROR else float(tri))
        if isinstance(e, A.Arith):
            return self.value(e)  # numeric EBV: != 0 at the use site
        raise ExprCompileError(f"cannot lower {type(e).__name__} as a boolean")

    # -- comparison classification (the §2.2.1 code/value split) -----------

    def _cmp(self, e: A.Cmp) -> int:
        leaves = isinstance(e.lhs, (A.VarRef, A.Lit)) and isinstance(
            e.rhs, (A.VarRef, A.Lit)
        )
        if e.op in ("=", "!=") and leaves:
            return self._code_eq(e.lhs, e.rhs, negate=e.op == "!=")
        return self.emit(_CMP_TO_OP[e.op], self.value(e.lhs), self.value(e.rhs))

    def _code_eq(self, lhs: A.Expr, rhs: A.Expr, negate: bool) -> int:
        if isinstance(lhs, A.Lit) and isinstance(rhs, A.VarRef):
            lhs, rhs = rhs, lhs
        if isinstance(lhs, A.VarRef) and isinstance(rhs, A.VarRef):
            op = B.NE_CODE if negate else B.EQ_CODE
            a, b = self._code_col(lhs.var), self._code_col(rhs.var)
            if a > b:  # canonical operand order widens CSE hits
                a, b = b, a
            return self.emit(op, a, b)
        if isinstance(lhs, A.VarRef):  # var vs constant term
            op = B.NE_CONST if negate else B.EQ_CONST
            return self.emit(op, self._code_col(lhs.var), self._encode(rhs.value))
        # constant vs constant: term identity folds
        eq = lhs.value == rhs.value
        return self.const(float(eq != negate))

    # -- builtin calls -----------------------------------------------------

    def _test(self, func: str, args: Tuple, var: int) -> int:
        spec = B.TableSpec(func, tuple(args), var)
        self._need_dict()  # tables are built against the dictionary
        tcol = self._table_col(spec)
        return self.emit(B.TEST, tcol, self._code_col(var), 0)

    def _branch(self, e: A.Expr, mode: str) -> int:
        """IF/COALESCE operands follow the *enclosing* context: boolean in
        a FILTER (so a term variable gets its EBV, matching the tree
        walk), value in a BIND."""
        return self.boolean(e) if mode == "mask" else self.value(e)

    def _func(self, e: A.Func, mode: str = "mask") -> int:
        name = e.name
        if name == "if":
            c, t, f = e.args
            return self.emit(
                B.IF, self.boolean(c), self._branch(t, mode), self._branch(f, mode)
            )
        if name == "coalesce":
            reg = self._branch(e.args[0], mode)
            for arg in e.args[1:]:
                reg = self.emit(B.COALESCE, reg, self._branch(arg, mode))
            return reg
        if name == "in":
            # per-item classification, mirroring Cmp('='): a leaf item
            # against a leaf lhs compares by term identity (code domain);
            # only computed items drop to value-domain equality
            lhs, items = e.args[0], e.args[1:]
            lhs_leaf = isinstance(lhs, (A.VarRef, A.Lit))
            regs = []
            lhs_val = None
            for item in items:
                if lhs_leaf and isinstance(item, (A.VarRef, A.Lit)):
                    regs.append(self._code_eq(lhs, item, negate=False))
                else:
                    if lhs_val is None:
                        lhs_val = self.value(lhs)
                    regs.append(
                        self.emit(B.EQ_NUM, lhs_val, self.value(item))
                    )
            reg = regs[0]
            for r in regs[1:]:
                reg = self.emit(B.OR, reg, r)
            return reg
        if name == "sameterm":
            a, b = e.args
            if not (isinstance(a, (A.VarRef, A.Lit)) and isinstance(b, (A.VarRef, A.Lit))):
                raise ExprCompileError("sameTerm arguments must be terms")
            return self._code_eq(a, b, negate=False)
        if name in _TEST_FUNCS:
            subject, rest = e.args[0], e.args[1:]
            for a in rest:
                if not isinstance(a, A.Lit):
                    raise ExprCompileError(
                        f"{name} pattern arguments must be constants"
                    )
            args = tuple(a.value for a in rest)
            if isinstance(subject, A.Lit):  # constant subject: fold
                tri = T.term_predicate(name, args)(subject.value)
                return self.const(math.nan if tri == T.ERROR else float(tri))
            if not isinstance(subject, A.VarRef):
                raise ExprCompileError(
                    f"{name} subject must be a variable or constant"
                )
            return self._test(name, args, subject.var)
        raise ExprCompileError(f"unknown function {name!r}")

    # -- finalize ----------------------------------------------------------

    def _finish(self, out_reg: int, source_ops: int) -> B.ExprProgram:
        # TEST's table operand was a table ordinal; rebase onto the icols
        # block (tables follow the code columns)
        base = len(self.code_vars)
        instrs = [
            (op, dst, a + base, b, c) if op == B.TEST else (op, dst, a, b, c)
            for (op, dst, a, b, c) in _dce(self.instrs, out_reg)
        ]
        instrs, n_regs, out_reg = _allocate(instrs, out_reg)
        return B.ExprProgram(
            instrs=tuple(instrs),
            n_regs=n_regs,
            out_reg=out_reg,
            consts=tuple(self.consts),
            code_vars=tuple(self.code_vars),
            num_vars=tuple(self.num_vars),
            tables=tuple(self.tables),
            source_ops=source_ops,
        )


def _reg_operands(instr: B.Instr) -> Tuple[int, ...]:
    op, _, a, b, c = instr
    if op in B.CODE_OPS or op in (B.LOAD_NUM, B.LOAD_CONST):
        return ()
    if op == B.NOT:
        return (a,)
    if op == B.IF:
        return (a, b, c)
    return (a, b)


def _dce(instrs: List[B.Instr], out_reg: int) -> List[B.Instr]:
    """Drop instructions whose result is never read (all ops are pure;
    constant folding leaves its operand LOAD_CONSTs behind). SSA names are
    unique, so one backward liveness sweep suffices."""
    live = {out_reg}
    keep: List[B.Instr] = []
    for ins in reversed(instrs):
        if ins[1] in live:
            live.update(_reg_operands(ins))
            keep.append(ins)
    keep.reverse()
    return keep


def _allocate(
    instrs: List[B.Instr], out_reg: int
) -> Tuple[List[B.Instr], int, int]:
    """Linear-scan rename: SSA names -> minimal register pool."""
    last_use = {out_reg: len(instrs)}
    for i, ins in enumerate(instrs):
        for r in _reg_operands(ins):
            last_use[r] = max(last_use.get(r, -1), i)
    mapping: Dict[int, int] = {}
    free: List[int] = []
    n_regs = 0
    out: List[B.Instr] = []
    for i, ins in enumerate(instrs):
        op, dst, a, b, c = ins
        regs = _reg_operands(ins)  # SSA operand names
        if op == B.NOT:
            a = mapping[a]
        elif op == B.IF:
            a, b, c = mapping[a], mapping[b], mapping[c]
        elif regs:
            a, b = mapping[a], mapping[b]
        for r in set(regs):  # free operands dying here (reads precede write)
            if last_use.get(r) == i:
                free.append(mapping[r])
        rd = free.pop() if free else n_regs
        n_regs = max(n_regs, rd + 1)
        mapping[dst] = rd
        out.append((op, rd, a, b, c))
    return out, max(n_regs, 1), mapping.get(out_reg, out_reg)


def _count_nodes(e: A.Expr) -> int:
    if isinstance(e, (A.VarRef, A.Lit, A.Bound)):
        return 1
    if isinstance(e, (A.Cmp, A.Arith)):
        return 1 + _count_nodes(e.lhs) + _count_nodes(e.rhs)
    if isinstance(e, (A.And, A.Or)):
        return 1 + sum(_count_nodes(t) for t in e.terms)
    if isinstance(e, A.Not):
        return 1 + _count_nodes(e.term)
    if isinstance(e, A.Func):
        return 1 + sum(_count_nodes(a) for a in e.args)
    return 1


def compile_expr(
    expr: A.Expr,
    dictionary: Optional[Dictionary],
    mode: str = "mask",
) -> B.ExprProgram:
    """Compile an expression tree. ``mode='mask'`` lowers in boolean
    context (FILTER / left-join condition), ``mode='value'`` in value
    context (BIND / ORDER BY / GROUP BY keys)."""
    bld = _Builder(dictionary)
    out = bld.boolean(expr) if mode == "mask" else bld.value(expr)
    return bld._finish(out, _count_nodes(expr))
