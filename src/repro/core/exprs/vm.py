"""Expression VM executor (DESIGN.md §9.3).

``_interp`` is the single semantic definition of the bytecode — a
straight-line pass over the instruction tuple, parameterized on the array
namespace. All three backends run it:

  * numpy  — the float64 oracle (this module), the engine's default;
  * jax    — repro.kernels.ref.expr_eval, the jit'd float32 reference;
  * pallas — repro.kernels.expr_eval, the fused TPU kernel: the *whole
    program* unrolls at trace time into one kernel body, so a batch costs
    one dispatch regardless of expression size (the paper's 'compile hot
    expressions' future-work note, realized as kernel specialization).

Host-side preparation stays O(columns + distinct terms): code columns are
raw int32 views, value columns decode through the numeric side-array with
one take, and term predicates (string tests, EBV, classification) evaluate
once per *dictionary entry* into a cached trinary table that is broadcast
per batch with another take — the hot loop never touches a string.

Backend note (DESIGN.md §2): the jnp/Pallas value plane is float32 (x64
stays off on TPU). Parity with the float64 oracle is exact whenever row
values are exactly representable in float32 — dictionary codes always are;
benchmarks and parity sweeps generate such values.
"""

from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.batch import ColumnBatch
from repro.core.dictionary import Dictionary
from repro.core.exprs import bytecode as B
from repro.core.exprs import terms as T

# ---------------------------------------------------------------------------
# dictionary-domain predicate tables
# ---------------------------------------------------------------------------

# tables live ON the dictionary (spec -> trinary int32 array), so their
# lifetime tracks the dictionary's and append-only growth extends a
# cached table incrementally as new terms are encoded
def predicate_table(d: Dictionary, spec: B.TableSpec) -> np.ndarray:
    cache: Dict[B.TableSpec, np.ndarray] = d.__dict__.setdefault(
        "_pred_tables", {}
    )
    table = cache.get(spec)
    n = len(d)
    if table is None or len(table) < n:
        fn = T.term_predicate(spec.func, spec.args)
        lo = 0 if table is None else len(table)
        ext = np.fromiter(
            (fn(d.decode(i)) for i in range(lo, n)), dtype=np.int32, count=n - lo
        )
        table = ext if table is None else np.concatenate([table, ext])
        cache[spec] = table
    return table


# ---------------------------------------------------------------------------
# input preparation (one take per referenced column; paper §2.2.1)
# ---------------------------------------------------------------------------


def prepare_inputs(
    prog: B.ExprProgram, batch: ColumnBatch, d: Optional[Dictionary]
) -> Tuple[np.ndarray, np.ndarray]:
    """(icols int32 (KI, n), fcols float64 (KF, n)) for a batch. Rows are
    the *physically filled* prefix (inactive rows produce garbage that the
    caller's mask-AND discards, same as every vectorized operator)."""
    n = batch.n_rows
    ki = max(prog.n_icols, 1)
    kf = max(prog.n_fcols, 1)
    icols = np.zeros((ki, n), dtype=np.int32)
    for i, var in enumerate(prog.code_vars):
        icols[i] = batch.column(var)
    for j, spec in enumerate(prog.tables):
        assert d is not None, "dictionary required for term predicates"
        table = predicate_table(d, spec)
        codes = batch.column(spec.var)
        # NULL codes take slot 0; TEST reads the code column for the error
        row = table[np.where(codes >= 0, codes, 0)] if len(table) else codes * 0
        icols[len(prog.code_vars) + j] = row
    fcols = np.full((kf, n), np.nan)
    for i, var in enumerate(prog.num_vars):
        assert d is not None, "dictionary required for value expressions"
        fcols[i] = d.numeric_of(batch.column(var))
    return icols, fcols


# ---------------------------------------------------------------------------
# the interpreter (shared by all three backends)
# ---------------------------------------------------------------------------


def _interp(xp, prog: B.ExprProgram, icols, fcols, dtype):
    """Evaluate ``prog`` over an input block. ``xp`` is numpy or
    jax.numpy; under jit / Pallas the python loop unrolls at trace time —
    the program IS the kernel. Returns (value, err) for the output
    register."""
    vals = [None] * prog.n_regs
    errs = [None] * prog.n_regs
    n = icols.shape[1]
    no_err = xp.zeros((n,), dtype=bool)
    null = icols == -1 if prog.n_icols else None

    def truthy(r):
        return vals[r] != 0

    for op, dst, a, b, c in prog.instrs:
        if op == B.LOAD_NUM:
            v = fcols[a].astype(dtype)
            vals[dst], errs[dst] = v, xp.isnan(fcols[a])
        elif op == B.LOAD_CONST:
            k = prog.consts[a]
            vals[dst] = xp.full((n,), k, dtype=dtype)
            vals[dst] = xp.where(xp.isfinite(vals[dst]), vals[dst], 0)
            errs[dst] = xp.full((n,), not np.isfinite(k), dtype=bool)
        elif op == B.BOUND:
            vals[dst] = (~null[a]).astype(dtype)
            errs[dst] = no_err
        elif op in (B.EQ_CODE, B.NE_CODE):
            eq = icols[a] == icols[b]
            vals[dst] = (eq if op == B.EQ_CODE else ~eq).astype(dtype)
            errs[dst] = null[a] | null[b]
        elif op in (B.EQ_CONST, B.NE_CONST):
            eq = icols[a] == b
            vals[dst] = (eq if op == B.EQ_CONST else ~eq).astype(dtype)
            errs[dst] = null[a]
        elif op == B.TEST:
            tri = icols[a]
            vals[dst] = (tri == T.TRUE).astype(dtype)
            errs[dst] = (tri == T.ERROR) | null[b]
        elif op in B.ARITH_OPS:
            x, y = vals[a], vals[b]
            if xp is np:
                with np.errstate(divide="ignore", invalid="ignore"):
                    v = _ARITH_FN[op](xp, x, y)
            else:
                v = _ARITH_FN[op](xp, x, y)
            fin = xp.isfinite(v)
            vals[dst] = xp.where(fin, v, 0)
            errs[dst] = errs[a] | errs[b] | ~fin
        elif op in B.CMP_OPS:
            vals[dst] = _CMP_FN[op](vals[a], vals[b]).astype(dtype)
            errs[dst] = errs[a] | errs[b]
        elif op == B.NOT:
            vals[dst] = (~truthy(a)).astype(dtype)
            errs[dst] = errs[a]
        elif op == B.AND:
            # Kleene: a definite false dominates the other side's error
            fa = ~truthy(a) & ~errs[a]
            fb = ~truthy(b) & ~errs[b]
            vals[dst] = (truthy(a) & truthy(b) & ~errs[a] & ~errs[b]).astype(dtype)
            errs[dst] = (errs[a] | errs[b]) & ~fa & ~fb
        elif op == B.OR:
            # Kleene: a definite true dominates the other side's error
            ta = truthy(a) & ~errs[a]
            tb = truthy(b) & ~errs[b]
            vals[dst] = (ta | tb).astype(dtype)
            errs[dst] = (errs[a] | errs[b]) & ~ta & ~tb
        elif op == B.IF:
            take_t = truthy(a)
            vals[dst] = xp.where(take_t, vals[b], vals[c])
            errs[dst] = errs[a] | xp.where(take_t, errs[b], errs[c])
        elif op == B.COALESCE:
            vals[dst] = xp.where(errs[a], vals[b], vals[a])
            errs[dst] = errs[a] & errs[b]
        else:  # pragma: no cover - opcode set is closed
            raise ValueError(f"bad opcode {op}")
    return vals[prog.out_reg], errs[prog.out_reg]


_ARITH_FN = {
    B.ADD: lambda xp, x, y: x + y,
    B.SUB: lambda xp, x, y: x - y,
    B.MUL: lambda xp, x, y: x * y,
    B.DIV: lambda xp, x, y: x / y,
}
_CMP_FN = {
    B.LT: lambda x, y: x < y,
    B.LE: lambda x, y: x <= y,
    B.GT: lambda x, y: x > y,
    B.GE: lambda x, y: x >= y,
    B.EQ_NUM: lambda x, y: x == y,
    B.NE_NUM: lambda x, y: x != y,
}


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def run_program(
    prog: B.ExprProgram,
    icols: np.ndarray,
    fcols: np.ndarray,
    backend: Optional[str] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """(value, err) over an input block, dispatched like every other
    kernel (numpy / jax / pallas via kernels.ops)."""
    from repro.kernels import ops as KOPS

    return KOPS.expr_eval(prog, icols, fcols, backend=backend)


def eval_program_mask(
    prog: B.ExprProgram,
    batch: ColumnBatch,
    d: Optional[Dictionary] = None,
    backend: Optional[str] = None,
) -> np.ndarray:
    """FILTER semantics: capacity-sized bool mask, True where the program
    evaluates to (three-valued) true — error rows are excluded. Drop-in
    for expressions.eval_expr_mask."""
    icols, fcols = prepare_inputs(prog, batch, d)
    val, err = run_program(prog, icols, fcols, backend)
    m = np.zeros(batch.capacity, dtype=bool)
    m[: batch.n_rows] = (np.asarray(val) != 0) & ~np.asarray(err)
    return m


def eval_program_values(
    prog: B.ExprProgram,
    batch: ColumnBatch,
    d: Dictionary,
    backend: Optional[str] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """BIND semantics: (float64 values, valid) over the filled prefix —
    drop-in for expressions.eval_expr_values (valid == not error)."""
    icols, fcols = prepare_inputs(prog, batch, d)
    val, err = run_program(prog, icols, fcols, backend)
    return np.asarray(val, dtype=np.float64), ~np.asarray(err)


class ProgramTimer:
    """Tiny accumulator the operators feed the profiler from: per-program
    fused-dispatch count and cumulative evaluation wall time."""

    __slots__ = ("dispatches", "wall_s", "_t0")

    def __init__(self) -> None:
        self.dispatches = 0
        self.wall_s = 0.0

    def __enter__(self) -> "ProgramTimer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.dispatches += 1
        self.wall_s += time.perf_counter() - self._t0
