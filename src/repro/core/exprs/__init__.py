"""Vectorized expression subsystem (DESIGN.md §9).

``compile_expr`` lowers an algebra.Expr tree to a flat register-based
bytecode program (constant folding, CSE, code/value operand split);
``eval_program_mask`` / ``eval_program_values`` execute it over a
ColumnBatch with exact three-valued SPARQL semantics on the numpy oracle,
the jit'd jnp reference, or the fused ``expr_eval`` Pallas kernel.
"""

from repro.core.exprs.bytecode import ExprProgram, TableSpec, disassemble
from repro.core.exprs.compiler import ExprCompileError, compile_expr
from repro.core.exprs.vm import (
    ProgramTimer,
    eval_program_mask,
    eval_program_values,
    prepare_inputs,
    run_program,
)

__all__ = [
    "ExprProgram",
    "TableSpec",
    "ExprCompileError",
    "ProgramTimer",
    "compile_expr",
    "disassemble",
    "eval_program_mask",
    "eval_program_values",
    "prepare_inputs",
    "run_program",
]
